#!/usr/bin/env bash
# CI entry point: tier-1 verification plus style and lint gates.
#
# Usage: ./ci.sh [--quick]
#   --quick  skip fmt/clippy (tier-1 only)
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: build =="
cargo build --release

echo "== tier-1: tests =="
cargo test -q

if [[ "${1:-}" != "--quick" ]]; then
    echo "== style: rustfmt =="
    cargo fmt --check

    echo "== lint: clippy =="
    cargo clippy --all-targets -- -D warnings
fi

echo "CI OK"
