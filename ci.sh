#!/usr/bin/env bash
# CI entry point: tier-1 verification plus style, lint and perf gates.
#
# Usage: ./ci.sh [--quick|--bench-smoke|--isa-smoke|--serve-smoke|--chaos-smoke]
#   --quick        tier-1 only (skip fmt/clippy, the per-ISA sweep and
#                  the bench smoke run)
#   --bench-smoke  only the shrunken hot-path bench + baseline gate
#   --isa-smoke    only the per-ISA CLI sweep over workloads/
#   --serve-smoke  only the live `osaca serve` session smoke test
#   --chaos-smoke  only the seeded fault-injection run against the
#                  live binary (worker panics, limits, oversized and
#                  torn frames must all degrade structurally)
set -euo pipefail
cd "$(dirname "$0")"

bench_smoke() {
    echo "== perf: hotpath bench (smoke) =="
    local fresh="${TMPDIR:-/tmp}/osaca-bench-smoke.json"
    OSACA_BENCH_SMOKE=1 OSACA_BENCH_JSON="$fresh" cargo bench --bench hotpath
    # Automated baseline gate (±20% on every shared derived rate).
    # While BENCH_hotpath.json is still the PR-3 placeholder the script
    # warns and passes; it arms itself once a real baseline is
    # committed. See scripts/check_bench_baseline.py. The serving
    # cases (steady-state req/s and the load-shed rejection path) must
    # exist in the fresh run regardless — a silently dropped serving
    # bench must not read as "no regression".
    if command -v python3 >/dev/null 2>&1; then
        OSACA_BENCH_REQUIRE=serve/req_s,serve/shed_latency \
            python3 scripts/check_bench_baseline.py BENCH_hotpath.json "$fresh"
    else
        echo "bench-baseline: WARNING — python3 unavailable, comparison skipped"
    fi
}

# Live-service smoke: boot `osaca serve` on an ephemeral port, drive it
# over the real socket with scripts/serve_smoke_client.py (analyzes on
# both shards, memo-hit check, stats consistency, wire shutdown), then
# require a clean drain of the server process. The rust integration
# tests cover the same surface in-process; this leg proves the shipped
# binary + a foreign-language client agree on the wire contract.
serve_smoke() {
    echo "== serve smoke: live osaca serve session =="
    if ! command -v python3 >/dev/null 2>&1; then
        echo "serve-smoke: WARNING — python3 unavailable, leg skipped"
        return 0
    fi
    cargo build --release
    local bin=./target/release/osaca
    local log="${TMPDIR:-/tmp}/osaca-serve-smoke.log"
    "$bin" serve --addr 127.0.0.1:0 --shards 2 >"$log" 2>&1 &
    local pid=$!
    local addr="" i
    for i in $(seq 1 100); do
        addr="$(sed -n 's/^serving on //p' "$log" | head -n1)"
        [[ -n "$addr" ]] && break
        if ! kill -0 "$pid" 2>/dev/null; then
            echo "serve-smoke: server died during startup"
            cat "$log"
            exit 1
        fi
        sleep 0.1
    done
    if [[ -z "$addr" ]]; then
        echo "serve-smoke: server never reported its address"
        cat "$log"
        kill "$pid" 2>/dev/null || true
        exit 1
    fi
    if ! python3 scripts/serve_smoke_client.py "$addr" 16; then
        kill "$pid" 2>/dev/null || true
        cat "$log"
        exit 1
    fi
    # The client sent the wire shutdown; the server must drain and exit
    # cleanly on its own.
    if ! wait "$pid"; then
        echo "serve-smoke: server exited non-zero after shutdown"
        cat "$log"
        exit 1
    fi
    if ! grep -q "drained cleanly" "$log"; then
        echo "serve-smoke: no clean-drain confirmation in the server log"
        cat "$log"
        exit 1
    fi
    echo "serve-smoke: OK"
}

# Chaos smoke: boot the shipped binary with seeded fault injection,
# per-connection limits and test ops armed, then drive it with the
# chaos mode of the smoke client. The fixed seed makes the fault
# schedule reproducible; the client proves every degradation is a
# structured frame, the panic counters are pinned nonzero, and the
# server still drains cleanly afterwards — the full ladder on the
# shipped binary, not just in-process.
chaos_smoke() {
    echo "== chaos smoke: seeded fault injection against the live binary =="
    if ! command -v python3 >/dev/null 2>&1; then
        echo "chaos-smoke: WARNING — python3 unavailable, leg skipped"
        return 0
    fi
    cargo build --release
    local bin=./target/release/osaca
    local log="${TMPDIR:-/tmp}/osaca-chaos-smoke.log"
    "$bin" serve --addr 127.0.0.1:0 --shards 2 --queue-depth 4 \
        --chaos 7117 --test-ops --max-rps 2 --burst 3 \
        --max-frame-bytes 65536 >"$log" 2>&1 &
    local pid=$!
    local addr="" i
    for i in $(seq 1 100); do
        addr="$(sed -n 's/^serving on //p' "$log" | head -n1)"
        [[ -n "$addr" ]] && break
        if ! kill -0 "$pid" 2>/dev/null; then
            echo "chaos-smoke: server died during startup"
            cat "$log"
            exit 1
        fi
        sleep 0.1
    done
    if [[ -z "$addr" ]]; then
        echo "chaos-smoke: server never reported its address"
        cat "$log"
        kill "$pid" 2>/dev/null || true
        exit 1
    fi
    if ! python3 scripts/serve_smoke_client.py "$addr" 12 --chaos; then
        kill "$pid" 2>/dev/null || true
        cat "$log"
        exit 1
    fi
    # Even after injected panics and torn frames, the wire shutdown
    # must drain the server cleanly.
    if ! wait "$pid"; then
        echo "chaos-smoke: server exited non-zero after shutdown"
        cat "$log"
        exit 1
    fi
    if ! grep -q "drained cleanly" "$log"; then
        echo "chaos-smoke: no clean-drain confirmation in the server log"
        cat "$log"
        exit 1
    fi
    echo "chaos-smoke: OK"
}

# Cross-ISA regression gate: run the CLI analyze path (parse + marker
# extraction + resolve + throughput + critpath) over every fixture in
# workloads/ against every ISA-matching built-in model — x86 fixtures
# on both skl and zen (the paper's cross-compile Table I cases
# included), tx2_* on tx2, rv64_* on rv64. Any parse/resolve error
# fails the leg; unit tests only cover the fixtures they name, this
# covers them all. Each analysis also runs a `--format json` leg piped
# through `python3 -m json.tool`, so a malformed byte from the
# hand-rolled emitter fails CI on every fixture × model combination.
isa_smoke() {
    echo "== per-ISA smoke: CLI analyze over workloads/ × {skl,zen,tx2,rv64} =="
    # Always (re)build: cargo makes this a no-op when fresh, and a
    # stale binary must never silently validate old code.
    cargo build --release
    local bin=./target/release/osaca
    local json_check=1
    if ! command -v python3 >/dev/null 2>&1; then
        json_check=0
        echo "per-ISA smoke: WARNING — python3 unavailable, JSON legs skipped"
    fi
    local fails=0 runs=0
    local f base archs arch
    for f in workloads/*/*.s; do
        base="$(basename "$f")"
        case "$base" in
            tx2_*)  archs="tx2" ;;
            rv64_*) archs="rv64" ;;
            skl_*)  archs="skl" ;;
            zen_*)  archs="zen" ;;
            *)      archs="skl zen" ;;
        esac
        for arch in $archs; do
            runs=$((runs + 1))
            if ! "$bin" analyze "$f" --arch "$arch" --critpath >/dev/null; then
                echo "FAIL: analyze $f --arch $arch"
                fails=$((fails + 1))
            fi
            if (( json_check )); then
                runs=$((runs + 1))
                if ! "$bin" analyze "$f" --arch "$arch" --critpath --frontend-bound \
                        --format json | python3 -m json.tool >/dev/null; then
                    echo "FAIL: analyze $f --arch $arch --format json"
                    fails=$((fails + 1))
                fi
            fi
        done
    done
    if (( fails > 0 )); then
        echo "per-ISA smoke: $fails of $runs analyses failed"
        exit 1
    fi
    echo "per-ISA smoke: OK ($runs analyses)"
}

case "${1:-}" in
    --bench-smoke)
        bench_smoke
        exit 0
        ;;
    --isa-smoke)
        isa_smoke
        exit 0
        ;;
    --serve-smoke)
        serve_smoke
        exit 0
        ;;
    --chaos-smoke)
        chaos_smoke
        exit 0
        ;;
esac

echo "== tier-1: build =="
cargo build --release

echo "== tier-1: tests =="
cargo test -q

if [[ "${1:-}" != "--quick" ]]; then
    # Release-mode tests: debug_assert!-guarded invariants (simulator
    # scheduling, decode wiring) must not mask different release-build
    # behavior — the retire-cursor invariant in sim/core.rs is
    # release-checked for exactly this reason.
    echo "== tier-1: tests (release) =="
    cargo test -q --release

    echo "== style: rustfmt =="
    cargo fmt --check

    echo "== lint: clippy =="
    cargo clippy --all-targets -- -W clippy::perf -D warnings

    # Every fixture × every matching model through the real CLI.
    isa_smoke

    # The shipped binary serving over a real socket to a python client.
    serve_smoke

    # The same binary under seeded fault injection: every degradation
    # must be a structured frame and the drain must stay clean.
    chaos_smoke

    # Hot-path regressions fail loudly at two levels: the smoke bench
    # asserts the cached-model and warm-resolution counters while
    # exercising the simulator, solver and api batch paths end to end,
    # and scripts/check_bench_baseline.py diffs the emitted rates
    # against the committed BENCH_hotpath.json within ±20%.
    bench_smoke
fi

echo "CI OK"
