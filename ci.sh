#!/usr/bin/env bash
# CI entry point: tier-1 verification plus style, lint and perf gates.
#
# Usage: ./ci.sh [--quick|--bench-smoke]
#   --quick        tier-1 only (skip fmt/clippy and the bench smoke run)
#   --bench-smoke  only the shrunken hot-path bench (perf smoke gate)
set -euo pipefail
cd "$(dirname "$0")"

bench_smoke() {
    echo "== perf: hotpath bench (smoke) =="
    OSACA_BENCH_SMOKE=1 cargo bench --bench hotpath
}

if [[ "${1:-}" == "--bench-smoke" ]]; then
    bench_smoke
    exit 0
fi

echo "== tier-1: build =="
cargo build --release

echo "== tier-1: tests =="
cargo test -q

if [[ "${1:-}" != "--quick" ]]; then
    # Release-mode tests: debug_assert!-guarded invariants (simulator
    # scheduling, decode wiring) must not mask different release-build
    # behavior — the retire-cursor invariant in sim/core.rs is
    # release-checked for exactly this reason.
    echo "== tier-1: tests (release) =="
    cargo test -q --release

    echo "== style: rustfmt =="
    cargo fmt --check

    echo "== lint: clippy =="
    cargo clippy --all-targets -- -W clippy::perf -D warnings

    # Hot-path regressions fail loudly at the invariant level: the smoke
    # bench asserts the cached-model and warm-resolution counters while
    # exercising the simulator, solver and api batch paths end to end.
    # Absolute throughput is compared manually against the committed
    # BENCH_hotpath.json baseline (regenerate with a full
    # `cargo bench --bench hotpath` and commit the diff).
    bench_smoke
fi

echo "CI OK"
