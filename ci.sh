#!/usr/bin/env bash
# CI entry point: tier-1 verification plus style, lint and perf gates.
#
# Usage: ./ci.sh [--quick|--bench-smoke|--isa-smoke|--serve-smoke|--chaos-smoke|--corpus-smoke|--mem-smoke|--zoo-smoke]
#   --quick        tier-1 only (skip fmt/clippy, the per-ISA sweep and
#                  the bench smoke run)
#   --bench-smoke  only the shrunken hot-path bench + baseline gate
#   --isa-smoke    only the per-ISA CLI sweep over workloads/
#   --serve-smoke  only the live `osaca serve` session smoke test
#   --chaos-smoke  only the seeded fault-injection run against the
#                  live binary (worker panics, limits, oversized and
#                  torn frames must all degrade structurally)
#   --corpus-smoke only the corpus pipeline: gen_corpus.py synthesizes
#                  blocks, `osaca corpus` scores them, and the JSON
#                  scorecard must validate and reproduce byte-for-byte
#   --mem-smoke    only the cache-aware working-set sweep on the
#                  release binary: predictions must be monotone
#                  non-decreasing in footprint and the L1-resident
#                  point must equal the infinite-L1 prediction
#   --zoo-smoke    only the model-zoo pipeline: import-model compiles
#                  the vendored uops.info fixture into .mdb models,
#                  zoo-sweep scores every fixture × every registered
#                  model, and the scorecard must validate, be
#                  byte-reproducible, and carry no errors in the
#                  imported-model cells
set -euo pipefail
cd "$(dirname "$0")"

# Legs that need python3 call this first. On a dev box a missing
# interpreter downgrades the leg to a loud skip (return 1 so the
# caller can bail out of its own body); on a CI runner it is a hard
# failure — a gate that silently skips on the runners is no gate.
require_python3() {
    local leg="$1"
    if command -v python3 >/dev/null 2>&1; then
        return 0
    fi
    if [[ "${CI:-}" == "true" ]]; then
        echo "$leg: FAILED — python3 unavailable in CI"
        exit 1
    fi
    echo "$leg: WARNING — python3 unavailable, leg skipped"
    return 1
}

bench_smoke() {
    echo "== perf: hotpath bench (smoke) =="
    local fresh="${TMPDIR:-/tmp}/osaca-bench-smoke.json"
    OSACA_BENCH_SMOKE=1 OSACA_BENCH_JSON="$fresh" cargo bench --bench hotpath
    # Automated baseline gate (±20% on every shared derived rate).
    # While BENCH_hotpath.json is still the PR-3 placeholder the script
    # warns and passes; it arms itself once a real baseline is
    # committed. See scripts/check_bench_baseline.py. The serving
    # cases (steady-state req/s and the load-shed rejection path) must
    # exist in the fresh run regardless — a silently dropped serving
    # bench must not read as "no regression" — and so must the two
    # cache-aware simulator cases.
    if require_python3 bench-baseline; then
        OSACA_BENCH_REQUIRE=serve/req_s,serve/shed_latency,corpus/blocks_per_s,exec/steal_overhead,sim/mem_l1_resident,sim/mem_sweep,mdb/registry_lazy_load \
            python3 scripts/check_bench_baseline.py BENCH_hotpath.json "$fresh"
    fi
}

# Live-service smoke: boot `osaca serve` on an ephemeral port, drive it
# over the real socket with scripts/serve_smoke_client.py (analyzes on
# both shards, memo-hit check, stats consistency, wire shutdown), then
# require a clean drain of the server process. The rust integration
# tests cover the same surface in-process; this leg proves the shipped
# binary + a foreign-language client agree on the wire contract.
serve_smoke() {
    echo "== serve smoke: live osaca serve session =="
    require_python3 serve-smoke || return 0
    cargo build --release
    local bin=./target/release/osaca
    local log="${TMPDIR:-/tmp}/osaca-serve-smoke.log"
    "$bin" serve --addr 127.0.0.1:0 --shards 2 >"$log" 2>&1 &
    local pid=$!
    local addr="" i
    for i in $(seq 1 100); do
        addr="$(sed -n 's/^serving on //p' "$log" | head -n1)"
        [[ -n "$addr" ]] && break
        if ! kill -0 "$pid" 2>/dev/null; then
            echo "serve-smoke: server died during startup"
            cat "$log"
            exit 1
        fi
        sleep 0.1
    done
    if [[ -z "$addr" ]]; then
        echo "serve-smoke: server never reported its address"
        cat "$log"
        kill "$pid" 2>/dev/null || true
        exit 1
    fi
    if ! python3 scripts/serve_smoke_client.py "$addr" 16; then
        kill "$pid" 2>/dev/null || true
        cat "$log"
        exit 1
    fi
    # The client sent the wire shutdown; the server must drain and exit
    # cleanly on its own.
    if ! wait "$pid"; then
        echo "serve-smoke: server exited non-zero after shutdown"
        cat "$log"
        exit 1
    fi
    if ! grep -q "drained cleanly" "$log"; then
        echo "serve-smoke: no clean-drain confirmation in the server log"
        cat "$log"
        exit 1
    fi
    echo "serve-smoke: OK"
}

# Chaos smoke: boot the shipped binary with seeded fault injection,
# per-connection limits and test ops armed, then drive it with the
# chaos mode of the smoke client. The fixed seed makes the fault
# schedule reproducible; the client proves every degradation is a
# structured frame, the panic counters are pinned nonzero, and the
# server still drains cleanly afterwards — the full ladder on the
# shipped binary, not just in-process.
chaos_smoke() {
    echo "== chaos smoke: seeded fault injection against the live binary =="
    require_python3 chaos-smoke || return 0
    cargo build --release
    local bin=./target/release/osaca
    local log="${TMPDIR:-/tmp}/osaca-chaos-smoke.log"
    "$bin" serve --addr 127.0.0.1:0 --shards 2 --queue-depth 4 \
        --chaos 7117 --test-ops --max-rps 2 --burst 3 \
        --max-frame-bytes 65536 >"$log" 2>&1 &
    local pid=$!
    local addr="" i
    for i in $(seq 1 100); do
        addr="$(sed -n 's/^serving on //p' "$log" | head -n1)"
        [[ -n "$addr" ]] && break
        if ! kill -0 "$pid" 2>/dev/null; then
            echo "chaos-smoke: server died during startup"
            cat "$log"
            exit 1
        fi
        sleep 0.1
    done
    if [[ -z "$addr" ]]; then
        echo "chaos-smoke: server never reported its address"
        cat "$log"
        kill "$pid" 2>/dev/null || true
        exit 1
    fi
    if ! python3 scripts/serve_smoke_client.py "$addr" 12 --chaos; then
        kill "$pid" 2>/dev/null || true
        cat "$log"
        exit 1
    fi
    # Even after injected panics and torn frames, the wire shutdown
    # must drain the server cleanly.
    if ! wait "$pid"; then
        echo "chaos-smoke: server exited non-zero after shutdown"
        cat "$log"
        exit 1
    fi
    if ! grep -q "drained cleanly" "$log"; then
        echo "chaos-smoke: no clean-drain confirmation in the server log"
        cat "$log"
        exit 1
    fi
    echo "chaos-smoke: OK"
}

# Corpus smoke: synthesize a corpus of basic blocks from the workload
# fixtures, score it with the shipped `osaca corpus` binary, and gate
# on three properties: the scorecard validates (schema tag, block
# count, zero errors, histogram totals), two runs over the same corpus
# are byte-identical (the executor must not leak scheduling order into
# aggregates), and the tar-archive loader agrees with the directory
# loader. A self-derived measured-cycles sidecar then pins the MAPE
# path at ~0.
corpus_smoke() {
    echo "== corpus smoke: gen_corpus.py → osaca corpus scorecard =="
    require_python3 corpus-smoke || return 0
    cargo build --release
    local bin=./target/release/osaca
    local dir="${TMPDIR:-/tmp}/osaca-corpus-smoke"
    rm -rf "$dir"
    mkdir -p "$dir"
    python3 scripts/gen_corpus.py --out "$dir/blocks" --count 60 --seed 7117 \
        --tar "$dir/blocks.tar"
    "$bin" corpus "$dir/blocks" --arch skl --format json >"$dir/run_a.json"
    "$bin" corpus "$dir/blocks" --arch skl --format json >"$dir/run_b.json"
    if ! cmp -s "$dir/run_a.json" "$dir/run_b.json"; then
        echo "corpus-smoke: scorecard is not reproducible across runs"
        diff "$dir/run_a.json" "$dir/run_b.json" || true
        exit 1
    fi
    "$bin" corpus "$dir/blocks.tar" --arch skl --format json >"$dir/run_tar.json"
    if ! cmp -s "$dir/run_a.json" "$dir/run_tar.json"; then
        echo "corpus-smoke: tar loader disagrees with the directory loader"
        exit 1
    fi
    python3 - "$dir/run_a.json" "$dir/measured.csv" <<'EOF'
import json, sys
card = json.load(open(sys.argv[1]))
assert card["schema_version"] == 5, card["schema_version"]
assert card["kind"] == "corpus_scorecard", card["kind"]
assert card["blocks"] == 60, card["blocks"]
assert len(card["scores"]) == 60
assert card["errors"] == 0, [s for s in card["scores"] if s["error"]]
assert sum(card["histogram"].values()) == 60, card["histogram"]
assert card["mape_pct"] is None and card["measured_blocks"] == 0
with open(sys.argv[2], "w") as f:
    f.write("name,cycles\n")
    for s in card["scores"]:
        f.write(f"{s['name']},{s['cy_per_asm_iter']}\n")
EOF
    "$bin" corpus "$dir/blocks" --arch skl --format json \
        --measured "$dir/measured.csv" >"$dir/run_measured.json"
    python3 - "$dir/run_measured.json" <<'EOF'
import json, sys
card = json.load(open(sys.argv[1]))
assert card["measured_blocks"] == 60, card["measured_blocks"]
# Predictions measured against themselves: MAPE ~0 up to the f32→text
# →f64 round trip.
assert card["mape_pct"] is not None and card["mape_pct"] < 1e-4, card["mape_pct"]
EOF
    echo "corpus-smoke: OK"
}

# Memory-model smoke: run the cache-aware working-set sweep on the
# release binary and gate the two invariants the opt-in mode promises
# (DESIGN.md §12). Predictions must be monotone non-decreasing in
# footprint — a larger working set can never get faster — and the
# L1-resident point must equal the infinite-L1 prediction exactly,
# because that equality is what keeps every paper-pinned table valid
# with the feature merged. The JSON must also survive an independent
# parser, like every other emitter leg.
mem_smoke() {
    echo "== mem smoke: cache-aware working-set sweep =="
    require_python3 mem-smoke || return 0
    cargo build --release
    local bin=./target/release/osaca
    local out="${TMPDIR:-/tmp}/osaca-mem-smoke.json"
    "$bin" mem-sweep --arch skl --format json >"$out"
    python3 -m json.tool "$out" >/dev/null
    python3 - "$out" <<'EOF'
import json, sys
card = json.load(open(sys.argv[1]))
assert card["schema_version"] == 5, card["schema_version"]
assert card["kind"] == "mem_sweep", card["kind"]
pts = card["points"]
assert len(pts) >= 3, pts
cys = [p["cy_per_asm_iter"] for p in pts]
assert cys == sorted(cys), f"sweep not monotone non-decreasing: {cys}"
# The smallest default size (16 KiB) is L1-resident: the cache-aware
# prediction must collapse to the infinite-L1 one, bit for bit.
assert pts[0]["cy_per_asm_iter"] == pts[0]["infinite_l1_cy"], pts[0]
assert pts[0]["level"] == "l1", pts[0]
# And the sweep must actually leave L1: at least one memory-bound point.
assert any(p["bound"] == "memory" for p in pts), cys
EOF
    echo "mem-smoke: OK"
}

# Model-zoo smoke: compile the vendored uops.info-format fixture into
# .mdb models with the shipped binary, then run the cross-model
# validation sweep twice from the scanned models directory. Gates:
# every import emits valid JSON and a loadable .mdb file, the sweep
# scorecard validates (schema tag, imported models present, every x86
# fixture covered per imported model, zero errors in imported cells),
# and two runs are byte-identical — model order and cell contents must
# be deterministic.
zoo_smoke() {
    echo "== zoo smoke: import-model → zoo-sweep scorecard =="
    require_python3 zoo-smoke || return 0
    cargo build --release
    local bin=./target/release/osaca
    local dir="${TMPDIR:-/tmp}/osaca-zoo-smoke"
    rm -rf "$dir"
    mkdir -p "$dir/models"
    local xml=rust/tests/fixtures/uops_trimmed.xml
    local arch
    for arch in clx icl zen2; do
        "$bin" import-model "$xml" --arch "$arch" --out "$dir/models" \
            --format json >"$dir/import_$arch.json"
        python3 -m json.tool "$dir/import_$arch.json" >/dev/null
        if [[ ! -s "$dir/models/$arch.mdb" ]]; then
            echo "zoo-smoke: import-model wrote no $arch.mdb"
            exit 1
        fi
    done
    "$bin" zoo-sweep --models-dir "$dir/models" --format json >"$dir/sweep_a.json"
    "$bin" zoo-sweep --models-dir "$dir/models" --format json >"$dir/sweep_b.json"
    if ! cmp -s "$dir/sweep_a.json" "$dir/sweep_b.json"; then
        echo "zoo-smoke: sweep scorecard is not reproducible across runs"
        diff "$dir/sweep_a.json" "$dir/sweep_b.json" || true
        exit 1
    fi
    python3 -m json.tool "$dir/sweep_a.json" >/dev/null
    python3 - "$dir/sweep_a.json" <<'EOF'
import json, sys
card = json.load(open(sys.argv[1]))
assert card["schema_version"] == 5, card["schema_version"]
assert card["kind"] == "zoo_sweep", card["kind"]
imported = {"clx", "icl", "zen2"}
assert imported <= set(card["models"]), card["models"]
cells = card["cells"]
x86 = {c["workload"] for c in cells if c["isa"] == "x86"}
assert len(x86) >= 10, x86
for m in sorted(imported):
    mine = [c for c in cells if c["model"] == m]
    assert {c["workload"] for c in mine} == x86, (m, x86)
    bad = [c for c in mine if "error" in c]
    assert not bad, (m, bad)
    assert all(c["cy_per_asm_iter"] > 0 for c in mine), m
EOF
    echo "zoo-smoke: OK"
}

# Cross-ISA regression gate: run the CLI analyze path (parse + marker
# extraction + resolve + throughput + critpath) over every fixture in
# workloads/ against every ISA-matching built-in model — x86 fixtures
# on both skl and zen (the paper's cross-compile Table I cases
# included), tx2_* on tx2, rv64_* on rv64. Any parse/resolve error
# fails the leg; unit tests only cover the fixtures they name, this
# covers them all. Each analysis also runs a `--format json` leg piped
# through `python3 -m json.tool`, so a malformed byte from the
# hand-rolled emitter fails CI on every fixture × model combination.
isa_smoke() {
    echo "== per-ISA smoke: CLI analyze over workloads/ × {skl,zen,tx2,rv64} =="
    # Always (re)build: cargo makes this a no-op when fresh, and a
    # stale binary must never silently validate old code.
    cargo build --release
    local bin=./target/release/osaca
    local json_check=1
    if ! require_python3 per-ISA-smoke; then
        json_check=0
    fi
    local fails=0 runs=0
    local f base archs arch
    for f in workloads/*/*.s; do
        base="$(basename "$f")"
        case "$base" in
            tx2_*)  archs="tx2" ;;
            rv64_*) archs="rv64" ;;
            skl_*)  archs="skl" ;;
            zen_*)  archs="zen" ;;
            *)      archs="skl zen" ;;
        esac
        for arch in $archs; do
            runs=$((runs + 1))
            if ! "$bin" analyze "$f" --arch "$arch" --critpath >/dev/null; then
                echo "FAIL: analyze $f --arch $arch"
                fails=$((fails + 1))
            fi
            if (( json_check )); then
                runs=$((runs + 1))
                if ! "$bin" analyze "$f" --arch "$arch" --critpath --frontend-bound \
                        --format json | python3 -m json.tool >/dev/null; then
                    echo "FAIL: analyze $f --arch $arch --format json"
                    fails=$((fails + 1))
                fi
            fi
        done
    done
    if (( fails > 0 )); then
        echo "per-ISA smoke: $fails of $runs analyses failed"
        exit 1
    fi
    echo "per-ISA smoke: OK ($runs analyses)"
}

case "${1:-}" in
    --bench-smoke)
        bench_smoke
        exit 0
        ;;
    --isa-smoke)
        isa_smoke
        exit 0
        ;;
    --serve-smoke)
        serve_smoke
        exit 0
        ;;
    --chaos-smoke)
        chaos_smoke
        exit 0
        ;;
    --corpus-smoke)
        corpus_smoke
        exit 0
        ;;
    --mem-smoke)
        mem_smoke
        exit 0
        ;;
    --zoo-smoke)
        zoo_smoke
        exit 0
        ;;
esac

echo "== tier-1: build =="
cargo build --release

echo "== tier-1: tests =="
cargo test -q

if [[ "${1:-}" != "--quick" ]]; then
    # Release-mode tests: debug_assert!-guarded invariants (simulator
    # scheduling, decode wiring) must not mask different release-build
    # behavior — the retire-cursor invariant in sim/core.rs is
    # release-checked for exactly this reason.
    echo "== tier-1: tests (release) =="
    cargo test -q --release

    echo "== style: rustfmt =="
    cargo fmt --check

    echo "== lint: clippy =="
    cargo clippy --all-targets -- -W clippy::perf -D warnings

    # Every fixture × every matching model through the real CLI.
    isa_smoke

    # The cache-aware working-set sweep on the shipped binary:
    # monotonicity + L1-resident/infinite-L1 equality.
    mem_smoke

    # The shipped binary serving over a real socket to a python client.
    serve_smoke

    # The same binary under seeded fault injection: every degradation
    # must be a structured frame and the drain must stay clean.
    chaos_smoke

    # The corpus pipeline end to end: synthesized blocks, reproducible
    # scorecard, tar/dir loader agreement, MAPE sidecar.
    corpus_smoke

    # The model zoo end to end: uops.info fixture → import-model →
    # reproducible, error-free zoo-sweep scorecard.
    zoo_smoke

    # Hot-path regressions fail loudly at two levels: the smoke bench
    # asserts the cached-model and warm-resolution counters while
    # exercising the simulator, solver and api batch paths end to end,
    # and scripts/check_bench_baseline.py diffs the emitted rates
    # against the committed BENCH_hotpath.json within ±20%.
    bench_smoke
fi

echo "CI OK"
