#!/usr/bin/env python3
"""Synthesize a corpus of .s basic blocks by mutating the workload fixtures.

Reads every fixture under --workloads for the selected ISA, strips
comments, labels, branches and IACA/OSACA marker pairs down to a bare
straightline basic block (BHive-style: no markers, no back-edge — the
analyzer's whole-file-as-kernel fallback picks it up), then emits
--count mutated variants:

  * register rename — a seeded permutation of the ISA's vector
    register file, applied consistently within the block;
  * reorder        — a seeded shuffle of the instruction lines;
  * unroll         — the block body repeated 1/2/4 times.

Everything is driven by one random.Random(--seed), so the same seed
and fixture set produce a byte-identical corpus (CI relies on this to
diff two `osaca corpus` runs).

Usage:
  python3 scripts/gen_corpus.py --out /tmp/corpus --count 60 --seed 0
  python3 scripts/gen_corpus.py --out /tmp/corpus --tar /tmp/corpus.tar
"""

import argparse
import io
import pathlib
import random
import re
import sys
import tarfile

# Marker prologue/epilogue lines (x86, aarch64 and riscv flavors) plus
# the encoded-nop .byte lines that accompany them.
MARKER_RE = re.compile(
    r"^\s*(\.byte\b|movl\s+\$(111|222)\b|mov\s+x1,\s*#(111|222)\b|li\s+t0,\s*(111|222)\b)"
)
LABEL_RE = re.compile(r"^\s*[.\w$]+:\s*$")
BRANCH_RE = {
    "x86": re.compile(r"^\s*(j[a-z]+)\s"),
    "aarch64": re.compile(r"^\s*(b\.?[a-z]*|cbn?z|tbn?z)\s"),
    "riscv": re.compile(r"^\s*(beq|bne|blt|bge|bltu|bgeu|j|jal|jalr)\s"),
}
COMMENT_PREFIXES = ("#", "//", ";")

# Vector register families whose indices a rename permutes. GP/pointer
# registers are left alone: a textual rename could alias a base pointer
# onto the stack pointer or a loop counter.
RENAME = {
    "x86": (re.compile(r"%(ymm|xmm)(\d+)\b"), 16, "%{family}{idx}"),
    "aarch64": (re.compile(r"\b(v|q)(\d+)\b"), 32, "{family}{idx}"),
    "riscv": (re.compile(r"\b(fa)(\d+)\b"), 8, "{family}{idx}"),
}


def isa_of(path: pathlib.Path) -> str:
    name = path.name
    if "rv64" in name:
        return "riscv"
    if "tx2" in name:
        return "aarch64"
    return "x86"


def to_basic_block(text: str, isa: str) -> list[str]:
    """Strip a fixture to its bare instruction lines."""
    out = []
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith(COMMENT_PREFIXES):
            continue
        if MARKER_RE.match(line) or LABEL_RE.match(line):
            continue
        if BRANCH_RE[isa].match(line) or line == "ret":
            continue
        out.append(line)
    return out


def rename_registers(lines: list[str], isa: str, rng: random.Random) -> list[str]:
    pattern, nregs, template = RENAME[isa]
    perm = list(range(nregs))
    rng.shuffle(perm)

    def sub(m: re.Match) -> str:
        return template.format(family=m.group(1), idx=perm[int(m.group(2))])

    return [pattern.sub(sub, l) for l in lines]


def mutate(lines: list[str], isa: str, rng: random.Random) -> list[str]:
    body = rename_registers(lines, isa, rng)
    if rng.random() < 0.5:
        rng.shuffle(body)
    unroll = rng.choice([1, 1, 2, 4])
    return body * unroll


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", required=True, help="output directory for block_NNNN.s files")
    ap.add_argument("--count", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--isa",
        default="x86",
        choices=["x86", "aarch64", "riscv", "all"],
        help="restrict source fixtures to one ISA (a corpus is scored "
        "against one machine model, so mixing ISAs yields error rows)",
    )
    ap.add_argument("--workloads", default="workloads", help="fixture directory")
    ap.add_argument("--tar", help="also pack the corpus into this ustar archive")
    args = ap.parse_args()

    fixtures = sorted(pathlib.Path(args.workloads).rglob("*.s"))
    sources = []
    for f in fixtures:
        isa = isa_of(f)
        if args.isa != "all" and isa != args.isa:
            continue
        block = to_basic_block(f.read_text(), isa)
        if block:
            sources.append((isa, block))
    if not sources:
        print(f"no {args.isa} fixtures under {args.workloads}", file=sys.stderr)
        return 1

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    rng = random.Random(args.seed)
    names = []
    for i in range(args.count):
        isa, block = sources[i % len(sources)]
        lines = mutate(block, isa, rng)
        name = f"block_{i:04d}.s"
        (out / name).write_text("\n".join(lines) + "\n")
        names.append(name)

    if args.tar:
        # Fixed metadata so the archive is byte-stable across runs.
        with tarfile.open(args.tar, "w", format=tarfile.USTAR_FORMAT) as tf:
            for name in sorted(names):
                info = tarfile.TarInfo(name=name)
                data = (out / name).read_bytes()
                info.size = len(data)
                info.mtime = 0
                info.mode = 0o644
                tf.addfile(info, fileobj=io.BytesIO(data))

    print(f"wrote {len(names)} blocks to {out}" + (f" and {args.tar}" if args.tar else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
