#!/usr/bin/env python3
"""Smoke client for the `osaca serve` TCP service (ci.sh --serve-smoke).

Usage: serve_smoke_client.py <host:port> <n_requests> [--chaos]

Default mode drives one live server end to end over the real socket:

* sends <n_requests> `analyze` frames (alternating the shipped skl and
  rv64 triad fixtures so both shards and both ISAs are exercised),
  asserting every response is a schema-versioned `ok` frame whose
  embedded JSON report parses;
* asserts at least one `memo_hit:true` response once a fingerprint
  repeats (n_requests >= 3 guarantees a repeat);
* requests `stats` and asserts the counters cover the analyzes sent;
* sends `shutdown` and asserts the `bye` acknowledgement.

`--chaos` mode (ci.sh --chaos-smoke) expects a server booted with
`--chaos <seed> --test-ops --max-rps 2 --burst 3 --max-frame-bytes
65536` and proves the degradation ladder on the shipped binary:

* the analyze sweep tolerates every structured degradation frame
  (`overloaded`, `rate_limited`, redacted `internal_error`,
  `solver_timeout`, `deadline_exceeded`) but nothing unstructured;
* a `panic` probe must answer the redacted frame and the connection
  must recover to an `ok` within a bounded retry loop;
* an oversized frame answers `frame_too_large` and the connection
  survives; a torn/blank-line frame reassembles;
* `stats` must pin the fault counters (panics, worker_restarts,
  oversized_frames, rate_limited) as nonzero;
* the wire shutdown still acknowledges with `bye`.

Exits non-zero (with a diagnostic on stderr) on the first violated
expectation. The caller owns the server process and checks its clean
exit separately.
"""
import json
import socket
import sys
import time

SCHEMA_VERSION = 5

SKL_SOURCE = "workloads/triad/skl_o3.s"
RV64_SOURCE = "workloads/triad/rv64_o2.s"

# Structured degradation statuses a chaotic server may answer.
CHAOS_STATUSES = {"ok", "overloaded", "rate_limited"}
CHAOS_ERROR_KINDS = {
    "internal_error",
    "solver_timeout",
    "deadline_exceeded",
    "frame_too_large",
}


def fail(msg):
    print(f"serve-smoke: FAIL — {msg}", file=sys.stderr)
    sys.exit(1)


def request_frames():
    with open(SKL_SOURCE) as f:
        skl = f.read()
    with open(RV64_SOURCE) as f:
        rv64 = f.read()
    return [
        {
            "op": "analyze",
            "name": "smoke-skl",
            "arch": "skl",
            "source": skl,
            "passes": ["throughput"],
            "unroll": 4,
        },
        {
            "op": "analyze",
            "name": "smoke-rv64",
            "arch": "rv64",
            "source": rv64,
            "passes": ["throughput", "critpath"],
            "frontend_bound": True,
            "unroll": 1,
        },
    ]


def check_chaos_frame(i, resp):
    """A chaotic server may degrade, but only into structured frames."""
    if resp.get("schema_version") != SCHEMA_VERSION:
        fail(f"response {i}: schema_version {resp.get('schema_version')}: {resp}")
    status = resp.get("status")
    if status in CHAOS_STATUSES:
        return status
    if status == "error":
        kind = resp.get("error", {}).get("kind")
        if kind in CHAOS_ERROR_KINDS:
            return f"error:{kind}"
        fail(f"response {i}: unexpected error kind: {resp}")
    fail(f"response {i}: unstructured degradation: {resp}")


def chaos_session(sock, rfile, round_trip, n):
    templates = request_frames()
    seen = {}
    for i in range(n):
        frame = dict(templates[i % len(templates)])
        # Generous deadline: exercises the deadline plumbing end to
        # end; expiry under an injected stall is a tolerated outcome.
        frame["deadline_ms"] = 2000
        outcome = check_chaos_frame(i, round_trip(frame))
        seen[outcome] = seen.get(outcome, 0) + 1

    # Deterministic panic probe: the redacted frame, then recovery.
    resp = round_trip({"op": "panic"})
    if resp.get("status") != "error":
        fail(f"panic probe: {resp}")
    if resp.get("error", {}).get("kind") != "internal_error":
        fail(f"panic probe kind: {resp}")
    if resp.get("error", {}).get("message") != "injected_test_panic":
        fail(f"panic payload not redacted: {resp}")
    for _ in range(20):
        time.sleep(0.6)  # also refills the 2 rps token bucket
        resp = round_trip(templates[0])
        check_chaos_frame("recovery", resp)
        if resp.get("status") == "ok":
            break
    else:
        fail("connection never recovered to an ok after the panic probe")

    # Oversized frame: structured rejection, connection survives.
    sock.sendall(b"x" * 100_000 + b"\n")
    line = rfile.readline()
    resp = json.loads(line)
    if resp.get("error", {}).get("kind") != "frame_too_large":
        fail(f"oversized probe: {resp}")
    check_chaos_frame("post-oversized", round_trip(templates[0]))

    # Torn frame with wire noise: blank line, split writes, CRLF.
    payload = json.dumps(templates[0]).encode()
    sock.sendall(b"\n")
    sock.sendall(payload[: len(payload) // 2])
    time.sleep(0.2)
    sock.sendall(payload[len(payload) // 2 :])
    sock.sendall(b"\r\n")
    check_chaos_frame("torn", json.loads(rfile.readline()))

    stats = round_trip({"op": "stats"})
    if stats.get("status") != "stats":
        fail(f"stats frame: {stats}")
    if stats.get("schema_version") != SCHEMA_VERSION:
        fail(f"stats schema_version: {stats}")
    for counter in ("panics", "worker_restarts", "oversized_frames", "rate_limited"):
        if stats.get(counter, 0) < 1:
            fail(f"stats.{counter} = {stats.get(counter)} — fault never recorded: {stats}")
    if stats.get("served", 0) < n:
        fail(f"stats.served {stats.get('served')} < {n} analyzes sent")

    bye = round_trip({"op": "shutdown"})
    if bye.get("status") != "bye":
        fail(f"shutdown acknowledgement: {bye}")

    mix = ", ".join(f"{k}×{v}" for k, v in sorted(seen.items()))
    print(
        f"serve-smoke: OK (chaos) — {n} analyzes degraded only structurally "
        f"({mix}); panic redacted + recovered; oversized and torn frames "
        f"survived; fault counters pinned; clean shutdown"
    )
    return 0


def main():
    if len(sys.argv) not in (3, 4) or (len(sys.argv) == 4 and sys.argv[3] != "--chaos"):
        print(__doc__, file=sys.stderr)
        return 2
    host, _, port = sys.argv[1].rpartition(":")
    n = int(sys.argv[2])
    chaos = len(sys.argv) == 4

    sock = socket.create_connection((host, int(port)), timeout=30)
    rfile = sock.makefile("r", encoding="utf-8")

    def round_trip(frame):
        sock.sendall((json.dumps(frame) + "\n").encode())
        line = rfile.readline()
        if not line:
            fail("server closed the connection mid-session")
        try:
            return json.loads(line)
        except json.JSONDecodeError as e:
            fail(f"unparseable response frame: {e}: {line!r}")

    if chaos:
        return chaos_session(sock, rfile, round_trip, n)

    templates = request_frames()
    memo_hits = 0
    for i in range(n):
        resp = round_trip(templates[i % len(templates)])
        if resp.get("schema_version") != SCHEMA_VERSION:
            fail(f"response {i}: schema_version {resp.get('schema_version')}")
        if resp.get("status") != "ok":
            fail(f"response {i}: status {resp.get('status')}: {resp}")
        report = resp.get("report")
        if not isinstance(report, dict) or "prediction" not in report:
            fail(f"response {i}: malformed embedded report: {resp}")
        if resp.get("memo_hit"):
            memo_hits += 1
    if n >= 3 and memo_hits == 0:
        fail("no memo hit despite repeated fingerprints")

    stats = round_trip({"op": "stats"})
    if stats.get("status") != "stats":
        fail(f"stats frame: {stats}")
    if stats.get("served", 0) < n:
        fail(f"stats.served {stats.get('served')} < {n} analyzes sent")
    if stats.get("memo_hits", 0) != memo_hits:
        fail(f"stats.memo_hits {stats.get('memo_hits')} != observed {memo_hits}")

    bye = round_trip({"op": "shutdown"})
    if bye.get("status") != "bye":
        fail(f"shutdown acknowledgement: {bye}")

    print(
        f"serve-smoke: OK — {n} analyzes "
        f"({memo_hits} memo hits), stats consistent, clean shutdown"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
