#!/usr/bin/env python3
"""Smoke client for the `osaca serve` TCP service (ci.sh --serve-smoke).

Usage: serve_smoke_client.py <host:port> <n_requests>

Drives one live server end to end over the real socket:

* sends <n_requests> `analyze` frames (alternating the shipped skl and
  rv64 triad fixtures so both shards and both ISAs are exercised),
  asserting every response is a schema-versioned `ok` frame whose
  embedded JSON report parses;
* asserts at least one `memo_hit:true` response once a fingerprint
  repeats (n_requests >= 3 guarantees a repeat);
* requests `stats` and asserts the counters cover the analyzes sent;
* sends `shutdown` and asserts the `bye` acknowledgement.

Exits non-zero (with a diagnostic on stderr) on the first violated
expectation. The caller owns the server process and checks its clean
exit separately.
"""
import json
import socket
import sys

SCHEMA_VERSION = 2

SKL_SOURCE = "workloads/triad/skl_o3.s"
RV64_SOURCE = "workloads/triad/rv64_o2.s"


def fail(msg):
    print(f"serve-smoke: FAIL — {msg}", file=sys.stderr)
    sys.exit(1)


def request_frames():
    with open(SKL_SOURCE) as f:
        skl = f.read()
    with open(RV64_SOURCE) as f:
        rv64 = f.read()
    return [
        {
            "op": "analyze",
            "name": "smoke-skl",
            "arch": "skl",
            "source": skl,
            "passes": ["throughput"],
            "unroll": 4,
        },
        {
            "op": "analyze",
            "name": "smoke-rv64",
            "arch": "rv64",
            "source": rv64,
            "passes": ["throughput", "critpath"],
            "frontend_bound": True,
            "unroll": 1,
        },
    ]


def main():
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    host, _, port = sys.argv[1].rpartition(":")
    n = int(sys.argv[2])

    sock = socket.create_connection((host, int(port)), timeout=30)
    rfile = sock.makefile("r", encoding="utf-8")

    def round_trip(frame):
        sock.sendall((json.dumps(frame) + "\n").encode())
        line = rfile.readline()
        if not line:
            fail("server closed the connection mid-session")
        try:
            return json.loads(line)
        except json.JSONDecodeError as e:
            fail(f"unparseable response frame: {e}: {line!r}")

    templates = request_frames()
    memo_hits = 0
    for i in range(n):
        resp = round_trip(templates[i % len(templates)])
        if resp.get("schema_version") != SCHEMA_VERSION:
            fail(f"response {i}: schema_version {resp.get('schema_version')}")
        if resp.get("status") != "ok":
            fail(f"response {i}: status {resp.get('status')}: {resp}")
        report = resp.get("report")
        if not isinstance(report, dict) or "prediction" not in report:
            fail(f"response {i}: malformed embedded report: {resp}")
        if resp.get("memo_hit"):
            memo_hits += 1
    if n >= 3 and memo_hits == 0:
        fail("no memo hit despite repeated fingerprints")

    stats = round_trip({"op": "stats"})
    if stats.get("status") != "stats":
        fail(f"stats frame: {stats}")
    if stats.get("served", 0) < n:
        fail(f"stats.served {stats.get('served')} < {n} analyzes sent")
    if stats.get("memo_hits", 0) != memo_hits:
        fail(f"stats.memo_hits {stats.get('memo_hits')} != observed {memo_hits}")

    bye = round_trip({"op": "shutdown"})
    if bye.get("status") != "bye":
        fail(f"shutdown acknowledgement: {bye}")

    print(
        f"serve-smoke: OK — {n} analyzes "
        f"({memo_hits} memo hits), stats consistent, clean shutdown"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
