#!/usr/bin/env python3
"""Automated bench-baseline gate for ci.sh (replaces the old "compared
manually" note).

Usage: check_bench_baseline.py <baseline.json> <fresh.json>

Both files are `osaca-hotpath-bench-v1` JSON emitted by
`cargo bench --bench hotpath` (the fresh one from the smoke run via
OSACA_BENCH_JSON). For every benchmark present in BOTH files, each
derived rate (kernels/s, req/s, ...) is compared against the baseline:

* a rate more than the tolerance BELOW baseline is a regression — the
  script prints every offender and exits 1 (fail loudly);
* a rate more than the tolerance ABOVE baseline is reported as a
  warning only (the committed baseline is stale-fast, regenerate it);
* benchmarks present in only one file are listed informationally.

Tolerance defaults to 0.20 (±20%), override with OSACA_BENCH_TOLERANCE.

OSACA_BENCH_REQUIRE (comma-separated benchmark names) lists benchmarks
that must be present in the FRESH results regardless of the baseline's
state — a required bench silently dropped from the suite must fail the
gate, not read as "nothing regressed". The requirement is checked even
while the placeholder-baseline skip below is active.

While the committed baseline is still the PR-3 placeholder (empty
`results`, no toolchain had ever existed in the dev containers), the
comparison is meaningless: the script prints a warning and exits 0 so
CI is not blocked on history we cannot retroactively measure. The skip
disappears automatically the moment a real baseline is committed.
"""
import json
import os
import sys


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        print(f"bench-baseline: {path} not found", file=sys.stderr)
        sys.exit(2)
    except json.JSONDecodeError as e:
        print(f"bench-baseline: {path} is not valid JSON: {e}", file=sys.stderr)
        sys.exit(2)


def main():
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    baseline_path, fresh_path = sys.argv[1], sys.argv[2]
    tolerance = float(os.environ.get("OSACA_BENCH_TOLERANCE", "0.20"))

    baseline = load(baseline_path)
    fresh = load(fresh_path)
    base_results = baseline.get("results") or {}
    fresh_results = fresh.get("results") or {}

    # One unambiguous status line for the CI log: is the ±tolerance
    # comparison actually live, or still waiting on a real committed
    # baseline? Greppable, so "the gate passed" can be told apart from
    # "the gate never ran".
    if base_results:
        print(f"bench-baseline: ARMED ({len(base_results)} baseline benchmark(s), ±{tolerance:.0%})")
    else:
        print("bench-baseline: UNARMED (placeholder baseline)")

    required = [n for n in os.environ.get("OSACA_BENCH_REQUIRE", "").split(",") if n]
    missing = [n for n in required if n not in fresh_results]
    if missing:
        print(
            f"bench-baseline: FAILED — required benchmark(s) missing from "
            f"{fresh_path}: {', '.join(missing)}",
            file=sys.stderr,
        )
        return 1

    if not base_results:
        print(
            f"bench-baseline: WARNING — {baseline_path} has no results "
            "(still the placeholder baseline); skipping the comparison. "
            "Regenerate with `cargo bench --bench hotpath` and commit "
            "BENCH_hotpath.json to arm this gate."
        )
        return 0
    if not fresh_results:
        print(f"bench-baseline: fresh run {fresh_path} has no results", file=sys.stderr)
        return 1

    shared = sorted(set(base_results) & set(fresh_results))
    only_base = sorted(set(base_results) - set(fresh_results))
    only_fresh = sorted(set(fresh_results) - set(base_results))
    for name in only_base:
        print(f"bench-baseline: note — `{name}` in baseline only (bench removed?)")
    for name in only_fresh:
        print(f"bench-baseline: note — `{name}` in fresh run only (new bench, no baseline)")

    regressions = []
    compared = 0
    for name in shared:
        base_rates = base_results[name].get("rates") or {}
        fresh_rates = fresh_results[name].get("rates") or {}
        for key in sorted(set(base_rates) & set(fresh_rates)):
            b, f = base_rates[key], fresh_rates[key]
            if not isinstance(b, (int, float)) or not isinstance(f, (int, float)) or b <= 0:
                continue
            compared += 1
            ratio = f / b
            if ratio < 1.0 - tolerance:
                regressions.append((name, key, b, f, ratio))
                print(
                    f"bench-baseline: REGRESSION `{name}` {key}: "
                    f"{f:.0f} vs baseline {b:.0f} ({ratio:.2%})"
                )
            elif ratio > 1.0 + tolerance:
                print(
                    f"bench-baseline: faster than baseline `{name}` {key}: "
                    f"{f:.0f} vs {b:.0f} ({ratio:.2%}) — consider regenerating the baseline"
                )

    if compared == 0:
        print("bench-baseline: WARNING — no comparable rates between the two files")
        return 0
    if regressions:
        print(
            f"bench-baseline: FAILED — {len(regressions)} rate(s) regressed beyond "
            f"{tolerance:.0%} of {baseline_path}"
        )
        return 1
    print(f"bench-baseline: OK — {compared} rate(s) within {tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
