"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

assert_allclose on fixed cases + hypothesis sweeps over shapes/values.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.port_solver import port_solver


def rand_case(rng, b, u, p, frac_pad=0.3):
    """Random admissible-mask/cost batch with padding rows."""
    mask = (rng.random((b, u, p)) < 0.35).astype(np.float32)
    # Ensure non-padding rows have at least one admissible port.
    first = np.zeros((b, u, p), dtype=np.float32)
    first[..., 0] = 1.0
    empty = mask.sum(-1, keepdims=True) == 0
    mask = np.where(empty, first, mask)
    cost = rng.random((b, u)).astype(np.float32) * 2.0
    pad = rng.random((b, u)) < frac_pad
    cost[pad] = 0.0
    mask[pad] = 0.0
    return jnp.asarray(mask), jnp.asarray(cost)


def assert_matches_ref(mask, cost):
    pu_k, pb_k, tu_k, tb_k = port_solver(mask, cost)
    pu_r, pb_r, tu_r, tb_r = ref.solve(mask, cost)
    assert_allclose(np.asarray(pu_k), np.asarray(pu_r), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(pb_k), np.asarray(pb_r), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(tu_k), np.asarray(tu_r), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(tb_k), np.asarray(tb_r), rtol=1e-5, atol=1e-6)


def test_kernel_matches_ref_fixed():
    rng = np.random.default_rng(0)
    mask, cost = rand_case(rng, 8, 64, 12)
    assert_matches_ref(mask, cost)


def test_single_port_instruction():
    # One µ-op bound to port 3, cost 2 -> all pressure on port 3.
    mask = np.zeros((1, 4, 8), np.float32)
    cost = np.zeros((1, 4), np.float32)
    mask[0, 0, 3] = 1.0
    cost[0, 0] = 2.0
    pu, pb, tu, tb = port_solver(jnp.asarray(mask), jnp.asarray(cost))
    assert_allclose(np.asarray(pu)[0, 3], 2.0, rtol=1e-6)
    assert_allclose(np.asarray(pb)[0, 3], 2.0, rtol=1e-6)
    assert_allclose(np.asarray(tu)[0], 2.0, rtol=1e-6)
    assert_allclose(np.asarray(tb)[0], 2.0, rtol=1e-6)


def test_two_port_split_uniform():
    # µ-op on ports {0,1}, cost 1 -> 0.5/0.5 uniform, bottleneck 0.5.
    mask = np.zeros((1, 1, 4), np.float32)
    mask[0, 0, :2] = 1.0
    cost = np.ones((1, 1), np.float32)
    pu, pb, tu, tb = port_solver(jnp.asarray(mask), jnp.asarray(cost))
    assert_allclose(np.asarray(pu)[0], [0.5, 0.5, 0.0, 0.0], atol=1e-6)
    assert_allclose(np.asarray(tu)[0], 0.5, atol=1e-6)


def test_balanced_beats_uniform_on_asymmetry():
    """The paper's asymmetric-port scenario (assumption 3 discussion).

    add may use {0,1}; mul only {0}. Uniform puts 0.5 of add on port 0
    giving 1.5 bottleneck; the balanced scheduler moves add to port 1
    entirely -> bottleneck -> 1.0 (O(1/t) tie-breaking tail leaves a few
    percent of mass on port 0 after 32 iterations, matching the slight
    overhang IACA itself shows, e.g. 2.21 cy vs the exact 2.00 in
    Table I).
    """
    mask = np.zeros((1, 2, 4), np.float32)
    mask[0, 0, :2] = 1.0  # add: ports 0,1
    mask[0, 1, 0] = 1.0  # mul: port 0
    cost = np.ones((1, 2), np.float32)
    pu, pb, tu, tb = port_solver(jnp.asarray(mask), jnp.asarray(cost))
    assert np.asarray(tu)[0] == pytest.approx(1.5, abs=1e-6)
    assert np.asarray(tb)[0] == pytest.approx(1.0, abs=0.06)


def test_balanced_close_to_lp_optimum():
    rng = np.random.default_rng(7)
    mask, cost = rand_case(rng, 4, 32, 8, frac_pad=0.2)
    _, _, _, tb = port_solver(mask, cost)
    for i in range(4):
        opt = ref.lp_optimum(np.asarray(mask)[i], np.asarray(cost)[i])
        assert float(np.asarray(tb)[i]) <= opt * 1.05 + 1e-3


def test_padding_rows_are_inert():
    rng = np.random.default_rng(3)
    mask, cost = rand_case(rng, 2, 16, 6, frac_pad=0.0)
    # Append 16 padding rows; results must be identical.
    mask_p = jnp.concatenate([mask, jnp.zeros((2, 16, 6))], axis=1)
    cost_p = jnp.concatenate([cost, jnp.zeros((2, 16))], axis=1)
    pu0, pb0, tu0, tb0 = port_solver(mask, cost)
    pu1, pb1, tu1, tb1 = port_solver(mask_p, cost_p)
    assert_allclose(np.asarray(pu0), np.asarray(pu1), rtol=1e-5, atol=1e-7)
    assert_allclose(np.asarray(pb0), np.asarray(pb1), rtol=1e-4, atol=1e-6)


def test_all_padding_batch_element():
    mask = jnp.zeros((2, 8, 6))
    cost = jnp.zeros((2, 8))
    pu, pb, tu, tb = port_solver(mask, cost)
    assert float(jnp.max(jnp.abs(pu))) == 0.0
    assert float(jnp.max(jnp.abs(pb))) == 0.0
    assert float(jnp.max(jnp.abs(tu))) == 0.0
    assert float(jnp.max(jnp.abs(tb))) == 0.0


def test_pressure_mass_conserved():
    """Sum of per-port pressure equals total µ-op cost for both schedulers."""
    rng = np.random.default_rng(11)
    mask, cost = rand_case(rng, 8, 64, 12)
    pu, pb, _, _ = port_solver(mask, cost)
    total = np.asarray(jnp.sum(cost, axis=1))
    assert_allclose(np.asarray(jnp.sum(pu, axis=1)), total, rtol=1e-5)
    assert_allclose(np.asarray(jnp.sum(pb, axis=1)), total, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 8),
    u=st.integers(1, 64),
    p=st.integers(2, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(b, u, p, seed):
    rng = np.random.default_rng(seed)
    mask, cost = rand_case(rng, b, u, p)
    assert_matches_ref(mask, cost)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_balanced_never_worse_than_uniform(seed):
    """Balancing minimizes max pressure; must be <= uniform bottleneck."""
    rng = np.random.default_rng(seed)
    mask, cost = rand_case(rng, 4, 32, 10)
    _, _, tu, tb = port_solver(mask, cost)
    assert np.all(np.asarray(tb) <= np.asarray(tu) + 1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 8.0))
def test_pressure_scales_linearly(seed, scale):
    rng = np.random.default_rng(seed)
    mask, cost = rand_case(rng, 2, 24, 8)
    pu0, _, tu0, _ = port_solver(mask, cost)
    pu1, _, tu1, _ = port_solver(mask, cost * np.float32(scale))
    assert_allclose(np.asarray(pu1), np.asarray(pu0) * scale, rtol=1e-4, atol=1e-5)
    assert_allclose(np.asarray(tu1), np.asarray(tu0) * scale, rtol=1e-4, atol=1e-5)
