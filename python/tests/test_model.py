"""L2 model: shapes, AOT lowering, and HLO-text round-trip sanity."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import aot
from compile.kernels import ref
from compile.model import B, P, U, predict


def toy_batch():
    rng = np.random.default_rng(42)
    mask = (rng.random((B, U, P)) < 0.3).astype(np.float32)
    empty = mask.sum(-1, keepdims=True) == 0
    first = np.zeros_like(mask)
    first[..., 0] = 1.0
    mask = np.where(empty, first, mask)
    cost = rng.random((B, U)).astype(np.float32)
    return jnp.asarray(mask), jnp.asarray(cost)


def test_predict_shapes():
    mask, cost = toy_batch()
    pu, pb, tu, tb, lo = predict(mask, cost)
    assert pu.shape == (B, P)
    assert pb.shape == (B, P)
    assert tu.shape == (B,)
    assert tb.shape == (B,)
    assert lo.shape == (B,)


def test_crit_lower_is_a_lower_bound():
    mask, cost = toy_batch()
    _, _, tu, tb, lo = predict(mask, cost)
    assert np.all(np.asarray(lo) <= np.asarray(tu) + 1e-5)
    assert np.all(np.asarray(lo) <= np.asarray(tb) + 1e-4)


def test_predict_matches_ref_solver():
    mask, cost = toy_batch()
    pu, pb, tu, tb, _ = predict(mask, cost)
    pu_r, pb_r, tu_r, tb_r = ref.solve(mask, cost)
    assert_allclose(np.asarray(pu), np.asarray(pu_r), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(tb), np.asarray(tb_r), rtol=1e-5, atol=1e-6)


def test_aot_lowering_emits_hlo_text():
    text = aot.to_hlo_text(aot.lower())
    assert "HloModule" in text
    # 5-tuple result with fixed shapes.
    assert f"f32[{B},{P}]" in text
    assert f"f32[{B}]" in text


def test_lowered_module_executes_like_predict(tmp_path):
    """Compile the lowered module with jax's own runtime and compare."""
    mask, cost = toy_batch()
    compiled = jax.jit(predict).lower(
        jax.ShapeDtypeStruct((B, U, P), jnp.float32),
        jax.ShapeDtypeStruct((B, U), jnp.float32),
    ).compile()
    out = compiled(mask, cost)
    direct = predict(mask, cost)
    for a, b in zip(out, direct):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
