"""Critical-path Pallas kernel vs numpy oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.critpath import critpath_solver, NEG


def dag_case(rng, b, u, p_edge=0.25, p_carried=0.1):
    """Random forward DAG with latencies + carried edges."""
    lat = (rng.integers(1, 8, size=(b, u))).astype(np.float32)
    adj = np.full((b, u, u), NEG, dtype=np.float32)
    carried = np.zeros((b, u, u), dtype=np.float32)
    for k in range(b):
        for i in range(u):
            for v in range(i + 1, u):
                if rng.random() < p_edge:
                    adj[k, i, v] = lat[k, v]
        for i in range(u):
            for w in range(i, u):
                if rng.random() < p_carried:
                    carried[k, i, w] = 1.0
    return jnp.asarray(adj), jnp.asarray(lat), jnp.asarray(carried)


def check(adj, lat, carried):
    intra_k, bound_k = critpath_solver(adj, lat, carried)
    intra_r, bound_r = ref.critpath(adj, lat, carried)
    assert_allclose(np.asarray(intra_k), intra_r, rtol=1e-5, atol=1e-4)
    assert_allclose(np.asarray(bound_k), bound_r, rtol=1e-5, atol=1e-4)


def test_single_chain():
    # 0 -> 1 -> 2 with lat 4 each: intra = 12; carried 2->0 cycle = 12.
    u = 4
    adj = np.full((1, u, u), NEG, dtype=np.float32)
    lat = np.zeros((1, u), dtype=np.float32)
    carried = np.zeros((1, u, u), dtype=np.float32)
    lat[0, :3] = 4.0
    adj[0, 0, 1] = 4.0
    adj[0, 1, 2] = 4.0
    carried[0, 0, 2] = 1.0
    intra, bound = critpath_solver(jnp.asarray(adj), jnp.asarray(lat), jnp.asarray(carried))
    assert float(intra[0]) == 12.0
    assert float(bound[0]) == 12.0


def test_self_loop_carried():
    # Single µ-op chained to itself (vaddpd accumulator): bound = lat.
    u = 2
    adj = np.full((1, u, u), NEG, dtype=np.float32)
    lat = np.zeros((1, u), dtype=np.float32)
    carried = np.zeros((1, u, u), dtype=np.float32)
    lat[0, 0] = 3.0
    carried[0, 0, 0] = 1.0
    intra, bound = critpath_solver(jnp.asarray(adj), jnp.asarray(lat), jnp.asarray(carried))
    assert float(intra[0]) == 3.0
    assert float(bound[0]) == 3.0


def test_empty_graph_is_zero():
    adj = jnp.full((2, 8, 8), NEG)
    lat = jnp.zeros((2, 8))
    carried = jnp.zeros((2, 8, 8))
    intra, bound = critpath_solver(adj, lat, carried)
    assert float(jnp.max(intra)) == 0.0
    assert float(jnp.max(bound)) == 0.0


def test_matches_oracle_fixed():
    rng = np.random.default_rng(0)
    check(*dag_case(rng, 4, 16))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), u=st.integers(2, 24))
def test_matches_oracle_hypothesis(seed, u):
    rng = np.random.default_rng(seed)
    check(*dag_case(rng, 2, u))


def test_bound_never_exceeds_intra_for_forward_carried():
    # Carried edges (i <= w) select sub-paths of the DAG, so the carried
    # bound can never exceed the longest chain.
    rng = np.random.default_rng(5)
    adj, lat, carried = dag_case(rng, 4, 20)
    intra, bound = critpath_solver(adj, lat, carried)
    assert np.all(np.asarray(bound) <= np.asarray(intra) + 1e-4)
