"""L1 Pallas kernel: batched critical-path solver (max-plus closure).

Implements the latency-modeling extension (paper §IV-B future work) as
a tensor program: given a batch of dependency graphs over the µ-ops of
one loop iteration, compute

  * the longest latency chain through one iteration, and
  * the longest loop-carried cycle per iteration (the steady-state
    lower bound that explains the paper's §III-B -O1 anomaly),

via max-plus matrix squaring:

  M = I ⊕ A           (A[u,v] = lat[v] if v depends on u, else -inf)
  M^(2^k) by repeated squaring (U = 64 -> 6 squarings)
  D = diag(lat) ⊗ M^U  (longest path i→v, inclusive of both endpoints)

  intra[b]   = max_{i,v} D[i,v]
  carried[b] = max over back-edges (w -> i of next iter) of D[i,w]

Pallas notes: grid over B; one (U, U) tile (16 KiB f32) per program
instance in VMEM; the squaring loop runs inside the kernel. The max-plus
product is expressed as a broadcasted add + reduce (VPU work).
interpret=True (CPU substrate).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1.0e9
N_SQUARINGS = 6  # 2^6 = 64 = U: covers all simple paths


def _maxplus_square(m):
    """One max-plus squaring: out[i,j] = max_k m[i,k] + m[k,j]."""
    return jnp.max(m[:, :, None] + m[None, :, :], axis=1)


def _critpath_kernel(adj_ref, lat_ref, carried_ref, intra_ref, bound_ref):
    adj = adj_ref[...]  # (U, U) with NEG for no edge
    lat = lat_ref[...]  # (U, 1)
    carried = carried_ref[...]  # (U, U) 1.0 where back-edge i->w

    u = adj.shape[0]
    eye = jnp.where(jnp.eye(u, dtype=adj.dtype) > 0.0, 0.0, NEG)
    m = jnp.maximum(eye, adj)

    def body(_, m):
        return _maxplus_square(m)

    m = jax.lax.fori_loop(0, N_SQUARINGS, body, m)
    # D[i, v] = lat[i] + path(i -> v); diag(lat) ⊗ m.
    d = lat + m  # broadcast over rows: row i shifted by lat[i]
    intra = jnp.max(d)
    bound = jnp.max(jnp.where(carried > 0.0, d, NEG))
    intra_ref[...] = jnp.maximum(intra, 0.0)[None]
    bound_ref[...] = jnp.maximum(bound, 0.0)[None]


def critpath_solver(adj, lat, carried):
    """Batched critical-path solve.

    Args:
      adj: f32[B, U, U] — adj[b, u, v] = lat_v when µ-op v of batch b
        depends on µ-op u (program order u < v), else NEG.
      lat: f32[B, U] — per-µ-op latency (0 rows for padding).
      carried: f32[B, U, U] — carried[b, i, w] = 1 when µ-op i of the
        next iteration depends on µ-op w of the current one.

    Returns:
      (intra[B], carried_bound[B]) — longest chain through an iteration
      and the loop-carried cycle bound (cycles/iteration); 0 when the
      graph is empty.
    """
    b, u, _ = adj.shape
    assert lat.shape == (b, u)
    assert carried.shape == (b, u, u)
    lat3 = lat[..., None]
    out_shape = (
        jax.ShapeDtypeStruct((b, 1), jnp.float32),
        jax.ShapeDtypeStruct((b, 1), jnp.float32),
    )
    intra, bound = pl.pallas_call(
        lambda a, l, c, i_ref, b_ref: _critpath_kernel(
            _S(a), _S(l), _S(c), _S(i_ref), _S(b_ref)
        ),
        out_shape=out_shape,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, u, u), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, u, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, u, u), lambda i: (i, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ),
        interpret=True,
    )(adj, lat3, carried)
    return intra[:, 0], bound[:, 0]


class _S:
    """Ref adapter dropping the leading size-1 block dimension."""

    def __init__(self, ref):
        self._ref = ref

    @property
    def shape(self):
        return self._ref.shape[1:]

    def __getitem__(self, idx):
        if idx is Ellipsis:
            return self._ref[...][0]
        raise NotImplementedError(idx)

    def __setitem__(self, idx, val):
        if idx is Ellipsis:
            self._ref[...] = val[None]
            return
        raise NotImplementedError(idx)
