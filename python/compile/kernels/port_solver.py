"""L1 Pallas kernel: batched port-pressure solver.

The numeric hot-spot of instruction-stream throughput prediction.

A kernel (loop body) is encoded as a batch of dense tensors:

  mask[B, U, P]  -- {0,1}: µ-op u may execute on port p
  cost[B, U]     -- cycles the µ-op occupies whichever port it lands on
                    (0 for padding rows)

Two schedulers are computed:

  * uniform   -- OSACA's assumption 2: every admissible port receives the
                 µ-op with equal probability (fixed probabilities).
  * balanced  -- IACA-like heuristic: iteratively shift probability mass
                 toward less-pressured ports (multiplicative weights on
                 the min-max port-pressure LP). T fixed iterations.

Outputs per batch element: per-port cumulative pressure for both
schedulers and the bottleneck cycle count (max over ports).

Pallas notes: grid over B; one (U, P) tile per program instance lives in
VMEM (64x16 f32 = 4 KiB -- far below VMEM capacity; see DESIGN.md §5).
interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and correctness is the target on this substrate.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed solver iteration count. 32 iterations converge to <1e-3 of the
# LP optimum for every realistic port model (P <= 12, U <= 64); see
# python/tests/test_kernel.py::test_balanced_close_to_lp_optimum.
DEFAULT_ITERS = 32
# Learning rate for the multiplicative-weights update. eta too large
# oscillates on 2-port ties; 0.35 is stable for pressures in [0, ~64].
ETA = 0.35


def _solver_kernel(mask_ref, cost_ref, up_ref, bp_ref, tu_ref, tb_ref, *, iters: int):
    """Pallas kernel body. One program instance handles one batch element.

    mask_ref: (U, P) f32, cost_ref: (U, 1) f32
    up_ref:   (P,) uniform pressure     bp_ref: (P,) balanced pressure
    tu_ref:   (1,) uniform bottleneck   tb_ref: (1,) balanced bottleneck
    """
    mask = mask_ref[...]
    cost = cost_ref[...]  # (U, 1)

    # Row sums guarded against all-zero padding rows.
    nports = jnp.sum(mask, axis=1, keepdims=True)  # (U, 1)
    safe = jnp.maximum(nports, 1.0)

    # --- uniform (OSACA) split ---------------------------------------
    w_uniform = mask / safe
    press_u = jnp.sum(w_uniform * cost, axis=0)  # (P,)

    # --- balanced (IACA-like) split ----------------------------------
    def body(_, w):
        press = jnp.sum(w * cost, axis=0, keepdims=True)  # (1, P)
        # Shift mass toward low-pressure admissible ports.
        upd = w * jnp.exp(-ETA * press)
        upd = upd * mask
        norm = jnp.maximum(jnp.sum(upd, axis=1, keepdims=True), 1e-30)
        # Keep padding rows at zero weight.
        return jnp.where(nports > 0.0, upd / norm, 0.0)

    w0 = jnp.where(nports > 0.0, mask / safe, 0.0)
    w_bal = jax.lax.fori_loop(0, iters, body, w0)
    press_b = jnp.sum(w_bal * cost, axis=0)  # (P,)

    up_ref[...] = press_u
    bp_ref[...] = press_b
    tu_ref[...] = jnp.max(press_u, keepdims=True)
    tb_ref[...] = jnp.max(press_b, keepdims=True)


def port_solver(mask, cost, iters: int = DEFAULT_ITERS):
    """Batched port-pressure solve.

    Args:
      mask: f32[B, U, P] admissible-port indicator per µ-op.
      cost: f32[B, U] cycle cost per µ-op (0 padding).
      iters: balancing iterations.

    Returns:
      (press_uniform[B, P], press_balanced[B, P],
       tp_uniform[B], tp_balanced[B])
    """
    b, u, p = mask.shape
    assert cost.shape == (b, u), (mask.shape, cost.shape)
    cost3 = cost[..., None]  # (B, U, 1)

    kern = partial(_solver_kernel, iters=iters)
    out_shape = (
        jax.ShapeDtypeStruct((b, p), jnp.float32),
        jax.ShapeDtypeStruct((b, p), jnp.float32),
        jax.ShapeDtypeStruct((b, 1), jnp.float32),
        jax.ShapeDtypeStruct((b, 1), jnp.float32),
    )
    grid = (b,)
    in_specs = [
        pl.BlockSpec((1, u, p), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, u, 1), lambda i: (i, 0, 0)),
    ]
    out_specs = (
        pl.BlockSpec((1, p), lambda i: (i, 0)),
        pl.BlockSpec((1, p), lambda i: (i, 0)),
        pl.BlockSpec((1, 1), lambda i: (i, 0)),
        pl.BlockSpec((1, 1), lambda i: (i, 0)),
    )

    def kernel_3d(mask_ref, cost_ref, up_ref, bp_ref, tu_ref, tb_ref):
        # Block shapes carry the leading batch dim of size 1; peel it.
        _solver_kernel(
            _Squeeze0(mask_ref),
            _Squeeze0(cost_ref),
            _Squeeze0(up_ref),
            _Squeeze0(bp_ref),
            _Squeeze0(tu_ref),
            _Squeeze0(tb_ref),
            iters=iters,
        )

    press_u, press_b, tu, tb = pl.pallas_call(
        kernel_3d,
        out_shape=out_shape,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        interpret=True,
    )(mask, cost3)
    return press_u, press_b, tu[:, 0], tb[:, 0]


class _Squeeze0:
    """Ref adapter dropping the leading size-1 block dimension."""

    def __init__(self, ref):
        self._ref = ref

    def __getitem__(self, idx):
        if idx is Ellipsis:
            return self._ref[...][0]
        raise NotImplementedError(idx)

    def __setitem__(self, idx, val):
        if idx is Ellipsis:
            self._ref[...] = val[None]
            return
        raise NotImplementedError(idx)
