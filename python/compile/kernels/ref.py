"""Pure-jnp oracle for the port-pressure solver (no pallas).

Mirrors kernels/port_solver.py op-for-op; correctness contract enforced
by python/tests/test_kernel.py (assert_allclose + hypothesis sweeps).
Also provides an LP-exact min-max solve (scipy-free, via long-horizon
multiplicative weights) used to bound the balanced heuristic's gap.
"""

import jax
import jax.numpy as jnp

from .port_solver import DEFAULT_ITERS, ETA


def uniform_pressure(mask, cost):
    """OSACA assumption-2 split: equal probability over admissible ports.

    mask: f32[..., U, P], cost: f32[..., U] -> f32[..., P]
    """
    nports = jnp.sum(mask, axis=-1, keepdims=True)
    w = mask / jnp.maximum(nports, 1.0)
    return jnp.sum(w * cost[..., None], axis=-2)


def balanced_pressure(mask, cost, iters: int = DEFAULT_ITERS):
    """IACA-like multiplicative-weights balancing, reference semantics."""
    nports = jnp.sum(mask, axis=-1, keepdims=True)
    safe = jnp.maximum(nports, 1.0)
    w = jnp.where(nports > 0.0, mask / safe, 0.0)
    cost3 = cost[..., None]

    def body(_, w):
        press = jnp.sum(w * cost3, axis=-2, keepdims=True)
        upd = w * jnp.exp(-ETA * press) * mask
        norm = jnp.maximum(jnp.sum(upd, axis=-1, keepdims=True), 1e-30)
        return jnp.where(nports > 0.0, upd / norm, 0.0)

    w = jax.lax.fori_loop(0, iters, body, w)
    return jnp.sum(w * cost3, axis=-2)


def solve(mask, cost, iters: int = DEFAULT_ITERS):
    """Full reference solve; same outputs as kernels.port_solver.port_solver."""
    pu = uniform_pressure(mask, cost)
    pb = balanced_pressure(mask, cost, iters)
    return pu, pb, jnp.max(pu, axis=-1), jnp.max(pb, axis=-1)


def critpath(adj, lat, carried):
    """Reference longest-path / carried-bound via numpy DP.

    Edges only point forward in index order (program order), so a single
    topological sweep suffices. Mirrors kernels/critpath.py semantics.
    """
    import numpy as np

    adj = np.asarray(adj, dtype=np.float64)
    lat = np.asarray(lat, dtype=np.float64)
    carried = np.asarray(carried, dtype=np.float64)
    b, u, _ = adj.shape
    NEG = -1.0e9
    intra = np.zeros(b)
    bound = np.zeros(b)
    for k in range(b):
        # d[i, v] = longest path value from i to v (inclusive).
        d = np.full((u, u), NEG)
        for i in range(u):
            d[i, i] = lat[k, i]
            for v in range(i + 1, u):
                best = NEG
                for w in range(i, v):
                    if adj[k, w, v] > NEG / 2 and d[i, w] > NEG / 2:
                        best = max(best, d[i, w] + lat[k, v])
                d[i, v] = best
        intra[k] = max(0.0, d.max())
        m = np.where(carried[k] > 0, d, NEG)
        bound[k] = max(0.0, m.max())
    return intra, bound


def lp_optimum(mask, cost, iters: int = 4000):
    """Near-exact min-max pressure via long-horizon balancing (small eta).

    Used only in tests as a ground-truth bound; not exported to HLO.
    mask: f32[U, P], cost: f32[U] -> scalar optimal bottleneck.
    """
    import numpy as np

    mask = np.asarray(mask, dtype=np.float64)
    cost = np.asarray(cost, dtype=np.float64)
    nports = mask.sum(axis=1, keepdims=True)
    safe = np.maximum(nports, 1.0)
    w = np.where(nports > 0, mask / safe, 0.0)
    eta = 0.05
    for _ in range(iters):
        press = (w * cost[:, None]).sum(axis=0, keepdims=True)
        upd = w * np.exp(-eta * press) * mask
        norm = np.maximum(upd.sum(axis=1, keepdims=True), 1e-300)
        w = np.where(nports > 0, upd / norm, 0.0)
    return float((w * cost[:, None]).sum(axis=0).max())
