"""AOT-lower the L2 model to HLO text for the rust PJRT runtime.

HLO *text* is the interchange format, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Usage: python -m compile.aot --out ../artifacts/port_solver.hlo.txt
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import B, P, U, predict, predict_critpath


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower():
    mask_spec = jax.ShapeDtypeStruct((B, U, P), jnp.float32)
    cost_spec = jax.ShapeDtypeStruct((B, U), jnp.float32)
    return jax.jit(predict).lower(mask_spec, cost_spec)


def lower_critpath():
    adj_spec = jax.ShapeDtypeStruct((B, U, U), jnp.float32)
    lat_spec = jax.ShapeDtypeStruct((B, U), jnp.float32)
    car_spec = jax.ShapeDtypeStruct((B, U, U), jnp.float32)
    return jax.jit(predict_critpath).lower(adj_spec, lat_spec, car_spec)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/port_solver.hlo.txt")
    args = ap.parse_args()
    text = to_hlo_text(lower())
    with open(args.out, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {args.out} (B={B}, U={U}, P={P})")
    # Companion artifact: the critical-path solver, same directory.
    crit_path = os.path.join(os.path.dirname(args.out), "critpath.hlo.txt")
    text = to_hlo_text(lower_critpath())
    with open(crit_path, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {crit_path} (B={B}, U={U})")


if __name__ == "__main__":
    main()
