"""L2: the JAX compute graph for batched throughput prediction.

Calls the L1 Pallas kernel (kernels.port_solver) so the whole analysis
lowers into a single HLO module. Shapes are fixed at AOT time; the rust
coordinator pads kernels into (B, U, P) slots:

  B = 8   analysis requests per batch (coordinator batches to this)
  U = 64  µ-ops per kernel (triad -O3 uses 10, π -O3 uses ~20)
  P = 12  ports incl. divider pipes (SKL uses 9: P0..P7 + 0DV;
          Zen uses 11: FP0..3, 4..7 int, 8/9 AGU+LD, 3DV)

Outputs, concatenated as a 5-tuple:
  press_uniform f32[B, P]  -- OSACA per-port cumulative occupation
  press_balanced f32[B, P] -- IACA-like balanced occupation
  tp_uniform f32[B]        -- bottleneck cy / asm iteration (OSACA)
  tp_balanced f32[B]       -- bottleneck cy / asm iteration (IACA-like)
  crit_lower f32[B]        -- sum-of-cost lower bound / widest port count
                              (sanity channel the coordinator cross-checks)
"""

import jax.numpy as jnp

from .kernels.critpath import critpath_solver
from .kernels.port_solver import DEFAULT_ITERS, port_solver

B, U, P = 8, 64, 12


def predict(mask, cost):
    """Batched prediction. mask f32[B,U,P], cost f32[B,U]."""
    press_u, press_b, tp_u, tp_b = port_solver(mask, cost, iters=DEFAULT_ITERS)
    # Work lower bound: total µ-op cycles spread over the union of all
    # ports any µ-op may use (perfectly symmetric machine). Cheap
    # cross-check channel for the coordinator's sanity asserts.
    used_ports = jnp.max(mask, axis=1)  # (B, P)
    width = jnp.maximum(jnp.sum(used_ports, axis=1), 1.0)  # (B,)
    crit_lower = jnp.sum(cost, axis=1) / width
    return press_u, press_b, tp_u, tp_b, crit_lower


def predict_critpath(adj, lat, carried):
    """Batched latency analysis (paper §IV-B future work): longest
    intra-iteration chain and loop-carried cycle bound.

    adj f32[B,U,U], lat f32[B,U], carried f32[B,U,U].
    """
    return critpath_solver(adj, lat, carried)
