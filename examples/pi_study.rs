//! The π-benchmark study (paper §III-B): predictions vs measurement at
//! -O1/-O2/-O3, the stall-counter investigation of the -O1 anomaly, and
//! the critical-path extension that explains it.
//!
//! Run: `cargo run --release --example pi_study`

use anyhow::Result;
use osaca::api::{Engine, Passes};
use osaca::benchlib::print_table;
use osaca::workloads;

fn main() -> Result<()> {
    let engine = Engine::new();
    let mut rows = Vec::new();
    let mut stall_rows = Vec::new();
    for arch in ["skl", "zen"] {
        for flag in ["-O1", "-O2", "-O3"] {
            let w = workloads::find("pi", arch, flag).unwrap();
            // One request runs all four passes over the kernel.
            let r = engine.analyze(
                &Engine::request(&w.name())
                    .arch(arch)
                    .source(w.source)
                    .passes(Passes::ALL)
                    .unroll(w.unroll),
            )?;
            let a = r.throughput.as_ref().expect("throughput pass");
            let b = r.baseline.as_ref().expect("baseline pass");
            let cp = r.critpath.as_ref().expect("critpath pass");
            let m = r.simulation.as_ref().expect("simulate pass");
            let u = w.unroll as f64;
            // The structured winner names the limiting resource per
            // row — the -O1 lines literally say "critical_path".
            let prediction = r.prediction();
            let winner = prediction.winner().expect("analytic passes ran");
            rows.push(vec![
                r.machine.arch_name.clone(),
                flag.to_string(),
                format!("{:.2}", b.cy_per_asm_iter as f64 / u),
                format!("{:.2}", a.cy_per_asm_iter as f64 / u),
                format!("{:.2}", cp.carried_per_iteration as f64 / u),
                format!("{:.2}", m.cy_per_source_it(w.unroll)),
                format!("{} ({})", winner.kind.name(), winner.resource),
            ]);
            stall_rows.push(vec![
                r.machine.arch_name.clone(),
                flag.to_string(),
                format!("{}", m.counters.issue_stall_cycles),
                format!(
                    "{:.1}%",
                    100.0 * m.counters.issue_stall_cycles as f64 / m.window_cycles as f64
                ),
                format!("{}", m.counters.forwarded_loads),
            ]);
        }
    }
    print_table(
        "pi benchmark (Table V + critical-path extension), cy per source iteration",
        &["arch", "flag", "IACA-like", "OSACA", "crit-path bound", "measured", "winning bound"],
        &rows,
    );
    print_table(
        "stall counters (the §III-B investigation)",
        &["arch", "flag", "issue-stall cy", "stall fraction", "forwarded loads"],
        &stall_rows,
    );
    println!(
        "\nNote how at -O1 the critical-path bound (store->load forwarding through\n\
         the stack) explains the measured runtime that the pure throughput models\n\
         miss — the paper's §IV-B motivation for latency analysis."
    );
    Ok(())
}
