//! End-to-end driver: the full system on a real workload set.
//!
//! 1. *Model construction from scratch*: treat the Zen simulator as an
//!    undocumented machine, rebuild database entries for its core
//!    instruction forms via ibench + conflict probing (§II), and verify
//!    them against the shipped model.
//! 2. *Analysis service*: submit every workload x architecture as ONE
//!    batch through `Engine::analyze_batch` — the requests map directly
//!    onto the solver's B=8 artifact slots, serving-framework style.
//! 3. *Validation*: simulate every workload on both machines and report
//!    prediction vs measurement — the paper's full evaluation, plus the
//!    extra kernels.
//!
//! The run is recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example pipeline_e2e`

use std::sync::atomic::Ordering;
use std::time::Instant;

use anyhow::{anyhow, Result};
use osaca::api::{Engine, Passes};
use osaca::benchlib::print_table;
use osaca::builder::{default_probes, infer_entry, validate_model};
use osaca::isa::InstructionForm;
use osaca::workloads;

fn main() -> Result<()> {
    let t0 = Instant::now();
    let engine = Engine::new();

    // ---- phase 1: model construction ------------------------------
    println!("[1/3] model construction on the 'undocumented' Zen substrate");
    let zen = engine.machine("zen").map_err(|e| anyhow!("{e}"))?;
    let probes = default_probes(&zen);
    let forms = [
        "vaddpd-xmm_xmm_xmm",
        "vmulpd-xmm_xmm_xmm",
        "vfmadd132pd-xmm_xmm_xmm",
        "vfmadd132pd-mem_xmm_xmm",
        "vdivsd-xmm_xmm_xmm",
    ];
    let mut rows = Vec::new();
    for f in forms {
        let form = InstructionForm::parse(f);
        let inf = infer_entry(&form, &zen, &probes)?;
        let db = zen.entries.get(&form).expect("shipped entry");
        rows.push(vec![
            f.to_string(),
            format!("{:.1}/{:.1}", inf.measured_latency, db.latency),
            format!("{:.2}/{:.2}", inf.measured_rtp, db.implied_rtp()),
            format!("{:?}", inf.conflicting_probes),
        ]);
    }
    print_table(
        "inferred vs shipped (lat meas/db, rTP meas/db)",
        &["form", "latency", "rTP", "conflicts"],
        &rows,
    );
    let validation = validate_model(
        &zen,
        &forms.iter().map(|f| InstructionForm::parse(f)).collect::<Vec<_>>(),
    )?;
    let ok = validation.iter().filter(|r| r.ok()).count();
    println!("validation: {ok}/{} entries re-derived within tolerance", validation.len());

    // ---- phase 2: batched analysis service ------------------------
    println!("\n[2/3] batch submission through Engine::analyze_batch");
    let ws = workloads::all();
    let n_reqs = 96;
    let reqs: Vec<_> = (0..n_reqs)
        .map(|i| {
            let w = ws[i % ws.len()];
            let arch = if i % 2 == 0 { "skl" } else { "zen" };
            Engine::request(&w.name())
                .arch(arch)
                .source(w.source)
                .passes(Passes::ANALYTIC)
                .unroll(w.unroll)
        })
        .collect();
    let t1 = Instant::now();
    let results = engine.analyze_batch(&reqs);
    let dt = t1.elapsed();
    for r in &results {
        let report = r.as_ref().map_err(|e| anyhow!("batch request failed: {e}"))?;
        let t = report.throughput.as_ref().expect("throughput pass");
        let b = report.baseline.as_ref().expect("baseline pass");
        // Balanced prediction never exceeds the uniform one.
        assert!(b.cy_per_asm_iter <= t.cy_per_asm_iter + 1e-3);
    }
    let stats = engine.stats();
    println!(
        "served {n_reqs} requests in {dt:?} ({:.0} req/s), {} solver batches, avg batch {:.2}",
        n_reqs as f64 / dt.as_secs_f64(),
        stats.batches.load(Ordering::Relaxed),
        stats.avg_batch_size(),
    );

    // ---- phase 3: full prediction-vs-measurement sweep -------------
    println!("\n[3/3] prediction vs simulated measurement, all workloads x machines");
    let mut rows = Vec::new();
    let mut worst: f64 = 1.0;
    for arch in ["skl", "zen"] {
        for w in workloads::all() {
            if !w.is_for(arch) && w.family != "triad" {
                continue;
            }
            let report = engine.analyze(
                &Engine::request(&w.name())
                    .arch(arch)
                    .source(w.source)
                    .passes(Passes::THROUGHPUT | Passes::CRITPATH | Passes::SIMULATE)
                    .unroll(w.unroll),
            ).map_err(|e| anyhow!("{e}"))?;
            let a = report.throughput.as_ref().expect("throughput pass");
            let cp = report.critpath.as_ref().expect("critpath pass");
            let m = report.simulation.as_ref().expect("simulate pass");
            // The combined model is the Prediction's max-over-bounds;
            // the winner also names the limiting resource per row.
            let prediction = report.prediction();
            let winner = prediction.winner().expect("analytic passes ran");
            let ratio = m.cycles_per_iteration / winner.cy_per_asm_iter as f64;
            // Track accuracy of the combined (throughput + critical
            // path) model; pure-OSACA deviates on latency-bound kernels.
            if w.family != "pi" || w.flag != "-O1" {
                worst = worst.max(ratio.max(1.0 / ratio));
            }
            rows.push(vec![
                report.arch.clone(),
                w.name(),
                format!("{:.2}", a.cy_per_asm_iter),
                format!("{:.2}", cp.carried_per_iteration),
                format!("{:.2}", m.cycles_per_iteration),
                format!("{:.2}", ratio),
                format!("{} ({})", winner.kind.name(), winner.resource),
            ]);
        }
    }
    print_table(
        "cy per assembly iteration",
        &[
            "machine",
            "workload",
            "OSACA",
            "critpath",
            "measured",
            "meas/max(pred)",
            "winning bound",
        ],
        &rows,
    );
    println!(
        "\nworst measured/predicted ratio (excl. the §III-B -O1 anomaly): {worst:.2}"
    );
    println!("total end-to-end runtime: {:?}", t0.elapsed());
    Ok(())
}
