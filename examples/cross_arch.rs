//! Cross-architecture study (paper Table III): run code compiled for
//! one microarchitecture on the other, on both simulated machines, and
//! watch Zen pay 2x for 256-bit AVX splitting.
//!
//! Run: `cargo run --release --example cross_arch`

use anyhow::Result;
use osaca::api::{Engine, Format};
use osaca::benchlib::{format_table, print_table};
use osaca::report::experiments::{render_table3, table3};
use osaca::sim::SimConfig;

const HEADERS: [&str; 9] = [
    "executed on",
    "compiled for",
    "flag",
    "unroll",
    "MFLOP/s",
    "Mit/s",
    "measured cy/it",
    "OSACA cy/it",
    "IACA-like cy/it",
];

fn main() -> Result<()> {
    let engine = Engine::new();
    let rows = table3(engine.coordinator(), SimConfig::default())?;
    print_table(
        "Table III: Schönauer triad, measured (simulator @1.8 GHz) vs predicted",
        &HEADERS,
        &render_table3(&rows),
    );
    // Machine-readable appendix: the same rows through the CSV table
    // emitter (what `tables --table3 --format csv` prints) — ready for
    // plotting scripts.
    print!("\n{}", format_table(Format::Csv, "table3", &HEADERS, &render_table3(&rows)));

    // Paper's headline observation, stated explicitly:
    let get = |on: &str, for_: &str| {
        rows.iter()
            .find(|r| r.executed_on == on && r.compiled_for == for_ && r.flag == "-O3")
            .unwrap()
    };
    let skl_native = get("Skylake", "Skylake");
    let zen_foreign = get("Zen", "Skylake");
    let zen_native = get("Zen", "Zen");
    println!(
        "\nSkylake executes its own AVX2 code at {:.2} cy/it; Zen executes the same\n\
         code at {:.2} cy/it ({}x) because 256-bit AVX cracks into 2x128-bit halves,\n\
         while Zen's own 128-bit code runs at {:.2} cy/it — the Table III story.",
        skl_native.measured_cy_it,
        zen_foreign.measured_cy_it,
        (zen_foreign.measured_cy_it / skl_native.measured_cy_it).round(),
        zen_native.measured_cy_it,
    );
    Ok(())
}
