//! Model construction (paper §II-C): reproduce the FMA workflow.
//!
//! Benchmarks `vfmadd132pd mem,xmm,xmm` on the Zen and Skylake
//! simulator substrates (latency, parallelism sweep, TP), probes port
//! conflicts against vaddpd / vmulpd, deduces the port assignment, and
//! prints the resulting database entry — exactly the §II-C narrative,
//! mechanized. Machine models come from the engine's shared registry.
//!
//! Run: `cargo run --release --example model_construction`

use anyhow::{anyhow, Result};
use osaca::api::{Engine, Format};
use osaca::benchlib::format_table;
use osaca::builder::{default_probes, infer_entry};
use osaca::ibench::{run_conflict, run_sweep, BenchSpec};
use osaca::isa::InstructionForm;

fn main() -> Result<()> {
    let engine = Engine::new();
    let form = InstructionForm::parse("vfmadd132pd-mem_xmm_xmm");
    for arch in ["zen", "skl"] {
        let machine = engine.machine(arch).map_err(|e| anyhow!("{e}"))?;
        println!("=== {} ===", machine.arch_name);

        // §II-C parallelism sweep (the ibench output listing).
        let sweep = run_sweep(&BenchSpec { form: form.clone() }, &machine)?;
        print!("{}", sweep.render(machine.frequency_ghz));

        // §II-B/C conflict probes.
        for probe in ["vaddpd-xmm_xmm_xmm", "vmulpd-xmm_xmm_xmm"] {
            let r = run_conflict(
                &BenchSpec { form: form.clone() },
                &BenchSpec::parse(probe),
                &machine,
            )?;
            println!("{}:  {:.3} (clk cy)", r.label, r.cy_per_instr);
        }

        // Automated deduction -> database entry.
        let probes = default_probes(&machine);
        let inf = infer_entry(&form, &machine, &probes)?;
        println!(
            "deduced: lat {:.1} cy, rTP {:.2} cy/instr, conflicts {:?}",
            inf.measured_latency, inf.measured_rtp, inf.conflicting_probes
        );
        let mut m2 = machine.as_ref().clone();
        m2.entries.clear();
        m2.insert(inf.entry.clone());
        let entry_line = m2
            .serialize()
            .lines()
            .find(|l| l.starts_with("entry"))
            .unwrap_or_default()
            .to_string();
        println!("  {entry_line}");
        // Compare with the shipped (ground-truth) database entry.
        if let Some(db) = machine.entries.get(&form) {
            println!(
                "  shipped entry: lat {} tp {} ({} µ-ops) — match: {}",
                db.latency,
                db.implied_rtp(),
                db.uops.len(),
                (db.implied_rtp() as f64 - inf.measured_rtp).abs() < 0.1
            );
        }
        // Machine-readable appendix: the same deduction through the
        // JSON table emitter — the identical 5-column shape (incl. the
        // serialized entry) that `build-model --format json` emits.
        println!(
            "{}",
            format_table(
                Format::Json,
                "build-model",
                &["form", "latency_cy", "rtp_cy_per_instr", "conflicting_probes", "entry"],
                &[vec![
                    inf.entry.form.to_string(),
                    format!("{:.2}", inf.measured_latency),
                    format!("{:.3}", inf.measured_rtp),
                    format!("{:?}", inf.conflicting_probes),
                    entry_line,
                ]],
            )
        );
        println!();
    }
    Ok(())
}
