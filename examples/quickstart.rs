//! Quickstart: analyze the Schönauer triad for both architectures and
//! compare against the simulated hardware — the paper's Fig. 4 flow.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use osaca::analyzer::analyze;
use osaca::coordinator::Coordinator;
use osaca::mdb;
use osaca::report::render_occupancy;
use osaca::sim::{simulate, SimConfig};
use osaca::workloads;

fn main() -> Result<()> {
    let coord = Coordinator::auto();
    for arch in ["skl", "zen"] {
        let machine = mdb::by_name(arch).unwrap();
        let w = workloads::find("triad", arch, "-O3").unwrap();
        let kernel = w.kernel();

        println!("=== {} ({}) — {} ===\n", machine.arch_name, arch, w.name());

        // 1. OSACA throughput analysis (Tables II / IV).
        let a = analyze(&kernel, &machine)?;
        println!("{}", render_occupancy(&a, &machine));

        // 2. Balanced baseline through the AOT artifact (IACA-like).
        let r = coord.analyze_kernel(&kernel, &machine)?;
        println!(
            "balanced baseline: {:.2} cy/asm-iter (uniform cross-check {:.2})",
            r.baseline.cy_per_asm_iter, r.baseline.uniform_cy
        );

        // 3. "Measurement" on the simulator substrate.
        let m = simulate(&kernel, &machine, SimConfig::default())?;
        println!(
            "simulated hardware: {:.2} cy/asm-iter = {:.2} cy per source iteration\n",
            m.cycles_per_iteration,
            m.cy_per_source_it(w.unroll)
        );
    }
    Ok(())
}
