//! Quickstart: analyze the Schönauer triad for x86 (Skylake, Zen),
//! AArch64 (ThunderX2) and RISC-V (RV64) and compare against the
//! simulated hardware — the paper's Fig. 4 flow plus its "generalize
//! to new architectures" outlook, driven entirely through the
//! `osaca::api` session layer (the `tx2`/`rv64` archs flip the
//! frontend to the matching syntax automatically).
//!
//! Instead of grepping report text, the structured `Prediction` is the
//! thing to inspect: every resource bound (port pressure, the
//! width-aware frontend bound, divider occupancy, critical path) with
//! the winner identifying *why* the kernel is slow. On the 2-wide
//! `rv64` core the winner flips from the LS port (3.0 cy) to the
//! frontend (4.0 cy) — exactly what the simulator measures.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use osaca::api::{BoundKind, Engine, Passes};
use osaca::workloads;

fn main() -> Result<()> {
    let engine = Engine::new();
    for (arch, flag) in [("skl", "-O3"), ("zen", "-O3"), ("tx2", "-O2"), ("rv64", "-O2")] {
        let w = workloads::find("triad", arch, flag).unwrap();

        // One request, every pass — with the width-aware frontend
        // bound on, so narrow cores are predicted correctly (the
        // paper-pinned wide-core tables are unaffected: their port
        // bound dominates).
        let report = engine.analyze(
            &Engine::request(&w.name())
                .arch(arch)
                .source(w.source)
                .passes(Passes::ALL)
                .frontend_bound(true)
                .unroll(w.unroll),
        )?;

        print!("{}", report.to_text());

        // Bound inspection: a queryable decomposition, not a string.
        let prediction = report.prediction();
        let winner = prediction.winner().expect("analytic passes ran");
        println!(
            "winning bound: {} ({}) -> {:.2} cy / assembly iteration",
            winner.kind.name(),
            winner.resource,
            winner.cy_per_asm_iter
        );
        for bound in &prediction.bounds {
            println!(
                "  {:<14} {:>6.2} cy  [{}, from the {} pass]",
                bound.kind.name(),
                bound.cy_per_asm_iter,
                bound.resource,
                bound.source.name()
            );
        }
        // The simulator's measurement rides along in the same
        // vocabulary — compare prediction vs observation directly.
        let sim = prediction.bound(BoundKind::Simulated).expect("simulate pass ran");
        println!(
            "simulated hardware: {:.2} cy/asm-iter ({}), predicted {:.2}\n",
            sim.cy_per_asm_iter,
            sim.resource,
            winner.cy_per_asm_iter
        );
    }
    Ok(())
}
