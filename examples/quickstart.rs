//! Quickstart: analyze the Schönauer triad for x86 (Skylake, Zen),
//! AArch64 (ThunderX2) and RISC-V (RV64) and compare against the
//! simulated hardware — the paper's Fig. 4 flow plus its "generalize
//! to new architectures" outlook, driven entirely through the
//! `osaca::api` session layer (the `tx2`/`rv64` archs flip the
//! frontend to the matching syntax automatically).
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use osaca::api::{Engine, Passes};
use osaca::workloads;

fn main() -> Result<()> {
    let engine = Engine::new();
    for (arch, flag) in [("skl", "-O3"), ("zen", "-O3"), ("tx2", "-O2"), ("rv64", "-O2")] {
        let w = workloads::find("triad", arch, flag).unwrap();

        // One request, every pass: OSACA throughput analysis (Tables
        // II/IV), the balanced IACA-like baseline through the batching
        // solver, and a "measurement" on the simulator substrate.
        let report = engine.analyze(
            &Engine::request(&w.name())
                .arch(arch)
                .source(w.source)
                .passes(Passes::THROUGHPUT | Passes::BASELINE | Passes::SIMULATE)
                .unroll(w.unroll),
        )?;

        print!("{}", report.to_text());
        let b = report.baseline.as_ref().expect("baseline pass");
        println!(
            "balanced baseline: {:.2} cy/asm-iter (uniform cross-check {:.2})",
            b.cy_per_asm_iter, b.uniform_cy
        );
        let m = report.simulation.as_ref().expect("simulate pass");
        println!(
            "simulated hardware: {:.2} cy/asm-iter = {:.2} cy per source iteration\n",
            m.cycles_per_iteration,
            m.cy_per_source_it(w.unroll)
        );
    }
    Ok(())
}
