//! Corpus-scale analysis: score a directory (or tar archive) of `.s`
//! basic blocks and aggregate a scorecard.
//!
//! A *corpus block* is one assembly file holding one basic block —
//! BHive-style input with no IACA/OSACA markers and usually no loop
//! back-edge; kernel extraction falls back to whole-file-as-kernel for
//! these. Blocks stream through [`crate::api::Engine::analyze_batch`],
//! which fans the analytic passes out on the shared work-stealing
//! executor ([`crate::exec`]), so corpus throughput scales with cores
//! without any scheduling code here.
//!
//! The scorecard is a **sibling document** of the v4 report schema: it
//! carries the same `"schema_version":5` tag but its own `"kind"`, and
//! adds no keys to the existing report/stats shapes. It contains no
//! timestamps or host identifiers — the same corpus and machine model
//! must produce byte-identical output across runs (CI diffs two runs).
//! With [`CorpusOptions::mem_model`] set, the bottleneck histogram
//! gains a `memory` bucket for blocks whose working set blows the
//! hierarchy; without it, scoring is byte-identical to infinite-L1.

use std::collections::{BTreeMap, HashMap};
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::api::{Engine, Passes};
use crate::report::emit::{csv_field, fmt_f32, fmt_f64, push_json_string, SCHEMA_VERSION};

/// One assembly basic block of the corpus, named by its path within
/// the corpus root (or tar archive).
#[derive(Debug, Clone)]
pub struct CorpusBlock {
    pub name: String,
    pub source: String,
}

/// Knobs for [`score_blocks`].
#[derive(Debug, Clone)]
pub struct CorpusOptions {
    /// Machine model to score against (default `skl`).
    pub arch: String,
    /// Include the opt-in frontend bound in each block's prediction.
    pub frontend_bound: bool,
    /// Opt-in memory-model spec (`crate::sim::MemModel` grammar) added
    /// to every block's request; blocks whose footprint blows the
    /// hierarchy land in the scorecard's `memory` histogram bucket.
    /// `None` keeps the infinite-L1 scoring byte-identical.
    pub mem_model: Option<String>,
    /// Blocks per `analyze_batch` call. Bounds peak memory on huge
    /// corpora while still keeping the executor saturated.
    pub chunk: usize,
}

impl Default for CorpusOptions {
    fn default() -> Self {
        CorpusOptions {
            arch: "skl".to_string(),
            frontend_bound: false,
            mem_model: None,
            chunk: 256,
        }
    }
}

/// Per-block scoring outcome. Failed blocks keep their slot (with
/// `bound == "error"`) so the scorecard always covers the whole corpus.
#[derive(Debug, Clone)]
pub struct BlockScore {
    pub name: String,
    /// Predicted cycles per assembly iteration (the winning model
    /// bound); `None` when analysis failed.
    pub cy_per_asm_iter: Option<f32>,
    /// Winning bound kind name (`port_pressure`, `frontend`, `divider`,
    /// `critical_path`) or `error`.
    pub bound: String,
    /// The concrete winning resource (port name, rename stage, chain).
    pub resource: String,
    pub error: Option<String>,
}

/// Aggregate corpus scorecard: every block's prediction plus the
/// bottleneck histogram and (optional) accuracy vs. measured cycles.
#[derive(Debug, Clone)]
pub struct Scorecard {
    pub arch: String,
    pub scores: Vec<BlockScore>,
    /// Bound-kind name → number of blocks it won (plus the `error`
    /// bucket). `BTreeMap` so rendering order is deterministic.
    pub histogram: BTreeMap<String, u64>,
    /// Blocks matched against the measured-cycles sidecar.
    pub measured_blocks: u64,
    /// Mean absolute percentage error vs. the sidecar, in percent.
    pub mape_pct: Option<f64>,
}

impl Scorecard {
    pub fn errors(&self) -> u64 {
        self.histogram.get("error").copied().unwrap_or(0)
    }

    /// Scorecard as one JSON document (`"kind":"corpus_scorecard"`,
    /// tagged with the shared wire [`SCHEMA_VERSION`]). Key order is
    /// fixed and no timestamps are included: identical inputs render
    /// byte-identical output.
    pub fn render_json(&self) -> String {
        let mut out = String::with_capacity(256 + self.scores.len() * 96);
        out.push_str(&format!(
            "{{\"schema_version\":{SCHEMA_VERSION},\"kind\":\"corpus_scorecard\",\"arch\":"
        ));
        push_json_string(&mut out, &self.arch);
        out.push_str(&format!(",\"blocks\":{},\"errors\":{}", self.scores.len(), self.errors()));
        out.push_str(",\"histogram\":{");
        for (i, (kind, n)) in self.histogram.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            push_json_string(&mut out, kind);
            out.push_str(&format!(":{n}"));
        }
        out.push('}');
        out.push_str(&format!(",\"measured_blocks\":{}", self.measured_blocks));
        out.push_str(",\"mape_pct\":");
        match self.mape_pct {
            Some(v) => out.push_str(&fmt_f64(v)),
            None => out.push_str("null"),
        }
        out.push_str(",\"scores\":[");
        for (i, s) in self.scores.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("{\"name\":");
            push_json_string(&mut out, &s.name);
            out.push_str(",\"cy_per_asm_iter\":");
            match s.cy_per_asm_iter {
                Some(v) => out.push_str(&fmt_f32(v)),
                None => out.push_str("null"),
            }
            out.push_str(",\"bound\":");
            push_json_string(&mut out, &s.bound);
            out.push_str(",\"resource\":");
            push_json_string(&mut out, &s.resource);
            out.push_str(",\"error\":");
            match &s.error {
                Some(e) => push_json_string(&mut out, e),
                None => out.push_str("null"),
            }
            out.push('}');
        }
        out.push_str("]}");
        out
    }

    /// Per-block rows as RFC-4180 CSV with a header line. Aggregates
    /// (histogram, MAPE) are JSON-only; CSV is the flat per-block view.
    pub fn render_csv(&self) -> String {
        let mut out = String::from("name,cy_per_asm_iter,bound,resource,error\r\n");
        for s in &self.scores {
            let cy = match s.cy_per_asm_iter {
                Some(v) => fmt_f32(v),
                None => String::new(),
            };
            out.push_str(&format!(
                "{},{},{},{},{}\r\n",
                csv_field(&s.name),
                cy,
                csv_field(&s.bound),
                csv_field(&s.resource),
                csv_field(s.error.as_deref().unwrap_or("")),
            ));
        }
        out
    }
}

/// Load corpus blocks from `path`: a directory (every `.s` file,
/// recursively), a `.tar` archive of `.s` files, or a single `.s`
/// file. Blocks are sorted by name so corpus order — and therefore the
/// scorecard — is independent of filesystem enumeration order.
pub fn load_blocks(path: &Path) -> Result<Vec<CorpusBlock>> {
    let mut blocks = Vec::new();
    if path.is_dir() {
        walk_dir(path, path, &mut blocks)?;
    } else if path.extension().and_then(|e| e.to_str()) == Some("tar") {
        let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        load_tar(&bytes, &mut blocks)?;
    } else {
        let source =
            fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        blocks.push(CorpusBlock { name, source });
    }
    blocks.sort_by(|a, b| a.name.cmp(&b.name));
    if blocks.is_empty() {
        bail!("no .s blocks found under {}", path.display());
    }
    Ok(blocks)
}

fn walk_dir(dir: &Path, root: &Path, out: &mut Vec<CorpusBlock>) -> Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("reading directory {}", dir.display()))?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_dir(&p, root, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("s") {
            let source =
                fs::read_to_string(&p).with_context(|| format!("reading {}", p.display()))?;
            let name = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace(std::path::MAIN_SEPARATOR, "/");
            out.push(CorpusBlock { name, source });
        }
    }
    Ok(())
}

/// Minimal ustar reader: 512-byte headers, octal size, regular-file
/// entries only. Enough for archives produced by `tar -cf` (and by
/// `scripts/gen_corpus.py --tar`); no extensions (pax, GNU longname).
fn load_tar(bytes: &[u8], out: &mut Vec<CorpusBlock>) -> Result<()> {
    let mut off = 0usize;
    while off + 512 <= bytes.len() {
        let hdr = &bytes[off..off + 512];
        if hdr.iter().all(|&b| b == 0) {
            break; // end-of-archive marker
        }
        let name = tar_str(&hdr[0..100]);
        let prefix = tar_str(&hdr[345..500]);
        let size = tar_octal(&hdr[124..136])
            .with_context(|| format!("bad size field in tar header for `{name}`"))?;
        let typeflag = hdr[156];
        let full = if prefix.is_empty() { name.to_string() } else { format!("{prefix}/{name}") };
        let data = off + 512;
        let end = data + size;
        if end > bytes.len() {
            bail!("truncated tar entry `{full}`");
        }
        if (typeflag == b'0' || typeflag == 0) && full.ends_with(".s") {
            let source = String::from_utf8_lossy(&bytes[data..end]).into_owned();
            out.push(CorpusBlock { name: full, source });
        }
        off = data + size.div_ceil(512) * 512;
    }
    Ok(())
}

fn tar_str(field: &[u8]) -> &str {
    let len = field.iter().position(|&b| b == 0).unwrap_or(field.len());
    std::str::from_utf8(&field[..len]).unwrap_or("").trim()
}

fn tar_octal(field: &[u8]) -> Result<usize> {
    let s = tar_str(field);
    if s.is_empty() {
        return Ok(0);
    }
    usize::from_str_radix(s, 8).map_err(|e| anyhow::anyhow!("{e}"))
}

/// Score every block through the engine's batch path (throughput +
/// critical-path passes) and aggregate the bottleneck histogram.
/// Blocks are scored in chunks of [`CorpusOptions::chunk`]; results
/// stay in corpus order regardless of executor scheduling.
pub fn score_blocks(engine: &Engine, blocks: &[CorpusBlock], opts: &CorpusOptions) -> Scorecard {
    let passes = Passes::THROUGHPUT | Passes::CRITPATH;
    let mut scores: Vec<BlockScore> = Vec::with_capacity(blocks.len());
    for chunk in blocks.chunks(opts.chunk.max(1)) {
        let reqs: Vec<_> = chunk
            .iter()
            .map(|b| {
                let mut req = Engine::request(&b.name)
                    .arch(&opts.arch)
                    .source(b.source.as_str())
                    .passes(passes)
                    .frontend_bound(opts.frontend_bound);
                if let Some(spec) = &opts.mem_model {
                    req = req.mem_model(spec.clone());
                }
                req
            })
            .collect();
        for (b, outcome) in chunk.iter().zip(engine.analyze_batch(&reqs)) {
            scores.push(match outcome {
                Ok(report) => {
                    let prediction = report.prediction();
                    match prediction.winner() {
                        Some(w) => BlockScore {
                            name: b.name.clone(),
                            cy_per_asm_iter: Some(w.cy_per_asm_iter),
                            bound: w.kind.name().to_string(),
                            resource: w.resource.clone(),
                            error: None,
                        },
                        None => BlockScore {
                            name: b.name.clone(),
                            cy_per_asm_iter: None,
                            bound: "error".to_string(),
                            resource: String::new(),
                            error: Some("no model bound produced".to_string()),
                        },
                    }
                }
                Err(e) => BlockScore {
                    name: b.name.clone(),
                    cy_per_asm_iter: None,
                    bound: "error".to_string(),
                    resource: String::new(),
                    error: Some(e.to_string()),
                },
            });
        }
    }
    let mut histogram = BTreeMap::new();
    for s in &scores {
        *histogram.entry(s.bound.clone()).or_insert(0u64) += 1;
    }
    Scorecard { arch: opts.arch.clone(), scores, histogram, measured_blocks: 0, mape_pct: None }
}

/// Fold a measured-cycles sidecar (`name,cycles` CSV; `#` comments and
/// a `name,cycles` header tolerated) into the scorecard's MAPE. Blocks
/// without a measurement — and measurements without a block — are
/// skipped; only positive measurements with a successful prediction
/// count.
pub fn attach_measured(card: &mut Scorecard, csv: &str) -> Result<()> {
    let mut measured: HashMap<String, f64> = HashMap::new();
    for (lineno, raw) in csv.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((name, cy)) = line.rsplit_once(',') else {
            bail!("sidecar line {}: expected `name,cycles`, got `{line}`", lineno + 1);
        };
        let name = name.trim();
        let cy = cy.trim();
        match cy.parse::<f64>() {
            Ok(v) => {
                measured.insert(name.to_string(), v);
            }
            // Tolerate a leading header row; anything else is a bad file.
            Err(_) if lineno == 0 => continue,
            Err(e) => bail!("sidecar line {}: bad cycles `{cy}`: {e}", lineno + 1),
        }
    }
    let mut n = 0u64;
    let mut sum = 0.0f64;
    for s in &card.scores {
        let (Some(pred), Some(&m)) = (s.cy_per_asm_iter, measured.get(&s.name)) else {
            continue;
        };
        if m > 0.0 {
            sum += ((pred as f64 - m) / m).abs();
            n += 1;
        }
    }
    card.measured_blocks = n;
    card.mape_pct = if n > 0 { Some(100.0 * sum / n as f64) } else { None };
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Backend;

    const BLOCK_A: &str = "vmovapd (%r15,%rax), %ymm0\nvaddpd %ymm0, %ymm1, %ymm2\n";
    const BLOCK_B: &str = "vfmadd231pd %ymm1, %ymm2, %ymm3\nvfmadd231pd %ymm1, %ymm2, %ymm3\n";

    fn tar_entry(name: &str, data: &[u8]) -> Vec<u8> {
        let mut hdr = vec![0u8; 512];
        hdr[..name.len()].copy_from_slice(name.as_bytes());
        let size = format!("{:011o}\0", data.len());
        hdr[124..124 + size.len()].copy_from_slice(size.as_bytes());
        hdr[156] = b'0';
        // Checksum: field treated as spaces while summing.
        hdr[148..156].fill(b' ');
        let sum: u32 = hdr.iter().map(|&b| b as u32).sum();
        let chk = format!("{sum:06o}\0 ");
        hdr[148..148 + chk.len()].copy_from_slice(chk.as_bytes());
        let mut out = hdr;
        out.extend_from_slice(data);
        out.resize(out.len().div_ceil(512) * 512, 0);
        out
    }

    #[test]
    fn tar_blocks_load_sorted_and_skip_non_asm() {
        let mut tar = Vec::new();
        tar.extend(tar_entry("b.s", BLOCK_B.as_bytes()));
        tar.extend(tar_entry("readme.txt", b"not assembly"));
        tar.extend(tar_entry("a.s", BLOCK_A.as_bytes()));
        tar.extend(vec![0u8; 1024]); // end-of-archive
        let mut blocks = Vec::new();
        load_tar(&tar, &mut blocks).unwrap();
        blocks.sort_by(|a, b| a.name.cmp(&b.name));
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].name, "a.s");
        assert_eq!(blocks[0].source, BLOCK_A);
        assert_eq!(blocks[1].name, "b.s");
    }

    #[test]
    fn truncated_tar_is_rejected() {
        let mut tar = tar_entry("a.s", BLOCK_A.as_bytes());
        tar.truncate(600); // header promises more data than present
        let mut blocks = Vec::new();
        assert!(load_tar(&tar, &mut blocks).is_err());
    }

    #[test]
    fn scorecard_covers_every_block_and_is_reproducible() {
        let engine = Engine::builder().backend(Backend::Cpu).build();
        let blocks = vec![
            CorpusBlock { name: "a.s".into(), source: BLOCK_A.into() },
            // Instruction-free source: kernel extraction rejects it.
            CorpusBlock { name: "bad.s".into(), source: "\n\n".into() },
            CorpusBlock { name: "b.s".into(), source: BLOCK_B.into() },
        ];
        let opts = CorpusOptions::default();
        let card = score_blocks(&engine, &blocks, &opts);
        assert_eq!(card.scores.len(), 3);
        assert_eq!(card.scores[0].name, "a.s");
        assert!(card.scores[0].cy_per_asm_iter.is_some());
        assert_eq!(card.scores[1].bound, "error");
        assert!(card.scores[1].error.is_some());
        assert_eq!(card.errors(), 1);
        assert_eq!(card.histogram.values().sum::<u64>(), 3);
        // Aggregate counts (and the rendered document) must not depend
        // on executor scheduling: score the same corpus again and
        // compare byte-for-byte.
        let again = score_blocks(&engine, &blocks, &opts);
        assert_eq!(card.render_json(), again.render_json());
        assert_eq!(card.render_csv(), again.render_csv());
        let json = card.render_json();
        assert!(json.starts_with(&format!("{{\"schema_version\":{SCHEMA_VERSION}")));
        assert!(json.contains("\"kind\":\"corpus_scorecard\""));
        assert!(json.contains("\"blocks\":3"));
        assert!(json.contains("\"errors\":1"));
    }

    #[test]
    fn mape_matches_hand_computation() {
        let mut card = Scorecard {
            arch: "skl".into(),
            scores: vec![
                BlockScore {
                    name: "a.s".into(),
                    cy_per_asm_iter: Some(2.0),
                    bound: "port_pressure".into(),
                    resource: "P0".into(),
                    error: None,
                },
                BlockScore {
                    name: "b.s".into(),
                    cy_per_asm_iter: Some(3.0),
                    bound: "critical_path".into(),
                    resource: "chain".into(),
                    error: None,
                },
                BlockScore {
                    name: "c.s".into(),
                    cy_per_asm_iter: None,
                    bound: "error".into(),
                    resource: String::new(),
                    error: Some("boom".into()),
                },
            ],
            histogram: BTreeMap::new(),
            measured_blocks: 0,
            mape_pct: None,
        };
        // a: |2-4|/4 = 0.5; b: |3-2|/2 = 0.5; c unmatched (error);
        // d present in sidecar but not the corpus — skipped.
        let sidecar = "name,cycles\na.s,4.0\nb.s,2.0\nc.s,1.0\nd.s,9.0\n";
        attach_measured(&mut card, sidecar).unwrap();
        assert_eq!(card.measured_blocks, 2);
        assert!((card.mape_pct.unwrap() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn csv_escapes_and_orders_rows() {
        let card = Scorecard {
            arch: "skl".into(),
            scores: vec![BlockScore {
                name: "odd,name.s".into(),
                cy_per_asm_iter: Some(1.5),
                bound: "frontend".into(),
                resource: "4-wide".into(),
                error: None,
            }],
            histogram: BTreeMap::new(),
            measured_blocks: 0,
            mape_pct: None,
        };
        let csv = card.render_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("name,cy_per_asm_iter,bound,resource,error"));
        assert_eq!(lines.next(), Some("\"odd,name.s\",1.5,frontend,4-wide,"));
    }
}
