//! The cycle-level out-of-order core loop.
//!
//! Replays the decoded iteration template N times through a
//! rename/dispatch → schedule → execute → retire pipeline and reports
//! steady-state cycles per assembly iteration plus hardware-style event
//! counters.

use std::collections::{HashMap, VecDeque};

use anyhow::Result;

use crate::asm::Kernel;
use crate::isa::register::RegisterFile;
use crate::mdb::{MachineModel, UopKind};

use super::decode::{decode_kernel, DecodedIter, DepSource, DepVersion, MemIdent};
use super::trace::Counters;

/// Simulation run parameters.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Measured iterations (after warm-up).
    pub iterations: usize,
    /// Warm-up iterations excluded from the measurement.
    pub warmup: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { iterations: 1000, warmup: 200 }
    }
}

/// Result of a simulation run — the "hardware measurement".
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Steady-state cycles per assembly-loop iteration.
    pub cycles_per_iteration: f64,
    pub iterations: usize,
    pub total_cycles: u64,
    pub counters: Counters,
    /// Busy cycles per port over the measured window.
    pub port_busy: Vec<u64>,
    /// Cycles in the measured window.
    pub window_cycles: u64,
}

impl Measurement {
    /// Performance in (source-code) iterations per second, given the
    /// machine frequency and the unroll factor of the assembly loop.
    pub fn iterations_per_sec(&self, freq_ghz: f64, unroll: usize) -> f64 {
        freq_ghz * 1e9 / self.cycles_per_iteration * unroll as f64
    }

    /// Cycles per *source* iteration for a given unroll factor.
    pub fn cy_per_source_it(&self, unroll: usize) -> f64 {
        self.cycles_per_iteration / unroll as f64
    }
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct MemKey {
    base: Option<(RegisterFile, u64)>,
    index: Option<(RegisterFile, u64)>,
    scale: u8,
    displacement: i64,
    symbol: Option<String>,
}

fn instantiate(ident: &MemIdent, iter: u64, uops_per_iter: u64) -> MemKey {
    let ver = |v: DepVersion| -> u64 {
        match v {
            DepVersion::Invariant => u64::MAX,
            DepVersion::Iter(w) => iter * uops_per_iter + w as u64,
            DepVersion::CarriedIter(w) => {
                if iter == 0 {
                    u64::MAX - 1
                } else {
                    (iter - 1) * uops_per_iter + w as u64
                }
            }
        }
    };
    MemKey {
        base: ident.base.map(|(f, v)| (f, ver(v))),
        index: ident.index.map(|(f, v)| (f, ver(v))),
        scale: ident.scale,
        displacement: ident.displacement,
        symbol: ident.symbol.clone(),
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum UopState {
    Waiting,
    /// Issued; result available at the stored cycle.
    Done(u64),
}

#[derive(Debug, Clone)]
struct InFlight {
    /// Index into the iteration template.
    tidx: usize,
    iter: u64,
    state: UopState,
    /// Forwarding source (global store id), resolved at dispatch.
    fwd_store: Option<u64>,
}

/// Simulate `cfg.warmup + cfg.iterations` iterations of the kernel.
pub fn simulate(kernel: &Kernel, machine: &MachineModel, cfg: SimConfig) -> Result<Measurement> {
    let template = decode_kernel(kernel, machine)?;
    Ok(run(&template, machine, cfg))
}

/// Run a pre-decoded template (used by ibench to avoid re-decoding).
pub fn run(template: &DecodedIter, machine: &MachineModel, cfg: SimConfig) -> Measurement {
    let nuops = template.uops.len();
    let total_iters = (cfg.warmup + cfg.iterations) as u64;
    let uops_per_iter = nuops as u64;
    let n_ports = machine.n_ports();
    let rob_size = machine.params.rob_size;
    let sched_size = machine.params.scheduler_size;
    let rename_width = machine.params.rename_width;
    let retire_width = machine.params.retire_width;
    let fwd_lat = machine.params.store_forward_latency as u64;
    let load_lat = machine.params.load_latency as u64;

    // Slot structure for frontend/retire bandwidth: ranges of µ-ops that
    // share a fused rename slot, plus eliminated-but-renamed slots that
    // consume dispatch bandwidth without entering the ROB.
    let mut slot_ranges: Vec<(usize, usize)> = Vec::new();
    for (i, u) in template.uops.iter().enumerate() {
        if u.new_slot {
            slot_ranges.push((i, i + 1));
        } else if let Some(last) = slot_ranges.last_mut() {
            last.1 = i + 1;
        }
    }
    let empty_slots = template.slots.saturating_sub(slot_ranges.len());

    let mut rob: VecDeque<InFlight> = VecDeque::with_capacity(rob_size + nuops);
    // Un-issued µ-ops (global id, wake-up hint) in dispatch order — the
    // scheduler. The hint is the earliest cycle the µ-op could possibly
    // issue (dep completion / port free time), so sleeping µ-ops are
    // skipped with one comparison.
    let mut waiting: Vec<(u64, u64)> = Vec::with_capacity(sched_size + nuops);
    let mut rob_head_gid: u64 = 0; // global id of rob.front()
    let mut next_gid: u64 = 0; // next µ-op to dispatch (global)
    let mut sched_occupancy: usize = 0;
    let mut port_free_at: Vec<u64> = vec![0; n_ports];
    let mut port_busy: Vec<u64> = vec![0; n_ports];
    let mut last_store: HashMap<MemKey, u64> = HashMap::new();
    let mut store_done: HashMap<u64, u64> = HashMap::new();
    let mut counters = Counters::default();

    // Dispatch cursor in slot units.
    let mut disp_iter: u64 = 0;
    let mut disp_slot: usize = 0; // 0..empty_slots+slot_ranges.len()
    let total_slots = empty_slots + slot_ranges.len();

    // Retire cursor.
    let mut ret_iter: u64 = 0;
    let mut ret_slot: usize = 0;
    let mut retired_iters: u64 = 0;

    // Measurement window.
    let mut window_start_cycle: Option<u64> = None;
    let mut window_start_counters = Counters::default();
    let mut window_start_ports: Vec<u64> = vec![0; n_ports];

    let mut cycle: u64 = 0;
    let max_cycles: u64 = 1_000_000_000; // hard safety stop

    let done_of = |rob: &VecDeque<InFlight>, rob_head_gid: u64, gid: u64| -> Option<u64> {
        if gid < rob_head_gid {
            return Some(0); // retired long ago
        }
        match rob.get((gid - rob_head_gid) as usize) {
            Some(f) => match f.state {
                UopState::Done(c) => Some(c),
                UopState::Waiting => None,
            },
            None => None, // not yet dispatched
        }
    };

    while retired_iters < total_iters && cycle < max_cycles {
        // ---------------- retire ------------------------------------
        let mut retired_slots = 0;
        while retired_slots < retire_width && ret_iter < total_iters {
            if ret_slot < empty_slots {
                // Eliminated slot: retires for free once reached.
                ret_slot += 1;
                retired_slots += 1;
                continue;
            }
            let (s, e) = slot_ranges[ret_slot - empty_slots];
            let first_gid = ret_iter * uops_per_iter + s as u64;
            if first_gid < rob_head_gid {
                // already popped (shouldn't happen) — advance
                ret_slot += 1;
                continue;
            }
            let all_done = (s..e).all(|t| {
                let gid = ret_iter * uops_per_iter + t as u64;
                matches!(done_of(&rob, rob_head_gid, gid), Some(c) if c <= cycle)
            });
            if !all_done {
                break;
            }
            // Pop the slot's µ-ops from the ROB front.
            for _ in s..e {
                rob.pop_front();
                rob_head_gid += 1;
            }
            ret_slot += 1;
            retired_slots += 1;
            if ret_slot == total_slots {
                ret_slot = 0;
                ret_iter += 1;
                retired_iters += 1;
                if retired_iters == cfg.warmup as u64 {
                    window_start_cycle = Some(cycle);
                    window_start_counters = counters.clone();
                    window_start_ports = port_busy.clone();
                }
            }
        }

        // ---------------- issue / execute ---------------------------
        let mut issued_any = false;
        // Oldest-first over the scheduler contents. `waiting` holds the
        // global ids of un-issued µ-ops in dispatch (= age) order, so
        // the scan is O(scheduler occupancy), not O(ROB).
        waiting.retain_mut(|(gid, wake)| {
            if *wake > cycle {
                return true; // sleeping on a known future event
            }
            let gid = *gid;
            let i = (gid - rob_head_gid) as usize;
            debug_assert_eq!(rob[i].state, UopState::Waiting);
            let tu = &template.uops[rob[i].tidx];
            // Dependencies ready?
            let iter = rob[i].iter;
            let mut ready = true;
            for d in &tu.deps {
                let dep_gid = match d {
                    DepSource::Intra(w) => iter * uops_per_iter + *w as u64,
                    DepSource::Carried(w) => {
                        if iter == 0 {
                            continue;
                        }
                        (iter - 1) * uops_per_iter + *w as u64
                    }
                    DepSource::Invariant => continue,
                };
                match done_of(&rob, rob_head_gid, dep_gid) {
                    Some(c) if c <= cycle => {}
                    Some(c) => {
                        // Dep issued; completion cycle is known — sleep.
                        *wake = c;
                        ready = false;
                        break;
                    }
                    None => {
                        ready = false;
                        break;
                    }
                }
            }
            if !ready {
                return true; // stay in the scheduler
            }
            // Forwarding store must have produced its data.
            let mut fwd_done: Option<u64> = None;
            if let Some(sid) = rob[i].fwd_store {
                match store_done
                    .get(&sid)
                    .copied()
                    .or_else(|| done_of(&rob, rob_head_gid, sid))
                {
                    Some(c) if c <= cycle => fwd_done = Some(c),
                    Some(c) => {
                        *wake = c;
                        return true;
                    }
                    None => return true, // store not yet issued
                }
            }
            // Port available? occupancy 0 → no port needed.
            let done_cycle = if tu.occupancy == 0 {
                cycle + tu.latency.max(1) as u64
            } else {
                // Spread symmetric choices: rotate the starting port.
                // (Bitmask walk — no allocation on this path.)
                let mut chosen: Option<usize> = None;
                let nports = tu.ports.count() as usize;
                let off = (gid as usize) % nports;
                let mut seen = 0usize;
                let mut wrapped: Option<usize> = None;
                for p in 0..16usize {
                    if !tu.ports.contains(p) {
                        continue;
                    }
                    if port_free_at[p] <= cycle {
                        if seen >= off {
                            chosen = Some(p);
                            break;
                        } else if wrapped.is_none() {
                            wrapped = Some(p);
                        }
                    }
                    seen += 1;
                }
                let chosen = chosen.or(wrapped);
                let Some(p) = chosen else { return true };
                port_free_at[p] = cycle + tu.occupancy as u64;
                port_busy[p] += tu.occupancy as u64;
                let mut dc = cycle + tu.latency.max(1) as u64;
                if tu.kind == UopKind::Load {
                    let base = cycle + load_lat;
                    dc = match fwd_done {
                        Some(sc) => base.max(sc + fwd_lat),
                        None => base,
                    };
                }
                dc
            };
            rob[i].state = UopState::Done(done_cycle);
            sched_occupancy -= 1;
            counters.uops_executed += 1;
            issued_any = true;
            if tu.kind == UopKind::StoreData {
                store_done.insert(gid, done_cycle);
            }
            false // issued: leave the scheduler
        });
        if !issued_any && !rob.is_empty() {
            counters.issue_stall_cycles += 1;
        }

        // ---------------- dispatch / rename --------------------------
        let mut dispatched = 0;
        while dispatched < rename_width && disp_iter < total_iters {
            if disp_slot < empty_slots {
                disp_slot += 1;
                dispatched += 1;
                continue;
            }
            let (s, e) = slot_ranges[disp_slot - empty_slots];
            let n_new = e - s;
            if rob.len() + n_new > rob_size || sched_occupancy + n_new > sched_size {
                counters.dispatch_stall_cycles += 1;
                break;
            }
            for t in s..e {
                let tu = &template.uops[t];
                let mut fwd_store = None;
                if tu.kind == UopKind::Load {
                    if let Some(ident) = &tu.mem_ident {
                        let key = instantiate(ident, disp_iter, uops_per_iter);
                        if let Some(&sid) = last_store.get(&key) {
                            fwd_store = Some(sid);
                            counters.forwarded_loads += 1;
                        }
                    }
                } else if tu.kind == UopKind::StoreData {
                    if let Some(ident) = &tu.mem_ident {
                        let key = instantiate(ident, disp_iter, uops_per_iter);
                        last_store.insert(key, next_gid);
                    }
                }
                rob.push_back(InFlight {
                    tidx: t,
                    iter: disp_iter,
                    state: UopState::Waiting,
                    fwd_store,
                });
                waiting.push((next_gid, 0));
                next_gid += 1;
                sched_occupancy += 1;
            }
            counters.uops_dispatched += n_new as u64;
            disp_slot += 1;
            dispatched += 1;
            if disp_slot == total_slots {
                disp_slot = 0;
                disp_iter += 1;
                // Trim the store bookkeeping occasionally.
                if disp_iter % 64 == 0 && store_done.len() > 1024 {
                    let min_keep = rob_head_gid.saturating_sub(uops_per_iter * 8);
                    store_done.retain(|gid, _| *gid >= min_keep);
                    last_store.retain(|_, gid| *gid >= min_keep);
                }
            }
        }

        cycle += 1;
    }

    let wstart = window_start_cycle.unwrap_or(0);
    let window_cycles = cycle.saturating_sub(wstart).max(1);
    let measured_iters = cfg.iterations.max(1);
    let mut wcounters = counters.clone();
    wcounters.subtract(&window_start_counters);
    let wports: Vec<u64> = port_busy
        .iter()
        .zip(window_start_ports.iter())
        .map(|(a, b)| a - b)
        .collect();
    Measurement {
        cycles_per_iteration: window_cycles as f64 / measured_iters as f64,
        iterations: measured_iters,
        total_cycles: cycle,
        counters: wcounters,
        port_busy: wports,
        window_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::extract_kernel;
    use crate::mdb::{skylake, zen};

    fn measure(src: &str, m: &MachineModel) -> Measurement {
        let k = extract_kernel("t", src).unwrap();
        simulate(&k, m, SimConfig { iterations: 500, warmup: 100 }).unwrap()
    }

    #[test]
    fn single_add_chain_is_latency_bound() {
        // One loop-carried vaddpd chain: 4 cy/iter on SKL, 3 on Zen.
        let src = "\n.L1:\nvaddpd %xmm1, %xmm0, %xmm0\ncmpl $1, %eax\njne .L1\n";
        let skl = measure(src, &skylake());
        assert!((skl.cycles_per_iteration - 4.0).abs() < 0.2, "{}", skl.cycles_per_iteration);
        let zen_m = measure(src, &zen());
        assert!((zen_m.cycles_per_iteration - 3.0).abs() < 0.2, "{}", zen_m.cycles_per_iteration);
    }

    #[test]
    fn independent_adds_are_throughput_bound() {
        // Twelve parallel chains on 2 ports: port bound 6 cy/iter beats
        // the 4 cy chain latency — the §II-A TP benchmark shape.
        let body: String = (0..12)
            .map(|i| format!("vaddpd %xmm{}, %xmm{}, %xmm{}\n", 12 + i % 3, i, i))
            .collect();
        let src = format!("\n.L1:\n{body}cmpl $1, %eax\njne .L1\n");
        let m = measure(&src, &skylake());
        assert!((m.cycles_per_iteration - 6.0).abs() < 0.4, "{}", m.cycles_per_iteration);
    }

    #[test]
    fn three_chains_are_latency_bound() {
        // Only three chains: the 4-cycle dependency chain dominates the
        // 1.5-cycle port bound.
        let src = "\n.L1:\nvaddpd %xmm3, %xmm0, %xmm0\nvaddpd %xmm4, %xmm1, %xmm1\nvaddpd %xmm5, %xmm2, %xmm2\ncmpl $1, %eax\njne .L1\n";
        let m = measure(src, &skylake());
        assert!((m.cycles_per_iteration - 4.0).abs() < 0.3, "{}", m.cycles_per_iteration);
    }

    #[test]
    fn divider_pipe_gates_throughput() {
        // Independent divides: DV occupancy 4 -> 4 cy/iter on SKL.
        let src = "\n.L1:\nvdivsd %xmm1, %xmm2, %xmm0\ncmpl $1, %eax\njne .L1\n";
        let m = measure(src, &skylake());
        assert!((m.cycles_per_iteration - 4.0).abs() < 0.3, "{}", m.cycles_per_iteration);
        // Zen: scaled divider (5 cy).
        let mz = measure(src, &zen());
        assert!((mz.cycles_per_iteration - 5.0).abs() < 0.3, "{}", mz.cycles_per_iteration);
    }

    #[test]
    fn store_forward_chain_matches_o1_anomaly() {
        // The §III-B pattern: sum updated through the stack.
        let src = "\n.L2:\nvaddsd (%rsp), %xmm0, %xmm5\nvmovsd %xmm5, (%rsp)\naddl $1, %eax\ncmpl $100, %eax\njne .L2\n";
        let m = measure(src, &skylake());
        // fwd(4) + addsd(4) + store(1) = 9 cy/iter.
        assert!((m.cycles_per_iteration - 9.0).abs() < 0.5, "{}", m.cycles_per_iteration);
        assert!(m.counters.forwarded_loads > 0);
    }

    #[test]
    fn unrelated_store_does_not_forward() {
        let src = "\n.L2:\nvaddsd (%rsp), %xmm0, %xmm5\nvmovsd %xmm5, 8(%rsp)\naddl $1, %eax\ncmpl $100, %eax\njne .L2\n";
        let m = measure(src, &skylake());
        assert!(m.cycles_per_iteration < 2.5, "{}", m.cycles_per_iteration);
    }

    #[test]
    fn load_bound_triad_hits_two_cycles() {
        // Triad -O2-style scalar: 3 loads + 1 store on 2 AGU-capable
        // ports -> 2 cy/iter on SKL.
        let src = "\n.L3:\nvmovsd (%rcx,%rax,8), %xmm0\nvmulsd (%rdx,%rax,8), %xmm0, %xmm0\nvaddsd (%rsi,%rax,8), %xmm0, %xmm0\nvmovsd %xmm0, (%rdi,%rax,8)\naddq $1, %rax\ncmpq %rbp, %rax\njne .L3\n";
        let m = measure(src, &skylake());
        assert!((m.cycles_per_iteration - 2.0).abs() < 0.3, "{}", m.cycles_per_iteration);
    }

    #[test]
    fn stall_counter_high_for_dependency_chain() {
        let chain = "\n.L2:\nvaddsd (%rsp), %xmm0, %xmm5\nvmovsd %xmm5, (%rsp)\naddl $1, %eax\ncmpl $100, %eax\njne .L2\n";
        let tp_body: String = (0..12)
            .map(|i| format!("vaddpd %xmm{}, %xmm{}, %xmm{}\n", 12 + i % 3, i, i))
            .collect();
        let tp = format!("\n.L2:\n{tp_body}addl $1, %eax\ncmpl $100, %eax\njne .L2\n");
        let a = measure(chain, &skylake());
        let b = measure(&tp, &skylake());
        let ra = a.counters.issue_stall_cycles as f64 / a.window_cycles as f64;
        let rb = b.counters.issue_stall_cycles as f64 / b.window_cycles as f64;
        assert!(ra > 4.0 * rb.max(0.01), "stall ratios {ra} vs {rb}");
    }
}
