//! The cycle-level out-of-order core loop.
//!
//! Replays the decoded iteration template N times through a
//! rename/dispatch → schedule → execute → retire pipeline and reports
//! steady-state cycles per assembly iteration plus hardware-style event
//! counters.
//!
//! The clock is **event-skipping**: on cycles where nothing retired,
//! issued or dispatched, the machine state is frozen except for time,
//! so the loop jumps directly to the next known event (a µ-op
//! completing, a port freeing, a scheduler wake hint) instead of
//! stepping `cycle += 1` through dead cycles. Stall counters are
//! accounted for the skipped span exactly as the strict loop would
//! have, so results are bit-identical (see DESIGN.md §Perf for why the
//! skip cannot change retire/dispatch ordering).

use std::collections::{HashMap, VecDeque};

use anyhow::Result;

use crate::asm::Kernel;
use crate::isa::register::RegisterFile;
use crate::mdb::{MachineModel, UopKind};

use super::decode::{slot_structure, DecodedIter, DecodedKernel, DepSource, DepVersion, MemIdent};
use super::mem::MemSimPlan;
use super::trace::Counters;

/// Simulation run parameters.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Measured iterations (after warm-up).
    pub iterations: usize,
    /// Warm-up iterations excluded from the measurement.
    pub warmup: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { iterations: 1000, warmup: 200 }
    }
}

/// Result of a simulation run — the "hardware measurement".
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Steady-state cycles per assembly-loop iteration.
    pub cycles_per_iteration: f64,
    pub iterations: usize,
    pub total_cycles: u64,
    pub counters: Counters,
    /// Busy cycles per port over the measured window.
    pub port_busy: Vec<u64>,
    /// Cycles in the measured window.
    pub window_cycles: u64,
    /// Fused rename/retire slots one iteration occupies (the frontend
    /// bandwidth unit — see `DecodedKernel::total_slots`).
    pub slots_per_iteration: usize,
}

impl Measurement {
    /// Performance in (source-code) iterations per second, given the
    /// machine frequency and the unroll factor of the assembly loop.
    pub fn iterations_per_sec(&self, freq_ghz: f64, unroll: usize) -> f64 {
        freq_ghz * 1e9 / self.cycles_per_iteration * unroll as f64
    }

    /// Cycles per *source* iteration for a given unroll factor.
    pub fn cy_per_source_it(&self, unroll: usize) -> f64 {
        self.cycles_per_iteration / unroll as f64
    }

    /// Name the resource that bounded the measured window, in the same
    /// vocabulary the analytic `Bound`s use: the busiest port when its
    /// per-iteration busy cycles saturate the iteration period (within
    /// half a cycle of slack for warm-up ripple); otherwise the
    /// frontend when the rename-slot bound `slots / rename_width`
    /// accounts for the period (e.g. the 2-wide `rv64` triad: LS busy
    /// 3.0 cy under a 4.0 cy = 8/2 period); otherwise a dependency
    /// chain — nothing structural saturated, so latency did.
    pub fn bottleneck_resource(&self, machine: &MachineModel) -> String {
        // A dispatch front half-throttled by LSQ-full cycles is a memory
        // bottleneck regardless of what the ports show downstream (only
        // possible under an opt-in memory model; off-mode keeps the
        // counter at zero).
        if self.counters.lsq_stall_cycles * 2 >= self.window_cycles {
            return "load/store queue".to_string();
        }
        let iters = self.iterations.max(1) as f64;
        let mut best = 0usize;
        let mut best_busy = f64::NEG_INFINITY;
        for (p, &b) in self.port_busy.iter().enumerate() {
            let busy = b as f64 / iters;
            // >= : last of equals, matching the analyzer convention.
            if busy >= best_busy {
                best_busy = busy;
                best = p;
            }
        }
        if !self.port_busy.is_empty() && best_busy + 0.5 >= self.cycles_per_iteration {
            return machine.ports[best].clone();
        }
        let width = machine.params.rename_width.max(1);
        let frontend_cy = self.slots_per_iteration as f64 / width as f64;
        if frontend_cy + 0.5 >= self.cycles_per_iteration {
            frontend_resource_label(self.slots_per_iteration, width)
        } else {
            "dependency chain".to_string()
        }
    }
}

/// The canonical resource label for a frontend (rename-width) bound,
/// e.g. `"8 slots / 2-wide"`. One definition on purpose: the analyzer's
/// `FrontendBound`, the simulator's [`Measurement::bottleneck_resource`]
/// and the report emitters must all speak the identical string so
/// prediction and measurement are comparable in JSON/CSV output.
pub fn frontend_resource_label(slots: usize, width: usize) -> String {
    format!("{slots} slots / {width}-wide")
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct MemKey {
    base: Option<(RegisterFile, u64)>,
    index: Option<(RegisterFile, u64)>,
    scale: u8,
    displacement: i64,
    symbol: Option<String>,
}

fn instantiate(ident: &MemIdent, iter: u64, uops_per_iter: u64) -> MemKey {
    let ver = |v: DepVersion| -> u64 {
        match v {
            DepVersion::Invariant => u64::MAX,
            DepVersion::Iter(w) => iter * uops_per_iter + w as u64,
            DepVersion::CarriedIter(w) => {
                if iter == 0 {
                    u64::MAX - 1
                } else {
                    (iter - 1) * uops_per_iter + w as u64
                }
            }
        }
    };
    MemKey {
        base: ident.base.map(|(f, v)| (f, ver(v))),
        index: ident.index.map(|(f, v)| (f, ver(v))),
        scale: ident.scale,
        displacement: ident.displacement,
        symbol: ident.symbol.clone(),
    }
}

/// Ring sentinel: the µ-op is dispatched but has no completion cycle
/// yet (not issued).
const NOT_DONE: u64 = u64::MAX;

/// Completion cycle of a µ-op by global id, against the done-cycle
/// ring. Retired µ-ops (gid below the ROB head) completed long ago;
/// gids at or past the dispatch cursor have no entry yet.
#[inline]
fn done_at(
    done: &[u64],
    ring_mask: usize,
    rob_head_gid: u64,
    next_gid: u64,
    gid: u64,
) -> Option<u64> {
    if gid < rob_head_gid {
        return Some(0); // retired long ago
    }
    if gid >= next_gid {
        return None; // not yet dispatched
    }
    match done[(gid as usize) & ring_mask] {
        NOT_DONE => None,
        c => Some(c),
    }
}

#[derive(Debug, Clone)]
struct InFlight {
    /// Index into the iteration template.
    tidx: usize,
    iter: u64,
    /// Forwarding source (global store id), resolved at dispatch.
    fwd_store: Option<u64>,
}

/// Simulate `cfg.warmup + cfg.iterations` iterations of the kernel.
pub fn simulate(kernel: &Kernel, machine: &MachineModel, cfg: SimConfig) -> Result<Measurement> {
    let template = DecodedKernel::new(kernel, machine)?;
    Ok(run_decoded(&template, machine, cfg))
}

/// Run a pre-decoded iteration template. Computes the slot structure on
/// every call; hot paths that re-simulate the same kernel should build
/// a [`DecodedKernel`] once and use [`run_decoded`].
pub fn run(template: &DecodedIter, machine: &MachineModel, cfg: SimConfig) -> Measurement {
    let (slot_ranges, empty_slots) = slot_structure(template);
    run_core(template, &slot_ranges, empty_slots, machine, cfg, None)
}

/// Run a prebuilt [`DecodedKernel`]: no per-call decode or slot-range
/// work. Bit-identical to [`simulate`] on the same kernel.
pub fn run_decoded(dk: &DecodedKernel, machine: &MachineModel, cfg: SimConfig) -> Measurement {
    run_core(&dk.iter, &dk.slot_ranges, dk.empty_slots, machine, cfg, None)
}

/// Like [`run_decoded`], but with an optional memory-model plan: loads
/// that open a new cacheline at the resident hierarchy level pay the
/// level's extra latency, and Load/StoreAgu µ-ops compete for a finite
/// load/store queue from dispatch to retire. `plan: None` is exactly
/// [`run_decoded`] — bit-identical, enforced by `tests/sim_memory.rs`.
pub fn run_decoded_mem(
    dk: &DecodedKernel,
    machine: &MachineModel,
    cfg: SimConfig,
    plan: Option<&MemSimPlan>,
) -> Measurement {
    run_core(&dk.iter, &dk.slot_ranges, dk.empty_slots, machine, cfg, plan)
}

fn run_core(
    template: &DecodedIter,
    slot_ranges: &[(usize, usize)],
    empty_slots: usize,
    machine: &MachineModel,
    cfg: SimConfig,
    plan: Option<&MemSimPlan>,
) -> Measurement {
    let nuops = template.uops.len();
    let total_iters = (cfg.warmup + cfg.iterations) as u64;
    let uops_per_iter = nuops as u64;
    let n_ports = machine.n_ports();
    let rob_size = machine.params.rob_size;
    let sched_size = machine.params.scheduler_size;
    let rename_width = machine.params.rename_width;
    let retire_width = machine.params.retire_width;
    let fwd_lat = machine.params.store_forward_latency as u64;
    let load_lat = machine.params.load_latency as u64;

    let mut rob: VecDeque<InFlight> = VecDeque::with_capacity(rob_size + nuops);
    // Un-issued µ-ops (global id, wake-up hint) in dispatch order — the
    // scheduler. The hint is the earliest cycle the µ-op could possibly
    // issue (dep completion / port free time), so sleeping µ-ops are
    // skipped with one comparison.
    let mut waiting: Vec<(u64, u64)> = Vec::with_capacity(sched_size + nuops);
    // Done-cycle ring indexed by gid: completion cycle of every
    // in-flight µ-op, NOT_DONE before issue. `gid & ring_mask` cannot
    // collide: live gids span [rob_head_gid, next_gid), whose width is
    // rob.len(), and dispatch refuses a slot whenever rob.len() + n_new
    // would exceed rob_size — so the live span never exceeds rob_size,
    // and ring_cap > rob_size by construction. The release-checked
    // retire assert below would trip on any violation of this bound.
    let ring_cap = (rob_size + nuops + 1).next_power_of_two();
    let ring_mask = ring_cap - 1;
    let mut done: Vec<u64> = vec![NOT_DONE; ring_cap];
    let mut rob_head_gid: u64 = 0; // global id of rob.front()
    let mut next_gid: u64 = 0; // next µ-op to dispatch (global)
    let mut sched_occupancy: usize = 0;
    let mut port_free_at: Vec<u64> = vec![0; n_ports];
    let mut port_busy: Vec<u64> = vec![0; n_ports];
    let mut last_store: HashMap<MemKey, u64> = HashMap::new();
    let mut store_done: HashMap<u64, u64> = HashMap::new();
    let mut counters = Counters::default();

    // Memory-model state (all dead when `plan` is None). Per-template-uop:
    // does it hold an LSQ entry (Load/StoreAgu, dispatch → retire), and
    // which Load ordinal is it (index into the plan's miss periods)?
    let lsq_size = plan.map_or(usize::MAX, |p| p.lsq_size);
    let mut lsq_uop: Vec<bool> = Vec::new();
    let mut load_ord: Vec<usize> = Vec::new();
    if plan.is_some() {
        let mut n_loads = 0usize;
        for u in &template.uops {
            lsq_uop.push(matches!(u.kind, UopKind::Load | UopKind::StoreAgu));
            if u.kind == UopKind::Load {
                load_ord.push(n_loads);
                n_loads += 1;
            } else {
                load_ord.push(usize::MAX);
            }
        }
    }
    let mut lsq_occ: usize = 0;

    // Dispatch cursor in slot units.
    let mut disp_iter: u64 = 0;
    let mut disp_slot: usize = 0; // 0..empty_slots+slot_ranges.len()
    let total_slots = empty_slots + slot_ranges.len();

    // Retire cursor.
    let mut ret_iter: u64 = 0;
    let mut ret_slot: usize = 0;
    let mut retired_iters: u64 = 0;

    // Measurement window.
    let mut window_start_cycle: Option<u64> = None;
    let mut window_start_counters = Counters::default();
    let mut window_start_ports: Vec<u64> = vec![0; n_ports];

    let mut cycle: u64 = 0;
    let max_cycles: u64 = 1_000_000_000; // hard safety stop

    while retired_iters < total_iters && cycle < max_cycles {
        // ---------------- retire ------------------------------------
        let mut retired_slots = 0;
        while retired_slots < retire_width && ret_iter < total_iters {
            if ret_slot < empty_slots {
                // Eliminated slot: retires for free once reached.
                ret_slot += 1;
                retired_slots += 1;
                continue;
            }
            let (s, e) = slot_ranges[ret_slot - empty_slots];
            // Invariant: retirement is gid-indexed — slots pop from the
            // ROB front exactly once, in order, so the slot's first
            // µ-op is always the current head. (An older revision
            // silently advanced `ret_slot` when this was violated,
            // corrupting results.) Checked in release builds too: a
            // done-ring collision here would silently skew every
            // Measurement field, and the check is one multiply-add and
            // compare per retired slot — far off the hot path.
            assert_eq!(
                ret_iter * uops_per_iter + s as u64,
                rob_head_gid,
                "retire cursor desynced from ROB head"
            );
            let all_done = (s..e).all(|t| {
                let gid = ret_iter * uops_per_iter + t as u64;
                matches!(
                    done_at(&done, ring_mask, rob_head_gid, next_gid, gid),
                    Some(c) if c <= cycle
                )
            });
            if !all_done {
                break;
            }
            // Pop the slot's µ-ops from the ROB front.
            for _ in s..e {
                let fin = rob.pop_front();
                if plan.is_some() {
                    if let Some(f) = fin {
                        if lsq_uop[f.tidx] {
                            lsq_occ -= 1;
                        }
                    }
                }
                rob_head_gid += 1;
            }
            ret_slot += 1;
            retired_slots += 1;
            if ret_slot == total_slots {
                ret_slot = 0;
                ret_iter += 1;
                retired_iters += 1;
                if retired_iters == cfg.warmup as u64 {
                    window_start_cycle = Some(cycle);
                    window_start_counters = counters.clone();
                    window_start_ports = port_busy.clone();
                }
            }
        }

        // ---------------- issue / execute ---------------------------
        let mut issued_any = false;
        // Oldest-first over the scheduler contents. `waiting` holds the
        // global ids of un-issued µ-ops in dispatch (= age) order, so
        // the scan is O(scheduler occupancy), not O(ROB).
        waiting.retain_mut(|(gid, wake)| {
            if *wake > cycle {
                return true; // sleeping on a known future event
            }
            let gid = *gid;
            let i = (gid - rob_head_gid) as usize;
            debug_assert_eq!(done[(gid as usize) & ring_mask], NOT_DONE);
            let tu = &template.uops[rob[i].tidx];
            // Dependencies ready?
            let iter = rob[i].iter;
            let mut ready = true;
            for d in &tu.deps {
                let dep_gid = match d {
                    DepSource::Intra(w) => iter * uops_per_iter + *w as u64,
                    DepSource::Carried(w) => {
                        if iter == 0 {
                            continue;
                        }
                        (iter - 1) * uops_per_iter + *w as u64
                    }
                    DepSource::Invariant => continue,
                };
                match done_at(&done, ring_mask, rob_head_gid, next_gid, dep_gid) {
                    Some(c) if c <= cycle => {}
                    Some(c) => {
                        // Dep issued; completion cycle is known — sleep.
                        *wake = c;
                        ready = false;
                        break;
                    }
                    None => {
                        ready = false;
                        break;
                    }
                }
            }
            if !ready {
                return true; // stay in the scheduler
            }
            // Forwarding store must have produced its data.
            let mut fwd_done: Option<u64> = None;
            if let Some(sid) = rob[i].fwd_store {
                match store_done
                    .get(&sid)
                    .copied()
                    .or_else(|| done_at(&done, ring_mask, rob_head_gid, next_gid, sid))
                {
                    Some(c) if c <= cycle => fwd_done = Some(c),
                    Some(c) => {
                        *wake = c;
                        return true;
                    }
                    None => return true, // store not yet issued
                }
            }
            // Port available? occupancy 0 → no port needed.
            let done_cycle = if tu.occupancy == 0 {
                cycle + tu.latency.max(1) as u64
            } else {
                // Spread symmetric choices: rotate the starting port.
                // (Bitmask walk — no allocation on this path.)
                let mut chosen: Option<usize> = None;
                let nports = tu.ports.count() as usize;
                let off = (gid as usize) % nports;
                let mut seen = 0usize;
                let mut wrapped: Option<usize> = None;
                for p in 0..16usize {
                    if !tu.ports.contains(p) {
                        continue;
                    }
                    if port_free_at[p] <= cycle {
                        if seen >= off {
                            chosen = Some(p);
                            break;
                        } else if wrapped.is_none() {
                            wrapped = Some(p);
                        }
                    }
                    seen += 1;
                }
                let chosen = chosen.or(wrapped);
                let Some(p) = chosen else { return true };
                port_free_at[p] = cycle + tu.occupancy as u64;
                port_busy[p] += tu.occupancy as u64;
                let mut dc = cycle + tu.latency.max(1) as u64;
                if tu.kind == UopKind::Load {
                    let mut base = cycle + load_lat;
                    // Memory model: a load that opens a new cacheline at
                    // the resident level pays the level's extra latency.
                    // Forwarded loads read the store buffer, not the
                    // hierarchy, so they never miss.
                    if fwd_done.is_none() {
                        if let Some(p) = plan {
                            if p.load_misses(load_ord[rob[i].tidx], iter as usize) {
                                base += p.miss_latency_cy as u64;
                                counters.cache_miss_loads += 1;
                            }
                        }
                    }
                    dc = match fwd_done {
                        Some(sc) => base.max(sc + fwd_lat),
                        None => base,
                    };
                }
                dc
            };
            done[(gid as usize) & ring_mask] = done_cycle;
            sched_occupancy -= 1;
            counters.uops_executed += 1;
            issued_any = true;
            if tu.kind == UopKind::StoreData {
                store_done.insert(gid, done_cycle);
            }
            false // issued: leave the scheduler
        });
        if !issued_any && !rob.is_empty() {
            counters.issue_stall_cycles += 1;
        }

        // ---------------- dispatch / rename --------------------------
        let mut dispatched = 0;
        let mut dispatch_blocked = false;
        let mut lsq_blocked = false;
        while dispatched < rename_width && disp_iter < total_iters {
            if disp_slot < empty_slots {
                disp_slot += 1;
                dispatched += 1;
                continue;
            }
            let (s, e) = slot_ranges[disp_slot - empty_slots];
            let n_new = e - s;
            let n_lsq = if plan.is_some() {
                (s..e).filter(|&t| lsq_uop[t]).count()
            } else {
                0
            };
            if rob.len() + n_new > rob_size || sched_occupancy + n_new > sched_size {
                counters.dispatch_stall_cycles += 1;
                dispatch_blocked = true;
                break;
            }
            if lsq_occ + n_lsq > lsq_size {
                // ROB and scheduler have room but the LSQ is full: a
                // stall the infinite-L1 model cannot produce.
                counters.dispatch_stall_cycles += 1;
                counters.lsq_stall_cycles += 1;
                dispatch_blocked = true;
                lsq_blocked = true;
                break;
            }
            for t in s..e {
                let tu = &template.uops[t];
                let mut fwd_store = None;
                if tu.kind == UopKind::Load {
                    if let Some(ident) = &tu.mem_ident {
                        let key = instantiate(ident, disp_iter, uops_per_iter);
                        if let Some(&sid) = last_store.get(&key) {
                            fwd_store = Some(sid);
                            counters.forwarded_loads += 1;
                        }
                    }
                } else if tu.kind == UopKind::StoreData {
                    if let Some(ident) = &tu.mem_ident {
                        let key = instantiate(ident, disp_iter, uops_per_iter);
                        last_store.insert(key, next_gid);
                    }
                }
                rob.push_back(InFlight { tidx: t, iter: disp_iter, fwd_store });
                done[(next_gid as usize) & ring_mask] = NOT_DONE;
                waiting.push((next_gid, 0));
                next_gid += 1;
                sched_occupancy += 1;
            }
            lsq_occ += n_lsq;
            counters.uops_dispatched += n_new as u64;
            disp_slot += 1;
            dispatched += 1;
            if disp_slot == total_slots {
                disp_slot = 0;
                disp_iter += 1;
                // Trim the store bookkeeping occasionally.
                if disp_iter % 64 == 0 && store_done.len() > 1024 {
                    let min_keep = rob_head_gid.saturating_sub(uops_per_iter * 8);
                    store_done.retain(|gid, _| *gid >= min_keep);
                    last_store.retain(|_, gid| *gid >= min_keep);
                }
            }
        }

        // ---------------- clock / event skip ------------------------
        // When the cycle retired nothing, issued nothing and dispatched
        // nothing, the machine is frozen except for the clock: retire
        // waits on completion cycles ≥ the next event, every scheduler
        // entry waits on an unissued dep, a completion, a forwarding
        // store or a busy port, and dispatch is capacity-blocked (or
        // drained). Jump just before the earliest such event; the
        // per-cycle stall counters are the only observable effect of
        // the skipped span, and they accrue exactly as the strict loop
        // would have — so retire/dispatch ordering and all Measurement
        // fields stay bit-identical.
        if retired_slots == 0 && !issued_any && dispatched == 0 {
            let mut next_event = u64::MAX;
            for &(_, wake) in &waiting {
                if wake > cycle && wake < next_event {
                    next_event = wake;
                }
            }
            for gid in rob_head_gid..next_gid {
                let d = done[(gid as usize) & ring_mask];
                if d != NOT_DONE && d > cycle && d < next_event {
                    next_event = d;
                }
            }
            for &free in &port_free_at {
                if free > cycle && free < next_event {
                    next_event = free;
                }
            }
            let target = next_event.min(max_cycles);
            if target > cycle + 1 {
                let skipped = target - cycle - 1;
                if !rob.is_empty() {
                    counters.issue_stall_cycles += skipped;
                }
                if dispatch_blocked {
                    counters.dispatch_stall_cycles += skipped;
                }
                if lsq_blocked {
                    counters.lsq_stall_cycles += skipped;
                }
                cycle = target - 1;
            }
        }

        cycle += 1;
    }

    let wstart = window_start_cycle.unwrap_or(0);
    let window_cycles = cycle.saturating_sub(wstart).max(1);
    let measured_iters = cfg.iterations.max(1);
    let mut wcounters = counters.clone();
    wcounters.subtract(&window_start_counters);
    let wports: Vec<u64> = port_busy
        .iter()
        .zip(window_start_ports.iter())
        .map(|(a, b)| a - b)
        .collect();
    Measurement {
        cycles_per_iteration: window_cycles as f64 / measured_iters as f64,
        iterations: measured_iters,
        total_cycles: cycle,
        counters: wcounters,
        port_busy: wports,
        window_cycles,
        slots_per_iteration: total_slots,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::extract_kernel;
    use crate::mdb::{skylake, zen};

    fn measure(src: &str, m: &MachineModel) -> Measurement {
        let k = extract_kernel("t", src).unwrap();
        simulate(&k, m, SimConfig { iterations: 500, warmup: 100 }).unwrap()
    }

    #[test]
    fn single_add_chain_is_latency_bound() {
        // One loop-carried vaddpd chain: 4 cy/iter on SKL, 3 on Zen.
        let src = "\n.L1:\nvaddpd %xmm1, %xmm0, %xmm0\ncmpl $1, %eax\njne .L1\n";
        let skl = measure(src, &skylake());
        assert!((skl.cycles_per_iteration - 4.0).abs() < 0.2, "{}", skl.cycles_per_iteration);
        let zen_m = measure(src, &zen());
        assert!((zen_m.cycles_per_iteration - 3.0).abs() < 0.2, "{}", zen_m.cycles_per_iteration);
    }

    #[test]
    fn independent_adds_are_throughput_bound() {
        // Twelve parallel chains on 2 ports: port bound 6 cy/iter beats
        // the 4 cy chain latency — the §II-A TP benchmark shape.
        let body: String = (0..12)
            .map(|i| format!("vaddpd %xmm{}, %xmm{}, %xmm{}\n", 12 + i % 3, i, i))
            .collect();
        let src = format!("\n.L1:\n{body}cmpl $1, %eax\njne .L1\n");
        let m = measure(&src, &skylake());
        assert!((m.cycles_per_iteration - 6.0).abs() < 0.4, "{}", m.cycles_per_iteration);
    }

    #[test]
    fn three_chains_are_latency_bound() {
        // Only three chains: the 4-cycle dependency chain dominates the
        // 1.5-cycle port bound.
        let src = "\n.L1:\nvaddpd %xmm3, %xmm0, %xmm0\nvaddpd %xmm4, %xmm1, %xmm1\nvaddpd %xmm5, %xmm2, %xmm2\ncmpl $1, %eax\njne .L1\n";
        let m = measure(src, &skylake());
        assert!((m.cycles_per_iteration - 4.0).abs() < 0.3, "{}", m.cycles_per_iteration);
    }

    #[test]
    fn divider_pipe_gates_throughput() {
        // Independent divides: DV occupancy 4 -> 4 cy/iter on SKL.
        let src = "\n.L1:\nvdivsd %xmm1, %xmm2, %xmm0\ncmpl $1, %eax\njne .L1\n";
        let m = measure(src, &skylake());
        assert!((m.cycles_per_iteration - 4.0).abs() < 0.3, "{}", m.cycles_per_iteration);
        // Zen: scaled divider (5 cy).
        let mz = measure(src, &zen());
        assert!((mz.cycles_per_iteration - 5.0).abs() < 0.3, "{}", mz.cycles_per_iteration);
    }

    #[test]
    fn store_forward_chain_matches_o1_anomaly() {
        // The §III-B pattern: sum updated through the stack.
        let src = "\n.L2:\nvaddsd (%rsp), %xmm0, %xmm5\nvmovsd %xmm5, (%rsp)\naddl $1, %eax\ncmpl $100, %eax\njne .L2\n";
        let m = measure(src, &skylake());
        // fwd(4) + addsd(4) + store(1) = 9 cy/iter.
        assert!((m.cycles_per_iteration - 9.0).abs() < 0.5, "{}", m.cycles_per_iteration);
        assert!(m.counters.forwarded_loads > 0);
    }

    #[test]
    fn unrelated_store_does_not_forward() {
        let src = "\n.L2:\nvaddsd (%rsp), %xmm0, %xmm5\nvmovsd %xmm5, 8(%rsp)\naddl $1, %eax\ncmpl $100, %eax\njne .L2\n";
        let m = measure(src, &skylake());
        assert!(m.cycles_per_iteration < 2.5, "{}", m.cycles_per_iteration);
    }

    #[test]
    fn load_bound_triad_hits_two_cycles() {
        // Triad -O2-style scalar: 3 loads + 1 store on 2 AGU-capable
        // ports -> 2 cy/iter on SKL.
        let src = "\n.L3:\nvmovsd (%rcx,%rax,8), %xmm0\nvmulsd (%rdx,%rax,8), %xmm0, %xmm0\nvaddsd (%rsi,%rax,8), %xmm0, %xmm0\nvmovsd %xmm0, (%rdi,%rax,8)\naddq $1, %rax\ncmpq %rbp, %rax\njne .L3\n";
        let m = measure(src, &skylake());
        assert!((m.cycles_per_iteration - 2.0).abs() < 0.3, "{}", m.cycles_per_iteration);
    }

    #[test]
    fn stall_counter_high_for_dependency_chain() {
        let chain = "\n.L2:\nvaddsd (%rsp), %xmm0, %xmm5\nvmovsd %xmm5, (%rsp)\naddl $1, %eax\ncmpl $100, %eax\njne .L2\n";
        let tp_body: String = (0..12)
            .map(|i| format!("vaddpd %xmm{}, %xmm{}, %xmm{}\n", 12 + i % 3, i, i))
            .collect();
        let tp = format!("\n.L2:\n{tp_body}addl $1, %eax\ncmpl $100, %eax\njne .L2\n");
        let a = measure(chain, &skylake());
        let b = measure(&tp, &skylake());
        let ra = a.counters.issue_stall_cycles as f64 / a.window_cycles as f64;
        let rb = b.counters.issue_stall_cycles as f64 / b.window_cycles as f64;
        assert!(ra > 4.0 * rb.max(0.01), "stall ratios {ra} vs {rb}");
    }

    #[test]
    fn bottleneck_resource_names_port_or_frontend() {
        // Divider-serialized: the DV pseudo-pipe saturates the period.
        let skl = skylake();
        let src = "\n.L1:\nvdivsd %xmm1, %xmm2, %xmm0\ncmpl $1, %eax\njne .L1\n";
        let m = measure(src, &skl);
        assert_eq!(m.bottleneck_resource(&skl), "0DV");
        // Latency-bound chain: no port saturates and the rename-slot
        // bound (4 slots / 4-wide = 1 cy) is far under the 9 cy period.
        let src = "\n.L2:\nvaddsd (%rsp), %xmm0, %xmm5\nvmovsd %xmm5, (%rsp)\naddl $1, %eax\ncmpl $100, %eax\njne .L2\n";
        let m = measure(src, &skl);
        assert_eq!(m.bottleneck_resource(&skl), "dependency chain");
    }

    #[test]
    fn run_and_run_decoded_agree() {
        // The compat shim (per-call slot structure) and the prebuilt
        // DecodedKernel path must produce identical measurements.
        let src = "\n.L1:\nvdivsd %xmm1, %xmm2, %xmm0\nvaddpd %xmm3, %xmm4, %xmm4\ncmpl $1, %eax\njne .L1\n";
        let k = extract_kernel("t", src).unwrap();
        let m = skylake();
        let cfg = SimConfig { iterations: 200, warmup: 40 };
        let template = super::super::decode::decode_kernel(&k, &m).unwrap();
        let a = run(&template, &m, cfg);
        let dk = DecodedKernel::from_iter(template);
        let b = run_decoded(&dk, &m, cfg);
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.window_cycles, b.window_cycles);
        assert_eq!(a.counters, b.counters);
        assert_eq!(a.port_busy, b.port_busy);
    }
}
