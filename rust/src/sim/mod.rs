//! Out-of-order core simulator — the measurement substrate.
//!
//! Stands in for the paper's Skylake i7-6700HQ and Zen EPYC 7451 test
//! machines (see DESIGN.md §2 for the substitution rationale). It is a
//! cycle-level port-model simulator, not an RTL model: fetch/rename →
//! dispatch → port scheduling → execute → retire, with
//!
//! * register renaming with zero-idiom elimination and move elimination,
//! * cmp/test + jcc macro-fusion,
//! * per-port pipelined execution, non-pipelined divider pipes,
//! * dependency-carrying memory (store-to-load forwarding with latency —
//!   the mechanism behind the paper's §III-B `-O1` anomaly),
//! * finite ROB / scheduler, bounded rename and retire width,
//! * an **opt-in** parametric memory hierarchy + load/store queue
//!   (`sim::mem`) that lifts the paper's infinite-L1 assumption: load
//!   completion latency then depends on the kernel's working-set
//!   footprint, and Load/StoreAgu µ-ops compete for finite LSQ entries,
//! * event counters mirroring the hardware events the paper quotes
//!   (`UOPS_EXECUTED_STALL_CYCLES` etc.).
//!
//! The same machine files drive both this simulator ("the hardware") and
//! the analyzer ("the model"); deliberate differences — what real silicon
//! does that the analytic model does not know — are marked `sim_*` in the
//! machine file.

pub mod core;
pub mod decode;
pub mod mem;
pub mod trace;

pub use core::{
    frontend_resource_label, run_decoded, run_decoded_mem, simulate, Measurement, SimConfig,
};
pub use decode::{decode_kernel, DecodedIter, DecodedKernel, SimUop};
pub use mem::{analyze_memory, derive_footprint, Footprint, MemModel, MemSimPlan, MemoryAnalysis};
