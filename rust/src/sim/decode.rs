//! Decode a kernel into simulator µ-ops with dependency wiring.
//!
//! One `DecodedIter` describes one assembly iteration of the loop body;
//! the core replays it N times, renaming registers and memory versions
//! per iteration.

use std::sync::Arc;

use anyhow::Result;

use crate::asm::Kernel;
use crate::isa::register::RegisterFile;
use crate::mdb::{MachineModel, PortMask, UopKind};

/// A dependency source, relative to the decoded iteration template.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DepSource {
    /// µ-op `idx` of the same iteration.
    Intra(usize),
    /// µ-op `idx` of the previous iteration (loop-carried).
    Carried(usize),
    /// Value produced before the loop (loop-invariant) — always ready.
    Invariant,
}

/// One µ-op template.
#[derive(Debug, Clone)]
pub struct SimUop {
    /// Index of the source instruction within the kernel.
    pub instr: usize,
    pub kind: UopKind,
    pub ports: PortMask,
    /// Cycles the chosen port stays busy (divider scaled by
    /// `sim_divider_scale`). 0 for store-data µ-ops under
    /// `store_data_free` (they still occupy a ROB slot).
    pub occupancy: u32,
    /// Completion latency once issued (result available `latency` cycles
    /// after issue).
    pub latency: u32,
    /// Dependencies that must complete before issue.
    pub deps: Vec<DepSource>,
    /// Memory-address identity for store-to-load forwarding: two memory
    /// µ-ops alias iff their identities match in the same renaming
    /// generation. `None` for non-memory µ-ops.
    pub mem_ident: Option<MemIdent>,
    /// True when this µ-op starts a new fused rename slot (micro-fusion:
    /// load+compute and store-data+AGU pairs share a slot).
    pub new_slot: bool,
}

/// Symbolic memory identity: (address-register versions, disp, scale).
/// Versions are expressed as dependency sources so the identity is only
/// equal when the address registers hold the *same* value generation —
/// `(%rsp)` matches across iterations, `(%rcx,%rax,8)` does not once
/// `%rax` is updated in the loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemIdent {
    pub base: Option<(RegisterFile, DepVersion)>,
    pub index: Option<(RegisterFile, DepVersion)>,
    pub scale: u8,
    pub displacement: i64,
    pub symbol: Option<String>,
}

/// Version of an address register relative to the iteration template.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DepVersion {
    /// Never written inside the loop: same value every iteration.
    Invariant,
    /// Defined by µ-op `idx` of the current iteration.
    Iter(usize),
    /// Defined by µ-op `idx` of the *previous* iteration (address read
    /// before the in-loop update, e.g. `(%rdi,%rax)` before `addq`).
    CarriedIter(usize),
}

/// A fully decoded loop iteration.
#[derive(Debug, Clone)]
pub struct DecodedIter {
    pub uops: Vec<SimUop>,
    /// Fused rename slots per iteration (frontend bandwidth unit).
    pub slots: usize,
    /// Instructions eliminated at rename (zero idioms, moves, fused
    /// branches) — they consume no scheduler entry.
    pub eliminated: usize,
}

/// A reusable decode artifact: the µ-op template of one iteration plus
/// the slot structure the core loop consumes, built **once** per
/// (kernel, machine) and shared across `simulate` calls and iteration
/// counts. Cloning is cheap (the template is behind `Arc`), so a
/// `DecodedKernel` can be handed to several simulation runs — the
/// results are bit-identical to decoding fresh every time
/// (`tests/perf_caches.rs` asserts this).
#[derive(Debug, Clone)]
pub struct DecodedKernel {
    /// The decoded iteration template (µ-ops, dep edges).
    pub iter: Arc<DecodedIter>,
    /// Ranges of template µ-ops sharing one fused rename slot
    /// (micro-fusion: load+compute, store-data+AGU).
    pub slot_ranges: Vec<(usize, usize)>,
    /// Slots eliminated at rename: they consume dispatch and retire
    /// bandwidth but never enter the ROB.
    pub empty_slots: usize,
}

impl DecodedKernel {
    /// Decode `kernel` against `machine` and precompute the slot
    /// structure.
    pub fn new(kernel: &Kernel, machine: &MachineModel) -> Result<Self> {
        Ok(Self::from_iter(decode_kernel(kernel, machine)?))
    }

    /// Wrap an already-decoded iteration template.
    pub fn from_iter(iter: DecodedIter) -> Self {
        let (slot_ranges, empty_slots) = slot_structure(&iter);
        DecodedKernel { iter: Arc::new(iter), slot_ranges, empty_slots }
    }

    /// Total rename/retire slots per iteration.
    pub fn total_slots(&self) -> usize {
        self.empty_slots + self.slot_ranges.len()
    }
}

/// Slot structure for frontend/retire bandwidth: ranges of µ-ops that
/// share a fused rename slot, plus eliminated-but-renamed slots that
/// consume dispatch bandwidth without entering the ROB.
pub(crate) fn slot_structure(iter: &DecodedIter) -> (Vec<(usize, usize)>, usize) {
    let mut slot_ranges: Vec<(usize, usize)> = Vec::new();
    for (i, u) in iter.uops.iter().enumerate() {
        if u.new_slot {
            slot_ranges.push((i, i + 1));
        } else if let Some(last) = slot_ranges.last_mut() {
            last.1 = i + 1;
        }
    }
    let empty_slots = iter.slots.saturating_sub(slot_ranges.len());
    (slot_ranges, empty_slots)
}

/// Decode the kernel against the machine model.
pub fn decode_kernel(kernel: &Kernel, machine: &MachineModel) -> Result<DecodedIter> {
    // Track, per register file, who last wrote it: absent = loop-
    // invariant, Uop(idx) = µ-op of this iteration, Zeroed = reset by an
    // eliminated zeroing idiom (a *known constant*, NOT carried — this
    // is exactly what the compiler-emitted vxorpd before vcvtsi2sd is
    // for). After the first pass, reads-before-first-write become
    // carried deps from the end-of-iteration producer.
    use std::collections::HashMap;
    #[derive(Clone, Copy, PartialEq)]
    enum Writer {
        Uop(usize),
        Zeroed,
    }
    let mut writer: HashMap<RegisterFile, Writer> = HashMap::new();
    // Move-elimination aliases: dest file -> source file chain.
    let mut alias: HashMap<RegisterFile, RegisterFile> = HashMap::new();

    let mut uops: Vec<SimUop> = Vec::new();
    let mut pending_reads: Vec<(usize, RegisterFile)> = Vec::new(); // (uop, file) unresolved at decode time
    let mut slots = 0usize;
    let mut eliminated = 0usize;

    let resolve =
        |alias: &HashMap<RegisterFile, RegisterFile>, mut f: RegisterFile| -> RegisterFile {
            let mut hops = 0;
            while let Some(&next) = alias.get(&f) {
                f = next;
                hops += 1;
                if hops > 16 {
                    break; // cyclic alias chains can't happen, but be safe
                }
            }
            f
        };

    for (i, ins) in kernel.instructions.iter().enumerate() {
        // ---- rename-stage eliminations ------------------------------
        if ins.is_zero_idiom() && machine.sim_zero_idiom_elim {
            // Dest becomes a known zero; no µ-op, no dependency.
            for w in ins.writes() {
                let f = w.file();
                alias.remove(&f);
                writer.insert(f, Writer::Zeroed);
            }
            eliminated += 1;
            slots += 1; // still decoded/renamed
            continue;
        }
        if ins.is_reg_move() && machine.sim_move_elim {
            // Operand order is ISA-dependent: AT&T is source-first,
            // AArch64 and RISC-V destination-first. `is_reg_move`
            // guarantees two register operands.
            let (src_op, dst_op) = match ins.isa {
                crate::isa::Isa::X86 => (&ins.operands[0], &ins.operands[1]),
                crate::isa::Isa::AArch64 | crate::isa::Isa::RiscV => {
                    (&ins.operands[1], &ins.operands[0])
                }
            };
            let src = src_op.reg().map(|r| r.file());
            let dst = dst_op.reg().map(|r| r.file());
            if let (Some(s), Some(d)) = (src, dst) {
                let s = resolve(&alias, s);
                alias.insert(d, s);
                // Dest now tracks source's writer.
                match writer.get(&s).copied() {
                    Some(w) => {
                        writer.insert(d, w);
                    }
                    None => {
                        writer.remove(&d);
                    }
                }
                eliminated += 1;
                slots += 1;
                continue;
            }
        }
        if ins.is_fusible_branch() && machine.sim_macro_fusion {
            // Fused with the preceding flag-setting µ-op: no extra
            // µ-op. On x86 all modeled kernels end in cmp/test+jcc; on
            // AArch64 only `b.<cond>` (and bare `b`) fuse —
            // compare-and-branch forms (cbnz/cbz/tbz/tbnz) carry their
            // own register read and rename slot, so they resolve and
            // execute like any other instruction below.
            eliminated += 1;
            continue;
        }

        let resolved = machine.resolve(ins)?;
        if resolved.entry.uops.is_empty() {
            // Port-free entry (branch without fusion flag).
            eliminated += 1;
            continue;
        }

        // ---- source dependencies ------------------------------------
        let mem = ins.mem_operand();
        let addr_files: Vec<RegisterFile> = mem
            .map(|m| m.address_registers().map(|r| r.file()).collect())
            .unwrap_or_default();
        let data_files: Vec<RegisterFile> = ins
            .reads()
            .into_iter()
            .map(|r| resolve(&alias, r.file()))
            .filter(|f| !addr_files.contains(f))
            .collect();
        let addr_files: Vec<RegisterFile> =
            addr_files.into_iter().map(|f| resolve(&alias, f)).collect();

        let dep_of = |writer: &HashMap<RegisterFile, Writer>,
                      pending: &mut Vec<(usize, RegisterFile)>,
                      uop_idx: usize,
                      f: RegisterFile|
         -> DepSource {
            match writer.get(&f) {
                Some(Writer::Uop(w)) => DepSource::Intra(*w),
                // Zeroed: a known constant, never a dependency.
                Some(Writer::Zeroed) => DepSource::Invariant,
                None => {
                    // Not yet written this iteration: may be loop-carried;
                    // fix up after the full pass.
                    pending.push((uop_idx, f));
                    DepSource::Invariant
                }
            }
        };

        let version_of = |writer: &HashMap<RegisterFile, Writer>, f: RegisterFile| match writer
            .get(&f)
        {
            Some(Writer::Uop(w)) => DepVersion::Iter(*w),
            // Zeroed address registers hold the same value (0) in every
            // iteration — invariant for aliasing purposes.
            Some(Writer::Zeroed) => DepVersion::Invariant,
            None => DepVersion::Invariant,
        };
        let ident = mem.map(|m| MemIdent {
            base: m.base.map(|r| {
                let f = resolve(&alias, r.file());
                (f, version_of(&writer, f))
            }),
            index: m.index.map(|r| {
                let f = resolve(&alias, r.file());
                (f, version_of(&writer, f))
            }),
            scale: m.scale,
            displacement: m.displacement,
            symbol: m.symbol.clone(),
        });

        // ---- emit µ-ops ----------------------------------------------
        // Kind-sort so that intra-instruction dependencies (load feeds
        // compute) always point backwards — index order stays
        // topological, which the critical-path analysis relies on.
        let mut entry_uops = resolved.entry.uops.clone();
        entry_uops.sort_by_key(|u| match u.kind {
            UopKind::Load => 0,
            UopKind::Compute => 1,
            UopKind::Divider => 2,
            UopKind::StoreData => 3,
            UopKind::StoreAgu => 4,
        });
        let first_uop = uops.len();
        let mut load_uop: Option<usize> = None;
        let is_div_scaled = machine.params.sim_divider_scale;
        for u in &entry_uops {
            let idx = uops.len();
            let mut deps: Vec<DepSource> = Vec::new();
            let (occupancy, latency) = match u.kind {
                UopKind::Load => {
                    for &f in &addr_files {
                        let d = dep_of(&writer, &mut pending_reads, idx, f);
                        deps.push(d);
                    }
                    (u.occupancy.round() as u32, machine.params.load_latency)
                }
                UopKind::StoreAgu => {
                    for &f in &addr_files {
                        let d = dep_of(&writer, &mut pending_reads, idx, f);
                        deps.push(d);
                    }
                    (u.occupancy.round() as u32, 1)
                }
                UopKind::StoreData => {
                    for &f in &data_files {
                        let d = dep_of(&writer, &mut pending_reads, idx, f);
                        deps.push(d);
                    }
                    let occ = if machine.sim_store_data_free {
                        0
                    } else {
                        u.occupancy.round() as u32
                    };
                    (occ, 1)
                }
                UopKind::Compute => {
                    for &f in &data_files {
                        let d = dep_of(&writer, &mut pending_reads, idx, f);
                        deps.push(d);
                    }
                    if let Some(l) = load_uop {
                        deps.push(DepSource::Intra(l));
                    }
                    (u.occupancy.round() as u32, resolved.entry.latency.max(1.0).round() as u32)
                }
                UopKind::Divider => {
                    // Divider occupancy gates throughput; it has no data
                    // consumers of its own (the compute µ-op carries the
                    // result). Scaled by the measured-vs-documented factor.
                    ((u.occupancy * is_div_scaled).round() as u32, 1)
                }
            };
            let mem_ident = match u.kind {
                UopKind::Load | UopKind::StoreData => ident.clone(),
                _ => None,
            };
            // Micro-fusion: the first µ-op of an instruction opens a
            // rename slot; load+compute / data+agu pairs share it.
            let new_slot = idx == first_uop;
            if new_slot {
                slots += 1;
            }
            if u.kind == UopKind::Load {
                load_uop = Some(idx);
            }
            uops.push(SimUop {
                instr: i,
                kind: u.kind,
                ports: u.ports,
                occupancy,
                latency,
                deps,
                mem_ident,
                new_slot,
            });
        }

        // ---- register writes -----------------------------------------
        // The result-producing µ-op is the last Compute (or the Load for
        // pure-load instructions).
        let producer = uops[first_uop..]
            .iter()
            .rposition(|u| u.kind == UopKind::Compute)
            .map(|off| first_uop + off)
            .or_else(|| {
                uops[first_uop..]
                    .iter()
                    .rposition(|u| u.kind == UopKind::Load)
                    .map(|off| first_uop + off)
            });
        if let Some(p) = producer {
            for w in ins.writes() {
                let f = w.file();
                alias.remove(&f);
                writer.insert(f, Writer::Uop(p));
            }
        }
    }

    // ---- loop-carried fix-up -----------------------------------------
    // Reads that found no writer yet: if the register IS written later in
    // the iteration (by a real µ-op — zeroing idioms leave a constant),
    // the value comes from the previous iteration.
    for (uop_idx, f) in pending_reads {
        if let Some(Writer::Uop(w)) = writer.get(&f) {
            if let Some(slot) = uops[uop_idx]
                .deps
                .iter_mut()
                .find(|d| **d == DepSource::Invariant)
            {
                *slot = DepSource::Carried(*w);
            }
        }
    }
    // Memory-identity fix-up: an address register read before its in-loop
    // update carries the *previous* iteration's value — without this,
    // `a[i] += x`-style kernels would falsely alias across iterations.
    for u in &mut uops {
        if let Some(ident) = &mut u.mem_ident {
            for comp in [&mut ident.base, &mut ident.index].into_iter().flatten() {
                if comp.1 == DepVersion::Invariant {
                    if let Some(Writer::Uop(w)) = writer.get(&comp.0) {
                        comp.1 = DepVersion::CarriedIter(*w);
                    }
                }
            }
        }
    }

    Ok(DecodedIter { uops, slots, eliminated })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::extract_kernel;
    use crate::mdb::{skylake, zen};

    fn kernel(src: &str) -> Kernel {
        extract_kernel("t", src).unwrap()
    }

    #[test]
    fn triad_skl_uop_count() {
        let k = kernel(
            "\n.L10:\nvmovapd (%r15,%rax), %ymm0\nvmovapd (%r12,%rax), %ymm3\naddl $1, %ecx\nvfmadd132pd 0(%r13,%rax), %ymm3, %ymm0\nvmovapd %ymm0, (%r14,%rax)\naddq $32, %rax\ncmpl %ecx, %r10d\nja .L10\n",
        );
        let d = decode_kernel(&k, &skylake()).unwrap();
        // ld, ld, alu, (c+ld), (st+agu), alu, alu = 9 µ-ops; ja fused.
        assert_eq!(d.uops.len(), 9);
        // Slots: 7 instructions get slots (branch fused into cmp's... the
        // branch is eliminated pre-decode so 7 slots).
        assert_eq!(d.slots, 7);
        assert_eq!(d.eliminated, 1);
    }

    #[test]
    fn loop_carried_dependency_detected() {
        // addq %rax, %rax chains iteration to iteration.
        let k = kernel("\n.L1:\naddq %rax, %rax\ncmpq %rdx, %rax\njne .L1\n");
        let d = decode_kernel(&k, &skylake()).unwrap();
        let add = &d.uops[0];
        assert!(add.deps.iter().any(|d| matches!(d, DepSource::Carried(0))));
    }

    #[test]
    fn zero_idiom_eliminated() {
        let k = kernel("\n.L1:\nvxorpd %xmm0, %xmm0, %xmm0\nvaddsd %xmm0, %xmm1, %xmm1\ncmpq %rdx, %rax\njne .L1\n");
        let d = decode_kernel(&k, &skylake()).unwrap();
        // vxorpd gone; vaddsd must NOT depend on it (invariant zero).
        assert_eq!(d.eliminated, 2); // xor + fused jne
        let add = &d.uops[0];
        assert!(add.deps.iter().all(|dp| !matches!(dp, DepSource::Intra(_))));
    }

    #[test]
    fn store_forward_identity_matches_rsp() {
        // store (%rsp) then load (%rsp): same identity (rsp invariant).
        let k = kernel("\n.L2:\nvaddsd (%rsp), %xmm0, %xmm5\nvmovsd %xmm5, (%rsp)\ncmpl $100, %eax\njne .L2\n");
        let d = decode_kernel(&k, &skylake()).unwrap();
        let load_ident = d.uops.iter().find(|u| u.kind == UopKind::Load).unwrap().mem_ident.clone();
        let store_ident = d.uops.iter().find(|u| u.kind == UopKind::StoreData).unwrap().mem_ident.clone();
        assert_eq!(load_ident, store_ident);
        assert!(load_ident.is_some());
    }

    #[test]
    fn zen_store_data_free() {
        let k = kernel("\n.L1:\nvmovaps %xmm0, (%r12,%rax)\naddq $16, %rax\ncmpl %esi, %ebx\nja .L1\n");
        let d = decode_kernel(&k, &zen()).unwrap();
        let st = d.uops.iter().find(|u| u.kind == UopKind::StoreData).unwrap();
        assert_eq!(st.occupancy, 0);
    }

    #[test]
    fn zen_divider_scaled() {
        let k = kernel("\n.L1:\nvdivsd %xmm0, %xmm1, %xmm2\ncmpl $1, %eax\njne .L1\n");
        let d = decode_kernel(&k, &zen()).unwrap();
        let dv = d.uops.iter().find(|u| u.kind == UopKind::Divider).unwrap();
        assert_eq!(dv.occupancy, 5); // 4 * 1.25
    }

    #[test]
    fn move_elimination_breaks_dependency() {
        let k = kernel("\n.L1:\nvmovapd %ymm1, %ymm0\nvaddpd %ymm0, %ymm2, %ymm2\ncmpq %rdx, %rax\njne .L1\n");
        let d = decode_kernel(&k, &skylake()).unwrap();
        // mov eliminated; vaddpd reads ymm0 -> aliases ymm1 (invariant).
        assert_eq!(d.eliminated, 2);
        assert_eq!(d.uops.len(), 2); // vaddpd + cmp
    }

    #[test]
    fn aarch64_compare_branch_is_not_fused() {
        // cbnz carries its own register read: it must resolve, occupy
        // a rename slot, and depend on the counter update — unlike
        // b.<cond>, which macro-fuses away.
        use crate::asm::extract_kernel_isa;
        use crate::isa::Isa;
        let m = crate::mdb::thunderx2();
        let src = "\n.L4:\nldr q0, [x7, x4]\nadd x4, x4, #16\nsub x5, x5, #2\ncbnz x5, .L4\n";
        let k = extract_kernel_isa("t", src, Isa::AArch64).unwrap();
        let d = decode_kernel(&k, &m).unwrap();
        assert_eq!(d.eliminated, 0);
        assert_eq!(d.uops.len(), 4);
        assert_eq!(d.slots, 4);
        let cbnz = d.uops.last().unwrap();
        assert!(
            cbnz.deps.iter().any(|dp| matches!(dp, DepSource::Intra(2))),
            "{:?}",
            cbnz.deps
        );
    }

    #[test]
    fn aarch64_cross_file_fmov_not_eliminated() {
        // `fmov d0, x1` transfers GP->FP: not move-elimination
        // eligible even with sim_move_elim set.
        use crate::asm::parser::parse_instruction_isa;
        use crate::isa::Isa;
        let i = parse_instruction_isa("fmov d0, x1", 1, Isa::AArch64).unwrap();
        assert!(!i.is_reg_move());
        let i = parse_instruction_isa("fmov d0, d1", 1, Isa::AArch64).unwrap();
        assert!(i.is_reg_move());
        let i = parse_instruction_isa("mov x0, x1", 1, Isa::AArch64).unwrap();
        assert!(i.is_reg_move());
    }

    #[test]
    fn aarch64_move_elim_aliases_dest_to_source() {
        // AArch64 moves are destination-FIRST; the alias must map the
        // dest to the source's writer, not the AT&T-order reverse.
        use crate::asm::extract_kernel_isa;
        use crate::isa::Isa;
        let mut m = crate::mdb::thunderx2();
        m.sim_move_elim = true;
        let src = "\n.L1:\nadd x1, x1, #1\nmov x0, x1\nadd x2, x0, #1\nsubs x5, x5, #1\nb.ne .L1\n";
        let k = extract_kernel_isa("t", src, Isa::AArch64).unwrap();
        let d = decode_kernel(&k, &m).unwrap();
        assert_eq!(d.eliminated, 2); // mov + fused b.ne
        assert_eq!(d.uops.len(), 3);
        // `add x2, x0, #1` reads x0 -> alias -> x1, written by uop 0.
        let add2 = &d.uops[1];
        assert!(
            add2.deps.iter().any(|dp| matches!(dp, DepSource::Intra(0))),
            "{:?}",
            add2.deps
        );
    }
}
