//! Event counters mirroring the hardware events used in the paper
//! (§III-B quotes `UOPS_EXECUTED_STALL_CYCLES` on Skylake and
//! `DYN_TOKENS_DISP_STALL_CYCLES_*` on Zen).

/// Simulator event counters, accumulated over the run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Counters {
    /// Cycles in which no µ-op issued although work was in flight —
    /// the analog of `UOPS_EXECUTED_STALL_CYCLES`.
    pub issue_stall_cycles: u64,
    /// Cycles in which rename/dispatch was blocked on ROB/scheduler
    /// capacity — the analog of Zen's token-stall events.
    pub dispatch_stall_cycles: u64,
    pub uops_executed: u64,
    pub uops_dispatched: u64,
    /// Loads that hit store-to-load forwarding.
    pub forwarded_loads: u64,
    /// Cycles rename/dispatch was blocked specifically on a full
    /// load/store queue (only under the opt-in memory model; zero in
    /// infinite-L1 mode).
    pub lsq_stall_cycles: u64,
    /// Loads that opened a new cacheline at the resident hierarchy
    /// level and paid its latency (opt-in memory model only).
    pub cache_miss_loads: u64,
}

impl Counters {
    /// Subtract a snapshot (for windowed measurement).
    pub fn subtract(&mut self, start: &Counters) {
        self.issue_stall_cycles -= start.issue_stall_cycles;
        self.dispatch_stall_cycles -= start.dispatch_stall_cycles;
        self.uops_executed -= start.uops_executed;
        self.uops_dispatched -= start.uops_dispatched;
        self.forwarded_loads -= start.forwarded_loads;
        self.lsq_stall_cycles -= start.lsq_stall_cycles;
        self.cache_miss_loads -= start.cache_miss_loads;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subtract_window() {
        let mut c = Counters {
            issue_stall_cycles: 10,
            dispatch_stall_cycles: 4,
            uops_executed: 100,
            uops_dispatched: 110,
            forwarded_loads: 7,
            lsq_stall_cycles: 6,
            cache_miss_loads: 9,
        };
        let start = Counters {
            issue_stall_cycles: 3,
            dispatch_stall_cycles: 1,
            uops_executed: 40,
            uops_dispatched: 45,
            forwarded_loads: 2,
            lsq_stall_cycles: 2,
            cache_miss_loads: 4,
        };
        c.subtract(&start);
        assert_eq!(c.issue_stall_cycles, 7);
        assert_eq!(c.uops_executed, 60);
        assert_eq!(c.forwarded_loads, 5);
        assert_eq!(c.lsq_stall_cycles, 4);
        assert_eq!(c.cache_miss_loads, 5);
    }
}
