//! Parametric memory hierarchy + working-set footprint analysis.
//!
//! The paper's in-core model assumes an infinite L1: every load completes in
//! `load_latency` cycles regardless of the kernel's data footprint. This
//! module lifts that assumption behind an **opt-in** memory model
//! (`AnalysisRequest::mem_model`). Nothing here runs unless a spec string is
//! supplied, which keeps every paper-pinned table bit-identical.
//!
//! Three pieces compose:
//!
//! 1. [`MemModel`] — the hierarchy parameters. Seeded from the machine
//!    model's `cache` stanzas (`mdb::machine::CacheLevel`), then overridden
//!    by a CLI-style spec string such as
//!    `l1=32K:4,l2=1M:12,mem=:80,ws=4M,lsq=72,lfb=8`.
//! 2. [`Footprint`] — a static sweep over the kernel's memory references.
//!    Streams are grouped by (base, index, scale, symbol); each stream's
//!    advance per assembly iteration is recovered from the pointer-bump
//!    instructions (`add`/`sub` with one immediate operand writing the
//!    address register). Working set = bytes/iter × iterations unless the
//!    spec pins `ws=`.
//! 3. [`MemoryAnalysis`] — the ECM-style throughput bound: the working set
//!    is assigned to the first level that holds it, and the cycles per
//!    cacheline to move data that deep is the cumulative sum of inter-level
//!    latency deltas divided by the line-fill-buffer count (overlap factor).
//!
//! [`MemSimPlan`] carries the per-load miss periods + level latency into the
//! OoO simulator so `run_decoded_mem` can charge realistic load completion
//! times and model a finite load/store queue.

use crate::asm::kernel::Kernel;
use crate::isa::instruction::Instruction;
use crate::isa::operand::{MemRef, Operand, Register};
use crate::mdb::format::{fmt_size, parse_size};
use crate::mdb::machine::{CacheLevel, MachineModel};
use crate::mdb::UopKind;
use crate::sim::decode::DecodedIter;
use anyhow::{bail, Context, Result};

/// Fully-resolved memory hierarchy parameters for one analysis.
#[derive(Debug, Clone, PartialEq)]
pub struct MemModel {
    /// Cache levels ordered nearest-first (l1, l2, ...). Never empty.
    pub levels: Vec<CacheLevel>,
    /// Flat latency of a line fill that misses every cache level.
    pub mem_latency_cy: u32,
    /// Load/store queue entries available to the simulator.
    pub lsq_size: usize,
    /// Concurrent line fills (line-fill buffers); the ECM overlap divisor.
    pub lfb: u32,
    /// `ws=` spec override: pin the working set instead of deriving it.
    pub ws_override: Option<u64>,
}

impl MemModel {
    /// Build a model from the machine's `cache` stanzas plus a spec string.
    ///
    /// Grammar: comma-separated entries. `l<N>=SIZE:LAT` overrides or creates
    /// a level (empty SIZE keeps the existing size); `mem=:LAT` sets the
    /// miss-everything latency; `ws=SIZE`, `lsq=N`, `lfb=N` set scalars.
    /// The bare spec (`""`, `on`, `default`, `true`) takes model defaults.
    pub fn build(machine: &MachineModel, spec: &str) -> Result<MemModel> {
        let mut levels = machine.caches.clone();
        let mut mem_latency_cy = machine.mem_latency_cy;
        let mut lsq_size = machine.params.lsq_size;
        let mut lfb = machine.params.lfb;
        let mut ws_override = None;

        let spec = spec.trim();
        if !matches!(spec, "" | "on" | "default" | "true") {
            for entry in spec.split(',') {
                let entry = entry.trim();
                if entry.is_empty() {
                    continue;
                }
                let (key, value) = entry
                    .split_once('=')
                    .with_context(|| format!("mem-model entry `{entry}`: expected key=value"))?;
                match key {
                    "ws" => {
                        ws_override = Some(
                            parse_size(value)
                                .with_context(|| format!("mem-model ws `{value}`"))?,
                        );
                    }
                    "lsq" => {
                        lsq_size = value
                            .parse()
                            .with_context(|| format!("mem-model lsq `{value}`"))?;
                    }
                    "lfb" => {
                        lfb = value
                            .parse()
                            .with_context(|| format!("mem-model lfb `{value}`"))?;
                    }
                    "mem" => {
                        let lat = value.strip_prefix(':').unwrap_or(value);
                        mem_latency_cy = lat
                            .parse()
                            .with_context(|| format!("mem-model mem latency `{value}`"))?;
                    }
                    name => {
                        let (size, lat) = value.split_once(':').with_context(|| {
                            format!("mem-model level `{entry}`: expected {name}=SIZE:LAT")
                        })?;
                        let latency_cy: u32 = lat
                            .parse()
                            .with_context(|| format!("mem-model `{name}` latency `{lat}`"))?;
                        if let Some(level) = levels.iter_mut().find(|l| l.name == name) {
                            if !size.is_empty() {
                                level.size_bytes = parse_size(size)
                                    .with_context(|| format!("mem-model `{name}` size"))?;
                            }
                            level.latency_cy = latency_cy;
                        } else {
                            if size.is_empty() {
                                bail!("mem-model `{name}`: new level needs an explicit size");
                            }
                            levels.push(CacheLevel {
                                name: name.to_string(),
                                size_bytes: parse_size(size)
                                    .with_context(|| format!("mem-model `{name}` size"))?,
                                line_bytes: 64,
                                latency_cy,
                                assoc: 8,
                            });
                        }
                    }
                }
            }
        }

        levels.sort_by_key(|l| l.size_bytes);
        if levels.is_empty() {
            bail!(
                "mem-model: machine `{}` declares no cache levels and the spec adds none",
                machine.arch_name
            );
        }
        if mem_latency_cy == 0 {
            bail!("mem-model: memory latency is unset (add `mem=:LAT` or a `cache mem` stanza)");
        }
        if lfb == 0 {
            bail!("mem-model: lfb must be >= 1");
        }
        if lsq_size == 0 {
            bail!("mem-model: lsq must be >= 1");
        }
        let mut prev = 0u32;
        for l in &levels {
            if l.latency_cy < prev {
                bail!("mem-model: level `{}` latency {} below inner level's {prev}", l.name, l.latency_cy);
            }
            prev = l.latency_cy;
        }
        if mem_latency_cy < prev {
            bail!("mem-model: memory latency {mem_latency_cy} below outermost cache's {prev}");
        }

        Ok(MemModel { levels, mem_latency_cy, lsq_size, lfb, ws_override })
    }

    /// Line size used for footprint math (the innermost level's).
    pub fn line_bytes(&self) -> u32 {
        self.levels[0].line_bytes
    }
}

/// One contiguous access stream: a distinct (base, index, scale, symbol)
/// address expression, with the bytes it advances per assembly iteration.
#[derive(Debug, Clone, PartialEq)]
pub struct Stream {
    pub base: Option<Register>,
    pub index: Option<Register>,
    pub scale: u8,
    pub symbol: Option<String>,
    /// Bytes the address moves per assembly (unrolled) iteration.
    pub advance: u64,
}

/// Static working-set summary of a kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct Footprint {
    pub streams: Vec<Stream>,
    /// Total bytes of new data touched per assembly iteration.
    pub bytes_per_iter: u64,
    /// `bytes_per_iter / line`, as a float (streams can share lines).
    pub lines_per_iter: f32,
    /// For each Load uop in the decoded iteration (in uop order): the miss
    /// period — a new line every `P` iterations (0 = address never moves).
    pub load_periods: Vec<u32>,
}

/// Per-iteration advance of `reg`: scan for pointer-bump instructions
/// (`add*`/`sub*` mnemonics with exactly one immediate operand) that write
/// the register, and sum their |immediate|s.
fn register_advance(kernel: &Kernel, reg: Register) -> u64 {
    let mut adv = 0u64;
    for instr in &kernel.instructions {
        let m = instr.mnemonic.to_ascii_lowercase();
        if !(m.starts_with("add") || m.starts_with("sub")) {
            continue;
        }
        let imms: Vec<i64> = instr
            .operands
            .iter()
            .filter_map(|o| match o {
                Operand::Imm(v) => Some(*v),
                _ => None,
            })
            .collect();
        if imms.len() != 1 {
            continue;
        }
        if instr.writes().contains(&reg) {
            adv += imms[0].unsigned_abs();
        }
    }
    adv
}

fn stream_key(m: &MemRef) -> (Option<Register>, Option<Register>, u8, Option<String>) {
    (m.base, m.index, m.scale, m.symbol.clone())
}

/// Derive the kernel's access streams and per-load miss periods.
///
/// `iter` supplies the Load uops (one period each, aligned with the decoded
/// uop order the simulator walks); `kernel` supplies the concrete address
/// registers and the pointer-bump instructions that advance them.
pub fn derive_footprint(kernel: &Kernel, iter: &DecodedIter, line_bytes: u32) -> Footprint {
    let mut streams: Vec<Stream> = Vec::new();
    let mut keys: Vec<(Option<Register>, Option<Register>, u8, Option<String>)> = Vec::new();

    let mut note_stream = |m: &MemRef| {
        let key = stream_key(m);
        if keys.contains(&key) {
            return;
        }
        let base_adv = m.base.map_or(0, |r| register_advance(kernel, r));
        let index_adv = m.index.map_or(0, |r| register_advance(kernel, r));
        let advance = base_adv + index_adv * u64::from(m.scale.max(1));
        streams.push(Stream {
            base: m.base,
            index: m.index,
            scale: m.scale,
            symbol: m.symbol.clone(),
            advance,
        });
        keys.push(key);
    };

    for instr in &kernel.instructions {
        for op in &instr.operands {
            if let Operand::Mem(m) = op {
                note_stream(m);
            }
        }
    }

    let bytes_per_iter: u64 = streams.iter().map(|s| s.advance).sum();
    let lines_per_iter = bytes_per_iter as f32 / line_bytes as f32;

    // Map each Load uop back to its kernel instruction's first memref stream
    // and compute the miss period: a fresh line every ceil(line/advance)
    // iterations. Invariant addresses (advance 0) never miss.
    let load_periods = iter
        .uops
        .iter()
        .filter(|u| u.kind == UopKind::Load)
        .map(|u| {
            let adv = kernel
                .instructions
                .get(u.instr)
                .and_then(instr_first_memref)
                .map(|m| {
                    let key = stream_key(m);
                    keys.iter()
                        .position(|k| *k == key)
                        .map_or(0, |i| streams[i].advance)
                })
                .unwrap_or(0);
            if adv == 0 {
                0
            } else {
                u32::try_from(u64::from(line_bytes).div_ceil(adv)).unwrap_or(u32::MAX)
            }
        })
        .collect();

    Footprint { streams, bytes_per_iter, lines_per_iter, load_periods }
}

fn instr_first_memref(instr: &Instruction) -> Option<&MemRef> {
    instr.operands.iter().find_map(|o| o.mem())
}

/// The memory bound and its ECM decomposition, as surfaced in reports.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryAnalysis {
    /// Working set in bytes (derived or `ws=`-pinned).
    pub working_set: u64,
    pub bytes_per_iter: u64,
    pub lines_per_iter: f32,
    /// Number of distinct access streams found.
    pub streams: usize,
    /// Level the working set resides in: a cache name or `"mem"`.
    pub level: String,
    /// Flat load-completion latency at that level (cycles).
    pub level_latency_cy: u32,
    /// Cycles per cacheline to move data from `level` into L1.
    pub cy_per_line: f32,
    /// The memory throughput bound: `cy_per_line * lines_per_iter`.
    pub cy_per_asm_iter: f32,
    pub lsq_size: usize,
    /// Cumulative cycles/line for every hierarchy tier (ECM-style), e.g.
    /// `[("l1", 0.0), ("l2", 1.0), ("l3", 5.0), ("mem", 9.5)]`.
    pub ecm: Vec<(String, f32)>,
}

/// Assign the working set to a hierarchy level and compute the ECM bound.
pub fn analyze_memory(model: &MemModel, fp: &Footprint, iterations: u64) -> MemoryAnalysis {
    let working_set = model
        .ws_override
        .unwrap_or_else(|| fp.bytes_per_iter.saturating_mul(iterations));

    // Cumulative cycles/line to pull data from tier k into L1: the sum over
    // inner transfers of (lat_k - lat_{k-1}) / lfb. Residency in L1 is free.
    let lfb = model.lfb as f32;
    let mut ecm: Vec<(String, f32)> = Vec::with_capacity(model.levels.len() + 1);
    let mut cum = 0.0f32;
    let mut prev_lat = model.levels[0].latency_cy;
    for (i, l) in model.levels.iter().enumerate() {
        if i > 0 {
            cum += (l.latency_cy - prev_lat) as f32 / lfb;
            prev_lat = l.latency_cy;
        }
        ecm.push((l.name.clone(), cum));
    }
    cum += (model.mem_latency_cy - prev_lat) as f32 / lfb;
    ecm.push(("mem".to_string(), cum));

    let (level, level_latency_cy, cy_per_line) = model
        .levels
        .iter()
        .enumerate()
        .find(|(_, l)| l.size_bytes >= working_set)
        .map(|(i, l)| (l.name.clone(), l.latency_cy, ecm[i].1))
        .unwrap_or_else(|| {
            ("mem".to_string(), model.mem_latency_cy, ecm.last().unwrap().1)
        });

    MemoryAnalysis {
        working_set,
        bytes_per_iter: fp.bytes_per_iter,
        lines_per_iter: fp.lines_per_iter,
        streams: fp.streams.len(),
        level,
        level_latency_cy,
        cy_per_line,
        cy_per_asm_iter: cy_per_line * fp.lines_per_iter,
        lsq_size: model.lsq_size,
        ecm,
    }
}

impl MemoryAnalysis {
    /// Human-readable working set, e.g. `4M`.
    pub fn working_set_human(&self) -> String {
        fmt_size(self.working_set)
    }
}

/// What the OoO simulator needs from the memory model.
#[derive(Debug, Clone, PartialEq)]
pub struct MemSimPlan {
    /// Extra completion latency (beyond the in-core `load_latency`) a load
    /// pays when it opens a new cacheline at the resident level.
    pub miss_latency_cy: u32,
    /// LSQ entries; Load and StoreAgu uops occupy one from dispatch to
    /// retire.
    pub lsq_size: usize,
    /// Per-Load-uop miss periods from [`Footprint::load_periods`].
    pub load_periods: Vec<u32>,
}

impl MemSimPlan {
    /// Build the plan: loads at the resident level pay `level_latency - l1`
    /// extra cycles on iterations that open a new line.
    pub fn new(model: &MemModel, analysis: &MemoryAnalysis, fp: &Footprint) -> MemSimPlan {
        let l1_lat = model.levels[0].latency_cy;
        MemSimPlan {
            miss_latency_cy: analysis.level_latency_cy.saturating_sub(l1_lat),
            lsq_size: model.lsq_size,
            load_periods: fp.load_periods.clone(),
        }
    }

    /// Does load-uop number `load_idx` (0-based among Load uops in one
    /// iteration) miss L1 on assembly iteration `iter_idx`?
    pub fn load_misses(&self, load_idx: usize, iter_idx: usize) -> bool {
        if self.miss_latency_cy == 0 {
            return false;
        }
        match self.load_periods.get(load_idx) {
            Some(&p) if p > 0 => iter_idx % (p as usize) == 0,
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mdb;

    fn skl() -> std::sync::Arc<MachineModel> {
        mdb::by_name_shared("skl").unwrap()
    }

    #[test]
    fn default_spec_takes_machine_hierarchy() {
        let m = MemModel::build(&skl(), "default").unwrap();
        assert_eq!(m.levels.len(), 3);
        assert_eq!(m.levels[0].name, "l1");
        assert_eq!(m.levels[0].size_bytes, 32 * 1024);
        assert_eq!(m.levels[2].size_bytes, 8 << 20);
        assert_eq!(m.mem_latency_cy, 80);
        assert_eq!(m.lsq_size, 72);
        assert_eq!(m.lfb, 8);
        assert!(m.ws_override.is_none());
    }

    #[test]
    fn spec_overrides_and_scalars() {
        let m = MemModel::build(&skl(), "l2=512K:14,mem=:100,ws=4M,lsq=8,lfb=4").unwrap();
        let l2 = m.levels.iter().find(|l| l.name == "l2").unwrap();
        assert_eq!(l2.size_bytes, 512 * 1024);
        assert_eq!(l2.latency_cy, 14);
        assert_eq!(m.mem_latency_cy, 100);
        assert_eq!(m.ws_override, Some(4 << 20));
        assert_eq!(m.lsq_size, 8);
        assert_eq!(m.lfb, 4);
        // Empty size keeps the existing one, just swaps latency.
        let m = MemModel::build(&skl(), "l1=:5").unwrap();
        assert_eq!(m.levels[0].size_bytes, 32 * 1024);
        assert_eq!(m.levels[0].latency_cy, 5);
    }

    #[test]
    fn bad_specs_error() {
        for bad in [
            "l9=:7",            // new level without a size
            "l1=32K",           // missing latency
            "ws=banana",        // unparseable size
            "mem=:0",           // zero memory latency
            "lfb=0",
            "lsq=0",
            "l1=32K:90",        // latency above l2's -> non-increasing
        ] {
            assert!(MemModel::build(&skl(), bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn ecm_decomposition_is_cumulative_over_lfb() {
        let m = MemModel::build(&skl(), "on").unwrap();
        // skl: l1@4, l2@12, l3@44, mem@80, lfb 8 ->
        // l1 0, l2 (12-4)/8=1, l3 +32/8=5, mem +36/8=9.5
        let fp = Footprint {
            streams: vec![],
            bytes_per_iter: 128,
            lines_per_iter: 2.0,
            load_periods: vec![],
        };
        let a = analyze_memory(&m, &fp, 1000);
        assert_eq!(
            a.ecm,
            vec![
                ("l1".to_string(), 0.0),
                ("l2".to_string(), 1.0),
                ("l3".to_string(), 5.0),
                ("mem".to_string(), 9.5),
            ]
        );
        // 128 B/iter * 1000 iters = 128000 B -> l2 (32K < 128000 <= 1M).
        assert_eq!(a.level, "l2");
        assert_eq!(a.cy_per_line, 1.0);
        assert_eq!(a.cy_per_asm_iter, 2.0);
        assert_eq!(a.working_set, 128_000);
    }

    #[test]
    fn l1_resident_working_set_costs_nothing() {
        let m = MemModel::build(&skl(), "ws=16K").unwrap();
        let fp = Footprint {
            streams: vec![],
            bytes_per_iter: 512,
            lines_per_iter: 8.0,
            load_periods: vec![],
        };
        let a = analyze_memory(&m, &fp, 1_000_000);
        assert_eq!(a.level, "l1");
        assert_eq!(a.cy_per_line, 0.0);
        assert_eq!(a.cy_per_asm_iter, 0.0);
        // ws override wins over the derived footprint.
        assert_eq!(a.working_set, 16 * 1024);
    }

    #[test]
    fn sim_plan_miss_periods() {
        let m = MemModel::build(&skl(), "ws=4M").unwrap();
        let fp = Footprint {
            streams: vec![],
            bytes_per_iter: 512,
            lines_per_iter: 8.0,
            load_periods: vec![1, 2, 0],
        };
        let a = analyze_memory(&m, &fp, 1);
        assert_eq!(a.level, "l3");
        let plan = MemSimPlan::new(&m, &a, &fp);
        assert_eq!(plan.miss_latency_cy, 44 - 4);
        assert!(plan.load_misses(0, 0) && plan.load_misses(0, 7));
        assert!(plan.load_misses(1, 0) && !plan.load_misses(1, 1) && plan.load_misses(1, 2));
        assert!(!plan.load_misses(2, 0)); // invariant address never misses
    }
}
