//! Assembly-file handling: AT&T x86, AArch64 and RISC-V parsing behind
//! the [`syntax::IsaSyntax`] trait, IACA/OSACA marker detection, and
//! marked-kernel extraction (paper §III, Fig. 4).

pub mod kernel;
pub mod marker;
pub mod parser;
pub mod syntax;

pub use kernel::{extract_kernel, extract_kernel_isa, Kernel};
pub use parser::{
    parse_file, parse_file_isa, parse_instruction, parse_instruction_isa, Line, ParseError,
};
pub use syntax::{syntax_for, AArch64Syntax, AttSyntax, IsaSyntax, RiscVSyntax};
