//! Assembly-file handling: AT&T x86 parsing, IACA/OSACA marker detection,
//! and marked-kernel extraction (paper §III, Fig. 4).

pub mod kernel;
pub mod marker;
pub mod parser;

pub use kernel::{extract_kernel, Kernel};
pub use parser::{parse_file, parse_instruction, Line, ParseError};
