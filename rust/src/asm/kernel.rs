//! Kernel extraction: the marked loop body that the analyzer and the
//! simulator consume.

use anyhow::{bail, Result};

use crate::isa::{Instruction, Isa};

use super::marker::find_marked_region;
use super::parser::{parse_file_isa, Line};

/// An extracted loop kernel: the instruction sequence of one assembly
/// iteration, in program order, plus the loop back-edge label (if any).
#[derive(Debug, Clone)]
pub struct Kernel {
    pub name: String,
    pub instructions: Vec<Instruction>,
    /// Label the terminating branch jumps to (loop head), if present.
    pub loop_label: Option<String>,
    /// ISA of the kernel's instructions (derived from them; kernels
    /// never mix ISAs).
    pub isa: Isa,
}

impl Kernel {
    pub fn from_instructions(name: &str, instructions: Vec<Instruction>) -> Self {
        let loop_label = instructions
            .iter()
            .rev()
            .find(|i| i.is_branch())
            .and_then(|i| branch_target(i).cloned());
        let isa = instructions.first().map(|i| i.isa).unwrap_or_default();
        Kernel { name: name.to_string(), instructions, loop_label, isa }
    }

    /// Number of instructions excluding the back-edge branch (µ-op counts
    /// in the paper's tables include the branch line but it gets no port).
    pub fn len(&self) -> usize {
        self.instructions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instructions.is_empty()
    }

    /// Loads / stores in the kernel (for the Zen hideable-load rule).
    pub fn n_loads(&self) -> usize {
        self.instructions.iter().filter(|i| i.is_load()).count()
    }

    pub fn n_stores(&self) -> usize {
        self.instructions.iter().filter(|i| i.is_store()).count()
    }
}

/// Extract the marked kernel from AT&T x86 assembly source text.
///
/// If IACA/OSACA markers are present, the marked region is used;
/// otherwise, the body of the innermost label/backward-branch loop is
/// extracted (convenience for unmarked fixtures), and if neither exists
/// the whole file's instructions are taken.
pub fn extract_kernel(name: &str, src: &str) -> Result<Kernel> {
    extract_kernel_isa(name, src, Isa::X86)
}

/// [`extract_kernel`] under an explicit ISA syntax (markers, loop
/// detection and instruction classification all follow the ISA).
pub fn extract_kernel_isa(name: &str, src: &str, isa: Isa) -> Result<Kernel> {
    let lines = parse_file_isa(src, isa).map_err(|e| anyhow::anyhow!("{e}"))?;
    let region = find_marked_region(&lines);
    // Borrow the body slice instead of cloning the lines; only the
    // instructions are copied into the kernel.
    let body: &[Line] = match region {
        Some(r) => &lines[r.start..r.end],
        None => match innermost_loop(&lines) {
            Some((head, end)) => &lines[head..end],
            // Whole-file-as-kernel: a bare basic block (BHive-style
            // corpus input) has neither markers nor a back-edge; treat
            // every instruction in the file as one iteration.
            None => &lines[..],
        },
    };
    let instructions: Vec<Instruction> = body
        .iter()
        .filter_map(|l| match l {
            Line::Instruction(i) => Some(i.clone()),
            _ => None,
        })
        .collect();
    if instructions.is_empty() {
        bail!("marked region of `{name}` contains no instructions");
    }
    Ok(Kernel::from_instructions(name, instructions))
}

/// The label operand of a branch. x86 jcc/jmp carry it as the only
/// operand; AArch64 compare-and-branch forms (`cbnz x5, .L4`,
/// `tbz x3, #2, .L4`) carry it last, after the tested register — so
/// scan from the back.
fn branch_target(ins: &Instruction) -> Option<&String> {
    ins.operands.iter().rev().find_map(|o| match o {
        crate::isa::operand::Operand::Label(l) => Some(l),
        _ => None,
    })
}

/// Fallback: the `[head, end)` line range of the smallest
/// `label: ... ; jcc label` loop.
fn innermost_loop(lines: &[Line]) -> Option<(usize, usize)> {
    use std::collections::HashMap;
    let mut label_pos: HashMap<&str, usize> = HashMap::new();
    let mut best: Option<(usize, usize)> = None;
    for (i, l) in lines.iter().enumerate() {
        match l {
            Line::Label(name) => {
                label_pos.insert(name.as_str(), i);
            }
            Line::Instruction(ins) if ins.is_branch() => {
                if let Some(t) = branch_target(ins) {
                    if let Some(&head) = label_pos.get(t.as_str()) {
                        let span = i - head;
                        if best.map(|(s, _)| span < s).unwrap_or(true) {
                            best = Some((span, head));
                        }
                    }
                }
            }
            _ => {}
        }
    }
    best.map(|(span, head)| (head, head + span + 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    const LOOP: &str = r#"
main:
xorl %eax, %eax
.L10:
vmovapd (%r15,%rax), %ymm0
addq $32, %rax
cmpq %rdx, %rax
jne .L10
ret
"#;

    #[test]
    fn unmarked_innermost_loop() {
        let k = extract_kernel("t", LOOP).unwrap();
        assert_eq!(k.len(), 4);
        assert_eq!(k.loop_label.as_deref(), Some(".L10"));
    }

    #[test]
    fn marked_region_preferred() {
        let src = format!(
            "movl $111, %ebx\n.byte 100,103,144\naddl $1, %eax\nmovl $222, %ebx\n.byte 100,103,144\n{LOOP}"
        );
        let k = extract_kernel("t", &src).unwrap();
        assert_eq!(k.len(), 1);
        assert_eq!(k.instructions[0].mnemonic, "addl");
    }

    #[test]
    fn load_store_counts() {
        let src = "\n.L1:\nvmovapd (%rax), %ymm0\nvmovapd %ymm0, (%rbx)\nja .L1\n";
        let k = extract_kernel("t", src).unwrap();
        assert_eq!(k.n_loads(), 1);
        assert_eq!(k.n_stores(), 1);
    }

    #[test]
    fn empty_file_errors() {
        assert!(extract_kernel("t", "\n\n").is_err());
    }

    #[test]
    fn straightline_block_falls_back_to_whole_file() {
        // No markers, no back-edge: a bare basic block (corpus-style
        // input) is taken whole, one file = one iteration.
        let src = "vmovapd (%r15,%rax), %ymm0\nvaddpd %ymm0, %ymm1, %ymm2\naddq $32, %rax\n";
        let k = extract_kernel("t", src).unwrap();
        assert_eq!(k.len(), 3);
        assert_eq!(k.loop_label, None);
        assert_eq!(k.n_loads(), 1);
    }

    #[test]
    fn aarch64_unmarked_innermost_loop() {
        use crate::isa::Isa;
        let src = "\nmain:\nmov x4, #0\n.L4:\nldr q0, [x7, x4]\nadd x4, x4, #16\nsubs x5, x5, #2\nb.ne .L4\nret\n";
        let k = extract_kernel_isa("t", src, Isa::AArch64).unwrap();
        assert_eq!(k.len(), 4);
        assert_eq!(k.loop_label.as_deref(), Some(".L4"));
        assert_eq!(k.isa, Isa::AArch64);
        assert_eq!(k.n_loads(), 1);
    }

    #[test]
    fn aarch64_cbnz_loop_target_is_last_operand() {
        // Compare-and-branch back-edges carry the label after the
        // tested register; both loop detection and loop_label must
        // still find it.
        use crate::isa::Isa;
        let src = "\n.L4:\nldr q0, [x7, x4]\nadd x4, x4, #16\nsub x5, x5, #2\ncbnz x5, .L4\n";
        let k = extract_kernel_isa("t", src, Isa::AArch64).unwrap();
        assert_eq!(k.len(), 4);
        assert_eq!(k.loop_label.as_deref(), Some(".L4"));
    }
}
