//! Assembly parsing: the ISA-shared line grammar plus the AT&T x86-64
//! instruction syntax.
//!
//! Parses the GNU-as subset compilers emit for loop kernels: labels,
//! directives, instructions with register/immediate/memory/label
//! operands. IACA consumes compiled object files; OSACA parses the
//! textual assembly directly (paper §III), which is what we do.
//!
//! Labels, directives and blank lines are common to every supported
//! ISA; everything instruction-shaped is delegated to the
//! [`super::syntax::IsaSyntax`] implementation selected by the `Isa`
//! argument of the `*_isa` entry points. The unsuffixed functions keep
//! their historical AT&T x86 behavior.

use std::fmt;

use crate::isa::operand::{MemRef, Operand};
use crate::isa::register::parse_register;
use crate::isa::{Instruction, Isa};

use super::syntax::syntax_for;

/// One logical line of an assembly file.
#[derive(Debug, Clone, PartialEq)]
pub enum Line {
    /// `.L10:` — local or global label.
    Label(String),
    /// `.align 16`, `.byte 100,103,144`, ... Directive args kept raw.
    Directive { name: String, args: String },
    Instruction(Instruction),
    Empty,
}

/// Parse failure with line context.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub line: usize,
    pub text: String,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {} (in `{}`)", self.line, self.message, self.text)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, text: &str, message: impl Into<String>) -> ParseError {
    ParseError { line, text: text.to_string(), message: message.into() }
}

/// Parse a whole assembly file into logical lines (AT&T x86).
pub fn parse_file(src: &str) -> Result<Vec<Line>, ParseError> {
    parse_file_isa(src, Isa::X86)
}

/// Parse a whole assembly file into logical lines under `isa`'s syntax.
pub fn parse_file_isa(src: &str, isa: Isa) -> Result<Vec<Line>, ParseError> {
    src.lines()
        .enumerate()
        .map(|(i, l)| parse_line_isa(l, i + 1, isa))
        .collect()
}

/// Parse one source line (1-based line number for diagnostics; AT&T x86).
pub fn parse_line(raw: &str, lineno: usize) -> Result<Line, ParseError> {
    parse_line_isa(raw, lineno, Isa::X86)
}

/// Parse one source line under `isa`'s syntax. Labels, directives and
/// blank lines are ISA-shared; instructions go through the ISA's
/// [`super::syntax::IsaSyntax`].
pub fn parse_line_isa(raw: &str, lineno: usize, isa: Isa) -> Result<Line, ParseError> {
    let syntax = syntax_for(isa);
    let code = syntax.strip_comment(raw).trim();
    if code.is_empty() {
        return Ok(Line::Empty);
    }
    if let Some(label) = code.strip_suffix(':') {
        // Labels may be followed by code on the same line in theory, but
        // compilers never emit that; treat trailing content as an error.
        if label.contains(char::is_whitespace) {
            return Err(err(lineno, raw, "label with embedded whitespace"));
        }
        return Ok(Line::Label(label.to_string()));
    }
    if let Some(rest) = code.strip_prefix('.') {
        let (name, args) = match rest.split_once(char::is_whitespace) {
            Some((n, a)) => (n, a.trim()),
            None => (rest, ""),
        };
        return Ok(Line::Directive { name: name.to_string(), args: args.to_string() });
    }
    syntax.parse_instruction(code, lineno).map(Line::Instruction)
}

/// Parse a single AT&T x86 instruction like
/// `vfmadd132pd 0(%r13,%rax), %ymm3, %ymm0`.
pub fn parse_instruction(code: &str, lineno: usize) -> Result<Instruction, ParseError> {
    parse_instruction_att(code, lineno)
}

/// Parse a single instruction under `isa`'s syntax.
pub fn parse_instruction_isa(
    code: &str,
    lineno: usize,
    isa: Isa,
) -> Result<Instruction, ParseError> {
    syntax_for(isa).parse_instruction(code, lineno)
}

/// The AT&T x86 instruction grammar (the `AttSyntax` implementation).
pub(crate) fn parse_instruction_att(
    code: &str,
    lineno: usize,
) -> Result<Instruction, ParseError> {
    let mut code = code.trim();
    // Instruction prefixes we don't model are kept for display fidelity
    // but stripped from the mnemonic.
    let mut prefix: Option<String> = None;
    loop {
        let (mnemonic, rest) = match code.split_once(char::is_whitespace) {
            Some((m, r)) => (m, r.trim()),
            None => (code, ""),
        };
        if mnemonic.is_empty() {
            return Err(err(lineno, code, "empty instruction"));
        }
        if matches!(mnemonic, "lock" | "rep" | "repz" | "repnz" | "notrack") {
            match &mut prefix {
                Some(p) => {
                    p.push(' ');
                    p.push_str(mnemonic);
                }
                None => prefix = Some(mnemonic.to_string()),
            }
            code = rest;
            continue;
        }
        // GCC emits lower-case mnemonics; only pay for a case-fold when
        // the source actually needs one.
        let mnemonic = if mnemonic.bytes().any(|b| b.is_ascii_uppercase()) {
            mnemonic.to_ascii_lowercase()
        } else {
            mnemonic.to_string()
        };
        let operands = if rest.is_empty() {
            Vec::new()
        } else {
            split_operands(rest)
                .into_iter()
                .map(|o| parse_operand(o.trim(), lineno, code))
                .collect::<Result<Vec<_>, _>>()?
        };
        return Ok(Instruction { mnemonic, operands, line: lineno, isa: Isa::X86, prefix });
    }
}

/// Split an operand list on commas that are not inside the given
/// grouping delimiters — x86 memory references carry commas inside
/// parentheses (`(%r13,%rax,8)`), AArch64 inside brackets
/// (`[x7, x4, lsl #3]`).
pub(crate) fn split_operands_delim(s: &str, open: char, close: char) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            c if c == open => depth += 1,
            c if c == close => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

fn split_operands(s: &str) -> Vec<&str> {
    split_operands_delim(s, '(', ')')
}

fn parse_operand(s: &str, lineno: usize, ctx: &str) -> Result<Operand, ParseError> {
    if s.is_empty() {
        return Err(err(lineno, ctx, "empty operand"));
    }
    // Immediate: $123, $-1, $0x1f
    if let Some(imm) = s.strip_prefix('$') {
        let v = parse_int(imm).ok_or_else(|| err(lineno, ctx, format!("bad immediate `{s}`")))?;
        return Ok(Operand::Imm(v));
    }
    // Memory reference: disp(base,index,scale), possibly with segment
    // override (`%fs:16(%rax)`) or rip-relative symbol. Checked before
    // the bare-register branch so segment-prefixed operands (which also
    // start with `%`) parse as memory.
    if s.contains('(') {
        return parse_memref(s, lineno, ctx).map(Operand::Mem);
    }
    // Register: %rax (possibly with * indirect-call sigil which we reject)
    if let Some(name) = s.strip_prefix('%') {
        let r = parse_register(name)
            .ok_or_else(|| err(lineno, ctx, format!("unknown register `%{name}`")))?;
        return Ok(Operand::Reg(r));
    }
    // Bare integer = absolute address (rare) — treat as memory.
    if let Some(v) = parse_int(s) {
        return Ok(Operand::Mem(MemRef {
            displacement: v,
            base: None,
            index: None,
            scale: 1,
            segment: None,
            symbol: None,
        }));
    }
    // Branch target label.
    Ok(Operand::Label(s.to_string()))
}

fn parse_memref(s: &str, lineno: usize, ctx: &str) -> Result<MemRef, ParseError> {
    let (mut pre, inner) = match (s.find('('), s.rfind(')')) {
        (Some(a), Some(b)) if b > a => (&s[..a], &s[a + 1..b]),
        _ => return Err(err(lineno, ctx, format!("malformed memory operand `{s}`"))),
    };
    // Segment override: %fs:disp(...)
    let mut segment = None;
    if let Some((seg, rest)) = pre.split_once(':') {
        if let Some(name) = seg.strip_prefix('%') {
            segment = Some(
                parse_register(name)
                    .ok_or_else(|| err(lineno, ctx, format!("unknown segment `%{name}`")))?,
            );
        }
        pre = rest;
    }
    let pre = pre.trim();
    let (displacement, symbol) = if pre.is_empty() {
        (0, None)
    } else if let Some(v) = parse_int(pre) {
        (v, None)
    } else {
        // Symbolic displacement (rip-relative or absolute symbol).
        (0, Some(pre.to_string()))
    };
    let parts: Vec<&str> = inner.split(',').map(str::trim).collect();
    let reg_of = |p: &str| -> Result<Option<crate::isa::Register>, ParseError> {
        if p.is_empty() {
            return Ok(None);
        }
        let name = p
            .strip_prefix('%')
            .ok_or_else(|| err(lineno, ctx, format!("expected register in `{s}`")))?;
        parse_register(name)
            .map(Some)
            .ok_or_else(|| err(lineno, ctx, format!("unknown register `{p}`")))
    };
    let base = reg_of(parts.first().copied().unwrap_or(""))?;
    let index = reg_of(parts.get(1).copied().unwrap_or(""))?;
    let scale = match parts.get(2) {
        Some(p) if !p.is_empty() => parse_int(p)
            .filter(|v| matches!(v, 1 | 2 | 4 | 8))
            .ok_or_else(|| err(lineno, ctx, format!("bad scale in `{s}`")))? as u8,
        _ => 1,
    };
    Ok(MemRef { displacement, base, index, scale, segment, symbol })
}

pub(crate) fn parse_int(s: &str) -> Option<i64> {
    let s = s.trim();
    let (neg, s) = match s.strip_prefix('-') {
        Some(r) => (true, r),
        None => (false, s),
    };
    let v = if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        i64::from_str_radix(hex, 16).ok()?
    } else {
        s.parse::<i64>().ok()?
    };
    Some(if neg { -v } else { v })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_triad_fma() {
        let i = parse_instruction("vfmadd132pd 0(%r13,%rax), %ymm3, %ymm0", 1).unwrap();
        assert_eq!(i.mnemonic, "vfmadd132pd");
        assert_eq!(i.operands.len(), 3);
        let m = i.operands[0].mem().unwrap();
        assert_eq!(m.displacement, 0);
        assert_eq!(m.base.unwrap().name, "r13");
        assert_eq!(m.index.unwrap().name, "rax");
    }

    #[test]
    fn parses_scaled_memref() {
        let i = parse_instruction("vmovsd -8(%rcx,%rax,8), %xmm0", 1).unwrap();
        let m = i.operands[0].mem().unwrap();
        assert_eq!(m.displacement, -8);
        assert_eq!(m.scale, 8);
    }

    #[test]
    fn parses_labels_and_directives() {
        assert_eq!(parse_line(".L10:", 1).unwrap(), Line::Label(".L10".into()));
        match parse_line(".byte 100,103,144", 1).unwrap() {
            Line::Directive { name, args } => {
                assert_eq!(name, "byte");
                assert_eq!(args, "100,103,144");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn strips_comments() {
        assert_eq!(parse_line("  # just a comment", 3).unwrap(), Line::Empty);
        match parse_line("addl $1, %eax # bump", 4).unwrap() {
            Line::Instruction(i) => assert_eq!(i.operands.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hex_and_negative_immediates() {
        let i = parse_instruction("vextracti128 $0x1, %ymm2, %xmm1", 1).unwrap();
        assert_eq!(i.operands[0], Operand::Imm(1));
        let i = parse_instruction("addq $-32, %rax", 1).unwrap();
        assert_eq!(i.operands[0], Operand::Imm(-32));
    }

    #[test]
    fn unknown_register_errors() {
        assert!(parse_instruction("addl $1, %exx", 1).is_err());
    }

    #[test]
    fn branch_label_operand() {
        let i = parse_instruction("jne .L2", 1).unwrap();
        assert_eq!(i.operands[0], Operand::Label(".L2".into()));
    }

    #[test]
    fn rip_relative_symbol() {
        let i = parse_instruction("vmovsd .LC2(%rip), %xmm4", 1).unwrap();
        let m = i.operands[0].mem().unwrap();
        assert_eq!(m.symbol.as_deref(), Some(".LC2"));
        assert_eq!(m.base.unwrap().name, "rip");
    }

    #[test]
    fn whole_file_parses() {
        let src = "\n.L10:\n\tvmovapd (%r15,%rax), %ymm0 # load\n\tja .L10\n";
        let lines = parse_file(src).unwrap();
        assert_eq!(lines.len(), 4);
    }

    #[test]
    fn prefixes_preserved_for_display() {
        let i = parse_instruction("lock addl $1, (%rax)", 1).unwrap();
        assert_eq!(i.mnemonic, "addl");
        assert_eq!(i.prefix.as_deref(), Some("lock"));
        assert_eq!(i.to_string(), "lock addl $1, (%rax)");
        let re = parse_instruction(&i.to_string(), 1).unwrap();
        assert_eq!(re, i);
    }

    #[test]
    fn segment_override_roundtrips() {
        let i = parse_instruction("movq %fs:16(%rax), %rbx", 1).unwrap();
        let m = i.operands[0].mem().unwrap();
        assert_eq!(m.segment.unwrap().name, "fs");
        assert_eq!(i.to_string(), "movq %fs:16(%rax), %rbx");
        let re = parse_instruction(&i.to_string(), 1).unwrap();
        assert_eq!(re, i);
    }

    #[test]
    fn aarch64_file_parses_via_isa_entry_point() {
        use crate::isa::Isa;
        let src = "\n.L4:\n\tldr q0, [x7, x4] // load\n\tb.ne .L4\n";
        let lines = parse_file_isa(src, Isa::AArch64).unwrap();
        assert_eq!(lines.len(), 4);
        match &lines[2] {
            Line::Instruction(i) => {
                assert_eq!(i.mnemonic, "ldr");
                assert_eq!(i.isa, Isa::AArch64);
            }
            other => panic!("{other:?}"),
        }
    }
}
