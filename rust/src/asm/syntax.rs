//! Per-ISA assembly syntax: the [`IsaSyntax`] trait and its AT&T x86
//! ([`AttSyntax`]), ARMv8 A64 ([`AArch64Syntax`]) and RISC-V RV64
//! ([`RiscVSyntax`]) implementations.
//!
//! The line-level grammar (labels, `.`-directives, blank lines) is
//! shared across ISAs and lives in [`super::parser`]; what differs per
//! ISA — comment markers, mnemonic prefixes, operand splitting, operand
//! and memory-reference shapes, register names — is behind this trait.
//! The trait also carries the benchmark-emission surface consumed by
//! `ibench::gen`, so `--learn` model construction works on every
//! backend: register pools, memory/immediate spellings, destination
//! position and the counter/branch loop scaffold are per-ISA data, not
//! hard-coded AT&T text. Adding a backend is a syntax impl plus a
//! `.mdb` machine model: nothing in the analyzer, simulator or api
//! layers is ISA-specific (DESIGN.md §7).

use crate::isa::operand::{MemRef, Operand};
use crate::isa::register::{parse_aarch64_register, parse_riscv_register};
use crate::isa::{Instruction, Isa};

use super::parser::{parse_instruction_att, parse_int, split_operands_delim, ParseError};

/// The syntax of one instruction-set architecture: how to strip
/// comments, how to parse one instruction statement, and how to emit
/// benchmark-loop text (`ibench::gen`).
pub trait IsaSyntax: Sync {
    /// The ISA this syntax parses.
    fn isa(&self) -> Isa;

    /// Strip the line comment (if any) from a raw source line.
    fn strip_comment<'a>(&self, line: &'a str) -> &'a str;

    /// Parse a single instruction statement (labels and directives are
    /// handled by the shared line parser).
    fn parse_instruction(&self, code: &str, lineno: usize) -> Result<Instruction, ParseError>;

    // ---- benchmark-loop emission (ibench::gen) ----------------------
    //
    // The pools below are disjoint from each other and from the
    // registers the loop scaffold and memory bases use, so latency
    // chains never tangle with the loop counter. Index convention
    // (shared across ISAs, established by the x86 generator):
    // * 0..=12  — destination pool (chains / rotating TP dests);
    // * 13..=15 — never-written source pool;
    // * 16..    — probe-destination pool (conflict loops).

    /// Spelling of a register of signature-class `tok` from pool slot
    /// `idx`, or `None` when the class cannot be benchmarked on this
    /// ISA. `mnemonic` lets an impl pick a spelling variant (AArch64
    /// `q0` for loads/stores vs `v0.2d` for ALU forms).
    fn bench_reg(&self, mnemonic: &str, tok: &str, idx: usize) -> Option<String>;

    /// Loop-invariant memory-operand spelling (store target when
    /// `store`, load source otherwise).
    fn bench_mem(&self, store: bool) -> &'static str;

    /// Immediate-operand spelling.
    fn bench_imm(&self) -> &'static str;

    /// Counter / compare / branch lines closing a `.Lbench:` loop.
    fn bench_loop_overhead(&self) -> &'static str;

    /// Index of the destination operand for an `n`-token form of
    /// `mnemonic` (x86: last; AArch64/RISC-V: first, except stores
    /// whose destination is the memory operand).
    fn bench_dest_index(&self, mnemonic: &str, toks: &[&str]) -> usize;
}

/// The syntax implementation for an ISA.
pub fn syntax_for(isa: Isa) -> &'static dyn IsaSyntax {
    match isa {
        Isa::X86 => &AttSyntax,
        Isa::AArch64 => &AArch64Syntax,
        Isa::RiscV => &RiscVSyntax,
    }
}

/// AT&T-syntax x86-64 (`%rax`, `$imm`, `disp(base,index,scale)`).
pub struct AttSyntax;

impl IsaSyntax for AttSyntax {
    fn isa(&self) -> Isa {
        Isa::X86
    }

    fn strip_comment<'a>(&self, line: &'a str) -> &'a str {
        // `#` to end of line (GNU as x86); `/* */` is not emitted by GCC
        // so we ignore it.
        match line.find('#') {
            Some(idx) => &line[..idx],
            None => line,
        }
    }

    fn parse_instruction(&self, code: &str, lineno: usize) -> Result<Instruction, ParseError> {
        parse_instruction_att(code, lineno)
    }

    /// Pools (disjoint by construction so chains never tangle):
    /// * vector: dests 0..=12 -> xmm/ymm 0..12, sources 13..=15;
    /// * GP: dests 0..4 -> r8..r11, sources 13/14 -> r12/r13,
    ///   probe-dests 16.. -> rsi/rdi/rbp/r14/r15
    ///   (rax/rbx are memory bases, ecx/edx the loop counter).
    fn bench_reg(&self, _mnemonic: &str, tok: &str, idx: usize) -> Option<String> {
        let gp = |idx: usize| -> String {
            const PROBE_POOL: [&str; 5] = ["rsi", "rdi", "rbp", "r14", "r15"];
            if idx >= 16 {
                PROBE_POOL[(idx - 16) % 5].to_string()
            } else if idx >= 13 {
                format!("r{}", 12 + (idx - 13) % 2)
            } else {
                format!("r{}", 8 + idx % 4)
            }
        };
        let gp32 = |idx: usize| -> String {
            const PROBE_POOL: [&str; 5] = ["esi", "edi", "ebp", "r14d", "r15d"];
            if idx >= 16 {
                PROBE_POOL[(idx - 16) % 5].to_string()
            } else if idx >= 13 {
                format!("r{}d", 12 + (idx - 13) % 2)
            } else {
                format!("r{}d", 8 + idx % 4)
            }
        };
        Some(match tok {
            "xmm" => format!("%xmm{}", idx.min(15)),
            "ymm" => format!("%ymm{}", idx.min(15)),
            "r64" => format!("%{}", gp(idx)),
            "r32" | "r" => format!("%{}", gp32(idx)),
            _ => return None,
        })
    }

    fn bench_mem(&self, store: bool) -> &'static str {
        if store {
            "(%rbx)" // store target, loop-invariant
        } else {
            "(%rax)" // load source, loop-invariant
        }
    }

    fn bench_imm(&self) -> &'static str {
        "$1"
    }

    fn bench_loop_overhead(&self) -> &'static str {
        "addl $1, %ecx\ncmpl %ecx, %edx\njne .Lbench\n"
    }

    fn bench_dest_index(&self, _mnemonic: &str, toks: &[&str]) -> usize {
        toks.len().saturating_sub(1)
    }
}

/// Destination-operand position shared by the dest-first ISAs: operand
/// 0, except stores, whose destination is the (sole, last) memory
/// operand in the signature.
fn dest_first_dest_index(is_store: bool, toks: &[&str]) -> usize {
    if is_store {
        toks.iter().position(|t| *t == "mem").unwrap_or(0)
    } else {
        0
    }
}

/// ARMv8 AArch64 GNU-as syntax (`x0`, `#imm`, `[base, index, lsl #s]`).
pub struct AArch64Syntax;

impl IsaSyntax for AArch64Syntax {
    fn isa(&self) -> Isa {
        Isa::AArch64
    }

    fn strip_comment<'a>(&self, line: &'a str) -> &'a str {
        // `//` to end of line. `#` starts immediates on AArch64 and MUST
        // NOT be treated as a comment marker (the classic porting trap
        // when generalizing an x86 parser).
        match line.find("//") {
            Some(idx) => &line[..idx],
            None => line,
        }
    }

    fn parse_instruction(&self, code: &str, lineno: usize) -> Result<Instruction, ParseError> {
        parse_instruction_a64(code, lineno)
    }

    /// Pools: GP dests x0/x2/x3/x9, sources x12/x13, probe dests
    /// x4..x8 (x10/x11 are the memory bases, x17 the loop counter, and
    /// x1 is excluded everywhere — it is the AArch64 marker register,
    /// so a future marker-wrapped benchmark loop can never clobber
    /// it); FP/vector pool indices map straight onto d/s/v/q 0..15
    /// like the x86 vector pool.
    fn bench_reg(&self, mnemonic: &str, tok: &str, idx: usize) -> Option<String> {
        let gp = |idx: usize| -> usize {
            const DEST_POOL: [usize; 4] = [0, 2, 3, 9];
            const PROBE_POOL: [usize; 5] = [4, 5, 6, 7, 8];
            if idx >= 16 {
                PROBE_POOL[(idx - 16) % 5]
            } else if idx >= 13 {
                12 + (idx - 13) % 2
            } else {
                DEST_POOL[idx % 4]
            }
        };
        Some(match tok {
            "x" => format!("x{}", gp(idx)),
            "w" => format!("w{}", gp(idx)),
            "d" => format!("d{}", idx.min(15)),
            "s" => format!("s{}", idx.min(15)),
            "q" => {
                // Loads/stores take the scalar `q` spelling; ALU forms
                // the arrangement spelling. Both carry the `q`
                // signature and alias the same vector slot.
                let n = idx.min(15);
                if mnemonic.starts_with("ld") || mnemonic.starts_with("st") {
                    format!("q{n}")
                } else {
                    format!("v{n}.2d")
                }
            }
            _ => return None,
        })
    }

    fn bench_mem(&self, store: bool) -> &'static str {
        if store {
            "[x11]"
        } else {
            "[x10]"
        }
    }

    fn bench_imm(&self) -> &'static str {
        "#1"
    }

    fn bench_loop_overhead(&self) -> &'static str {
        "subs x17, x17, #1\nb.ne .Lbench\n"
    }

    fn bench_dest_index(&self, mnemonic: &str, toks: &[&str]) -> usize {
        dest_first_dest_index(mnemonic.starts_with("st"), toks)
    }
}

/// RISC-V RV64 GNU-as syntax (`a0`/`fa5` registers, bare immediates,
/// `offset(base)` memory operands, `#` comments — unlike A64, `#` is
/// safe as a comment marker because immediates carry no sigil).
pub struct RiscVSyntax;

impl IsaSyntax for RiscVSyntax {
    fn isa(&self) -> Isa {
        Isa::RiscV
    }

    fn strip_comment<'a>(&self, line: &'a str) -> &'a str {
        match line.find('#') {
            Some(idx) => &line[..idx],
            None => line,
        }
    }

    fn parse_instruction(&self, code: &str, lineno: usize) -> Result<Instruction, ParseError> {
        parse_instruction_riscv(code, lineno)
    }

    /// Pools: GP dests t3..t6, sources s2/s3, probe dests s4..s8
    /// (a6/a7 are the memory bases, t1/t2 the loop counter and bound,
    /// t0 the marker register); FP pool indices map onto f0..f15 like
    /// the x86 vector pool.
    fn bench_reg(&self, _mnemonic: &str, tok: &str, idx: usize) -> Option<String> {
        Some(match tok {
            "x" => {
                const DEST_POOL: [&str; 4] = ["t3", "t4", "t5", "t6"];
                const SRC_POOL: [&str; 2] = ["s2", "s3"];
                const PROBE_POOL: [&str; 5] = ["s4", "s5", "s6", "s7", "s8"];
                if idx >= 16 {
                    PROBE_POOL[(idx - 16) % 5]
                } else if idx >= 13 {
                    SRC_POOL[(idx - 13) % 2]
                } else {
                    DEST_POOL[idx % 4]
                }
                .to_string()
            }
            "f" => format!("f{}", idx.min(15)),
            _ => return None,
        })
    }

    fn bench_mem(&self, store: bool) -> &'static str {
        if store {
            "0(a7)"
        } else {
            "0(a6)"
        }
    }

    fn bench_imm(&self) -> &'static str {
        "1"
    }

    fn bench_loop_overhead(&self) -> &'static str {
        "addi t1, t1, 1\nbne t1, t2, .Lbench\n"
    }

    fn bench_dest_index(&self, mnemonic: &str, toks: &[&str]) -> usize {
        dest_first_dest_index(crate::isa::instruction::riscv_is_store_mnemonic(mnemonic), toks)
    }
}

fn err(line: usize, text: &str, message: impl Into<String>) -> ParseError {
    ParseError { line, text: text.to_string(), message: message.into() }
}

/// Parse one A64 instruction like `fmla v0.2d, v1.2d, v2.2d` or
/// `ldr q0, [x7, x4]`.
pub(crate) fn parse_instruction_a64(
    code: &str,
    lineno: usize,
) -> Result<Instruction, ParseError> {
    let code = code.trim();
    let (mnemonic, rest) = match code.split_once(char::is_whitespace) {
        Some((m, r)) => (m, r.trim()),
        None => (code, ""),
    };
    if mnemonic.is_empty() {
        return Err(err(lineno, code, "empty instruction"));
    }
    let mnemonic = if mnemonic.bytes().any(|b| b.is_ascii_uppercase()) {
        mnemonic.to_ascii_lowercase()
    } else {
        mnemonic.to_string()
    };
    // Multi-register transfers write (or read) more than one data
    // register; the single-destination model would silently drop the
    // second register's write — reject them like writeback forms until
    // they are modeled.
    if matches!(
        mnemonic.as_str(),
        "ldp" | "stp" | "ldnp" | "stnp" | "ld1" | "ld2" | "ld3" | "ld4" | "st1" | "st2"
            | "st3" | "st4"
    ) {
        return Err(err(lineno, code, format!("multi-register form `{mnemonic}` not supported")));
    }
    let operands = if rest.is_empty() {
        Vec::new()
    } else {
        split_operands_delim(rest, '[', ']')
            .into_iter()
            .map(|o| parse_operand_a64(o.trim(), lineno, code))
            .collect::<Result<Vec<_>, _>>()?
    };
    // Post-index writeback (`ldr x0, [x1], #8`) splits into a memory
    // operand followed by an immediate; like pre-index it mutates the
    // base register, which the dependency model does not represent —
    // reject it rather than silently dropping the base-register write.
    if (mnemonic.starts_with("ld") || mnemonic.starts_with("st"))
        && operands
            .iter()
            .position(|o| o.is_mem())
            .is_some_and(|i| i + 1 != operands.len())
    {
        return Err(err(lineno, code, "post-index writeback not supported"));
    }
    Ok(Instruction { mnemonic, operands, line: lineno, isa: Isa::AArch64, prefix: None })
}

fn parse_operand_a64(s: &str, lineno: usize, ctx: &str) -> Result<Operand, ParseError> {
    if s.is_empty() {
        return Err(err(lineno, ctx, "empty operand"));
    }
    // Immediate: #16, #-8, #0x1f.
    if let Some(imm) = s.strip_prefix('#') {
        let v = parse_int(imm).ok_or_else(|| err(lineno, ctx, format!("bad immediate `{s}`")))?;
        return Ok(Operand::Imm(v));
    }
    // Memory reference: [base], [base, #disp], [base, index{, lsl #s}].
    if s.starts_with('[') {
        return parse_memref_a64(s, lineno, ctx).map(Operand::Mem);
    }
    if let Some(r) = parse_aarch64_register(s) {
        return Ok(Operand::Reg(r));
    }
    // GAS accepts bare immediates without the `#` sigil.
    if let Some(v) = parse_int(s) {
        return Ok(Operand::Imm(v));
    }
    // Shifted/extended data operands (`add x2, x1, x3, lsl #3`) are not
    // modeled — reject them at the source line like the memref extends,
    // instead of surfacing later as a bogus `...-lbl` database miss.
    let head = s.split([' ', '\t', '#']).next().unwrap_or(s);
    if matches!(
        head,
        "lsl" | "lsr" | "asr" | "ror" | "sxtb" | "sxth" | "sxtw" | "sxtx" | "uxtb" | "uxth"
            | "uxtw" | "uxtx"
    ) {
        return Err(err(lineno, ctx, format!("shifted/extended operand `{s}` not supported")));
    }
    // Register-shaped tokens that failed to parse (`x31`, `d33`,
    // `v0.3d`, unsupported `h0`/`b1` scalar views) are typos or
    // unmodeled names, not labels — error at the source line instead
    // of surfacing later as a bogus `...-lbl` database miss. The whole
    // tail must be numeric (plus an optional `.arr` part) so labels
    // that merely start with a register letter (`x2_loop`) still parse.
    let looks_like_register = matches!(
        s.chars().next(),
        Some('x' | 'w' | 'q' | 'd' | 's' | 'v' | 'h' | 'b')
    ) && {
        // Letter + digits, with any dotted tail: unsupported
        // arrangements and lane references (`v2.d[0]`) error here too,
        // instead of parsing as labels.
        let num = match s[1..].split_once('.') {
            Some((n, _)) => n,
            None => &s[1..],
        };
        !num.is_empty() && num.bytes().all(|b| b.is_ascii_digit())
    };
    if looks_like_register {
        return Err(err(lineno, ctx, format!("unknown register `{s}`")));
    }
    // Branch target label.
    Ok(Operand::Label(s.to_string()))
}

fn parse_memref_a64(s: &str, lineno: usize, ctx: &str) -> Result<MemRef, ParseError> {
    if s.ends_with('!') {
        return Err(err(lineno, ctx, format!("pre-index writeback not supported in `{s}`")));
    }
    let inner = s
        .strip_prefix('[')
        .and_then(|r| r.strip_suffix(']'))
        .ok_or_else(|| err(lineno, ctx, format!("malformed memory operand `{s}`")))?;
    let parts: Vec<&str> = inner.split(',').map(str::trim).collect();
    let base_name = parts
        .first()
        .copied()
        .filter(|p| !p.is_empty())
        .ok_or_else(|| err(lineno, ctx, format!("memory operand `{s}` has no base")))?;
    let base = parse_aarch64_register(base_name)
        .ok_or_else(|| err(lineno, ctx, format!("unknown register `{base_name}`")))?;
    let mut mem = MemRef {
        displacement: 0,
        base: Some(base),
        index: None,
        scale: 1,
        segment: None,
        symbol: None,
    };
    if parts.len() == 1 {
        return Ok(mem);
    }
    let second = parts[1];
    if let Some(imm) = second.strip_prefix('#').map_or_else(|| parse_int(second), parse_int) {
        // [base, #disp] — no further components allowed.
        if parts.len() > 2 {
            return Err(err(lineno, ctx, format!("malformed memory operand `{s}`")));
        }
        mem.displacement = imm;
        return Ok(mem);
    }
    let index = parse_aarch64_register(second)
        .ok_or_else(|| err(lineno, ctx, format!("unknown register `{second}`")))?;
    mem.index = Some(index);
    match parts.get(2) {
        None => {}
        Some(ext) => {
            // Only `lsl #shift` extends are modeled (enough for the
            // GCC-emitted array-indexing idioms).
            let shift = ext
                .strip_prefix("lsl")
                .map(str::trim)
                .and_then(|r| r.strip_prefix('#'))
                .and_then(parse_int)
                .filter(|v| (0..=4).contains(v))
                .ok_or_else(|| err(lineno, ctx, format!("unsupported extend `{ext}` in `{s}`")))?;
            mem.scale = 1u8 << (shift as u32);
        }
    }
    if parts.len() > 3 {
        return Err(err(lineno, ctx, format!("malformed memory operand `{s}`")));
    }
    Ok(mem)
}

/// Parse one RV64 instruction like `fmadd.d fa5, fa5, fa0, fa4` or
/// `ld a0, 8(sp)`.
pub(crate) fn parse_instruction_riscv(
    code: &str,
    lineno: usize,
) -> Result<Instruction, ParseError> {
    let code = code.trim();
    let (mnemonic, rest) = match code.split_once(char::is_whitespace) {
        Some((m, r)) => (m, r.trim()),
        None => (code, ""),
    };
    if mnemonic.is_empty() {
        return Err(err(lineno, code, "empty instruction"));
    }
    let mnemonic = if mnemonic.bytes().any(|b| b.is_ascii_uppercase()) {
        mnemonic.to_ascii_lowercase()
    } else {
        mnemonic.to_string()
    };
    let operands = if rest.is_empty() {
        Vec::new()
    } else {
        // Memory operands carry no commas inside their parentheses
        // (`offset(base)` only), but reuse the depth-aware splitter for
        // robustness against spaced spellings.
        split_operands_delim(rest, '(', ')')
            .into_iter()
            .map(|o| parse_operand_riscv(o.trim(), lineno, code))
            .collect::<Result<Vec<_>, _>>()?
    };
    Ok(Instruction { mnemonic, operands, line: lineno, isa: Isa::RiscV, prefix: None })
}

fn parse_operand_riscv(s: &str, lineno: usize, ctx: &str) -> Result<Operand, ParseError> {
    if s.is_empty() {
        return Err(err(lineno, ctx, "empty operand"));
    }
    // Memory reference: offset(base), 0 offset may be spelled `(base)`.
    if s.contains('(') {
        return parse_memref_riscv(s, lineno, ctx).map(Operand::Mem);
    }
    if let Some(r) = parse_riscv_register(s) {
        return Ok(Operand::Reg(r));
    }
    // Immediates are bare: 16, -8, 0x1f.
    if let Some(v) = parse_int(s) {
        return Ok(Operand::Imm(v));
    }
    // Register-shaped tokens that failed to parse (`x32`, `f40`, `a9`,
    // `s12`, `ft12`) are typos or out-of-range names, not labels —
    // error at the source line instead of surfacing later as a bogus
    // `...-lbl` database miss. Labels that merely start with a register
    // letter (`x2_loop`, `sum_head`) still parse as labels.
    if riscv_register_shaped(s) {
        return Err(err(lineno, ctx, format!("unknown register `{s}`")));
    }
    Ok(Operand::Label(s.to_string()))
}

/// Does `s` look like a RISC-V register name (letter prefix + all-digit
/// tail) without actually being one? Case-folded like
/// `parse_riscv_register`, so `X32` is caught the same as `x32`.
fn riscv_register_shaped(s: &str) -> bool {
    let lower = s.to_ascii_lowercase();
    let s = lower.as_str();
    let tail_digits = |t: &str| !t.is_empty() && t.bytes().all(|b| b.is_ascii_digit());
    if let Some(rest) = s.strip_prefix('x') {
        return tail_digits(rest);
    }
    if let Some(rest) = s.strip_prefix('f') {
        if tail_digits(rest) {
            return true; // f32..: raw FP spelling out of range
        }
        // fa9 / ft12 / fs13 shapes.
        if let Some(r2) = rest.strip_prefix(['a', 't', 's']) {
            return tail_digits(r2);
        }
        return false;
    }
    if let Some(rest) = s.strip_prefix(['a', 't', 's']) {
        return tail_digits(rest);
    }
    false
}

fn parse_memref_riscv(s: &str, lineno: usize, ctx: &str) -> Result<MemRef, ParseError> {
    // Relocation operands (`%lo(sym)(a5)`) are linker-level syntax our
    // subset does not model; reject rather than mis-parse.
    if s.starts_with('%') {
        return Err(err(lineno, ctx, format!("relocation operand `{s}` not supported")));
    }
    let (pre, rest) = match s.find('(') {
        Some(a) => (&s[..a], &s[a + 1..]),
        None => return Err(err(lineno, ctx, format!("malformed memory operand `{s}`"))),
    };
    let inner = rest
        .strip_suffix(')')
        .ok_or_else(|| err(lineno, ctx, format!("malformed memory operand `{s}`")))?;
    if inner.contains('(') {
        return Err(err(lineno, ctx, format!("malformed memory operand `{s}`")));
    }
    let pre = pre.trim();
    let displacement = if pre.is_empty() {
        0
    } else {
        parse_int(pre)
            .ok_or_else(|| err(lineno, ctx, format!("bad displacement in `{s}`")))?
    };
    let base_name = inner.trim();
    let base = parse_riscv_register(base_name)
        .ok_or_else(|| err(lineno, ctx, format!("unknown register `{base_name}`")))?;
    Ok(MemRef {
        displacement,
        base: Some(base),
        index: None,
        scale: 1,
        segment: None,
        symbol: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::RegisterClass;

    fn ins(s: &str) -> Instruction {
        parse_instruction_a64(s, 1).expect(s)
    }

    #[test]
    fn parses_fmla() {
        let i = ins("fmla v0.2d, v1.2d, v2.2d");
        assert_eq!(i.mnemonic, "fmla");
        assert_eq!(i.operands.len(), 3);
        assert_eq!(i.form().to_string(), "fmla-q_q_q");
        assert_eq!(i.isa, Isa::AArch64);
    }

    #[test]
    fn parses_load_with_index() {
        let i = ins("ldr q0, [x7, x4]");
        let m = i.operands[1].mem().unwrap();
        assert_eq!(m.base.unwrap().name, "x7");
        assert_eq!(m.index.unwrap().name, "x4");
        assert_eq!(m.scale, 1);
        assert!(i.is_load());
        assert!(!i.is_store());
    }

    #[test]
    fn parses_scaled_index_and_displacement() {
        let i = ins("ldr d0, [x2, x5, lsl #3]");
        let m = i.operands[1].mem().unwrap();
        assert_eq!(m.scale, 8);
        let i = ins("str x0, [sp, #16]");
        let m = i.operands[1].mem().unwrap();
        assert_eq!(m.displacement, 16);
        assert_eq!(m.base.unwrap().name, "sp");
        assert!(i.is_store());
        assert!(!i.is_load());
    }

    #[test]
    fn store_dest_is_memory_and_data_is_read() {
        let i = ins("str q0, [x9, x4]");
        assert!(matches!(i.dest(), Some(Operand::Mem(_))));
        let reads = i.reads();
        assert!(reads.iter().any(|r| r.name == "q0"));
        assert!(reads.iter().any(|r| r.name == "x9"));
        assert!(reads.iter().any(|r| r.name == "x4"));
        assert!(i.writes().is_empty());
    }

    #[test]
    fn dest_first_semantics() {
        let i = ins("fadd d0, d1, d2");
        assert_eq!(i.writes().len(), 1);
        assert_eq!(i.writes()[0].name, "d0");
        // fadd does not read its destination...
        assert_eq!(i.reads().len(), 2);
        // ...but fmla does.
        let i = ins("fmla v0.2d, v1.2d, v2.2d");
        assert_eq!(i.reads().len(), 3);
    }

    #[test]
    fn immediates_and_flags() {
        let i = ins("add x4, x4, #16");
        assert_eq!(i.operands[2], Operand::Imm(16));
        assert!(!i.writes_flags());
        let i = ins("subs x5, x5, #2");
        assert!(i.writes_flags());
        assert_eq!(i.form().to_string(), "subs-x_x_imm");
        let i = ins("cmp w4, w5");
        assert!(i.is_compare());
        assert!(i.dest().is_none());
    }

    #[test]
    fn cond_branch_reads_flags() {
        let i = ins("b.ne .L4");
        assert!(i.is_branch());
        assert!(i.is_cond_branch());
        assert!(i.reads().iter().any(|r| r.name == "flags"));
        assert_eq!(i.operands[0], Operand::Label(".L4".into()));
        let i = ins("cbnz x3, .L4");
        assert!(i.is_branch());
        // cbnz reads its register, not the flags.
        assert!(i.reads().iter().any(|r| r.name == "x3"));
        assert!(!i.reads().iter().any(|r| r.name == "flags"));
    }

    #[test]
    fn zero_register_writes_discarded() {
        let i = ins("subs xzr, x5, #2");
        assert!(i.writes().iter().all(|r| r.name == "flags"));
    }

    #[test]
    fn zero_idiom_and_moves() {
        assert!(ins("movi v0.2d, #0").is_zero_idiom());
        assert!(!ins("movi v0.2d, #1").is_zero_idiom());
        assert!(ins("eor v1.16b, v1.16b, v1.16b").is_zero_idiom());
        assert!(!ins("eor v1.16b, v1.16b, v2.16b").is_zero_idiom());
        assert!(ins("mov x0, x1").is_reg_move());
        assert!(ins("fmov d0, d1").is_reg_move());
        assert!(!ins("mov x0, #7").is_reg_move());
    }

    #[test]
    fn scvtf_reads_gp_writes_fp() {
        let i = ins("scvtf d0, w4");
        assert_eq!(i.form().to_string(), "scvtf-d_w");
        assert_eq!(i.reads().len(), 1);
        assert_eq!(i.reads()[0].class, RegisterClass::AGp32);
        assert_eq!(i.writes()[0].class, RegisterClass::AFp64);
    }

    #[test]
    fn writeback_and_bad_extends_error() {
        assert!(parse_instruction_a64("ldr x0, [x1, #8]!", 1).is_err());
        // Post-index writeback mutates the base register: rejected, not
        // silently modeled without the write.
        assert!(parse_instruction_a64("ldr x0, [x1], #8", 1).is_err());
        assert!(parse_instruction_a64("str q0, [x1], #16", 1).is_err());
        assert!(parse_instruction_a64("ldr x0, [x1, w2, sxtw #3]", 1).is_err());
        assert!(parse_instruction_a64("ldr x0, [zz9]", 1).is_err());
    }

    #[test]
    fn shifted_register_operands_rejected() {
        assert!(parse_instruction_a64("add x2, x1, x3, lsl #3", 1).is_err());
        assert!(parse_instruction_a64("add x2, x1, w3, sxtw", 1).is_err());
    }

    #[test]
    fn multi_register_forms_rejected() {
        // Pair/structure transfers have a second data register the
        // single-dest model would silently drop.
        assert!(parse_instruction_a64("ldp x0, x1, [x2]", 1).is_err());
        assert!(parse_instruction_a64("stp x0, x1, [sp, #16]", 1).is_err());
        assert!(parse_instruction_a64("ld1 {v0.2d}, [x0]", 1).is_err());
    }

    #[test]
    fn register_shaped_typos_error_not_label() {
        assert!(parse_instruction_a64("fadd d0, d1, d33", 1).is_err());
        assert!(parse_instruction_a64("add x31, x0, #1", 1).is_err());
        assert!(parse_instruction_a64("fadd v0.3d, v1.3d, v2.3d", 1).is_err());
        assert!(parse_instruction_a64("ldr h0, [x0]", 1).is_err());
        // Lane references are register-shaped too: error, not label.
        assert!(parse_instruction_a64("fmla v0.2d, v1.2d, v2.d[0]", 1).is_err());
        // Real labels still parse — including ones that merely start
        // with a register letter.
        let i = parse_instruction_a64("b.ne .L4", 1).unwrap();
        assert_eq!(i.operands[0], Operand::Label(".L4".into()));
        let i = parse_instruction_a64("cbnz x5, x2_loop", 1).unwrap();
        assert_eq!(i.operands[1], Operand::Label("x2_loop".into()));
        // The frame-pointer alias is a real register.
        let i = parse_instruction_a64("add fp, sp, #16", 1).unwrap();
        assert_eq!(i.form().to_string(), "add-x_x_imm");
    }

    #[test]
    fn comment_stripping_keeps_immediates() {
        let syn = AArch64Syntax;
        assert_eq!(syn.strip_comment("add x4, x4, #16 // bump"), "add x4, x4, #16 ");
        assert_eq!(syn.strip_comment("add x4, x4, #16"), "add x4, x4, #16");
    }

    #[test]
    fn display_roundtrip() {
        for src in [
            "ldr q0, [x7, x4]",
            "ldr d0, [x2, x5, lsl #3]",
            "str x0, [sp, #16]",
            "fmla v0.2d, v1.2d, v2.2d",
            "add x4, x4, #16",
            "subs x5, x5, #2",
            "b.ne .L4",
            "scvtf d0, w4",
            "ldr x0, [x1]",
        ] {
            let i = ins(src);
            assert_eq!(i.to_string(), src);
            let re = parse_instruction_a64(&i.to_string(), 1).unwrap();
            assert_eq!(re, i, "{src}");
        }
    }

    // ---- RISC-V ------------------------------------------------------

    fn rv(s: &str) -> Instruction {
        parse_instruction_riscv(s, 1).expect(s)
    }

    #[test]
    fn riscv_parses_fmadd() {
        let i = rv("fmadd.d fa5, fa5, fa0, fa4");
        assert_eq!(i.mnemonic, "fmadd.d");
        assert_eq!(i.operands.len(), 4);
        assert_eq!(i.form().to_string(), "fmadd.d-f_f_f_f");
        assert_eq!(i.isa, Isa::RiscV);
        // Dest-first, addend explicit: 3 reads, 1 write.
        assert_eq!(i.reads().len(), 3);
        assert_eq!(i.writes().len(), 1);
        assert_eq!(i.writes()[0].name, "fa5");
    }

    #[test]
    fn riscv_loads_and_stores() {
        let i = rv("fld fa5, 0(a5)");
        assert_eq!(i.form().to_string(), "fld-f_mem");
        assert!(i.is_load());
        assert!(!i.is_store());
        let m = i.operands[1].mem().unwrap();
        assert_eq!(m.displacement, 0);
        assert_eq!(m.base.unwrap().name, "a5");
        assert!(m.index.is_none());
        let i = rv("fsd fa5, 8(a3)");
        assert!(i.is_store());
        assert!(!i.is_load());
        assert!(matches!(i.dest(), Some(Operand::Mem(_))));
        // Store data + address registers are all reads; nothing written.
        let reads = i.reads();
        assert!(reads.iter().any(|r| r.name == "fa5"));
        assert!(reads.iter().any(|r| r.name == "a3"));
        assert!(i.writes().is_empty());
        // `li` is not a load; `ld` with raw names parses too.
        assert!(!rv("li a0, 1").is_load());
        assert!(rv("ld x10, 0(x15)").is_load());
    }

    #[test]
    fn riscv_branches_carry_register_reads() {
        let i = rv("bne a4, a5, .L2");
        assert!(i.is_branch());
        assert!(i.is_cond_branch());
        // No flags register on RISC-V: never fusible, reads both regs.
        assert!(!i.is_fusible_branch());
        let reads = i.reads();
        assert_eq!(reads.len(), 2);
        assert!(reads.iter().all(|r| r.name != "flags"));
        assert_eq!(i.operands[2], Operand::Label(".L2".into()));
        assert!(i.dest().is_none());
        let j = rv("j .L5");
        assert!(j.is_branch());
        assert!(!j.is_cond_branch());
        assert!(!j.is_fusible_branch());
    }

    #[test]
    fn riscv_zero_register_and_idioms() {
        let i = rv("addi zero, a0, 1");
        assert!(i.writes().is_empty());
        let i = rv("xor a3, a3, a3");
        assert!(i.is_zero_idiom());
        assert!(!rv("xor a3, a3, a4").is_zero_idiom());
        assert!(rv("mv a0, a1").is_reg_move());
        assert!(rv("fmv.d fa0, fa1").is_reg_move());
        // Cross-file transfers are spelled differently and never match.
        assert!(!rv("fmv.d.x fa0, a1").is_reg_move());
    }

    #[test]
    fn riscv_immediates_are_bare_and_comments_are_hash() {
        let i = rv("addi a5, a5, 8");
        assert_eq!(i.operands[2], Operand::Imm(8));
        assert_eq!(i.form().to_string(), "addi-x_x_imm");
        assert!(!i.writes_flags());
        let syn = RiscVSyntax;
        assert_eq!(syn.strip_comment("addi a5, a5, 8 # bump"), "addi a5, a5, 8 ");
        assert_eq!(syn.strip_comment("addi a5, a5, 8"), "addi a5, a5, 8");
    }

    #[test]
    fn riscv_register_shaped_typos_error_not_label() {
        assert!(parse_instruction_riscv("add x32, x0, x1", 1).is_err());
        // Case-folded like register parsing itself: `X32` is the same
        // typo as `x32`, not a label.
        assert!(parse_instruction_riscv("add X32, x0, x1", 1).is_err());
        assert!(parse_instruction_riscv("fadd.d f32, f0, f1", 1).is_err());
        assert!(parse_instruction_riscv("add a9, a0, a1", 1).is_err());
        assert!(parse_instruction_riscv("fadd.d fa9, fa0, fa1", 1).is_err());
        assert!(parse_instruction_riscv("add s12, s0, s1", 1).is_err());
        assert!(parse_instruction_riscv("ld a0, 0(zz9)", 1).is_err());
        assert!(parse_instruction_riscv("ld a0, %lo(sym)(a5)", 1).is_err());
        // Labels that merely start with a register letter still parse.
        let i = rv("bne a4, a5, x2_loop");
        assert_eq!(i.operands[2], Operand::Label("x2_loop".into()));
        let i = rv("j sum_head");
        assert_eq!(i.operands[0], Operand::Label("sum_head".into()));
    }

    #[test]
    fn riscv_display_roundtrip() {
        for src in [
            "fld fa5, 0(a5)",
            "fsd fa5, 0(a3)",
            "ld a0, 8(sp)",
            "fmadd.d fa5, fa5, fa0, fa4",
            "fadd.d fa4, fa4, fa1",
            "fdiv.d fa4, fa0, fa4",
            "addi a5, a5, 8",
            "addiw a4, a4, 1",
            "fcvt.d.w fa5, a4",
            "bne a4, a5, .L2",
            "li t0, 111",
        ] {
            let i = rv(src);
            assert_eq!(i.to_string(), src);
            let re = parse_instruction_riscv(&i.to_string(), 1).unwrap();
            assert_eq!(re, i, "{src}");
        }
    }

    #[test]
    fn bench_emission_hooks_per_isa() {
        // Dest index: x86 last, dest-first first, stores -> mem token.
        assert_eq!(AttSyntax.bench_dest_index("vaddpd", &["xmm", "xmm", "xmm"]), 2);
        assert_eq!(AArch64Syntax.bench_dest_index("fadd", &["d", "d", "d"]), 0);
        assert_eq!(AArch64Syntax.bench_dest_index("str", &["x", "mem"]), 1);
        assert_eq!(RiscVSyntax.bench_dest_index("fadd.d", &["f", "f", "f"]), 0);
        assert_eq!(RiscVSyntax.bench_dest_index("fsd", &["f", "mem"]), 1);
        // Register pools produce parseable spellings.
        assert_eq!(AttSyntax.bench_reg("vaddpd", "xmm", 0).unwrap(), "%xmm0");
        assert_eq!(AArch64Syntax.bench_reg("fadd", "d", 2).unwrap(), "d2");
        assert_eq!(AArch64Syntax.bench_reg("ldr", "q", 0).unwrap(), "q0");
        assert_eq!(AArch64Syntax.bench_reg("fmla", "q", 0).unwrap(), "v0.2d");
        assert_eq!(RiscVSyntax.bench_reg("fadd.d", "f", 3).unwrap(), "f3");
        assert_eq!(RiscVSyntax.bench_reg("add", "x", 0).unwrap(), "t3");
        assert_eq!(RiscVSyntax.bench_reg("add", "x", 13).unwrap(), "s2");
        assert_eq!(RiscVSyntax.bench_reg("add", "x", 16).unwrap(), "s4");
        // Unknown classes are None, not panics.
        assert!(RiscVSyntax.bench_reg("add", "ymm", 0).is_none());
        assert!(AArch64Syntax.bench_reg("add", "r64", 0).is_none());
    }
}
