//! Per-ISA assembly syntax: the [`IsaSyntax`] trait and its AT&T x86
//! ([`AttSyntax`]) and ARMv8 A64 ([`AArch64Syntax`]) implementations.
//!
//! The line-level grammar (labels, `.`-directives, blank lines) is
//! shared across ISAs and lives in [`super::parser`]; what differs per
//! ISA — comment markers, mnemonic prefixes, operand splitting, operand
//! and memory-reference shapes, register names — is behind this trait.
//! Adding a backend is a syntax impl plus a `.mdb` machine model:
//! nothing in the analyzer, simulator or api layers is ISA-specific
//! (DESIGN.md §7).

use crate::isa::operand::{MemRef, Operand};
use crate::isa::register::parse_aarch64_register;
use crate::isa::{Instruction, Isa};

use super::parser::{parse_instruction_att, parse_int, split_operands_delim, ParseError};

/// The syntax of one instruction-set architecture: how to strip
/// comments and how to parse one instruction statement.
pub trait IsaSyntax: Sync {
    /// The ISA this syntax parses.
    fn isa(&self) -> Isa;

    /// Strip the line comment (if any) from a raw source line.
    fn strip_comment<'a>(&self, line: &'a str) -> &'a str;

    /// Parse a single instruction statement (labels and directives are
    /// handled by the shared line parser).
    fn parse_instruction(&self, code: &str, lineno: usize) -> Result<Instruction, ParseError>;
}

/// The syntax implementation for an ISA.
pub fn syntax_for(isa: Isa) -> &'static dyn IsaSyntax {
    match isa {
        Isa::X86 => &AttSyntax,
        Isa::AArch64 => &AArch64Syntax,
    }
}

/// AT&T-syntax x86-64 (`%rax`, `$imm`, `disp(base,index,scale)`).
pub struct AttSyntax;

impl IsaSyntax for AttSyntax {
    fn isa(&self) -> Isa {
        Isa::X86
    }

    fn strip_comment<'a>(&self, line: &'a str) -> &'a str {
        // `#` to end of line (GNU as x86); `/* */` is not emitted by GCC
        // so we ignore it.
        match line.find('#') {
            Some(idx) => &line[..idx],
            None => line,
        }
    }

    fn parse_instruction(&self, code: &str, lineno: usize) -> Result<Instruction, ParseError> {
        parse_instruction_att(code, lineno)
    }
}

/// ARMv8 AArch64 GNU-as syntax (`x0`, `#imm`, `[base, index, lsl #s]`).
pub struct AArch64Syntax;

impl IsaSyntax for AArch64Syntax {
    fn isa(&self) -> Isa {
        Isa::AArch64
    }

    fn strip_comment<'a>(&self, line: &'a str) -> &'a str {
        // `//` to end of line. `#` starts immediates on AArch64 and MUST
        // NOT be treated as a comment marker (the classic porting trap
        // when generalizing an x86 parser).
        match line.find("//") {
            Some(idx) => &line[..idx],
            None => line,
        }
    }

    fn parse_instruction(&self, code: &str, lineno: usize) -> Result<Instruction, ParseError> {
        parse_instruction_a64(code, lineno)
    }
}

fn err(line: usize, text: &str, message: impl Into<String>) -> ParseError {
    ParseError { line, text: text.to_string(), message: message.into() }
}

/// Parse one A64 instruction like `fmla v0.2d, v1.2d, v2.2d` or
/// `ldr q0, [x7, x4]`.
pub(crate) fn parse_instruction_a64(
    code: &str,
    lineno: usize,
) -> Result<Instruction, ParseError> {
    let code = code.trim();
    let (mnemonic, rest) = match code.split_once(char::is_whitespace) {
        Some((m, r)) => (m, r.trim()),
        None => (code, ""),
    };
    if mnemonic.is_empty() {
        return Err(err(lineno, code, "empty instruction"));
    }
    let mnemonic = if mnemonic.bytes().any(|b| b.is_ascii_uppercase()) {
        mnemonic.to_ascii_lowercase()
    } else {
        mnemonic.to_string()
    };
    // Multi-register transfers write (or read) more than one data
    // register; the single-destination model would silently drop the
    // second register's write — reject them like writeback forms until
    // they are modeled.
    if matches!(
        mnemonic.as_str(),
        "ldp" | "stp" | "ldnp" | "stnp" | "ld1" | "ld2" | "ld3" | "ld4" | "st1" | "st2"
            | "st3" | "st4"
    ) {
        return Err(err(lineno, code, format!("multi-register form `{mnemonic}` not supported")));
    }
    let operands = if rest.is_empty() {
        Vec::new()
    } else {
        split_operands_delim(rest, '[', ']')
            .into_iter()
            .map(|o| parse_operand_a64(o.trim(), lineno, code))
            .collect::<Result<Vec<_>, _>>()?
    };
    // Post-index writeback (`ldr x0, [x1], #8`) splits into a memory
    // operand followed by an immediate; like pre-index it mutates the
    // base register, which the dependency model does not represent —
    // reject it rather than silently dropping the base-register write.
    if (mnemonic.starts_with("ld") || mnemonic.starts_with("st"))
        && operands
            .iter()
            .position(|o| o.is_mem())
            .is_some_and(|i| i + 1 != operands.len())
    {
        return Err(err(lineno, code, "post-index writeback not supported"));
    }
    Ok(Instruction { mnemonic, operands, line: lineno, isa: Isa::AArch64, prefix: None })
}

fn parse_operand_a64(s: &str, lineno: usize, ctx: &str) -> Result<Operand, ParseError> {
    if s.is_empty() {
        return Err(err(lineno, ctx, "empty operand"));
    }
    // Immediate: #16, #-8, #0x1f.
    if let Some(imm) = s.strip_prefix('#') {
        let v = parse_int(imm).ok_or_else(|| err(lineno, ctx, format!("bad immediate `{s}`")))?;
        return Ok(Operand::Imm(v));
    }
    // Memory reference: [base], [base, #disp], [base, index{, lsl #s}].
    if s.starts_with('[') {
        return parse_memref_a64(s, lineno, ctx).map(Operand::Mem);
    }
    if let Some(r) = parse_aarch64_register(s) {
        return Ok(Operand::Reg(r));
    }
    // GAS accepts bare immediates without the `#` sigil.
    if let Some(v) = parse_int(s) {
        return Ok(Operand::Imm(v));
    }
    // Shifted/extended data operands (`add x2, x1, x3, lsl #3`) are not
    // modeled — reject them at the source line like the memref extends,
    // instead of surfacing later as a bogus `...-lbl` database miss.
    let head = s.split([' ', '\t', '#']).next().unwrap_or(s);
    if matches!(
        head,
        "lsl" | "lsr" | "asr" | "ror" | "sxtb" | "sxth" | "sxtw" | "sxtx" | "uxtb" | "uxth"
            | "uxtw" | "uxtx"
    ) {
        return Err(err(lineno, ctx, format!("shifted/extended operand `{s}` not supported")));
    }
    // Register-shaped tokens that failed to parse (`x31`, `d33`,
    // `v0.3d`, unsupported `h0`/`b1` scalar views) are typos or
    // unmodeled names, not labels — error at the source line instead
    // of surfacing later as a bogus `...-lbl` database miss. The whole
    // tail must be numeric (plus an optional `.arr` part) so labels
    // that merely start with a register letter (`x2_loop`) still parse.
    let looks_like_register = matches!(
        s.chars().next(),
        Some('x' | 'w' | 'q' | 'd' | 's' | 'v' | 'h' | 'b')
    ) && {
        // Letter + digits, with any dotted tail: unsupported
        // arrangements and lane references (`v2.d[0]`) error here too,
        // instead of parsing as labels.
        let num = match s[1..].split_once('.') {
            Some((n, _)) => n,
            None => &s[1..],
        };
        !num.is_empty() && num.bytes().all(|b| b.is_ascii_digit())
    };
    if looks_like_register {
        return Err(err(lineno, ctx, format!("unknown register `{s}`")));
    }
    // Branch target label.
    Ok(Operand::Label(s.to_string()))
}

fn parse_memref_a64(s: &str, lineno: usize, ctx: &str) -> Result<MemRef, ParseError> {
    if s.ends_with('!') {
        return Err(err(lineno, ctx, format!("pre-index writeback not supported in `{s}`")));
    }
    let inner = s
        .strip_prefix('[')
        .and_then(|r| r.strip_suffix(']'))
        .ok_or_else(|| err(lineno, ctx, format!("malformed memory operand `{s}`")))?;
    let parts: Vec<&str> = inner.split(',').map(str::trim).collect();
    let base_name = parts
        .first()
        .copied()
        .filter(|p| !p.is_empty())
        .ok_or_else(|| err(lineno, ctx, format!("memory operand `{s}` has no base")))?;
    let base = parse_aarch64_register(base_name)
        .ok_or_else(|| err(lineno, ctx, format!("unknown register `{base_name}`")))?;
    let mut mem = MemRef {
        displacement: 0,
        base: Some(base),
        index: None,
        scale: 1,
        segment: None,
        symbol: None,
    };
    if parts.len() == 1 {
        return Ok(mem);
    }
    let second = parts[1];
    if let Some(imm) = second.strip_prefix('#').map_or_else(|| parse_int(second), parse_int) {
        // [base, #disp] — no further components allowed.
        if parts.len() > 2 {
            return Err(err(lineno, ctx, format!("malformed memory operand `{s}`")));
        }
        mem.displacement = imm;
        return Ok(mem);
    }
    let index = parse_aarch64_register(second)
        .ok_or_else(|| err(lineno, ctx, format!("unknown register `{second}`")))?;
    mem.index = Some(index);
    match parts.get(2) {
        None => {}
        Some(ext) => {
            // Only `lsl #shift` extends are modeled (enough for the
            // GCC-emitted array-indexing idioms).
            let shift = ext
                .strip_prefix("lsl")
                .map(str::trim)
                .and_then(|r| r.strip_prefix('#'))
                .and_then(parse_int)
                .filter(|v| (0..=4).contains(v))
                .ok_or_else(|| err(lineno, ctx, format!("unsupported extend `{ext}` in `{s}`")))?;
            mem.scale = 1u8 << (shift as u32);
        }
    }
    if parts.len() > 3 {
        return Err(err(lineno, ctx, format!("malformed memory operand `{s}`")));
    }
    Ok(mem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::RegisterClass;

    fn ins(s: &str) -> Instruction {
        parse_instruction_a64(s, 1).expect(s)
    }

    #[test]
    fn parses_fmla() {
        let i = ins("fmla v0.2d, v1.2d, v2.2d");
        assert_eq!(i.mnemonic, "fmla");
        assert_eq!(i.operands.len(), 3);
        assert_eq!(i.form().to_string(), "fmla-q_q_q");
        assert_eq!(i.isa, Isa::AArch64);
    }

    #[test]
    fn parses_load_with_index() {
        let i = ins("ldr q0, [x7, x4]");
        let m = i.operands[1].mem().unwrap();
        assert_eq!(m.base.unwrap().name, "x7");
        assert_eq!(m.index.unwrap().name, "x4");
        assert_eq!(m.scale, 1);
        assert!(i.is_load());
        assert!(!i.is_store());
    }

    #[test]
    fn parses_scaled_index_and_displacement() {
        let i = ins("ldr d0, [x2, x5, lsl #3]");
        let m = i.operands[1].mem().unwrap();
        assert_eq!(m.scale, 8);
        let i = ins("str x0, [sp, #16]");
        let m = i.operands[1].mem().unwrap();
        assert_eq!(m.displacement, 16);
        assert_eq!(m.base.unwrap().name, "sp");
        assert!(i.is_store());
        assert!(!i.is_load());
    }

    #[test]
    fn store_dest_is_memory_and_data_is_read() {
        let i = ins("str q0, [x9, x4]");
        assert!(matches!(i.dest(), Some(Operand::Mem(_))));
        let reads = i.reads();
        assert!(reads.iter().any(|r| r.name == "q0"));
        assert!(reads.iter().any(|r| r.name == "x9"));
        assert!(reads.iter().any(|r| r.name == "x4"));
        assert!(i.writes().is_empty());
    }

    #[test]
    fn dest_first_semantics() {
        let i = ins("fadd d0, d1, d2");
        assert_eq!(i.writes().len(), 1);
        assert_eq!(i.writes()[0].name, "d0");
        // fadd does not read its destination...
        assert_eq!(i.reads().len(), 2);
        // ...but fmla does.
        let i = ins("fmla v0.2d, v1.2d, v2.2d");
        assert_eq!(i.reads().len(), 3);
    }

    #[test]
    fn immediates_and_flags() {
        let i = ins("add x4, x4, #16");
        assert_eq!(i.operands[2], Operand::Imm(16));
        assert!(!i.writes_flags());
        let i = ins("subs x5, x5, #2");
        assert!(i.writes_flags());
        assert_eq!(i.form().to_string(), "subs-x_x_imm");
        let i = ins("cmp w4, w5");
        assert!(i.is_compare());
        assert!(i.dest().is_none());
    }

    #[test]
    fn cond_branch_reads_flags() {
        let i = ins("b.ne .L4");
        assert!(i.is_branch());
        assert!(i.is_cond_branch());
        assert!(i.reads().iter().any(|r| r.name == "flags"));
        assert_eq!(i.operands[0], Operand::Label(".L4".into()));
        let i = ins("cbnz x3, .L4");
        assert!(i.is_branch());
        // cbnz reads its register, not the flags.
        assert!(i.reads().iter().any(|r| r.name == "x3"));
        assert!(!i.reads().iter().any(|r| r.name == "flags"));
    }

    #[test]
    fn zero_register_writes_discarded() {
        let i = ins("subs xzr, x5, #2");
        assert!(i.writes().iter().all(|r| r.name == "flags"));
    }

    #[test]
    fn zero_idiom_and_moves() {
        assert!(ins("movi v0.2d, #0").is_zero_idiom());
        assert!(!ins("movi v0.2d, #1").is_zero_idiom());
        assert!(ins("eor v1.16b, v1.16b, v1.16b").is_zero_idiom());
        assert!(!ins("eor v1.16b, v1.16b, v2.16b").is_zero_idiom());
        assert!(ins("mov x0, x1").is_reg_move());
        assert!(ins("fmov d0, d1").is_reg_move());
        assert!(!ins("mov x0, #7").is_reg_move());
    }

    #[test]
    fn scvtf_reads_gp_writes_fp() {
        let i = ins("scvtf d0, w4");
        assert_eq!(i.form().to_string(), "scvtf-d_w");
        assert_eq!(i.reads().len(), 1);
        assert_eq!(i.reads()[0].class, RegisterClass::AGp32);
        assert_eq!(i.writes()[0].class, RegisterClass::AFp64);
    }

    #[test]
    fn writeback_and_bad_extends_error() {
        assert!(parse_instruction_a64("ldr x0, [x1, #8]!", 1).is_err());
        // Post-index writeback mutates the base register: rejected, not
        // silently modeled without the write.
        assert!(parse_instruction_a64("ldr x0, [x1], #8", 1).is_err());
        assert!(parse_instruction_a64("str q0, [x1], #16", 1).is_err());
        assert!(parse_instruction_a64("ldr x0, [x1, w2, sxtw #3]", 1).is_err());
        assert!(parse_instruction_a64("ldr x0, [zz9]", 1).is_err());
    }

    #[test]
    fn shifted_register_operands_rejected() {
        assert!(parse_instruction_a64("add x2, x1, x3, lsl #3", 1).is_err());
        assert!(parse_instruction_a64("add x2, x1, w3, sxtw", 1).is_err());
    }

    #[test]
    fn multi_register_forms_rejected() {
        // Pair/structure transfers have a second data register the
        // single-dest model would silently drop.
        assert!(parse_instruction_a64("ldp x0, x1, [x2]", 1).is_err());
        assert!(parse_instruction_a64("stp x0, x1, [sp, #16]", 1).is_err());
        assert!(parse_instruction_a64("ld1 {v0.2d}, [x0]", 1).is_err());
    }

    #[test]
    fn register_shaped_typos_error_not_label() {
        assert!(parse_instruction_a64("fadd d0, d1, d33", 1).is_err());
        assert!(parse_instruction_a64("add x31, x0, #1", 1).is_err());
        assert!(parse_instruction_a64("fadd v0.3d, v1.3d, v2.3d", 1).is_err());
        assert!(parse_instruction_a64("ldr h0, [x0]", 1).is_err());
        // Lane references are register-shaped too: error, not label.
        assert!(parse_instruction_a64("fmla v0.2d, v1.2d, v2.d[0]", 1).is_err());
        // Real labels still parse — including ones that merely start
        // with a register letter.
        let i = parse_instruction_a64("b.ne .L4", 1).unwrap();
        assert_eq!(i.operands[0], Operand::Label(".L4".into()));
        let i = parse_instruction_a64("cbnz x5, x2_loop", 1).unwrap();
        assert_eq!(i.operands[1], Operand::Label("x2_loop".into()));
        // The frame-pointer alias is a real register.
        let i = parse_instruction_a64("add fp, sp, #16", 1).unwrap();
        assert_eq!(i.form().to_string(), "add-x_x_imm");
    }

    #[test]
    fn comment_stripping_keeps_immediates() {
        let syn = AArch64Syntax;
        assert_eq!(syn.strip_comment("add x4, x4, #16 // bump"), "add x4, x4, #16 ");
        assert_eq!(syn.strip_comment("add x4, x4, #16"), "add x4, x4, #16");
    }

    #[test]
    fn display_roundtrip() {
        for src in [
            "ldr q0, [x7, x4]",
            "ldr d0, [x2, x5, lsl #3]",
            "str x0, [sp, #16]",
            "fmla v0.2d, v1.2d, v2.2d",
            "add x4, x4, #16",
            "subs x5, x5, #2",
            "b.ne .L4",
            "scvtf d0, w4",
            "ldr x0, [x1]",
        ] {
            let i = ins(src);
            assert_eq!(i.to_string(), src);
            let re = parse_instruction_a64(&i.to_string(), 1).unwrap();
            assert_eq!(re, i, "{src}");
        }
    }
}
