//! IACA/OSACA kernel markers (paper §III).
//!
//! OSACA supports the same byte markers as IACA on x86:
//!
//! ```asm
//! movl $111, %ebx        # start marker
//! .byte 100,103,144
//! ...kernel...
//! movl $222, %ebx        # end marker
//! .byte 100,103,144
//! ```
//!
//! The `.byte 100,103,144` sequence encodes `fs addr32 nop`, a no-op the
//! processor executes but IACA's disassembler recognizes. On AArch64 the
//! marker is `mov x1, #111` / `mov x1, #222` followed by
//! `.byte 213,3,32,31` (a `nop` encoding), matching OSACA's ARM support.
//! On RISC-V the analogous convention is `li t0, 111` / `li t0, 222`
//! followed by `.byte 19,0,0,0` (the little-endian encoding of
//! `addi x0, x0, 0`, the canonical RV nop). We detect the mov/li +
//! `.byte` pairs in parsed lines; the marker shape is keyed by the
//! instruction's own ISA.

use crate::isa::operand::Operand;
use crate::isa::Isa;

use super::parser::Line;

pub const START_MARKER_IMM: i64 = 111;
pub const END_MARKER_IMM: i64 = 222;
pub const MARKER_BYTES: &str = "100,103,144";
pub const AARCH64_MARKER_BYTES: &str = "213,3,32,31";
pub const RISCV_MARKER_BYTES: &str = "19,0,0,0";

/// Location of the marked region: indices into the parsed `Line` slice,
/// exclusive of the marker instructions themselves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MarkedRegion {
    pub start: usize,
    pub end: usize,
}

fn is_marker_mov(line: &Line, imm: i64) -> bool {
    match line {
        Line::Instruction(i) => match i.isa {
            Isa::X86 => {
                i.mnemonic == "movl"
                    && i.operands.len() == 2
                    && i.operands[0] == Operand::Imm(imm)
                    && matches!(&i.operands[1], Operand::Reg(r) if r.name == "ebx")
            }
            Isa::AArch64 => {
                i.mnemonic == "mov"
                    && i.operands.len() == 2
                    && matches!(&i.operands[0], Operand::Reg(r) if r.name == "x1")
                    && i.operands[1] == Operand::Imm(imm)
            }
            Isa::RiscV => {
                // `li t0, 111` — accept the raw `x5` spelling too (the
                // slot, not the name, identifies the register).
                i.mnemonic == "li"
                    && i.operands.len() == 2
                    && matches!(&i.operands[0], Operand::Reg(r) if r.slot == 5
                        && r.class == crate::isa::RegisterClass::RGp64)
                    && i.operands[1] == Operand::Imm(imm)
            }
        },
        _ => false,
    }
}

fn is_marker_bytes(line: &Line) -> bool {
    match line {
        Line::Directive { name, args } => {
            let compact = args.replace(' ', "");
            name == "byte"
                && (compact == MARKER_BYTES
                    || compact == AARCH64_MARKER_BYTES
                    || compact == RISCV_MARKER_BYTES)
        }
        _ => false,
    }
}

/// Find the IACA/OSACA-marked region. Returns `None` when either marker is
/// missing or malformed (mov without the byte sequence).
pub fn find_marked_region(lines: &[Line]) -> Option<MarkedRegion> {
    let mut start = None;
    let mut end = None;
    let mut i = 0;
    while i < lines.len() {
        if is_marker_mov(&lines[i], START_MARKER_IMM) {
            // The byte directive must follow (possibly after blank lines).
            let mut j = i + 1;
            while j < lines.len() && matches!(lines[j], Line::Empty) {
                j += 1;
            }
            if j < lines.len() && is_marker_bytes(&lines[j]) {
                start = Some(j + 1);
                i = j + 1;
                continue;
            }
        }
        if is_marker_mov(&lines[i], END_MARKER_IMM) && start.is_some() && end.is_none() {
            end = Some(i);
        }
        i += 1;
    }
    match (start, end) {
        (Some(s), Some(e)) if e >= s => Some(MarkedRegion { start: s, end: e }),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::parser::parse_file;

    const MARKED: &str = r#"
movl $111, %ebx
.byte 100,103,144
.L10:
vmovapd (%r15,%rax), %ymm0
ja .L10
movl $222, %ebx
.byte 100,103,144
"#;

    #[test]
    fn finds_region() {
        let lines = parse_file(MARKED).unwrap();
        let r = find_marked_region(&lines).unwrap();
        // Region spans label + 2 instructions.
        let body = &lines[r.start..r.end];
        let n_instr = body
            .iter()
            .filter(|l| matches!(l, Line::Instruction(_)))
            .count();
        assert_eq!(n_instr, 2);
    }

    #[test]
    fn missing_end_marker_is_none() {
        let src = "movl $111, %ebx\n.byte 100,103,144\naddl $1, %eax\n";
        let lines = parse_file(src).unwrap();
        assert!(find_marked_region(&lines).is_none());
    }

    #[test]
    fn mov_without_bytes_is_not_a_marker() {
        let src = "movl $111, %ebx\naddl $1, %eax\nmovl $222, %ebx\n.byte 100,103,144\n";
        let lines = parse_file(src).unwrap();
        assert!(find_marked_region(&lines).is_none());
    }

    #[test]
    fn spaced_byte_args_accepted() {
        let src = "movl $111, %ebx\n.byte 100, 103, 144\nnop\nmovl $222, %ebx\n.byte 100,103,144\n";
        let lines = parse_file(src).unwrap();
        assert!(find_marked_region(&lines).is_some());
    }

    #[test]
    fn riscv_markers_found() {
        use crate::asm::parser::parse_file_isa;
        use crate::isa::Isa;
        let src = "li t0, 111\n.byte 19,0,0,0\n.L3:\nfld fa5, 0(a5)\nbne a4, a5, .L3\nli t0, 222\n.byte 19,0,0,0\n";
        let lines = parse_file_isa(src, Isa::RiscV).unwrap();
        let r = find_marked_region(&lines).unwrap();
        let n_instr = lines[r.start..r.end]
            .iter()
            .filter(|l| matches!(l, Line::Instruction(_)))
            .count();
        assert_eq!(n_instr, 2);
        // The raw x5 spelling is the same marker register.
        let src2 = src.replace("li t0,", "li x5,");
        let lines2 = parse_file_isa(&src2, Isa::RiscV).unwrap();
        assert!(find_marked_region(&lines2).is_some());
    }

    #[test]
    fn aarch64_markers_found() {
        use crate::asm::parser::parse_file_isa;
        use crate::isa::Isa;
        let src = "mov x1, #111\n.byte 213,3,32,31\n.L4:\nldr q0, [x7, x4]\nb.ne .L4\nmov x1, #222\n.byte 213,3,32,31\n";
        let lines = parse_file_isa(src, Isa::AArch64).unwrap();
        let r = find_marked_region(&lines).unwrap();
        let n_instr = lines[r.start..r.end]
            .iter()
            .filter(|l| matches!(l, Line::Instruction(_)))
            .count();
        assert_eq!(n_instr, 2);
    }
}
