//! Semi-automatic model construction (paper §II-B/§II-C).
//!
//! Rebuilds database entries for instruction forms by benchmarking them
//! on the simulator substrate (the "hardware"):
//!
//! * **latency** from the chained ibench loop (§II-A);
//! * **reciprocal throughput** from the fully independent TP loop;
//! * **port assignment** from a *differential* port-busy measurement:
//!   the TP loop runs at two widths and each port's busy-cycle increase
//!   is attributed to the benchmarked form — the loop overhead
//!   contributes identically at both widths and cancels out. This is
//!   the simulator-substrate analog of reading per-port µ-op PMU
//!   counters (`UOPS_DISPATCHED_PORT.*`) on real hardware;
//! * **conflict probes** (§II-B narrative): the form interleaved 1:1
//!   with representative probes of each port class — a combined
//!   reciprocal throughput above the form's own reveals port sharing.
//!
//! Load/store/divider µ-ops are classified by the machine's declared
//! pipe roles; the remaining busy ports form the compute µ-op.

use anyhow::{bail, Result};

use crate::asm::{extract_kernel_isa, Kernel};
use crate::ibench::{latency_loop, run_conflict, throughput_loop, BenchSpec};
use crate::isa::{InstructionForm, Isa};
use crate::mdb::{FormEntry, MachineModel, PortMask, Uop, UopKind};
use crate::sim::{simulate, SimConfig};

/// An inferred database entry plus the raw measurements behind it.
#[derive(Debug, Clone)]
pub struct Inference {
    /// The deduced entry, insertable into a [`MachineModel`].
    pub entry: FormEntry,
    /// Chained-loop latency (cycles).
    pub measured_latency: f64,
    /// TP-loop reciprocal throughput (cycles per instruction).
    pub measured_rtp: f64,
    /// Probe forms whose interleaved run degraded the form's
    /// throughput (paper §II-C: "vmulpd cannot be hidden behind FMA").
    pub conflicting_probes: Vec<String>,
}

/// One row of a model re-derivation report.
#[derive(Debug, Clone)]
pub struct ValidationRow {
    pub form: String,
    pub db_latency: f64,
    pub inferred_latency: f64,
    pub db_rtp: f64,
    pub inferred_rtp: f64,
    /// Inferred compute-port set equals the database entry's.
    pub ports_match: bool,
}

impl ValidationRow {
    /// Within the paper's measurement tolerances.
    pub fn ok(&self) -> bool {
        (self.db_latency - self.inferred_latency).abs() < 0.4
            && (self.db_rtp - self.inferred_rtp).abs() < 0.15
            && self.ports_match
    }
}

/// The standard probe set (§II-B): one representative per port class —
/// FP add, FP mul, vector int, scalar int (or the nearest equivalents
/// the target ISA offers). Probes without a database entry on `machine`
/// are dropped (they could not be co-scheduled).
pub fn default_probes(machine: &MachineModel) -> Vec<BenchSpec> {
    let probes: &[&str] = match machine.isa {
        Isa::X86 => {
            &["vaddpd-xmm_xmm_xmm", "vmulpd-xmm_xmm_xmm", "vpaddd-xmm_xmm_xmm", "add-imm_r"]
        }
        Isa::AArch64 => &["fadd-d_d_d", "fmul-d_d_d", "fadd-q_q_q", "add-x_x_imm"],
        Isa::RiscV => &["fadd.d-f_f_f", "fmul.d-f_f_f", "add-x_x_x", "addi-x_x_imm"],
    };
    probes
        .iter()
        .map(|s| BenchSpec::parse(s))
        .filter(|spec| machine.entries.contains_key(&spec.form))
        .collect()
}

/// TP-benchmark one form at `width` independent instances: returns
/// cycles/instruction and per-port busy cycles per loop iteration.
fn tp_profile(spec: &BenchSpec, machine: &MachineModel, width: usize) -> Result<(f64, Vec<f64>)> {
    let src = throughput_loop(spec, machine.isa, width)?;
    let kernel = extract_kernel_isa("tp-profile", &src, machine.isa)?;
    let m = simulate(&kernel, machine, SimConfig { iterations: 400, warmup: 100 })?;
    let busy: Vec<f64> =
        m.port_busy.iter().map(|&b| b as f64 / m.iterations as f64).collect();
    Ok((m.cycles_per_iteration / width as f64, busy))
}

/// Chained-loop latency (§II-A): cycles per chained instance.
fn latency_of(spec: &BenchSpec, machine: &MachineModel) -> Result<f64> {
    let unroll = 4;
    let src = latency_loop(spec, machine.isa, unroll)?;
    let kernel = extract_kernel_isa("lat-profile", &src, machine.isa)?;
    let m = simulate(&kernel, machine, SimConfig { iterations: 400, warmup: 100 })?;
    Ok(m.cycles_per_iteration / unroll as f64)
}

/// Minimum per-port busy increase (cycles/iteration between the two TP
/// widths) for a port to count as admissible. The form adds
/// `(W2-W1)/n_ports >= 8/4 = 2` cycles to each of its ports; scheduling
/// noise from the constant loop overhead stays well under this.
const PORT_ATTRIBUTION_THRESHOLD: f64 = 1.5;
const WIDTH_SMALL: usize = 4;
const WIDTH_LARGE: usize = 12;

/// Benchmark `form` on `machine` (the hardware substrate) and deduce a
/// database entry: latency, rTP, and the µ-op decomposition with port
/// assignment (§II-C, mechanized).
pub fn infer_entry(
    form: &InstructionForm,
    machine: &MachineModel,
    probes: &[BenchSpec],
) -> Result<Inference> {
    // The loop generator goes through the machine's `IsaSyntax`
    // (register pools, operand spellings, loop scaffold), so this works
    // for every backend — the historical x86-only bail is gone.
    let spec = BenchSpec { form: form.clone() };
    let measured_latency = latency_of(&spec, machine)?;
    let (rtp, busy_large) = tp_profile(&spec, machine, WIDTH_LARGE)?;
    let (_, busy_small) = tp_profile(&spec, machine, WIDTH_SMALL)?;
    let added = (WIDTH_LARGE - WIDTH_SMALL) as f64;

    let sig = &form.sig.0;
    let tokens: Vec<&str> = if sig.is_empty() { Vec::new() } else { sig.split('_').collect() };
    // A form is a store iff the *destination* operand is the memory one.
    // Position alone cannot decide this across ISAs: x86 stores carry
    // `mem` last, but so do dest-first loads (`ldr-x_mem` vs
    // `str-x_mem`) — ask the ISA's syntax where the destination sits.
    let dest_pos = crate::asm::syntax_for(machine.isa).bench_dest_index(&form.mnemonic, &tokens);
    let is_store = tokens.get(dest_pos).copied() == Some("mem");
    let has_load = tokens
        .iter()
        .enumerate()
        .any(|(i, t)| *t == "mem" && (!is_store || i != dest_pos));

    let divider = machine.divider_ports();
    let mut compute = PortMask::EMPTY;
    let mut divider_hit = PortMask::EMPTY;
    let mut divider_occ = 0f64;
    for p in 0..machine.n_ports() {
        let diff = busy_large[p] - busy_small[p];
        if diff < PORT_ATTRIBUTION_THRESHOLD {
            continue;
        }
        if divider.contains(p) {
            divider_hit = divider_hit.union(PortMask::single(p));
            divider_occ = divider_occ.max(diff / added);
        } else if has_load && machine.load_ports.contains(p) {
            // Attributed to the load µ-op, not the compute µ-op.
        } else if is_store
            && (machine.store_data_ports.contains(p)
                || machine.store_agu_ports.contains(p)
                || machine.store_agu_simple_ports.contains(p))
        {
            // Attributed to the store µ-ops.
        } else {
            compute = compute.union(PortMask::single(p));
        }
    }
    if compute.is_empty() && divider_hit.is_empty() && !has_load && !is_store {
        bail!("no port signal for `{form}` on {} (eliminated at rename?)", machine.name);
    }

    // Conflict probes: §II-B. Purely diagnostic output — the port sets
    // above come from the counter differential.
    let mut conflicting_probes = Vec::new();
    for probe in probes {
        if probe.form == *form {
            continue;
        }
        let r = run_conflict(&spec, probe, machine)?;
        if r.cy_per_instr > rtp * 1.4 + 0.02 {
            conflicting_probes.push(probe.form.to_string());
        }
    }

    let mut uops = Vec::new();
    if !compute.is_empty() {
        let occupancy = if divider_hit.is_empty() {
            ((rtp * compute.count() as f64).round() as f32).max(1.0)
        } else {
            1.0
        };
        uops.push(Uop { kind: UopKind::Compute, ports: compute, occupancy });
    }
    if !divider_hit.is_empty() {
        uops.push(Uop {
            kind: UopKind::Divider,
            ports: divider_hit,
            occupancy: (divider_occ.round() as f32).max(1.0),
        });
    }
    if has_load {
        uops.push(Uop { kind: UopKind::Load, ports: machine.load_ports, occupancy: 1.0 });
    }
    if is_store {
        uops.push(Uop {
            kind: UopKind::StoreData,
            ports: machine.store_data_ports,
            occupancy: 1.0,
        });
        let agu = if machine.store_agu_simple_ports.is_empty() {
            machine.store_agu_ports
        } else {
            machine.store_agu_simple_ports
        };
        uops.push(Uop { kind: UopKind::StoreAgu, ports: agu, occupancy: 1.0 });
    }

    let entry = FormEntry {
        form: form.clone(),
        // Half-cycle resolution, like the paper's published tables.
        latency: ((measured_latency * 2.0).round() / 2.0) as f32,
        throughput: ((rtp * 100.0).round() / 100.0) as f32,
        uops,
    };
    Ok(Inference { entry, measured_latency, measured_rtp: rtp, conflicting_probes })
}

/// Union of the compute-µ-op ports of an entry.
fn compute_ports(entry: &FormEntry) -> PortMask {
    entry
        .uops
        .iter()
        .filter(|u| u.kind == UopKind::Compute)
        .fold(PortMask::EMPTY, |m, u| m.union(u.ports))
}

/// Re-derive `forms` from benchmarks and compare against the shipped
/// database (§II-C validation workflow).
pub fn validate_model(
    machine: &MachineModel,
    forms: &[InstructionForm],
) -> Result<Vec<ValidationRow>> {
    let probes = default_probes(machine);
    let mut rows = Vec::new();
    for form in forms {
        let Some(db) = machine.entries.get(form) else {
            bail!("`{form}` is not in the {} database", machine.name);
        };
        let inf = infer_entry(form, machine, &probes)?;
        rows.push(ValidationRow {
            form: form.to_string(),
            db_latency: db.latency as f64,
            inferred_latency: inf.measured_latency,
            db_rtp: db.implied_rtp() as f64,
            inferred_rtp: inf.measured_rtp,
            ports_match: compute_ports(db) == compute_ports(&inf.entry),
        });
    }
    Ok(rows)
}

/// §III "--learn" workflow: benchmark every form of `kernel` that
/// `model` cannot resolve on the `hardware` substrate and insert the
/// inferred entries into `model`. Returns the inferences made.
pub fn learn_missing(
    kernel: &Kernel,
    model: &mut MachineModel,
    hardware: &MachineModel,
) -> Result<Vec<Inference>> {
    let probes = default_probes(hardware);
    let mut learned: Vec<Inference> = Vec::new();
    for ins in &kernel.instructions {
        if ins.is_branch() {
            continue;
        }
        if model.resolve(ins).is_ok() {
            continue;
        }
        let form = ins.form();
        if learned.iter().any(|i| i.entry.form == form) {
            continue;
        }
        let inf = infer_entry(&form, hardware, &probes)?;
        model.insert(inf.entry.clone());
        learned.push(inf);
    }
    Ok(learned)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mdb::{rv64, skylake, thunderx2, zen};

    #[test]
    fn probes_exist_in_both_databases() {
        for m in [skylake(), zen()] {
            assert_eq!(default_probes(&m).len(), 4, "{}", m.name);
        }
    }

    #[test]
    fn probes_exist_in_every_builtin_database() {
        // The probe set is ISA-aware: every built-in model keeps a full
        // probe complement so `--learn` conflict analysis works on all.
        for m in [skylake(), zen(), crate::mdb::haswell(), thunderx2(), rv64()] {
            assert_eq!(default_probes(&m).len(), 4, "{}", m.name);
        }
    }

    /// The ISSUE-4 satellite: `--learn` on a **non-x86** model produces
    /// a well-formed `.mdb` stanza — the historical "model construction
    /// is x86-only" bail is gone and must stay gone.
    #[test]
    fn learn_missing_produces_mdb_stanza_on_non_x86() {
        // AArch64 substrate.
        let hardware = thunderx2();
        let mut model = hardware.clone();
        let form = InstructionForm::parse("fmul-d_d_d");
        model.entries.remove(&form);
        let w = crate::workloads::find("pi", "tx2", "-O1").unwrap();
        let learned = learn_missing(&w.kernel(), &mut model, &hardware).unwrap();
        assert_eq!(learned.len(), 1, "{learned:?}");
        assert_eq!(learned[0].entry.form, form);
        // The learned entry round-trips through the `.mdb` text format.
        let text = model.serialize();
        assert!(text.contains("entry fmul-d_d_d"), "{text}");
        let reparsed = MachineModel::parse(&text).unwrap();
        assert!(reparsed.entries.contains_key(&form));
        assert!(crate::analyzer::analyze(&w.kernel(), &model).is_ok());

        // RISC-V substrate, same workflow.
        let hardware = rv64();
        let mut model = hardware.clone();
        let form = InstructionForm::parse("fmul.d-f_f_f");
        model.entries.remove(&form);
        let w = crate::workloads::find("pi", "rv64", "-O1").unwrap();
        let learned = learn_missing(&w.kernel(), &mut model, &hardware).unwrap();
        assert_eq!(learned.len(), 1, "{learned:?}");
        let inf = &learned[0];
        assert!((inf.measured_latency - 5.0).abs() < 0.3, "{}", inf.measured_latency);
        // Single F pipe -> rTP 1.0 and a one-port compute µ-op.
        assert!((inf.measured_rtp - 1.0).abs() < 0.15, "{}", inf.measured_rtp);
        let c = inf.entry.uops.iter().find(|u| u.kind == UopKind::Compute).unwrap();
        assert_eq!(c.ports.count(), 1);
        let text = model.serialize();
        assert!(text.contains("entry fmul.d-f_f_f"), "{text}");
        assert!(MachineModel::parse(&text).unwrap().entries.contains_key(&form));
        assert!(crate::analyzer::analyze(&w.kernel(), &model).is_ok());
    }

    #[test]
    fn infer_vaddpd_skylake() {
        let m = skylake();
        let probes = default_probes(&m);
        let form = InstructionForm::parse("vaddpd-xmm_xmm_xmm");
        let inf = infer_entry(&form, &m, &probes).unwrap();
        assert!((inf.measured_latency - 4.0).abs() < 0.3, "{}", inf.measured_latency);
        assert!((inf.measured_rtp - 0.5).abs() < 0.1, "{}", inf.measured_rtp);
        let db = &m.entries[&form];
        assert_eq!(compute_ports(&inf.entry), compute_ports(db));
    }

    #[test]
    fn learn_missing_fills_stripped_model() {
        let hardware = skylake();
        let mut model = hardware.clone();
        let form = InstructionForm::parse("vmulpd-xmm_xmm_xmm");
        model.entries.remove(&form);
        let w = crate::workloads::find("triad", "skl", "-O2").unwrap();
        // The -O2 triad resolves fully; strip mulsd's base form too so
        // learning has something to do.
        let mul_scalar = InstructionForm::parse("vmulsd-xmm_xmm_xmm");
        let mul_mem = InstructionForm::parse("vmulsd-mem_xmm_xmm");
        model.entries.remove(&mul_scalar);
        model.entries.remove(&mul_mem);
        let learned = learn_missing(&w.kernel(), &mut model, &hardware).unwrap();
        assert_eq!(learned.len(), 1, "{learned:?}");
        assert!(model.entries.contains_key(&mul_mem));
        // The re-learned model analyzes the kernel again.
        assert!(crate::analyzer::analyze(&w.kernel(), &model).is_ok());
    }
}
