//! The structured prediction: throughput as a decomposition into named
//! resource bounds.
//!
//! The paper's core claim is that the reciprocal throughput of a kernel
//! is the *maximum over resource bounds* — port pressure, divider
//! occupancy, dependency chains — yet a flat cycle number cannot say
//! *which* resource won. [`Prediction`] makes that queryable: every
//! pass contributes [`Bound`]s carrying the kind of resource, the bound
//! it enforces in cycles per assembly iteration, the concrete winning
//! resource (a port name, the rename stage, a dependency chain) and the
//! pass that produced it. Model-derived bounds (port pressure, the
//! opt-in width-aware frontend bound, divider occupancy, critical path)
//! combine by `max` into the analytic prediction; observations (the
//! balanced baseline, the simulator measurement) ride along in the same
//! vocabulary without being folded into it.

use crate::analyzer::LineOccupancy;
use crate::api::AnalysisReport;

/// The resource class a [`Bound`] describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BoundKind {
    /// Uniform-split pressure on the busiest non-divider port.
    PortPressure,
    /// Width-aware frontend bound: `rename slots / rename_width`
    /// (opt-in via `AnalysisRequest::frontend_bound`).
    FrontEnd,
    /// Occupancy of the busiest divider pseudo-pipe (`DV`/`0DV`).
    Divider,
    /// Loop-carried dependency-chain bound (cycles per iteration).
    CriticalPath,
    /// ECM-style memory-hierarchy bound: cycles per cacheline at the
    /// resident level × lines per iteration (opt-in via
    /// `AnalysisRequest::mem_model`).
    Memory,
    /// IACA-like balanced baseline — an alternative predictor, not a
    /// lower bound; reported for comparison only.
    Baseline,
    /// Simulated-hardware throughput — an observation, not a bound.
    Simulated,
}

impl BoundKind {
    /// Stable machine-readable name (used by the JSON/CSV emitters).
    pub fn name(self) -> &'static str {
        match self {
            BoundKind::PortPressure => "port_pressure",
            BoundKind::FrontEnd => "frontend",
            BoundKind::Divider => "divider",
            BoundKind::CriticalPath => "critical_path",
            BoundKind::Memory => "memory",
            BoundKind::Baseline => "baseline",
            BoundKind::Simulated => "simulated",
        }
    }

    /// Does this bound participate in the analytic `max`? Baseline and
    /// simulation are comparisons, not model-derived lower bounds.
    pub fn is_model_bound(self) -> bool {
        !matches!(self, BoundKind::Baseline | BoundKind::Simulated)
    }
}

/// The pass that produced a [`Bound`] (provenance).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PassSource {
    Throughput,
    Critpath,
    Memory,
    Baseline,
    Simulate,
}

impl PassSource {
    /// Stable machine-readable name (used by the JSON/CSV emitters).
    pub fn name(self) -> &'static str {
        match self {
            PassSource::Throughput => "throughput",
            PassSource::Critpath => "critpath",
            PassSource::Memory => "memory",
            PassSource::Baseline => "baseline",
            PassSource::Simulate => "simulate",
        }
    }
}

/// One named resource bound of a [`Prediction`].
#[derive(Debug, Clone, PartialEq)]
pub struct Bound {
    pub kind: BoundKind,
    /// Cycles per assembly iteration this resource alone enforces (for
    /// observations: the value measured/predicted by that pass).
    pub cy_per_asm_iter: f32,
    /// The concrete winning resource: a port name (`"LS"`, `"P3"`),
    /// the rename stage (`"8 slots / 2-wide"`), a divider pipe, or a
    /// chain description.
    pub resource: String,
    /// Which pass computed the bound.
    pub source: PassSource,
}

/// The structured result of an analysis: every resource bound the
/// requested passes produced, in a fixed kind order (port pressure,
/// frontend, divider, critical path, memory, baseline, simulated).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Prediction {
    pub bounds: Vec<Bound>,
    /// Assembly-loop unroll factor (for per-source-iteration values).
    pub unroll: usize,
    /// Per-line port occupancy rows from the throughput pass (empty
    /// when the pass did not run). Absorbed here so the structured
    /// prediction carries the paper's whole table, not only its max —
    /// the last string-only part of the report before schema v2.
    pub lines: Vec<LineOccupancy>,
}

impl Prediction {
    /// The winning model bound: the largest
    /// [`BoundKind::is_model_bound`] entry (first of equals, in kind
    /// order). `None` when no model-bound pass ran.
    pub fn winner(&self) -> Option<&Bound> {
        let mut best: Option<&Bound> = None;
        for b in self.bounds.iter().filter(|b| b.kind.is_model_bound()) {
            if best.map(|w| b.cy_per_asm_iter > w.cy_per_asm_iter).unwrap_or(true) {
                best = Some(b);
            }
        }
        best
    }

    /// The analytic prediction: max over the model bounds, cycles per
    /// assembly iteration.
    pub fn cy_per_asm_iter(&self) -> Option<f32> {
        self.winner().map(|b| b.cy_per_asm_iter)
    }

    /// The analytic prediction per *source* iteration.
    pub fn cy_per_source_it(&self) -> Option<f32> {
        self.cy_per_asm_iter().map(|cy| cy / self.unroll.max(1) as f32)
    }

    /// The bound of one kind, if the producing pass ran.
    pub fn bound(&self, kind: BoundKind) -> Option<&Bound> {
        self.bounds.iter().find(|b| b.kind == kind)
    }

    /// Build the decomposition from a report's pass sections.
    pub(crate) fn from_report(r: &AnalysisReport) -> Prediction {
        let mut bounds = Vec::new();
        let divider = r.machine.divider_ports();
        if let Some(t) = &r.throughput {
            // Busiest non-divider port; "last max" to match the
            // analyzer's bottleneck_port convention on ties.
            let mut port: Option<(usize, f32)> = None;
            let mut div: Option<(usize, f32)> = None;
            for (i, &v) in t.totals.iter().enumerate() {
                let slot = if divider.contains(i) { &mut div } else { &mut port };
                let better = match slot {
                    Some((_, best)) => v >= *best,
                    None => true,
                };
                if better {
                    *slot = Some((i, v));
                }
            }
            if let Some((i, v)) = port {
                bounds.push(Bound {
                    kind: BoundKind::PortPressure,
                    cy_per_asm_iter: v,
                    resource: r.machine.ports[i].clone(),
                    source: PassSource::Throughput,
                });
            }
            if let Some(f) = &t.frontend {
                bounds.push(Bound {
                    kind: BoundKind::FrontEnd,
                    cy_per_asm_iter: f.cy_per_asm_iter,
                    resource: crate::sim::frontend_resource_label(f.slots, f.width),
                    source: PassSource::Throughput,
                });
            }
            if let Some((i, v)) = div {
                bounds.push(Bound {
                    kind: BoundKind::Divider,
                    cy_per_asm_iter: v,
                    resource: r.machine.ports[i].clone(),
                    source: PassSource::Throughput,
                });
            }
        }
        if let Some(c) = &r.critpath {
            bounds.push(Bound {
                kind: BoundKind::CriticalPath,
                cy_per_asm_iter: c.carried_per_iteration,
                resource: "loop-carried chain".to_string(),
                source: PassSource::Critpath,
            });
        }
        if let Some(mem) = &r.memory {
            bounds.push(Bound {
                kind: BoundKind::Memory,
                cy_per_asm_iter: mem.cy_per_asm_iter,
                resource: mem.level.clone(),
                source: PassSource::Memory,
            });
        }
        if let Some(b) = &r.baseline {
            bounds.push(Bound {
                kind: BoundKind::Baseline,
                cy_per_asm_iter: b.cy_per_asm_iter,
                resource: "balanced ports".to_string(),
                source: PassSource::Baseline,
            });
        }
        if let Some(m) = &r.simulation {
            bounds.push(Bound {
                kind: BoundKind::Simulated,
                cy_per_asm_iter: m.cycles_per_iteration as f32,
                resource: m.bottleneck_resource(&r.machine),
                source: PassSource::Simulate,
            });
        }
        let lines = r.throughput.as_ref().map(|t| t.lines.clone()).unwrap_or_default();
        Prediction { bounds, unroll: r.unroll, lines }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bound(kind: BoundKind, cy: f32) -> Bound {
        Bound {
            kind,
            cy_per_asm_iter: cy,
            resource: "r".to_string(),
            source: PassSource::Throughput,
        }
    }

    #[test]
    fn winner_is_the_max_model_bound() {
        let p = Prediction {
            bounds: vec![
                bound(BoundKind::PortPressure, 3.0),
                bound(BoundKind::FrontEnd, 4.0),
                bound(BoundKind::Divider, 0.0),
                bound(BoundKind::Simulated, 9.0), // observation: ignored
            ],
            unroll: 2,
            lines: Vec::new(),
        };
        let w = p.winner().unwrap();
        assert_eq!(w.kind, BoundKind::FrontEnd);
        assert_eq!(p.cy_per_asm_iter(), Some(4.0));
        assert_eq!(p.cy_per_source_it(), Some(2.0));
    }

    #[test]
    fn ties_prefer_the_earlier_kind() {
        let p = Prediction {
            bounds: vec![
                bound(BoundKind::PortPressure, 2.0),
                bound(BoundKind::CriticalPath, 2.0),
            ],
            unroll: 1,
            lines: Vec::new(),
        };
        assert_eq!(p.winner().unwrap().kind, BoundKind::PortPressure);
    }

    #[test]
    fn memory_is_a_model_bound_and_loses_ties_to_ports() {
        assert!(BoundKind::Memory.is_model_bound());
        assert_eq!(BoundKind::Memory.name(), "memory");
        // Push order puts port pressure before memory, so an exact tie
        // keeps the infinite-L1 winner — the L1-resident sweep point
        // stays byte-identical to the base prediction.
        let p = Prediction {
            bounds: vec![
                bound(BoundKind::PortPressure, 2.0),
                bound(BoundKind::Memory, 2.0),
            ],
            unroll: 1,
            lines: Vec::new(),
        };
        assert_eq!(p.winner().unwrap().kind, BoundKind::PortPressure);
        let p = Prediction {
            bounds: vec![
                bound(BoundKind::PortPressure, 2.0),
                bound(BoundKind::Memory, 40.0),
            ],
            unroll: 1,
            lines: Vec::new(),
        };
        assert_eq!(p.winner().unwrap().kind, BoundKind::Memory);
    }

    #[test]
    fn empty_prediction_has_no_winner() {
        let p = Prediction::default();
        assert!(p.winner().is_none());
        assert!(p.cy_per_asm_iter().is_none());
        // Observations alone do not make a prediction.
        let p = Prediction {
            bounds: vec![bound(BoundKind::Baseline, 2.0)],
            unroll: 1,
            lines: Vec::new(),
        };
        assert!(p.cy_per_asm_iter().is_none());
        assert!(p.bound(BoundKind::Baseline).is_some());
    }
}
