//! Analysis requests: which kernel, which machine, which passes.

use std::ops::{BitOr, BitOrAssign};
use std::sync::Arc;

use crate::asm::Kernel;
use crate::isa::Isa;
use crate::mdb::MachineModel;
use crate::report::emit::Format;
use crate::sim::SimConfig;

/// The composable analysis passes an [`super::Engine`] can run over a
/// kernel. Combine with `|`:
///
/// ```ignore
/// Passes::THROUGHPUT | Passes::CRITPATH | Passes::BASELINE
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Passes(u8);

impl Passes {
    /// No passes (the request only validates the kernel).
    pub const NONE: Passes = Passes(0);
    /// OSACA uniform-split port-occupancy throughput analysis.
    pub const THROUGHPUT: Passes = Passes(1);
    /// Critical-path / loop-carried latency bound.
    pub const CRITPATH: Passes = Passes(1 << 1);
    /// IACA-like balanced baseline through the batching solver.
    pub const BASELINE: Passes = Passes(1 << 2);
    /// Cycle-level simulation on the hardware-substrate model.
    pub const SIMULATE: Passes = Passes(1 << 3);
    /// The three analytic passes (default for new requests).
    pub const ANALYTIC: Passes = Passes(0b0111);
    /// Everything, including the (slower) simulation.
    pub const ALL: Passes = Passes(0b1111);

    /// Does `self` include every pass in `other`?
    pub fn contains(self, other: Passes) -> bool {
        self.0 & other.0 == other.0
    }

    /// The raw flag bits (stable across releases: THROUGHPUT=1,
    /// CRITPATH=2, BASELINE=4, SIMULATE=8). Used by the request
    /// fingerprint and the serve wire format.
    pub fn bits(self) -> u8 {
        self.0
    }

    /// Parse one pass (or pass-set) name as used on the serve wire:
    /// `throughput`, `critpath`, `baseline`, `simulate`, `analytic`,
    /// `all`. Case-insensitive; unknown names return `None`.
    pub fn from_name(name: &str) -> Option<Passes> {
        Some(match name.to_ascii_lowercase().as_str() {
            "throughput" => Passes::THROUGHPUT,
            "critpath" => Passes::CRITPATH,
            "baseline" => Passes::BASELINE,
            "simulate" => Passes::SIMULATE,
            "analytic" => Passes::ANALYTIC,
            "all" => Passes::ALL,
            _ => return None,
        })
    }

    /// Does `self` include at least one pass of `other`?
    pub fn intersects(self, other: Passes) -> bool {
        self.0 & other.0 != 0
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }
}

impl BitOr for Passes {
    type Output = Passes;
    fn bitor(self, rhs: Passes) -> Passes {
        Passes(self.0 | rhs.0)
    }
}

impl BitOrAssign for Passes {
    fn bitor_assign(&mut self, rhs: Passes) {
        self.0 |= rhs.0;
    }
}

/// A buildable analysis request. Construct with
/// [`super::Engine::request`] and chain setters:
///
/// ```ignore
/// let req = Engine::request("triad")
///     .arch("skl")
///     .source(src)
///     .passes(Passes::THROUGHPUT | Passes::CRITPATH | Passes::BASELINE)
///     .unroll(4);
/// let report = engine.analyze(&req)?;
/// ```
#[derive(Debug, Clone)]
pub struct AnalysisRequest {
    /// Request / kernel name (diagnostics and report headers).
    pub name: String,
    /// Architecture name resolved through the engine registry
    /// (ignored when [`AnalysisRequest::machine`] supplies a model).
    pub arch: String,
    /// Explicit machine model, overriding `arch`.
    pub machine: Option<Arc<MachineModel>>,
    /// Assembly source text (parsed + kernel-extracted by the engine).
    pub source: Option<String>,
    /// Pre-extracted kernel, overriding `source`.
    pub kernel: Option<Kernel>,
    /// Assertion of the syntax `source` is written in. `None` (the
    /// default) parses with the resolved machine model's ISA, so
    /// `.arch("tx2")` parses AArch64 without further ceremony.
    /// `Some(isa)` that disagrees with the model's ISA fails fast with
    /// a structured [`super::OsacaError::IsaMismatch`] — before any
    /// parsing — instead of mis-parsing the source under the wrong
    /// grammar; it never reinterprets the source for a
    /// different-ISA model.
    pub isa: Option<Isa>,
    /// Which passes to run.
    pub passes: Passes,
    /// Compute the width-aware frontend bound
    /// `max(port pressure, rename slots / rename_width)` in the
    /// throughput pass. Off by default so the paper-pinned skl/zen/tx2
    /// tables stay exact; on narrow cores (the 2-wide `rv64`) it closes
    /// the analyzer-vs-simulator gap documented in DESIGN.md §7.
    pub frontend_bound: bool,
    /// Output format for [`super::AnalysisReport::render`]
    /// (default: text).
    pub format: Format,
    /// Assembly-loop unroll factor (cycles-per-source-iteration
    /// conversions in the report).
    pub unroll: usize,
    /// Simulation parameters for [`Passes::SIMULATE`].
    pub sim: SimConfig,
    /// Opt-in memory-model spec (`None` = the paper's infinite-L1
    /// assumption, the default). `""`/`"on"`/`"default"` take the
    /// machine model's `cache` stanzas; entries like
    /// `l1=32K:4,l2=1M:12,mem=:80,ws=4M,lsq=72,lfb=8` override them.
    /// See `sim::MemModel::build` for the grammar.
    pub mem_model: Option<String>,
}

impl AnalysisRequest {
    pub fn new(name: &str) -> Self {
        AnalysisRequest {
            name: name.to_string(),
            arch: "skl".to_string(),
            machine: None,
            source: None,
            kernel: None,
            isa: None,
            passes: Passes::ANALYTIC,
            frontend_bound: false,
            format: Format::Text,
            unroll: 1,
            sim: SimConfig::default(),
            mem_model: None,
        }
    }

    /// Select a registered architecture by name (`skl`, `zen`, `hsw`,
    /// or a model registered on the engine).
    pub fn arch(mut self, arch: &str) -> Self {
        self.arch = arch.to_string();
        self
    }

    /// Use an explicit machine model (e.g. a user-supplied `.mdb`),
    /// bypassing the registry.
    pub fn machine(mut self, machine: Arc<MachineModel>) -> Self {
        self.machine = Some(machine);
        self
    }

    /// Provide assembly source text.
    pub fn source(mut self, src: impl Into<String>) -> Self {
        self.source = Some(src.into());
        self
    }

    /// Provide an already-extracted kernel.
    pub fn kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = Some(kernel);
        self
    }

    /// Assert the syntax `source` is written in (default: the machine
    /// model's ISA). A disagreement with the model's ISA fails the
    /// request with [`super::OsacaError::IsaMismatch`].
    pub fn isa(mut self, isa: Isa) -> Self {
        self.isa = Some(isa);
        self
    }

    /// Select the passes to run (default: [`Passes::ANALYTIC`]).
    pub fn passes(mut self, passes: Passes) -> Self {
        self.passes = passes;
        self
    }

    /// Enable the width-aware frontend bound in the throughput pass
    /// (default off — see [`AnalysisRequest::frontend_bound`]).
    pub fn frontend_bound(mut self, enabled: bool) -> Self {
        self.frontend_bound = enabled;
        self
    }

    /// Select the report output format (default: [`Format::Text`]).
    pub fn format(mut self, format: Format) -> Self {
        self.format = format;
        self
    }

    /// Set the unroll factor (default 1).
    pub fn unroll(mut self, unroll: usize) -> Self {
        self.unroll = unroll.max(1);
        self
    }

    /// Set simulation parameters for [`Passes::SIMULATE`].
    pub fn sim_config(mut self, cfg: SimConfig) -> Self {
        self.sim = cfg;
        self
    }

    /// Enable the opt-in cache-aware memory model (default off — see
    /// [`AnalysisRequest::mem_model`] for the spec grammar).
    pub fn mem_model(mut self, spec: impl Into<String>) -> Self {
        self.mem_model = Some(spec.into());
        self
    }

    /// A stable 64-bit fingerprint of the *analysis-relevant* request
    /// configuration: the kernel text (source, or the canonical
    /// rendering of a pre-extracted kernel), the machine (registered
    /// model name or lower-cased arch), the pass set, the frontend-bound
    /// flag, the unroll factor and the simulation parameters.
    ///
    /// `name` and `format` are presentation-only and deliberately
    /// excluded, so differently-labelled requests for the same analysis
    /// share one memo slot in `serve::MemoCache`.
    pub fn fingerprint(&self) -> u64 {
        // FNV-1a; 0xff separators so adjacent fields cannot alias.
        const BASIS: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = BASIS;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
            h ^= 0xff;
            h = h.wrapping_mul(PRIME);
        };
        match &self.machine {
            Some(m) => eat(m.name.to_ascii_lowercase().as_bytes()),
            None => eat(self.arch.to_ascii_lowercase().as_bytes()),
        }
        match (&self.kernel, &self.source) {
            // A pre-extracted kernel hashes its canonical Display
            // rendering, so source-text and kernel submissions of the
            // same loop agree only when their spellings do.
            (Some(k), _) => {
                for ins in &k.instructions {
                    eat(ins.to_string().as_bytes());
                }
            }
            (None, Some(src)) => eat(src.as_bytes()),
            (None, None) => eat(b""),
        }
        if let Some(isa) = self.isa {
            eat(isa.name().as_bytes());
        }
        eat(&[self.passes.bits(), self.frontend_bound as u8]);
        eat(&self.unroll.to_le_bytes());
        eat(&self.sim.iterations.to_le_bytes());
        eat(&self.sim.warmup.to_le_bytes());
        // Presence byte first so `None` and `Some("")` cannot alias.
        eat(&[self.mem_model.is_some() as u8]);
        if let Some(spec) = &self.mem_model {
            eat(spec.as_bytes());
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_compose() {
        let p = Passes::THROUGHPUT | Passes::BASELINE;
        assert!(p.contains(Passes::THROUGHPUT));
        assert!(!p.contains(Passes::CRITPATH));
        assert!(p.intersects(Passes::BASELINE | Passes::SIMULATE));
        assert!(Passes::ALL.contains(Passes::ANALYTIC));
        assert!(Passes::NONE.is_empty());
        let mut q = Passes::NONE;
        q |= Passes::SIMULATE;
        assert!(q.contains(Passes::SIMULATE));
    }

    #[test]
    fn pass_names_round_trip() {
        for (name, p) in [
            ("throughput", Passes::THROUGHPUT),
            ("critpath", Passes::CRITPATH),
            ("baseline", Passes::BASELINE),
            ("simulate", Passes::SIMULATE),
            ("analytic", Passes::ANALYTIC),
            ("all", Passes::ALL),
        ] {
            assert_eq!(Passes::from_name(name), Some(p));
        }
        assert_eq!(Passes::from_name("THROUGHPUT"), Some(Passes::THROUGHPUT));
        assert_eq!(Passes::from_name("warp"), None);
        assert_eq!(Passes::THROUGHPUT.bits(), 1);
        assert_eq!(Passes::ALL.bits(), 0b1111);
    }

    #[test]
    fn fingerprint_ignores_presentation_fields_only() {
        let base = || {
            AnalysisRequest::new("a")
                .arch("skl")
                .source(".L1:\naddl $1, %eax\njne .L1\n")
                .passes(Passes::THROUGHPUT)
                .unroll(2)
        };
        let f = base().fingerprint();
        // name and format are presentation-only.
        let mut renamed = base();
        renamed.name = "b".into();
        assert_eq!(renamed.fingerprint(), f);
        assert_eq!(base().format(Format::Json).fingerprint(), f);
        // Everything analysis-relevant changes the key.
        assert_ne!(base().arch("zen").fingerprint(), f);
        assert_ne!(base().unroll(3).fingerprint(), f);
        assert_ne!(base().passes(Passes::ANALYTIC).fingerprint(), f);
        assert_ne!(base().frontend_bound(true).fingerprint(), f);
        assert_ne!(base().source(".L1:\naddl $2, %eax\njne .L1\n").fingerprint(), f);
        assert_ne!(
            base().sim_config(SimConfig { iterations: 7, warmup: 0 }).fingerprint(),
            f
        );
        // The memory-model spec is analysis-relevant; empty-spec "on"
        // differs from off.
        assert_ne!(base().mem_model("ws=4M").fingerprint(), f);
        assert_ne!(base().mem_model("").fingerprint(), f);
        assert_ne!(base().mem_model("").fingerprint(), base().mem_model("ws=4M").fingerprint());
    }

    #[test]
    fn builder_chains() {
        let req = AnalysisRequest::new("triad")
            .arch("zen")
            .source(".L1:\naddl $1, %eax\njne .L1\n")
            .passes(Passes::THROUGHPUT)
            .frontend_bound(true)
            .format(Format::Json)
            .unroll(4);
        assert_eq!(req.arch, "zen");
        assert_eq!(req.unroll, 4);
        assert!(req.source.is_some());
        assert_eq!(req.passes, Passes::THROUGHPUT);
        assert!(req.frontend_bound);
        assert_eq!(req.format, Format::Json);
        // Defaults: off / text.
        let d = AnalysisRequest::new("d");
        assert!(!d.frontend_bound);
        assert_eq!(d.format, Format::Text);
    }
}
