//! Structured errors at the public API boundary.
//!
//! Everything below `api` keeps using `anyhow` internally; the `api`
//! layer converts failures into [`OsacaError`] so callers can match on
//! causes (unknown architecture, parse failure at a line, unresolved
//! instruction form, solver timeout, ...) instead of grepping strings.

use std::fmt;
use std::time::Duration;

use crate::coordinator::SubmitError;

/// A structured failure from the `osaca::api` layer.
#[derive(Debug)]
pub enum OsacaError {
    /// The requested architecture is not registered. `available` lists
    /// every built-in and user-registered model name.
    UnknownArch { requested: String, available: Vec<String> },
    /// Assembly source failed to parse or contained no kernel.
    ParseError { name: String, line: Option<usize>, message: String },
    /// A `.mdb` machine-model text failed to parse.
    MalformedModel { line: Option<usize>, message: String },
    /// A uops.info XML import failed (`osaca import-model`): malformed
    /// XML, an uncurated architecture, or measurements the overlay's
    /// port list cannot express. `line` is the 1-based XML source line
    /// when the failure is localized.
    BadModelImport { line: Option<usize>, message: String },
    /// An instruction form has no database entry and could not be
    /// synthesized.
    UnresolvedForm { form: String, line: usize, arch: String },
    /// The kernel's instruction-set architecture does not match the
    /// machine model's (e.g. an x86 kernel against the `tx2` model).
    IsaMismatch { kernel_isa: &'static str, model_isa: &'static str, arch: String },
    /// The request carried neither source text nor a kernel.
    EmptyRequest { name: String },
    /// An unknown report format name (CLI `--format`, emitter
    /// selection). `supported` lists every built-in emitter.
    UnsupportedFormat { requested: String, supported: Vec<String> },
    /// The `--mem-model` / `AnalysisRequest::mem_model` spec string is
    /// malformed or inconsistent with the machine's hierarchy.
    BadMemModel { message: String },
    /// The kernel does not fit the solver artifact's µ-op budget.
    KernelTooLarge { max: usize, message: String },
    /// The solver thread did not reply within the configured timeout.
    SolverTimeout { waited: Duration },
    /// The coordinator service is shut down.
    ServiceUnavailable { message: String },
    /// Anything else (internal invariant failures).
    Internal { message: String },
}

impl OsacaError {
    /// Stable machine-readable error kind, used by the serve wire
    /// format's error frames (`{"error":{"kind":...}}`). Renaming a
    /// kind is a wire-contract change and needs a schema-version bump.
    pub fn kind_name(&self) -> &'static str {
        match self {
            OsacaError::UnknownArch { .. } => "unknown_arch",
            OsacaError::ParseError { .. } => "parse_error",
            OsacaError::MalformedModel { .. } => "malformed_model",
            OsacaError::BadModelImport { .. } => "bad_model_import",
            OsacaError::UnresolvedForm { .. } => "unresolved_form",
            OsacaError::IsaMismatch { .. } => "isa_mismatch",
            OsacaError::EmptyRequest { .. } => "empty_request",
            OsacaError::UnsupportedFormat { .. } => "unsupported_format",
            OsacaError::BadMemModel { .. } => "bad_mem_model",
            OsacaError::KernelTooLarge { .. } => "kernel_too_large",
            OsacaError::SolverTimeout { .. } => "solver_timeout",
            OsacaError::ServiceUnavailable { .. } => "service_unavailable",
            OsacaError::Internal { .. } => "internal",
        }
    }
}

impl fmt::Display for OsacaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OsacaError::UnknownArch { requested, available } => write!(
                f,
                "unknown architecture `{requested}` (available: {})",
                available.join(", ")
            ),
            OsacaError::ParseError { name, line: Some(line), message } => {
                write!(f, "parse error in `{name}` at line {line}: {message}")
            }
            OsacaError::ParseError { name, line: None, message } => {
                write!(f, "parse error in `{name}`: {message}")
            }
            OsacaError::MalformedModel { line: Some(line), message } => {
                write!(f, "malformed machine model at line {line}: {message}")
            }
            OsacaError::MalformedModel { line: None, message } => {
                write!(f, "malformed machine model: {message}")
            }
            OsacaError::BadModelImport { line: Some(line), message } => {
                write!(f, "model import failed at XML line {line}: {message}")
            }
            OsacaError::BadModelImport { line: None, message } => {
                write!(f, "model import failed: {message}")
            }
            OsacaError::UnresolvedForm { form, line, arch } => write!(
                f,
                "no {arch} database entry for instruction form `{form}` (line {line}); \
                 run with --learn or add the entry"
            ),
            OsacaError::IsaMismatch { kernel_isa, model_isa, arch } => write!(
                f,
                "ISA mismatch: {kernel_isa} kernel cannot be analyzed against the \
                 {model_isa} model `{arch}`"
            ),
            OsacaError::EmptyRequest { name } => {
                write!(f, "request `{name}` has neither source text nor a kernel")
            }
            OsacaError::UnsupportedFormat { requested, supported } => write!(
                f,
                "unsupported report format `{requested}` (supported: {})",
                supported.join(", ")
            ),
            OsacaError::BadMemModel { message } => {
                write!(f, "bad memory-model spec: {message}")
            }
            OsacaError::KernelTooLarge { max, message } => {
                write!(f, "kernel exceeds the solver budget of {max} µ-ops: {message}")
            }
            OsacaError::SolverTimeout { waited } => {
                write!(f, "solver did not reply within {waited:?}")
            }
            OsacaError::ServiceUnavailable { message } => {
                write!(f, "analysis service unavailable: {message}")
            }
            OsacaError::Internal { message } => write!(f, "internal error: {message}"),
        }
    }
}

impl std::error::Error for OsacaError {}

impl From<SubmitError> for OsacaError {
    fn from(e: SubmitError) -> Self {
        match e {
            SubmitError::Timeout { waited } => OsacaError::SolverTimeout { waited },
            SubmitError::Closed => {
                OsacaError::ServiceUnavailable { message: "solver thread gone".into() }
            }
            SubmitError::Panicked { category } => OsacaError::Internal {
                message: format!("solver worker panicked ({category}); backend restarted"),
            },
        }
    }
}

/// Extract the first `line N` mention from an error chain — the parse
/// layers annotate failures with `line {n}` context.
pub(crate) fn find_line(message: &str) -> Option<usize> {
    let mut rest = message;
    while let Some(pos) = rest.find("line ") {
        let digits: String = rest[pos + 5..].chars().take_while(|c| c.is_ascii_digit()).collect();
        if !digits.is_empty() {
            return digits.parse().ok();
        }
        rest = &rest[pos + 5..];
    }
    None
}

/// Classify a kernel-preparation failure from the lower layers.
pub(crate) fn parse_failure(name: &str, err: &anyhow::Error) -> OsacaError {
    let message = format!("{err:#}");
    OsacaError::ParseError { name: name.to_string(), line: find_line(&message), message }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_extraction() {
        assert_eq!(find_line("entry line 12: bad uop"), Some(12));
        assert_eq!(find_line("line 3: unknown directive `bogus`"), Some(3));
        assert_eq!(find_line("no line info"), None);
        assert_eq!(find_line("line x then line 7: ok"), Some(7));
    }

    #[test]
    fn unknown_arch_lists_available() {
        let e = OsacaError::UnknownArch {
            requested: "m1max".into(),
            available: vec!["hsw".into(), "skl".into(), "zen".into()],
        };
        let msg = e.to_string();
        assert!(msg.contains("m1max"));
        assert!(msg.contains("skl"));
        assert!(msg.contains("zen"));
    }
}
