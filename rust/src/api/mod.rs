//! The public analysis-session layer — the crate's front door.
//!
//! The paper's workflow is one pipeline (parse kernel → resolve against
//! a machine model → run throughput / critical-path / baseline
//! analyses), and this module exposes it as one API instead of five
//! disconnected entry points:
//!
//! ```ignore
//! use osaca::api::{Engine, Passes};
//!
//! let engine = Engine::new();
//! let report = engine.analyze(
//!     &Engine::request("triad")
//!         .arch("skl")
//!         .source(src)
//!         .passes(Passes::THROUGHPUT | Passes::CRITPATH | Passes::BASELINE)
//!         .unroll(4),
//! )?;
//! println!("{}", report.to_text());
//! ```
//!
//! * [`Engine`] owns the shared machine-model registry (`Arc`-cached
//!   built-ins plus user-registered `.mdb` models) and the lazily
//!   started batching [`Coordinator`]; it is a cheap `Clone` (an `Arc`
//!   handle), so requests can fan out across threads and executor jobs
//!   without scoped lifetimes;
//! * [`AnalysisRequest`] is a builder: name, arch/machine,
//!   source/kernel, composable [`Passes`], unroll, sim parameters;
//! * [`Engine::analyze_batch`] fans the analytic passes out on the
//!   crate-wide [`crate::exec`] executor, then maps every baseline
//!   solve of the batch directly onto the solver's B=8 batch slots
//!   (`ceil(n/8)` artifact executions — see `ServiceStats::batches`);
//! * [`AnalysisReport`] carries one optional section per pass, the
//!   structured [`Prediction`] bound decomposition (which resource wins
//!   and why), and pluggable text/JSON/CSV rendering via the
//!   [`Emitter`] trait (selected per request with
//!   [`AnalysisRequest::format`]);
//! * [`OsacaError`] makes failures matchable (unknown arch with the
//!   available list, parse errors with line numbers, unresolved forms,
//!   solver timeouts) instead of stringly-typed.
//!
//! The pre-existing free functions (`analyzer::analyze`,
//! `baseline::predict_cpu`, `Coordinator::analyze_source`, ...) remain
//! as thin compatibility shims.

mod error;
mod prediction;
mod report;
mod request;

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, OnceLock, RwLock};
use std::time::Duration;

use crate::analyzer::{analyze, analyze_with_slots, critical_path_decoded};
use crate::asm::{extract_kernel_isa, Kernel};
use crate::baseline::{encode, to_prediction};
use crate::coordinator::{Coordinator, CoordinatorConfig, ServiceStats, SubmitError};
use crate::exec::{self, Executor};
use crate::mdb::{self, MachineModel};
use crate::runtime::{EncodedKernel, MAX_UOPS};
use crate::sim::{
    analyze_memory, derive_footprint, run_decoded_mem, DecodedKernel, MemModel, MemSimPlan,
};

/// Upper bound on the executor pool that runs the in-process analytic
/// passes of [`Engine::analyze_batch`]. Small on purpose: the passes
/// are short and allocation-light, so a handful of workers saturates
/// the win while keeping thread startup cost negligible.
const ANALYTIC_POOL_MAX: usize = 8;

pub use crate::coordinator::Backend;
pub use crate::report::emit::{Emitter, Format, SCHEMA_VERSION};
pub use crate::sim::{MemModel, MemoryAnalysis};
pub use error::OsacaError;
pub use prediction::{Bound, BoundKind, PassSource, Prediction};
pub use report::AnalysisReport;
pub use request::{AnalysisRequest, Passes};

/// Engine tunables (forwarded to the underlying [`Coordinator`]).
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    pub backend: Backend,
    /// Batching window of the single-request path.
    pub batch_window: Duration,
    /// Reply timeout for solver submissions.
    pub reply_timeout: Duration,
    /// Submission queue depth.
    pub queue_depth: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        let c = CoordinatorConfig::default();
        EngineConfig {
            backend: c.backend,
            batch_window: c.window,
            reply_timeout: c.reply_timeout,
            queue_depth: c.queue_depth,
        }
    }
}

/// Fluent constructor for a configured [`Engine`].
#[derive(Debug, Default)]
pub struct EngineBuilder {
    cfg: EngineConfig,
}

impl EngineBuilder {
    /// Select the solver backend (default: artifact if present, CPU
    /// reference otherwise).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.cfg.backend = backend;
        self
    }

    /// Set the single-path batching window.
    pub fn batch_window(mut self, window: Duration) -> Self {
        self.cfg.batch_window = window;
        self
    }

    /// Set the solver reply timeout.
    pub fn reply_timeout(mut self, timeout: Duration) -> Self {
        self.cfg.reply_timeout = timeout;
        self
    }

    /// Set the submission queue depth.
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.cfg.queue_depth = depth;
        self
    }

    pub fn build(self) -> Engine {
        Engine {
            inner: Arc::new(EngineInner {
                config: self.cfg,
                models: RwLock::new(HashMap::new()),
                coord: OnceLock::new(),
                pool: OnceLock::new(),
            }),
        }
    }
}

/// The shared state behind an [`Engine`] handle.
struct EngineInner {
    config: EngineConfig,
    /// User-registered models, keyed by lower-cased name. Built-ins
    /// come from the process-wide `mdb` cache.
    models: RwLock<HashMap<String, Arc<MachineModel>>>,
    coord: OnceLock<Coordinator>,
    /// Lazily started analytic worker pool for [`Engine::analyze_batch`]
    /// (context-free workers — the analytic passes only need `&Engine`,
    /// which each job captures as its own cheap clone).
    pool: OnceLock<Executor<()>>,
}

/// The analysis engine: machine-model registry + batching service.
///
/// An `Engine` is a cheap clonable handle (`Arc` inside): clones share
/// the registry, the coordinator and the analytic pool, so one can be
/// captured by `'static` executor jobs while the caller keeps using
/// its own. The solver thread starts lazily on the first request that
/// needs the baseline pass.
#[derive(Clone)]
pub struct Engine {
    inner: Arc<EngineInner>,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    /// Engine with default configuration (auto backend).
    pub fn new() -> Self {
        Engine::builder().build()
    }

    /// Engine pinned to the pure-rust solver (deterministic; used by
    /// tests and examples that must not depend on the artifact).
    pub fn cpu_only() -> Self {
        Engine::builder().backend(Backend::Cpu).build()
    }

    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// Start building an [`AnalysisRequest`]:
    /// `Engine::request("triad").arch("skl").source(src)`.
    pub fn request(name: &str) -> AnalysisRequest {
        AnalysisRequest::new(name)
    }

    /// The underlying batching coordinator (started on first use).
    pub fn coordinator(&self) -> &Coordinator {
        self.inner.coord.get_or_init(|| {
            Coordinator::with_config(CoordinatorConfig {
                backend: self.inner.config.backend,
                window: self.inner.config.batch_window,
                reply_timeout: self.inner.config.reply_timeout,
                queue_depth: self.inner.config.queue_depth,
            })
        })
    }

    /// Service statistics of the coordinator.
    pub fn stats(&self) -> &ServiceStats {
        &self.coordinator().stats
    }

    /// Shared handle to a machine model: user-registered models first,
    /// then the cached built-ins (`skl`, `zen`, `hsw` + aliases).
    pub fn machine(&self, arch: &str) -> Result<Arc<MachineModel>, OsacaError> {
        let key = arch.to_ascii_lowercase();
        if let Some(m) = self.inner.models.read().expect("model registry").get(&key) {
            return Ok(m.clone());
        }
        mdb::by_name_shared(&key).ok_or_else(|| OsacaError::UnknownArch {
            requested: arch.to_string(),
            available: self.available_arches(),
        })
    }

    /// Every architecture [`Engine::machine`] can resolve.
    pub fn available_arches(&self) -> Vec<String> {
        let mut v: Vec<String> =
            mdb::builtin_names().iter().map(|s| s.to_string()).collect();
        v.extend(mdb::registry_names());
        v.extend(self.inner.models.read().expect("model registry").keys().cloned());
        v.sort();
        v.dedup();
        v
    }

    /// Parse `.mdb` text and register the model under its `arch` name.
    pub fn register_model_text(&self, text: &str) -> Result<Arc<MachineModel>, OsacaError> {
        let model = MachineModel::parse(text).map_err(|e| {
            let message = format!("{e:#}");
            OsacaError::MalformedModel { line: error::find_line(&message), message }
        })?;
        Ok(self.register_machine(model))
    }

    /// Register an in-memory model under its `name`.
    pub fn register_machine(&self, model: MachineModel) -> Arc<MachineModel> {
        let arc = Arc::new(model);
        self.inner
            .models
            .write()
            .expect("model registry")
            .insert(arc.name.to_ascii_lowercase(), arc.clone());
        arc
    }

    /// Resolve the request's machine + kernel and pre-validate that
    /// every non-branch instruction resolves against the model, so
    /// pass execution cannot fail with a stringly error. Source text
    /// is parsed with the request's ISA override if set, otherwise the
    /// machine model's ISA — the kernel and model ISAs must agree.
    fn prepare(&self, req: &AnalysisRequest) -> Result<(Arc<MachineModel>, Kernel), OsacaError> {
        let machine = match &req.machine {
            Some(m) => m.clone(),
            None => self.machine(&req.arch)?,
        };
        let isa = req.isa.unwrap_or(machine.isa);
        // A forced syntax that disagrees with the model is decidable
        // before parsing: fail fast with the structured error instead
        // of parsing the source under a grammar that cannot match.
        if isa != machine.isa {
            return Err(OsacaError::IsaMismatch {
                kernel_isa: isa.name(),
                model_isa: machine.isa.name(),
                arch: machine.name.clone(),
            });
        }
        let kernel = match (&req.kernel, &req.source) {
            (Some(k), _) => k.clone(),
            (None, Some(src)) => extract_kernel_isa(&req.name, src, isa)
                .map_err(|e| error::parse_failure(&req.name, &e))?,
            (None, None) => return Err(OsacaError::EmptyRequest { name: req.name.clone() }),
        };
        if kernel.isa != machine.isa {
            return Err(OsacaError::IsaMismatch {
                kernel_isa: kernel.isa.name(),
                model_isa: machine.isa.name(),
                arch: machine.name.clone(),
            });
        }
        if !req.passes.is_empty() {
            for ins in &kernel.instructions {
                // Branches that macro-fuse away are never resolved;
                // AArch64 compare-and-branch forms execute a real µ-op
                // and must pre-validate like any other instruction.
                if ins.is_fusible_branch() {
                    continue;
                }
                if machine.resolve(ins).is_err() {
                    return Err(OsacaError::UnresolvedForm {
                        form: ins.form().to_string(),
                        line: ins.line,
                        arch: machine.name.clone(),
                    });
                }
            }
        }
        Ok((machine, kernel))
    }

    /// Run the in-process passes (everything except the baseline,
    /// which goes through the batching solver).
    fn run_inline(
        &self,
        req: &AnalysisRequest,
        machine: &Arc<MachineModel>,
        kernel: &Kernel,
    ) -> Result<AnalysisReport, OsacaError> {
        let mut report = AnalysisReport {
            name: req.name.clone(),
            arch: machine.name.clone(),
            machine: machine.clone(),
            unroll: req.unroll,
            format: req.format,
            throughput: None,
            critpath: None,
            memory: None,
            baseline: None,
            simulation: None,
            prediction_cell: std::sync::OnceLock::new(),
        };
        // Decode once: the critical-path pass, the simulator, the
        // width-aware frontend bound and the opt-in memory model all
        // consume the same dependency-wired template, so
        // parse+resolve+decode work happens once per request, not once
        // per pass.
        let wants_frontend = req.frontend_bound && req.passes.contains(Passes::THROUGHPUT);
        let wants_decode = req.passes.intersects(Passes::CRITPATH | Passes::SIMULATE)
            || wants_frontend
            || req.mem_model.is_some();
        let decoded = if wants_decode {
            Some(DecodedKernel::new(kernel, machine).map_err(internal)?)
        } else {
            None
        };
        if req.passes.contains(Passes::THROUGHPUT) {
            report.throughput = Some(if wants_frontend {
                let slots = decoded.as_ref().expect("decoded for frontend bound").iter.slots;
                analyze_with_slots(kernel, machine, slots).map_err(internal)?
            } else {
                analyze(kernel, machine).map_err(internal)?
            });
        }
        if let Some(dk) = &decoded {
            if req.passes.contains(Passes::CRITPATH) {
                report.critpath = Some(critical_path_decoded(&dk.iter, machine));
            }
            // The opt-in memory model: footprint-derived ECM bound plus
            // the simulator plan. Strictly additive — with `mem_model`
            // unset nothing here runs and every pinned table is
            // bit-identical to the infinite-L1 pipeline.
            let mut sim_plan: Option<MemSimPlan> = None;
            if let Some(spec) = &req.mem_model {
                let model = MemModel::build(machine, spec)
                    .map_err(|e| OsacaError::BadMemModel { message: format!("{e:#}") })?;
                let fp = derive_footprint(kernel, &dk.iter, model.line_bytes());
                let analysis = analyze_memory(&model, &fp, req.sim.iterations as u64);
                sim_plan = Some(MemSimPlan::new(&model, &analysis, &fp));
                report.memory = Some(analysis);
            }
            if req.passes.contains(Passes::SIMULATE) {
                report.simulation =
                    Some(run_decoded_mem(dk, machine, req.sim, sim_plan.as_ref()));
            }
        }
        Ok(report)
    }

    fn encode_for_solver(
        &self,
        kernel: &Kernel,
        machine: &MachineModel,
    ) -> Result<EncodedKernel, OsacaError> {
        encode(kernel, machine).map_err(|e| {
            let message = format!("{e:#}");
            // `EncodedKernel::push_uop` reports the µ-op budget as
            // "kernel exceeds {MAX_UOPS} µ-ops"; other encode failures
            // (e.g. port-width overflow of a user model) stay Internal.
            if message.contains("µ-ops") && message.contains("exceeds") {
                OsacaError::KernelTooLarge { max: MAX_UOPS, message }
            } else {
                OsacaError::Internal { message }
            }
        })
    }

    /// Run one request through its selected passes.
    pub fn analyze(&self, req: &AnalysisRequest) -> Result<AnalysisReport, OsacaError> {
        let (machine, kernel) = self.prepare(req)?;
        let mut report = self.run_inline(req, &machine, &kernel)?;
        if req.passes.contains(Passes::BASELINE) {
            let enc = self.encode_for_solver(&kernel, &machine)?;
            let coord = self.coordinator();
            coord.stats.requests.fetch_add(1, Ordering::Relaxed);
            let out = coord.solve_one(enc)?;
            report.baseline = Some(to_prediction(&out));
        }
        Ok(report)
    }

    /// One request's in-process work: preparation, analytic passes, and
    /// the solver encoding. The solver submission itself stays with the
    /// caller so a batch's baselines map onto B=8 slots together.
    fn analytic_one(
        &self,
        req: &AnalysisRequest,
    ) -> Result<(AnalysisReport, Option<EncodedKernel>), OsacaError> {
        let (machine, kernel) = self.prepare(req)?;
        let report = self.run_inline(req, &machine, &kernel)?;
        let enc = if req.passes.contains(Passes::BASELINE) {
            Some(self.encode_for_solver(&kernel, &machine)?)
        } else {
            None
        };
        Ok((report, enc))
    }

    /// The lazily started analytic worker pool (shared by every clone
    /// of this engine).
    fn analytic_pool(&self) -> &Executor<()> {
        self.inner.pool.get_or_init(|| {
            let workers = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(ANALYTIC_POOL_MAX);
            Executor::new(
                exec::ExecConfig {
                    workers,
                    queue_depth: 64,
                    name: "osaca-analytic".to_string(),
                    ..Default::default()
                },
                |_worker| (),
            )
        })
    }

    /// Fan the per-request analytic work out over the executor pool.
    /// Jobs go through the shared injector (no affinity — the passes
    /// have no per-worker state to stay close to) and report
    /// `(index, outcome)` pairs, so the returned vector is in request
    /// order regardless of steal interleaving and per-request failures
    /// stay in their slot. A panicking request costs only its own slot:
    /// executor supervision rebuilds the worker and the job's
    /// `on_panic` files a structured `Internal` error.
    #[allow(clippy::type_complexity)]
    fn run_analytic_exec(
        &self,
        reqs: &[AnalysisRequest],
    ) -> Vec<Result<(AnalysisReport, Option<EncodedKernel>), OsacaError>> {
        if reqs.len() <= 1 {
            return reqs.iter().map(|r| self.analytic_one(r)).collect();
        }
        let pool = self.analytic_pool();
        let (tx, rx) = mpsc::channel();
        for (i, req) in reqs.iter().enumerate() {
            let engine = self.clone();
            let req = req.clone();
            let run_tx = tx.clone();
            let panic_tx = tx.clone();
            let job = exec::Job::new(move |_ctx: &mut ()| {
                let _ = run_tx.send((i, engine.analytic_one(&req)));
            })
            .on_panic(move |category| {
                let _ = panic_tx.send((
                    i,
                    Err(OsacaError::Internal {
                        message: format!(
                            "analysis worker panicked ({category}); worker restarted"
                        ),
                    }),
                ));
            });
            if pool.submit(None, job).is_err() {
                // Only possible during teardown of a closed pool.
                let _ = tx.send((
                    i,
                    Err(OsacaError::ServiceUnavailable {
                        message: "analytic pool closed".into(),
                    }),
                ));
            }
        }
        // Every job answers exactly once (run or on_panic); the channel
        // closes when the last job's sender drops.
        drop(tx);
        let mut slots: Vec<Option<Result<(AnalysisReport, Option<EncodedKernel>), OsacaError>>> =
            Vec::with_capacity(reqs.len());
        slots.resize_with(reqs.len(), || None);
        for (i, outcome) in rx {
            slots[i] = Some(outcome);
        }
        slots.into_iter().map(|s| s.expect("every request analyzed")).collect()
    }

    /// Run many requests: the in-process analytic passes fan out on the
    /// executor pool, then every baseline solve of the batch maps
    /// directly onto consecutive B=8 solver slots (`ceil(n/8)` artifact
    /// executions instead of one windowed reply channel per request).
    /// Results come back in request order; per-request failures do not
    /// abort the rest of the batch.
    pub fn analyze_batch(
        &self,
        reqs: &[AnalysisRequest],
    ) -> Vec<Result<AnalysisReport, OsacaError>> {
        let mut results: Vec<Result<AnalysisReport, OsacaError>> = Vec::with_capacity(reqs.len());
        let mut baseline_idx: Vec<usize> = Vec::new();
        let mut baseline_encs: Vec<EncodedKernel> = Vec::new();
        for (i, outcome) in self.run_analytic_exec(reqs).into_iter().enumerate() {
            match outcome {
                Ok((report, enc)) => {
                    if let Some(enc) = enc {
                        baseline_idx.push(i);
                        baseline_encs.push(enc);
                    }
                    results.push(Ok(report));
                }
                Err(e) => results.push(Err(e)),
            }
        }
        if baseline_idx.is_empty() {
            return results;
        }
        let coord = self.coordinator();
        coord.stats.requests.fetch_add(baseline_idx.len() as u64, Ordering::Relaxed);
        match coord.solve_batch(baseline_encs) {
            Ok(outs) => {
                for (i, out) in baseline_idx.into_iter().zip(outs.iter()) {
                    if let Ok(report) = &mut results[i] {
                        report.baseline = Some(to_prediction(out));
                    }
                }
            }
            Err(e) => {
                for i in baseline_idx {
                    results[i] = Err(match &e {
                        SubmitError::Timeout { waited } => {
                            OsacaError::SolverTimeout { waited: *waited }
                        }
                        SubmitError::Closed => OsacaError::ServiceUnavailable {
                            message: "solver thread gone".into(),
                        },
                        SubmitError::Panicked { category } => OsacaError::Internal {
                            message: format!(
                                "solver worker panicked ({category}); backend restarted"
                            ),
                        },
                    });
                }
            }
        }
        results
    }
}

fn internal(e: anyhow::Error) -> OsacaError {
    OsacaError::Internal { message: format!("{e:#}") }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn request_flows_through_all_analytic_passes() {
        let engine = Engine::cpu_only();
        let w = workloads::find("triad", "skl", "-O3").unwrap();
        let req = Engine::request(&w.name())
            .arch("skl")
            .source(w.source)
            .passes(Passes::ANALYTIC)
            .unroll(w.unroll);
        let report = engine.analyze(&req).unwrap();
        let t = report.throughput.as_ref().unwrap();
        assert!((t.cy_per_asm_iter - 2.0).abs() < 0.01);
        assert!(report.critpath.is_some());
        let b = report.baseline.as_ref().unwrap();
        assert!(b.cy_per_asm_iter <= t.cy_per_asm_iter + 0.25);
        assert!((report.predicted_cy_per_source_it().unwrap() - 0.5).abs() < 0.01);
        let json = report.to_json();
        assert!(json.contains("\"throughput\""));
        assert!(json.contains("\"baseline\""));
        // The structured decomposition: ports win (the load-bound
        // triad), the baseline rides along as an observation, and the
        // winner agrees with the flat prediction.
        let p = report.prediction();
        let w = p.winner().unwrap();
        assert_eq!(w.kind, BoundKind::PortPressure);
        assert!((w.cy_per_asm_iter - 2.0).abs() < 0.01);
        assert!(p.bound(BoundKind::Divider).is_some());
        assert!(p.bound(BoundKind::CriticalPath).is_some());
        assert!(p.bound(BoundKind::Baseline).is_some());
        assert!(p.bound(BoundKind::FrontEnd).is_none(), "frontend bound is opt-in");
        assert_eq!(p.cy_per_asm_iter(), report.predicted_cy_per_asm_iter());
    }

    #[test]
    fn unknown_arch_error_lists_builtins() {
        let engine = Engine::cpu_only();
        let req = Engine::request("x").arch("m1max").source(".L1:\naddl $1, %eax\njne .L1\n");
        match engine.analyze(&req) {
            Err(OsacaError::UnknownArch { requested, available }) => {
                assert_eq!(requested, "m1max");
                assert!(available.contains(&"skl".to_string()));
                assert!(available.contains(&"zen".to_string()));
                assert!(available.contains(&"hsw".to_string()));
            }
            other => panic!("expected UnknownArch, got {other:?}"),
        }
    }

    #[test]
    fn empty_request_is_structured() {
        let engine = Engine::cpu_only();
        match engine.analyze(&Engine::request("void")) {
            Err(OsacaError::EmptyRequest { name }) => assert_eq!(name, "void"),
            other => panic!("expected EmptyRequest, got {other:?}"),
        }
    }

    #[test]
    fn registered_model_is_resolvable() {
        let engine = Engine::cpu_only();
        let text = "arch toy \"Toy\"\nports P0 LD\nloadports LD\n\
                    entry vaddpd-xmm_xmm_xmm lat=2 tp=1 uops=c@1:P0\n";
        let m = engine.register_model_text(text).unwrap();
        assert_eq!(m.name, "toy");
        assert!(engine.machine("toy").is_ok());
        assert!(engine.available_arches().contains(&"toy".to_string()));
    }

    #[test]
    fn engine_clones_share_state() {
        let engine = Engine::cpu_only();
        let clone = engine.clone();
        let text = "arch toy2 \"Toy2\"\nports P0 LD\nloadports LD\n\
                    entry vaddpd-xmm_xmm_xmm lat=2 tp=1 uops=c@1:P0\n";
        engine.register_model_text(text).unwrap();
        // Registry, coordinator and stats are one shared instance.
        assert!(clone.machine("toy2").is_ok());
        assert!(std::ptr::eq(engine.coordinator(), clone.coordinator()));
    }
}
