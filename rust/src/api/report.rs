//! The unified analysis report: one struct, optional per-pass sections,
//! text and JSON rendering.

use std::fmt::Write as _;
use std::sync::Arc;

use crate::analyzer::{Analysis, CritPathReport};
use crate::baseline::BaselinePrediction;
use crate::mdb::MachineModel;
use crate::report::render_occupancy;
use crate::sim::Measurement;

/// Result of one [`super::Engine::analyze`] call. Sections are present
/// for exactly the passes requested.
#[derive(Debug, Clone)]
pub struct AnalysisReport {
    pub name: String,
    /// Machine name the analysis ran against.
    pub arch: String,
    pub machine: Arc<MachineModel>,
    pub unroll: usize,
    /// OSACA uniform-split throughput analysis ([`super::Passes::THROUGHPUT`]).
    pub throughput: Option<Analysis>,
    /// Latency bounds ([`super::Passes::CRITPATH`]).
    pub critpath: Option<CritPathReport>,
    /// IACA-like balanced baseline ([`super::Passes::BASELINE`]).
    pub baseline: Option<BaselinePrediction>,
    /// Simulator measurement ([`super::Passes::SIMULATE`]).
    pub simulation: Option<Measurement>,
}

impl AnalysisReport {
    /// The combined analytic prediction: max of the throughput bound
    /// and the loop-carried latency bound, cycles per assembly
    /// iteration. `None` when neither pass ran.
    pub fn predicted_cy_per_asm_iter(&self) -> Option<f32> {
        match (&self.throughput, &self.critpath) {
            (Some(t), Some(c)) => Some(t.cy_per_asm_iter.max(c.carried_per_iteration)),
            (Some(t), None) => Some(t.cy_per_asm_iter),
            (None, Some(c)) => Some(c.carried_per_iteration),
            (None, None) => None,
        }
    }

    /// Combined prediction per *source* iteration.
    pub fn predicted_cy_per_source_it(&self) -> Option<f32> {
        self.predicted_cy_per_asm_iter().map(|cy| cy / self.unroll as f32)
    }

    /// Human-readable rendering: the paper-style occupancy table plus
    /// one line per additional section.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} on {} ({}) ===", self.name, self.machine.arch_name, self.arch);
        if let Some(t) = &self.throughput {
            out.push_str(&render_occupancy(t, &self.machine));
        }
        if let Some(c) = &self.critpath {
            let _ = writeln!(
                out,
                "Critical path: {:.2} cy intra-iteration, {:.2} cy/it loop-carried bound",
                c.intra_iteration, c.carried_per_iteration
            );
        }
        if let Some(b) = &self.baseline {
            let _ = writeln!(
                out,
                "Balanced (IACA-like) baseline: {:.2} cy / assembly iteration (uniform {:.2})",
                b.cy_per_asm_iter, b.uniform_cy
            );
        }
        if let Some(m) = &self.simulation {
            let _ = writeln!(
                out,
                "Simulated hardware: {:.3} cy / assembly iteration over {} iterations",
                m.cycles_per_iteration, m.iterations
            );
        }
        if self.unroll > 1 {
            if let Some(cy) = self.predicted_cy_per_source_it() {
                let _ = writeln!(
                    out,
                    "Combined prediction: {cy:.2} cy / source iteration (unroll {})",
                    self.unroll
                );
            }
        }
        out
    }

    /// Machine-readable rendering (hand-rolled JSON: serde is not
    /// vendored in the offline build).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        push_str_field(&mut out, "name", &self.name);
        out.push(',');
        push_str_field(&mut out, "arch", &self.arch);
        let _ = write!(out, ",\"unroll\":{}", self.unroll);
        if let Some(t) = &self.throughput {
            let _ = write!(
                out,
                ",\"throughput\":{{\"cy_per_asm_iter\":{},\"bottleneck_port\":",
                fmt_f32(t.cy_per_asm_iter)
            );
            push_json_string(&mut out, &self.machine.ports[t.bottleneck_port]);
            out.push_str(",\"totals\":[");
            for (i, v) in t.totals.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&fmt_f32(*v));
            }
            out.push_str("]}");
        }
        if let Some(c) = &self.critpath {
            let _ = write!(
                out,
                ",\"critpath\":{{\"intra_iteration\":{},\"carried_per_iteration\":{}}}",
                fmt_f32(c.intra_iteration),
                fmt_f32(c.carried_per_iteration)
            );
        }
        if let Some(b) = &self.baseline {
            let _ = write!(
                out,
                ",\"baseline\":{{\"cy_per_asm_iter\":{},\"uniform_cy\":{}}}",
                fmt_f32(b.cy_per_asm_iter),
                fmt_f32(b.uniform_cy)
            );
        }
        if let Some(m) = &self.simulation {
            let _ = write!(
                out,
                ",\"simulation\":{{\"cycles_per_iteration\":{},\"iterations\":{},\
                 \"issue_stall_cycles\":{},\"forwarded_loads\":{}}}",
                fmt_f64(m.cycles_per_iteration),
                m.iterations,
                m.counters.issue_stall_cycles,
                m.counters.forwarded_loads
            );
        }
        out.push('}');
        out
    }
}

fn fmt_f32(v: f32) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn push_str_field(out: &mut String, key: &str, value: &str) {
    push_json_string(out, key);
    out.push(':');
    push_json_string(out, value);
}

fn push_json_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping() {
        let mut s = String::new();
        push_json_string(&mut s, "a\"b\\c\nd");
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }
}
