//! The unified analysis report: one struct, optional per-pass sections,
//! a structured [`Prediction`] decomposition, and pluggable rendering
//! through the `report::emit` emitters.

use std::sync::{Arc, OnceLock};

use crate::analyzer::{Analysis, CritPathReport};
use crate::api::prediction::Prediction;
use crate::baseline::BaselinePrediction;
use crate::mdb::MachineModel;
use crate::report::emit::Format;
use crate::sim::{Measurement, MemoryAnalysis};

/// Result of one [`super::Engine::analyze`] call. Sections are present
/// for exactly the passes requested; [`AnalysisReport::prediction`]
/// assembles them into the bound decomposition.
#[derive(Debug, Clone)]
pub struct AnalysisReport {
    pub name: String,
    /// Machine name the analysis ran against.
    pub arch: String,
    pub machine: Arc<MachineModel>,
    pub unroll: usize,
    /// Output format selected on the request (used by
    /// [`AnalysisReport::render`]).
    pub format: Format,
    /// OSACA uniform-split throughput analysis ([`super::Passes::THROUGHPUT`]).
    pub throughput: Option<Analysis>,
    /// Latency bounds ([`super::Passes::CRITPATH`]).
    pub critpath: Option<CritPathReport>,
    /// ECM-style memory-hierarchy bound (present only when the opt-in
    /// `AnalysisRequest::mem_model` is set).
    pub memory: Option<MemoryAnalysis>,
    /// IACA-like balanced baseline ([`super::Passes::BASELINE`]).
    pub baseline: Option<BaselinePrediction>,
    /// Simulator measurement ([`super::Passes::SIMULATE`]).
    pub simulation: Option<Measurement>,
    /// Lazily-built shared decomposition (see
    /// [`AnalysisReport::prediction_shared`]). Cloning a report after
    /// the cell is filled shares the same `Arc<Prediction>` — that is
    /// what lets `serve`'s memo hand every memo hit the one
    /// decomposition instead of rebuilding it per response.
    pub(crate) prediction_cell: OnceLock<Arc<Prediction>>,
}

impl AnalysisReport {
    /// The structured prediction: every resource bound the requested
    /// passes produced (port pressure, opt-in frontend, divider,
    /// critical path, plus baseline/simulation observations), with the
    /// winning model bound identifying *why* the kernel is slow.
    /// Assembled on demand so it always reflects the sections present
    /// (the baseline attaches after the in-process passes).
    pub fn prediction(&self) -> Prediction {
        Prediction::from_report(self)
    }

    /// The decomposition behind a shared handle, built at most once per
    /// report (and shared by clones made afterwards). The engine only
    /// returns complete reports, so by the time a caller can reach this
    /// every requested section is attached; a caller that mutates the
    /// pass sections afterwards should use [`AnalysisReport::prediction`]
    /// to re-derive. The emitters render through this handle — one
    /// decomposition serves text, JSON and CSV output of the same
    /// report, and `serve` memo hits reuse it across responses.
    pub fn prediction_shared(&self) -> Arc<Prediction> {
        self.prediction_cell.get_or_init(|| Arc::new(self.prediction())).clone()
    }

    /// The combined analytic prediction — the max over the model
    /// bounds, cycles per assembly iteration. `None` when no
    /// model-bound pass ran.
    ///
    /// Allocation-free equivalent of `prediction().cy_per_asm_iter()`
    /// (serving loops call this per request): the throughput section's
    /// port max already equals `max(port pressure, divider)`, so only
    /// the frontend and critical-path values need folding in.
    pub fn predicted_cy_per_asm_iter(&self) -> Option<f32> {
        let mut best: Option<f32> = None;
        let mut fold = |v: f32| best = Some(best.map_or(v, |b| b.max(v)));
        if let Some(t) = &self.throughput {
            fold(t.cy_per_asm_iter);
            if let Some(f) = &t.frontend {
                fold(f.cy_per_asm_iter);
            }
        }
        if let Some(c) = &self.critpath {
            fold(c.carried_per_iteration);
        }
        if let Some(m) = &self.memory {
            fold(m.cy_per_asm_iter);
        }
        best
    }

    /// Combined prediction per *source* iteration.
    pub fn predicted_cy_per_source_it(&self) -> Option<f32> {
        self.predicted_cy_per_asm_iter().map(|cy| cy / self.unroll as f32)
    }

    /// Render in the format selected on the request
    /// (`AnalysisRequest::format`, default text).
    pub fn render(&self) -> String {
        self.format.emitter().emit(self)
    }

    /// Human-readable rendering: the paper-style occupancy table plus
    /// one line per additional section.
    pub fn to_text(&self) -> String {
        crate::report::emit::TEXT.emit(self)
    }

    /// Machine-readable JSON (versioned — see
    /// [`crate::report::emit::SCHEMA_VERSION`]).
    pub fn to_json(&self) -> String {
        crate::report::emit::JSON.emit(self)
    }

    /// Machine-readable CSV (one row per bound / port total).
    pub fn to_csv(&self) -> String {
        crate::report::emit::CSV.emit(self)
    }
}
