//! PJRT runtime: load and execute the AOT-compiled L2/L1 artifact.
//!
//! `artifacts/port_solver.hlo.txt` is produced once at build time by
//! `python/compile/aot.py` (jax + pallas, lowered to HLO *text* — see
//! that file for why text, not a serialized proto). This module loads
//! it, compiles it on the PJRT CPU client, and exposes a typed batch
//! interface. Python never runs on this path.
//!
//! The PJRT execution path needs the `xla` bindings, which are not
//! vendored in the offline build environment — it is gated behind the
//! `pjrt` cargo feature. Without the feature, [`PortSolver`] and
//! [`CritSolver`] are stubs whose loaders report the artifact as
//! unavailable, and every caller falls back to the pure-rust reference
//! solver ([`solve_cpu`]), which computes identical math.

use anyhow::{bail, Result};

/// Fixed artifact shapes — must match python/compile/model.py.
pub const BATCH: usize = 8;
pub const MAX_UOPS: usize = 64;
pub const MAX_PORTS: usize = 12;

/// A kernel encoded for the solver: admissible-port mask and cycle cost
/// per µ-op row (padded with zeros to MAX_UOPS).
#[derive(Debug, Clone, Default)]
pub struct EncodedKernel {
    /// Row-major [MAX_UOPS][MAX_PORTS].
    pub mask: Vec<f32>,
    /// [MAX_UOPS].
    pub cost: Vec<f32>,
}

impl EncodedKernel {
    pub fn empty() -> Self {
        EncodedKernel {
            mask: vec![0.0; MAX_UOPS * MAX_PORTS],
            cost: vec![0.0; MAX_UOPS],
        }
    }

    /// Add one µ-op row. Errors when the kernel exceeds MAX_UOPS.
    pub fn push_uop(&mut self, row: usize, ports: &[usize], cost: f32) -> Result<()> {
        if row >= MAX_UOPS {
            bail!("kernel exceeds {MAX_UOPS} µ-ops");
        }
        for &p in ports {
            if p >= MAX_PORTS {
                bail!("port index {p} exceeds artifact width {MAX_PORTS}");
            }
            self.mask[row * MAX_PORTS + p] = 1.0;
        }
        self.cost[row] = cost;
        Ok(())
    }
}

/// Solver outputs for one kernel.
#[derive(Debug, Clone)]
pub struct SolveOut {
    /// Per-port cumulative pressure, uniform (OSACA) scheduling.
    pub press_uniform: Vec<f32>,
    /// Per-port pressure after iterative balancing (IACA-like).
    pub press_balanced: Vec<f32>,
    /// Bottleneck cycles/iteration under uniform scheduling.
    pub tp_uniform: f32,
    /// Bottleneck cycles/iteration under balanced scheduling.
    pub tp_balanced: f32,
    /// Work lower bound (sanity channel).
    pub crit_lower: f32,
}

/// "No edge" sentinel in the adjacency encoding (max-plus -infinity).
/// Keep in sync with python/compile/kernels/critpath.py.
pub const NEG: f32 = -1.0e9;

/// A dependency graph encoded for the critical-path artifact.
#[derive(Debug, Clone)]
pub struct EncodedGraph {
    /// Row-major [MAX_UOPS][MAX_UOPS]; adj[u][v] = lat_v on edge, NEG
    /// otherwise.
    pub adj: Vec<f32>,
    /// [MAX_UOPS] per-µ-op latency.
    pub lat: Vec<f32>,
    /// Row-major [MAX_UOPS][MAX_UOPS]; 1.0 on back-edges (i -> w of the
    /// previous iteration).
    pub carried: Vec<f32>,
}

impl EncodedGraph {
    pub fn empty() -> Self {
        EncodedGraph {
            adj: vec![NEG; MAX_UOPS * MAX_UOPS],
            lat: vec![0.0; MAX_UOPS],
            carried: vec![0.0; MAX_UOPS * MAX_UOPS],
        }
    }

    pub fn set_latency(&mut self, u: usize, lat: f32) -> Result<()> {
        if u >= MAX_UOPS {
            bail!("µ-op index {u} exceeds {MAX_UOPS}");
        }
        self.lat[u] = lat;
        Ok(())
    }

    /// Edge: µ-op `v` depends on µ-op `u` (program order u < v).
    pub fn add_edge(&mut self, u: usize, v: usize) -> Result<()> {
        if u >= MAX_UOPS || v >= MAX_UOPS {
            bail!("edge ({u},{v}) exceeds {MAX_UOPS}");
        }
        self.adj[u * MAX_UOPS + v] = self.lat[v];
        Ok(())
    }

    /// Back-edge: µ-op `i` of the next iteration depends on `w`.
    pub fn add_carried(&mut self, i: usize, w: usize) -> Result<()> {
        if i >= MAX_UOPS || w >= MAX_UOPS {
            bail!("carried edge ({i},{w}) exceeds {MAX_UOPS}");
        }
        self.carried[i * MAX_UOPS + w] = 1.0;
        Ok(())
    }
}

/// Critical-path results for one graph.
#[derive(Debug, Clone, Copy)]
pub struct CritOut {
    /// Longest latency chain through one iteration.
    pub intra: f32,
    /// Loop-carried cycle bound, cycles per iteration.
    pub carried_bound: f32,
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    //! The real PJRT-backed solvers (feature `pjrt`; requires the
    //! `xla` bindings to be added as a dependency).

    use std::path::Path;

    use anyhow::{anyhow, bail, Context, Result};

    use super::{CritOut, EncodedGraph, EncodedKernel, SolveOut, BATCH, MAX_PORTS, MAX_UOPS, NEG};

    /// The loaded artifact: a compiled PJRT executable.
    pub struct PortSolver {
        exe: xla::PjRtLoadedExecutable,
    }

    impl PortSolver {
        /// Default artifact location relative to the repo root.
        pub const DEFAULT_PATH: &'static str = "artifacts/port_solver.hlo.txt";

        /// Load + compile the artifact on the PJRT CPU client.
        pub fn load(path: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(wrap_xla)
                .with_context(|| format!("loading HLO text from {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(wrap_xla)?;
            Ok(PortSolver { exe })
        }

        /// Load from the default path, searching upward from the current
        /// directory (tests and benches run from different cwds).
        pub fn load_default() -> Result<Self> {
            let mut dir = std::env::current_dir()?;
            loop {
                let cand = dir.join(Self::DEFAULT_PATH);
                if cand.exists() {
                    return Self::load(&cand);
                }
                if !dir.pop() {
                    bail!(
                        "artifact {} not found (run `make artifacts` first)",
                        Self::DEFAULT_PATH
                    );
                }
            }
        }

        /// Solve a batch of up to BATCH kernels in one artifact execution.
        pub fn solve(&self, kernels: &[EncodedKernel]) -> Result<Vec<SolveOut>> {
            if kernels.len() > BATCH {
                bail!("batch of {} exceeds artifact batch size {BATCH}", kernels.len());
            }
            let mut mask = Vec::with_capacity(BATCH * MAX_UOPS * MAX_PORTS);
            let mut cost = Vec::with_capacity(BATCH * MAX_UOPS);
            for k in kernels {
                debug_assert_eq!(k.mask.len(), MAX_UOPS * MAX_PORTS);
                debug_assert_eq!(k.cost.len(), MAX_UOPS);
                mask.extend_from_slice(&k.mask);
                cost.extend_from_slice(&k.cost);
            }
            // Pad the batch.
            mask.resize(BATCH * MAX_UOPS * MAX_PORTS, 0.0);
            cost.resize(BATCH * MAX_UOPS, 0.0);

            let mask_lit = xla::Literal::vec1(&mask)
                .reshape(&[BATCH as i64, MAX_UOPS as i64, MAX_PORTS as i64])
                .map_err(wrap_xla)?;
            let cost_lit = xla::Literal::vec1(&cost)
                .reshape(&[BATCH as i64, MAX_UOPS as i64])
                .map_err(wrap_xla)?;
            let result =
                self.exe.execute::<xla::Literal>(&[mask_lit, cost_lit]).map_err(wrap_xla)?;
            let tuple = result[0][0].to_literal_sync().map_err(wrap_xla)?;
            let parts = tuple.to_tuple().map_err(wrap_xla)?;
            if parts.len() != 5 {
                bail!("artifact returned {}-tuple, expected 5", parts.len());
            }
            let press_u = parts[0].to_vec::<f32>().map_err(wrap_xla)?;
            let press_b = parts[1].to_vec::<f32>().map_err(wrap_xla)?;
            let tp_u = parts[2].to_vec::<f32>().map_err(wrap_xla)?;
            let tp_b = parts[3].to_vec::<f32>().map_err(wrap_xla)?;
            let lower = parts[4].to_vec::<f32>().map_err(wrap_xla)?;

            Ok((0..kernels.len())
                .map(|i| SolveOut {
                    press_uniform: press_u[i * MAX_PORTS..(i + 1) * MAX_PORTS].to_vec(),
                    press_balanced: press_b[i * MAX_PORTS..(i + 1) * MAX_PORTS].to_vec(),
                    tp_uniform: tp_u[i],
                    tp_balanced: tp_b[i],
                    crit_lower: lower[i],
                })
                .collect())
        }
    }

    fn wrap_xla(e: xla::Error) -> anyhow::Error {
        anyhow!("xla: {e}")
    }

    /// The critical-path artifact (see python/compile/kernels/critpath.py).
    pub struct CritSolver {
        exe: xla::PjRtLoadedExecutable,
    }

    impl CritSolver {
        pub const DEFAULT_PATH: &'static str = "artifacts/critpath.hlo.txt";

        pub fn load(path: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(wrap_xla)
                .with_context(|| format!("loading HLO text from {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(wrap_xla)?;
            Ok(CritSolver { exe })
        }

        pub fn load_default() -> Result<Self> {
            let mut dir = std::env::current_dir()?;
            loop {
                let cand = dir.join(Self::DEFAULT_PATH);
                if cand.exists() {
                    return Self::load(&cand);
                }
                if !dir.pop() {
                    bail!("artifact {} not found (run `make artifacts`)", Self::DEFAULT_PATH);
                }
            }
        }

        /// Solve a batch of up to BATCH graphs in one execution.
        pub fn solve(&self, graphs: &[EncodedGraph]) -> Result<Vec<CritOut>> {
            if graphs.len() > BATCH {
                bail!("batch of {} exceeds artifact batch size {BATCH}", graphs.len());
            }
            let mut adj = Vec::with_capacity(BATCH * MAX_UOPS * MAX_UOPS);
            let mut lat = Vec::with_capacity(BATCH * MAX_UOPS);
            let mut carried = Vec::with_capacity(BATCH * MAX_UOPS * MAX_UOPS);
            for g in graphs {
                adj.extend_from_slice(&g.adj);
                lat.extend_from_slice(&g.lat);
                carried.extend_from_slice(&g.carried);
            }
            adj.resize(BATCH * MAX_UOPS * MAX_UOPS, NEG);
            lat.resize(BATCH * MAX_UOPS, 0.0);
            carried.resize(BATCH * MAX_UOPS * MAX_UOPS, 0.0);
            let dims3 = [BATCH as i64, MAX_UOPS as i64, MAX_UOPS as i64];
            let adj_lit = xla::Literal::vec1(&adj).reshape(&dims3).map_err(wrap_xla)?;
            let lat_lit = xla::Literal::vec1(&lat)
                .reshape(&[BATCH as i64, MAX_UOPS as i64])
                .map_err(wrap_xla)?;
            let car_lit = xla::Literal::vec1(&carried).reshape(&dims3).map_err(wrap_xla)?;
            let result = self
                .exe
                .execute::<xla::Literal>(&[adj_lit, lat_lit, car_lit])
                .map_err(wrap_xla)?;
            let tuple = result[0][0].to_literal_sync().map_err(wrap_xla)?;
            let parts = tuple.to_tuple().map_err(wrap_xla)?;
            if parts.len() != 2 {
                bail!("critpath artifact returned {}-tuple, expected 2", parts.len());
            }
            let intra = parts[0].to_vec::<f32>().map_err(wrap_xla)?;
            let bound = parts[1].to_vec::<f32>().map_err(wrap_xla)?;
            Ok((0..graphs.len())
                .map(|i| CritOut { intra: intra[i], carried_bound: bound[i] })
                .collect())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod pjrt_impl {
    //! Stub solvers for builds without the `pjrt` feature. The loaders
    //! fail with a clear message; callers (coordinator, CLI, tests)
    //! treat that exactly like a missing artifact and fall back to
    //! [`super::solve_cpu`].

    use std::path::Path;

    use anyhow::{bail, Result};

    use super::{CritOut, EncodedGraph, EncodedKernel, SolveOut, BATCH};

    /// Stub port solver (built without the `pjrt` feature).
    pub struct PortSolver {
        _private: (),
    }

    impl PortSolver {
        pub const DEFAULT_PATH: &'static str = "artifacts/port_solver.hlo.txt";

        pub fn load(_path: &Path) -> Result<Self> {
            bail!("built without the `pjrt` feature; PJRT artifact execution is unavailable")
        }

        pub fn load_default() -> Result<Self> {
            bail!("built without the `pjrt` feature; using the cpu reference solver")
        }

        pub fn solve(&self, kernels: &[EncodedKernel]) -> Result<Vec<SolveOut>> {
            if kernels.len() > BATCH {
                bail!("batch of {} exceeds artifact batch size {BATCH}", kernels.len());
            }
            unreachable!("stub PortSolver cannot be constructed")
        }
    }

    /// Stub critical-path solver (built without the `pjrt` feature).
    pub struct CritSolver {
        _private: (),
    }

    impl CritSolver {
        pub const DEFAULT_PATH: &'static str = "artifacts/critpath.hlo.txt";

        pub fn load(_path: &Path) -> Result<Self> {
            bail!("built without the `pjrt` feature; PJRT artifact execution is unavailable")
        }

        pub fn load_default() -> Result<Self> {
            bail!("built without the `pjrt` feature; using the cpu reference analysis")
        }

        pub fn solve(&self, graphs: &[EncodedGraph]) -> Result<Vec<CritOut>> {
            if graphs.len() > BATCH {
                bail!("batch of {} exceeds artifact batch size {BATCH}", graphs.len());
            }
            unreachable!("stub CritSolver cannot be constructed")
        }
    }
}

pub use pjrt_impl::{CritSolver, PortSolver};

/// Pure-rust reference of the solver math (mirrors
/// python/compile/kernels/ref.py). Used as the no-artifact fallback and
/// to cross-check the PJRT path in integration tests.
pub fn solve_cpu(kernels: &[EncodedKernel], iters: usize) -> Vec<SolveOut> {
    const ETA: f32 = 0.35; // keep in sync with python DEFAULT/ETA
    kernels
        .iter()
        .map(|k| {
            let u = MAX_UOPS;
            let p = MAX_PORTS;
            let nports: Vec<f32> =
                (0..u).map(|r| k.mask[r * p..(r + 1) * p].iter().sum()).collect();
            // Uniform split.
            let mut press_u = vec![0f32; p];
            for r in 0..u {
                if nports[r] > 0.0 {
                    let share = k.cost[r] / nports[r];
                    for j in 0..p {
                        press_u[j] += k.mask[r * p + j] * share;
                    }
                }
            }
            // Balanced (multiplicative weights).
            let mut w = vec![0f32; u * p];
            for r in 0..u {
                if nports[r] > 0.0 {
                    for j in 0..p {
                        w[r * p + j] = k.mask[r * p + j] / nports[r];
                    }
                }
            }
            let mut press_b = vec![0f32; p];
            for _ in 0..iters {
                press_b.iter_mut().for_each(|x| *x = 0.0);
                for r in 0..u {
                    for j in 0..p {
                        press_b[j] += w[r * p + j] * k.cost[r];
                    }
                }
                for r in 0..u {
                    if nports[r] == 0.0 {
                        continue;
                    }
                    let mut norm = 0f32;
                    for j in 0..p {
                        let upd = w[r * p + j] * (-ETA * press_b[j]).exp() * k.mask[r * p + j];
                        w[r * p + j] = upd;
                        norm += upd;
                    }
                    let norm = norm.max(1e-30);
                    for j in 0..p {
                        w[r * p + j] /= norm;
                    }
                }
            }
            press_b.iter_mut().for_each(|x| *x = 0.0);
            for r in 0..u {
                for j in 0..p {
                    press_b[j] += w[r * p + j] * k.cost[r];
                }
            }
            let tp_u = press_u.iter().cloned().fold(0.0, f32::max);
            let tp_b = press_b.iter().cloned().fold(0.0, f32::max);
            let used: f32 = (0..p)
                .map(|j| (0..u).map(|r| k.mask[r * p + j]).fold(0.0, f32::max))
                .sum();
            let total: f32 = k.cost.iter().sum();
            SolveOut {
                press_uniform: press_u,
                press_balanced: press_b,
                tp_uniform: tp_u,
                tp_balanced: tp_b,
                crit_lower: total / used.max(1.0),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encoded_kernel_bounds() {
        let mut k = EncodedKernel::empty();
        assert!(k.push_uop(0, &[0, 1], 1.0).is_ok());
        assert!(k.push_uop(MAX_UOPS, &[0], 1.0).is_err());
        assert!(k.push_uop(1, &[MAX_PORTS], 1.0).is_err());
    }

    #[test]
    fn cpu_solver_uniform_two_ports() {
        let mut k = EncodedKernel::empty();
        k.push_uop(0, &[0, 1], 1.0).unwrap();
        let out = solve_cpu(&[k], 32);
        assert!((out[0].press_uniform[0] - 0.5).abs() < 1e-6);
        assert!((out[0].tp_uniform - 0.5).abs() < 1e-6);
    }

    #[test]
    fn cpu_solver_balanced_resolves_asymmetry() {
        // add {0,1} + mul {0}: uniform 1.5, balanced -> ~1.0.
        let mut k = EncodedKernel::empty();
        k.push_uop(0, &[0, 1], 1.0).unwrap();
        k.push_uop(1, &[0], 1.0).unwrap();
        let out = solve_cpu(&[k], 32);
        assert!((out[0].tp_uniform - 1.5).abs() < 1e-6);
        assert!(out[0].tp_balanced < 1.1, "{}", out[0].tp_balanced);
    }

    #[test]
    fn cpu_solver_mass_conserved() {
        let mut k = EncodedKernel::empty();
        k.push_uop(0, &[0, 1, 2], 1.5).unwrap();
        k.push_uop(1, &[3], 2.0).unwrap();
        let out = solve_cpu(&[k], 32);
        let total_u: f32 = out[0].press_uniform.iter().sum();
        let total_b: f32 = out[0].press_balanced.iter().sum();
        assert!((total_u - 3.5).abs() < 1e-5);
        assert!((total_b - 3.5).abs() < 1e-4);
    }

    #[test]
    fn stub_or_real_solver_reports_consistently() {
        // Without the artifact (or without the `pjrt` feature), loading
        // fails with an error message rather than panicking.
        if let Err(e) = PortSolver::load_default() {
            let msg = format!("{e:#}");
            assert!(!msg.is_empty());
        }
    }
}
