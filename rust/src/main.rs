//! osaca CLI — the L3 coordinator binary.
//!
//! Every subcommand goes through the `osaca::api::Engine` session
//! layer: one machine-model registry, one batching coordinator, one
//! request/report shape, structured errors.
//!
//! Subcommands (all take `--format text|json|csv`; `analyze` also
//! takes `--frontend-bound` for the width-aware frontend bound):
//!   analyze <file.s> --arch skl|zen|hsw|tx2|rv64 [--baseline] [--critpath] [--frontend-bound] [--json]
//!   simulate <file.s> --arch skl|zen|tx2|rv64 [--iterations N]
//!   ibench --instr <form> --arch skl|zen|tx2|rv64 [--conflict <form>]
//!   build-model --instr <form> --arch skl|zen|tx2|rv64
//!   validate-model --arch skl|zen
//!   compare <file.s> --arch skl|zen [--unroll N]
//!   tables [--table1] [--table3] [--table5] [--all]
//!   figures
//!   serve [--addr host:port] [--shards N] [--memo-cap N] [--memo-max-bytes N] [--max-rps R]
//!         [--burst N] [--max-inflight N] [--max-frame-bytes N] [--chaos [seed]] [--test-ops]
//!         (persistent TCP service; --loopback for the in-process batch demo)
//!   corpus <dir|archive.tar|file.s> [--arch skl] [--measured file.csv] [--frontend-bound]
//!         (score a corpus of basic blocks; scorecard to stdout)
//!   mem-sweep [--arch skl] [--workload triad-strided] [--sizes 16K,1M,64M]
//!         (working-set sweep under the opt-in memory model)
//!   import-model <uops.xml> --arch clx|icl|zen2 [--out models]
//!         (model zoo: compile a uops.info XML dump into a .mdb model)
//!   zoo-sweep (every workload fixture x every registered model)
//!   list-workloads
//!
//! Every subcommand also accepts `--models-dir <dir>` to register the
//! `*.mdb` files inside with the dynamic model registry.
//!
//! `analyze`, `simulate`, `compare`, and `corpus` also take
//! `--mem-model [spec]` to switch on the opt-in cache hierarchy + LSQ
//! (see `sim::mem::MemModel` for the spec grammar).
//!
//! Hand-rolled argument parsing: clap is not vendored in this offline
//! build environment.

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use osaca::api::{Engine, Format, Passes};
use osaca::benchlib::{format_table, print_table};
use osaca::builder::{default_probes, infer_entry, validate_model};
use osaca::ibench::{run_conflict, run_sweep, BenchSpec};
use osaca::isa::InstructionForm;
use osaca::mdb::MachineModel;
use osaca::report::emit::{csv_field, json_string};
use osaca::report::emit::SCHEMA_VERSION;
use osaca::report::experiments::{
    mem_sweep, render_mem_sweep, render_table1, render_table3, render_table5, render_zoo_sweep,
    table1, table3, table5, zoo_sweep, MEM_SWEEP_SIZES,
};
use osaca::report::render_port_diagram;
use osaca::serve::{ServeConfig, Server};
use osaca::sim::SimConfig;
use osaca::{asm, corpus, workloads};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Split `args` into positional arguments and `--key [value]` options.
fn parse_opts(args: &[String]) -> (Vec<&str>, HashMap<&str, &str>) {
    let mut pos = Vec::new();
    let mut opts = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = args[i].as_str();
        if let Some(key) = a.strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].as_str()
            } else {
                "true"
            };
            opts.insert(key, val);
        } else {
            pos.push(a);
        }
        i += 1;
    }
    (pos, opts)
}

fn machine_opt(engine: &Engine, opts: &HashMap<&str, &str>) -> Result<Arc<MachineModel>> {
    let arch = opts.get("arch").copied().unwrap_or("skl");
    engine.machine(arch).map_err(|e| anyhow!("{e}"))
}

fn load_kernel(path: &str, isa: osaca::isa::Isa) -> Result<asm::Kernel> {
    let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    asm::extract_kernel_isa(path, &src, isa)
}

/// Print a generic table in the selected `--format`.
fn emit_table(format: Format, title: &str, header: &[&str], rows: &[Vec<String>]) {
    let s = format_table(format, title, header, rows);
    if format == Format::Json {
        println!("{s}");
    } else {
        print!("{s}");
    }
}

/// Print a rendered report: text keeps its trailing layout, the
/// machine-readable formats get a final newline for shell pipelines.
fn emit_report(report: &osaca::api::AnalysisReport) {
    let s = report.render();
    if report.format == Format::Json {
        println!("{s}");
    } else {
        print!("{s}");
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    let (pos, opts) = parse_opts(rest);
    // `--format text|json|csv` is accepted by every subcommand; unknown
    // names fail fast with the structured UnsupportedFormat error.
    let format = match opts.get("format") {
        Some(v) => Format::parse(v).map_err(|e| anyhow!("{e}"))?,
        None => Format::Text,
    };
    let engine = Engine::new();
    // `--models-dir <dir>` (accepted by every subcommand) registers
    // each `*.mdb` file in the process-wide dynamic registry before
    // dispatch, so `--arch clx` works anywhere a built-in name does.
    if let Some(dir) = opts.get("models-dir") {
        osaca::mdb::scan_models_dir(std::path::Path::new(dir))
            .with_context(|| format!("scanning --models-dir {dir}"))?;
    }
    match cmd.as_str() {
        "analyze" => {
            let path = pos.first().ok_or_else(|| {
                anyhow!("usage: analyze <file.s> --arch skl|zen [--model file.mdb] [--learn] [--baseline] [--critpath] [--frontend-bound] [--format text|json|csv]")
            })?;
            // --model loads a (possibly partial) user model file; --arch
            // still selects the hardware substrate for --learn.
            let hardware = machine_opt(&engine, &opts)?;
            let machine: Arc<MachineModel> = match opts.get("model") {
                Some(p) => engine
                    .register_model_text(
                        &std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?,
                    )
                    .map_err(|e| anyhow!("{e}"))?,
                None => hardware.clone(),
            };
            let kernel = load_kernel(path, machine.isa)?;
            // `--json` predates `--format` and remains as an alias.
            let format = if opts.contains_key("json") { Format::Json } else { format };
            let machine = if opts.contains_key("learn") {
                // §III: benchmark unknown forms automatically on the
                // hardware substrate and register the extended model.
                let mut learned_model = machine.as_ref().clone();
                let learned =
                    osaca::builder::learn_missing(&kernel, &mut learned_model, &hardware)?;
                for inf in &learned {
                    // Progress notes must not corrupt machine-readable
                    // stdout: route them to stderr unless in text mode.
                    let line = format!(
                        "learned {}: lat {:.1} cy, rTP {:.2} cy/instr (probes: {:?})",
                        inf.entry.form,
                        inf.measured_latency,
                        inf.measured_rtp,
                        inf.conflicting_probes
                    );
                    if format == Format::Text {
                        println!("{line}");
                    } else {
                        eprintln!("{line}");
                    }
                }
                engine.register_machine(learned_model)
            } else {
                machine
            };
            let mut passes = Passes::THROUGHPUT;
            if opts.contains_key("critpath") {
                passes |= Passes::CRITPATH;
            }
            if opts.contains_key("baseline") {
                passes |= Passes::BASELINE;
            }
            let mut req = Engine::request(path)
                .machine(machine)
                .kernel(kernel)
                .passes(passes)
                .frontend_bound(opts.contains_key("frontend-bound"))
                .format(format);
            // Bare `--mem-model` means "machine defaults"; a value is
            // the spec grammar (`l1=32K:4,l2=1M:12,mem=:80,ws=4M,...`).
            if let Some(spec) = opts.get("mem-model") {
                req = req.mem_model(*spec);
            }
            let report = engine.analyze(&req).map_err(|e| anyhow!("{e}"))?;
            emit_report(&report);
        }
        "simulate" => {
            let path = pos
                .first()
                .ok_or_else(|| anyhow!("usage: simulate <file.s> --arch skl|zen"))?;
            let machine = machine_opt(&engine, &opts)?;
            let iterations: usize =
                opts.get("iterations").map(|v| v.parse()).transpose()?.unwrap_or(1000);
            let mut req = Engine::request(path)
                .machine(machine.clone())
                .kernel(load_kernel(path, machine.isa)?)
                .passes(Passes::SIMULATE)
                .format(format)
                .sim_config(SimConfig { iterations, warmup: iterations / 5 });
            if let Some(spec) = opts.get("mem-model") {
                req = req.mem_model(*spec);
            }
            let report = engine.analyze(&req).map_err(|e| anyhow!("{e}"))?;
            if format != Format::Text {
                emit_report(&report);
                return Ok(());
            }
            let m = report.simulation.as_ref().expect("simulation pass ran");
            println!(
                "{}: {:.3} cy / assembly iteration over {} measured iterations",
                machine.name, m.cycles_per_iteration, m.iterations
            );
            println!(
                "counters: issue-stall {} / {} cy ({:.1}%), dispatch-stall {}, µops {} ({} forwarded loads)",
                m.counters.issue_stall_cycles,
                m.window_cycles,
                100.0 * m.counters.issue_stall_cycles as f64 / m.window_cycles as f64,
                m.counters.dispatch_stall_cycles,
                m.counters.uops_executed,
                m.counters.forwarded_loads,
            );
            if let Some(mem) = &report.memory {
                println!(
                    "memory model: {} in {} ({} streams, {} B/iter), lsq-stall {} cy, {} cache-miss loads",
                    mem.working_set_human(),
                    mem.level,
                    mem.streams,
                    mem.bytes_per_iter,
                    m.counters.lsq_stall_cycles,
                    m.counters.cache_miss_loads,
                );
            }
            let busy: Vec<String> = machine
                .ports
                .iter()
                .zip(m.port_busy.iter())
                .map(|(p, b)| format!("{p}:{:.2}", *b as f64 / m.iterations as f64))
                .collect();
            println!("port busy cy/iter: {}", busy.join(" "));
        }
        "ibench" => {
            let machine = machine_opt(&engine, &opts)?;
            let instr = opts
                .get("instr")
                .ok_or_else(|| anyhow!("usage: ibench --instr vaddpd-xmm_xmm_xmm --arch skl"))?;
            let spec = BenchSpec::parse(instr);
            if let Some(dir) = opts.get("emit") {
                let files = osaca::ibench::runner::emit_bench_files(
                    &spec,
                    machine.isa,
                    std::path::Path::new(dir),
                )?;
                for f in &files {
                    println!("wrote {}", f.display());
                }
                return Ok(());
            }
            if let Some(other) = opts.get("conflict") {
                let b = BenchSpec::parse(other);
                let r = run_conflict(&spec, &b, &machine)?;
                if format != Format::Text {
                    emit_table(
                        format,
                        "ibench conflict",
                        &["benchmark", "cy_per_instr"],
                        &[vec![r.label.clone(), format!("{:.3}", r.cy_per_instr)]],
                    );
                    return Ok(());
                }
                println!("Using frequency {:.2}GHz.", machine.frequency_ghz);
                println!("{}:  {:.3} (clk cy)", r.label, r.cy_per_instr);
            } else {
                let sweep = run_sweep(&spec, &machine)?;
                if format != Format::Text {
                    let mut rows =
                        vec![vec![format!("{}-1", sweep.form), format!("{:.3}", sweep.latency)]];
                    for (k, cy) in &sweep.points {
                        rows.push(vec![format!("{}-{k}", sweep.form), format!("{cy:.3}")]);
                    }
                    rows.push(vec![format!("{}-TP", sweep.form), format!("{:.3}", sweep.tp)]);
                    emit_table(format, "ibench sweep", &["benchmark", "cy_per_instr"], &rows);
                    return Ok(());
                }
                print!("{}", sweep.render(machine.frequency_ghz));
            }
        }
        "build-model" => {
            let machine = machine_opt(&engine, &opts)?;
            let instr = opts
                .get("instr")
                .ok_or_else(|| anyhow!("usage: build-model --instr <form> --arch skl"))?;
            let form = InstructionForm::parse(instr);
            let probes = default_probes(&machine);
            let inf = infer_entry(&form, &machine, &probes)?;
            let mut m2 = machine.as_ref().clone();
            m2.entries.clear();
            m2.insert(inf.entry.clone());
            let line = m2
                .serialize()
                .lines()
                .find(|l| l.starts_with("entry"))
                .unwrap_or_default()
                .to_string();
            if format != Format::Text {
                emit_table(
                    format,
                    "build-model",
                    &["form", "latency_cy", "rtp_cy_per_instr", "conflicting_probes", "entry"],
                    &[vec![
                        inf.entry.form.to_string(),
                        format!("{:.2}", inf.measured_latency),
                        format!("{:.3}", inf.measured_rtp),
                        format!("{:?}", inf.conflicting_probes),
                        line,
                    ]],
                );
                return Ok(());
            }
            println!(
                "measured: latency {:.2} cy, rTP {:.3} cy/instr",
                inf.measured_latency, inf.measured_rtp
            );
            println!("conflicting probes: {:?}", inf.conflicting_probes);
            println!("database entry: {line}");
        }
        "validate-model" => {
            let machine = machine_opt(&engine, &opts)?;
            let forms: Vec<InstructionForm> = [
                "vaddpd-xmm_xmm_xmm",
                "vmulpd-xmm_xmm_xmm",
                "vfmadd132pd-xmm_xmm_xmm",
                "vfmadd132pd-mem_xmm_xmm",
                "vdivsd-xmm_xmm_xmm",
                "vpaddd-xmm_xmm_xmm",
                "add-imm_r",
            ]
            .iter()
            .map(|s| InstructionForm::parse(s))
            .collect();
            let rows = validate_model(&machine, &forms)?;
            let table: Vec<Vec<String>> = rows
                .iter()
                .map(|r| {
                    vec![
                        r.form.clone(),
                        format!("{:.2}", r.db_latency),
                        format!("{:.2}", r.inferred_latency),
                        format!("{:.2}", r.db_rtp),
                        format!("{:.2}", r.inferred_rtp),
                        format!("{}", r.ports_match),
                        if r.ok() { "OK".into() } else { "MISMATCH".into() },
                    ]
                })
                .collect();
            emit_table(
                format,
                &format!("model validation ({})", machine.name),
                &["form", "db lat", "meas lat", "db rTP", "meas rTP", "ports", "verdict"],
                &table,
            );
        }
        "compare" => {
            let path =
                pos.first().ok_or_else(|| anyhow!("usage: compare <file.s> --arch skl|zen"))?;
            let machine = machine_opt(&engine, &opts)?;
            let unroll: usize = opts.get("unroll").map(|v| v.parse()).transpose()?.unwrap_or(1);
            let mut req = Engine::request(path)
                .machine(machine.clone())
                .kernel(load_kernel(path, machine.isa)?)
                .passes(Passes::ALL)
                .format(format)
                .unroll(unroll);
            if let Some(spec) = opts.get("mem-model") {
                req = req.mem_model(*spec);
            }
            let r = engine.analyze(&req).map_err(|e| anyhow!("{e}"))?;
            if format != Format::Text {
                // The report carries all four passes; the emitters
                // already speak the bound vocabulary.
                emit_report(&r);
                return Ok(());
            }
            let osaca = r.throughput.as_ref().expect("throughput pass");
            let baseline = r.baseline.as_ref().expect("baseline pass");
            let critpath = r.critpath.as_ref().expect("critpath pass");
            let m = r.simulation.as_ref().expect("simulate pass");
            let mut rows = vec![
                vec![
                    "OSACA (uniform ports)".into(),
                    format!("{:.2}", osaca.cy_per_asm_iter),
                    format!("{:.2}", osaca.cy_per_asm_iter / unroll as f32),
                ],
                vec![
                    "balanced baseline (batched solver)".into(),
                    format!("{:.2}", baseline.cy_per_asm_iter),
                    format!("{:.2}", baseline.cy_per_asm_iter / unroll as f32),
                ],
                vec![
                    "critical-path bound".into(),
                    format!("{:.2}", critpath.carried_per_iteration),
                    format!("{:.2}", critpath.carried_per_iteration / unroll as f32),
                ],
            ];
            if let Some(mem) = &r.memory {
                rows.push(vec![
                    format!("memory bound ({} in {})", mem.working_set_human(), mem.level),
                    format!("{:.2}", mem.cy_per_asm_iter),
                    format!("{:.2}", mem.cy_per_asm_iter / unroll as f32),
                ]);
            }
            rows.push(vec![
                "simulated hardware".into(),
                format!("{:.2}", m.cycles_per_iteration),
                format!("{:.2}", m.cy_per_source_it(unroll)),
            ]);
            print_table(
                &format!("{path} on {}", machine.name),
                &["predictor", "cy/asm-iter", "cy/src-it"],
                &rows,
            );
        }
        "tables" => {
            let coord = engine.coordinator();
            // No table selector (only e.g. `--format`) means all.
            let all = opts.contains_key("all")
                || !["table1", "table3", "table5"].iter().any(|t| opts.contains_key(*t));
            let cfg = SimConfig::default();
            let mut selected: Vec<(&str, Vec<&str>, Vec<Vec<String>>)> = Vec::new();
            if all || opts.contains_key("table1") {
                selected.push((
                    "Table I: triad throughput analyses (cy per assembly iteration)",
                    vec![
                        "compiled for",
                        "flag",
                        "unroll",
                        "OSACA Zen",
                        "OSACA SKL",
                        "IACA-like SKL",
                    ],
                    render_table1(&table1(coord)?),
                ));
            }
            if all || opts.contains_key("table3") {
                selected.push((
                    "Table III: triad measured (simulator @1.8GHz) vs predictions",
                    vec![
                        "executed on",
                        "compiled for",
                        "flag",
                        "unroll",
                        "MFLOP/s",
                        "Mit/s",
                        "measured cy/it",
                        "OSACA cy/it",
                        "IACA-like cy/it",
                    ],
                    render_table3(&table3(coord, cfg)?),
                ));
            }
            if all || opts.contains_key("table5") {
                selected.push((
                    "Table V: pi benchmark predictions vs measurement",
                    vec!["arch", "flag", "IACA-like", "OSACA", "measured cy/it", "stall cy"],
                    render_table5(&table5(coord, cfg)?),
                ));
            }
            match format {
                Format::Json => {
                    // One JSON document, not one per table — consumers
                    // pipe this straight into json.tool / jq.
                    let docs: Vec<String> = selected
                        .iter()
                        .map(|(title, header, rows)| format_table(format, title, header, rows))
                        .collect();
                    println!("{{\"tables\":[{}]}}", docs.join(","));
                }
                Format::Csv => {
                    // CSV has no multi-table framing: concatenating
                    // tables with different headers/arities would be a
                    // ragged stream, so require one table per document.
                    if selected.len() > 1 {
                        bail!(
                            "--format csv emits one table per document; select one of \
                             --table1 | --table3 | --table5 (or use --format json for all)"
                        );
                    }
                    for (title, header, rows) in &selected {
                        emit_table(format, title, header, rows);
                    }
                }
                Format::Text => {
                    for (title, header, rows) in &selected {
                        emit_table(format, title, header, rows);
                    }
                }
            }
        }
        "figures" => {
            match format {
                Format::Text => {
                    for arch in ["skl", "zen"] {
                        let m = engine.machine(arch).map_err(|e| anyhow!("{e}"))?;
                        println!("{}", render_port_diagram(&m));
                    }
                }
                Format::Json => {
                    let mut out = String::from("{\"figures\":[");
                    for (i, arch) in ["skl", "zen"].iter().enumerate() {
                        let m = engine.machine(arch).map_err(|e| anyhow!("{e}"))?;
                        if i > 0 {
                            out.push(',');
                        }
                        out.push_str("{\"arch\":");
                        out.push_str(&json_string(arch));
                        out.push_str(",\"diagram\":");
                        out.push_str(&json_string(&render_port_diagram(&m)));
                        out.push('}');
                    }
                    out.push_str("]}");
                    println!("{out}");
                }
                Format::Csv => {
                    println!("arch,diagram");
                    for arch in ["skl", "zen"] {
                        let m = engine.machine(arch).map_err(|e| anyhow!("{e}"))?;
                        println!("{arch},{}", csv_field(&render_port_diagram(&m)));
                    }
                }
            }
        }
        "serve" => {
            // `--loopback` keeps the old in-process batch demo; the
            // default is the persistent TCP service (`osaca::serve`).
            if opts.contains_key("loopback") {
                let n: usize =
                    opts.get("requests").map(|v| v.parse()).transpose()?.unwrap_or(64);
                serve_demo(&engine, n, format)?;
                return Ok(());
            }
            let mut cfg = ServeConfig {
                addr: opts.get("addr").unwrap_or(&"127.0.0.1:7117").to_string(),
                ..ServeConfig::default()
            };
            if let Some(v) = opts.get("shards") {
                cfg.shards = v.parse::<usize>().context("--shards")?.max(1);
            }
            if let Some(v) = opts.get("memo-cap") {
                cfg.memo_cap = v.parse().context("--memo-cap")?;
            }
            if let Some(v) = opts.get("queue-depth") {
                cfg.queue_depth = v.parse::<usize>().context("--queue-depth")?.max(1);
            }
            if let Some(v) = opts.get("memo-max-bytes") {
                cfg.memo_max_bytes = v.parse().context("--memo-max-bytes")?;
            }
            if let Some(v) = opts.get("max-rps") {
                cfg.max_rps = v.parse::<f64>().context("--max-rps")?.max(0.0);
            }
            if let Some(v) = opts.get("burst") {
                cfg.burst = v.parse::<u32>().context("--burst")?.max(1);
            }
            if let Some(v) = opts.get("max-inflight") {
                cfg.max_inflight = v.parse().context("--max-inflight")?;
            }
            if let Some(v) = opts.get("max-frame-bytes") {
                cfg.max_frame_bytes = v.parse::<usize>().context("--max-frame-bytes")?.max(1024);
            }
            cfg.test_ops = opts.contains_key("test-ops");
            // The global scan above already registered the directory's
            // models; handing it to the server additionally enables the
            // `reload_models` wire op to re-scan without a restart.
            cfg.models_dir = opts.get("models-dir").map(|s| s.to_string());
            if let Some(v) = opts.get("chaos") {
                // Bare `--chaos` uses the default seed; a value pins one.
                cfg.chaos_seed = Some(if *v == "true" {
                    osaca::serve::faults::DEFAULT_CHAOS_SEED
                } else {
                    v.parse::<u64>().context("--chaos")?
                });
            }
            let server = Server::bind(cfg.clone())
                .with_context(|| format!("binding {}", cfg.addr))?;
            // The smoke harness greps this exact line for the resolved
            // (possibly ephemeral) address.
            println!("serving on {}", server.local_addr());
            println!(
                "shards={} memo-cap={} queue-depth={} (send {{\"op\":\"shutdown\"}} to stop)",
                cfg.shards, cfg.memo_cap, cfg.queue_depth
            );
            if let Some(seed) = cfg.chaos_seed {
                println!("chaos fault injection enabled (seed {seed})");
            }
            server.join();
            println!("drained cleanly");
        }
        "corpus" => {
            let path = pos.first().ok_or_else(|| {
                anyhow!(
                    "usage: corpus <dir|archive.tar|file.s> [--arch skl] [--measured file.csv] \
                     [--frontend-bound] [--chunk N] [--format text|json|csv]"
                )
            })?;
            let blocks = corpus::load_blocks(std::path::Path::new(path))?;
            let mut copts = corpus::CorpusOptions {
                arch: opts.get("arch").copied().unwrap_or("skl").to_string(),
                frontend_bound: opts.contains_key("frontend-bound"),
                mem_model: opts.get("mem-model").map(|s| s.to_string()),
                ..Default::default()
            };
            if let Some(v) = opts.get("chunk") {
                copts.chunk = v.parse::<usize>().context("--chunk")?.max(1);
            }
            let mut card = corpus::score_blocks(&engine, &blocks, &copts);
            if let Some(p) = opts.get("measured") {
                let csv =
                    std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
                corpus::attach_measured(&mut card, &csv)?;
            }
            match format {
                Format::Json => println!("{}", card.render_json()),
                Format::Csv => print!("{}", card.render_csv()),
                Format::Text => {
                    println!(
                        "corpus: {} blocks on {} ({} errors)",
                        card.scores.len(),
                        card.arch,
                        card.errors()
                    );
                    for (kind, n) in &card.histogram {
                        println!("  {kind:<14} {n}");
                    }
                    if let Some(m) = card.mape_pct {
                        println!(
                            "MAPE vs measured: {m:.2}% over {} blocks",
                            card.measured_blocks
                        );
                    }
                }
            }
        }
        "mem-sweep" => {
            // Working-set sweep under the opt-in memory model: one
            // analytic prediction per pinned footprint, next to the
            // infinite-L1 prediction. `ci.sh --mem-smoke` gates on the
            // JSON form (monotone, L1-resident == infinite-L1).
            let arch = opts.get("arch").copied().unwrap_or("skl");
            let family = opts.get("workload").copied().unwrap_or("triad-strided");
            let target = opts.get("target").copied().unwrap_or("any");
            let flag = opts.get("flag").copied().unwrap_or("-O3");
            let sizes: Vec<u64> = match opts.get("sizes") {
                Some(list) => list
                    .split(',')
                    .map(|s| osaca::mdb::format::parse_size(s.trim()))
                    .collect::<Result<_>>()?,
                None => MEM_SWEEP_SIZES.to_vec(),
            };
            let rows = mem_sweep(family, target, flag, arch, &sizes)?;
            match format {
                Format::Json => {
                    let mut out = format!(
                        "{{\"schema_version\":{SCHEMA_VERSION},\"kind\":\"mem_sweep\",\
                         \"arch\":{},\"workload\":{},\"points\":[",
                        json_string(arch),
                        json_string(&format!("{family}-{target}-{}", flag.trim_start_matches('-'))),
                    );
                    for (i, r) in rows.iter().enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        out.push_str(&format!(
                            "{{\"working_set\":{},\"cy_per_asm_iter\":{},\"bound\":{},\
                             \"level\":{},\"infinite_l1_cy\":{}}}",
                            r.working_set,
                            r.cy_per_asm_iter,
                            json_string(r.bound),
                            json_string(&r.level),
                            r.infinite_l1_cy,
                        ));
                    }
                    out.push_str("]}");
                    println!("{out}");
                }
                _ => emit_table(
                    format,
                    &format!("working-set sweep: {family} on {arch}"),
                    &["working_set", "cy/asm-iter", "bound", "level", "infinite-L1 cy"],
                    &render_mem_sweep(&rows),
                ),
            }
        }
        "import-model" => {
            // Model zoo importer (DESIGN.md §13): uops.info-format XML
            // + curated overlay -> .mdb text, written to --out and
            // registered for the rest of this process.
            let path = pos.first().ok_or_else(|| {
                anyhow!("usage: import-model <uops.xml> --arch clx|icl|zen2 [--out models]")
            })?;
            let xml = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            let arch = match opts.get("arch") {
                Some(a) => *a,
                None => {
                    let present = osaca::zoo::arches_in(&xml).map_err(|e| anyhow!("{e}"))?;
                    bail!(
                        "import-model needs --arch; the XML has measurements for: {} \
                         (curated overlays: {})",
                        present.join(", "),
                        osaca::zoo::curated_arches().join(", ")
                    );
                }
            };
            let imported = osaca::zoo::import_model(&xml, arch).map_err(|e| anyhow!("{e}"))?;
            let name = imported.model.name.clone();
            let out_dir = opts.get("out").copied().unwrap_or("models");
            std::fs::create_dir_all(out_dir).with_context(|| format!("creating {out_dir}"))?;
            let out_path = format!("{out_dir}/{name}.mdb");
            std::fs::write(&out_path, &imported.text)
                .with_context(|| format!("writing {out_path}"))?;
            osaca::mdb::register_model_text(&name, &imported.text);
            match format {
                Format::Json => println!(
                    "{{\"schema_version\":{SCHEMA_VERSION},\"kind\":\"import_model\",\
                     \"arch\":{},\"entries\":{},\"ports\":{},\"path\":{}}}",
                    json_string(&name),
                    imported.entries,
                    imported.model.ports.len(),
                    json_string(&out_path),
                ),
                _ => println!(
                    "imported {name} ({}): {} instruction forms, {} ports -> {out_path}",
                    imported.model.arch_name,
                    imported.entries,
                    imported.model.ports.len(),
                ),
            }
        }
        "zoo-sweep" => {
            // Cross-model validation sweep: every embedded workload ×
            // every registered ISA-matching model (built-ins + whatever
            // --models-dir / import-model registered). Deterministic
            // order; `ci.sh --zoo-smoke` byte-compares two runs.
            let rows = zoo_sweep(&engine);
            match format {
                Format::Json => {
                    let mut models: Vec<&str> = Vec::new();
                    for r in &rows {
                        if !models.contains(&r.model.as_str()) {
                            models.push(&r.model);
                        }
                    }
                    let mut out = format!(
                        "{{\"schema_version\":{SCHEMA_VERSION},\"kind\":\"zoo_sweep\",\
                         \"models\":[{}],\"cells\":[",
                        models
                            .iter()
                            .map(|m| json_string(m))
                            .collect::<Vec<_>>()
                            .join(","),
                    );
                    for (i, r) in rows.iter().enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        out.push_str(&format!(
                            "{{\"workload\":{},\"model\":{},\"isa\":{}",
                            json_string(&r.workload),
                            json_string(&r.model),
                            json_string(r.isa),
                        ));
                        match (&r.cy_per_asm_iter, &r.error) {
                            (Some(cy), _) => out.push_str(&format!(
                                ",\"cy_per_asm_iter\":{cy},\"bound\":{}}}",
                                json_string(&r.bound)
                            )),
                            (None, Some(e)) => {
                                out.push_str(&format!(",\"error\":{}}}", json_string(e)))
                            }
                            (None, None) => out.push('}'),
                        }
                    }
                    out.push_str("]}");
                    println!("{out}");
                }
                _ => emit_table(
                    format,
                    "zoo sweep: workloads x registered models",
                    &["workload", "model", "isa", "cy/asm-iter", "bound"],
                    &render_zoo_sweep(&rows),
                ),
            }
        }
        "list-workloads" => {
            if format != Format::Text {
                let rows: Vec<Vec<String>> = workloads::all_isa()
                    .iter()
                    .map(|w| {
                        vec![
                            w.name(),
                            w.isa.name().to_string(),
                            w.compiled_for.to_string(),
                            w.unroll.to_string(),
                            w.flops_per_it.to_string(),
                        ]
                    })
                    .collect();
                emit_table(
                    format,
                    "workloads",
                    &["name", "isa", "compiled_for", "unroll", "flops_per_it"],
                    &rows,
                );
                return Ok(());
            }
            for w in workloads::all_isa() {
                println!(
                    "{:<16} isa={:<8} compiled-for={:<4} unroll={} flops/it={}",
                    w.name(),
                    w.isa.name(),
                    w.compiled_for,
                    w.unroll,
                    w.flops_per_it
                );
            }
        }
        other => {
            print_usage();
            bail!("unknown command `{other}`");
        }
    }
    Ok(())
}

/// Drive the coordinator's true batch path with a request mix and
/// report service statistics (the serving-framework face of the repo).
fn serve_demo(engine: &Engine, n: usize, format: Format) -> Result<()> {
    let ws = workloads::all();
    let reqs: Vec<_> = (0..n)
        .map(|i| {
            let w = ws[i % ws.len()];
            let arch = if i % 2 == 0 { "skl" } else { "zen" };
            Engine::request(&w.name())
                .arch(arch)
                .source(w.source)
                .passes(Passes::ANALYTIC)
                .unroll(w.unroll)
        })
        .collect();
    let t0 = std::time::Instant::now();
    let results = engine.analyze_batch(&reqs);
    let dt = t0.elapsed();
    for r in &results {
        if let Err(e) = r {
            bail!("batch request failed: {e}");
        }
    }
    let stats = engine.stats();
    if format != Format::Text {
        emit_table(
            format,
            "serve",
            &["requests", "req_per_s", "batches", "avg_batch_size", "solve_micros"],
            &[vec![
                n.to_string(),
                format!("{:.0}", n as f64 / dt.as_secs_f64()),
                stats.batches.load(std::sync::atomic::Ordering::Relaxed).to_string(),
                format!("{:.2}", stats.avg_batch_size()),
                stats.solve_micros.load(std::sync::atomic::Ordering::Relaxed).to_string(),
            ]],
        );
        return Ok(());
    }
    println!(
        "served {n} analysis requests in {dt:?} ({:.0} req/s)",
        n as f64 / dt.as_secs_f64()
    );
    println!(
        "batches: {} (avg size {:.2}), solver time {} µs total",
        stats.batches.load(std::sync::atomic::Ordering::Relaxed),
        stats.avg_batch_size(),
        stats.solve_micros.load(std::sync::atomic::Ordering::Relaxed),
    );
    Ok(())
}

fn print_usage() {
    println!(
        "osaca — instruction-stream throughput prediction (OSACA reproduction)

usage: osaca <command> [options]

commands (all accept --format text|json|csv):
  analyze <file.s> --arch skl|zen|hsw|tx2|rv64 [--learn] [--baseline] [--critpath] [--frontend-bound]
          [--mem-model [spec]]
  simulate <file.s> --arch skl|zen|tx2|rv64 [--iterations N] [--mem-model [spec]]
  ibench --instr <form> --arch skl|zen|tx2|rv64 [--conflict <form>]
  build-model --instr <form> --arch skl|zen|tx2|rv64
  validate-model --arch skl|zen
  compare <file.s> --arch skl|zen [--unroll N] [--mem-model [spec]]
  tables [--table1|--table3|--table5|--all]
  figures
  serve [--addr host:port] [--shards N] [--memo-cap N] [--memo-max-bytes N] [--queue-depth N]
        [--max-rps R] [--burst N] [--max-inflight N] [--max-frame-bytes N]
        [--chaos [seed]] [--test-ops] [--loopback [--requests N]]
  corpus <dir|archive.tar|file.s> [--arch skl] [--measured file.csv] [--frontend-bound] [--chunk N]
         [--mem-model [spec]]
  mem-sweep [--arch skl] [--workload triad-strided] [--target any] [--flag -O3] [--sizes 16K,1M,...]
  import-model <uops.xml> --arch clx|icl|zen2 [--out models]
         (compile uops.info-format XML + curated overlay into a .mdb model)
  zoo-sweep [--models-dir dir]
         (every workload fixture x every registered ISA-matching model)
  list-workloads

every subcommand accepts --models-dir <dir>: each *.mdb file inside is
registered (lazily parsed) so --arch takes imported names like clx;
`serve` re-scans it on the `reload_models` wire op.

memory-model spec: bare `--mem-model` takes the machine's hierarchy; or
`l1=32K:4,l2=1M:12,mem=:80,ws=4M,lsq=72,lfb=8` (any subset; sizes take
K/M/G binary suffixes). Off by default — the paper-pinned tables never
change unless the flag is given."
    );
}
