//! Embedded workload fixtures — the paper's validation kernels
//! (transcribed from its listings; see workloads/*/*.s) plus extra
//! kernels exercising other bottleneck classes.

use crate::asm::{extract_kernel, Kernel};

/// One fixture: a compiled kernel variant.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    /// Benchmark family (`triad`, `pi`, ...).
    pub family: &'static str,
    /// Which architecture the code was "compiled for" (`skl`, `zen`,
    /// or `any` when identical code is produced for both).
    pub compiled_for: &'static str,
    /// Optimization flag (`-O1`, `-O2`, `-O3`).
    pub flag: &'static str,
    /// Assembly-loop unroll factor relative to source iterations.
    pub unroll: usize,
    /// FLOP per source iteration (for the MFLOP/s columns).
    pub flops_per_it: usize,
    pub source: &'static str,
}

impl Workload {
    pub fn name(&self) -> String {
        format!("{}-{}-{}", self.family, self.compiled_for, self.flag.trim_start_matches('-'))
    }

    pub fn kernel(&self) -> Kernel {
        extract_kernel(&self.name(), self.source).expect("embedded fixture parses")
    }

    /// Does this fixture represent code compiled for `arch`?
    pub fn is_for(&self, arch: &str) -> bool {
        self.compiled_for == "any" || self.compiled_for == arch
    }
}

/// The triad fixtures (Tables I-IV): -O1/-O2 are scalar and identical
/// for both compile targets; -O3 differs (ymm 4x for SKL, xmm 2x Zen).
pub const TRIAD: &[Workload] = &[
    Workload {
        family: "triad",
        compiled_for: "any",
        flag: "-O1",
        unroll: 1,
        flops_per_it: 2,
        source: include_str!("../../workloads/triad/o1.s"),
    },
    Workload {
        family: "triad",
        compiled_for: "any",
        flag: "-O2",
        unroll: 1,
        flops_per_it: 2,
        source: include_str!("../../workloads/triad/o2.s"),
    },
    Workload {
        family: "triad",
        compiled_for: "skl",
        flag: "-O3",
        unroll: 4,
        flops_per_it: 2,
        source: include_str!("../../workloads/triad/skl_o3.s"),
    },
    Workload {
        family: "triad",
        compiled_for: "zen",
        flag: "-O3",
        unroll: 2,
        flops_per_it: 2,
        source: include_str!("../../workloads/triad/zen_o3.s"),
    },
];

/// The π fixtures (Tables V-VII). The -O3 kernel covers 8 source
/// iterations per assembly iteration (ymm x 2-way unroll).
pub const PI: &[Workload] = &[
    Workload {
        family: "pi",
        compiled_for: "any",
        flag: "-O1",
        unroll: 1,
        flops_per_it: 5,
        source: include_str!("../../workloads/pi/o1.s"),
    },
    Workload {
        family: "pi",
        compiled_for: "any",
        flag: "-O2",
        unroll: 1,
        flops_per_it: 5,
        source: include_str!("../../workloads/pi/o2.s"),
    },
    Workload {
        family: "pi",
        compiled_for: "any",
        flag: "-O3",
        unroll: 8,
        flops_per_it: 5,
        source: include_str!("../../workloads/pi/o3.s"),
    },
];

/// Additional kernels beyond the paper's two validation cases.
pub const EXTRA: &[Workload] = &[
    Workload {
        family: "sum",
        compiled_for: "any",
        flag: "-O2",
        unroll: 1,
        flops_per_it: 1,
        source: include_str!("../../workloads/extra/sum_reduction.s"),
    },
    Workload {
        family: "daxpy",
        compiled_for: "any",
        flag: "-O3",
        unroll: 4,
        flops_per_it: 2,
        source: include_str!("../../workloads/extra/daxpy.s"),
    },
    Workload {
        family: "copy",
        compiled_for: "any",
        flag: "-O3",
        unroll: 8,
        flops_per_it: 0,
        source: include_str!("../../workloads/extra/stream_copy.s"),
    },
    Workload {
        family: "dot",
        compiled_for: "any",
        flag: "-O3",
        unroll: 8,
        flops_per_it: 2,
        source: include_str!("../../workloads/extra/dot_product.s"),
    },
    Workload {
        family: "triad-sse",
        compiled_for: "any",
        flag: "-O3",
        unroll: 2,
        flops_per_it: 2,
        source: include_str!("../../workloads/extra/triad_sse.s"),
    },
];

/// All fixtures.
pub fn all() -> Vec<&'static Workload> {
    TRIAD.iter().chain(PI.iter()).chain(EXTRA.iter()).collect()
}

/// Find a fixture by `family`, target arch, and flag.
pub fn find(family: &str, arch: &str, flag: &str) -> Option<&'static Workload> {
    all()
        .into_iter()
        .find(|w| w.family == family && w.flag == flag && w.is_for(arch))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_fixtures_parse_and_have_markers() {
        for w in all() {
            let k = w.kernel();
            assert!(!k.is_empty(), "{}", w.name());
            assert!(k.loop_label.is_some(), "{}", w.name());
        }
    }

    #[test]
    fn find_selects_arch_specific_o3() {
        let skl = find("triad", "skl", "-O3").unwrap();
        assert_eq!(skl.unroll, 4);
        let zen = find("triad", "zen", "-O3").unwrap();
        assert_eq!(zen.unroll, 2);
        let o1 = find("triad", "zen", "-O1").unwrap();
        assert_eq!(o1.compiled_for, "any");
    }

    #[test]
    fn pi_o3_has_two_divides() {
        let k = find("pi", "skl", "-O3").unwrap().kernel();
        let divs = k.instructions.iter().filter(|i| i.mnemonic == "vdivpd").count();
        assert_eq!(divs, 2);
    }
}
