//! Embedded workload fixtures — the paper's validation kernels
//! (transcribed from its listings; see workloads/*/*.s) plus extra
//! kernels exercising other bottleneck classes, and the AArch64
//! (ThunderX2) and RISC-V (RV64) variants for the multi-ISA frontend.

use crate::asm::{extract_kernel_isa, Kernel};
use crate::isa::Isa;

/// One fixture: a compiled kernel variant.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    /// Benchmark family (`triad`, `pi`, ...).
    pub family: &'static str,
    /// Which architecture the code was "compiled for" (`skl`, `zen`,
    /// `tx2`, `rv64`, or `any` when identical x86 code is produced for
    /// both x86 targets).
    pub compiled_for: &'static str,
    /// Optimization flag (`-O1`, `-O2`, `-O3`).
    pub flag: &'static str,
    /// Assembly-loop unroll factor relative to source iterations.
    pub unroll: usize,
    /// FLOP per source iteration (for the MFLOP/s columns).
    pub flops_per_it: usize,
    /// Syntax the fixture is written in.
    pub isa: Isa,
    pub source: &'static str,
}

impl Workload {
    pub fn name(&self) -> String {
        format!("{}-{}-{}", self.family, self.compiled_for, self.flag.trim_start_matches('-'))
    }

    pub fn kernel(&self) -> Kernel {
        extract_kernel_isa(&self.name(), self.source, self.isa).expect("embedded fixture parses")
    }

    /// Does this fixture represent code compiled for `arch`?
    pub fn is_for(&self, arch: &str) -> bool {
        self.compiled_for == "any" || self.compiled_for == arch
    }
}

/// The triad fixtures (Tables I-IV): -O1/-O2 are scalar and identical
/// for both compile targets; -O3 differs (ymm 4x for SKL, xmm 2x Zen).
pub const TRIAD: &[Workload] = &[
    Workload {
        family: "triad",
        compiled_for: "any",
        flag: "-O1",
        unroll: 1,
        flops_per_it: 2,
        isa: Isa::X86,
        source: include_str!("../../workloads/triad/o1.s"),
    },
    Workload {
        family: "triad",
        compiled_for: "any",
        flag: "-O2",
        unroll: 1,
        flops_per_it: 2,
        isa: Isa::X86,
        source: include_str!("../../workloads/triad/o2.s"),
    },
    Workload {
        family: "triad",
        compiled_for: "skl",
        flag: "-O3",
        unroll: 4,
        flops_per_it: 2,
        isa: Isa::X86,
        source: include_str!("../../workloads/triad/skl_o3.s"),
    },
    Workload {
        family: "triad",
        compiled_for: "zen",
        flag: "-O3",
        unroll: 2,
        flops_per_it: 2,
        isa: Isa::X86,
        source: include_str!("../../workloads/triad/zen_o3.s"),
    },
];

/// The π fixtures (Tables V-VII). The -O3 kernel covers 8 source
/// iterations per assembly iteration (ymm x 2-way unroll).
pub const PI: &[Workload] = &[
    Workload {
        family: "pi",
        compiled_for: "any",
        flag: "-O1",
        unroll: 1,
        flops_per_it: 5,
        isa: Isa::X86,
        source: include_str!("../../workloads/pi/o1.s"),
    },
    Workload {
        family: "pi",
        compiled_for: "any",
        flag: "-O2",
        unroll: 1,
        flops_per_it: 5,
        isa: Isa::X86,
        source: include_str!("../../workloads/pi/o2.s"),
    },
    Workload {
        family: "pi",
        compiled_for: "any",
        flag: "-O3",
        unroll: 8,
        flops_per_it: 5,
        isa: Isa::X86,
        source: include_str!("../../workloads/pi/o3.s"),
    },
];

/// Additional kernels beyond the paper's two validation cases.
pub const EXTRA: &[Workload] = &[
    Workload {
        family: "sum",
        compiled_for: "any",
        flag: "-O2",
        unroll: 1,
        flops_per_it: 1,
        isa: Isa::X86,
        source: include_str!("../../workloads/extra/sum_reduction.s"),
    },
    Workload {
        family: "daxpy",
        compiled_for: "any",
        flag: "-O3",
        unroll: 4,
        flops_per_it: 2,
        isa: Isa::X86,
        source: include_str!("../../workloads/extra/daxpy.s"),
    },
    Workload {
        family: "copy",
        compiled_for: "any",
        flag: "-O3",
        unroll: 8,
        flops_per_it: 0,
        isa: Isa::X86,
        source: include_str!("../../workloads/extra/stream_copy.s"),
    },
    Workload {
        family: "dot",
        compiled_for: "any",
        flag: "-O3",
        unroll: 8,
        flops_per_it: 2,
        isa: Isa::X86,
        source: include_str!("../../workloads/extra/dot_product.s"),
    },
    Workload {
        family: "triad-sse",
        compiled_for: "any",
        flag: "-O3",
        unroll: 2,
        flops_per_it: 2,
        isa: Isa::X86,
        source: include_str!("../../workloads/extra/triad_sse.s"),
    },
    Workload {
        family: "triad-strided",
        compiled_for: "any",
        flag: "-O3",
        unroll: 4,
        flops_per_it: 2,
        isa: Isa::X86,
        source: include_str!("../../workloads/extra/strided_triad.s"),
    },
];

/// RISC-V (RV64GC) fixtures — the third-backend proof of the
/// DESIGN.md §7 recipe: the paper's two validation kernels re-targeted
/// for the riscv-sim-derived dual-issue `rv64` model.
pub const RISCV: &[Workload] = &[
    Workload {
        family: "triad",
        compiled_for: "rv64",
        flag: "-O2",
        unroll: 1,
        flops_per_it: 2,
        isa: Isa::RiscV,
        source: include_str!("../../workloads/triad/rv64_o2.s"),
    },
    Workload {
        family: "pi",
        compiled_for: "rv64",
        flag: "-O1",
        unroll: 1,
        flops_per_it: 5,
        isa: Isa::RiscV,
        source: include_str!("../../workloads/pi/rv64_o1.s"),
    },
];

/// AArch64 (ThunderX2) fixtures for the multi-ISA frontend: the triad
/// and π kernels of the paper re-targeted per the 2019 follow-up.
pub const AARCH64: &[Workload] = &[
    Workload {
        family: "triad",
        compiled_for: "tx2",
        flag: "-O2",
        unroll: 2,
        flops_per_it: 2,
        isa: Isa::AArch64,
        source: include_str!("../../workloads/triad/tx2_o2.s"),
    },
    Workload {
        family: "pi",
        compiled_for: "tx2",
        flag: "-O1",
        unroll: 1,
        flops_per_it: 5,
        isa: Isa::AArch64,
        source: include_str!("../../workloads/pi/tx2_o1.s"),
    },
];

/// All **x86** fixtures (the paper's validation set). Kept x86-only on
/// purpose: callers iterate this against the skl/zen/hsw models. See
/// [`AARCH64`] / [`all_isa`] for the ARM fixtures.
pub fn all() -> Vec<&'static Workload> {
    TRIAD.iter().chain(PI.iter()).chain(EXTRA.iter()).collect()
}

/// Every fixture of every ISA.
pub fn all_isa() -> Vec<&'static Workload> {
    all().into_iter().chain(AARCH64.iter()).chain(RISCV.iter()).collect()
}

/// ISA of a target architecture name, via the built-in model registry
/// (unknown names default to x86, preserving the historical behavior
/// for ad-hoc arch strings).
fn arch_isa(arch: &str) -> Isa {
    crate::mdb::by_name_shared(arch).map(|m| m.isa).unwrap_or_default()
}

/// Find a fixture by `family`, target arch, and flag (searches all
/// ISAs; the `tx2` arch selects the AArch64 set). An exact
/// `compiled_for` match wins over the `any` fixtures, and the `any`
/// fallback only applies ISA-compatibly — so `("triad", "tx2", "-O2")`
/// finds the ARM kernel, and a flag with no ARM fixture returns `None`
/// rather than an x86 kernel that could only fail `IsaMismatch`.
pub fn find(family: &str, arch: &str, flag: &str) -> Option<&'static Workload> {
    let all = all_isa();
    all.iter()
        .find(|w| w.family == family && w.flag == flag && w.compiled_for == arch)
        .or_else(|| {
            let isa = arch_isa(arch);
            all.iter()
                .find(|w| w.family == family && w.flag == flag && w.is_for(arch) && w.isa == isa)
        })
        .copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_fixtures_parse_and_have_markers() {
        for w in all_isa() {
            let k = w.kernel();
            assert!(!k.is_empty(), "{}", w.name());
            assert!(k.loop_label.is_some(), "{}", w.name());
            assert_eq!(k.isa, w.isa, "{}", w.name());
        }
    }

    #[test]
    fn aarch64_fixtures_found_by_arch() {
        let t = find("triad", "tx2", "-O2").unwrap();
        assert_eq!(t.isa, Isa::AArch64);
        assert_eq!(t.unroll, 2);
        assert_eq!(t.kernel().len(), 7);
        let p = find("pi", "tx2", "-O1").unwrap();
        assert_eq!(p.kernel().len(), 10);
        // The x86 sets are untouched by the ARM additions.
        assert!(all().iter().all(|w| w.isa == Isa::X86));
        // No ISA-incompatible fallback: a flag with no ARM fixture is
        // None, never an x86 kernel; and x86 archs still reach the
        // `any` fixtures.
        assert!(find("pi", "tx2", "-O2").is_none());
        assert!(find("triad", "tx2", "-O3").is_none());
        assert_eq!(find("pi", "skl", "-O2").unwrap().compiled_for, "any");
    }

    #[test]
    fn riscv_fixtures_found_by_arch() {
        let t = find("triad", "rv64", "-O2").unwrap();
        assert_eq!(t.isa, Isa::RiscV);
        assert_eq!(t.unroll, 1);
        assert_eq!(t.kernel().len(), 8);
        let p = find("pi", "rv64", "-O1").unwrap();
        assert_eq!(p.kernel().len(), 9);
        // No ISA-incompatible fallback, and the x86/ARM sets are
        // untouched by the RISC-V additions.
        assert!(find("pi", "rv64", "-O2").is_none());
        assert!(find("triad", "rv64", "-O3").is_none());
        assert!(all().iter().all(|w| w.isa == Isa::X86));
        assert!(AARCH64.iter().all(|w| w.isa == Isa::AArch64));
    }

    #[test]
    fn find_selects_arch_specific_o3() {
        let skl = find("triad", "skl", "-O3").unwrap();
        assert_eq!(skl.unroll, 4);
        let zen = find("triad", "zen", "-O3").unwrap();
        assert_eq!(zen.unroll, 2);
        let o1 = find("triad", "zen", "-O1").unwrap();
        assert_eq!(o1.compiled_for, "any");
    }

    #[test]
    fn pi_o3_has_two_divides() {
        let k = find("pi", "skl", "-O3").unwrap().kernel();
        let divs = k.instructions.iter().filter(|i| i.mnemonic == "vdivpd").count();
        assert_eq!(divs, 2);
    }
}
