//! The OSACA throughput analyzer (paper §III).
//!
//! Distributes each instruction's µ-ops over their admissible ports with
//! *fixed uniform probabilities* (paper assumption 2), sums per-port
//! occupation, and reports the bottleneck port's cycles per assembly
//! iteration. Special cases, faithful to OSACA 0.2:
//!
//! * divider pseudo-pipes (`0DV`/`DV`) carry multi-cycle occupancy while
//!   the issuing port frees after one cycle;
//! * on Zen, one load instruction's AGU occupancy is hidden behind each
//!   store (`hide_load_behind_store`, Table IV's parenthesized entries);
//! * branch instructions carry no port occupancy (blank rows);
//! * no zero-idiom shortcuts and no macro-fusion — the model
//!   deliberately over-counts where real hardware takes shortcuts
//!   (§III-B: 4.25 cy predicted vs 4.00 measured for π at -O2).
//!
//! Beyond the paper, [`AnalyzerConfig::frontend_bound`] adds an opt-in
//! width-aware bound `rename slots / rename_width` that closes the
//! narrow-core blind spot documented in DESIGN.md §7 (the 2-wide `rv64`
//! triad is frontend-bound at 4.0 cy where the port model sees 3.0 cy).

pub mod critpath;
pub mod throughput;

pub use critpath::{critical_path, critical_path_decoded, CritPathReport};
pub use throughput::{
    analyze, analyze_with, analyze_with_slots, Analysis, AnalyzerConfig, FrontendBound,
    LineOccupancy,
};
