//! Uniform-probability port-occupancy analysis (the OSACA prediction),
//! plus the opt-in width-aware frontend bound.

use anyhow::Result;

use crate::asm::Kernel;
use crate::mdb::{MachineModel, Provenance, UopKind};
use crate::sim::decode_kernel;

/// Analyzer options beyond the paper's fixed method.
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyzerConfig {
    /// Compute the width-aware frontend bound
    /// `rename slots / rename_width` alongside the port-pressure bound.
    ///
    /// Off by default: the paper's method assumes the issue width never
    /// limits (assumption 4), and the pinned skl/zen/tx2 tables are
    /// exact under that assumption. Narrow cores break it — the 2-wide
    /// `rv64` model runs the triad frontend-bound at 4.0 cy where the
    /// port model sees 3.0 cy (DESIGN.md §7) — so the bound is opt-in
    /// per request rather than a silent change to the paper numbers.
    pub frontend_bound: bool,
}

/// The width-aware frontend bound: the rename stage hands `slots` fused
/// slots per iteration to a `width`-wide pipeline, so no schedule can
/// beat `slots / width` cycles per iteration regardless of port
/// pressure. Slot accounting matches `sim::decode` exactly (micro-fused
/// load+compute / data+AGU pairs share a slot; rename-eliminated zero
/// idioms and moves still consume one; macro-fused branches consume
/// none), so when this bound wins the analyzer agrees with the
/// simulator's frontend behavior.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrontendBound {
    /// Fused rename slots one assembly iteration occupies.
    pub slots: usize,
    /// The machine's rename width (slots consumed per cycle).
    pub width: usize,
    /// `slots / width`, cycles per assembly iteration.
    pub cy_per_asm_iter: f32,
}

/// Per-line port occupancy (one row of Tables II/IV/VI/VII).
#[derive(Debug, Clone, PartialEq)]
pub struct LineOccupancy {
    /// Kernel instruction index.
    pub instr: usize,
    /// Source text of the instruction.
    pub text: String,
    /// Occupancy per port (cycles/iteration).
    pub occupancy: Vec<f32>,
    /// Hidden occupancy per port (Zen hideable loads — rendered in
    /// parentheses and excluded from the totals).
    pub hidden: Vec<f32>,
    /// Where the µ-ops came from (measured entry vs synthesized).
    pub provenance: Provenance,
}

/// The analyzer's result for one kernel on one machine.
#[derive(Debug, Clone)]
pub struct Analysis {
    pub machine: String,
    pub kernel: String,
    pub lines: Vec<LineOccupancy>,
    /// Total per-port occupancy (the table footer).
    pub totals: Vec<f32>,
    /// Predicted reciprocal throughput: max over ports, cycles per
    /// assembly iteration.
    pub cy_per_asm_iter: f32,
    /// Index of the bottleneck port.
    pub bottleneck_port: usize,
    /// Width-aware frontend bound — present only when requested via
    /// [`AnalyzerConfig::frontend_bound`]; the port table above is
    /// identical either way.
    pub frontend: Option<FrontendBound>,
}

impl Analysis {
    /// Cycles per *source* iteration given the unroll factor.
    pub fn cy_per_source_it(&self, unroll: usize) -> f32 {
        self.cy_per_asm_iter / unroll as f32
    }
}

/// Run the OSACA throughput analysis of `kernel` against `machine`
/// with the paper's fixed method (no frontend bound).
pub fn analyze(kernel: &Kernel, machine: &MachineModel) -> Result<Analysis> {
    analyze_ports(kernel, machine, None)
}

/// [`analyze`] with options. When [`AnalyzerConfig::frontend_bound`] is
/// set, the kernel is decoded with the simulator's slot accounting to
/// obtain the rename-slot count; the port table is unaffected.
pub fn analyze_with(
    kernel: &Kernel,
    machine: &MachineModel,
    cfg: &AnalyzerConfig,
) -> Result<Analysis> {
    let slots = if cfg.frontend_bound {
        Some(decode_kernel(kernel, machine)?.slots)
    } else {
        None
    };
    analyze_ports(kernel, machine, slots)
}

/// [`analyze_with`] for callers that already hold a decoded template
/// (the api layer shares one decode between this bound, the
/// critical-path pass and the simulator): `slots` is
/// `DecodedIter::slots`.
pub fn analyze_with_slots(
    kernel: &Kernel,
    machine: &MachineModel,
    slots: usize,
) -> Result<Analysis> {
    analyze_ports(kernel, machine, Some(slots))
}

fn frontend_bound_of(machine: &MachineModel, slots: usize) -> FrontendBound {
    let width = machine.params.rename_width.max(1);
    FrontendBound { slots, width, cy_per_asm_iter: slots as f32 / width as f32 }
}

fn analyze_ports(
    kernel: &Kernel,
    machine: &MachineModel,
    frontend_slots: Option<usize>,
) -> Result<Analysis> {
    let np = machine.n_ports();
    let mut lines: Vec<LineOccupancy> = Vec::with_capacity(kernel.instructions.len());

    // The Zen AGU rule: one load instruction's Load-µ-op occupancy is
    // hidden per store instruction, in program order (Table IV hides the
    // first load).
    let mut hideable = if machine.hide_load_behind_store {
        kernel.n_stores().min(kernel.n_loads())
    } else {
        0
    };

    for (i, ins) in kernel.instructions.iter().enumerate() {
        let mut occ = vec![0f32; np];
        let mut hid = vec![0f32; np];
        if ins.is_fusible_branch() {
            // Fusible branches (x86 jcc, AArch64 b.<cond>) carry no
            // port occupancy in OSACA's model. AArch64
            // compare-and-branch forms execute a real µ-op and are
            // charged below, matching `sim::decode`.
            lines.push(LineOccupancy {
                instr: i,
                text: ins.to_string(),
                occupancy: occ,
                hidden: hid,
                provenance: Provenance::Direct,
            });
            continue;
        }
        let resolved = machine.resolve(ins)?;
        let hide_this = ins.is_load() && hideable > 0;
        if hide_this {
            hideable -= 1;
        }
        for u in &resolved.entry.uops {
            let share = u.occupancy / u.ports.count().max(1) as f32;
            let target = if hide_this && u.kind == UopKind::Load { &mut hid } else { &mut occ };
            for p in u.ports.iter() {
                target[p] += share;
            }
        }
        lines.push(LineOccupancy {
            instr: i,
            text: ins.to_string(),
            occupancy: occ,
            hidden: hid,
            provenance: resolved.provenance,
        });
    }

    let mut totals = vec![0f32; np];
    for l in &lines {
        for (t, o) in totals.iter_mut().zip(l.occupancy.iter()) {
            *t += o;
        }
    }
    let (bottleneck_port, &max) = totals
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .expect("machine has ports");
    Ok(Analysis {
        machine: machine.name.clone(),
        kernel: kernel.name.clone(),
        lines,
        totals,
        cy_per_asm_iter: max,
        bottleneck_port,
        frontend: frontend_slots.map(|s| frontend_bound_of(machine, s)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::extract_kernel;
    use crate::mdb::{skylake, zen};

    /// Paper Table II: triad -O3 compiled for Skylake, analyzed for SKL.
    const TRIAD_SKL_O3: &str = "\n.L10:\nvmovapd (%r15,%rax), %ymm0\nvmovapd (%r12,%rax), %ymm3\naddl $1, %ecx\nvfmadd132pd 0(%r13,%rax), %ymm3, %ymm0\nvmovapd %ymm0, (%r14,%rax)\naddq $32, %rax\ncmpl %ecx, %r10d\nja .L10\n";

    /// Paper Table IV: triad -O3 compiled for Zen (xmm, 2x unroll).
    const TRIAD_ZEN_O3: &str = "\n.L10:\nvmovaps 0(%r13,%rax), %xmm0\nvmovaps (%r15,%rax), %xmm3\nincl %esi\nvfmadd132pd (%r14,%rax), %xmm3, %xmm0\nvmovaps %xmm0, (%r12,%rax)\naddq $16, %rax\ncmpl %esi, %ebx\nja .L10\n";

    fn approx(a: f32, b: f32) -> bool {
        (a - b).abs() < 0.011
    }

    #[test]
    fn table2_skl_triad_totals() {
        let k = extract_kernel("triad", TRIAD_SKL_O3).unwrap();
        let m = skylake();
        let a = analyze(&k, &m).unwrap();
        // Paper Table II footer: P0..P7 = 1.25 1.25 2.0 2.0 1.0 0.75 0.75 0.0
        let want = [1.25, 1.25, 2.0, 2.0, 1.0, 0.75, 0.75, 0.0];
        for (i, w) in want.iter().enumerate() {
            let p = m.port_index(&format!("P{i}")).unwrap();
            assert!(approx(a.totals[p], *w), "P{i}: {} want {}", a.totals[p], w);
        }
        assert!(approx(a.cy_per_asm_iter, 2.0));
        assert!(approx(a.cy_per_source_it(4), 0.5));
    }

    #[test]
    fn table2_fma_line() {
        let k = extract_kernel("triad", TRIAD_SKL_O3).unwrap();
        let m = skylake();
        let a = analyze(&k, &m).unwrap();
        let fma = &a.lines[3];
        // 0.50 0.50 on P0/P1 + 0.50 0.50 on P2/P3 (Table II row 4).
        for port in ["P0", "P1", "P2", "P3"] {
            let p = m.port_index(port).unwrap();
            assert!(approx(fma.occupancy[p], 0.5), "{port}: {}", fma.occupancy[p]);
        }
    }

    #[test]
    fn table4_zen_triad_totals() {
        let k = extract_kernel("triad", TRIAD_ZEN_O3).unwrap();
        let m = zen();
        let a = analyze(&k, &m).unwrap();
        // Paper Table IV footer: FP0..3 = 1.25 1.25 0.75 0.75,
        // ALU0..3 = 0.75, AGU0/1 = 2.0.
        let want: &[(&str, f32)] = &[
            ("FP0", 1.25),
            ("FP1", 1.25),
            ("FP2", 0.75),
            ("FP3", 0.75),
            ("ALU0", 0.75),
            ("ALU1", 0.75),
            ("ALU2", 0.75),
            ("ALU3", 0.75),
            ("AGU0", 2.0),
            ("AGU1", 2.0),
        ];
        for (port, w) in want {
            let p = m.port_index(port).unwrap();
            assert!(approx(a.totals[p], *w), "{port}: {} want {}", a.totals[p], w);
        }
        assert!(approx(a.cy_per_asm_iter, 2.0));
    }

    #[test]
    fn table4_first_load_hidden() {
        let k = extract_kernel("triad", TRIAD_ZEN_O3).unwrap();
        let m = zen();
        let a = analyze(&k, &m).unwrap();
        let first_load = &a.lines[0];
        let agu0 = m.port_index("AGU0").unwrap();
        assert!(approx(first_load.hidden[agu0], 0.5), "{}", first_load.hidden[agu0]);
        assert!(approx(first_load.occupancy[agu0], 0.0));
        // Second load is NOT hidden.
        let second = &a.lines[1];
        assert!(approx(second.occupancy[agu0], 0.5));
        assert!(approx(second.hidden[agu0], 0.0));
    }

    #[test]
    fn branch_rows_are_blank() {
        let k = extract_kernel("triad", TRIAD_SKL_O3).unwrap();
        let a = analyze(&k, &skylake()).unwrap();
        let ja = a.lines.last().unwrap();
        assert!(ja.occupancy.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn zen_runs_skl_avx_code_at_4_cycles() {
        // Paper Table I row 3: SKL -O3 code analyzed for Zen -> 4.00 cy.
        let k = extract_kernel("triad", TRIAD_SKL_O3).unwrap();
        let a = analyze(&k, &zen()).unwrap();
        assert!(approx(a.cy_per_asm_iter, 4.0), "{}", a.cy_per_asm_iter);
    }

    #[test]
    fn unknown_instruction_is_an_error() {
        let k = extract_kernel("t", "\n.L1:\nfrobnicate %xmm0, %xmm1\nja .L1\n").unwrap();
        assert!(analyze(&k, &skylake()).is_err());
    }

    #[test]
    fn frontend_bound_is_opt_in_and_leaves_the_table_alone() {
        let k = extract_kernel("triad", TRIAD_SKL_O3).unwrap();
        let m = skylake();
        let base = analyze(&k, &m).unwrap();
        assert!(base.frontend.is_none());
        let a = analyze_with(&k, &m, &AnalyzerConfig { frontend_bound: true }).unwrap();
        let f = a.frontend.unwrap();
        // 7 rename slots (cmpl+ja macro-fuse) on the 4-wide stage: the
        // bound (1.75 cy) stays below the 2.0 cy port bound, as the
        // paper's assumption expects on wide cores.
        assert_eq!(f.slots, 7);
        assert_eq!(f.width, 4);
        assert!((f.cy_per_asm_iter - 1.75).abs() < 1e-6);
        assert_eq!(a.totals, base.totals);
        assert_eq!(a.cy_per_asm_iter, base.cy_per_asm_iter);
        assert_eq!(a.bottleneck_port, base.bottleneck_port);
    }
}
