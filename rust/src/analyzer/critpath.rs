//! Critical-path / loop-carried-dependency analysis.
//!
//! This implements the paper's §IV-B *future work* item ("support for
//! critical path analysis, tracking dependencies between sources and
//! destinations"): the longest latency chain through one iteration and
//! the longest loop-carried cycle, which together bound the runtime from
//! below when the throughput assumption (assumption 4) fails — exactly
//! the -O1 π situation in §III-B.

use anyhow::Result;

use crate::asm::Kernel;
use crate::mdb::MachineModel;
use crate::mdb::UopKind;
use crate::sim::decode::{decode_kernel, DepSource};
use crate::sim::{DecodedIter, SimUop};

/// Latency analysis result.
#[derive(Debug, Clone)]
pub struct CritPathReport {
    /// Longest dependency chain through a single iteration (cycles).
    pub intra_iteration: f32,
    /// Longest loop-carried cycle per iteration (cycles/iteration) —
    /// the steady-state lower bound from dependencies.
    pub carried_per_iteration: f32,
    /// Instruction indices on the carried cycle (empty if none).
    pub carried_path: Vec<usize>,
}

/// µ-op latency as the critical-path model sees it: issue-to-result,
/// with store-forwarded loads paying the forwarding penalty.
fn uop_latency(u: &SimUop, machine: &MachineModel, forwarded: bool) -> f32 {
    match u.kind {
        UopKind::Load if forwarded => machine.params.store_forward_latency as f32,
        _ => u.latency.max(1) as f32,
    }
}

/// Compute the critical path of `kernel` under `machine`.
///
/// Uses the simulator's decoded dependency graph (including memory
/// identities): longest path for the intra-iteration chain, and for the
/// carried bound the maximum cycle mean over back-edges, computed by
/// unrolling the recurrence twice (exact for single-back-edge cycles,
/// a tight bound for the kernels we model).
pub fn critical_path(kernel: &Kernel, machine: &MachineModel) -> Result<CritPathReport> {
    let t = decode_kernel(kernel, machine)?;
    Ok(critical_path_decoded(&t, machine))
}

/// [`critical_path`] over an already-decoded iteration template, so the
/// api layer can share one decode between the critical-path pass and
/// the simulator (`DecodedKernel`).
pub fn critical_path_decoded(t: &DecodedIter, machine: &MachineModel) -> CritPathReport {
    let n = t.uops.len();

    // Forwarding: a load aliases a store across iterations only when the
    // address is *version-stable* — all address-register components are
    // loop-invariant (e.g. `(%rsp)`). Addresses indexed by an in-loop
    // counter (e.g. `(%rsi,%rax)` in daxpy) change every iteration and
    // never produce a carried memory edge.
    let stable = |u: &SimUop| -> bool {
        u.mem_ident
            .as_ref()
            .map(|id| {
                [&id.base, &id.index].into_iter().flatten().all(|(_, v)| {
                    matches!(v, crate::sim::decode::DepVersion::Invariant)
                })
            })
            .unwrap_or(false)
    };
    let forwarded: Vec<bool> = t
        .uops
        .iter()
        .map(|u| {
            u.kind == UopKind::Load
                && stable(u)
                && t.uops.iter().any(|s| {
                    s.kind == UopKind::StoreData && stable(s) && s.mem_ident == u.mem_ident
                })
        })
        .collect();

    // Longest path within one iteration (DAG over Intra edges).
    let mut dist = vec![0f32; n];
    for i in 0..n {
        let lat = uop_latency(&t.uops[i], machine, forwarded[i]);
        let mut start = 0f32;
        for d in &t.uops[i].deps {
            if let DepSource::Intra(w) = d {
                start = start.max(dist[*w]);
            }
        }
        dist[i] = start + lat;
    }
    let intra = dist.iter().cloned().fold(0.0, f32::max);

    // Loop-carried bound: for each back-edge (Carried dep w -> i, plus
    // store->load forwarding across iterations), the cycle length is
    // dist_from(w hits i) + ... ; we compute the max over simple cycles
    // by relaxing a two-iteration unroll.
    let mut best_cycle = 0f32;
    let mut best_path: Vec<usize> = Vec::new();
    for i in 0..n {
        let mut sources: Vec<usize> = t.uops[i]
            .deps
            .iter()
            .filter_map(|d| match d {
                DepSource::Carried(w) => Some(*w),
                _ => None,
            })
            .collect();
        // Cross-iteration forwarding edge: load i <- store w (prev iter).
        if forwarded[i] {
            for (w, s) in t.uops.iter().enumerate() {
                if s.kind == UopKind::StoreData && s.mem_ident == t.uops[i].mem_ident {
                    sources.push(w);
                }
            }
        }
        for w in sources {
            // Longest path from i to w within an iteration.
            if let Some((len, path)) = longest_path(&t.uops, machine, &forwarded, i, w) {
                if len > best_cycle {
                    best_cycle = len;
                    best_path = path.iter().map(|&u| t.uops[u].instr).collect();
                    best_path.dedup();
                }
            }
        }
    }

    CritPathReport {
        intra_iteration: intra,
        carried_per_iteration: best_cycle,
        carried_path: best_path,
    }
}

/// Encode a kernel's dependency graph for the batched critical-path
/// artifact (python/compile/kernels/critpath.py): per-µ-op latencies,
/// forward edges, carried back-edges (including version-stable
/// store-to-load forwarding).
pub fn encode_graph(
    kernel: &Kernel,
    machine: &MachineModel,
) -> Result<crate::runtime::EncodedGraph> {
    let t = decode_kernel(kernel, machine)?;
    let n = t.uops.len();
    if n > crate::runtime::MAX_UOPS {
        anyhow::bail!("kernel exceeds {} µ-ops", crate::runtime::MAX_UOPS);
    }
    let stable = |u: &SimUop| -> bool {
        u.mem_ident
            .as_ref()
            .map(|id| {
                [&id.base, &id.index]
                    .into_iter()
                    .flatten()
                    .all(|(_, v)| matches!(v, crate::sim::decode::DepVersion::Invariant))
            })
            .unwrap_or(false)
    };
    let forwarded: Vec<bool> = t
        .uops
        .iter()
        .map(|u| {
            u.kind == UopKind::Load
                && stable(u)
                && t.uops
                    .iter()
                    .any(|s| s.kind == UopKind::StoreData && stable(s) && s.mem_ident == u.mem_ident)
        })
        .collect();
    let mut g = crate::runtime::EncodedGraph::empty();
    for (i, u) in t.uops.iter().enumerate() {
        g.set_latency(i, uop_latency(u, machine, forwarded[i]))?;
    }
    for (i, u) in t.uops.iter().enumerate() {
        for d in &u.deps {
            match d {
                DepSource::Intra(w) => g.add_edge(*w, i)?,
                DepSource::Carried(w) => g.add_carried(i, *w)?,
                DepSource::Invariant => {}
            }
        }
        if forwarded[i] {
            for (w, s) in t.uops.iter().enumerate() {
                if s.kind == UopKind::StoreData && s.mem_ident == u.mem_ident {
                    g.add_carried(i, w)?;
                }
            }
        }
    }
    Ok(g)
}

/// Batched critical-path analysis through the AOT artifact — the
/// offline-sweep variant of `critical_path`.
pub fn critical_path_batch(
    kernels: &[&Kernel],
    machine: &MachineModel,
    solver: &crate::runtime::CritSolver,
) -> Result<Vec<crate::runtime::CritOut>> {
    let graphs: Vec<_> = kernels
        .iter()
        .map(|k| encode_graph(k, machine))
        .collect::<Result<_>>()?;
    solver.solve(&graphs)
}

/// Longest Intra-edge path from µ-op `from` to µ-op `to` (inclusive
/// latencies), or `None` when unreachable.
fn longest_path(
    uops: &[SimUop],
    machine: &MachineModel,
    forwarded: &[bool],
    from: usize,
    to: usize,
) -> Option<(f32, Vec<usize>)> {
    let n = uops.len();
    let mut dist = vec![f32::NEG_INFINITY; n];
    let mut prev: Vec<Option<usize>> = vec![None; n];
    dist[from] = uop_latency(&uops[from], machine, forwarded[from]);
    for i in from + 1..n {
        for d in &uops[i].deps {
            if let DepSource::Intra(w) = d {
                if dist[*w] > f32::NEG_INFINITY {
                    let cand = dist[*w] + uop_latency(&uops[i], machine, forwarded[i]);
                    if cand > dist[i] {
                        dist[i] = cand;
                        prev[i] = Some(*w);
                    }
                }
            }
        }
    }
    if to <= from {
        // `to` must be downstream of `from` in program order for a
        // cycle through the back-edge; identical index = self-loop.
        if to == from {
            return Some((dist[from], vec![from]));
        }
        return None;
    }
    if dist[to] == f32::NEG_INFINITY {
        return None;
    }
    let mut path = vec![to];
    let mut cur = to;
    while let Some(p) = prev[cur] {
        path.push(p);
        cur = p;
    }
    path.reverse();
    Some((dist[to], path))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::extract_kernel;
    use crate::mdb::{skylake, zen};

    #[test]
    fn add_chain_carried_latency() {
        let src = "\n.L1:\nvaddpd %xmm1, %xmm0, %xmm0\ncmpl $1, %eax\njne .L1\n";
        let r = critical_path(&extract_kernel("t", src).unwrap(), &skylake()).unwrap();
        assert!((r.carried_per_iteration - 4.0).abs() < 1e-3, "{r:?}");
        let rz = critical_path(&extract_kernel("t", src).unwrap(), &zen()).unwrap();
        assert!((rz.carried_per_iteration - 3.0).abs() < 1e-3, "{rz:?}");
    }

    #[test]
    fn pi_o1_memory_cycle() {
        // store->load forwarding cycle: fwd + addsd + store-data.
        let src = "\n.L2:\nvaddsd (%rsp), %xmm0, %xmm5\nvmovsd %xmm5, (%rsp)\naddl $1, %eax\ncmpl $100, %eax\njne .L2\n";
        let r = critical_path(&extract_kernel("t", src).unwrap(), &skylake()).unwrap();
        // 4 (fwd) + 4 (addsd) + 1 (store) = 9.
        assert!((r.carried_per_iteration - 9.0).abs() < 1e-3, "{r:?}");
    }

    #[test]
    fn throughput_kernel_has_tiny_carried_path() {
        let src = "\n.L1:\nvaddpd %xmm3, %xmm0, %xmm0\nvaddpd %xmm4, %xmm1, %xmm1\naddl $1, %eax\ncmpl $100, %eax\njne .L1\n";
        let r = critical_path(&extract_kernel("t", src).unwrap(), &skylake()).unwrap();
        // Carried chains: each vaddpd on itself (4 cy), eax increment (1).
        assert!((r.carried_per_iteration - 4.0).abs() < 1e-3, "{r:?}");
        assert!(r.intra_iteration >= 4.0);
    }
}
