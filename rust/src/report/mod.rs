//! Report rendering: the paper's tables and port-model figures, plus
//! the pluggable text/JSON/CSV emitters (`emit`).

pub mod emit;
pub mod experiments;

use crate::analyzer::Analysis;
use crate::mdb::{MachineModel, Provenance};

/// Render a per-line occupancy table in the layout of paper Tables
/// II/IV/VI/VII: one column per port, hidden (hideable-load) occupancy
/// in parentheses, totals in the footer, bottleneck marked.
pub fn render_occupancy(analysis: &Analysis, machine: &MachineModel) -> String {
    let np = machine.n_ports();
    let mut out = String::new();
    let header: Vec<String> = machine.ports.iter().map(|p| format!("{p:>6}")).collect();
    out.push_str(&format!("{} | Assembly Instructions\n", header.join(" ")));
    out.push_str(&format!("{}\n", "-".repeat(7 * np + 24)));
    for line in &analysis.lines {
        let mut cells = String::new();
        for p in 0..np {
            let occ = line.occupancy[p];
            let hid = line.hidden[p];
            let cell = if hid > 0.0005 {
                format!("({hid:.2})")
            } else if occ > 0.0005 {
                format!("{occ:.2}")
            } else {
                String::new()
            };
            cells.push_str(&format!("{cell:>6} "));
        }
        let prov = match line.provenance {
            Provenance::Direct => "",
            Provenance::SynthesizedMem => " [mem-synth]",
            Provenance::SynthesizedSplit => " [256-split]",
            Provenance::SynthesizedSuffix => "",
        };
        out.push_str(&format!("{cells}| {}{prov}\n", line.text));
    }
    out.push_str(&format!("{}\n", "-".repeat(7 * np + 24)));
    let mut totals = String::new();
    for p in 0..np {
        totals.push_str(&format!("{:>6.2} ", analysis.totals[p]));
    }
    out.push_str(&format!("{totals}|\n"));
    out.push_str(&format!(
        "Throughput bottleneck: port {} ({}) -> {:.2} cy / assembly iteration\n",
        analysis.bottleneck_port, machine.ports[analysis.bottleneck_port], analysis.cy_per_asm_iter
    ));
    out
}

/// ASCII port-model diagram (Figs. 1-3): scheduler feeding ports, each
/// port listing the µ-op classes that the database maps to it.
pub fn render_port_diagram(machine: &MachineModel) -> String {
    let np = machine.n_ports();
    // Collect representative functional units per port from the DB.
    let mut units: Vec<Vec<&'static str>> = vec![Vec::new(); np];
    let tag_of = |m: &str| -> Option<&'static str> {
        Some(match () {
            _ if m.starts_with("vdiv") || m.starts_with("vsqrt") => "DIV",
            _ if m.starts_with("vfmadd") || m.starts_with("vfnmadd") => "FMA",
            _ if m.starts_with("vmul") => "FP MUL",
            _ if m.starts_with("vadd") || m.starts_with("vsub") => "FP ADD",
            _ if m.starts_with("vcvt") => "CVT",
            _ if m.starts_with("vextract") || m.starts_with("vshuf") || m.starts_with("vunpck") => {
                "SHUF"
            }
            _ if m.starts_with("vpadd") || m.starts_with("vpsub") => "VEC INT",
            _ if m == "add" || m == "sub" || m == "inc" || m == "cmp" => "ALU",
            _ if m == "shl" || m == "shr" || m == "sar" => "SHIFT",
            _ if m == "imul" => "INT MUL",
            _ if m == "lea" => "LEA",
            _ => return None,
        })
    };
    for e in machine.entries.values() {
        if let Some(tag) = tag_of(&e.form.mnemonic) {
            for u in &e.uops {
                if u.kind == crate::mdb::UopKind::Compute || u.kind == crate::mdb::UopKind::Divider
                {
                    for p in u.ports.iter() {
                        if !units[p].contains(&tag) {
                            units[p].push(tag);
                        }
                    }
                }
            }
        }
    }
    for (p, name) in machine.ports.iter().enumerate() {
        let n = name.to_ascii_uppercase();
        if machine.load_ports.contains(p) {
            units[p].insert(0, "LOAD/AGU");
        }
        if machine.store_data_ports.contains(p) && !n.contains("AGU") {
            units[p].insert(0, "STORE");
        }
        if machine.store_agu_ports.contains(p) && !machine.load_ports.contains(p) {
            units[p].insert(0, "AGU");
        }
    }

    let mut out = String::new();
    out.push_str(&format!(
        "{} ({}) @ {:.1} GHz — out-of-order port model\n",
        machine.arch_name, machine.name, machine.frequency_ghz
    ));
    out.push_str(&format!(
        "ROB {} µops | scheduler {} | rename {}/cy | retire {}/cy\n",
        machine.params.rob_size,
        machine.params.scheduler_size,
        machine.params.rename_width,
        machine.params.retire_width
    ));
    out.push_str("                 ┌───────────────────────────┐\n");
    out.push_str("                 │   out-of-order scheduler  │\n");
    out.push_str("                 └─┬───┬───┬───┬───┬───┬───┬─┘\n");
    for (p, name) in machine.ports.iter().enumerate() {
        let mut tags = units[p].clone();
        tags.sort();
        tags.dedup();
        out.push_str(&format!("  port {name:<5} -> {}\n", tags.join(", ")));
    }
    if machine.avx256_split {
        out.push_str("  (256-bit AVX executes as two 128-bit halves)\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::mdb::{skylake, zen};
    use crate::workloads;

    #[test]
    fn occupancy_table_contains_footer_and_bottleneck() {
        let w = workloads::find("triad", "skl", "-O3").unwrap();
        let m = skylake();
        let a = analyze(&w.kernel(), &m).unwrap();
        let s = render_occupancy(&a, &m);
        assert!(s.contains("Throughput bottleneck"));
        assert!(s.contains("2.00 cy"));
        assert!(s.contains("vfmadd132pd"));
    }

    #[test]
    fn zen_table_shows_hidden_loads_in_parens() {
        let w = workloads::find("triad", "zen", "-O3").unwrap();
        let m = zen();
        let a = analyze(&w.kernel(), &m).unwrap();
        let s = render_occupancy(&a, &m);
        assert!(s.contains("(0.50)"), "{s}");
    }

    #[test]
    fn port_diagram_mentions_units() {
        let d = render_port_diagram(&skylake());
        assert!(d.contains("FMA"));
        assert!(d.contains("DIV"));
        assert!(d.contains("LOAD"));
        let dz = render_port_diagram(&zen());
        assert!(dz.contains("256-bit"));
    }
}
