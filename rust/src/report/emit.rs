//! Pluggable report emitters: one [`Emitter`] trait, three built-in
//! implementations.
//!
//! * [`Text`] — the human-readable report: the paper-style occupancy
//!   table plus one line per section. With the frontend bound disabled
//!   (the default) its output is byte-for-byte what the pre-emitter
//!   `to_text` produced, so the paper-pinned table snapshots stay
//!   exact.
//! * [`Json`] — versioned machine-readable output (hand-rolled: serde
//!   is not vendored in the offline build). [`SCHEMA_VERSION`] is bumped
//!   whenever the key shape changes; `tests/report_formats.rs` pins the
//!   version-1 key set so a shape change without a bump fails CI.
//! * [`Csv`] — flat rows (one per bound / port total) for spreadsheet
//!   and shell-pipeline consumers.
//!
//! Emitters are selected per request (`AnalysisRequest::format`) or on
//! the CLI via `--format text|json|csv`; unknown names fail with the
//! structured `OsacaError::UnsupportedFormat`.

use std::fmt::Write as _;

use crate::api::{AnalysisReport, Bound, OsacaError};
use crate::report::render_occupancy;

/// Version of the machine-readable report schema (JSON `schema_version`
/// field, CSV first column, and the serve wire frames). Bump on any
/// change to the emitted key shape; numeric values may change freely.
///
/// v2: the prediction object absorbed the per-line occupancy rows
/// (`prediction.lines`, CSV `line_occupancy`/`line_hidden` records) and
/// the serve error/stats/ok/overloaded frames joined the contract.
///
/// v3: the serve fault-tolerance surface — a `rate_limited` frame
/// (`reason`, `retry_after_ms`), a `shedding` flag on `overloaded`
/// frames, and the `stats` frame grew the degradation counters
/// (`rate_limited`, `shed`, `deadline_expired`, `panics`,
/// `worker_restarts`, `oversized_frames`, `memo_bytes`, `shedding`).
/// The report JSON/CSV key shape is unchanged from v2.
///
/// v4: the opt-in memory model. A `memory` report section
/// (`working_set`, `bytes_per_iter`, `lines_per_iter`, `streams`,
/// `level`, `level_latency`, `cy_per_line`, `cy_per_asm_iter`,
/// `lsq_size`, `ecm`) appears when `AnalysisRequest::mem_model` is set,
/// the `simulation` section carries `lsq_stall_cycles`, and the bound
/// vocabulary gains `memory`. With the memory model off (the default)
/// only the version digit changes from v3.
///
/// v5: the model zoo. The serve `stats` frame grew a `model_reloads`
/// counter (completed `--models-dir` scans, including those triggered
/// by the `reload_models` wire op), and `reload_models` joined the
/// wire-op vocabulary. The report JSON/CSV key shape is unchanged
/// from v4.
pub const SCHEMA_VERSION: u32 = 5;

/// The built-in output formats.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Format {
    #[default]
    Text,
    Json,
    Csv,
}

impl Format {
    pub const ALL: [Format; 3] = [Format::Text, Format::Json, Format::Csv];

    pub fn name(self) -> &'static str {
        match self {
            Format::Text => "text",
            Format::Json => "json",
            Format::Csv => "csv",
        }
    }

    /// Parse a format name (CLI `--format` value). Unknown names
    /// produce the structured error listing what is supported.
    pub fn parse(name: &str) -> Result<Format, OsacaError> {
        Format::ALL
            .into_iter()
            .find(|f| f.name().eq_ignore_ascii_case(name))
            .ok_or_else(|| OsacaError::UnsupportedFormat {
                requested: name.to_string(),
                supported: Format::ALL.iter().map(|f| f.name().to_string()).collect(),
            })
    }

    /// The emitter implementing this format.
    pub fn emitter(self) -> &'static dyn Emitter {
        match self {
            Format::Text => &TEXT,
            Format::Json => &JSON,
            Format::Csv => &CSV,
        }
    }
}

/// A report emitter. The three built-ins cover text/JSON/CSV; the trait
/// is public so embedders can render an [`AnalysisReport`] into their
/// own wire format with the same signature.
pub trait Emitter: Sync {
    /// The format this emitter implements (diagnostics, dispatch).
    fn format(&self) -> Format;

    /// Serialize one report.
    fn emit(&self, report: &AnalysisReport) -> String;
}

/// Human-readable text (the default; paper-style table layout).
pub struct Text;
/// Versioned machine-readable JSON.
pub struct Json;
/// Flat machine-readable CSV.
pub struct Csv;

pub static TEXT: Text = Text;
pub static JSON: Json = Json;
pub static CSV: Csv = Csv;

impl Emitter for Text {
    fn format(&self) -> Format {
        Format::Text
    }

    fn emit(&self, r: &AnalysisReport) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== {} on {} ({}) ===", r.name, r.machine.arch_name, r.arch);
        let mut frontend_on = false;
        if let Some(t) = &r.throughput {
            out.push_str(&render_occupancy(t, &r.machine));
            if let Some(f) = &t.frontend {
                frontend_on = true;
                let _ = writeln!(
                    out,
                    "Width-aware frontend bound: {:.2} cy / assembly iteration ({})",
                    f.cy_per_asm_iter,
                    crate::sim::frontend_resource_label(f.slots, f.width)
                );
            }
        }
        if let Some(c) = &r.critpath {
            let _ = writeln!(
                out,
                "Critical path: {:.2} cy intra-iteration, {:.2} cy/it loop-carried bound",
                c.intra_iteration, c.carried_per_iteration
            );
        }
        if let Some(m) = &r.memory {
            let _ = writeln!(
                out,
                "Memory ({} in {}): {:.2} cy/line x {:.2} lines = {:.2} cy / assembly iteration",
                m.working_set_human(),
                m.level,
                m.cy_per_line,
                m.lines_per_iter,
                m.cy_per_asm_iter
            );
        }
        if let Some(b) = &r.baseline {
            let _ = writeln!(
                out,
                "Balanced (IACA-like) baseline: {:.2} cy / assembly iteration (uniform {:.2})",
                b.cy_per_asm_iter, b.uniform_cy
            );
        }
        if let Some(m) = &r.simulation {
            let _ = writeln!(
                out,
                "Simulated hardware: {:.3} cy / assembly iteration over {} iterations",
                m.cycles_per_iteration, m.iterations
            );
        }
        // One decomposition serves both closing lines. The winner line
        // only appears alongside the opt-in frontend bound, so default
        // text output is unchanged from the pre-emitter layout.
        if frontend_on || r.unroll > 1 {
            let p = r.prediction_shared();
            if frontend_on {
                if let Some(w) = p.winner() {
                    let _ = writeln!(
                        out,
                        "Prediction: {:.2} cy / assembly iteration — {} bound ({})",
                        w.cy_per_asm_iter,
                        w.kind.name(),
                        w.resource
                    );
                }
            }
            if r.unroll > 1 {
                if let Some(cy) = p.cy_per_source_it() {
                    let _ = writeln!(
                        out,
                        "Combined prediction: {cy:.2} cy / source iteration (unroll {})",
                        r.unroll
                    );
                }
            }
        }
        out
    }
}

impl Emitter for Json {
    fn format(&self) -> Format {
        Format::Json
    }

    fn emit(&self, r: &AnalysisReport) -> String {
        let p = r.prediction_shared();
        let mut out = String::from("{");
        let _ = write!(out, "\"schema_version\":{SCHEMA_VERSION},");
        push_str_field(&mut out, "name", &r.name);
        out.push(',');
        push_str_field(&mut out, "arch", &r.arch);
        out.push(',');
        push_str_field(&mut out, "isa", r.machine.isa.name());
        let _ = write!(out, ",\"unroll\":{}", r.unroll);
        out.push_str(",\"prediction\":{");
        match p.winner() {
            Some(w) => {
                let _ = write!(
                    out,
                    "\"cy_per_asm_iter\":{},\"cy_per_source_iter\":{},",
                    fmt_f32(w.cy_per_asm_iter),
                    fmt_f32(w.cy_per_asm_iter / r.unroll.max(1) as f32)
                );
                out.push_str("\"bound\":");
                push_json_string(&mut out, w.kind.name());
                out.push_str(",\"resource\":");
                push_json_string(&mut out, &w.resource);
            }
            None => out.push_str(
                "\"cy_per_asm_iter\":null,\"cy_per_source_iter\":null,\
                 \"bound\":null,\"resource\":null",
            ),
        }
        out.push_str(",\"bounds\":[");
        for (i, b) in p.bounds.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            push_bound(&mut out, b);
        }
        out.push_str("],\"lines\":[");
        for (i, l) in p.lines.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{{\"instr\":{},\"text\":", l.instr);
            push_json_string(&mut out, &l.text);
            out.push_str(",\"occupancy\":[");
            for (j, v) in l.occupancy.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&fmt_f32(*v));
            }
            out.push_str("],\"hidden\":[");
            for (j, v) in l.hidden.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&fmt_f32(*v));
            }
            out.push_str("],\"provenance\":");
            push_json_string(&mut out, l.provenance.name());
            out.push('}');
        }
        out.push_str("]}");
        if let Some(t) = &r.throughput {
            let _ = write!(
                out,
                ",\"throughput\":{{\"cy_per_asm_iter\":{},\"bottleneck_port\":",
                fmt_f32(t.cy_per_asm_iter)
            );
            push_json_string(&mut out, &r.machine.ports[t.bottleneck_port]);
            out.push_str(",\"totals\":[");
            for (i, v) in t.totals.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&fmt_f32(*v));
            }
            out.push(']');
            if let Some(f) = &t.frontend {
                let _ = write!(
                    out,
                    ",\"frontend\":{{\"slots\":{},\"rename_width\":{},\"cy_per_asm_iter\":{}}}",
                    f.slots,
                    f.width,
                    fmt_f32(f.cy_per_asm_iter)
                );
            }
            out.push('}');
        }
        if let Some(c) = &r.critpath {
            let _ = write!(
                out,
                ",\"critpath\":{{\"intra_iteration\":{},\"carried_per_iteration\":{}}}",
                fmt_f32(c.intra_iteration),
                fmt_f32(c.carried_per_iteration)
            );
        }
        if let Some(m) = &r.memory {
            let _ = write!(
                out,
                ",\"memory\":{{\"working_set\":{},\"bytes_per_iter\":{},\
                 \"lines_per_iter\":{},\"streams\":{},\"level\":",
                m.working_set,
                m.bytes_per_iter,
                fmt_f32(m.lines_per_iter),
                m.streams
            );
            push_json_string(&mut out, &m.level);
            let _ = write!(
                out,
                ",\"level_latency\":{},\"cy_per_line\":{},\"cy_per_asm_iter\":{},\
                 \"lsq_size\":{},\"ecm\":[",
                m.level_latency_cy,
                fmt_f32(m.cy_per_line),
                fmt_f32(m.cy_per_asm_iter),
                m.lsq_size
            );
            for (i, (name, cy)) in m.ecm.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('[');
                push_json_string(&mut out, name);
                let _ = write!(out, ",{}]", fmt_f32(*cy));
            }
            out.push_str("]}");
        }
        if let Some(b) = &r.baseline {
            let _ = write!(
                out,
                ",\"baseline\":{{\"cy_per_asm_iter\":{},\"uniform_cy\":{}}}",
                fmt_f32(b.cy_per_asm_iter),
                fmt_f32(b.uniform_cy)
            );
        }
        if let Some(m) = &r.simulation {
            let _ = write!(
                out,
                ",\"simulation\":{{\"cycles_per_iteration\":{},\"iterations\":{},\
                 \"issue_stall_cycles\":{},\"forwarded_loads\":{},\"lsq_stall_cycles\":{}}}",
                fmt_f64(m.cycles_per_iteration),
                m.iterations,
                m.counters.issue_stall_cycles,
                m.counters.forwarded_loads,
                m.counters.lsq_stall_cycles
            );
        }
        out.push('}');
        out
    }
}

fn push_bound(out: &mut String, b: &Bound) {
    out.push_str("{\"kind\":");
    push_json_string(out, b.kind.name());
    out.push_str(",\"resource\":");
    push_json_string(out, &b.resource);
    let _ = write!(out, ",\"cy_per_asm_iter\":{},\"source\":", fmt_f32(b.cy_per_asm_iter));
    push_json_string(out, b.source.name());
    let _ = write!(out, ",\"model_bound\":{}}}", b.kind.is_model_bound());
}

impl Emitter for Csv {
    fn format(&self) -> Format {
        Format::Csv
    }

    fn emit(&self, r: &AnalysisReport) -> String {
        let p = r.prediction_shared();
        let mut out = String::from(
            "schema_version,name,arch,isa,unroll,record,kind,resource,cy_per_asm_iter\n",
        );
        let prefix = format!(
            "{SCHEMA_VERSION},{},{},{},{}",
            csv_field(&r.name),
            csv_field(&r.arch),
            r.machine.isa.name(),
            r.unroll
        );
        for b in &p.bounds {
            let record = if b.kind.is_model_bound() { "bound" } else { "observation" };
            let _ = writeln!(
                out,
                "{prefix},{record},{},{},{}",
                b.kind.name(),
                csv_field(&b.resource),
                fmt_f32(b.cy_per_asm_iter)
            );
        }
        if let Some(w) = p.winner() {
            let _ = writeln!(
                out,
                "{prefix},prediction,{},{},{}",
                w.kind.name(),
                csv_field(&w.resource),
                fmt_f32(w.cy_per_asm_iter)
            );
        }
        if let Some(t) = &r.throughput {
            for (i, v) in t.totals.iter().enumerate() {
                let _ = writeln!(
                    out,
                    "{prefix},port_total,port,{},{}",
                    csv_field(&r.machine.ports[i]),
                    fmt_f32(*v)
                );
            }
        }
        // Per-line rows mirror `prediction.lines` in the JSON shape:
        // one row per nonzero cell, kind = port, resource = the line
        // label (`#<index> <instruction text>`, quoted — AT&T operand
        // lists contain commas).
        for l in &p.lines {
            let label = csv_field(&format!("#{} {}", l.instr, l.text));
            for (i, v) in l.occupancy.iter().enumerate() {
                if *v != 0.0 {
                    let _ = writeln!(
                        out,
                        "{prefix},line_occupancy,{},{label},{}",
                        csv_field(&r.machine.ports[i]),
                        fmt_f32(*v)
                    );
                }
            }
            for (i, v) in l.hidden.iter().enumerate() {
                if *v != 0.0 {
                    let _ = writeln!(
                        out,
                        "{prefix},line_hidden,{},{label},{}",
                        csv_field(&r.machine.ports[i]),
                        fmt_f32(*v)
                    );
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Serve wire frames.
//
// The `osaca::serve` service speaks newline-delimited JSON; every frame
// it emits is versioned with the same [`SCHEMA_VERSION`] as the report
// emitters because the frames wrap (or stand in for) emitter output —
// a consumer that pins the report shape needs the envelope pinned by
// the same number, and an error/stats shape change is as much a wire
// break as a report shape change. Frames are built here rather than in
// `serve` so the whole machine-readable surface lives under one roof
// (and one version-bump policy).
// ---------------------------------------------------------------------------

/// Success envelope for one `analyze` request. For the JSON format the
/// rendered report is embedded raw (it is already a JSON object); text
/// and CSV renderings are carried as a JSON string. `report` is the
/// last key so stream consumers can slice it off positionally.
pub fn ok_frame(format: Format, memo_hit: bool, rendered: &str) -> String {
    let mut out = String::with_capacity(rendered.len() + 96);
    let _ = write!(
        out,
        "{{\"schema_version\":{SCHEMA_VERSION},\"status\":\"ok\",\"memo_hit\":{memo_hit},\
         \"format\":\"{}\",\"report\":",
        format.name()
    );
    match format {
        Format::Json => out.push_str(rendered),
        Format::Text | Format::Csv => push_json_string(&mut out, rendered),
    }
    out.push('}');
    out
}

/// Structured error envelope (`kind` is machine-readable — an
/// [`OsacaError::kind_name`] or the wire-level `bad_request`).
pub fn error_frame(kind: &str, message: &str) -> String {
    let mut out = String::with_capacity(message.len() + 80);
    let _ = write!(out, "{{\"schema_version\":{SCHEMA_VERSION},\"status\":\"error\",\"error\":{{\"kind\":");
    push_json_string(&mut out, kind);
    out.push_str(",\"message\":");
    push_json_string(&mut out, message);
    out.push_str("}}");
    out
}

/// Backpressure envelope: the request was rejected without being
/// enqueued — either the target shard's queue was full (`shedding:
/// false`) or the server is in load-shed mode and refusing fresh
/// analyses service-wide (`shedding: true`).
pub fn overloaded_frame(shard: usize, queue_depth: u64, shedding: bool) -> String {
    format!(
        "{{\"schema_version\":{SCHEMA_VERSION},\"status\":\"overloaded\",\
         \"shard\":{shard},\"queue_depth\":{queue_depth},\"shedding\":{shedding}}}"
    )
}

/// Per-connection fairness rejection: the client exceeded its token
/// bucket (`reason: "rps"`) or its in-flight cap (`reason:
/// "inflight"`). `retry_after_ms` is the earliest time a retry can
/// succeed assuming no other traffic on the connection.
pub fn rate_limited_frame(reason: &str, retry_after_ms: u64) -> String {
    let mut out = String::with_capacity(96);
    let _ = write!(
        out,
        "{{\"schema_version\":{SCHEMA_VERSION},\"status\":\"rate_limited\",\"reason\":"
    );
    push_json_string(&mut out, reason);
    let _ = write!(out, ",\"retry_after_ms\":{retry_after_ms}}}");
    out
}

/// Acknowledgement for a wire `shutdown` request, sent before the
/// server drains.
pub fn bye_frame() -> String {
    format!("{{\"schema_version\":{SCHEMA_VERSION},\"status\":\"bye\"}}")
}

/// Snapshot rendered for a wire `stats` request. Plain data — `serve`
/// fills it from its counters; rendering lives here with the other
/// frames so the key set is covered by the schema-version policy.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StatsFrame {
    /// Analyze-op responses sent (ok + error + overloaded).
    pub served: u64,
    /// Requests answered from the cross-request memo.
    pub memo_hits: u64,
    /// Requests that missed the memo and ran an analysis.
    pub memo_misses: u64,
    /// Full analyses executed (misses that reached an engine).
    pub analyses: u64,
    /// Error frames sent.
    pub errors: u64,
    /// Overloaded (queue-full) frames sent.
    pub overloaded: u64,
    /// Rate-limited frames sent (token bucket + in-flight cap).
    pub rate_limited: u64,
    /// Analyses rejected by load-shed mode (memo misses only — hits
    /// are still served while shedding).
    pub shed: u64,
    /// Requests whose `deadline_ms` expired while queued; dropped at
    /// dispatch with a `deadline_exceeded` frame.
    pub deadline_expired: u64,
    /// Worker panics caught by shard supervision.
    pub panics: u64,
    /// Workers restarted with a fresh engine after a panic.
    pub worker_restarts: u64,
    /// Frames rejected for exceeding the wire frame-size limit.
    pub oversized_frames: u64,
    /// Completed dynamic-model directory scans (startup + every
    /// `reload_models` wire op; process-wide).
    pub model_reloads: u64,
    /// Memo entries currently resident.
    pub memo_len: u64,
    /// Approximate bytes held by memoized rendered reports.
    pub memo_bytes: u64,
    /// Whether load-shed mode is active at snapshot time.
    pub shedding: bool,
    /// Per-shard queued+in-flight gauge at snapshot time.
    pub queue_depths: Vec<u64>,
}

impl StatsFrame {
    pub fn render(&self) -> String {
        let mut out = format!(
            "{{\"schema_version\":{SCHEMA_VERSION},\"status\":\"stats\",\"served\":{},\
             \"memo_hits\":{},\"memo_misses\":{},\"analyses\":{},\"errors\":{},\
             \"overloaded\":{},\"rate_limited\":{},\"shed\":{},\"deadline_expired\":{},\
             \"panics\":{},\"worker_restarts\":{},\"oversized_frames\":{},\
             \"model_reloads\":{},\
             \"memo_len\":{},\"memo_bytes\":{},\"shedding\":{},\"queue_depths\":[",
            self.served,
            self.memo_hits,
            self.memo_misses,
            self.analyses,
            self.errors,
            self.overloaded,
            self.rate_limited,
            self.shed,
            self.deadline_expired,
            self.panics,
            self.worker_restarts,
            self.oversized_frames,
            self.model_reloads,
            self.memo_len,
            self.memo_bytes,
            self.shedding
        );
        for (i, d) in self.queue_depths.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{d}");
        }
        out.push_str("]}");
        out
    }
}

/// Shortest-roundtrip float rendering; non-finite values become `null`
/// so JSON output always parses.
pub(crate) fn fmt_f32(v: f32) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

pub(crate) fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn push_str_field(out: &mut String, key: &str, value: &str) {
    push_json_string(out, key);
    out.push(':');
    push_json_string(out, value);
}

/// Append `s` as a JSON string literal (quotes, escapes).
pub fn push_json_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// `s` as a JSON string literal.
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    push_json_string(&mut out, s);
    out
}

/// Escape one CSV field (RFC 4180: quote when it contains a comma,
/// quote or newline; double embedded quotes).
pub fn csv_field(s: &str) -> String {
    if s.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping() {
        let mut s = String::new();
        push_json_string(&mut s, "a\"b\\c\nd");
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn csv_escaping() {
        assert_eq!(csv_field("plain"), "plain");
        assert_eq!(csv_field("a,b"), "\"a,b\"");
        assert_eq!(csv_field("q\"q"), "\"q\"\"q\"");
    }

    #[test]
    fn format_parse_round_trips_and_rejects() {
        for f in Format::ALL {
            assert_eq!(Format::parse(f.name()).unwrap(), f);
            assert_eq!(f.emitter().format(), f);
        }
        assert_eq!(Format::parse("JSON").unwrap(), Format::Json);
        match Format::parse("yaml") {
            Err(OsacaError::UnsupportedFormat { requested, supported }) => {
                assert_eq!(requested, "yaml");
                assert_eq!(supported, vec!["text", "json", "csv"]);
            }
            other => panic!("expected UnsupportedFormat, got {other:?}"),
        }
    }

    #[test]
    fn wire_frames_are_versioned_and_escaped() {
        let ok = ok_frame(Format::Json, true, "{\"k\":1}");
        assert!(ok.starts_with("{\"schema_version\":5,\"status\":\"ok\",\"memo_hit\":true,"));
        assert!(ok.ends_with(",\"report\":{\"k\":1}}"), "report must be the raw last key: {ok}");
        let ok_text = ok_frame(Format::Text, false, "line one\nline two");
        assert!(ok_text.ends_with(",\"report\":\"line one\\nline two\"}"));

        let e = error_frame("bad_request", "not a \"frame\"");
        assert!(e.starts_with("{\"schema_version\":5,\"status\":\"error\",\"error\":{\"kind\":\"bad_request\""));
        assert!(e.contains("\\\"frame\\\""));

        assert_eq!(
            overloaded_frame(1, 64, false),
            "{\"schema_version\":5,\"status\":\"overloaded\",\"shard\":1,\
             \"queue_depth\":64,\"shedding\":false}"
        );
        assert_eq!(
            rate_limited_frame("rps", 250),
            "{\"schema_version\":5,\"status\":\"rate_limited\",\"reason\":\"rps\",\
             \"retry_after_ms\":250}"
        );
        assert_eq!(bye_frame(), "{\"schema_version\":5,\"status\":\"bye\"}");

        let s = StatsFrame { served: 2, memo_hits: 1, queue_depths: vec![0, 3], ..Default::default() };
        let rendered = s.render();
        assert!(rendered.starts_with("{\"schema_version\":5,\"status\":\"stats\",\"served\":2,"));
        assert!(rendered.contains("\"rate_limited\":0"));
        assert!(rendered.contains("\"deadline_expired\":0"));
        assert!(rendered.contains("\"worker_restarts\":0"));
        assert!(rendered.contains("\"model_reloads\":0"));
        assert!(rendered.contains("\"memo_bytes\":0"));
        assert!(rendered.contains("\"shedding\":false"));
        assert!(rendered.ends_with("\"queue_depths\":[0,3]}"));
    }

    #[test]
    fn float_rendering_is_shortest_and_null_safe() {
        assert_eq!(fmt_f32(2.0), "2");
        assert_eq!(fmt_f32(1.25), "1.25");
        assert_eq!(fmt_f32(f32::NAN), "null");
        assert_eq!(fmt_f64(f64::INFINITY), "null");
    }
}
