//! Experiment drivers regenerating the paper's Tables I, III and V.
//!
//! Each function returns structured rows; the CLI and the bench
//! binaries render them. "Measured" values come from the simulator
//! substrate at the paper's fixed 1.8 GHz (see DESIGN.md §2).

use anyhow::Result;

use crate::analyzer::analyze;
use crate::coordinator::Coordinator;
use crate::mdb;
use crate::sim::{simulate, SimConfig};
use crate::workloads::{self, Workload};

/// Row of Table I: triad predictions per compile variant.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub compiled_for: &'static str,
    pub flag: &'static str,
    pub unroll: usize,
    pub osaca_zen: f32,
    pub osaca_skl: f32,
    /// IACA-like baseline, Skylake only (IACA does not support Zen).
    pub iaca_skl: f32,
}

/// Regenerate Table I (OSACA/IACA throughput analyses of the triad).
pub fn table1(coord: &Coordinator) -> Result<Vec<Table1Row>> {
    let skl = mdb::skylake();
    let zen = mdb::zen();
    let mut rows = Vec::new();
    for target in ["skl", "zen"] {
        for flag in ["-O1", "-O2", "-O3"] {
            let w = workloads::find("triad", target, flag).expect("triad fixture");
            let k = w.kernel();
            let osaca_zen = analyze(&k, &zen)?.cy_per_asm_iter;
            let osaca_skl = analyze(&k, &skl)?.cy_per_asm_iter;
            let iaca_skl = coord.analyze_kernel(&k, &skl)?.baseline.cy_per_asm_iter;
            rows.push(Table1Row {
                compiled_for: target,
                flag: w.flag,
                unroll: w.unroll,
                osaca_zen,
                osaca_skl,
                iaca_skl,
            });
        }
    }
    Ok(rows)
}

/// Row of Table III: measured triad performance vs predictions.
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub executed_on: &'static str,
    pub compiled_for: &'static str,
    pub flag: &'static str,
    pub unroll: usize,
    pub mflops: f64,
    pub mits: f64,
    pub measured_cy_it: f64,
    pub osaca_cy_it: f32,
    /// `None` on Zen (IACA is Intel-only).
    pub iaca_cy_it: Option<f32>,
}

/// Regenerate Table III: run every triad variant on both simulated
/// machines and compare with OSACA / baseline predictions.
pub fn table3(coord: &Coordinator, cfg: SimConfig) -> Result<Vec<Table3Row>> {
    let mut rows = Vec::new();
    for exec_arch in ["zen", "skl"] {
        let machine = mdb::by_name(exec_arch).unwrap();
        for target in ["zen", "skl"] {
            for flag in ["-O1", "-O2", "-O3"] {
                let w: &Workload = workloads::find("triad", target, flag).expect("fixture");
                let k = w.kernel();
                let m = simulate(&k, &machine, cfg)?;
                let cy_it = m.cy_per_source_it(w.unroll);
                let mits = machine.frequency_ghz * 1e3 / cy_it; // Mit/s
                let mflops = mits * w.flops_per_it as f64;
                let osaca = analyze(&k, &machine)?.cy_per_asm_iter / w.unroll as f32;
                let iaca = if exec_arch == "skl" {
                    Some(
                        coord.analyze_kernel(&k, &machine)?.baseline.cy_per_asm_iter
                            / w.unroll as f32,
                    )
                } else {
                    None
                };
                rows.push(Table3Row {
                    executed_on: machine_label(exec_arch),
                    compiled_for: machine_label(target),
                    flag: w.flag,
                    unroll: w.unroll,
                    mflops,
                    mits,
                    measured_cy_it: cy_it,
                    osaca_cy_it: osaca,
                    iaca_cy_it: iaca,
                });
            }
        }
    }
    Ok(rows)
}

/// Row of Table V: π benchmark predictions and measurements.
#[derive(Debug, Clone)]
pub struct Table5Row {
    pub arch: &'static str,
    pub flag: &'static str,
    pub iaca_cy_it: Option<f32>,
    pub osaca_cy_it: f32,
    pub measured_cy_it: f64,
    /// Issue-stall fraction in the measured window (the §III-B counter
    /// discussion: -O1 stalls ~17x more than -O2 on SKL).
    pub stall_fraction: f64,
}

/// Regenerate Table V (π benchmark; analyze and run only on the arch
/// compiled for, as in the paper).
pub fn table5(coord: &Coordinator, cfg: SimConfig) -> Result<Vec<Table5Row>> {
    let mut rows = Vec::new();
    for arch in ["skl", "zen"] {
        let machine = mdb::by_name(arch).unwrap();
        for flag in ["-O1", "-O2", "-O3"] {
            let w = workloads::find("pi", arch, flag).expect("pi fixture");
            let k = w.kernel();
            let m = simulate(&k, &machine, cfg)?;
            let osaca = analyze(&k, &machine)?.cy_per_asm_iter / w.unroll as f32;
            let iaca = if arch == "skl" {
                Some(coord.analyze_kernel(&k, &machine)?.baseline.cy_per_asm_iter / w.unroll as f32)
            } else {
                None
            };
            rows.push(Table5Row {
                arch: machine_label(arch),
                flag: w.flag,
                iaca_cy_it: iaca,
                osaca_cy_it: osaca,
                measured_cy_it: m.cy_per_source_it(w.unroll),
                stall_fraction: m.counters.issue_stall_cycles as f64 / m.window_cycles as f64,
            });
        }
    }
    Ok(rows)
}

fn machine_label(arch: &str) -> &'static str {
    match arch {
        "skl" => "Skylake",
        "zen" => "Zen",
        _ => "?",
    }
}

/// One point of the working-set sweep: the kernel re-analyzed with its
/// working set pinned to `working_set` bytes under the opt-in memory
/// model, next to the infinite-L1 prediction for the same kernel.
#[derive(Debug, Clone)]
pub struct MemSweepRow {
    pub working_set: u64,
    /// Analytic prediction with the memory model on (cy / asm iter).
    pub cy_per_asm_iter: f32,
    /// Which bound won (`port_pressure`, `memory`, ...).
    pub bound: &'static str,
    /// Hierarchy level the working set was assigned to.
    pub level: String,
    /// The infinite-L1 prediction (identical for every row).
    pub infinite_l1_cy: f32,
}

/// Default sweep sizes: L1-resident through far beyond every built-in
/// LLC (16 KiB .. 64 MiB).
pub const MEM_SWEEP_SIZES: [u64; 7] = [
    16 << 10,
    64 << 10,
    256 << 10,
    1 << 20,
    4 << 20,
    16 << 20,
    64 << 20,
];

/// The working-set sweep the paper's infinite-L1 model cannot produce:
/// re-analyze one workload at each pinned working-set size and report
/// where the memory bound overtakes the in-core bounds. Cache-aware
/// predictions must be monotone non-decreasing in footprint, and the
/// L1-resident point must equal the infinite-L1 prediction exactly —
/// `ci.sh --mem-smoke` gates both on the release binary.
pub fn mem_sweep(
    family: &str,
    target: &str,
    flag: &str,
    arch: &str,
    sizes: &[u64],
) -> Result<Vec<MemSweepRow>> {
    use crate::api::{Engine, Passes};
    let w = workloads::find(family, target, flag)
        .ok_or_else(|| anyhow::anyhow!("no fixture {family}/{target}/{flag}"))?;
    let engine = Engine::cpu_only();
    let base = engine
        .analyze(
            &Engine::request(&w.name())
                .arch(arch)
                .source(w.source)
                .passes(Passes::THROUGHPUT)
                .unroll(w.unroll),
        )
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let infinite_l1_cy = base.predicted_cy_per_asm_iter().unwrap_or(0.0);
    let mut rows = Vec::with_capacity(sizes.len());
    for &ws in sizes {
        let report = engine
            .analyze(
                &Engine::request(&w.name())
                    .arch(arch)
                    .source(w.source)
                    .passes(Passes::THROUGHPUT)
                    .unroll(w.unroll)
                    .mem_model(format!("ws={ws}")),
            )
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let p = report.prediction();
        let winner = p.winner().ok_or_else(|| anyhow::anyhow!("no model bound"))?;
        rows.push(MemSweepRow {
            working_set: ws,
            cy_per_asm_iter: winner.cy_per_asm_iter,
            bound: winner.kind.name(),
            level: report.memory.as_ref().map(|m| m.level.clone()).unwrap_or_default(),
            infinite_l1_cy,
        });
    }
    Ok(rows)
}

/// One cell of the cross-model validation sweep: a workload fixture
/// analyzed against one registered machine model. Error cells are
/// first-class (a partial model like `hsw` lacks divide entries, and
/// the sweep must say so deterministically rather than abort).
#[derive(Debug, Clone)]
pub struct ZooSweepRow {
    pub workload: String,
    pub model: String,
    pub isa: &'static str,
    /// Analytic prediction; `None` when the cell errored.
    pub cy_per_asm_iter: Option<f32>,
    /// Winning bound (`port_pressure`, `frontend`, ...); empty on error.
    pub bound: String,
    /// Structured error kind + message for failed cells.
    pub error: Option<String>,
}

/// The cross-model validation sweep (`osaca zoo-sweep`): every
/// embedded workload fixture × every registered ISA-matching model —
/// the five built-ins plus everything `import-model`/`--models-dir`
/// registered. Deterministic order (fixtures in declaration order,
/// models sorted by name) so two runs render byte-identical
/// scorecards; `ci.sh --zoo-smoke` gates on that.
pub fn zoo_sweep(engine: &crate::api::Engine) -> Vec<ZooSweepRow> {
    use crate::api::{Engine, Passes};
    let mut models: Vec<String> =
        mdb::builtin_names().iter().map(|s| s.to_string()).collect();
    models.extend(mdb::registry_names());
    models.sort();
    models.dedup();
    let mut rows = Vec::new();
    for w in workloads::all_isa() {
        for name in &models {
            let machine = match engine.machine(name) {
                Ok(m) => m,
                Err(_) => continue, // racing unregister; not reachable in the CLI
            };
            if machine.isa != w.isa {
                continue;
            }
            let req = Engine::request(&w.name())
                .machine(machine)
                .source(w.source)
                .passes(Passes::THROUGHPUT)
                .unroll(w.unroll);
            let row = match engine.analyze(&req) {
                Ok(report) => {
                    let p = report.prediction();
                    match p.winner() {
                        Some(winner) => ZooSweepRow {
                            workload: w.name(),
                            model: name.clone(),
                            isa: w.isa.name(),
                            cy_per_asm_iter: Some(winner.cy_per_asm_iter),
                            bound: winner.kind.name().to_string(),
                            error: None,
                        },
                        None => ZooSweepRow {
                            workload: w.name(),
                            model: name.clone(),
                            isa: w.isa.name(),
                            cy_per_asm_iter: None,
                            bound: String::new(),
                            error: Some("internal: no model bound".to_string()),
                        },
                    }
                }
                Err(e) => ZooSweepRow {
                    workload: w.name(),
                    model: name.clone(),
                    isa: w.isa.name(),
                    cy_per_asm_iter: None,
                    bound: String::new(),
                    error: Some(format!("{}: {e}", e.kind_name())),
                },
            };
            rows.push(row);
        }
    }
    rows
}

pub fn render_zoo_sweep(rows: &[ZooSweepRow]) -> Vec<Vec<String>> {
    rows.iter()
        .map(|r| {
            vec![
                r.workload.clone(),
                r.model.clone(),
                r.isa.to_string(),
                r.cy_per_asm_iter.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
                if r.error.is_some() { "error".to_string() } else { r.bound.clone() },
            ]
        })
        .collect()
}

pub fn render_mem_sweep(rows: &[MemSweepRow]) -> Vec<Vec<String>> {
    rows.iter()
        .map(|r| {
            vec![
                crate::mdb::format::fmt_size(r.working_set),
                format!("{:.2}", r.cy_per_asm_iter),
                r.bound.to_string(),
                r.level.clone(),
                format!("{:.2}", r.infinite_l1_cy),
            ]
        })
        .collect()
}

/// Format helpers shared by CLI and benches.
pub fn render_table1(rows: &[Table1Row]) -> Vec<Vec<String>> {
    rows.iter()
        .map(|r| {
            vec![
                machine_label(r.compiled_for).to_string(),
                r.flag.to_string(),
                format!("{}", r.unroll),
                format!("{:.2}", r.osaca_zen),
                format!("{:.2}", r.osaca_skl),
                format!("{:.2}", r.iaca_skl),
            ]
        })
        .collect()
}

pub fn render_table3(rows: &[Table3Row]) -> Vec<Vec<String>> {
    rows.iter()
        .map(|r| {
            vec![
                r.executed_on.to_string(),
                r.compiled_for.to_string(),
                r.flag.to_string(),
                format!("{}x", r.unroll),
                format!("{:.0}", r.mflops),
                format!("{:.0}", r.mits),
                format!("{:.2}", r.measured_cy_it),
                format!("{:.2}", r.osaca_cy_it),
                r.iaca_cy_it.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            ]
        })
        .collect()
}

pub fn render_table5(rows: &[Table5Row]) -> Vec<Vec<String>> {
    rows.iter()
        .map(|r| {
            vec![
                r.arch.to_string(),
                r.flag.to_string(),
                r.iaca_cy_it.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
                format!("{:.2}", r.osaca_cy_it),
                format!("{:.2}", r.measured_cy_it),
                format!("{:.1}%", r.stall_fraction * 100.0),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> SimConfig {
        SimConfig { iterations: 300, warmup: 80 }
    }

    #[test]
    fn zoo_sweep_covers_every_isa_matching_builtin_cell() {
        let engine = crate::api::Engine::cpu_only();
        let rows = zoo_sweep(&engine);
        // Every x86 fixture meets all three x86 built-ins; foreign-ISA
        // models never appear in x86 rows. (Containment, not equality:
        // the registry is process-global and sibling tests register
        // extra throwaway models.)
        let triad_skl: Vec<&ZooSweepRow> =
            rows.iter().filter(|r| r.workload == "triad-skl-O3").collect();
        let models: Vec<&str> = triad_skl.iter().map(|r| r.model.as_str()).collect();
        for builtin in ["hsw", "skl", "zen"] {
            assert!(models.contains(&builtin), "{models:?}");
        }
        assert!(!models.contains(&"tx2") && !models.contains(&"rv64"), "{models:?}");
        let skl_cell = triad_skl.iter().find(|r| r.model == "skl").unwrap();
        assert_eq!(skl_cell.cy_per_asm_iter, Some(2.0), "{skl_cell:?}");
        assert_eq!(skl_cell.bound, "port_pressure");
        assert!(skl_cell.error.is_none());
        // The foreign-ISA fixtures sweep against their own models.
        assert!(rows.iter().any(|r| r.model == "tx2" && r.isa == "aarch64"));
        assert!(rows.iter().any(|r| r.model == "rv64" && r.isa == "riscv"));
        // Error cells are structured, not panics/aborts.
        for r in &rows {
            assert_eq!(r.error.is_some(), r.cy_per_asm_iter.is_none(), "{r:?}");
        }
    }

    #[test]
    fn mem_sweep_is_monotone_and_anchored_at_infinite_l1() {
        // Strided triad on skl: 8 lines/iter; ECM cy/line 0 (l1),
        // 1 (l2), 5 (l3), 9.5 (mem) -> memory bounds 0/8/40/76 against
        // the 2.0 port bound.
        let rows =
            mem_sweep("triad-strided", "any", "-O3", "skl", &MEM_SWEEP_SIZES).unwrap();
        assert_eq!(rows.len(), 7);
        let cys: Vec<f32> = rows.iter().map(|r| r.cy_per_asm_iter).collect();
        assert_eq!(cys, vec![2.0, 8.0, 8.0, 8.0, 40.0, 76.0, 76.0]);
        // L1-resident == the infinite-L1 prediction, exactly.
        assert_eq!(rows[0].cy_per_asm_iter, rows[0].infinite_l1_cy);
        assert_eq!(rows[0].bound, "port_pressure");
        assert_eq!(rows[0].level, "l1");
        for w in rows.windows(2) {
            assert!(w[1].cy_per_asm_iter >= w[0].cy_per_asm_iter, "{w:?}");
        }
        for r in &rows[1..] {
            assert_eq!(r.bound, "memory", "{r:?}");
            assert_eq!(r.infinite_l1_cy, 2.0);
        }
        assert_eq!(rows[4].level, "l3");
        assert_eq!(rows[6].level, "mem");
    }

    #[test]
    fn table1_shape_matches_paper() {
        let coord = Coordinator::cpu_only();
        let rows = table1(&coord).unwrap();
        assert_eq!(rows.len(), 6);
        // All OSACA SKL predictions are 2.00 (paper Table I column 5).
        for r in &rows {
            assert!((r.osaca_skl - 2.0).abs() < 0.01, "{r:?}");
        }
        // SKL -O3 (ymm) analyzed for Zen costs 4.00; all other Zen
        // entries are 2.00.
        for r in &rows {
            let want = if r.flag == "-O3" && r.compiled_for == "skl" { 4.0 } else { 2.0 };
            assert!((r.osaca_zen - want).abs() < 0.01, "{r:?}");
        }
    }

    #[test]
    fn table3_shape_matches_paper() {
        let coord = Coordinator::cpu_only();
        let rows = table3(&coord, quick_cfg()).unwrap();
        assert_eq!(rows.len(), 12);
        for r in &rows {
            // Measured cy/it within 15% of the OSACA prediction except
            // where the paper also deviates (all triad rows agree).
            let ratio = r.measured_cy_it / r.osaca_cy_it as f64;
            assert!(
                (0.85..1.35).contains(&ratio),
                "{} {} {}: measured {:.2} vs osaca {:.2}",
                r.executed_on,
                r.compiled_for,
                r.flag,
                r.measured_cy_it,
                r.osaca_cy_it
            );
        }
        // The paper's headline cross-run effect: SKL-compiled -O3 code
        // runs at ~1 cy/it on Zen but ~0.5 cy/it on SKL.
        let zen_run = rows
            .iter()
            .find(|r| r.executed_on == "Zen" && r.compiled_for == "Skylake" && r.flag == "-O3")
            .unwrap();
        let skl_run = rows
            .iter()
            .find(|r| r.executed_on == "Skylake" && r.compiled_for == "Skylake" && r.flag == "-O3")
            .unwrap();
        assert!(zen_run.measured_cy_it > 1.7 * skl_run.measured_cy_it, "{zen_run:?} {skl_run:?}");
    }

    #[test]
    fn table5_shape_matches_paper() {
        let coord = Coordinator::cpu_only();
        let rows = table5(&coord, quick_cfg()).unwrap();
        assert_eq!(rows.len(), 6);
        let get = |arch: &str, flag: &str| {
            rows.iter().find(|r| r.arch == arch && r.flag == flag).unwrap()
        };
        // -O1: measurement blows past the prediction on both archs
        // (store-forwarding chain; paper: 9.02 vs 4.75 and 11.48 vs 4).
        let skl_o1 = get("Skylake", "-O1");
        assert!(skl_o1.measured_cy_it > 1.7 * skl_o1.osaca_cy_it as f64, "{skl_o1:?}");
        assert!((skl_o1.measured_cy_it - 9.0).abs() < 0.8, "{skl_o1:?}");
        let zen_o1 = get("Zen", "-O1");
        assert!((zen_o1.measured_cy_it - 11.0).abs() < 1.0, "{zen_o1:?}");
        // -O2 SKL: OSACA over-predicts (4.25 vs 4.00 measured).
        let skl_o2 = get("Skylake", "-O2");
        assert!((skl_o2.osaca_cy_it - 4.25).abs() < 0.01, "{skl_o2:?}");
        assert!((skl_o2.measured_cy_it - 4.0).abs() < 0.2, "{skl_o2:?}");
        assert!((skl_o2.iaca_cy_it.unwrap() - 4.0).abs() < 0.1, "{skl_o2:?}");
        // -O2 Zen: ~20% slower than the 4.00 prediction (divider).
        let zen_o2 = get("Zen", "-O2");
        assert!((zen_o2.osaca_cy_it - 4.0).abs() < 0.01, "{zen_o2:?}");
        assert!(zen_o2.measured_cy_it > 4.5 && zen_o2.measured_cy_it < 5.5, "{zen_o2:?}");
        // -O3: divider-bound 2.0, measured slightly above; Zen worse.
        let skl_o3 = get("Skylake", "-O3");
        assert!((skl_o3.osaca_cy_it - 2.0).abs() < 0.01, "{skl_o3:?}");
        assert!((skl_o3.measured_cy_it - 2.0).abs() < 0.15, "{skl_o3:?}");
        let zen_o3 = get("Zen", "-O3");
        assert!(zen_o3.measured_cy_it > 2.2 && zen_o3.measured_cy_it < 2.8, "{zen_o3:?}");
        // §III-B stall counters: -O1 stalls far more than -O2 on SKL.
        assert!(skl_o1.stall_fraction > 4.0 * skl_o2.stall_fraction.max(0.01), "{rows:?}");
    }
}
