//! Register names and classes — x86-64 and AArch64.
//!
//! Registers are the unit of dependency tracking in the simulator and of
//! operand-type classification in the analyzer. We canonicalize aliased
//! registers (`%eax`/`%rax` map to the `rax` slot; `w5`/`x5` to the `x5`
//! slot; `s0`/`d0`/`v0.2d`/`q0` to the `v0` slot) so that a narrow write
//! is seen by a wide read, matching renaming rules closely enough for
//! throughput analysis.

use std::fmt;

/// Architectural register class. Determines the operand-type letter used
/// in instruction-form signatures (`r32`, `r64`, `xmm`, `ymm`, ... on
/// x86; `w`, `x`, `s`, `d`, `q` on AArch64).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RegisterClass {
    /// 8-bit GP (al, r10b, ...)
    Gp8,
    /// 16-bit GP
    Gp16,
    /// 32-bit GP (eax, r10d, ...)
    Gp32,
    /// 64-bit GP (rax, r10, ...)
    Gp64,
    /// 128-bit SSE/AVX register
    Xmm,
    /// 256-bit AVX register
    Ymm,
    /// 512-bit AVX-512 register (parsed, unsupported by both models)
    Zmm,
    /// AVX-512 mask register
    Mask,
    /// Instruction pointer (rip-relative addressing)
    Rip,
    /// FLAGS register (x86) / NZCV (AArch64) — implicit operand of
    /// compares, flag-setting arithmetic and conditional branches.
    Flags,
    /// x86 segment register (`%fs`, `%gs`, ...), kept for display
    /// fidelity of segment overrides; never a dependency in our kernels.
    Seg,
    /// AArch64 32-bit GP view (w0..w30, wsp, wzr).
    AGp32,
    /// AArch64 64-bit GP (x0..x30, sp, lr, xzr).
    AGp64,
    /// AArch64 32-bit FP scalar view (s0..s31).
    AFp32,
    /// AArch64 64-bit FP scalar view (d0..d31).
    AFp64,
    /// AArch64 128-bit SIMD vector (v0..v31 with arrangement, q0..q31).
    AVec,
    /// RISC-V 64-bit integer register (x0..x31 / ABI names; x0 is the
    /// hard-wired zero whose writes are discarded).
    RGp64,
    /// RISC-V FP register (f0..f31 / ABI names; RV64GC `D` extension,
    /// so 64-bit wide).
    RFp64,
}

impl RegisterClass {
    /// Width in bits of a register of this class.
    pub fn bits(self) -> u32 {
        match self {
            RegisterClass::Gp8 => 8,
            RegisterClass::Gp16 => 16,
            RegisterClass::Gp32 => 32,
            RegisterClass::Gp64 => 64,
            RegisterClass::Xmm => 128,
            RegisterClass::Ymm => 256,
            RegisterClass::Zmm => 512,
            RegisterClass::Mask => 64,
            RegisterClass::Rip => 64,
            RegisterClass::Flags => 64,
            RegisterClass::Seg => 16,
            RegisterClass::AGp32 => 32,
            RegisterClass::AGp64 => 64,
            RegisterClass::AFp32 => 32,
            RegisterClass::AFp64 => 64,
            RegisterClass::AVec => 128,
            RegisterClass::RGp64 => 64,
            RegisterClass::RFp64 => 64,
        }
    }

    /// Signature letter used in instruction forms (paper §II: "instruction
    /// form" = mnemonic + operand types).
    pub fn sig(self) -> &'static str {
        match self {
            RegisterClass::Gp8 => "r8",
            RegisterClass::Gp16 => "r16",
            RegisterClass::Gp32 => "r32",
            RegisterClass::Gp64 => "r64",
            RegisterClass::Xmm => "xmm",
            RegisterClass::Ymm => "ymm",
            RegisterClass::Zmm => "zmm",
            RegisterClass::Mask => "k",
            RegisterClass::Rip => "rip",
            RegisterClass::Flags => "flags",
            RegisterClass::Seg => "seg",
            RegisterClass::AGp32 => "w",
            RegisterClass::AGp64 => "x",
            RegisterClass::AFp32 => "s",
            RegisterClass::AFp64 => "d",
            RegisterClass::AVec => "q",
            // RISC-V signature letters: kernels never mix ISAs and
            // `.mdb` resolution is ISA-gated, so reusing `x` for the
            // GP file (like AArch64) cannot collide across models.
            RegisterClass::RGp64 => "x",
            RegisterClass::RFp64 => "f",
        }
    }
}

/// A parsed architectural register: class + canonical slot index.
///
/// Slot indices: GP registers share slots 0..16 across widths (rax==eax),
/// vector registers share slots 0..32 across xmm/ymm/zmm. This gives the
/// simulator a single rename namespace per family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Register {
    pub class: RegisterClass,
    pub slot: u8,
    /// Original spelling without the `%` sigil, for diagnostics.
    pub name: &'static str,
}

/// Dependency-tracking family: registers that alias each other share one.
/// Kernels never mix ISAs, so the x86 and AArch64 GP/vector namespaces
/// can safely share the `Gp`/`Vec` variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RegisterFile {
    Gp(u8),
    Vec(u8),
    Mask(u8),
    Rip,
    Flags,
    Seg(u8),
}

impl Register {
    /// The rename-file slot this register occupies.
    pub fn file(&self) -> RegisterFile {
        match self.class {
            RegisterClass::Gp8
            | RegisterClass::Gp16
            | RegisterClass::Gp32
            | RegisterClass::Gp64
            | RegisterClass::AGp32
            | RegisterClass::AGp64
            | RegisterClass::RGp64 => RegisterFile::Gp(self.slot),
            RegisterClass::Xmm
            | RegisterClass::Ymm
            | RegisterClass::Zmm
            | RegisterClass::AFp32
            | RegisterClass::AFp64
            | RegisterClass::AVec
            | RegisterClass::RFp64 => RegisterFile::Vec(self.slot),
            RegisterClass::Mask => RegisterFile::Mask(self.slot),
            RegisterClass::Rip => RegisterFile::Rip,
            RegisterClass::Flags => RegisterFile::Flags,
            RegisterClass::Seg => RegisterFile::Seg(self.slot),
        }
    }
}

impl fmt::Display for Register {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "%{}", self.name)
    }
}

const GP64: [&str; 16] = [
    "rax", "rbx", "rcx", "rdx", "rsi", "rdi", "rbp", "rsp", "r8", "r9", "r10", "r11", "r12",
    "r13", "r14", "r15",
];
const GP32: [&str; 16] = [
    "eax", "ebx", "ecx", "edx", "esi", "edi", "ebp", "esp", "r8d", "r9d", "r10d", "r11d", "r12d",
    "r13d", "r14d", "r15d",
];
const GP16: [&str; 16] = [
    "ax", "bx", "cx", "dx", "si", "di", "bp", "sp", "r8w", "r9w", "r10w", "r11w", "r12w", "r13w",
    "r14w", "r15w",
];
const GP8: [&str; 20] = [
    "al", "bl", "cl", "dl", "sil", "dil", "bpl", "spl", "r8b", "r9b", "r10b", "r11b", "r12b",
    "r13b", "r14b", "r15b", "ah", "bh", "ch", "dh",
];
const SEG: [&str; 6] = ["es", "cs", "ss", "ds", "fs", "gs"];

/// Parse an x86 register name (without the `%` sigil). Returns `None`
/// for unknown names so the assembly parser can produce a real error.
pub fn parse_register(name: &str) -> Option<Register> {
    let lower = name.to_ascii_lowercase();
    let n = lower.as_str();
    if let Some(i) = GP64.iter().position(|&r| r == n) {
        return Some(Register { class: RegisterClass::Gp64, slot: i as u8, name: GP64[i] });
    }
    if let Some(i) = GP32.iter().position(|&r| r == n) {
        return Some(Register { class: RegisterClass::Gp32, slot: i as u8, name: GP32[i] });
    }
    if let Some(i) = GP16.iter().position(|&r| r == n) {
        return Some(Register { class: RegisterClass::Gp16, slot: i as u8, name: GP16[i] });
    }
    if let Some(i) = GP8.iter().position(|&r| r == n) {
        // ah/bh/ch/dh alias slots 0..4 like their low counterparts.
        let slot = if i >= 16 { (i - 16) as u8 } else { i as u8 };
        return Some(Register { class: RegisterClass::Gp8, slot, name: GP8[i] });
    }
    if n == "rip" {
        return Some(Register { class: RegisterClass::Rip, slot: 0, name: "rip" });
    }
    for (prefix, class) in [
        ("xmm", RegisterClass::Xmm),
        ("ymm", RegisterClass::Ymm),
        ("zmm", RegisterClass::Zmm),
    ] {
        if let Some(rest) = n.strip_prefix(prefix) {
            if let Ok(idx) = rest.parse::<u8>() {
                if idx < 32 {
                    // Leak-free static naming: reuse canonical tables.
                    return Some(Register { class, slot: idx, name: vec_name(class, idx) });
                }
            }
        }
    }
    if let Some(rest) = n.strip_prefix('k') {
        if let Ok(idx) = rest.parse::<u8>() {
            if idx < 8 {
                return Some(Register { class: RegisterClass::Mask, slot: idx, name: mask_name(idx) });
            }
        }
    }
    if let Some(i) = SEG.iter().position(|&r| r == n) {
        return Some(Register { class: RegisterClass::Seg, slot: i as u8, name: SEG[i] });
    }
    None
}

/// Parse an AArch64 register name. Aliasing follows the architecture:
/// `w5`/`x5` share GP slot 5, `s0`/`d0`/`q0`/`v0.<arr>` share vector
/// slot 0. `sp`/`wsp` live in GP slot 31, the zero registers
/// `xzr`/`wzr` in GP slot 32 (their writes are discarded by
/// `Instruction::writes`).
pub fn parse_aarch64_register(name: &str) -> Option<Register> {
    let lower = name.to_ascii_lowercase();
    let n = lower.as_str();
    match n {
        "sp" => {
            return Some(Register { class: RegisterClass::AGp64, slot: 31, name: "sp" });
        }
        "wsp" => {
            return Some(Register { class: RegisterClass::AGp32, slot: 31, name: "wsp" });
        }
        "xzr" => {
            return Some(Register { class: RegisterClass::AGp64, slot: 32, name: "xzr" });
        }
        "wzr" => {
            return Some(Register { class: RegisterClass::AGp32, slot: 32, name: "wzr" });
        }
        "lr" => {
            return Some(Register { class: RegisterClass::AGp64, slot: 30, name: "lr" });
        }
        "fp" => {
            return Some(Register { class: RegisterClass::AGp64, slot: 29, name: "fp" });
        }
        _ => {}
    }
    let numbered = |prefix: &str, class: RegisterClass, max: u8| -> Option<Register> {
        let rest = n.strip_prefix(prefix)?;
        let idx = rest.parse::<u8>().ok()?;
        if idx < max {
            Some(Register { class, slot: idx, name: static_name(prefix, idx) })
        } else {
            None
        }
    };
    // Vector registers may carry an arrangement: `v0.2d`, `v12.4s`, ...
    if let Some(rest) = n.strip_prefix('v') {
        if let Some((idx_s, arr)) = rest.split_once('.') {
            let idx = idx_s.parse::<u8>().ok()?;
            if idx < 32 {
                let name = a64_vec_name(idx, arr)?;
                return Some(Register { class: RegisterClass::AVec, slot: idx, name });
            }
            return None;
        }
        return numbered("v", RegisterClass::AVec, 32);
    }
    numbered("x", RegisterClass::AGp64, 31)
        .or_else(|| numbered("w", RegisterClass::AGp32, 31))
        .or_else(|| numbered("q", RegisterClass::AVec, 32))
        .or_else(|| numbered("d", RegisterClass::AFp64, 32))
        .or_else(|| numbered("s", RegisterClass::AFp32, 32))
}

/// RISC-V integer ABI names, index = architectural number (x0..x31).
const RV_GP_ABI: [&str; 32] = [
    "zero", "ra", "sp", "gp", "tp", "t0", "t1", "t2", "s0", "s1", "a0", "a1", "a2", "a3", "a4",
    "a5", "a6", "a7", "s2", "s3", "s4", "s5", "s6", "s7", "s8", "s9", "s10", "s11", "t3", "t4",
    "t5", "t6",
];

/// RISC-V FP ABI names, index = architectural number (f0..f31).
const RV_FP_ABI: [&str; 32] = [
    "ft0", "ft1", "ft2", "ft3", "ft4", "ft5", "ft6", "ft7", "fs0", "fs1", "fa0", "fa1", "fa2",
    "fa3", "fa4", "fa5", "fa6", "fa7", "fs2", "fs3", "fs4", "fs5", "fs6", "fs7", "fs8", "fs9",
    "fs10", "fs11", "ft8", "ft9", "ft10", "ft11",
];

/// Parse a RISC-V register name: ABI names (`a0`, `fa5`, `zero`, ...)
/// and raw architectural names (`x10`, `f15`). `x0`/`zero` writes are
/// discarded by `Instruction::writes`, mirroring AArch64's `xzr`. The
/// spelling is preserved in `name` so display round-trips.
pub fn parse_riscv_register(name: &str) -> Option<Register> {
    let lower = name.to_ascii_lowercase();
    let n = lower.as_str();
    if let Some(i) = RV_GP_ABI.iter().position(|&r| r == n) {
        return Some(Register { class: RegisterClass::RGp64, slot: i as u8, name: RV_GP_ABI[i] });
    }
    if let Some(i) = RV_FP_ABI.iter().position(|&r| r == n) {
        return Some(Register { class: RegisterClass::RFp64, slot: i as u8, name: RV_FP_ABI[i] });
    }
    // `fp` is the standard alias for s0/x8.
    if n == "fp" {
        return Some(Register { class: RegisterClass::RGp64, slot: 8, name: "fp" });
    }
    // Raw architectural spellings. `f` before `x` is irrelevant — the
    // prefixes are disjoint.
    if let Some(rest) = n.strip_prefix('x') {
        if let Ok(idx) = rest.parse::<u8>() {
            if idx < 32 && !rest.is_empty() {
                return Some(Register {
                    class: RegisterClass::RGp64,
                    slot: idx,
                    name: static_name("x", idx),
                });
            }
        }
        return None;
    }
    if let Some(rest) = n.strip_prefix('f') {
        if let Ok(idx) = rest.parse::<u8>() {
            if idx < 32 {
                return Some(Register {
                    class: RegisterClass::RFp64,
                    slot: idx,
                    name: static_name("f", idx),
                });
            }
        }
        return None;
    }
    None
}

fn vec_name(class: RegisterClass, idx: u8) -> &'static str {
    let prefix = match class {
        RegisterClass::Xmm => "xmm",
        RegisterClass::Ymm => "ymm",
        RegisterClass::Zmm => "zmm",
        _ => unreachable!(),
    };
    static_name(prefix, idx)
}

fn mask_name(idx: u8) -> &'static str {
    static_name("k", idx)
}

/// 32-entry static name table: `concat!(prefix, N, suffix)`.
macro_rules! name_table {
    ($p:literal, $s:literal, $idx:expr) => {{
        const T: [&str; 32] = [
            concat!($p, "0", $s), concat!($p, "1", $s), concat!($p, "2", $s),
            concat!($p, "3", $s), concat!($p, "4", $s), concat!($p, "5", $s),
            concat!($p, "6", $s), concat!($p, "7", $s), concat!($p, "8", $s),
            concat!($p, "9", $s), concat!($p, "10", $s), concat!($p, "11", $s),
            concat!($p, "12", $s), concat!($p, "13", $s), concat!($p, "14", $s),
            concat!($p, "15", $s), concat!($p, "16", $s), concat!($p, "17", $s),
            concat!($p, "18", $s), concat!($p, "19", $s), concat!($p, "20", $s),
            concat!($p, "21", $s), concat!($p, "22", $s), concat!($p, "23", $s),
            concat!($p, "24", $s), concat!($p, "25", $s), concat!($p, "26", $s),
            concat!($p, "27", $s), concat!($p, "28", $s), concat!($p, "29", $s),
            concat!($p, "30", $s), concat!($p, "31", $s),
        ];
        T[$idx as usize]
    }};
}

/// Canonical static names for numbered registers: xmm/ymm/zmm 0..32 and
/// k0..8 (x86), x/w/v/q/d/s (AArch64) — no leaking.
pub(crate) fn static_name(prefix: &str, idx: u8) -> &'static str {
    match prefix {
        "xmm" => name_table!("xmm", "", idx),
        "ymm" => name_table!("ymm", "", idx),
        "zmm" => name_table!("zmm", "", idx),
        "k" => name_table!("k", "", idx),
        "x" => name_table!("x", "", idx),
        "w" => name_table!("w", "", idx),
        "v" => name_table!("v", "", idx),
        "q" => name_table!("q", "", idx),
        "d" => name_table!("d", "", idx),
        "s" => name_table!("s", "", idx),
        "f" => name_table!("f", "", idx),
        _ => unreachable!("static_name prefix {prefix}"),
    }
}

/// Static names for AArch64 vector registers with an arrangement
/// specifier (`v0.2d`, ...). `None` for unsupported arrangements.
fn a64_vec_name(idx: u8, arr: &str) -> Option<&'static str> {
    Some(match arr {
        "2d" => name_table!("v", ".2d", idx),
        "1d" => name_table!("v", ".1d", idx),
        "4s" => name_table!("v", ".4s", idx),
        "2s" => name_table!("v", ".2s", idx),
        "8h" => name_table!("v", ".8h", idx),
        "4h" => name_table!("v", ".4h", idx),
        "16b" => name_table!("v", ".16b", idx),
        "8b" => name_table!("v", ".8b", idx),
        _ => return None,
    })
}

/// The FLAGS pseudo-register (implicit dep of compares and branches).
pub fn flags() -> Register {
    Register { class: RegisterClass::Flags, slot: 0, name: "flags" }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gp_aliasing_shares_slots() {
        let rax = parse_register("rax").unwrap();
        let eax = parse_register("eax").unwrap();
        assert_eq!(rax.file(), eax.file());
        assert_ne!(rax.class, eax.class);
    }

    #[test]
    fn vector_widths_share_slots() {
        let x = parse_register("xmm5").unwrap();
        let y = parse_register("ymm5").unwrap();
        assert_eq!(x.file(), y.file());
        assert_eq!(x.class.bits(), 128);
        assert_eq!(y.class.bits(), 256);
    }

    #[test]
    fn unknown_register_is_none() {
        assert!(parse_register("xmm99").is_none());
        assert!(parse_register("foo").is_none());
    }

    #[test]
    fn high_byte_regs_alias_low() {
        let ah = parse_register("ah").unwrap();
        let al = parse_register("al").unwrap();
        assert_eq!(ah.file(), al.file());
    }

    #[test]
    fn all_gp64_roundtrip() {
        for n in GP64 {
            let r = parse_register(n).unwrap();
            assert_eq!(r.class, RegisterClass::Gp64);
            assert_eq!(r.name, n);
        }
    }

    #[test]
    fn segment_registers_parse() {
        let fs = parse_register("fs").unwrap();
        assert_eq!(fs.class, RegisterClass::Seg);
        assert_eq!(fs.name, "fs");
    }

    #[test]
    fn aarch64_gp_aliasing() {
        let x5 = parse_aarch64_register("x5").unwrap();
        let w5 = parse_aarch64_register("w5").unwrap();
        assert_eq!(x5.file(), w5.file());
        assert_eq!(x5.class, RegisterClass::AGp64);
        assert_eq!(w5.class, RegisterClass::AGp32);
        assert_eq!(x5.name, "x5");
    }

    #[test]
    fn aarch64_vector_views_alias() {
        let v = parse_aarch64_register("v3.2d").unwrap();
        let q = parse_aarch64_register("q3").unwrap();
        let d = parse_aarch64_register("d3").unwrap();
        let s = parse_aarch64_register("s3").unwrap();
        assert_eq!(v.file(), q.file());
        assert_eq!(q.file(), d.file());
        assert_eq!(d.file(), s.file());
        assert_eq!(v.name, "v3.2d");
        assert_eq!(v.class.sig(), "q");
        assert_eq!(d.class.sig(), "d");
    }

    #[test]
    fn riscv_abi_and_raw_names_alias() {
        let a0 = parse_riscv_register("a0").unwrap();
        let x10 = parse_riscv_register("x10").unwrap();
        assert_eq!(a0.file(), x10.file());
        assert_eq!(a0.class, RegisterClass::RGp64);
        assert_eq!(a0.name, "a0");
        assert_eq!(x10.name, "x10");
        let fa5 = parse_riscv_register("fa5").unwrap();
        let f15 = parse_riscv_register("f15").unwrap();
        assert_eq!(fa5.file(), f15.file());
        assert_eq!(fa5.class, RegisterClass::RFp64);
        assert_eq!(fa5.class.sig(), "f");
        assert_eq!(a0.class.sig(), "x");
    }

    #[test]
    fn riscv_specials() {
        let zero = parse_riscv_register("zero").unwrap();
        assert_eq!(zero.slot, 0);
        assert_eq!(zero.file(), parse_riscv_register("x0").unwrap().file());
        assert_eq!(parse_riscv_register("fp").unwrap().slot, 8);
        assert_eq!(
            parse_riscv_register("fp").unwrap().file(),
            parse_riscv_register("s0").unwrap().file()
        );
        assert_eq!(parse_riscv_register("sp").unwrap().slot, 2);
        assert!(parse_riscv_register("x32").is_none());
        assert!(parse_riscv_register("f32").is_none());
        assert!(parse_riscv_register("rax").is_none());
        assert!(parse_riscv_register("x2_loop").is_none());
    }

    #[test]
    fn aarch64_specials() {
        assert_eq!(parse_aarch64_register("sp").unwrap().slot, 31);
        assert_eq!(parse_aarch64_register("xzr").unwrap().slot, 32);
        assert_eq!(
            parse_aarch64_register("wzr").unwrap().file(),
            parse_aarch64_register("xzr").unwrap().file()
        );
        assert!(parse_aarch64_register("x31").is_none());
        assert!(parse_aarch64_register("v32.2d").is_none());
        assert!(parse_aarch64_register("v0.3d").is_none());
        assert!(parse_aarch64_register("rax").is_none());
    }
}
