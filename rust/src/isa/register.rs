//! x86-64 register names and classes.
//!
//! Registers are the unit of dependency tracking in the simulator and of
//! operand-type classification in the analyzer. We canonicalize aliased
//! GP registers (`%eax` and `%rax` both map to the `rax` slot) so that a
//! 32-bit write is seen by a 64-bit read, matching x86 renaming rules
//! closely enough for throughput analysis.

use std::fmt;

/// Architectural register class. Determines the operand-type letter used
/// in instruction-form signatures (`r32`, `r64`, `xmm`, `ymm`, ...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RegisterClass {
    /// 8-bit GP (al, r10b, ...)
    Gp8,
    /// 16-bit GP
    Gp16,
    /// 32-bit GP (eax, r10d, ...)
    Gp32,
    /// 64-bit GP (rax, r10, ...)
    Gp64,
    /// 128-bit SSE/AVX register
    Xmm,
    /// 256-bit AVX register
    Ymm,
    /// 512-bit AVX-512 register (parsed, unsupported by both models)
    Zmm,
    /// AVX-512 mask register
    Mask,
    /// Instruction pointer (rip-relative addressing)
    Rip,
    /// FLAGS register (implicit operand of cmp/test/jcc and arithmetic)
    Flags,
}

impl RegisterClass {
    /// Width in bits of a register of this class.
    pub fn bits(self) -> u32 {
        match self {
            RegisterClass::Gp8 => 8,
            RegisterClass::Gp16 => 16,
            RegisterClass::Gp32 => 32,
            RegisterClass::Gp64 => 64,
            RegisterClass::Xmm => 128,
            RegisterClass::Ymm => 256,
            RegisterClass::Zmm => 512,
            RegisterClass::Mask => 64,
            RegisterClass::Rip => 64,
            RegisterClass::Flags => 64,
        }
    }

    /// Signature letter used in instruction forms (paper §II: "instruction
    /// form" = mnemonic + operand types).
    pub fn sig(self) -> &'static str {
        match self {
            RegisterClass::Gp8 => "r8",
            RegisterClass::Gp16 => "r16",
            RegisterClass::Gp32 => "r32",
            RegisterClass::Gp64 => "r64",
            RegisterClass::Xmm => "xmm",
            RegisterClass::Ymm => "ymm",
            RegisterClass::Zmm => "zmm",
            RegisterClass::Mask => "k",
            RegisterClass::Rip => "rip",
            RegisterClass::Flags => "flags",
        }
    }
}

/// A parsed architectural register: class + canonical slot index.
///
/// Slot indices: GP registers share slots 0..16 across widths (rax==eax),
/// vector registers share slots 0..32 across xmm/ymm/zmm. This gives the
/// simulator a single rename namespace per family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Register {
    pub class: RegisterClass,
    pub slot: u8,
    /// Original spelling without the `%` sigil, for diagnostics.
    pub name: &'static str,
}

/// Dependency-tracking family: registers that alias each other share one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RegisterFile {
    Gp(u8),
    Vec(u8),
    Mask(u8),
    Rip,
    Flags,
}

impl Register {
    /// The rename-file slot this register occupies.
    pub fn file(&self) -> RegisterFile {
        match self.class {
            RegisterClass::Gp8 | RegisterClass::Gp16 | RegisterClass::Gp32 | RegisterClass::Gp64 => {
                RegisterFile::Gp(self.slot)
            }
            RegisterClass::Xmm | RegisterClass::Ymm | RegisterClass::Zmm => RegisterFile::Vec(self.slot),
            RegisterClass::Mask => RegisterFile::Mask(self.slot),
            RegisterClass::Rip => RegisterFile::Rip,
            RegisterClass::Flags => RegisterFile::Flags,
        }
    }
}

impl fmt::Display for Register {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "%{}", self.name)
    }
}

const GP64: [&str; 16] = [
    "rax", "rbx", "rcx", "rdx", "rsi", "rdi", "rbp", "rsp", "r8", "r9", "r10", "r11", "r12",
    "r13", "r14", "r15",
];
const GP32: [&str; 16] = [
    "eax", "ebx", "ecx", "edx", "esi", "edi", "ebp", "esp", "r8d", "r9d", "r10d", "r11d", "r12d",
    "r13d", "r14d", "r15d",
];
const GP16: [&str; 16] = [
    "ax", "bx", "cx", "dx", "si", "di", "bp", "sp", "r8w", "r9w", "r10w", "r11w", "r12w", "r13w",
    "r14w", "r15w",
];
const GP8: [&str; 20] = [
    "al", "bl", "cl", "dl", "sil", "dil", "bpl", "spl", "r8b", "r9b", "r10b", "r11b", "r12b",
    "r13b", "r14b", "r15b", "ah", "bh", "ch", "dh",
];

/// Parse a register name (without the `%` sigil). Returns `None` for
/// unknown names so the assembly parser can produce a real error.
pub fn parse_register(name: &str) -> Option<Register> {
    let lower = name.to_ascii_lowercase();
    let n = lower.as_str();
    if let Some(i) = GP64.iter().position(|&r| r == n) {
        return Some(Register { class: RegisterClass::Gp64, slot: i as u8, name: GP64[i] });
    }
    if let Some(i) = GP32.iter().position(|&r| r == n) {
        return Some(Register { class: RegisterClass::Gp32, slot: i as u8, name: GP32[i] });
    }
    if let Some(i) = GP16.iter().position(|&r| r == n) {
        return Some(Register { class: RegisterClass::Gp16, slot: i as u8, name: GP16[i] });
    }
    if let Some(i) = GP8.iter().position(|&r| r == n) {
        // ah/bh/ch/dh alias slots 0..4 like their low counterparts.
        let slot = if i >= 16 { (i - 16) as u8 } else { i as u8 };
        return Some(Register { class: RegisterClass::Gp8, slot, name: GP8[i] });
    }
    if n == "rip" {
        return Some(Register { class: RegisterClass::Rip, slot: 0, name: "rip" });
    }
    for (prefix, class) in [
        ("xmm", RegisterClass::Xmm),
        ("ymm", RegisterClass::Ymm),
        ("zmm", RegisterClass::Zmm),
    ] {
        if let Some(rest) = n.strip_prefix(prefix) {
            if let Ok(idx) = rest.parse::<u8>() {
                if idx < 32 {
                    // Leak-free static naming: reuse canonical tables.
                    return Some(Register { class, slot: idx, name: vec_name(class, idx) });
                }
            }
        }
    }
    if let Some(rest) = n.strip_prefix('k') {
        if let Ok(idx) = rest.parse::<u8>() {
            if idx < 8 {
                return Some(Register { class: RegisterClass::Mask, slot: idx, name: mask_name(idx) });
            }
        }
    }
    None
}

fn vec_name(class: RegisterClass, idx: u8) -> &'static str {
    let prefix = match class {
        RegisterClass::Xmm => "xmm",
        RegisterClass::Ymm => "ymm",
        RegisterClass::Zmm => "zmm",
        _ => unreachable!(),
    };
    static_name(prefix, idx)
}

fn mask_name(idx: u8) -> &'static str {
    static_name("k", idx)
}

/// Canonical static names for numbered registers. Covers xmm/ymm/zmm 0..32
/// and k0..8 without leaking.
pub(crate) fn static_name(prefix: &str, idx: u8) -> &'static str {
    macro_rules! table {
        ($p:literal) => {{
            const T: [&str; 32] = [
                concat!($p, "0"), concat!($p, "1"), concat!($p, "2"), concat!($p, "3"),
                concat!($p, "4"), concat!($p, "5"), concat!($p, "6"), concat!($p, "7"),
                concat!($p, "8"), concat!($p, "9"), concat!($p, "10"), concat!($p, "11"),
                concat!($p, "12"), concat!($p, "13"), concat!($p, "14"), concat!($p, "15"),
                concat!($p, "16"), concat!($p, "17"), concat!($p, "18"), concat!($p, "19"),
                concat!($p, "20"), concat!($p, "21"), concat!($p, "22"), concat!($p, "23"),
                concat!($p, "24"), concat!($p, "25"), concat!($p, "26"), concat!($p, "27"),
                concat!($p, "28"), concat!($p, "29"), concat!($p, "30"), concat!($p, "31"),
            ];
            T[idx as usize]
        }};
    }
    match prefix {
        "xmm" => table!("xmm"),
        "ymm" => table!("ymm"),
        "zmm" => table!("zmm"),
        "k" => table!("k"),
        _ => unreachable!("static_name prefix {prefix}"),
    }
}

/// The FLAGS pseudo-register (implicit dep of compares and branches).
pub fn flags() -> Register {
    Register { class: RegisterClass::Flags, slot: 0, name: "flags" }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gp_aliasing_shares_slots() {
        let rax = parse_register("rax").unwrap();
        let eax = parse_register("eax").unwrap();
        assert_eq!(rax.file(), eax.file());
        assert_ne!(rax.class, eax.class);
    }

    #[test]
    fn vector_widths_share_slots() {
        let x = parse_register("xmm5").unwrap();
        let y = parse_register("ymm5").unwrap();
        assert_eq!(x.file(), y.file());
        assert_eq!(x.class.bits(), 128);
        assert_eq!(y.class.bits(), 256);
    }

    #[test]
    fn unknown_register_is_none() {
        assert!(parse_register("xmm99").is_none());
        assert!(parse_register("foo").is_none());
    }

    #[test]
    fn high_byte_regs_alias_low() {
        let ah = parse_register("ah").unwrap();
        let al = parse_register("al").unwrap();
        assert_eq!(ah.file(), al.file());
    }

    #[test]
    fn all_gp64_roundtrip() {
        for n in GP64 {
            let r = parse_register(n).unwrap();
            assert_eq!(r.class, RegisterClass::Gp64);
            assert_eq!(r.name, n);
        }
    }
}
