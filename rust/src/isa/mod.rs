//! Instruction-set abstractions: registers, operands, instructions and
//! *instruction forms* (mnemonic + operand-type signature, the unit of the
//! machine-model database — see paper §II).

pub mod instruction;
pub mod operand;
pub mod register;

pub use instruction::{Instruction, InstructionForm, OperandSig};
pub use operand::{MemRef, Operand};
pub use register::{Register, RegisterClass, RegisterFile};
