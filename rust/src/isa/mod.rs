//! Instruction-set abstractions: registers, operands, instructions and
//! *instruction forms* (mnemonic + operand-type signature, the unit of the
//! machine-model database — see paper §II).
//!
//! Everything in this module is ISA-tagged: an [`Instruction`] carries the
//! [`Isa`] it was parsed as, and the classification methods (operand
//! order, branch/compare detection, flag semantics) dispatch on it. The
//! parsing side of an ISA lives in `asm::syntax` ([`crate::asm`]).

use std::fmt;

pub mod instruction;
pub mod operand;
pub mod register;

pub use instruction::{Instruction, InstructionForm, OperandSig};
pub use operand::{MemRef, Operand};
pub use register::{Register, RegisterClass, RegisterFile};

/// The instruction-set architecture of a parsed instruction, kernel or
/// machine model. `X86` means AT&T-syntax x86-64 (the paper's target);
/// `AArch64` is the ARMv8 A64 syntax (the OSACA follow-up paper's second
/// backend, used by the `tx2` ThunderX2 model); `RiscV` is RV64GC
/// GNU-as syntax (the `rv64` model — the third proof of the DESIGN.md
/// §7 backend recipe).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Isa {
    /// AT&T-syntax x86-64 (`%rax`, `$imm`, `disp(base,index,scale)`,
    /// destination-last).
    #[default]
    X86,
    /// ARMv8 AArch64 (`x0`, `#imm`, `[base, index, lsl #s]`,
    /// destination-first).
    AArch64,
    /// RISC-V RV64GC (`a0`/`fa0`, bare immediates, `offset(base)`
    /// memory operands, destination-first, no flags register —
    /// branches are compare-and-branch).
    RiscV,
}

impl Isa {
    /// Canonical lower-case name (the `.mdb` `isa` directive spelling).
    pub fn name(self) -> &'static str {
        match self {
            Isa::X86 => "x86",
            Isa::AArch64 => "aarch64",
            Isa::RiscV => "riscv",
        }
    }

    /// Parse an ISA name (accepts the common aliases).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.to_ascii_lowercase().as_str() {
            "x86" | "x86_64" | "x86-64" | "att" => Some(Isa::X86),
            "aarch64" | "arm64" | "armv8" => Some(Isa::AArch64),
            "riscv" | "riscv64" | "rv64" | "rv64gc" => Some(Isa::RiscV),
            _ => None,
        }
    }

    /// Is `m` a branch mnemonic under this ISA? Single source of truth
    /// for [`Instruction::is_branch`] and the `.mdb` parser's
    /// "only branches may have zero µ-ops" rule.
    pub fn is_branch_mnemonic(self, m: &str) -> bool {
        match self {
            Isa::X86 => m.starts_with('j') || m == "loop",
            Isa::AArch64 => {
                m == "b" || m.starts_with("b.") || matches!(m, "cbz" | "cbnz" | "tbz" | "tbnz")
            }
            // RISC-V has no condition flags: every conditional branch
            // compares its own register operands (plus the `j` jump and
            // the `beqz`-style single-register pseudo-ops GCC emits).
            Isa::RiscV => matches!(
                m,
                "j" | "beq"
                    | "bne"
                    | "blt"
                    | "bge"
                    | "bltu"
                    | "bgeu"
                    | "bgt"
                    | "ble"
                    | "beqz"
                    | "bnez"
                    | "blez"
                    | "bgez"
                    | "bltz"
                    | "bgtz"
            ),
        }
    }
}

impl fmt::Display for Isa {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_names_roundtrip() {
        for isa in [Isa::X86, Isa::AArch64, Isa::RiscV] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("arm64"), Some(Isa::AArch64));
        assert_eq!(Isa::parse("rv64"), Some(Isa::RiscV));
        assert_eq!(Isa::parse("rv64gc"), Some(Isa::RiscV));
        assert_eq!(Isa::parse("sparc"), None);
        assert_eq!(Isa::default(), Isa::X86);
    }

    #[test]
    fn branch_mnemonics_per_isa() {
        assert!(Isa::X86.is_branch_mnemonic("jne"));
        assert!(Isa::X86.is_branch_mnemonic("jmp"));
        assert!(!Isa::X86.is_branch_mnemonic("b.ne"));
        assert!(Isa::AArch64.is_branch_mnemonic("b"));
        assert!(Isa::AArch64.is_branch_mnemonic("b.ne"));
        assert!(Isa::AArch64.is_branch_mnemonic("cbnz"));
        assert!(!Isa::AArch64.is_branch_mnemonic("bl"));
        assert!(!Isa::AArch64.is_branch_mnemonic("jne"));
        assert!(Isa::RiscV.is_branch_mnemonic("bne"));
        assert!(Isa::RiscV.is_branch_mnemonic("bgeu"));
        assert!(Isa::RiscV.is_branch_mnemonic("bnez"));
        assert!(Isa::RiscV.is_branch_mnemonic("j"));
        // jal/jalr write a link register and are out of the modeled
        // loop-kernel subset — not classified as plain branches.
        assert!(!Isa::RiscV.is_branch_mnemonic("jal"));
        assert!(!Isa::RiscV.is_branch_mnemonic("jne"));
    }
}
