//! Instruction-set abstractions: registers, operands, instructions and
//! *instruction forms* (mnemonic + operand-type signature, the unit of the
//! machine-model database — see paper §II).
//!
//! Everything in this module is ISA-tagged: an [`Instruction`] carries the
//! [`Isa`] it was parsed as, and the classification methods (operand
//! order, branch/compare detection, flag semantics) dispatch on it. The
//! parsing side of an ISA lives in `asm::syntax` ([`crate::asm`]).

use std::fmt;

pub mod instruction;
pub mod operand;
pub mod register;

pub use instruction::{Instruction, InstructionForm, OperandSig};
pub use operand::{MemRef, Operand};
pub use register::{Register, RegisterClass, RegisterFile};

/// The instruction-set architecture of a parsed instruction, kernel or
/// machine model. `X86` means AT&T-syntax x86-64 (the paper's target);
/// `AArch64` is the ARMv8 A64 syntax (the OSACA follow-up paper's second
/// backend, used by the `tx2` ThunderX2 model).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Isa {
    /// AT&T-syntax x86-64 (`%rax`, `$imm`, `disp(base,index,scale)`,
    /// destination-last).
    #[default]
    X86,
    /// ARMv8 AArch64 (`x0`, `#imm`, `[base, index, lsl #s]`,
    /// destination-first).
    AArch64,
}

impl Isa {
    /// Canonical lower-case name (the `.mdb` `isa` directive spelling).
    pub fn name(self) -> &'static str {
        match self {
            Isa::X86 => "x86",
            Isa::AArch64 => "aarch64",
        }
    }

    /// Parse an ISA name (accepts the common aliases).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.to_ascii_lowercase().as_str() {
            "x86" | "x86_64" | "x86-64" | "att" => Some(Isa::X86),
            "aarch64" | "arm64" | "armv8" => Some(Isa::AArch64),
            _ => None,
        }
    }

    /// Is `m` a branch mnemonic under this ISA? Single source of truth
    /// for [`Instruction::is_branch`] and the `.mdb` parser's
    /// "only branches may have zero µ-ops" rule.
    pub fn is_branch_mnemonic(self, m: &str) -> bool {
        match self {
            Isa::X86 => m.starts_with('j') || m == "loop",
            Isa::AArch64 => {
                m == "b" || m.starts_with("b.") || matches!(m, "cbz" | "cbnz" | "tbz" | "tbnz")
            }
        }
    }
}

impl fmt::Display for Isa {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_names_roundtrip() {
        for isa in [Isa::X86, Isa::AArch64] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("arm64"), Some(Isa::AArch64));
        assert_eq!(Isa::parse("riscv"), None);
        assert_eq!(Isa::default(), Isa::X86);
    }

    #[test]
    fn branch_mnemonics_per_isa() {
        assert!(Isa::X86.is_branch_mnemonic("jne"));
        assert!(Isa::X86.is_branch_mnemonic("jmp"));
        assert!(!Isa::X86.is_branch_mnemonic("b.ne"));
        assert!(Isa::AArch64.is_branch_mnemonic("b"));
        assert!(Isa::AArch64.is_branch_mnemonic("b.ne"));
        assert!(Isa::AArch64.is_branch_mnemonic("cbnz"));
        assert!(!Isa::AArch64.is_branch_mnemonic("bl"));
        assert!(!Isa::AArch64.is_branch_mnemonic("jne"));
    }
}
