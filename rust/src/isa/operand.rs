//! Operands of AT&T-syntax x86 instructions.

use std::fmt;

use super::register::Register;

/// A memory reference `disp(base, index, scale)` (AT&T syntax), with all
/// components optional. OSACA distinguishes addressing components (paper
//  §II) even though the current throughput model treats all addressing
/// modes as equal; the simulator uses them for dependency tracking and
/// the analyzer uses "simple address" detection for the SKL port-7 AGU.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct MemRef {
    pub displacement: i64,
    pub base: Option<Register>,
    pub index: Option<Register>,
    pub scale: u8,
    /// Segment override (`%fs:...`), parsed but unused by the models.
    pub segment: Option<Register>,
    /// rip-relative (`sym(%rip)`) references keep the symbol for display.
    pub symbol: Option<String>,
}

impl MemRef {
    /// "Simple" addresses (base + displacement, no index) may use the
    /// dedicated store-AGU on port 7 of Skylake (paper §I-B).
    pub fn is_simple(&self) -> bool {
        self.index.is_none()
    }

    /// Registers read to form the effective address.
    pub fn address_registers(&self) -> impl Iterator<Item = Register> + '_ {
        self.base.into_iter().chain(self.index)
    }
}

impl fmt::Display for MemRef {
    /// AT&T-syntax rendering (`disp(base,index,scale)`); AArch64
    /// instructions render through [`fmt_operand_aarch64`] instead.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if let Some(seg) = self.segment {
            write!(f, "{seg}:")?;
        }
        if let Some(sym) = &self.symbol {
            write!(f, "{sym}")?;
        } else if self.displacement != 0 {
            write!(f, "{}", self.displacement)?;
        }
        if self.base.is_some() || self.index.is_some() {
            write!(f, "(")?;
            if let Some(b) = self.base {
                write!(f, "{b}")?;
            }
            if let Some(i) = self.index {
                write!(f, ",{i}")?;
                if self.scale != 1 {
                    write!(f, ",{}", self.scale)?;
                }
            }
            write!(f, ")")?;
        }
        Ok(())
    }
}

/// A single instruction operand.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Operand {
    Reg(Register),
    Imm(i64),
    Mem(MemRef),
    /// Branch target label.
    Label(String),
}

impl Operand {
    pub fn is_mem(&self) -> bool {
        matches!(self, Operand::Mem(_))
    }

    pub fn reg(&self) -> Option<Register> {
        match self {
            Operand::Reg(r) => Some(*r),
            _ => None,
        }
    }

    pub fn mem(&self) -> Option<&MemRef> {
        match self {
            Operand::Mem(m) => Some(m),
            _ => None,
        }
    }

    /// Signature component for instruction-form matching (paper: operand
    /// *types*, not concrete registers: `mem`, `imm`, `r64`, `xmm`, ...).
    pub fn sig(&self) -> String {
        match self {
            Operand::Reg(r) => r.class.sig().to_string(),
            Operand::Imm(_) => "imm".to_string(),
            Operand::Mem(_) => "mem".to_string(),
            Operand::Label(_) => "lbl".to_string(),
        }
    }
}

impl fmt::Display for Operand {
    /// AT&T-syntax rendering; AArch64 instructions render through
    /// [`fmt_operand_aarch64`] instead.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Operand::Reg(r) => write!(f, "{r}"),
            Operand::Imm(v) => write!(f, "${v}"),
            Operand::Mem(m) => write!(f, "{m}"),
            Operand::Label(l) => write!(f, "{l}"),
        }
    }
}

impl MemRef {
    /// AArch64 rendering: `[base]`, `[base, #disp]`,
    /// `[base, index{, lsl #shift}]`.
    pub(crate) fn fmt_aarch64(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        if let Some(b) = self.base {
            write!(f, "{}", b.name)?;
        }
        if let Some(i) = self.index {
            write!(f, ", {}", i.name)?;
            if self.scale != 1 {
                write!(f, ", lsl #{}", self.scale.trailing_zeros())?;
            }
        } else if self.displacement != 0 {
            write!(f, ", #{}", self.displacement)?;
        }
        write!(f, "]")
    }
}

/// AArch64 operand rendering (no `%`/`$` sigils; `#` immediates;
/// bracketed memory references).
pub(crate) fn fmt_operand_aarch64(op: &Operand, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    match op {
        Operand::Reg(r) => write!(f, "{}", r.name),
        Operand::Imm(v) => write!(f, "#{v}"),
        Operand::Mem(m) => m.fmt_aarch64(f),
        Operand::Label(l) => write!(f, "{l}"),
    }
}

impl MemRef {
    /// RISC-V rendering: `disp(base)`. The displacement is always
    /// printed (GCC emits `0(a5)`), making the rendering a canonical
    /// fixpoint for the round-trip tests.
    pub(crate) fn fmt_riscv(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}(", self.displacement)?;
        if let Some(b) = self.base {
            write!(f, "{}", b.name)?;
        }
        write!(f, ")")
    }
}

/// RISC-V operand rendering (no sigils at all: bare register names,
/// bare immediates, `offset(base)` memory references).
pub(crate) fn fmt_operand_riscv(op: &Operand, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    match op {
        Operand::Reg(r) => write!(f, "{}", r.name),
        Operand::Imm(v) => write!(f, "{v}"),
        Operand::Mem(m) => m.fmt_riscv(f),
        Operand::Label(l) => write!(f, "{l}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::register::parse_register;

    fn mem(base: &str, index: Option<&str>, scale: u8, disp: i64) -> MemRef {
        MemRef {
            displacement: disp,
            base: Some(parse_register(base).unwrap()),
            index: index.map(|i| parse_register(i).unwrap()),
            scale,
            segment: None,
            symbol: None,
        }
    }

    #[test]
    fn simple_address_detection() {
        assert!(mem("rsp", None, 1, 8).is_simple());
        assert!(!mem("r13", Some("rax"), 1, 0).is_simple());
    }

    #[test]
    fn display_roundtrip_shape() {
        let m = mem("r13", Some("rax"), 8, 16);
        assert_eq!(m.to_string(), "16(%r13,%rax,8)");
        let m2 = mem("rsp", None, 1, 0);
        assert_eq!(m2.to_string(), "(%rsp)");
    }

    #[test]
    fn operand_sigs() {
        assert_eq!(Operand::Imm(3).sig(), "imm");
        assert_eq!(Operand::Reg(parse_register("ymm2").unwrap()).sig(), "ymm");
        assert_eq!(Operand::Mem(mem("rax", None, 1, 0)).sig(), "mem");
    }
}
