//! Instructions and instruction forms.
//!
//! The *instruction form* (paper §II, citing [20]) is the unit the machine
//! model is keyed on: mnemonic plus operand-type signature, e.g.
//! `vfmadd132pd mem_xmm_xmm`. Concrete registers and displacements are
//! irrelevant to throughput; they matter only for dependency analysis.

use std::fmt;

use super::operand::{fmt_operand_aarch64, fmt_operand_riscv, Operand};
use super::register::{flags, Register};
use super::Isa;

/// RISC-V store mnemonics (RV64GC loop-kernel subset). Stores are the
/// only dest-first-ISA instructions whose destination is the memory
/// operand rather than operand 0. Shared with
/// `asm::syntax::RiscVSyntax::bench_dest_index` so the parser's and the
/// benchmark generator's notion of "store" can never drift apart.
pub(crate) fn riscv_is_store_mnemonic(m: &str) -> bool {
    matches!(m, "sb" | "sh" | "sw" | "sd" | "fsw" | "fsd")
}

/// RISC-V load mnemonics. Spelled out (rather than `starts_with('l')`)
/// so pseudo-ops like `li`/`la` can never classify as loads.
fn riscv_is_load_mnemonic(m: &str) -> bool {
    matches!(m, "lb" | "lh" | "lw" | "ld" | "lbu" | "lhu" | "lwu" | "flw" | "fld")
}

/// One parsed assembly instruction. Operand order follows the source
/// syntax: destination **last** for AT&T x86, destination **first** for
/// AArch64 — the accessors below (`dest`, `reads`, `writes`, ...)
/// dispatch on [`Instruction::isa`] so every consumer stays ISA-neutral.
///
/// The raw source text is **not** stored: kernels clone instructions
/// freely (extraction, requests, decode templates), and a per-
/// instruction `String` of the source line doubled every clone's
/// allocation count for a field only diagnostics want. `line` indexes
/// into the kernel source for that, and `Display` reconstructs a
/// canonical spelling.
#[derive(Debug, Clone, PartialEq)]
pub struct Instruction {
    pub mnemonic: String,
    pub operands: Vec<Operand>,
    /// Source line number (1-based) for diagnostics and report tables.
    pub line: usize,
    /// Syntax/semantics the instruction was parsed under.
    pub isa: Isa,
    /// Unmodeled instruction prefixes (x86 `lock`, `rep`, ...), kept so
    /// `Display` can reconstruct the source line faithfully.
    pub prefix: Option<String>,
}

/// Canonical operand-type signature, e.g. `mem_xmm_xmm`.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OperandSig(pub String);

impl fmt::Display for OperandSig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Instruction form = mnemonic + operand signature. Database key.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InstructionForm {
    pub mnemonic: String,
    pub sig: OperandSig,
}

impl fmt::Display for InstructionForm {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.sig.0.is_empty() {
            write!(f, "{}", self.mnemonic)
        } else {
            write!(f, "{}-{}", self.mnemonic, self.sig)
        }
    }
}

impl InstructionForm {
    pub fn new(mnemonic: &str, sig: &str) -> Self {
        InstructionForm { mnemonic: mnemonic.to_string(), sig: OperandSig(sig.to_string()) }
    }

    /// Parse `mnemonic-sig` (the database spelling, e.g.
    /// `vfmadd132pd-mem_xmm_xmm`).
    pub fn parse(s: &str) -> Self {
        match s.split_once('-') {
            Some((m, sig)) => InstructionForm::new(m, sig),
            None => InstructionForm::new(s, ""),
        }
    }
}

impl Instruction {
    /// The instruction form of this instruction.
    pub fn form(&self) -> InstructionForm {
        let sig = self
            .operands
            .iter()
            .map(|o| o.sig())
            .collect::<Vec<_>>()
            .join("_");
        InstructionForm { mnemonic: self.mnemonic.clone(), sig: OperandSig(sig) }
    }

    /// Does any operand reference memory?
    pub fn has_mem_operand(&self) -> bool {
        self.operands.iter().any(|o| o.is_mem())
    }

    /// Memory operand, if present (x86 allows at most one real one in the
    /// instruction subset we model; `movs`-style string ops are out of
    /// scope).
    pub fn mem_operand(&self) -> Option<&super::operand::MemRef> {
        self.operands.iter().find_map(|o| o.mem())
    }

    /// The destination operand. AT&T x86: the **last** operand (compares,
    /// tests and branches have none). AArch64 and RISC-V: the **first**
    /// operand, except stores (`st*` / `sd`-family), whose destination
    /// is the memory operand.
    pub fn dest(&self) -> Option<&Operand> {
        if self.is_branch() || self.is_compare() || self.mnemonic == "nop" {
            return None;
        }
        match self.isa {
            Isa::X86 => self.operands.last(),
            Isa::AArch64 => {
                if self.mnemonic.starts_with("st") {
                    self.operands.iter().find(|o| o.is_mem())
                } else {
                    self.operands.first()
                }
            }
            Isa::RiscV => {
                if riscv_is_store_mnemonic(&self.mnemonic) {
                    self.operands.iter().find(|o| o.is_mem())
                } else {
                    self.operands.first()
                }
            }
        }
    }

    /// Registers written by this instruction (architectural view).
    /// Zero-register writes (AArch64 `xzr`/`wzr`, RISC-V `zero`/`x0`)
    /// are discarded. The RISC-V check is by class + slot, NOT by name:
    /// `x0` is a perfectly writable register on AArch64.
    pub fn writes(&self) -> Vec<Register> {
        let mut out = Vec::new();
        if let Some(Operand::Reg(r)) = self.dest() {
            let zero_reg = matches!(r.name, "xzr" | "wzr")
                || (r.class == super::register::RegisterClass::RGp64 && r.slot == 0);
            if !zero_reg {
                out.push(*r);
            }
        }
        if self.writes_flags() {
            out.push(flags());
        }
        out
    }

    /// Registers read by this instruction, including address registers of
    /// memory operands and the implicit flags read of conditional
    /// branches (x86 jcc, AArch64 `b.<cond>`).
    pub fn reads(&self) -> Vec<Register> {
        let mut out = Vec::new();
        match self.isa {
            Isa::X86 => {
                let n = self.operands.len();
                for (i, op) in self.operands.iter().enumerate() {
                    match op {
                        Operand::Reg(r) => {
                            let is_dest = self.dest().is_some() && i + 1 == n;
                            // Destination-only writes: plain moves replace
                            // the destination; read-modify-write ops (add,
                            // fma, ...) read it too.
                            if !is_dest || self.reads_dest() {
                                out.push(*r);
                            }
                        }
                        Operand::Mem(m) => out.extend(m.address_registers()),
                        _ => {}
                    }
                }
                if self.is_cond_branch() {
                    out.push(flags());
                }
            }
            Isa::AArch64 => {
                // Destination-first; the first operand is only read by
                // accumulating forms (fmla family). Store data registers
                // (operand 0 of `st*`) are always read — the store's
                // destination is the memory operand.
                let dest_is_reg0 = !self.is_branch()
                    && !self.is_compare()
                    && !self.mnemonic.starts_with("st")
                    && matches!(self.operands.first(), Some(Operand::Reg(_)));
                for (i, op) in self.operands.iter().enumerate() {
                    match op {
                        Operand::Reg(r) => {
                            if i == 0 && dest_is_reg0 && !self.reads_dest() {
                                continue;
                            }
                            out.push(*r);
                        }
                        Operand::Mem(m) => out.extend(m.address_registers()),
                        _ => {}
                    }
                }
                if self.mnemonic.starts_with("b.") {
                    out.push(flags());
                }
            }
            Isa::RiscV => {
                // Destination-first like AArch64, but there is no flags
                // register at all: conditional branches read their own
                // register operands (handled below because branches
                // have no dest), and compares don't exist as flag ops.
                let dest_is_reg0 = !self.is_branch()
                    && !riscv_is_store_mnemonic(&self.mnemonic)
                    && matches!(self.operands.first(), Some(Operand::Reg(_)));
                for (i, op) in self.operands.iter().enumerate() {
                    match op {
                        Operand::Reg(r) => {
                            if i == 0 && dest_is_reg0 {
                                continue;
                            }
                            out.push(*r);
                        }
                        Operand::Mem(m) => out.extend(m.address_registers()),
                        _ => {}
                    }
                }
            }
        }
        out
    }

    /// Write-only destination (moves, loads, converts with full-width
    /// writes) vs read-modify-write (x86 legacy 2-operand arithmetic and
    /// FMA; AArch64 accumulating multiplies).
    fn reads_dest(&self) -> bool {
        match self.isa {
            Isa::X86 => {
                // VEX 3-operand forms never read the destination, except
                // FMA which reads all three. Legacy 2-operand arithmetic
                // reads both; the mov family (mov, movl, movaps, movupd,
                // movdqa, movz/movs extensions) and lea replace the
                // destination outright.
                if self.mnemonic.starts_with("vfmadd")
                    || self.mnemonic.starts_with("vfmsub")
                    || self.mnemonic.starts_with("vfnmadd")
                {
                    return true;
                }
                if self.mnemonic.starts_with('v') {
                    return false;
                }
                if self.mnemonic.starts_with("mov") || self.mnemonic.starts_with("lea") {
                    return false;
                }
                // Converts write the full register.
                !self.mnemonic.starts_with("cvt")
            }
            Isa::AArch64 => {
                // Accumulating vector multiplies read the destination;
                // 4-operand fmadd carries its addend explicitly and does
                // not.
                self.mnemonic.starts_with("fmla")
                    || self.mnemonic.starts_with("fmls")
                    || matches!(self.mnemonic.as_str(), "mla" | "mls")
            }
            // RV64GC has no accumulating forms in the modeled subset:
            // fmadd.d carries its addend as an explicit 4th operand.
            Isa::RiscV => false,
        }
    }

    pub fn is_branch(&self) -> bool {
        self.isa.is_branch_mnemonic(&self.mnemonic)
    }

    pub fn is_cond_branch(&self) -> bool {
        self.is_branch() && !matches!(self.mnemonic.as_str(), "jmp" | "b" | "j")
    }

    /// Branches that macro-fuse with a flag-setting predecessor (and
    /// are therefore never resolved against the machine database):
    /// every x86 jcc/jmp, and AArch64 `b`/`b.<cond>`. AArch64
    /// compare-and-branch forms (cbz/cbnz/tbz/tbnz) carry their own
    /// register read and resolve/execute like other instructions —
    /// `api::Engine::prepare` and `sim::decode` share this predicate.
    /// RISC-V has no flags register, so *every* branch is a
    /// compare-and-branch that must resolve against the database.
    pub fn is_fusible_branch(&self) -> bool {
        self.is_branch()
            && match self.isa {
                Isa::X86 => true,
                Isa::AArch64 => self.mnemonic == "b" || self.mnemonic.starts_with("b."),
                Isa::RiscV => false,
            }
    }

    pub fn is_compare(&self) -> bool {
        match self.isa {
            Isa::X86 => {
                matches!(
                    self.mnemonic.trim_end_matches(['b', 'w', 'l', 'q']),
                    "cmp" | "test" | "comis" | "ucomis"
                ) || self.mnemonic.starts_with("cmp")
                    || self.mnemonic.starts_with("test")
            }
            Isa::AArch64 => {
                matches!(self.mnemonic.as_str(), "cmp" | "cmn" | "tst" | "fcmp" | "fcmpe" | "ccmp")
            }
            // No flags register: RISC-V "compares" (slt/sltu/...) write
            // an ordinary GP destination and classify as plain ALU ops.
            Isa::RiscV => false,
        }
    }

    /// Does the instruction set the flags register? (x86: arithmetic +
    /// compares; AArch64: compares + the `s`-suffixed arithmetic forms.)
    pub fn writes_flags(&self) -> bool {
        match self.isa {
            Isa::X86 => {
                if self.mnemonic.starts_with('v') {
                    return false;
                }
                // Match the spelled mnemonic first, then with ONE AT&T
                // size suffix stripped — `trim_end_matches` would eat
                // the trailing letter of `shl`/`imul` themselves and
                // misclassify them as not setting FLAGS.
                let flagged = |m: &str| {
                    matches!(
                        m,
                        "add" | "sub" | "and" | "or" | "xor" | "inc" | "dec" | "cmp" | "test"
                            | "neg" | "shl" | "shr" | "sar" | "imul"
                    )
                };
                let m = self.mnemonic.as_str();
                flagged(m) || m.strip_suffix(['b', 'w', 'l', 'q']).is_some_and(flagged)
            }
            Isa::AArch64 => {
                self.is_compare()
                    || matches!(self.mnemonic.as_str(), "subs" | "adds" | "ands" | "bics" | "negs")
            }
            Isa::RiscV => false,
        }
    }

    /// Is this a store (memory destination)?
    pub fn is_store(&self) -> bool {
        matches!(self.dest(), Some(Operand::Mem(_)))
    }

    /// Is this a load (memory source that is not the destination)?
    pub fn is_load(&self) -> bool {
        match self.isa {
            Isa::X86 => {
                let n = self.operands.len();
                self.operands.iter().enumerate().any(|(i, o)| {
                    o.is_mem()
                        && !(i + 1 == n && self.dest().map(|d| d.is_mem()).unwrap_or(false))
                })
            }
            Isa::AArch64 => self.mnemonic.starts_with("ld") && self.has_mem_operand(),
            Isa::RiscV => riscv_is_load_mnemonic(&self.mnemonic) && self.has_mem_operand(),
        }
    }

    /// Zeroing idiom (`vxorpd %x, %x, %x`, `xorl %eax, %eax`; AArch64
    /// `movi v0.2d, #0` / `eor v,v,v`): real cores resolve these at
    /// rename without consuming an execution port. The analyzer (like
    /// OSACA 0.2) does NOT know this; the simulator does — exactly the
    /// §III-B discrepancy for the -O2 π kernel.
    pub fn is_zero_idiom(&self) -> bool {
        let m = &self.mnemonic;
        match self.isa {
            Isa::X86 => {
                let is_xor = m.starts_with("xor")
                    || m.starts_with("vxor")
                    || m.starts_with("pxor")
                    || m.starts_with("vpxor");
                if !is_xor {
                    return false;
                }
                match self.operands.as_slice() {
                    [Operand::Reg(a), Operand::Reg(b)] => a == b,
                    [Operand::Reg(a), Operand::Reg(b), Operand::Reg(c)] => a == b && b == c,
                    _ => false,
                }
            }
            Isa::AArch64 => {
                if m == "movi" {
                    return matches!(self.operands.as_slice(), [Operand::Reg(_), Operand::Imm(0)]);
                }
                if m == "eor" {
                    return matches!(
                        self.operands.as_slice(),
                        [Operand::Reg(a), Operand::Reg(b), Operand::Reg(c)] if a == b && b == c
                    );
                }
                false
            }
            Isa::RiscV => {
                // `xor rd, rs, rs` with rd == rs is the idiomatic GP
                // zeroing sequence; `li rd, 0` decodes as an ALU op and
                // is not eliminated (matching real RV cores).
                m == "xor"
                    && matches!(
                        self.operands.as_slice(),
                        [Operand::Reg(a), Operand::Reg(b), Operand::Reg(c)] if a == b && b == c
                    )
            }
        }
    }

    /// Register-to-register move eligible for move elimination at rename.
    pub fn is_reg_move(&self) -> bool {
        let movish = match self.isa {
            Isa::X86 => {
                let m = self.mnemonic.trim_end_matches(['b', 'w', 'l', 'q']);
                matches!(m, "mov")
                    || self.mnemonic.starts_with("vmovap")
                    || self.mnemonic.starts_with("vmovup")
                    || self.mnemonic.starts_with("vmovdqa")
                    || self.mnemonic.starts_with("vmovdqu")
                    || self.mnemonic.starts_with("movap")
                    || self.mnemonic.starts_with("movup")
                    || self.mnemonic.starts_with("movdqa")
            }
            Isa::AArch64 => matches!(self.mnemonic.as_str(), "mov" | "fmov"),
            Isa::RiscV => matches!(self.mnemonic.as_str(), "mv" | "fmv.d" | "fmv.s"),
        };
        if !(movish && self.operands.len() == 2) {
            return false;
        }
        match (&self.operands[0], &self.operands[1]) {
            (Operand::Reg(a), Operand::Reg(b)) => match self.isa {
                Isa::X86 => true,
                // GP<->FP transfers (`fmov d0, x1`) cross register
                // files and cannot be eliminated at rename — real
                // cores pay a multi-cycle transfer for them. (RISC-V
                // spells its cross-file transfers `fmv.d.x`/`fmv.x.d`,
                // which the mnemonic list above already excludes, but
                // the file check keeps the rule structural.)
                Isa::AArch64 | Isa::RiscV => matches!(
                    (a.file(), b.file()),
                    (
                        super::register::RegisterFile::Gp(_),
                        super::register::RegisterFile::Gp(_)
                    ) | (
                        super::register::RegisterFile::Vec(_),
                        super::register::RegisterFile::Vec(_)
                    )
                ),
            },
            _ => false,
        }
    }

    /// Widest vector operand width in bits (0 for scalar-int only).
    pub fn vector_width(&self) -> u32 {
        self.operands
            .iter()
            .filter_map(|o| o.reg())
            .map(|r| match r.class {
                super::register::RegisterClass::Xmm => 128,
                super::register::RegisterClass::Ymm => 256,
                super::register::RegisterClass::Zmm => 512,
                super::register::RegisterClass::AVec => 128,
                _ => 0,
            })
            .max()
            .unwrap_or(0)
    }
}

impl fmt::Display for Instruction {
    /// Reconstruct a canonical source spelling in the instruction's own
    /// syntax; `tests/display_roundtrip.rs` pins parse→display→parse
    /// fidelity over every shipped fixture.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if let Some(p) = &self.prefix {
            write!(f, "{p} ")?;
        }
        write!(f, "{}", self.mnemonic)?;
        for (i, op) in self.operands.iter().enumerate() {
            write!(f, "{}", if i == 0 { " " } else { ", " })?;
            match self.isa {
                Isa::X86 => write!(f, "{op}")?,
                Isa::AArch64 => fmt_operand_aarch64(op, f)?,
                Isa::RiscV => fmt_operand_riscv(op, f)?,
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::parser::parse_instruction;

    fn ins(s: &str) -> Instruction {
        parse_instruction(s, 1).expect(s)
    }

    #[test]
    fn form_signature() {
        let i = ins("vfmadd132pd 0(%r13,%rax), %ymm3, %ymm0");
        assert_eq!(i.form().to_string(), "vfmadd132pd-mem_ymm_ymm");
    }

    #[test]
    fn load_store_classification() {
        assert!(ins("vmovapd (%r15,%rax), %ymm0").is_load());
        assert!(!ins("vmovapd (%r15,%rax), %ymm0").is_store());
        assert!(ins("vmovapd %ymm0, (%r14,%rax)").is_store());
        assert!(!ins("vmovapd %ymm0, (%r14,%rax)").is_load());
        assert!(ins("vaddsd (%rsp), %xmm0, %xmm5").is_load());
    }

    #[test]
    fn zero_idiom() {
        assert!(ins("vxorpd %xmm0, %xmm0, %xmm0").is_zero_idiom());
        assert!(!ins("vxorpd %xmm1, %xmm0, %xmm0").is_zero_idiom());
        assert!(ins("xorl %eax, %eax").is_zero_idiom());
    }

    #[test]
    fn fma_reads_all_operands() {
        let i = ins("vfmadd132pd %ymm0, %ymm5, %ymm0");
        let reads = i.reads();
        assert_eq!(reads.len(), 3);
    }

    #[test]
    fn vex_move_does_not_read_dest() {
        let i = ins("vmovapd %ymm1, %ymm0");
        assert_eq!(i.reads().len(), 1);
        assert!(i.is_reg_move());
    }

    #[test]
    fn cond_branch_reads_flags() {
        let i = ins("ja .L10");
        assert!(i.is_cond_branch());
        assert!(i.reads().iter().any(|r| r.name == "flags"));
    }

    #[test]
    fn cmp_writes_flags_only() {
        let i = ins("cmpl %ecx, %r10d");
        assert!(i.writes_flags());
        assert!(i.dest().is_none());
        assert_eq!(i.writes().len(), 1); // flags only
    }

    #[test]
    fn shift_and_imul_write_flags() {
        // Regression: `trim_end_matches` used to eat the trailing
        // letter of `shl`/`imul` themselves, so none of these matched.
        for src in ["shll $2, %eax", "shl $2, %eax", "imull %ebx, %eax", "imul %rbx, %rax"] {
            assert!(ins(src).writes_flags(), "{src}");
        }
        assert!(!ins("movl $1, %eax").writes_flags());
    }

    #[test]
    fn vector_width_detection() {
        assert_eq!(ins("vaddpd %ymm1, %ymm0, %ymm0").vector_width(), 256);
        assert_eq!(ins("vaddpd %xmm1, %xmm0, %xmm0").vector_width(), 128);
        assert_eq!(ins("addl $1, %eax").vector_width(), 0);
    }
}
