//! Model zoo (DESIGN.md §13): grow the machine-model database from
//! published measurement dumps instead of by hand.
//!
//! The paper's §II workflow builds each `.mdb` model from
//! documentation and ibench micro-benchmarks — faithful, but one
//! architecture at a time. uops.info publishes the same three facts
//! (latency, reciprocal throughput, port usage) for every x86
//! microarchitecture it measures, as one big XML database. This
//! module turns such a dump into first-class models:
//!
//! * [`xml`] — a dependency-free streaming pull parser for the
//!   uops.info XML subset (structured errors with line numbers,
//!   never a panic).
//! * [`overlay`] — curated per-µarch facts the XML does not carry:
//!   port roles, core parameters, flags, caches, CLI aliases.
//! * [`import`] — compiles XML measurements + overlay into a
//!   [`crate::mdb::MachineModel`] and round-trips it through the
//!   `.mdb` serializer so the emitted text is guaranteed loadable.
//!
//! Imported text registers with the dynamic model registry
//! (`mdb::registry`), after which the new architecture resolves
//! everywhere a built-in does: `analyze --arch clx`, the serve
//! shards, `zoo-sweep`, and `corpus`. The CLI entry points are
//! `osaca import-model <xml> --arch <name>` and `osaca zoo-sweep`.

pub mod import;
pub mod overlay;
pub mod xml;

pub use import::{arches_in, import_model, ImportedModel};
pub use overlay::curated_arches;

use crate::api::OsacaError;

/// Import `arch` from XML text and register the result with the
/// dynamic model registry under its canonical short name. Returns the
/// canonical name (what `--arch` then accepts).
pub fn import_and_register(xml: &str, arch: &str) -> Result<String, OsacaError> {
    let imported = import_model(xml, arch)?;
    let name = imported.model.name.clone();
    crate::mdb::register_model_text(&name, &imported.text);
    Ok(name)
}
