//! Compile uops.info XML measurements + a curated overlay into a
//! `MachineModel`, reusing the `.mdb` round-trip infrastructure.
//!
//! Data flow (DESIGN.md §13):
//!
//! ```text
//! uops.info XML --(xml::Pull)--> per-arch records
//!        + overlay (ports/roles/params/flags/caches)
//!   --> FormEntry µ-op decompositions
//!   --> MachineModel::serialize()  (the --learn stanza emitter)
//!   --> MachineModel::parse()      (round-trip: emitted text is
//!                                   guaranteed loadable)
//! ```
//!
//! Operand signatures are rebuilt in the repo's AT&T convention: the
//! XML lists operands in Intel order (destination first), so the
//! importer reverses them, maps register widths to the `.mdb` width
//! classes (128 -> `xmm`, 256 -> `ymm`), and generalizes GPR widths
//! to the bare `r` class for non-VEX mnemonics so the analyzer's
//! suffix normalization (`addl` -> `add-imm_r`) keeps working —
//! VEX-prefixed mnemonics keep explicit `r32`/`r64` classes exactly
//! like the hand-written models do.

use crate::api::OsacaError;
use crate::isa::InstructionForm;
use crate::mdb::machine::MachineModel;
use crate::mdb::{FormEntry, PortMask, Uop, UopKind};

use super::overlay::{self, Overlay};
use super::xml::{Event, Pull};

/// One instruction's worth of measurement for the target arch.
struct Record {
    /// 1-based XML line of the `<instruction>` element (error context).
    line: usize,
    mnemonic: String,
    sig: String,
    has_mem_read: bool,
    has_mem_write: bool,
    ports: String,
    tp: f32,
    latency: f32,
    div_cycles: f32,
}

/// A fully imported model: the compiled machine model plus the exact
/// `.mdb` text it round-tripped through.
pub struct ImportedModel {
    pub model: MachineModel,
    pub text: String,
    /// Instruction forms imported for the target architecture.
    pub entries: usize,
}

fn bad(line: impl Into<Option<usize>>, message: impl Into<String>) -> OsacaError {
    OsacaError::BadModelImport { line: line.into(), message: message.into() }
}

/// Every `<architecture name=..>` spelling in the XML, sorted unique —
/// what `import-model` offers when asked for an arch the dump lacks.
pub fn arches_in(xml: &str) -> Result<Vec<String>, OsacaError> {
    let mut pull = Pull::new(xml);
    let mut names = Vec::new();
    loop {
        match pull.next_event().map_err(|e| bad(e.line, e.message))? {
            Event::Open { name: "architecture", ref attrs, .. } => {
                if let Some((_, v)) = attrs.iter().find(|(k, _)| *k == "name") {
                    if !names.contains(v) {
                        names.push(v.clone());
                    }
                }
            }
            Event::Eof => break,
            _ => {}
        }
    }
    names.sort();
    Ok(names)
}

/// Import the measurements for `arch` from uops.info-format XML text,
/// compile them against the curated overlay, and round-trip the result
/// through the `.mdb` serializer/parser. Every failure is a structured
/// [`OsacaError::BadModelImport`]; malformed XML never panics.
pub fn import_model(xml: &str, arch: &str) -> Result<ImportedModel, OsacaError> {
    let ov = overlay::overlay_for(arch).ok_or_else(|| {
        bad(
            None,
            format!(
                "no curated overlay for architecture `{arch}` (curated: {})",
                overlay::curated_arches().join(", ")
            ),
        )
    })?;
    let records = collect_records(xml, ov)?;
    if records.is_empty() {
        return Err(bad(
            None,
            format!(
                "no measurements for architecture `{arch}` in the XML (present: {})",
                arches_in(xml)?.join(", ")
            ),
        ));
    }
    build_model(ov, &records)
}

/// Walk the XML once, keeping only instructions with a measurement
/// for one of the overlay's architecture spellings.
fn collect_records(xml: &str, ov: &Overlay) -> Result<Vec<Record>, OsacaError> {
    let arch_matches = |name: &str| {
        ov.arch.eq_ignore_ascii_case(name)
            || ov.xml_names.iter().any(|n| n.eq_ignore_ascii_case(name))
    };
    let mut pull = Pull::new(xml);
    let mut records: Vec<Record> = Vec::new();
    // Current <instruction> context.
    let mut cur: Option<Record> = None;
    let mut sig_parts: Vec<String> = Vec::new();
    let mut in_matching_arch = false;
    let mut in_measurement = false;
    let mut seen_measurement = false;
    loop {
        let line = pull.line();
        let ev = pull.next_event().map_err(|e| bad(e.line, e.message))?;
        match ev {
            Event::Open { name: "instruction", ref attrs, self_closing } => {
                if self_closing {
                    continue; // no operands, no measurements: nothing to import
                }
                let asm = attrs
                    .iter()
                    .find(|(k, _)| *k == "asm")
                    .map(|(_, v)| v.clone())
                    .ok_or_else(|| bad(line, "<instruction> without an `asm` attribute"))?;
                cur = Some(Record {
                    line,
                    mnemonic: asm.to_ascii_lowercase(),
                    sig: String::new(),
                    has_mem_read: false,
                    has_mem_write: false,
                    ports: String::new(),
                    tp: 0.0,
                    latency: 0.0,
                    div_cycles: 0.0,
                });
                sig_parts.clear();
                seen_measurement = false;
            }
            Event::Close { name: "instruction" } => {
                if let Some(mut rec) = cur.take() {
                    if seen_measurement {
                        // Intel operand order -> AT&T (dest last).
                        sig_parts.reverse();
                        rec.sig = sig_parts.join("_");
                        if rec.sig.is_empty() {
                            return Err(bad(rec.line, format!(
                                "instruction `{}` has no non-suppressed operands",
                                rec.mnemonic
                            )));
                        }
                        records.push(rec);
                    }
                }
                in_matching_arch = false;
                in_measurement = false;
            }
            Event::Open { name: "operand", ref attrs, .. } => {
                let rec = match cur.as_mut() {
                    Some(r) => r,
                    None => continue,
                };
                let attr = |k: &str| attrs.iter().find(|(a, _)| *a == k).map(|(_, v)| v.as_str());
                if attr("suppressed") == Some("1") {
                    continue;
                }
                let ty = attr("type").unwrap_or("");
                match ty {
                    "flags" => {}
                    "imm" => sig_parts.push("imm".to_string()),
                    "mem" | "agen" => {
                        sig_parts.push("mem".to_string());
                        if attr("r") == Some("1") {
                            rec.has_mem_read = true;
                        }
                        if attr("w") == Some("1") {
                            rec.has_mem_write = true;
                        }
                    }
                    "reg" => {
                        let width: u32 = attr("width")
                            .unwrap_or("64")
                            .parse()
                            .map_err(|_| bad(line, "bad operand width"))?;
                        sig_parts.push(reg_class(&rec.mnemonic, width).to_string());
                    }
                    other => {
                        return Err(bad(line, format!("unknown operand type `{other}`")));
                    }
                }
            }
            Event::Open { name: "architecture", ref attrs, self_closing } => {
                let name =
                    attrs.iter().find(|(k, _)| *k == "name").map(|(_, v)| v.as_str()).unwrap_or("");
                in_matching_arch = cur.is_some() && !self_closing && arch_matches(name);
            }
            Event::Close { name: "architecture" } => {
                in_matching_arch = false;
                in_measurement = false;
            }
            Event::Open { name: "measurement", ref attrs, self_closing } => {
                if !in_matching_arch {
                    continue;
                }
                let rec = match cur.as_mut() {
                    Some(r) => r,
                    None => continue,
                };
                let attr = |k: &str| attrs.iter().find(|(a, _)| *a == k).map(|(_, v)| v.as_str());
                rec.ports = attr("ports").unwrap_or("").to_string();
                rec.tp = parse_f32(attr("TP"), line, "TP")?;
                rec.div_cycles = parse_f32(attr("div_cycles"), line, "div_cycles")?;
                seen_measurement = true;
                in_measurement = !self_closing;
            }
            Event::Close { name: "measurement" } => in_measurement = false,
            Event::Open { name: "latency", ref attrs, .. } => {
                if !in_measurement {
                    continue;
                }
                if let Some(rec) = cur.as_mut() {
                    let cycles =
                        attrs.iter().find(|(k, _)| *k == "cycles").map(|(_, v)| v.as_str());
                    rec.latency = parse_f32(cycles, line, "latency cycles")?;
                }
            }
            Event::Eof => break,
            _ => {}
        }
    }
    Ok(records)
}

fn parse_f32(v: Option<&str>, line: usize, what: &str) -> Result<f32, OsacaError> {
    match v {
        None | Some("") => Ok(0.0),
        Some(s) => s.parse().map_err(|_| bad(line, format!("bad {what} value `{s}`"))),
    }
}

/// Map a register operand to the `.mdb` width class. VEX mnemonics
/// keep explicit GPR widths (`vcvtsi2sd-r32_xmm_xmm`); everything
/// else generalizes to `r` so suffix normalization applies.
fn reg_class(mnemonic: &str, width: u32) -> &'static str {
    match width {
        512 => "zmm",
        256 => "ymm",
        128 => "xmm",
        64 if mnemonic.starts_with('v') => "r64",
        32 if mnemonic.starts_with('v') => "r32",
        _ => "r",
    }
}

/// Resolve one port-usage token against the overlay's port list:
/// an exact port name, or a prefix + one digit per port
/// (`p0156` -> P0|P1|P5|P6, `FP01` -> FP0|FP1, `AGU012` -> all AGUs).
fn port_token_mask(ports: &[&str], token: &str) -> Option<PortMask> {
    let index_of =
        |name: &str| ports.iter().position(|p| p.eq_ignore_ascii_case(name));
    if let Some(i) = index_of(token) {
        return Some(PortMask::single(i));
    }
    let first_digit = token.find(|c: char| c.is_ascii_digit())?;
    let (prefix, digits) = token.split_at(first_digit);
    if prefix.is_empty() || digits.is_empty() || !digits.chars().all(|c| c.is_ascii_digit()) {
        return None;
    }
    let mut mask = PortMask::EMPTY;
    for d in digits.chars() {
        let i = index_of(&format!("{prefix}{d}"))?;
        mask = mask.union(PortMask::single(i));
    }
    Some(mask)
}

fn role_mask(ports: &[&str], role: &[&str]) -> PortMask {
    let mut mask = PortMask::EMPTY;
    for name in role {
        if let Some(i) = ports.iter().position(|p| p.eq_ignore_ascii_case(name)) {
            mask = mask.union(PortMask::single(i));
        }
    }
    mask
}

fn is_subset(a: PortMask, b: PortMask) -> bool {
    !a.is_empty() && a.iter().all(|p| b.contains(p))
}

/// Compile the records into a `MachineModel` and round-trip it
/// through the `.mdb` text format.
fn build_model(ov: &Overlay, records: &[Record]) -> Result<ImportedModel, OsacaError> {
    let load = role_mask(ov.ports, ov.load_ports);
    let store_data = role_mask(ov.ports, ov.store_data_ports);
    let store_agu = role_mask(ov.ports, ov.store_agu_ports);
    let divider = role_mask(ov.ports, &[ov.divider_port]);
    let mut model = MachineModel {
        name: ov.arch.to_string(),
        arch_name: ov.pretty.to_string(),
        isa: ov.isa,
        ports: ov.ports.iter().map(|p| p.to_string()).collect(),
        frequency_ghz: ov.freq_ghz,
        avx256_split: ov.flags.contains(&"avx256_split"),
        hide_load_behind_store: ov.flags.contains(&"hide_load_behind_store"),
        sim_zero_idiom_elim: ov.simflags.contains(&"zero_idiom_elim"),
        sim_macro_fusion: ov.simflags.contains(&"macro_fusion"),
        sim_move_elim: ov.simflags.contains(&"move_elim"),
        sim_store_data_free: ov.simflags.contains(&"store_data_free"),
        load_ports: load,
        store_data_ports: store_data,
        store_agu_ports: store_agu,
        store_agu_simple_ports: role_mask(ov.ports, ov.store_agu_simple_ports),
        params: ov.core_params(),
        caches: ov.cache_levels(),
        mem_latency_cy: ov.mem_latency_cy,
        entries: Default::default(),
        index: Default::default(),
    };
    let n = records.len();
    for rec in records {
        let uops = decode_uops(rec, ov, load, store_data, store_agu, divider)?;
        let form = InstructionForm::parse(&format!("{}-{}", rec.mnemonic, rec.sig));
        model.insert(FormEntry { form, latency: rec.latency, throughput: rec.tp, uops });
    }
    // Round-trip through the --learn stanza infrastructure: the text
    // we hand out must load exactly like a hand-written model.
    let text = model.serialize();
    let model = MachineModel::parse(&text).map_err(|e| {
        bad(None, format!("imported `{}` model failed the .mdb round-trip: {e:#}", ov.arch))
    })?;
    Ok(ImportedModel { model, text, entries: n })
}

/// Decode a uops.info port-usage string (`1*p015+1*p23`) into typed
/// µ-ops. Roles are inferred from the overlay's port sets and the
/// instruction's memory-operand direction: for a store, the first
/// term on the store-data ports is the store-data µ-op and the next
/// on the store-AGU ports the AGU µ-op; a term on the load ports of a
/// mem-reading instruction is the load µ-op; everything else computes.
/// A nonzero `div_cycles` appends the divider-pipe occupancy µ-op.
fn decode_uops(
    rec: &Record,
    ov: &Overlay,
    load: PortMask,
    store_data: PortMask,
    store_agu: PortMask,
    divider: PortMask,
) -> Result<Vec<Uop>, OsacaError> {
    let mut uops = Vec::new();
    let (mut st_done, mut agu_done, mut ld_done) = (false, false, false);
    if rec.ports.is_empty() {
        return Err(bad(
            rec.line,
            format!("instruction `{}-{}` has no `ports` usage", rec.mnemonic, rec.sig),
        ));
    }
    for term in rec.ports.split('+') {
        let term = term.trim();
        let (count_s, token) = term.split_once('*').ok_or_else(|| {
            bad(rec.line, format!("bad port-usage term `{term}` (want N*ports)"))
        })?;
        let count: u32 = count_s
            .trim()
            .parse()
            .map_err(|_| bad(rec.line, format!("bad µ-op count in `{term}`")))?;
        let mask = port_token_mask(ov.ports, token.trim()).ok_or_else(|| {
            bad(
                rec.line,
                format!("unknown port token `{}` for {} (ports: {})", token, ov.arch, ov.ports.join(" ")),
            )
        })?;
        for _ in 0..count {
            let kind = if rec.has_mem_write && !st_done && is_subset(mask, store_data) {
                st_done = true;
                UopKind::StoreData
            } else if rec.has_mem_write && !agu_done && is_subset(mask, store_agu) {
                agu_done = true;
                UopKind::StoreAgu
            } else if rec.has_mem_read && !ld_done && is_subset(mask, load) {
                ld_done = true;
                UopKind::Load
            } else {
                UopKind::Compute
            };
            uops.push(Uop { kind, ports: mask, occupancy: 1.0 });
        }
    }
    if rec.div_cycles > 0.0 {
        uops.push(Uop { kind: UopKind::Divider, ports: divider, occupancy: rec.div_cycles });
    }
    Ok(uops)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI_XML: &str = r#"<?xml version="1.0"?>
<!-- trimmed uops.info-style dump: two arches, three instructions -->
<root>
  <extension name="AVX">
    <instruction asm="VADDPD" string="VADDPD (XMM, XMM, XMM)">
      <operand idx="1" type="reg" width="128"/>
      <operand idx="2" type="reg" width="128"/>
      <operand idx="3" type="reg" width="128"/>
      <operand idx="4" type="flags" suppressed="1"/>
      <architecture name="CLX">
        <measurement ports="1*p01" TP="0.5" uops="1">
          <latency cycles="4"/>
        </measurement>
      </architecture>
      <architecture name="ZEN2">
        <measurement ports="1*FP23" TP="0.5" uops="1">
          <latency cycles="3"/>
        </measurement>
      </architecture>
    </instruction>
    <instruction asm="VMOVAPD" string="VMOVAPD (M256, YMM)">
      <operand idx="1" type="mem" width="256" w="1"/>
      <operand idx="2" type="reg" width="256"/>
      <architecture name="CLX">
        <measurement ports="1*p4+1*p23" TP="1" uops="2">
          <latency cycles="1"/>
        </measurement>
      </architecture>
    </instruction>
    <instruction asm="VDIVSD" string="VDIVSD (XMM, XMM, XMM)">
      <operand idx="1" type="reg" width="128"/>
      <operand idx="2" type="reg" width="128"/>
      <operand idx="3" type="reg" width="128"/>
      <architecture name="CLX">
        <measurement ports="1*p0" TP="4" uops="1" div_cycles="4">
          <latency cycles="13"/>
        </measurement>
      </architecture>
    </instruction>
  </extension>
</root>
"#;

    #[test]
    fn mini_import_compiles_signatures_and_uops() {
        let imp = import_model(MINI_XML, "clx").unwrap();
        assert_eq!(imp.model.name, "clx");
        assert_eq!(imp.entries, 3);
        let add = &imp.model.entries[&InstructionForm::new("vaddpd", "xmm_xmm_xmm")];
        assert_eq!(add.uops.len(), 1);
        assert_eq!(add.uops[0].kind, UopKind::Compute);
        assert_eq!(add.uops[0].ports.count(), 2); // P0|P1
        assert_eq!(add.latency, 4.0);
        // Store: Intel (M256, YMM) -> AT&T ymm_mem, st on P4 + agu on P2|P3.
        let st = &imp.model.entries[&InstructionForm::new("vmovapd", "ymm_mem")];
        assert_eq!(st.uops[0].kind, UopKind::StoreData);
        assert_eq!(st.uops[1].kind, UopKind::StoreAgu);
        // Divider occupancy rides the overlay's divider pseudo-port.
        let div = &imp.model.entries[&InstructionForm::new("vdivsd", "xmm_xmm_xmm")];
        assert_eq!(div.uops[1].kind, UopKind::Divider);
        assert_eq!(div.uops[1].occupancy, 4.0);
        // The emitted text is the round-tripped serialization.
        assert!(imp.text.contains("arch clx \"Intel Cascade Lake\""));
        assert!(imp.text.contains("entry vdivsd-xmm_xmm_xmm lat=13 tp=4 uops=c@1:P0,dv@4:0DV"));
    }

    #[test]
    fn zen2_tokens_resolve_against_amd_port_names() {
        let imp = import_model(MINI_XML, "zen2").unwrap();
        assert_eq!(imp.entries, 1);
        let add = &imp.model.entries[&InstructionForm::new("vaddpd", "xmm_xmm_xmm")];
        let names: Vec<&str> =
            add.uops[0].ports.iter().map(|i| imp.model.ports[i].as_str()).collect();
        assert_eq!(names, vec!["FP2", "FP3"]);
        assert_eq!(add.latency, 3.0);
        assert!(!imp.model.avx256_split);
    }

    #[test]
    fn unknown_arch_and_missing_measurements_are_structured() {
        let err = import_model(MINI_XML, "m1max").unwrap_err();
        assert_eq!(err.kind_name(), "bad_model_import");
        assert!(err.to_string().contains("curated"), "{err}");
        // icl is curated but absent from this dump.
        let err = import_model(MINI_XML, "icl").unwrap_err();
        assert_eq!(err.kind_name(), "bad_model_import");
        assert!(err.to_string().contains("no measurements"), "{err}");
        assert!(err.to_string().contains("CLX"), "{err}");
    }

    #[test]
    fn malformed_xml_is_a_structured_error_with_a_line() {
        let truncated = &MINI_XML[..MINI_XML.len() / 2];
        let err = import_model(truncated, "clx").unwrap_err();
        assert_eq!(err.kind_name(), "bad_model_import");
        let bad_port = MINI_XML.replace("1*p01", "1*p99");
        let err = import_model(&bad_port, "clx").unwrap_err();
        assert!(err.to_string().contains("unknown port token"), "{err}");
        let bad_term = MINI_XML.replace("1*p01", "frobnicate");
        let err = import_model(&bad_term, "clx").unwrap_err();
        assert!(err.to_string().contains("bad port-usage term"), "{err}");
    }

    #[test]
    fn arches_listing_is_sorted_unique() {
        assert_eq!(arches_in(MINI_XML).unwrap(), vec!["CLX".to_string(), "ZEN2".to_string()]);
    }

    #[test]
    fn port_tokens_cover_intel_and_amd_styles() {
        let intel = &["P0", "P1", "P2", "P3", "P4", "P5", "P6", "P7", "0DV"];
        assert_eq!(port_token_mask(intel, "p0156").unwrap().count(), 4);
        assert_eq!(port_token_mask(intel, "p23").unwrap().count(), 2);
        assert_eq!(port_token_mask(intel, "0DV").unwrap().count(), 1);
        assert!(port_token_mask(intel, "p9").is_none());
        let amd = &["FP0", "FP1", "FP2", "FP3", "AGU0", "AGU1", "AGU2", "DV"];
        assert_eq!(port_token_mask(amd, "FP01").unwrap().count(), 2);
        assert_eq!(port_token_mask(amd, "AGU012").unwrap().count(), 3);
        assert_eq!(port_token_mask(amd, "DV").unwrap().count(), 1);
        assert!(port_token_mask(amd, "IX3").is_none());
    }
}
