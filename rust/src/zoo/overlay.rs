//! Curated per-architecture overlays for the uops.info importer.
//!
//! uops.info measurements give latency, throughput and port *usage*
//! ("1*p23"), but not the machine-level facts a `.mdb` model needs:
//! which ports exist and what they do (load/store-data/store-AGU
//! roles), core parameters (ROB, scheduler, widths, forwarding
//! latencies), analyzer/simulator flags, the cache hierarchy, and the
//! CLI aliases. An [`Overlay`] supplies exactly that — the same
//! by-hand §II knowledge the built-in models encode, curated once per
//! microarchitecture family instead of once per instruction.
//!
//! The three shipped overlays match the vendored test fixture
//! (`rust/tests/fixtures/uops_trimmed.xml`) and the registry's
//! curated alias table (`mdb::registry`):
//!
//! * `clx` — Cascade Lake, structurally the paper's Skylake core
//!   (same port roles, sizes and caches), so imported predictions pin
//!   against the skl golden numbers.
//! * `icl` — Ice Lake: 10 execution ports with split store-data
//!   (P4/P9) and dedicated store-AGU (P7/P8) pipes, a bigger window.
//! * `zen2` — Zen 2: Zen's FP/ALU/AGU pipe split with a third AGU
//!   and native 256-bit datapaths (no `avx256_split`).

use crate::isa::Isa;
use crate::mdb::machine::{CacheLevel, CoreParams};

/// One cache level as overlay data: (name, size, line, latency, assoc).
pub type OverlayCache = (&'static str, u64, u32, u32, u32);

/// Everything the XML does not carry. Port-usage tokens in the XML
/// resolve against `ports` (see `import::port_token_mask`); the
/// `divider_port` receives the `div_cycles` occupancy µ-op.
pub struct Overlay {
    /// Canonical short name (registry key, `.mdb` `arch` directive).
    pub arch: &'static str,
    /// Human-readable name for the `arch` directive.
    pub pretty: &'static str,
    /// How the architecture is spelled in uops.info XML dumps
    /// (matched case-insensitively against `<architecture name=..>`).
    pub xml_names: &'static [&'static str],
    pub isa: Isa,
    pub freq_ghz: f64,
    pub ports: &'static [&'static str],
    pub load_ports: &'static [&'static str],
    pub store_data_ports: &'static [&'static str],
    pub store_agu_ports: &'static [&'static str],
    pub store_agu_simple_ports: &'static [&'static str],
    pub divider_port: &'static str,
    /// Analyzer flags (`avx256_split`, `hide_load_behind_store`).
    pub flags: &'static [&'static str],
    /// Simulator flags (`zero_idiom_elim`, ...).
    pub simflags: &'static [&'static str],
    /// (rob, sched, rename_width, retire_width, load_latency,
    /// store_forward_latency, sim_divider_scale).
    pub params: (usize, usize, usize, usize, u32, u32, f32),
    pub lsq_size: usize,
    pub lfb: u32,
    pub caches: &'static [OverlayCache],
    pub mem_latency_cy: u32,
}

impl Overlay {
    pub fn core_params(&self) -> CoreParams {
        let (rob, sched, rename, retire, load_lat, stfwd, div_scale) = self.params;
        CoreParams {
            rob_size: rob,
            scheduler_size: sched,
            rename_width: rename,
            retire_width: retire,
            load_latency: load_lat,
            store_forward_latency: stfwd,
            sim_divider_scale: div_scale,
            lsq_size: self.lsq_size,
            lfb: self.lfb,
        }
    }

    pub fn cache_levels(&self) -> Vec<CacheLevel> {
        self.caches
            .iter()
            .map(|&(name, size, line, lat, assoc)| CacheLevel {
                name: name.to_string(),
                size_bytes: size,
                line_bytes: line,
                latency_cy: lat,
                assoc,
            })
            .collect()
    }
}

const CLX: Overlay = Overlay {
    arch: "clx",
    pretty: "Intel Cascade Lake",
    xml_names: &["CLX", "CascadeLake"],
    isa: Isa::X86,
    freq_ghz: 1.8,
    // Skylake-server core: same port roles as data/skl.mdb.
    ports: &["P0", "P1", "P2", "P3", "P4", "P5", "P6", "P7", "0DV"],
    load_ports: &["P2", "P3"],
    store_data_ports: &["P4"],
    store_agu_ports: &["P2", "P3"],
    store_agu_simple_ports: &[],
    divider_port: "0DV",
    flags: &[],
    simflags: &["zero_idiom_elim", "macro_fusion", "move_elim"],
    params: (224, 97, 4, 4, 4, 4, 1.0),
    lsq_size: 72,
    lfb: 8,
    caches: &[
        ("l1", 32 << 10, 64, 4, 8),
        ("l2", 1 << 20, 64, 12, 16),
        ("l3", 8 << 20, 64, 44, 16),
    ],
    mem_latency_cy: 80,
};

const ICL: Overlay = Overlay {
    arch: "icl",
    pretty: "Intel Ice Lake",
    xml_names: &["ICL", "IceLake"],
    isa: Isa::X86,
    freq_ghz: 1.8,
    // Sunny Cove: store data moved off the load AGUs onto P4/P9 and
    // store AGUs onto dedicated P7/P8 pipes; wider window.
    ports: &["P0", "P1", "P2", "P3", "P4", "P5", "P6", "P7", "P8", "P9", "0DV"],
    load_ports: &["P2", "P3"],
    store_data_ports: &["P4", "P9"],
    store_agu_ports: &["P7", "P8"],
    store_agu_simple_ports: &[],
    divider_port: "0DV",
    flags: &[],
    simflags: &["zero_idiom_elim", "macro_fusion", "move_elim"],
    params: (352, 160, 5, 5, 5, 4, 1.0),
    lsq_size: 128,
    lfb: 12,
    caches: &[
        ("l1", 48 << 10, 64, 5, 12),
        ("l2", 512 << 10, 64, 13, 8),
        ("l3", 8 << 20, 64, 42, 16),
    ],
    mem_latency_cy: 85,
};

const ZEN2: Overlay = Overlay {
    arch: "zen2",
    pretty: "AMD Zen 2",
    xml_names: &["ZEN2", "ZEN+2", "Zen2"],
    isa: Isa::X86,
    freq_ghz: 1.8,
    // Zen pipe split (data/zen.mdb) with a third AGU and native
    // 256-bit datapaths, so no avx256_split flag.
    ports: &[
        "FP0", "FP1", "FP2", "FP3", "ALU0", "ALU1", "ALU2", "ALU3", "AGU0", "AGU1", "AGU2",
        "DV",
    ],
    load_ports: &["AGU0", "AGU1", "AGU2"],
    store_data_ports: &["AGU0", "AGU1", "AGU2"],
    store_agu_ports: &["AGU0", "AGU1", "AGU2"],
    store_agu_simple_ports: &[],
    divider_port: "DV",
    flags: &[],
    simflags: &["zero_idiom_elim", "macro_fusion", "move_elim"],
    params: (224, 92, 5, 5, 4, 7, 1.25),
    lsq_size: 92,
    lfb: 12,
    caches: &[
        ("l1", 32 << 10, 64, 4, 8),
        ("l2", 512 << 10, 64, 12, 8),
        ("l3", 16 << 20, 64, 39, 16),
    ],
    mem_latency_cy: 90,
};

const OVERLAYS: &[&Overlay] = &[&CLX, &ICL, &ZEN2];

/// The curated overlay for an architecture spelling (canonical short
/// name or any of its uops.info XML spellings), case-insensitively.
pub fn overlay_for(arch: &str) -> Option<&'static Overlay> {
    OVERLAYS.iter().copied().find(|o| {
        o.arch.eq_ignore_ascii_case(arch)
            || o.xml_names.iter().any(|n| n.eq_ignore_ascii_case(arch))
    })
}

/// Canonical names of every curated overlay (sorted, for messages).
pub fn curated_arches() -> Vec<&'static str> {
    let mut v: Vec<&'static str> = OVERLAYS.iter().map(|o| o.arch).collect();
    v.sort_unstable();
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlays_resolve_by_short_name_and_xml_spelling() {
        assert_eq!(overlay_for("clx").unwrap().arch, "clx");
        assert_eq!(overlay_for("CascadeLake").unwrap().arch, "clx");
        assert_eq!(overlay_for("ICL").unwrap().arch, "icl");
        assert_eq!(overlay_for("Zen2").unwrap().arch, "zen2");
        assert!(overlay_for("m1max").is_none());
        assert_eq!(curated_arches(), vec!["clx", "icl", "zen2"]);
    }

    #[test]
    fn overlay_port_roles_are_subsets_of_the_port_list() {
        for o in [&CLX, &ICL, &ZEN2] {
            for role in [
                o.load_ports,
                o.store_data_ports,
                o.store_agu_ports,
                o.store_agu_simple_ports,
            ] {
                for p in role {
                    assert!(o.ports.contains(p), "{}: role port {p} not declared", o.arch);
                }
            }
            assert!(o.ports.contains(&o.divider_port), "{}", o.arch);
        }
    }
}
