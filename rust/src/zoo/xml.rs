//! Minimal streaming XML pull parser for the uops.info instruction
//! database format — hand-rolled, zero dependencies, in the spirit of
//! the ustar reader in `corpus::tar`.
//!
//! This is deliberately not a general XML parser: it understands
//! exactly the subset the uops.info dumps use — elements with
//! single- or double-quoted attributes, self-closing tags, comments,
//! the `<?xml?>` declaration, a `<!DOCTYPE>` line, character data
//! (skipped; the importer only reads structure and attributes) and
//! the five predefined entities plus numeric character references in
//! attribute values. Anything outside that subset is a structured
//! error with a line number, never a panic (`tests/zoo_import.rs`
//! fuzzes the malformed cases).

/// One parse error with the 1-based source line it was found on.
#[derive(Debug)]
pub struct XmlError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for XmlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

/// A pull event: element open (with attributes), element close, or
/// end of input. Self-closing tags yield `Open { self_closing: true }`
/// and no matching `Close`.
#[derive(Debug)]
pub enum Event<'a> {
    Open { name: &'a str, attrs: Vec<(&'a str, String)>, self_closing: bool },
    Close { name: &'a str },
    Eof,
}

impl<'a> Event<'a> {
    /// Attribute value by name, if present.
    pub fn attr(&self, key: &str) -> Option<&str> {
        match self {
            Event::Open { attrs, .. } => {
                attrs.iter().find(|(k, _)| *k == key).map(|(_, v)| v.as_str())
            }
            _ => None,
        }
    }
}

/// The pull parser: call [`Pull::next_event`] until `Event::Eof`.
pub struct Pull<'a> {
    src: &'a str,
    pos: usize,
}

impl<'a> Pull<'a> {
    pub fn new(src: &'a str) -> Pull<'a> {
        Pull { src, pos: 0 }
    }

    /// 1-based line of the current position (for error context).
    pub fn line(&self) -> usize {
        self.src[..self.pos.min(self.src.len())].bytes().filter(|&b| b == b'\n').count() + 1
    }

    fn err(&self, message: impl Into<String>) -> XmlError {
        XmlError { line: self.line(), message: message.into() }
    }

    fn rest(&self) -> &'a str {
        &self.src[self.pos..]
    }

    /// Advance past `needle`, erroring (unterminated construct) if absent.
    fn skip_past(&mut self, needle: &str, what: &str) -> Result<(), XmlError> {
        match self.rest().find(needle) {
            Some(i) => {
                self.pos += i + needle.len();
                Ok(())
            }
            None => Err(self.err(format!("unterminated {what}"))),
        }
    }

    pub fn next_event(&mut self) -> Result<Event<'a>, XmlError> {
        loop {
            // Skip character data up to the next markup.
            match self.rest().find('<') {
                Some(i) => self.pos += i,
                None => {
                    let tail = self.rest().trim();
                    if !tail.is_empty() {
                        return Err(self.err("text after the last element"));
                    }
                    self.pos = self.src.len();
                    return Ok(Event::Eof);
                }
            }
            let rest = self.rest();
            if rest.starts_with("<!--") {
                self.skip_past("-->", "comment")?;
                continue;
            }
            if rest.starts_with("<?") {
                self.skip_past("?>", "processing instruction")?;
                continue;
            }
            if rest.starts_with("<!") {
                // DOCTYPE / CDATA-free subset: skip to the closing '>'.
                self.skip_past(">", "declaration")?;
                continue;
            }
            if let Some(tail) = rest.strip_prefix("</") {
                let end = tail.find('>').ok_or_else(|| self.err("unterminated closing tag"))?;
                let name = tail[..end].trim();
                if name.is_empty() {
                    return Err(self.err("closing tag with no name"));
                }
                self.pos += 2 + end + 1;
                return Ok(Event::Close { name });
            }
            return self.parse_open();
        }
    }

    fn parse_open(&mut self) -> Result<Event<'a>, XmlError> {
        debug_assert!(self.rest().starts_with('<'));
        let start = self.pos + 1;
        let body = &self.src[start..];
        let end = body.find('>').ok_or_else(|| self.err("unterminated tag"))?;
        let raw = &body[..end];
        let (raw, self_closing) = match raw.strip_suffix('/') {
            Some(r) => (r, true),
            None => (raw, false),
        };
        let name_end = raw
            .find(|c: char| c.is_ascii_whitespace())
            .unwrap_or(raw.len());
        let name = &raw[..name_end];
        if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == ':') {
            return Err(self.err(format!("bad element name `{name}`")));
        }
        let mut attrs = Vec::new();
        let mut rest = raw[name_end..].trim_start();
        while !rest.is_empty() {
            let eq = rest
                .find('=')
                .ok_or_else(|| self.err(format!("attribute without value in <{name}>")))?;
            let key = rest[..eq].trim();
            if key.is_empty() {
                return Err(self.err(format!("empty attribute name in <{name}>")));
            }
            let after = rest[eq + 1..].trim_start();
            let quote = after
                .chars()
                .next()
                .filter(|&q| q == '"' || q == '\'')
                .ok_or_else(|| self.err(format!("unquoted value for `{key}` in <{name}>")))?;
            let val_body = &after[1..];
            let close = val_body
                .find(quote)
                .ok_or_else(|| self.err(format!("unterminated value for `{key}` in <{name}>")))?;
            let value = decode_entities(&val_body[..close])
                .map_err(|m| self.err(format!("in `{key}` of <{name}>: {m}")))?;
            attrs.push((key, value));
            rest = val_body[close + 1..].trim_start();
        }
        self.pos = start + end + 1;
        Ok(Event::Open { name, attrs, self_closing })
    }
}

/// Decode the five predefined entities and numeric character
/// references in an attribute value.
fn decode_entities(s: &str) -> Result<String, String> {
    if !s.contains('&') {
        return Ok(s.to_string());
    }
    let mut out = String::with_capacity(s.len());
    let mut rest = s;
    while let Some(i) = rest.find('&') {
        out.push_str(&rest[..i]);
        let tail = &rest[i + 1..];
        let semi = tail.find(';').ok_or_else(|| format!("unterminated entity in `{s}`"))?;
        let ent = &tail[..semi];
        match ent {
            "amp" => out.push('&'),
            "lt" => out.push('<'),
            "gt" => out.push('>'),
            "quot" => out.push('"'),
            "apos" => out.push('\''),
            _ => {
                let code = ent
                    .strip_prefix("#x")
                    .map(|h| u32::from_str_radix(h, 16))
                    .or_else(|| ent.strip_prefix('#').map(|d| d.parse::<u32>()))
                    .ok_or_else(|| format!("unknown entity `&{ent};`"))?
                    .map_err(|_| format!("bad character reference `&{ent};`"))?;
                out.push(char::from_u32(code).ok_or_else(|| format!("bad codepoint &{ent};"))?);
            }
        }
        rest = &tail[semi + 1..];
    }
    out.push_str(rest);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn events(src: &str) -> Vec<String> {
        let mut p = Pull::new(src);
        let mut out = Vec::new();
        loop {
            match p.next_event().unwrap() {
                Event::Open { name, attrs, self_closing } => {
                    let a: Vec<String> =
                        attrs.iter().map(|(k, v)| format!("{k}={v}")).collect();
                    out.push(format!(
                        "open {name} [{}]{}",
                        a.join(","),
                        if self_closing { " /" } else { "" }
                    ));
                }
                Event::Close { name } => out.push(format!("close {name}")),
                Event::Eof => break,
            }
        }
        out
    }

    #[test]
    fn pulls_elements_attributes_and_comments() {
        let src = "<?xml version=\"1.0\"?>\n<!-- db -->\n<root>\n  \
                   <instruction asm=\"VADDPD\" string='VADDPD (XMM)'>\n    \
                   <operand type=\"reg\" width=\"128\"/>\n  </instruction>\n</root>\n";
        assert_eq!(
            events(src),
            vec![
                "open root []",
                "open instruction [asm=VADDPD,string=VADDPD (XMM)]",
                "open operand [type=reg,width=128] /",
                "close instruction",
                "close root",
            ]
        );
    }

    #[test]
    fn entities_decode_in_attribute_values() {
        let src = "<a v=\"1 &lt; 2 &amp;&amp; x &gt; 0 &quot;q&quot; &#65;&#x42;\"/>";
        assert_eq!(events(src), vec!["open a [v=1 < 2 && x > 0 \"q\" AB] /"]);
    }

    #[test]
    fn errors_carry_line_numbers_and_never_panic() {
        for (src, needle) in [
            ("<root>\n<unterminated\n", "unterminated tag"),
            ("<root>\n<a b=c/>\n</root>", "unquoted value"),
            ("<root>\n<a b=\"x/>\n", "unterminated value"),
            ("<root>\n<a b=\"&bogus;\"/>\n</root>", "unknown entity"),
            ("<!-- never closed", "unterminated comment"),
            ("<a/>trailing text", "text after the last element"),
            ("<root>\n</>\n", "closing tag with no name"),
        ] {
            let mut p = Pull::new(src);
            let err = loop {
                match p.next_event() {
                    Ok(Event::Eof) => panic!("`{src}` parsed cleanly"),
                    Ok(_) => continue,
                    Err(e) => break e,
                }
            };
            assert!(err.message.contains(needle), "`{src}` -> {err}");
            assert!(err.line >= 1);
        }
    }

    #[test]
    fn error_lines_point_at_the_offending_construct() {
        let mut p = Pull::new("<root>\n<ok/>\n<bad attr=novalue/>\n</root>");
        let mut last = None;
        loop {
            match p.next_event() {
                Ok(Event::Eof) => break,
                Ok(_) => continue,
                Err(e) => {
                    last = Some(e);
                    break;
                }
            }
        }
        assert_eq!(last.unwrap().line, 3);
    }
}
