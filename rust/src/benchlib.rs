//! Minimal criterion-style benchmark harness.
//!
//! The build environment is offline and criterion is not vendored, so
//! `cargo bench` targets (`harness = false`) use this: warm-up, N timed
//! samples, median/mean/stddev, and a one-line report comparable to
//! criterion's. Also provides table-printing helpers used by the
//! per-paper-table bench binaries.

use std::time::{Duration, Instant};

/// One benchmark statistic.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
    /// 10th-percentile sample (fast tail).
    pub p10: Duration,
    /// 90th-percentile sample (slow tail).
    pub p90: Duration,
}

impl Stats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{:>11?} {:>11?} {:>11?}]  ±{:?} ({} samples)",
            self.name, self.min, self.median, self.max, self.stddev, self.samples
        )
    }

    /// Throughput helper: elements per second at the median.
    pub fn per_sec(&self, elements: u64) -> f64 {
        elements as f64 / self.median.as_secs_f64()
    }
}

/// Run `f` repeatedly: warm-up for `warmup`, then collect `samples`
/// timed runs. `f` should perform one complete unit of work.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let median = times[times.len() / 2];
    let total: Duration = times.iter().sum();
    let mean = total / times.len() as u32;
    let mean_s = mean.as_secs_f64();
    let var = times
        .iter()
        .map(|t| {
            let d = t.as_secs_f64() - mean_s;
            d * d
        })
        .sum::<f64>()
        / times.len() as f64;
    let stddev = Duration::from_secs_f64(var.sqrt());
    let percentile = |q: usize| times[(times.len() * q / 100).min(times.len() - 1)];
    Stats {
        name: name.to_string(),
        samples,
        mean,
        median,
        stddev,
        min: times[0],
        max: *times.last().unwrap(),
        p10: percentile(10),
        p90: percentile(90),
    }
}

/// Default sample counts used by the bench binaries.
pub const WARMUP: usize = 3;
pub const SAMPLES: usize = 15;

/// Machine-readable benchmark results: accumulates `Stats` rows (plus
/// derived rates like kernels/s) and serializes them as JSON, so the
/// repo's perf trajectory can be tracked across PRs
/// (`BENCH_hotpath.json`). Hand-rolled serialization — no serde in the
/// offline build; names and rate keys must not contain `"` or `\`.
#[derive(Debug, Default)]
pub struct BenchJson {
    rows: Vec<String>,
}

impl BenchJson {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one benchmark result with named derived rates
    /// (e.g. `[("kernels_per_s", 1.2e6)]`). Names and keys are escaped
    /// and non-finite rates become `null`, so the output always parses.
    pub fn record(&mut self, s: &Stats, rates: &[(&str, f64)]) {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        fn num(v: f64) -> String {
            if v.is_finite() {
                format!("{v:.3}")
            } else {
                "null".to_string()
            }
        }
        let rates_json = rates
            .iter()
            .map(|(k, v)| format!("\"{}\": {}", esc(k), num(*v)))
            .collect::<Vec<_>>()
            .join(", ");
        self.rows.push(format!(
            "    \"{}\": {{\"median_ns\": {}, \"p10_ns\": {}, \"p90_ns\": {}, \
             \"mean_ns\": {}, \"samples\": {}, \"rates\": {{{}}}}}",
            esc(&s.name),
            s.median.as_nanos(),
            s.p10.as_nanos(),
            s.p90.as_nanos(),
            s.mean.as_nanos(),
            s.samples,
            rates_json
        ));
    }

    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"schema\": \"osaca-hotpath-bench-v1\",\n  \"results\": {{\n{}\n  }}\n}}\n",
            self.rows.join(",\n")
        )
    }

    /// Write the accumulated results to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Print a markdown-ish table: header + rows of equal arity.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    print!("{}", format_table(crate::report::emit::Format::Text, title, header, rows));
}

/// Render a generic table in any report format (the CLI's `--format`
/// plumbing for tabular subcommands): text reproduces [`print_table`]'s
/// layout, JSON emits `{"title", "header", "rows"}`, CSV emits header +
/// rows with RFC-4180 escaping (the title is dropped — CSV has no
/// comment syntax).
pub fn format_table(
    format: crate::report::emit::Format,
    title: &str,
    header: &[&str],
    rows: &[Vec<String>],
) -> String {
    use crate::report::emit::{csv_field, json_string, Format};
    match format {
        Format::Text => {
            let mut out = format!("\n== {title} ==\n");
            let ncol = header.len();
            let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
            for r in rows {
                for (i, c) in r.iter().enumerate().take(ncol) {
                    widths[i] = widths[i].max(c.len());
                }
            }
            let fmt_row = |cells: Vec<String>| {
                cells
                    .iter()
                    .enumerate()
                    .map(|(i, c)| format!("{:>w$}", c, w = widths[i.min(ncol - 1)]))
                    .collect::<Vec<_>>()
                    .join(" | ")
            };
            out.push_str(&fmt_row(header.iter().map(|s| s.to_string()).collect()));
            out.push('\n');
            out.push_str(
                &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("-|-"),
            );
            out.push('\n');
            for r in rows {
                out.push_str(&fmt_row(r.clone()));
                out.push('\n');
            }
            out
        }
        Format::Json => {
            let mut out = String::from("{\"title\":");
            out.push_str(&json_string(title));
            out.push_str(",\"header\":[");
            for (i, h) in header.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&json_string(h));
            }
            out.push_str("],\"rows\":[");
            for (i, r) in rows.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('[');
                for (j, c) in r.iter().enumerate() {
                    if j > 0 {
                        out.push(',');
                    }
                    out.push_str(&json_string(c));
                }
                out.push(']');
            }
            out.push_str("]}");
            out
        }
        Format::Csv => {
            let mut out = header.iter().map(|h| csv_field(h)).collect::<Vec<_>>().join(",");
            out.push('\n');
            for r in rows {
                out.push_str(&r.iter().map(|c| csv_field(c)).collect::<Vec<_>>().join(","));
                out.push('\n');
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut n = 0u64;
        let s = bench("noop", 1, 5, || n += 1);
        assert_eq!(s.samples, 5);
        assert_eq!(n, 6);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn per_sec_positive() {
        let s = bench("sleepless", 0, 3, || {
            std::hint::black_box(42);
        });
        assert!(s.per_sec(1000) > 0.0);
    }

    #[test]
    fn percentiles_are_ordered() {
        let s = bench("ordered", 0, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.min <= s.p10);
        assert!(s.p10 <= s.median);
        assert!(s.median <= s.p90);
        assert!(s.p90 <= s.max);
    }

    #[test]
    fn bench_json_shape() {
        let s = bench("group/case", 0, 3, || {
            std::hint::black_box(42);
        });
        let mut j = BenchJson::new();
        j.record(&s, &[("kernels_per_s", 123.456)]);
        let text = j.to_json();
        assert!(text.contains("\"schema\": \"osaca-hotpath-bench-v1\""));
        assert!(text.contains("\"group/case\""));
        assert!(text.contains("\"median_ns\""));
        assert!(text.contains("\"p10_ns\""));
        assert!(text.contains("\"p90_ns\""));
        assert!(text.contains("\"kernels_per_s\": 123.456"));
    }

    #[test]
    fn format_table_covers_all_formats() {
        use crate::report::emit::Format;
        let header = ["a", "b"];
        let rows = vec![vec!["1".to_string(), "x,y".to_string()]];
        let text = format_table(Format::Text, "t", &header, &rows);
        assert!(text.contains("== t =="));
        assert!(text.contains("a | "));
        let json = format_table(Format::Json, "t", &header, &rows);
        assert_eq!(json, "{\"title\":\"t\",\"header\":[\"a\",\"b\"],\"rows\":[[\"1\",\"x,y\"]]}");
        let csv = format_table(Format::Csv, "t", &header, &rows);
        assert_eq!(csv, "a,b\n1,\"x,y\"\n");
    }

    #[test]
    fn bench_json_stays_parseable_on_hostile_input() {
        let mut s = bench("quo\"te\\name", 0, 3, || {
            std::hint::black_box(42);
        });
        s.median = Duration::ZERO; // forces a non-finite derived rate
        let mut j = BenchJson::new();
        j.record(&s, &[("rate", 1.0 / s.median.as_secs_f64())]);
        let text = j.to_json();
        assert!(text.contains("quo\\\"te\\\\name"));
        assert!(text.contains("\"rate\": null"));
    }
}
