//! IACA-like baseline predictor.
//!
//! The paper compares OSACA against Intel's closed-source IACA, which
//! (a) *weighs* ports instead of splitting uniformly ("IACA does not
//! schedule instruction forms with an average probability but weighs
//! specific ports", §III-A) and (b) knows about scheduler shortcuts:
//! zeroing idioms and compare/branch µ-ops that bypass the port
//! scheduler (§III-B). This module reproduces that *shape*: kernels are
//! encoded into the batched port-pressure artifact and solved with the
//! iterative balancing scheduler (L1 Pallas kernel, executed through
//! PJRT — python never runs here), with the shortcut µ-ops dropped.

use anyhow::Result;

use crate::asm::Kernel;
use crate::mdb::MachineModel;
use crate::runtime::{solve_cpu, EncodedKernel, PortSolver, SolveOut};

/// Prediction from the baseline.
#[derive(Debug, Clone)]
pub struct BaselinePrediction {
    /// Balanced-scheduler bottleneck, cy per assembly iteration — the
    /// IACA-like number.
    pub cy_per_asm_iter: f32,
    /// Uniform-split bottleneck from the same artifact run (with the
    /// shortcut µ-ops removed — so it matches the rust analyzer exactly
    /// on kernels without zero idioms or fused compares; integration
    /// tests cross-check PJRT vs the pure-rust solver for parity).
    pub uniform_cy: f32,
    /// Per-port balanced pressure.
    pub port_pressure: Vec<f32>,
}

/// Encode a kernel for the artifact, applying the IACA-style shortcuts:
/// zero idioms and cmp/test+branch pairs carry no port load.
pub fn encode(kernel: &Kernel, machine: &MachineModel) -> Result<EncodedKernel> {
    let mut enc = EncodedKernel::empty();
    let mut row = 0usize;
    // Zen AGU sharing, same rule as the analyzer (Table IV): one load
    // instruction's load-pipe µ-op hides behind each store.
    let mut hideable = if machine.hide_load_behind_store {
        kernel.n_stores().min(kernel.n_loads())
    } else {
        0
    };
    for (i, ins) in kernel.instructions.iter().enumerate() {
        // Fusible branches and zero idioms take the IACA shortcut;
        // AArch64 compare-and-branch forms execute a real µ-op and are
        // encoded like any other instruction (matching the analyzer
        // and `sim::decode`).
        if ins.is_fusible_branch() || ins.is_zero_idiom() {
            continue;
        }
        // cmp/test immediately followed by a conditional branch fuses and
        // takes the "shortcut" through the architecture (§III-B).
        if ins.is_compare() {
            if let Some(next) = kernel.instructions.get(i + 1) {
                if next.is_cond_branch() {
                    continue;
                }
            }
        }
        let hide_this = ins.is_load() && hideable > 0;
        if hide_this {
            hideable -= 1;
        }
        let resolved = machine.resolve(ins)?;
        for u in &resolved.entry.uops {
            if hide_this && u.kind == crate::mdb::UopKind::Load {
                continue;
            }
            // Zen's store-data µ-op drains through the store queue, not
            // an execution pipe (`store_data_free`); the shortcut-aware
            // baseline mirrors the hardware and charges no port for it,
            // while OSACA's analyzer keeps the paper's Table IV
            // convention of counting it.
            if machine.sim_store_data_free && u.kind == crate::mdb::UopKind::StoreData {
                continue;
            }
            let ports: Vec<usize> = u.ports.iter().collect();
            enc.push_uop(row, &ports, u.occupancy)?;
            row += 1;
        }
    }
    Ok(enc)
}

/// Convert one solver output into the baseline's prediction shape
/// (shared with the coordinator and the `api` layer).
pub fn to_prediction(out: &SolveOut) -> BaselinePrediction {
    BaselinePrediction {
        cy_per_asm_iter: out.tp_balanced,
        uniform_cy: out.tp_uniform,
        port_pressure: out.press_balanced.clone(),
    }
}

/// Predict with the AOT artifact (PJRT path).
pub fn predict(kernel: &Kernel, machine: &MachineModel, solver: &PortSolver) -> Result<BaselinePrediction> {
    let enc = encode(kernel, machine)?;
    let out = solver.solve(&[enc])?;
    Ok(to_prediction(&out[0]))
}

/// Predict a batch of kernels in one artifact execution.
pub fn predict_batch(
    kernels: &[&Kernel],
    machine: &MachineModel,
    solver: &PortSolver,
) -> Result<Vec<BaselinePrediction>> {
    let encs: Vec<EncodedKernel> =
        kernels.iter().map(|k| encode(k, machine)).collect::<Result<_>>()?;
    let outs = solver.solve(&encs)?;
    Ok(outs.iter().map(to_prediction).collect())
}

/// Pure-rust fallback (no artifact needed); same math as the L1 kernel.
pub fn predict_cpu(kernel: &Kernel, machine: &MachineModel) -> Result<BaselinePrediction> {
    let enc = encode(kernel, machine)?;
    let out = solve_cpu(&[enc], 32);
    Ok(to_prediction(&out[0]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::extract_kernel;
    use crate::mdb::skylake;
    use crate::workloads;

    #[test]
    fn pi_o2_baseline_sees_4_cycles() {
        // §III-B: IACA predicts 4.00 cy for the -O2 π kernel (shortcut
        // for vxorpd and cmp+jne), where OSACA says 4.25.
        let w = workloads::find("pi", "skl", "-O2").unwrap();
        let p = predict_cpu(&w.kernel(), &skylake()).unwrap();
        assert!((p.cy_per_asm_iter - 4.0).abs() < 0.1, "{}", p.cy_per_asm_iter);
    }

    #[test]
    fn triad_baseline_matches_port_binding() {
        let w = workloads::find("triad", "skl", "-O3").unwrap();
        let p = predict_cpu(&w.kernel(), &skylake()).unwrap();
        // Pure port binding 2.0 cy (paper: IACA 2.00-2.21).
        assert!(p.cy_per_asm_iter >= 1.95 && p.cy_per_asm_iter < 2.3, "{}", p.cy_per_asm_iter);
    }

    #[test]
    fn encode_drops_shortcut_uops() {
        let src = "\n.L1:\nvxorpd %xmm0, %xmm0, %xmm0\ncmpl $10, %eax\njne .L1\n";
        let k = extract_kernel("t", src).unwrap();
        let enc = encode(&k, &skylake()).unwrap();
        assert!(enc.cost.iter().all(|&c| c == 0.0));
    }

    #[test]
    fn unfused_cmp_is_counted() {
        // cmp NOT followed by a branch still takes a port.
        let src = "\n.L1:\ncmpl $10, %eax\naddl $1, %eax\njne .L1\n";
        let k = extract_kernel("t", src).unwrap();
        let enc = encode(&k, &skylake()).unwrap();
        let total: f32 = enc.cost.iter().sum();
        assert!(total >= 2.0 - 1e-6);
    }

    #[test]
    fn balanced_never_exceeds_uniform() {
        for w in workloads::all() {
            let p = predict_cpu(&w.kernel(), &skylake()).unwrap();
            assert!(
                p.cy_per_asm_iter <= p.uniform_cy + 1e-3,
                "{}: {} > {}",
                w.name(),
                p.cy_per_asm_iter,
                p.uniform_cy
            );
        }
    }
}
