//! Benchmark-loop generation.
//!
//! Builds AT&T assembly source text for latency chains, parallelism
//! sweeps and port-conflict probes, mirroring the loops shown in paper
//! §II-A/§II-C. The generated text goes through the ordinary parser and
//! kernel extraction, so benchmarks exercise exactly the same pipeline
//! as user kernels.

use anyhow::{bail, Result};

use crate::isa::InstructionForm;

/// What to benchmark: an instruction form, e.g.
/// `vfmadd132pd-mem_xmm_xmm`.
#[derive(Debug, Clone)]
pub struct BenchSpec {
    pub form: InstructionForm,
}

impl BenchSpec {
    pub fn parse(s: &str) -> Self {
        BenchSpec { form: InstructionForm::parse(s) }
    }

    fn sig_tokens(&self) -> Vec<&str> {
        if self.form.sig.0.is_empty() {
            Vec::new()
        } else {
            self.form.sig.0.split('_').collect()
        }
    }

    /// Register spelling for an operand class and pool index.
    ///
    /// Pools (disjoint by construction so chains never tangle):
    /// * vector: dests 0..=12 -> xmm/ymm 0..12, sources 13..=15;
    /// * GP: dests 0..4 -> r8..r11, sources 13/14 -> r12/r13,
    ///   probe-dests 16..21 -> esi/edi/ebp/r14/r15
    ///   (rax/rbx are memory bases, ecx/edx the loop counter).
    fn reg(&self, tok: &str, idx: usize) -> Result<String> {
        let gp = |idx: usize| -> String {
            const PROBE_POOL: [&str; 5] = ["rsi", "rdi", "rbp", "r14", "r15"];
            if idx >= 16 {
                PROBE_POOL[(idx - 16) % 5].to_string()
            } else if idx >= 13 {
                format!("r{}", 12 + (idx - 13) % 2)
            } else {
                format!("r{}", 8 + idx % 4)
            }
        };
        let gp32 = |idx: usize| -> String {
            const PROBE_POOL: [&str; 5] = ["esi", "edi", "ebp", "r14d", "r15d"];
            if idx >= 16 {
                PROBE_POOL[(idx - 16) % 5].to_string()
            } else if idx >= 13 {
                format!("r{}d", 12 + (idx - 13) % 2)
            } else {
                format!("r{}d", 8 + idx % 4)
            }
        };
        Ok(match tok {
            "xmm" => format!("%xmm{}", idx.min(15)),
            "ymm" => format!("%ymm{}", idx.min(15)),
            "r64" => format!("%{}", gp(idx)),
            "r32" | "r" => format!("%{}", gp32(idx)),
            other => bail!("cannot choose a register for operand class `{other}`"),
        })
    }

    /// Render one instance of the instruction.
    ///
    /// * `dest_idx` — register index of the destination;
    /// * `src_idx` — register index used for the *first* register source
    ///   (the chained one in latency loops);
    /// * `other_idx` — register index for remaining sources.
    fn render(&self, dest_idx: usize, src_idx: usize, other_idx: usize) -> Result<String> {
        let toks = self.sig_tokens();
        if toks.is_empty() {
            return Ok(self.form.mnemonic.clone());
        }
        let n = toks.len();
        let mut ops: Vec<String> = Vec::with_capacity(n);
        let mut first_reg_source = true;
        for (i, tok) in toks.iter().enumerate() {
            let is_dest = i + 1 == n;
            let text = match *tok {
                "mem" => {
                    if is_dest {
                        "(%rbx)".to_string() // store target, loop-invariant
                    } else {
                        "(%rax)".to_string() // load source, loop-invariant
                    }
                }
                "imm" => "$1".to_string(),
                "lbl" => bail!("cannot benchmark branch forms"),
                cls => {
                    if is_dest {
                        self.reg(cls, dest_idx)?
                    } else if first_reg_source {
                        first_reg_source = false;
                        self.reg(cls, src_idx)?
                    } else {
                        self.reg(cls, other_idx)?
                    }
                }
            };
            ops.push(text);
        }
        Ok(format!("{} {}", self.form.mnemonic, ops.join(", ")))
    }
}

const LOOP_OVERHEAD: &str = "addl $1, %ecx\ncmpl %ecx, %edx\njne .Lbench\n";

/// Latency benchmark: `unroll` chained copies (paper §II-A first listing:
/// destination of each instruction is a source of the next).
pub fn latency_loop(spec: &BenchSpec, unroll: usize) -> Result<String> {
    let mut body = String::new();
    for _ in 0..unroll {
        // dest == chained source register 0.
        body.push_str(&spec.render(0, 0, 6)?);
        body.push('\n');
    }
    Ok(format!(".Lbench:\n{body}{LOOP_OVERHEAD}"))
}

/// Parallelism sweep: `chains` independent dependency chains, each
/// `depth` instructions long (paper §II-A second listing: three chains,
/// unrolled; §II-C sweeps 1..12 chains).
pub fn parallel_loop(spec: &BenchSpec, chains: usize, depth: usize) -> Result<String> {
    let mut body = String::new();
    for _ in 0..depth {
        for c in 0..chains {
            body.push_str(&spec.render(c, c, 13)?);
            body.push('\n');
        }
    }
    Ok(format!(".Lbench:\n{body}{LOOP_OVERHEAD}"))
}

/// Fully independent throughput loop ("TP"): destinations rotate over a
/// wide register range, sources are never written.
pub fn throughput_loop(spec: &BenchSpec, width: usize) -> Result<String> {
    let mut body = String::new();
    for c in 0..width {
        // dest rotates 0..width; sources fixed at 13/14 (never written).
        body.push_str(&spec.render(c, 13, 14)?);
        body.push('\n');
    }
    Ok(format!(".Lbench:\n{body}{LOOP_OVERHEAD}"))
}

/// Port-conflict probe (paper §II-B/§II-C): the TP loop of `a`
/// interleaved with instances of `b`, all operands independent.
///
/// `a`'s destinations rotate over the full dest pool (so even forms
/// that read their destination, like FMA, expose enough parallelism);
/// `b` writes the dedicated probe pool (vector: xmm12; GP:
/// esi/edi/ebp/r14/r15) and reads only never-written source registers.
pub fn conflict_loop(a: &BenchSpec, b: &BenchSpec, width: usize) -> Result<String> {
    let mut body = String::new();
    for c in 0..width {
        body.push_str(&a.render(c, c, 14)?);
        body.push('\n');
        body.push_str(&b.render(16 + c % 5, 13, 13)?);
        body.push('\n');
    }
    Ok(format!(".Lbench:\n{body}{LOOP_OVERHEAD}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::extract_kernel;

    #[test]
    fn latency_loop_chains_registers() {
        let spec = BenchSpec::parse("vaddpd-xmm_xmm_xmm");
        let src = latency_loop(&spec, 4).unwrap();
        let k = extract_kernel("lat", &src).unwrap();
        // 4 chained adds + 2 overhead instructions + branch.
        assert_eq!(k.len(), 7);
        // Every vaddpd writes xmm0 and reads xmm0.
        for i in k.instructions.iter().filter(|i| i.mnemonic == "vaddpd") {
            assert!(i.to_string().contains("%xmm0"));
        }
    }

    #[test]
    fn parallel_loop_has_k_chains() {
        let spec = BenchSpec::parse("vaddpd-xmm_xmm_xmm");
        let src = parallel_loop(&spec, 5, 3).unwrap();
        let k = extract_kernel("par", &src).unwrap();
        let adds = k.instructions.iter().filter(|i| i.mnemonic == "vaddpd").count();
        assert_eq!(adds, 15);
    }

    #[test]
    fn mem_form_uses_memory_source() {
        let spec = BenchSpec::parse("vfmadd132pd-mem_xmm_xmm");
        let src = latency_loop(&spec, 1).unwrap();
        assert!(src.contains("vfmadd132pd (%rax), %xmm0, %xmm0"));
    }

    #[test]
    fn branch_forms_rejected() {
        let spec = BenchSpec::parse("jne-lbl");
        assert!(latency_loop(&spec, 1).is_err());
    }

    #[test]
    fn conflict_loop_interleaves() {
        let a = BenchSpec::parse("vfmadd132pd-mem_xmm_xmm");
        let b = BenchSpec::parse("vmulpd-xmm_xmm_xmm");
        let src = conflict_loop(&a, &b, 6).unwrap();
        let k = extract_kernel("conf", &src).unwrap();
        let fmas = k.instructions.iter().filter(|i| i.mnemonic == "vfmadd132pd").count();
        let muls = k.instructions.iter().filter(|i| i.mnemonic == "vmulpd").count();
        assert_eq!(fmas, 6);
        assert_eq!(muls, 6);
    }

    #[test]
    fn store_form_targets_memory() {
        let spec = BenchSpec::parse("vmovapd-xmm_mem");
        let src = throughput_loop(&spec, 4).unwrap();
        assert!(src.contains("vmovapd %xmm13, (%rbx)"), "{src}");
    }
}
