//! Benchmark-loop generation.
//!
//! Builds assembly source text for latency chains, parallelism sweeps
//! and port-conflict probes, mirroring the loops shown in paper
//! §II-A/§II-C. Everything ISA-specific — register pools, memory and
//! immediate spellings, destination position, the counter/branch loop
//! scaffold — comes from the target's [`IsaSyntax`] implementation
//! (`asm::syntax`), so model construction (`--learn`) works for every
//! backend, not just AT&T x86. The generated text goes through the
//! ordinary parser and kernel extraction, so benchmarks exercise
//! exactly the same pipeline as user kernels.

use anyhow::{bail, Result};

use crate::asm::syntax::{syntax_for, IsaSyntax};
use crate::isa::{InstructionForm, Isa};

/// What to benchmark: an instruction form, e.g.
/// `vfmadd132pd-mem_xmm_xmm` (x86), `fadd-d_d_d` (AArch64),
/// `fadd.d-f_f_f` (RISC-V).
#[derive(Debug, Clone)]
pub struct BenchSpec {
    pub form: InstructionForm,
}

impl BenchSpec {
    pub fn parse(s: &str) -> Self {
        BenchSpec { form: InstructionForm::parse(s) }
    }

    fn sig_tokens(&self) -> Vec<&str> {
        if self.form.sig.0.is_empty() {
            Vec::new()
        } else {
            self.form.sig.0.split('_').collect()
        }
    }

    /// Render one instance of the instruction under `syntax`.
    ///
    /// * `dest_idx` — register-pool index of the destination;
    /// * `src_idx` — pool index used for the *first* register source
    ///   (the chained one in latency loops);
    /// * `other_idx` — pool index for remaining sources.
    fn render(
        &self,
        syntax: &dyn IsaSyntax,
        dest_idx: usize,
        src_idx: usize,
        other_idx: usize,
    ) -> Result<String> {
        let toks = self.sig_tokens();
        if toks.is_empty() {
            return Ok(self.form.mnemonic.clone());
        }
        let mnemonic = self.form.mnemonic.as_str();
        let dest_pos = syntax.bench_dest_index(mnemonic, &toks);
        let mut ops: Vec<String> = Vec::with_capacity(toks.len());
        let mut first_reg_source = true;
        for (i, tok) in toks.iter().enumerate() {
            let is_dest = i == dest_pos;
            let text = match *tok {
                "mem" => syntax.bench_mem(is_dest).to_string(),
                "imm" => syntax.bench_imm().to_string(),
                "lbl" => bail!("cannot benchmark branch forms"),
                cls => {
                    let idx = if is_dest {
                        dest_idx
                    } else if first_reg_source {
                        first_reg_source = false;
                        src_idx
                    } else {
                        other_idx
                    };
                    syntax.bench_reg(mnemonic, cls, idx).ok_or_else(|| {
                        anyhow::anyhow!(
                            "cannot choose a {} register for operand class `{cls}`",
                            syntax.isa()
                        )
                    })?
                }
            };
            ops.push(text);
        }
        Ok(format!("{} {}", self.form.mnemonic, ops.join(", ")))
    }
}

fn close_loop(syntax: &dyn IsaSyntax, body: String) -> String {
    format!(".Lbench:\n{body}{}", syntax.bench_loop_overhead())
}

/// Latency benchmark: `unroll` chained copies (paper §II-A first listing:
/// destination of each instruction is a source of the next).
pub fn latency_loop(spec: &BenchSpec, isa: Isa, unroll: usize) -> Result<String> {
    let syntax = syntax_for(isa);
    let mut body = String::new();
    for _ in 0..unroll {
        // dest == chained source register 0.
        body.push_str(&spec.render(syntax, 0, 0, 6)?);
        body.push('\n');
    }
    Ok(close_loop(syntax, body))
}

/// Parallelism sweep: `chains` independent dependency chains, each
/// `depth` instructions long (paper §II-A second listing: three chains,
/// unrolled; §II-C sweeps 1..12 chains).
pub fn parallel_loop(spec: &BenchSpec, isa: Isa, chains: usize, depth: usize) -> Result<String> {
    let syntax = syntax_for(isa);
    let mut body = String::new();
    for _ in 0..depth {
        for c in 0..chains {
            body.push_str(&spec.render(syntax, c, c, 13)?);
            body.push('\n');
        }
    }
    Ok(close_loop(syntax, body))
}

/// Fully independent throughput loop ("TP"): destinations rotate over a
/// wide register range, sources are never written.
pub fn throughput_loop(spec: &BenchSpec, isa: Isa, width: usize) -> Result<String> {
    let syntax = syntax_for(isa);
    let mut body = String::new();
    for c in 0..width {
        // dest rotates 0..width; sources fixed at 13/14 (never written).
        body.push_str(&spec.render(syntax, c, 13, 14)?);
        body.push('\n');
    }
    Ok(close_loop(syntax, body))
}

/// Port-conflict probe (paper §II-B/§II-C): the TP loop of `a`
/// interleaved with instances of `b`, all operands independent.
///
/// `a`'s destinations rotate over the full dest pool (so even forms
/// that read their destination, like FMA, expose enough parallelism);
/// `b` writes the dedicated probe pool and reads only never-written
/// source registers.
pub fn conflict_loop(a: &BenchSpec, b: &BenchSpec, isa: Isa, width: usize) -> Result<String> {
    let syntax = syntax_for(isa);
    let mut body = String::new();
    for c in 0..width {
        body.push_str(&a.render(syntax, c, c, 14)?);
        body.push('\n');
        body.push_str(&b.render(syntax, 16 + c % 5, 13, 13)?);
        body.push('\n');
    }
    Ok(close_loop(syntax, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::{extract_kernel, extract_kernel_isa};

    #[test]
    fn latency_loop_chains_registers() {
        let spec = BenchSpec::parse("vaddpd-xmm_xmm_xmm");
        let src = latency_loop(&spec, Isa::X86, 4).unwrap();
        let k = extract_kernel("lat", &src).unwrap();
        // 4 chained adds + 2 overhead instructions + branch.
        assert_eq!(k.len(), 7);
        // Every vaddpd writes xmm0 and reads xmm0.
        for i in k.instructions.iter().filter(|i| i.mnemonic == "vaddpd") {
            assert!(i.to_string().contains("%xmm0"));
        }
    }

    #[test]
    fn parallel_loop_has_k_chains() {
        let spec = BenchSpec::parse("vaddpd-xmm_xmm_xmm");
        let src = parallel_loop(&spec, Isa::X86, 5, 3).unwrap();
        let k = extract_kernel("par", &src).unwrap();
        let adds = k.instructions.iter().filter(|i| i.mnemonic == "vaddpd").count();
        assert_eq!(adds, 15);
    }

    #[test]
    fn mem_form_uses_memory_source() {
        let spec = BenchSpec::parse("vfmadd132pd-mem_xmm_xmm");
        let src = latency_loop(&spec, Isa::X86, 1).unwrap();
        assert!(src.contains("vfmadd132pd (%rax), %xmm0, %xmm0"));
    }

    #[test]
    fn branch_forms_rejected() {
        let spec = BenchSpec::parse("jne-lbl");
        assert!(latency_loop(&spec, Isa::X86, 1).is_err());
        let spec = BenchSpec::parse("bne-x_x_lbl");
        assert!(latency_loop(&spec, Isa::RiscV, 1).is_err());
    }

    #[test]
    fn conflict_loop_interleaves() {
        let a = BenchSpec::parse("vfmadd132pd-mem_xmm_xmm");
        let b = BenchSpec::parse("vmulpd-xmm_xmm_xmm");
        let src = conflict_loop(&a, &b, Isa::X86, 6).unwrap();
        let k = extract_kernel("conf", &src).unwrap();
        let fmas = k.instructions.iter().filter(|i| i.mnemonic == "vfmadd132pd").count();
        let muls = k.instructions.iter().filter(|i| i.mnemonic == "vmulpd").count();
        assert_eq!(fmas, 6);
        assert_eq!(muls, 6);
    }

    #[test]
    fn store_form_targets_memory() {
        let spec = BenchSpec::parse("vmovapd-xmm_mem");
        let src = throughput_loop(&spec, Isa::X86, 4).unwrap();
        assert!(src.contains("vmovapd %xmm13, (%rbx)"), "{src}");
    }

    #[test]
    fn aarch64_latency_loop_chains_dest_first() {
        // Destination-first chaining: `fadd d0, d0, d6`.
        let spec = BenchSpec::parse("fadd-d_d_d");
        let src = latency_loop(&spec, Isa::AArch64, 2).unwrap();
        assert!(src.contains("fadd d0, d0, d6"), "{src}");
        assert!(src.contains("subs x17, x17, #1"), "{src}");
        let k = extract_kernel_isa("lat", &src, Isa::AArch64).unwrap();
        assert_eq!(k.len(), 4); // 2 chained + subs + b.ne
        assert_eq!(k.isa, Isa::AArch64);
    }

    #[test]
    fn aarch64_store_and_load_forms() {
        // Stores: dest is the memory operand, data register is a source.
        let spec = BenchSpec::parse("str-q_mem");
        let src = throughput_loop(&spec, Isa::AArch64, 2).unwrap();
        assert!(src.contains("str q13, [x11]"), "{src}");
        // Loads: dest-first register, memory source.
        let spec = BenchSpec::parse("ldr-q_mem");
        let src = throughput_loop(&spec, Isa::AArch64, 2).unwrap();
        assert!(src.contains("ldr q0, [x10]"), "{src}");
        assert!(src.contains("ldr q1, [x10]"), "{src}");
    }

    #[test]
    fn riscv_latency_loop_chains_dest_first() {
        let spec = BenchSpec::parse("fadd.d-f_f_f");
        let src = latency_loop(&spec, Isa::RiscV, 2).unwrap();
        assert!(src.contains("fadd.d f0, f0, f6"), "{src}");
        assert!(src.contains("addi t1, t1, 1"), "{src}");
        assert!(src.contains("bne t1, t2, .Lbench"), "{src}");
        let k = extract_kernel_isa("lat", &src, Isa::RiscV).unwrap();
        assert_eq!(k.len(), 4); // 2 chained + addi + bne
        assert_eq!(k.isa, Isa::RiscV);
    }

    #[test]
    fn riscv_store_and_load_forms() {
        let spec = BenchSpec::parse("fsd-f_mem");
        let src = throughput_loop(&spec, Isa::RiscV, 2).unwrap();
        assert!(src.contains("fsd f13, 0(a7)"), "{src}");
        let spec = BenchSpec::parse("ld-x_mem");
        let src = throughput_loop(&spec, Isa::RiscV, 2).unwrap();
        assert!(src.contains("ld t3, 0(a6)"), "{src}");
        assert!(src.contains("ld t4, 0(a6)"), "{src}");
    }

    #[test]
    fn wrong_isa_class_errors() {
        // An x86 class token cannot be rendered on RISC-V and vice
        // versa — a real error, not a silent mis-spelling.
        assert!(latency_loop(&BenchSpec::parse("vaddpd-xmm_xmm_xmm"), Isa::RiscV, 1).is_err());
        assert!(latency_loop(&BenchSpec::parse("fadd.d-f_f_f"), Isa::X86, 1).is_err());
    }
}
