//! Run generated benchmarks on the simulator substrate and report
//! per-instruction cycle counts, in the format of the paper's §II-C
//! ibench output listings.

use anyhow::Result;

use crate::asm::extract_kernel_isa;
use crate::isa::Isa;
use crate::mdb::MachineModel;
use crate::sim::{simulate, SimConfig};

use super::gen::{conflict_loop, latency_loop, parallel_loop, throughput_loop, BenchSpec};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label, e.g. `vfmadd132pd-mem_xmm_xmm-8`.
    pub label: String,
    /// Cycles per instruction of the benchmarked form.
    pub cy_per_instr: f64,
}

/// Full parallelism sweep of one instruction form (paper §II-C listing).
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub form: String,
    /// (chains, cy/instr) for each sweep point.
    pub points: Vec<(usize, f64)>,
    /// TP benchmark (fully independent).
    pub tp: f64,
    /// Latency (single chain, per chained instruction).
    pub latency: f64,
}

impl SweepResult {
    /// Render in the paper's ibench output format.
    pub fn render(&self, freq_ghz: f64) -> String {
        let mut out = format!("Using frequency {freq_ghz:.2}GHz.\n");
        out.push_str(&format!(
            "{}-1:  {:>7.3} (clk cy)\n",
            self.form, self.latency
        ));
        for (k, cy) in &self.points {
            out.push_str(&format!("{}-{}:  {:>7.3} (clk cy)\n", self.form, k, cy));
        }
        out.push_str(&format!("{}-TP:  {:>7.3} (clk cy)\n", self.form, self.tp));
        out
    }
}

fn sim_cy_per_instr(src: &str, machine: &MachineModel, n_instr: usize) -> Result<f64> {
    let kernel = extract_kernel_isa("bench", src, machine.isa)?;
    let m = simulate(&kernel, machine, SimConfig { iterations: 400, warmup: 100 })?;
    Ok(m.cycles_per_iteration / n_instr as f64)
}

/// Measure the latency of an instruction form (single chain).
pub fn measure_latency(spec: &BenchSpec, machine: &MachineModel) -> Result<f64> {
    let unroll = 4;
    let src = latency_loop(spec, machine.isa, unroll)?;
    sim_cy_per_instr(&src, machine, unroll)
}

/// Measure reciprocal throughput (fully independent TP loop).
pub fn measure_throughput(spec: &BenchSpec, machine: &MachineModel) -> Result<f64> {
    let width = 12;
    let src = throughput_loop(spec, machine.isa, width)?;
    sim_cy_per_instr(&src, machine, width)
}

/// Run one named benchmark variant.
pub fn run_bench(spec: &BenchSpec, machine: &MachineModel, chains: usize) -> Result<BenchResult> {
    let depth = (24 / chains).max(2);
    let src = parallel_loop(spec, machine.isa, chains, depth)?;
    let cy = sim_cy_per_instr(&src, machine, chains * depth)?;
    Ok(BenchResult { label: format!("{}-{}", spec.form, chains), cy_per_instr: cy })
}

/// Write the generated benchmark family for one instruction form to
/// `dir` as `.s` files (the layout of the paper's artifact repository):
/// `<form>-lat.s`, `<form>-<k>.s` for each sweep point, `<form>-TP.s`.
/// Returns the file paths written.
pub fn emit_bench_files(
    spec: &BenchSpec,
    isa: Isa,
    dir: &std::path::Path,
) -> Result<Vec<std::path::PathBuf>> {
    std::fs::create_dir_all(dir)?;
    let mut written = Vec::new();
    let base = spec.form.to_string().replace(['/', ' '], "_");
    let mut emit = |suffix: &str, body: String| -> Result<()> {
        let path = dir.join(format!("{base}-{suffix}.s"));
        std::fs::write(&path, body)?;
        written.push(path);
        Ok(())
    };
    emit("lat", latency_loop(spec, isa, 4)?)?;
    for k in [2usize, 4, 5, 8, 10, 12] {
        emit(&k.to_string(), parallel_loop(spec, isa, k, (24 / k).max(2))?)?;
    }
    emit("TP", throughput_loop(spec, isa, 12)?)?;
    Ok(written)
}

/// The §II-C parallelism sweep: k ∈ {2,4,5,8,10,12} plus latency and TP.
pub fn run_sweep(spec: &BenchSpec, machine: &MachineModel) -> Result<SweepResult> {
    let latency = measure_latency(spec, machine)?;
    let mut points = Vec::new();
    for k in [2usize, 4, 5, 8, 10, 12] {
        let r = run_bench(spec, machine, k)?;
        points.push((k, r.cy_per_instr));
    }
    let tp = measure_throughput(spec, machine)?;
    Ok(SweepResult { form: spec.form.to_string(), points, tp, latency })
}

/// Port-conflict probe: cy per A-instruction when interleaved 1:1 with B
/// (paper §II-B). Compare against A's own TP to detect sharing.
pub fn run_conflict(
    a: &BenchSpec,
    b: &BenchSpec,
    machine: &MachineModel,
) -> Result<BenchResult> {
    // Width 10: enough chains that even a 5-cycle-latency FMA is
    // throughput-bound (paper §II-C sweeps to 10-12 for the same reason).
    let width = 10;
    let src = conflict_loop(a, b, machine.isa, width)?;
    let cy = sim_cy_per_instr(&src, machine, width)?;
    Ok(BenchResult { label: format!("{}-TP-{}", a.form, b.form.mnemonic), cy_per_instr: cy })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mdb::{skylake, zen};

    #[test]
    fn vaddpd_latency_matches_paper() {
        // §II-A: 4 cy on Skylake, 3 cy on Zen.
        let spec = BenchSpec::parse("vaddpd-xmm_xmm_xmm");
        let skl = measure_latency(&spec, &skylake()).unwrap();
        assert!((skl - 4.0).abs() < 0.2, "{skl}");
        let z = measure_latency(&spec, &zen()).unwrap();
        assert!((z - 3.0).abs() < 0.2, "{z}");
    }

    #[test]
    fn vaddpd_throughput_is_half_cycle() {
        // §II-A: rTP 0.5 on both architectures (two ports).
        let spec = BenchSpec::parse("vaddpd-xmm_xmm_xmm");
        for m in [skylake(), zen()] {
            let tp = measure_throughput(&spec, &m).unwrap();
            assert!((tp - 0.5).abs() < 0.1, "{}: {tp}", m.name);
        }
    }

    #[test]
    fn fma_mem_sweep_matches_paper_zen() {
        // §II-C Zen listing: lat 5, k=2 -> 2.5, k=5 -> ~1.0, TP -> 0.5.
        let spec = BenchSpec::parse("vfmadd132pd-mem_xmm_xmm");
        let sweep = run_sweep(&spec, &zen()).unwrap();
        assert!((sweep.latency - 5.0).abs() < 0.3, "lat {}", sweep.latency);
        let k2 = sweep.points.iter().find(|(k, _)| *k == 2).unwrap().1;
        assert!((k2 - 2.5).abs() < 0.3, "k2 {k2}");
        let k10 = sweep.points.iter().find(|(k, _)| *k == 10).unwrap().1;
        assert!((k10 - 0.5).abs() < 0.15, "k10 {k10}");
        assert!((sweep.tp - 0.5).abs() < 0.1, "tp {}", sweep.tp);
    }

    #[test]
    fn conflict_detects_shared_fma_mul_on_zen() {
        // §II-C: vmulpd cannot be hidden behind vfmadd132pd (both FP0/1:
        // combined ~1.0 cy), vaddpd can (FP2/3: combined ~0.5 cy).
        let fma = BenchSpec::parse("vfmadd132pd-mem_xmm_xmm");
        let mul = BenchSpec::parse("vmulpd-xmm_xmm_xmm");
        let add = BenchSpec::parse("vaddpd-xmm_xmm_xmm");
        let zen_m = zen();
        let with_mul = run_conflict(&fma, &mul, &zen_m).unwrap();
        let with_add = run_conflict(&fma, &add, &zen_m).unwrap();
        assert!(with_mul.cy_per_instr > 0.85, "mul {}", with_mul.cy_per_instr);
        assert!(with_add.cy_per_instr < 0.7, "add {}", with_add.cy_per_instr);
    }

    #[test]
    fn conflict_on_skl_shows_shared_ports_for_both() {
        // §II-C Skylake: both vaddpd and vmulpd share P0/P1 with FMA ->
        // both combined runs land at ~1.0 cy.
        let fma = BenchSpec::parse("vfmadd132pd-mem_xmm_xmm");
        let mul = BenchSpec::parse("vmulpd-xmm_xmm_xmm");
        let add = BenchSpec::parse("vaddpd-xmm_xmm_xmm");
        let skl = skylake();
        let with_mul = run_conflict(&fma, &mul, &skl).unwrap();
        let with_add = run_conflict(&fma, &add, &skl).unwrap();
        assert!(with_mul.cy_per_instr > 0.85, "mul {}", with_mul.cy_per_instr);
        assert!(with_add.cy_per_instr > 0.85, "add {}", with_add.cy_per_instr);
    }

    #[test]
    fn emit_bench_files_roundtrip() {
        let spec = BenchSpec::parse("vaddpd-xmm_xmm_xmm");
        let dir = std::env::temp_dir().join(format!("osaca-ibench-{}", std::process::id()));
        let files = emit_bench_files(&spec, Isa::X86, &dir).unwrap();
        assert_eq!(files.len(), 8); // lat + 6 sweep points + TP
        // Every emitted file parses and simulates.
        for f in &files {
            let src = std::fs::read_to_string(f).unwrap();
            let k = crate::asm::extract_kernel("emitted", &src).unwrap();
            let m = simulate(&k, &skylake(), SimConfig { iterations: 50, warmup: 10 }).unwrap();
            assert!(m.cycles_per_iteration > 0.0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tx2_fadd_latency_and_tp_measured() {
        // The ISA-generic generator drives the AArch64 substrate: fadd
        // latency 6 cy, rTP 0.5 (two symmetric FP pipes).
        let m = crate::mdb::thunderx2();
        let spec = BenchSpec::parse("fadd-d_d_d");
        let lat = measure_latency(&spec, &m).unwrap();
        assert!((lat - 6.0).abs() < 0.3, "{lat}");
        let tp = measure_throughput(&spec, &m).unwrap();
        assert!((tp - 0.5).abs() < 0.1, "{tp}");
    }

    #[test]
    fn rv64_fadd_latency_and_tp_measured() {
        // Single F pipe: latency 5 cy, rTP 1.0.
        let m = crate::mdb::rv64();
        let spec = BenchSpec::parse("fadd.d-f_f_f");
        let lat = measure_latency(&spec, &m).unwrap();
        assert!((lat - 5.0).abs() < 0.3, "{lat}");
        let tp = measure_throughput(&spec, &m).unwrap();
        assert!((tp - 1.0).abs() < 0.15, "{tp}");
    }

    #[test]
    fn divider_rtp_measured() {
        let spec = BenchSpec::parse("vdivsd-xmm_xmm_xmm");
        let tp = measure_throughput(&spec, &skylake()).unwrap();
        assert!((tp - 4.0).abs() < 0.3, "{tp}");
    }
}
