//! ibench-style micro-benchmark generation and execution (paper §II-A/B,
//! citing Hofmann's ibench [21]).
//!
//! Generates the three benchmark families of the paper and runs them on
//! the simulator substrate:
//!
//! * **latency**: one dependency chain — destination of each instruction
//!   feeds the next;
//! * **throughput / parallelism sweep**: k independent chains for
//!   k ∈ {1, 2, 4, 5, 8, 10, 12} plus a fully independent "TP" variant
//!   (the paper's `vfmadd132pd-xmm_xmm_mem-{k}` output);
//! * **port conflict** (§II-B): a throughput-bound loop of instruction A
//!   interleaved with instruction B — if the combined reciprocal
//!   throughput exceeds A's own, A and B share a port.
//!
//! Loop emission is ISA-generic: register pools, operand spellings and
//! the counter/branch scaffold come from the target's
//! [`crate::asm::IsaSyntax`], so the same machinery benchmarks x86,
//! AArch64 and RISC-V models (`--learn` on every backend).

pub mod gen;
pub mod runner;

pub use gen::{conflict_loop, latency_loop, parallel_loop, throughput_loop, BenchSpec};
pub use runner::{
    measure_latency, measure_throughput, run_bench, run_conflict, run_sweep, BenchResult,
    SweepResult,
};
