//! Tiny property-testing support (proptest is not vendored in this
//! offline environment): a deterministic splittable PRNG plus a
//! `for_cases` driver that reports the failing seed.

/// xorshift64* — deterministic, seedable, no dependencies.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform usize in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Run `prop` for `cases` seeded cases; panic with the seed on failure
/// so the case can be replayed exactly.
pub fn for_cases<F: FnMut(&mut Rng)>(cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64.wrapping_mul(case + 1);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_is_inclusive() {
        let mut r = Rng::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..200 {
            let v = r.range(2, 4);
            assert!((2..=4).contains(&v));
            seen_lo |= v == 2;
            seen_hi |= v == 4;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    #[should_panic]
    fn for_cases_propagates_failure() {
        for_cases(5, |rng| {
            assert!(rng.below(10) < 5, "intentional failure");
        });
    }
}
