//! Cross-request memoization: `(kernel, model, config) → Arc<report>`.
//!
//! The key is [`crate::api::AnalysisRequest::fingerprint`] — it covers
//! everything analysis-relevant and excludes the presentation-only
//! `name`/`format` fields, so differently-labelled requests for the
//! same analysis share one slot. The value is a shared
//! [`AnalysisReport`] whose `prediction_cell` the server fills once at
//! insert time: every hit clones the report (cheap — the sections are
//! small and the decomposition rides behind the `Arc`), patches the
//! presentation fields from the incoming request, and renders.
//!
//! Bounded true-LRU: a `HashMap` into a slab-backed doubly-linked
//! recency list. `get` promotes to the front, `insert` evicts the tail
//! once `cap` entries are resident. All operations are O(1); the server
//! holds the lock only for the map operation, never across an analysis.
//!
//! Doubly bounded: by entry count (`cap`) and, when `max_bytes > 0`, by
//! an approximate resident byte total so a flood of large kernels
//! cannot balloon memory past `--memo-max-bytes`. Each entry carries a
//! caller-supplied `cost` (the server uses the rendered report length
//! as the proxy — the dominant retained allocation); inserts evict from
//! the LRU tail until the budget holds, and an entry costlier than the
//! whole budget is simply never cached.

use std::collections::HashMap;
use std::sync::Arc;

use crate::api::AnalysisReport;

const NIL: usize = usize::MAX;

struct Slot {
    key: u64,
    value: Arc<AnalysisReport>,
    cost: usize,
    prev: usize,
    next: usize,
}

/// Bounded LRU over analysis fingerprints. `cap == 0` disables
/// memoization (every lookup misses, nothing is retained);
/// `max_bytes == 0` means no byte bound (entry cap only).
pub struct MemoCache {
    cap: usize,
    max_bytes: usize,
    bytes: usize,
    map: HashMap<u64, usize>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
}

impl MemoCache {
    pub fn new(cap: usize, max_bytes: usize) -> Self {
        MemoCache {
            cap,
            max_bytes,
            bytes: 0,
            map: HashMap::with_capacity(cap.min(1024)),
            slots: Vec::with_capacity(cap.min(1024)),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Approximate resident bytes (sum of entry costs).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up a fingerprint; a hit is promoted to most-recent.
    pub fn get(&mut self, key: u64) -> Option<Arc<AnalysisReport>> {
        let &i = self.map.get(&key)?;
        self.unlink(i);
        self.link_front(i);
        Some(self.slots[i].value.clone())
    }

    /// Insert (or replace) an entry, evicting least-recently-used ones
    /// until both the entry cap and the byte budget hold.
    pub fn insert(&mut self, key: u64, value: Arc<AnalysisReport>, cost: usize) {
        if self.cap == 0 {
            return;
        }
        if self.max_bytes > 0 && cost > self.max_bytes {
            // Larger than the whole budget: caching it would immediately
            // evict everything else and then itself — never admit it.
            return;
        }
        if let Some(&i) = self.map.get(&key) {
            self.bytes = self.bytes - self.slots[i].cost + cost;
            self.slots[i].value = value;
            self.slots[i].cost = cost;
            self.unlink(i);
            self.link_front(i);
        } else {
            if self.map.len() >= self.cap {
                self.evict_tail();
            }
            let slot = Slot { key, value, cost, prev: NIL, next: NIL };
            let i = match self.free.pop() {
                Some(i) => {
                    self.slots[i] = slot;
                    i
                }
                None => {
                    self.slots.push(slot);
                    self.slots.len() - 1
                }
            };
            self.bytes += cost;
            self.map.insert(key, i);
            self.link_front(i);
        }
        // Terminates: the entry just linked costs <= max_bytes, so at
        // worst it ends up alone within budget.
        while self.max_bytes > 0 && self.bytes > self.max_bytes {
            self.evict_tail();
        }
    }

    fn evict_tail(&mut self) {
        let lru = self.tail;
        debug_assert_ne!(lru, NIL);
        self.unlink(lru);
        self.bytes -= self.slots[lru].cost;
        self.map.remove(&self.slots[lru].key);
        self.free.push(lru);
    }

    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.slots[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.slots[next].prev = prev;
        }
        self.slots[i].prev = NIL;
        self.slots[i].next = NIL;
    }

    fn link_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Engine, Passes};

    fn report(name: &str) -> Arc<AnalysisReport> {
        let engine = Engine::cpu_only();
        let req = Engine::request(name)
            .arch("skl")
            .source(".L1:\naddl $1, %eax\njne .L1\n")
            .passes(Passes::THROUGHPUT);
        Arc::new(engine.analyze(&req).unwrap())
    }

    #[test]
    fn evicts_least_recently_used() {
        let r = report("m");
        let mut c = MemoCache::new(2, 0);
        c.insert(1, r.clone(), 10);
        c.insert(2, r.clone(), 10);
        assert!(c.get(1).is_some()); // promote 1; 2 is now LRU
        c.insert(3, r.clone(), 10);
        assert_eq!(c.len(), 2);
        assert!(c.get(2).is_none(), "2 was least recently used");
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
    }

    #[test]
    fn replace_promotes_and_keeps_len() {
        let r = report("m");
        let mut c = MemoCache::new(2, 0);
        c.insert(1, r.clone(), 10);
        c.insert(2, r.clone(), 10);
        c.insert(1, r.clone(), 10); // replace, promote
        c.insert(3, r.clone(), 10); // evicts 2
        assert!(c.get(1).is_some());
        assert!(c.get(2).is_none());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn zero_capacity_disables() {
        let r = report("m");
        let mut c = MemoCache::new(0, 0);
        c.insert(1, r, 10);
        assert!(c.is_empty());
        assert!(c.get(1).is_none());
    }

    #[test]
    fn byte_budget_evicts_in_lru_order() {
        let r = report("m");
        // Budget fits two 10-cost entries but not three.
        let mut c = MemoCache::new(8, 25);
        c.insert(1, r.clone(), 10);
        c.insert(2, r.clone(), 10);
        assert_eq!(c.bytes(), 20);
        assert!(c.get(1).is_some()); // promote 1; 2 is now LRU
        c.insert(3, r.clone(), 10);
        assert_eq!(c.len(), 2);
        assert_eq!(c.bytes(), 20);
        assert!(c.get(2).is_none(), "byte eviction must follow LRU order");
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
    }

    #[test]
    fn one_giant_entry_evicts_everything_smaller() {
        let r = report("m");
        let mut c = MemoCache::new(8, 30);
        c.insert(1, r.clone(), 5);
        c.insert(2, r.clone(), 5);
        c.insert(3, r.clone(), 28); // fits the budget alone, nothing else does
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 28);
        assert!(c.get(3).is_some());
    }

    #[test]
    fn over_budget_entry_is_never_admitted() {
        let r = report("m");
        let mut c = MemoCache::new(8, 30);
        c.insert(1, r.clone(), 10);
        c.insert(2, r.clone(), 31); // costs more than the whole budget
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 10);
        assert!(c.get(2).is_none());
        assert!(c.get(1).is_some(), "the resident entry must survive the rejected insert");
    }

    #[test]
    fn replace_adjusts_byte_gauge() {
        let r = report("m");
        let mut c = MemoCache::new(8, 100);
        c.insert(1, r.clone(), 10);
        c.insert(1, r.clone(), 40);
        assert_eq!(c.bytes(), 40);
        c.insert(1, r.clone(), 5);
        assert_eq!(c.bytes(), 5);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn hits_share_one_prediction_decomposition() {
        let r = report("shared");
        r.prediction_shared(); // fill the cell before insert, like the server
        let mut c = MemoCache::new(4, 0);
        c.insert(9, r, 10);
        let a = c.get(9).unwrap();
        // A hit clones the report (to patch name/format); the clone's
        // decomposition must still be the same allocation.
        let patched = (*a).clone();
        assert!(Arc::ptr_eq(&a.prediction_shared(), &patched.prediction_shared()));
    }
}
