//! A small hand-rolled JSON reader for the serve wire format.
//!
//! serde is not vendored in the offline build (DESIGN.md §5), so the
//! emitters hand-write JSON and this module hand-reads it. It is a
//! strict recursive-descent parser over the full JSON grammar — objects,
//! arrays, strings with escapes (including `\uXXXX` surrogate pairs),
//! numbers, booleans, null — sized for one request frame at a time, not
//! for streaming documents. Public so the integration tests parse the
//! server's response frames with the same reader the server uses for
//! requests.

use std::fmt;

/// One parsed JSON value. Object fields keep their wire order (the
/// frame contract makes `report` the *last* key of an ok frame, and
/// keeping order lets tests assert that through this type).
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric field as an exact non-negative integer (`None` for
    /// negatives, fractions, or anything beyond 2^53).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9_007_199_254_740_992.0 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse failure with a byte offset into the frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid JSON at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse one complete JSON document; trailing non-whitespace is an
/// error (a frame is exactly one value).
pub fn parse(input: &str) -> Result<JsonValue, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data after JSON value"));
    }
    Ok(v)
}

/// Nesting bound: the recursive-descent parser consumes stack per
/// container level, so a hostile frame of ten thousand `[`s must be
/// rejected, not allowed to overflow the connection thread's stack. No
/// legitimate request or response frame nests deeper than ~6 levels.
const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> JsonError {
        JsonError { offset: self.pos, message: message.to_string() }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting deeper than 64 levels"));
        }
        Ok(())
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<JsonValue, JsonError> {
        match self.peek() {
            Some(b'{') => {
                self.enter()?;
                let v = self.object();
                self.depth -= 1;
                v
            }
            Some(b'[') => {
                self.enter()?;
                let v = self.array();
                self.depth -= 1;
                v
            }
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, value: JsonValue) -> Result<JsonValue, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<JsonValue, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(fields));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: the low half follows
                                // as a second \uXXXX escape.
                                if self.peek() != Some(b'\\') {
                                    return Err(self.err("lone high surrogate"));
                                }
                                self.pos += 1;
                                if self.peek() != Some(b'u') {
                                    return Err(self.err("lone high surrogate"));
                                }
                                self.pos += 1;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(cp).ok_or_else(|| self.err("invalid code point"))?
                            } else {
                                char::from_u32(hi).ok_or_else(|| self.err("invalid code point"))?
                            };
                            out.push(c);
                            // hex4 leaves pos past the digits; skip the
                            // shared `pos += 1` below.
                            continue;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 scalar (frames are valid UTF-8 by
                    // construction: they arrive via from_utf8_lossy).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("invalid UTF-8"))?;
                    let c = s.chars().next().expect("non-empty");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = match self.peek() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a' + 10) as u32,
                Some(c @ b'A'..=b'F') => (c - b'A' + 10) as u32,
                _ => return Err(self.err("expected 4 hex digits")),
            };
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<JsonValue, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii number");
        text.parse::<f64>()
            .map(JsonValue::Num)
            .map_err(|_| JsonError { offset: start, message: "invalid number".into() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_emitter_shapes() {
        let v = parse(
            "{\"schema_version\":2,\"ok\":true,\"x\":null,\"arr\":[1,2.5,-3],\
             \"nested\":{\"s\":\"a b\"}}",
        )
        .unwrap();
        assert_eq!(v.get("schema_version").unwrap().as_u64(), Some(2));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("x"), Some(&JsonValue::Null));
        let arr = v.get("arr").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert_eq!(v.get("nested").unwrap().get("s").unwrap().as_str(), Some("a b"));
    }

    #[test]
    fn string_escapes_round_trip() {
        let mut encoded = String::new();
        crate::report::emit::push_json_string(&mut encoded, "a\"b\\c\nd\te\u{1}");
        let v = parse(&encoded).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\nd\te\u{1}"));
        // Surrogate pair for U+1F600.
        assert_eq!(parse("\"\\ud83d\\ude00\"").unwrap().as_str(), Some("😀"));
    }

    #[test]
    fn rejects_malformed_frames() {
        for bad in ["", "{", "{\"a\":}", "[1,]", "\"unterminated", "{\"a\":1} trailing", "nul"] {
            assert!(parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn nesting_is_bounded() {
        // Reasonable nesting parses fine...
        let ten = format!("{}1{}", "[".repeat(10), "]".repeat(10));
        assert!(parse(&ten).is_ok());
        // ...but a hostile deeply-nested frame is a structured error,
        // not a stack overflow.
        let hostile = format!("{}1{}", "[".repeat(10_000), "]".repeat(10_000));
        let e = parse(&hostile).unwrap_err();
        assert!(e.message.contains("nesting"), "{}", e.message);
        let hostile_obj = format!("{}1{}", "{\"k\":".repeat(200), "}".repeat(200));
        assert!(parse(&hostile_obj).is_err());
    }

    #[test]
    fn as_u64_is_exact() {
        assert_eq!(parse("18").unwrap().as_u64(), Some(18));
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("-1").unwrap().as_u64(), None);
    }
}
