//! Deterministic fault injection for the serving layer (`--chaos`).
//!
//! Every failure mode the robustness layer defends against must be
//! reproducible, not theoretical: a [`FaultPlan`] derives a fault
//! decision for each worker dispatch from a seed and a dispatch
//! counter via splitmix64, so the same seed always produces the same
//! fault sequence. Tests pin specific fault classes by searching the
//! seed space with the pure [`FaultPlan::fault_for`] (no server
//! needed), then boot a server with that seed and assert the exact
//! wire frames and counters.
//!
//! Server-side faults ([`Fault`]) are injected at the shard-worker
//! dispatch point — the single choke point every analyze job passes
//! through. Client-side wire noise ([`WireNoise`]) is drawn from the
//! same generator by the chaos test client (torn writes, oversized
//! frames, blank lines); the server cannot inject those against
//! itself.
//!
//! The module is always compiled (it is a few integer hashes), but a
//! plan is only constructed when `ServeConfig::chaos_seed` is set —
//! the `--chaos` CLI flag, gated the same way as `--test-ops`: never
//! in production configurations.

use std::sync::atomic::{AtomicU64, Ordering};

/// Seed used by a bare `--chaos` flag (any explicit value overrides).
pub const DEFAULT_CHAOS_SEED: u64 = 0x05AC_A001;

/// A server-side fault, injected at worker dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Panic inside the supervised analysis region: exercises
    /// catch_unwind, the `internal_error` frame and the engine rebuild.
    Panic,
    /// Sleep after computing the reply but before sending it:
    /// exercises the connection-side reply timeout.
    DelayReply { ms: u64 },
    /// Sleep before processing: the job occupies its queue slot longer,
    /// exercising backpressure, deadlines and load shed.
    StallQueue { ms: u64 },
}

/// Client-side wire noise, drawn by the chaos smoke client from the
/// same seeded stream (the noise happens on the sending side; the
/// server proves it tolerates it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireNoise {
    /// Interleave a blank line before the frame.
    BlankLine,
    /// Terminate the frame with `\r\n` instead of `\n`.
    CrLf,
    /// Split the frame into two writes with a pause between them.
    Torn,
}

/// The seeded fault schedule: one decision per worker dispatch,
/// derived purely from `(seed, dispatch_index)`.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    dispatches: AtomicU64,
}

/// splitmix64 finalizer — a well-mixed 64-bit hash (public domain
/// constants from Steele et al.), used as a pure function of
/// `seed ^ f(index)` rather than as advancing generator state so any
/// dispatch index can be inspected independently.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, dispatches: AtomicU64::new(0) }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The fault (if any) for the next worker dispatch; advances the
    /// dispatch counter.
    pub fn next_dispatch(&self) -> Option<Fault> {
        let n = self.dispatches.fetch_add(1, Ordering::Relaxed);
        Self::fault_for(self.seed, n)
    }

    /// Pure schedule lookup: the fault injected at dispatch `n` under
    /// `seed`. 3 in 8 dispatches fault (one class each); the rest run
    /// clean, so a chaotic server still makes progress.
    pub fn fault_for(seed: u64, n: u64) -> Option<Fault> {
        let h = splitmix64(seed ^ n.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        match h % 8 {
            0 => Some(Fault::Panic),
            1 => Some(Fault::DelayReply { ms: 20 + (h >> 16) % 60 }),
            2 => Some(Fault::StallQueue { ms: 40 + (h >> 16) % 80 }),
            _ => None,
        }
    }

    /// Pure schedule lookup for client-side wire noise at frame `n`
    /// (one class in 2 frames is noisy — noise is harmless by
    /// contract, so a denser schedule costs nothing).
    pub fn noise_for(seed: u64, n: u64) -> Option<WireNoise> {
        let h = splitmix64(seed ^ 0xC0FE ^ n.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        match h % 6 {
            0 => Some(WireNoise::BlankLine),
            1 => Some(WireNoise::CrLf),
            2 => Some(WireNoise::Torn),
            _ => None,
        }
    }

    /// Smallest seed whose dispatch-0 fault satisfies `pred` — how
    /// tests pin a specific fault class deterministically without
    /// hardcoding magic seeds next to the hash function.
    pub fn find_seed(pred: impl Fn(Option<Fault>) -> bool) -> u64 {
        (0u64..1_000_000)
            .find(|s| pred(Self::fault_for(*s, 0)))
            .expect("fault class unreachable in 1e6 seeds — schedule distribution broken")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic() {
        let a: Vec<Option<Fault>> = (0..64).map(|n| FaultPlan::fault_for(42, n)).collect();
        let b: Vec<Option<Fault>> = (0..64).map(|n| FaultPlan::fault_for(42, n)).collect();
        assert_eq!(a, b);
        let plan = FaultPlan::new(42);
        let via_plan: Vec<Option<Fault>> = (0..64).map(|_| plan.next_dispatch()).collect();
        assert_eq!(a, via_plan, "next_dispatch must walk the same pure schedule");
    }

    #[test]
    fn every_fault_class_is_reachable() {
        let faults: Vec<Fault> = (0..512).filter_map(|n| FaultPlan::fault_for(7, n)).collect();
        assert!(faults.contains(&Fault::Panic));
        assert!(faults.iter().any(|f| matches!(f, Fault::DelayReply { .. })));
        assert!(faults.iter().any(|f| matches!(f, Fault::StallQueue { .. })));
        // Clean dispatches dominate (5 in 8) so progress is guaranteed.
        let clean = (0..512).filter(|&n| FaultPlan::fault_for(7, n).is_none()).count();
        assert!(clean > 512 / 2, "only {clean}/512 dispatches were clean");
    }

    #[test]
    fn find_seed_pins_each_class() {
        let s = FaultPlan::find_seed(|f| f == Some(Fault::Panic));
        assert_eq!(FaultPlan::fault_for(s, 0), Some(Fault::Panic));
        let s = FaultPlan::find_seed(|f| matches!(f, Some(Fault::DelayReply { .. })));
        assert!(matches!(FaultPlan::fault_for(s, 0), Some(Fault::DelayReply { .. })));
        let s = FaultPlan::find_seed(|f| f.is_none());
        assert_eq!(FaultPlan::fault_for(s, 0), None);
    }

    #[test]
    fn delays_are_bounded() {
        for n in 0..2048 {
            match FaultPlan::fault_for(3, n) {
                Some(Fault::DelayReply { ms }) => assert!((20..80).contains(&ms), "{ms}"),
                Some(Fault::StallQueue { ms }) => assert!((40..120).contains(&ms), "{ms}"),
                _ => {}
            }
        }
    }

    #[test]
    fn noise_classes_are_reachable() {
        let noise: Vec<WireNoise> = (0..256).filter_map(|n| FaultPlan::noise_for(5, n)).collect();
        assert!(noise.contains(&WireNoise::BlankLine));
        assert!(noise.contains(&WireNoise::CrLf));
        assert!(noise.contains(&WireNoise::Torn));
    }
}
