//! Serving counters, exposed through the wire `stats` op.
//!
//! Plain relaxed atomics — the counters are monotonic event counts with
//! no cross-counter invariant to protect, so a `stats` snapshot taken
//! mid-request may observe e.g. a memo miss whose analysis has not yet
//! been counted. That is fine for an introspection surface; tests
//! quiesce the server before asserting.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::report::emit::StatsFrame;

/// Counters kept by the serve layer (the per-shard engines keep their
/// own solver-side `ServiceStats` underneath).
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Analyze-op responses sent: ok + error + overloaded +
    /// rate_limited + shed. Stats, shutdown and test-op responses are
    /// not "served analyses".
    pub served: AtomicU64,
    /// Analyze requests answered from the cross-request memo.
    pub memo_hits: AtomicU64,
    /// Analyze requests that missed the memo.
    pub memo_misses: AtomicU64,
    /// Analyses actually executed by an engine (misses that got to run).
    pub analyses: AtomicU64,
    /// Error frames sent (includes internal_error and
    /// deadline_exceeded, which also bump their dedicated counters).
    pub errors: AtomicU64,
    /// Overloaded (backpressure) frames sent, shedding or not.
    pub overloaded: AtomicU64,
    /// rate_limited frames sent (token bucket or in-flight cap).
    pub rate_limited: AtomicU64,
    /// Analyze misses rejected because the server was in shed mode.
    pub shed: AtomicU64,
    /// Requests dropped at dispatch because their deadline had expired.
    pub deadline_expired: AtomicU64,
    /// Frames rejected for exceeding the configured length bound.
    pub oversized_frames: AtomicU64,
}

impl ServeMetrics {
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot into the schema-versioned wire frame. The memo gauges,
    /// per-shard queue gauges, the shed flag and the supervision
    /// counters (`panics`/`worker_restarts`, owned by the
    /// `exec::ExecStats` of the worker pool since the executor
    /// unification) live outside this struct and are passed in by the
    /// server — the wire shape is unchanged.
    pub fn frame(
        &self,
        memo_len: u64,
        memo_bytes: u64,
        queue_depths: Vec<u64>,
        shedding: bool,
        panics: u64,
        worker_restarts: u64,
        model_reloads: u64,
    ) -> StatsFrame {
        StatsFrame {
            served: self.served.load(Ordering::Relaxed),
            memo_hits: self.memo_hits.load(Ordering::Relaxed),
            memo_misses: self.memo_misses.load(Ordering::Relaxed),
            analyses: self.analyses.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            overloaded: self.overloaded.load(Ordering::Relaxed),
            rate_limited: self.rate_limited.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            panics,
            worker_restarts,
            oversized_frames: self.oversized_frames.load(Ordering::Relaxed),
            model_reloads,
            memo_len,
            memo_bytes,
            shedding,
            queue_depths,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_carries_every_counter() {
        let m = ServeMetrics::default();
        ServeMetrics::bump(&m.served);
        ServeMetrics::bump(&m.served);
        ServeMetrics::bump(&m.memo_hits);
        ServeMetrics::bump(&m.errors);
        ServeMetrics::bump(&m.rate_limited);
        ServeMetrics::bump(&m.shed);
        ServeMetrics::bump(&m.deadline_expired);
        ServeMetrics::bump(&m.oversized_frames);
        let f = m.frame(3, 4096, vec![0, 2], true, 1, 1, 2);
        assert_eq!(f.served, 2);
        assert_eq!(f.memo_hits, 1);
        assert_eq!(f.memo_misses, 0);
        assert_eq!(f.errors, 1);
        assert_eq!(f.rate_limited, 1);
        assert_eq!(f.shed, 1);
        assert_eq!(f.deadline_expired, 1);
        assert_eq!(f.panics, 1);
        assert_eq!(f.worker_restarts, 1);
        assert_eq!(f.oversized_frames, 1);
        assert_eq!(f.model_reloads, 2);
        assert_eq!(f.memo_len, 3);
        assert_eq!(f.memo_bytes, 4096);
        assert!(f.shedding);
        assert_eq!(f.queue_depths, vec![0, 2]);
        let rendered = f.render();
        assert!(rendered.contains("\"served\":2"));
        assert!(rendered.contains("\"worker_restarts\":1"));
        assert!(rendered.contains("\"model_reloads\":2"));
        assert!(rendered.contains("\"memo_bytes\":4096"));
        assert!(rendered.contains("\"shedding\":true"));
        assert!(rendered.contains("\"queue_depths\":[0,2]"));
    }
}
