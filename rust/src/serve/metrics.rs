//! Serving counters, exposed through the wire `stats` op.
//!
//! Plain relaxed atomics — the counters are monotonic event counts with
//! no cross-counter invariant to protect, so a `stats` snapshot taken
//! mid-request may observe e.g. a memo miss whose analysis has not yet
//! been counted. That is fine for an introspection surface; tests
//! quiesce the server before asserting.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::report::emit::StatsFrame;

/// Counters kept by the serve layer (the per-shard engines keep their
/// own solver-side `ServiceStats` underneath).
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Analyze-op responses sent: ok + error + overloaded. Stats,
    /// shutdown and test-op responses are not "served analyses".
    pub served: AtomicU64,
    /// Analyze requests answered from the cross-request memo.
    pub memo_hits: AtomicU64,
    /// Analyze requests that missed the memo.
    pub memo_misses: AtomicU64,
    /// Analyses actually executed by an engine (misses that got to run).
    pub analyses: AtomicU64,
    /// Error frames sent.
    pub errors: AtomicU64,
    /// Overloaded (backpressure) frames sent.
    pub overloaded: AtomicU64,
}

impl ServeMetrics {
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot into the schema-versioned wire frame. The memo length
    /// and per-shard queue gauges live outside this struct and are
    /// passed in by the server.
    pub fn frame(&self, memo_len: u64, queue_depths: Vec<u64>) -> StatsFrame {
        StatsFrame {
            served: self.served.load(Ordering::Relaxed),
            memo_hits: self.memo_hits.load(Ordering::Relaxed),
            memo_misses: self.memo_misses.load(Ordering::Relaxed),
            analyses: self.analyses.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            overloaded: self.overloaded.load(Ordering::Relaxed),
            memo_len,
            queue_depths,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_carries_every_counter() {
        let m = ServeMetrics::default();
        ServeMetrics::bump(&m.served);
        ServeMetrics::bump(&m.served);
        ServeMetrics::bump(&m.memo_hits);
        ServeMetrics::bump(&m.errors);
        let f = m.frame(3, vec![0, 2]);
        assert_eq!(f.served, 2);
        assert_eq!(f.memo_hits, 1);
        assert_eq!(f.memo_misses, 0);
        assert_eq!(f.errors, 1);
        assert_eq!(f.memo_len, 3);
        assert_eq!(f.queue_depths, vec![0, 2]);
        let rendered = f.render();
        assert!(rendered.contains("\"served\":2"));
        assert!(rendered.contains("\"queue_depths\":[0,2]"));
    }
}
