//! `osaca::serve` — a persistent, sharded analysis service.
//!
//! The batch CLI pays the whole pipeline cost per invocation: process
//! start, model registry construction, solver-thread spin-up, then one
//! analysis. This module keeps all of that alive behind a TCP listener
//! speaking newline-delimited, schema-versioned JSON frames (the
//! request grammar is documented in [`wire`]; response frames are built
//! by `report::emit` so the whole machine-readable surface shares one
//! [`crate::report::emit::SCHEMA_VERSION`] policy).
//!
//! Architecture (DESIGN.md §9):
//!
//! * **Shards.** `ServeConfig::shards` long-lived [`Engine`]s, each
//!   with its own solver coordinator and bounded job queue. Requests
//!   route by `hash(arch) % shards`, so every model family lands on a
//!   stable shard and its coordinator batches same-model solver work.
//!   Built-in machine models are shared process-wide through the `mdb`
//!   Arc cache, so shards do not duplicate model memory.
//! * **Memoization.** A bounded LRU ([`memo::MemoCache`]) keyed by
//!   [`AnalysisRequest::fingerprint`] — everything analysis-relevant,
//!   nothing presentation-only. The cached value is an
//!   `Arc<AnalysisReport>` whose `prediction_cell` is filled once at
//!   insert; every hit clones the report, patches `name`/`format` from
//!   the incoming request, and renders — sharing one bound
//!   decomposition across all hits.
//! * **Backpressure.** Connection threads `try_send` into the target
//!   shard's bounded queue. A full queue answers immediately with a
//!   structured `overloaded` frame (shard index + current gauge)
//!   instead of blocking the connection or buffering unboundedly.
//! * **Timeouts.** Each queued request waits at most
//!   `ServeConfig::reply_timeout` (the same knob as the coordinator's
//!   solver reply timeout) for its shard worker; expiry produces a
//!   `solver_timeout` error frame. Reply channels are fresh per request
//!   (not pooled like the coordinator's): a timed-out connection drops
//!   its receiver and the worker's late `try_send` fails harmlessly,
//!   so a stale reply can never be delivered to a later request.
//! * **Drain.** Wire `shutdown` (or [`Server::shutdown`]) flips a flag
//!   and wakes the accept loop with a self-connection. [`Server::join`]
//!   then joins the accept thread, joins every connection thread
//!   (in-flight replies complete first — the shard workers are still
//!   alive), closes the shard queues, and joins the workers, which
//!   drain whatever was already queued before exiting. Nothing accepted
//!   is dropped on the floor.
//! * **Introspection.** The wire `stats` op snapshots
//!   [`metrics::ServeMetrics`] (served / memo hits / errors /
//!   overloaded), the memo length and the per-shard queue gauges into a
//!   schema-versioned frame.

pub mod json;
pub mod memo;
pub mod metrics;
pub mod wire;

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::api::{AnalysisRequest, Backend, Engine, Format};
use crate::coordinator::CoordinatorConfig;
use crate::report::emit::{bye_frame, error_frame, ok_frame, overloaded_frame};

use memo::MemoCache;
use metrics::ServeMetrics;
use wire::WireRequest;

/// Server tunables.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address (`host:port`; port 0 picks an ephemeral port —
    /// read it back with [`Server::local_addr`]).
    pub addr: String,
    /// Number of engine shards (≥ 1).
    pub shards: usize,
    /// Cross-request memo capacity (entries; 0 disables memoization).
    pub memo_cap: usize,
    /// Bounded per-shard job queue depth (≥ 1); a full queue produces
    /// `overloaded` frames.
    pub queue_depth: usize,
    /// Per-request reply timeout (also forwarded to each shard
    /// engine's solver coordinator).
    pub reply_timeout: Duration,
    /// Solver backend for the shard engines.
    pub backend: Backend,
    /// Enable test-only wire ops (`sleep`) that exist so integration
    /// tests can shape server load deterministically. Never enable in
    /// production configurations.
    pub test_ops: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7117".to_string(),
            shards: 2,
            memo_cap: 256,
            queue_depth: 64,
            reply_timeout: CoordinatorConfig::default().reply_timeout,
            backend: Backend::Auto,
            test_ops: false,
        }
    }
}

/// One engine shard: a long-lived [`Engine`] plus its bounded job
/// queue and a queued+in-flight gauge.
struct Shard {
    engine: Engine,
    /// `None` once the server is draining; taken by [`Server::join`]
    /// so the worker's `recv` loop ends after the queue empties.
    tx: Mutex<Option<SyncSender<Job>>>,
    /// Jobs accepted but not yet fully processed (queued + in-flight).
    queued: AtomicU64,
}

/// State shared by the accept loop, connection threads and shard
/// workers.
struct Shared {
    shards: Vec<Shard>,
    metrics: ServeMetrics,
    memo: Mutex<MemoCache>,
    shutdown: AtomicBool,
    conns: Mutex<Vec<JoinHandle<()>>>,
    reply_timeout: Duration,
    test_ops: bool,
    addr: SocketAddr,
}

impl Shared {
    fn initiate_shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake the accept loop; the dummy connection is dropped there.
        let _ = TcpStream::connect(self.addr);
    }
}

/// A shard job. Replies travel over a fresh 1-slot channel per request
/// so timeouts cannot leak a reply into a later request.
enum Job {
    Analyze { req: AnalysisRequest, key: u64, reply: SyncSender<String> },
    Sleep { ms: u64, reply: SyncSender<String> },
}

/// The running service. Bind with [`Server::bind`], stop with a wire
/// `shutdown` frame or [`Server::shutdown`], and wait for the drain
/// with [`Server::join`].
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind the listener and start the accept loop and shard workers.
    pub fn bind(cfg: ServeConfig) -> io::Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let n = cfg.shards.max(1);
        let mut rxs: Vec<Receiver<Job>> = Vec::with_capacity(n);
        let mut shards: Vec<Shard> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = mpsc::sync_channel(cfg.queue_depth.max(1));
            rxs.push(rx);
            shards.push(Shard {
                engine: Engine::builder()
                    .backend(cfg.backend)
                    .reply_timeout(cfg.reply_timeout)
                    .build(),
                tx: Mutex::new(Some(tx)),
                queued: AtomicU64::new(0),
            });
        }
        let shared = Arc::new(Shared {
            shards,
            metrics: ServeMetrics::default(),
            memo: Mutex::new(MemoCache::new(cfg.memo_cap)),
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            reply_timeout: cfg.reply_timeout,
            test_ops: cfg.test_ops,
            addr,
        });
        let workers = rxs
            .into_iter()
            .enumerate()
            .map(|(i, rx)| {
                let s = shared.clone();
                thread::Builder::new()
                    .name(format!("osaca-serve-shard{i}"))
                    .spawn(move || shard_worker(&s, i, rx))
                    .expect("spawn shard worker")
            })
            .collect();
        let accept = {
            let s = shared.clone();
            thread::Builder::new()
                .name("osaca-serve-accept".to_string())
                .spawn(move || accept_loop(&s, listener))
                .expect("spawn accept loop")
        };
        Ok(Server { shared, addr, accept: Some(accept), workers })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Programmatic equivalent of the wire `shutdown` op.
    pub fn shutdown(&self) {
        self.shared.initiate_shutdown();
    }

    /// Block until the server has shut down and fully drained: accept
    /// loop gone, every connection answered, every queued job
    /// processed, every worker joined. Without a `shutdown` trigger
    /// this serves forever — the CLI's foreground mode.
    pub fn join(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // The accept loop is gone, so the conns vector only shrinks
        // from here; loop in case a connection was being registered
        // while we took the first batch.
        loop {
            let conns: Vec<JoinHandle<()>> =
                std::mem::take(&mut *self.shared.conns.lock().expect("conns"));
            if conns.is_empty() {
                break;
            }
            for c in conns {
                let _ = c.join();
            }
        }
        for shard in &self.shared.shards {
            shard.tx.lock().expect("shard tx").take();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Forced teardown (join() leaves nothing for this to do): flip
        // the flag so conn threads and the accept loop exit, then
        // drain as usual.
        self.shared.initiate_shutdown();
        self.drain();
    }
}

/// Stable shard routing: FNV-1a over the lower-cased arch name. Every
/// model family maps to one shard, so its solver work batches together
/// and its engine's model registry stays hot.
fn shard_index(arch: &str, shards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in arch.bytes() {
        h ^= b.to_ascii_lowercase() as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % shards.max(1) as u64) as usize
}

fn accept_loop(shared: &Arc<Shared>, listener: TcpListener) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if shared.shutdown.load(Ordering::Relaxed) {
                    // The wake-up self-connection (or a late client);
                    // drop it and stop accepting.
                    return;
                }
                let s = shared.clone();
                let handle = thread::Builder::new()
                    .name("osaca-serve-conn".to_string())
                    .spawn(move || handle_conn(s, stream))
                    .expect("spawn connection thread");
                shared.conns.lock().expect("conns").push(handle);
            }
            Err(_) => {
                if shared.shutdown.load(Ordering::Relaxed) {
                    return;
                }
            }
        }
    }
}

/// Outcome of a non-blocking queue submission.
enum Submit {
    Queued,
    Full(u64),
    Closed,
}

fn submit(shared: &Shared, idx: usize, job: Job) -> Submit {
    let shard = &shared.shards[idx];
    let guard = shard.tx.lock().expect("shard tx");
    let Some(tx) = guard.as_ref() else {
        return Submit::Closed;
    };
    // Gauge counts queued + in-flight: incremented here, decremented by
    // the worker after it finishes the job (rolled back on rejection).
    shard.queued.fetch_add(1, Ordering::Relaxed);
    match tx.try_send(job) {
        Ok(()) => Submit::Queued,
        Err(TrySendError::Full(_)) => {
            let depth = shard.queued.fetch_sub(1, Ordering::Relaxed) - 1;
            Submit::Full(depth)
        }
        Err(TrySendError::Disconnected(_)) => {
            shard.queued.fetch_sub(1, Ordering::Relaxed);
            Submit::Closed
        }
    }
}

fn handle_conn(shared: Arc<Shared>, mut stream: TcpStream) {
    // Short read timeout: the read loop polls the shutdown flag between
    // attempts, so idle connections notice a drain within ~100ms.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut buf: Vec<u8> = Vec::new();
    while let Some(line) = read_frame(&mut stream, &mut buf, &shared.shutdown) {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let response = match wire::parse_request(line, shared.test_ops) {
            Err(e) => {
                // Malformed frame: structured error, connection stays
                // open. Not counted as "served" — we cannot even tell
                // which op it was.
                ServeMetrics::bump(&shared.metrics.errors);
                error_frame(e.kind, &e.message)
            }
            Ok(WireRequest::Stats) => {
                let memo_len = shared.memo.lock().expect("memo").len() as u64;
                let depths =
                    shared.shards.iter().map(|s| s.queued.load(Ordering::Relaxed)).collect();
                shared.metrics.frame(memo_len, depths).render()
            }
            Ok(WireRequest::Shutdown) => {
                let _ = write_frame(&mut stream, &bye_frame());
                shared.initiate_shutdown();
                return;
            }
            Ok(WireRequest::Sleep { ms }) => {
                let (rtx, rrx) = mpsc::sync_channel(1);
                match submit(&shared, 0, Job::Sleep { ms, reply: rtx }) {
                    Submit::Queued => rrx
                        .recv_timeout(shared.reply_timeout + Duration::from_millis(ms))
                        .unwrap_or_else(|_| {
                            error_frame("solver_timeout", "sleep reply timed out")
                        }),
                    Submit::Full(depth) => overloaded_frame(0, depth),
                    Submit::Closed => error_frame("service_unavailable", "server is draining"),
                }
            }
            Ok(WireRequest::Analyze(req)) => {
                let idx = shard_index(&req.arch, shared.shards.len());
                let key = req.fingerprint();
                let (rtx, rrx) = mpsc::sync_channel(1);
                let resp = match submit(&shared, idx, Job::Analyze { req, key, reply: rtx }) {
                    Submit::Queued => match rrx.recv_timeout(shared.reply_timeout) {
                        Ok(frame) => frame,
                        Err(_) => {
                            ServeMetrics::bump(&shared.metrics.errors);
                            error_frame(
                                "solver_timeout",
                                &format!("no reply within {:?}", shared.reply_timeout),
                            )
                        }
                    },
                    Submit::Full(depth) => {
                        ServeMetrics::bump(&shared.metrics.overloaded);
                        overloaded_frame(idx, depth)
                    }
                    Submit::Closed => {
                        ServeMetrics::bump(&shared.metrics.errors);
                        error_frame("service_unavailable", "server is draining")
                    }
                };
                ServeMetrics::bump(&shared.metrics.served);
                resp
            }
        };
        if !write_frame(&mut stream, &response) {
            return;
        }
    }
}

/// Read one newline-terminated frame, polling the shutdown flag
/// between read attempts. Returns `None` on connection close, IO
/// error, or drain.
fn read_frame(stream: &mut TcpStream, buf: &mut Vec<u8>, shutdown: &AtomicBool) -> Option<String> {
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = buf.drain(..=pos).collect();
            let mut line = String::from_utf8_lossy(&line[..line.len() - 1]).into_owned();
            if line.ends_with('\r') {
                line.pop();
            }
            return Some(line);
        }
        if shutdown.load(Ordering::Relaxed) {
            return None;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return None,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                continue
            }
            Err(_) => return None,
        }
    }
}

fn write_frame(stream: &mut TcpStream, frame: &str) -> bool {
    stream.write_all(frame.as_bytes()).and_then(|()| stream.write_all(b"\n")).is_ok()
}

fn shard_worker(shared: &Shared, index: usize, rx: Receiver<Job>) {
    // `recv` fails once the server takes the shard's sender; every job
    // queued before that is still delivered first, which is exactly the
    // graceful-drain contract.
    while let Ok(job) = rx.recv() {
        match job {
            Job::Analyze { req, key, reply } => {
                let frame = analyze_job(shared, index, req, key);
                // A timed-out connection dropped its receiver; the
                // failed send is the intended outcome then.
                let _ = reply.try_send(frame);
            }
            Job::Sleep { ms, reply } => {
                thread::sleep(Duration::from_millis(ms));
                let _ = reply.try_send(ok_frame(Format::Text, false, "slept"));
            }
        }
        shared.shards[index].queued.fetch_sub(1, Ordering::Relaxed);
    }
}

fn analyze_job(shared: &Shared, index: usize, req: AnalysisRequest, key: u64) -> String {
    if let Some(hit) = shared.memo.lock().expect("memo").get(key) {
        ServeMetrics::bump(&shared.metrics.memo_hits);
        // The fingerprint excludes presentation fields, so patch them
        // from this request before rendering. The clone shares the
        // cached report's Arc'd prediction decomposition.
        let mut patched = (*hit).clone();
        patched.name = req.name;
        patched.format = req.format;
        return ok_frame(patched.format, true, &patched.render());
    }
    ServeMetrics::bump(&shared.metrics.memo_misses);
    ServeMetrics::bump(&shared.metrics.analyses);
    match shared.shards[index].engine.analyze(&req) {
        Ok(report) => {
            let format = report.format;
            let arc = Arc::new(report);
            // Fill the shared decomposition once, before the report
            // becomes visible to other requests.
            arc.prediction_shared();
            let rendered = arc.render();
            shared.memo.lock().expect("memo").insert(key, arc);
            ok_frame(format, false, &rendered)
        }
        Err(e) => {
            // Failures are not memoized: a registered-later model or a
            // transient solver problem should not pin an error.
            ServeMetrics::bump(&shared.metrics.errors);
            error_frame(e.kind_name(), &e.to_string())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_routing_is_stable_and_case_insensitive() {
        for arches in [["skl", "SKL"], ["zen", "Zen"], ["rv64", "RV64"]] {
            assert_eq!(shard_index(arches[0], 4), shard_index(arches[1], 4));
        }
        // Different families spread (not all on one shard for the
        // built-ins we ship).
        let idx: Vec<usize> =
            ["skl", "zen", "hsw", "tx2", "rv64"].iter().map(|a| shard_index(a, 4)).collect();
        assert!(idx.iter().any(|&i| i != idx[0]), "built-ins all collided: {idx:?}");
        // Single shard degenerates safely.
        assert_eq!(shard_index("skl", 1), 0);
        assert_eq!(shard_index("skl", 0), 0);
    }

    #[test]
    fn config_defaults_are_documented_values() {
        let c = ServeConfig::default();
        assert_eq!(c.shards, 2);
        assert_eq!(c.memo_cap, 256);
        assert_eq!(c.queue_depth, 64);
        assert!(!c.test_ops);
    }
}
