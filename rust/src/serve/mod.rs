//! `osaca::serve` — a persistent, sharded analysis service.
//!
//! The batch CLI pays the whole pipeline cost per invocation: process
//! start, model registry construction, solver-thread spin-up, then one
//! analysis. This module keeps all of that alive behind a TCP listener
//! speaking newline-delimited, schema-versioned JSON frames (the
//! request grammar is documented in [`wire`]; response frames are built
//! by `report::emit` so the whole machine-readable surface shares one
//! [`crate::report::emit::SCHEMA_VERSION`] policy).
//!
//! Architecture (DESIGN.md §9–§11):
//!
//! * **Shards on the executor.** One [`crate::exec::Executor`] with
//!   `ServeConfig::shards` workers, each owning a long-lived [`Engine`]
//!   built inside its thread. Requests route by `hash(arch) % shards`
//!   as a *submit hint*: every model family lands on a stable home
//!   deque, so same-model solver work batches together and that
//!   engine's FormIndex/model registry stays hot — but the hint is not
//!   an assignment. An idle worker steals queued jobs cross-shard
//!   instead of sitting out a hot-arch burst (the steal counters in
//!   the exec stats make this observable). Built-in machine models are
//!   shared process-wide through the `mdb` Arc cache, so shards do not
//!   duplicate model memory.
//! * **Supervision** lives in the executor (DESIGN.md §11): every job
//!   runs under `catch_unwind`; a panic poisons only that request (a
//!   structured `internal_error` frame whose message is redacted to a
//!   category — panic payloads are not a wire surface), the worker's
//!   engine is rebuilt fresh before the error is answered, and the
//!   executor's `panics`/`worker_restarts` counters (re-exported into
//!   the wire `stats` frame) count the event. Reply channels are
//!   per-request, so a request whose worker died mid-flight times out
//!   like any other late reply — nothing deadlocks on a dead worker.
//! * **Memoization.** A doubly bounded LRU ([`memo::MemoCache`]) keyed
//!   by [`AnalysisRequest::fingerprint`] — capped by entries
//!   (`memo_cap`) and resident bytes (`memo_max_bytes`), so a flood of
//!   large kernels cannot balloon memory. The cached value is an
//!   `Arc<AnalysisReport>` whose `prediction_cell` is filled once at
//!   insert; every hit clones the report, patches `name`/`format` from
//!   the incoming request, and renders — sharing one bound
//!   decomposition across all hits.
//! * **Fairness.** Each connection carries a token bucket
//!   ([`limits::TokenBucket`], `--max-rps`/`--burst`) and an in-flight
//!   cap (`--max-inflight`), answered with `rate_limited` frames that
//!   carry a `retry_after_ms` hint — one greedy client cannot
//!   monopolize the bounded queues. An `analyze` may carry
//!   `deadline_ms`; if it has not reached a worker by then it is
//!   answered `deadline_exceeded` instead of being analyzed late.
//! * **Backpressure and shed.** Connection threads `try_submit` into
//!   the home worker's bounded deque; a full deque answers a structured
//!   `overloaded` frame immediately (the executor's `Submit::Full`
//!   contract). Under total saturation (every queue slot and worker
//!   busy, with hysteresis) the server enters shed mode: new `analyze`
//!   misses are rejected up front with `overloaded`+`shedding:true`,
//!   while `stats` and memo hits still answer — the degradation ladder
//!   trades throughput for introspection, never the reverse.
//! * **Fault injection.** `--chaos` arms a seeded deterministic
//!   schedule ([`faults::FaultPlan`]) that injects worker panics,
//!   reply delays and queue stalls at the dispatch choke point, so
//!   every failure mode above is reproducible in tests (and in the CI
//!   chaos smoke leg) rather than theoretical.
//! * **Timeouts.** Each queued request waits at most
//!   `ServeConfig::reply_timeout` (the same knob as the coordinator's
//!   solver reply timeout) for a worker; expiry produces a
//!   `solver_timeout` error frame. Reply channels are fresh per request
//!   (not pooled like the coordinator's): a timed-out connection drops
//!   its receiver and the worker's late `try_send` fails harmlessly,
//!   so a stale reply can never be delivered to a later request.
//! * **Wire robustness.** Frames longer than `max_frame_bytes` are
//!   answered with a `frame_too_large` error and skipped without
//!   unbounded buffering or killing the connection; blank lines and
//!   `\r\n` terminators are tolerated; request nesting is bounded by
//!   the JSON reader.
//! * **Drain.** Wire `shutdown` (or [`Server::shutdown`]) flips a flag
//!   and wakes the accept loop with a self-connection. [`Server::join`]
//!   then joins the accept thread, joins every connection thread
//!   (in-flight replies complete first — the workers are still alive),
//!   then closes and joins the executor, whose workers drain whatever
//!   was already queued before exiting. Nothing accepted is dropped on
//!   the floor.
//! * **Dynamic models.** `--models-dir` scans user `.mdb` files into
//!   the process-wide model registry at bind time; the wire
//!   `reload_models` op re-scans the same directory without a restart.
//!   Because the registry is process-global, new and updated models
//!   become visible to every shard (including panic-rebuilt engines)
//!   immediately, and the `stats` frame's `model_reloads` counter
//!   records completed scans.
//! * **Introspection.** The wire `stats` op snapshots
//!   [`metrics::ServeMetrics`] (served / memo hits / errors /
//!   overloaded / rate_limited / shed / deadline_expired /
//!   oversized_frames) plus the executor's supervision counters
//!   (panics / worker_restarts), the memo entry and byte gauges, the
//!   per-worker home-queue gauges and the shed flag into a
//!   schema-versioned frame — byte-identical keys to the pre-executor
//!   shape.

pub mod faults;
pub mod json;
pub mod limits;
pub mod memo;
pub mod metrics;
pub mod wire;

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::api::{AnalysisRequest, Backend, Engine, Format};
use crate::coordinator::CoordinatorConfig;
use crate::exec::{self, Executor};
use crate::report::emit::{bye_frame, error_frame, ok_frame, overloaded_frame, rate_limited_frame};

use faults::{Fault, FaultPlan};
use limits::TokenBucket;
use memo::MemoCache;
use metrics::ServeMetrics;
use wire::WireRequest;

/// `retry_after_ms` hint on in-flight-cap rejections: the client's own
/// outstanding request bounds the wait, so a short constant beats
/// guessing the analysis latency.
const RETRY_INFLIGHT_MS: u64 = 50;

/// Server tunables.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address (`host:port`; port 0 picks an ephemeral port —
    /// read it back with [`Server::local_addr`]).
    pub addr: String,
    /// Number of engine shards — executor workers (≥ 1).
    pub shards: usize,
    /// Cross-request memo capacity (entries; 0 disables memoization).
    pub memo_cap: usize,
    /// Cross-request memo byte budget (approximate resident bytes;
    /// 0 means entry-capped only).
    pub memo_max_bytes: usize,
    /// Bounded per-shard job queue depth (≥ 1); a full queue produces
    /// `overloaded` frames.
    pub queue_depth: usize,
    /// Per-request reply timeout (also forwarded to each shard
    /// engine's solver coordinator).
    pub reply_timeout: Duration,
    /// Solver backend for the shard engines.
    pub backend: Backend,
    /// Per-connection admitted analyze rate (tokens/second; 0 disables
    /// rate limiting).
    pub max_rps: f64,
    /// Token-bucket burst: analyzes admitted back-to-back before the
    /// rate applies (clamped ≥ 1 when limiting is on).
    pub burst: u32,
    /// Per-connection in-flight analyze cap (0 disables): a connection
    /// with this many analyzes queued or running is told to retry.
    pub max_inflight: usize,
    /// Maximum accepted frame length in bytes; longer lines answer a
    /// `frame_too_large` error and are skipped.
    pub max_frame_bytes: usize,
    /// Shed-mode entry threshold on the summed queued+in-flight gauge
    /// (0 = auto: total gauge capacity, i.e. shed only at full
    /// saturation).
    pub shed_high: usize,
    /// Shed-mode exit threshold (0 = auto: a quarter of capacity);
    /// clamped below `shed_high` so the hysteresis is real.
    pub shed_low: usize,
    /// Enable test-only wire ops (`sleep`, `panic`) that exist so
    /// integration tests can shape and fault server load
    /// deterministically. Never enable in production configurations.
    pub test_ops: bool,
    /// Seeded deterministic fault injection (`--chaos`): worker
    /// panics, reply delays and queue stalls per
    /// [`faults::FaultPlan`]. Never enable in production
    /// configurations.
    pub chaos_seed: Option<u64>,
    /// Directory of user `.mdb` models (`--models-dir`): scanned into
    /// the process-wide dynamic registry at bind time and again on
    /// every `reload_models` wire op. `None` disables the op.
    pub models_dir: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7117".to_string(),
            shards: 2,
            memo_cap: 256,
            memo_max_bytes: 0,
            queue_depth: 64,
            reply_timeout: CoordinatorConfig::default().reply_timeout,
            backend: Backend::Auto,
            max_rps: 0.0,
            burst: 8,
            max_inflight: 0,
            max_frame_bytes: 1 << 20,
            shed_high: 0,
            shed_low: 0,
            test_ops: false,
            chaos_seed: None,
            models_dir: None,
        }
    }
}

/// State shared by the accept loop, connection threads and executor
/// jobs.
struct Shared {
    /// The shard worker pool: one worker per shard, each owning an
    /// [`Engine`] built (and rebuilt after panics) inside its thread.
    exec: Executor<Engine>,
    metrics: ServeMetrics,
    memo: Mutex<MemoCache>,
    shutdown: AtomicBool,
    conns: Mutex<Vec<JoinHandle<()>>>,
    reply_timeout: Duration,
    max_rps: f64,
    burst: u32,
    max_inflight: u64,
    max_frame_bytes: usize,
    shed_high: u64,
    shed_low: u64,
    shedding: AtomicBool,
    chaos: Option<FaultPlan>,
    test_ops: bool,
    models_dir: Option<String>,
    addr: SocketAddr,
}

impl Shared {
    fn initiate_shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake the accept loop; the dummy connection is dropped there.
        let _ = TcpStream::connect(self.addr);
    }

    /// Memo lock, tolerant of poisoning: the memo is plain data with no
    /// cross-field invariant a panicking holder could have broken
    /// half-way (every mutation completes or the entry is absent), and
    /// the supervision story is that one panic never takes the cache
    /// down with it.
    fn lock_memo(&self) -> MutexGuard<'_, MemoCache> {
        self.memo.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Shed-mode state with hysteresis over the summed queued+in-flight
    /// gauge: enter at `shed_high`, leave at `shed_low`. Evaluated on
    /// the request path (no dedicated sampler thread) — under the loads
    /// where shedding matters, requests arrive constantly.
    fn shed_state(&self) -> bool {
        let total: u64 = self.exec.queue_depths().iter().sum();
        if self.shedding.load(Ordering::Relaxed) {
            if total <= self.shed_low {
                self.shedding.store(false, Ordering::Relaxed);
                return false;
            }
            true
        } else {
            if total >= self.shed_high {
                self.shedding.store(true, Ordering::Relaxed);
                return true;
            }
            false
        }
    }
}

/// The running service. Bind with [`Server::bind`], stop with a wire
/// `shutdown` frame or [`Server::shutdown`], and wait for the drain
/// with [`Server::join`].
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind the listener and start the accept loop and the shard worker
    /// pool.
    pub fn bind(cfg: ServeConfig) -> io::Result<Server> {
        // Startup scan: registered models are process-global, so every
        // shard (including panic-rebuilt engines) sees them. A missing
        // or unreadable directory is a configuration error worth
        // failing loudly at bind time rather than per-request.
        if let Some(dir) = &cfg.models_dir {
            crate::mdb::scan_models_dir(std::path::Path::new(dir))
                .map_err(|e| io::Error::new(io::ErrorKind::InvalidInput, format!("{e:#}")))?;
        }
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let n = cfg.shards.max(1);
        // The factory captures plain values, not `Shared` (which owns
        // the executor): each worker builds its engine on its own
        // thread, at start and again after every caught panic.
        let backend = cfg.backend;
        let reply_timeout = cfg.reply_timeout;
        let pool = Executor::new(
            exec::ExecConfig {
                workers: n,
                queue_depth: cfg.queue_depth.max(1),
                name: "osaca-serve-shard".to_string(),
                ..Default::default()
            },
            move |_shard| {
                Engine::builder().backend(backend).reply_timeout(reply_timeout).build()
            },
        );
        // Auto shed thresholds: the gauge tops out at shards ×
        // (queue_depth + 1) — every slot queued plus one in flight per
        // worker — so the default only sheds at provable saturation
        // (a merely-full single queue still answers plain
        // `overloaded`), and leaves once load drops to a quarter.
        let gauge_cap = n as u64 * (cfg.queue_depth.max(1) as u64 + 1);
        let shed_high = if cfg.shed_high > 0 { cfg.shed_high as u64 } else { gauge_cap };
        let shed_low = if cfg.shed_low > 0 { cfg.shed_low as u64 } else { gauge_cap / 4 };
        let shed_low = shed_low.min(shed_high.saturating_sub(1));
        let shared = Arc::new(Shared {
            exec: pool,
            metrics: ServeMetrics::default(),
            memo: Mutex::new(MemoCache::new(cfg.memo_cap, cfg.memo_max_bytes)),
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            reply_timeout: cfg.reply_timeout,
            max_rps: cfg.max_rps,
            burst: cfg.burst,
            max_inflight: cfg.max_inflight as u64,
            max_frame_bytes: cfg.max_frame_bytes.max(1),
            shed_high,
            shed_low,
            shedding: AtomicBool::new(false),
            chaos: cfg.chaos_seed.map(FaultPlan::new),
            test_ops: cfg.test_ops,
            models_dir: cfg.models_dir.clone(),
            addr,
        });
        let accept = {
            let s = shared.clone();
            thread::Builder::new()
                .name("osaca-serve-accept".to_string())
                .spawn(move || accept_loop(&s, listener))
                .expect("spawn accept loop")
        };
        Ok(Server { shared, addr, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Programmatic equivalent of the wire `shutdown` op.
    pub fn shutdown(&self) {
        self.shared.initiate_shutdown();
    }

    /// Executor-level counters of the shard worker pool (queued /
    /// in-flight / steals / panics / worker restarts).
    pub fn exec_stats(&self) -> &exec::ExecStats {
        self.shared.exec.stats()
    }

    /// Per-worker counters of the shard worker pool (jobs executed,
    /// home-queue gauge).
    pub fn worker_stats(&self) -> &[exec::WorkerStats] {
        self.shared.exec.worker_stats()
    }

    /// Block until the server has shut down and fully drained: accept
    /// loop gone, every connection answered, every queued job
    /// processed, every worker joined. Without a `shutdown` trigger
    /// this serves forever — the CLI's foreground mode.
    pub fn join(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // The accept loop is gone, so the conns vector only shrinks
        // from here; loop in case a connection was being registered
        // while we took the first batch.
        loop {
            let conns: Vec<JoinHandle<()>> =
                std::mem::take(&mut *self.shared.conns.lock().expect("conns"));
            if conns.is_empty() {
                break;
            }
            for c in conns {
                let _ = c.join();
            }
        }
        self.shared.exec.close();
        self.shared.exec.join();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Forced teardown (join() leaves nothing for this to do): flip
        // the flag so conn threads and the accept loop exit, then
        // drain as usual.
        self.shared.initiate_shutdown();
        self.drain();
    }
}

/// Stable shard routing: FNV-1a over the *canonical* lower-cased arch
/// name (the registry's alias table), so every spelling of one model
/// family — `skl`, `SKYLAKE`, an imported `CascadeLake` — maps to the
/// same home worker and its solver work batches together. Unknown
/// names hash their lower-cased raw spelling; the analysis will answer
/// `unknown_arch` anyway, the hint just has to be stable. Idle workers
/// still steal across shards under imbalance.
fn shard_index(arch: &str, shards: usize) -> usize {
    let canon = crate::mdb::canonical_arch(arch);
    let name = canon.as_deref().unwrap_or(arch);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b.to_ascii_lowercase() as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % shards.max(1) as u64) as usize
}

fn accept_loop(shared: &Arc<Shared>, listener: TcpListener) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if shared.shutdown.load(Ordering::Relaxed) {
                    // The wake-up self-connection (or a late client);
                    // drop it and stop accepting.
                    return;
                }
                let s = shared.clone();
                let handle = thread::Builder::new()
                    .name("osaca-serve-conn".to_string())
                    .spawn(move || handle_conn(s, stream))
                    .expect("spawn connection thread");
                shared.conns.lock().expect("conns").push(handle);
            }
            Err(_) => {
                if shared.shutdown.load(Ordering::Relaxed) {
                    return;
                }
            }
        }
    }
}

fn handle_conn(shared: Arc<Shared>, mut stream: TcpStream) {
    // Short read timeout: the read loop polls the shutdown flag between
    // attempts, so idle connections notice a drain within ~100ms.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut buf: Vec<u8> = Vec::new();
    // Per-connection fairness state: the token bucket admits analyzes,
    // the gauge counts this connection's queued/running analyzes (the
    // worker decrements it when a job finishes).
    let mut bucket = TokenBucket::new(shared.max_rps, shared.burst);
    let inflight = Arc::new(AtomicU64::new(0));
    loop {
        let line =
            match read_frame(&mut stream, &mut buf, &shared.shutdown, shared.max_frame_bytes) {
                ReadOutcome::Closed => return,
                ReadOutcome::Oversized => {
                    // Answer, then skip bytes until the offending line
                    // ends — the connection survives with bounded
                    // memory.
                    ServeMetrics::bump(&shared.metrics.oversized_frames);
                    ServeMetrics::bump(&shared.metrics.errors);
                    let msg = format!("frame exceeds {} bytes", shared.max_frame_bytes);
                    if !write_frame(&mut stream, &error_frame("frame_too_large", &msg)) {
                        return;
                    }
                    if !discard_through_newline(&mut stream, &mut buf, &shared.shutdown) {
                        return;
                    }
                    continue;
                }
                ReadOutcome::Frame(line) => line,
            };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let response = match wire::parse_request(line, shared.test_ops) {
            Err(e) => {
                // Malformed frame: structured error, connection stays
                // open. Not counted as "served" — we cannot even tell
                // which op it was.
                ServeMetrics::bump(&shared.metrics.errors);
                error_frame(e.kind, &e.message)
            }
            Ok(WireRequest::Stats) => {
                let (memo_len, memo_bytes) = {
                    let memo = shared.lock_memo();
                    (memo.len() as u64, memo.bytes() as u64)
                };
                let depths = shared.exec.queue_depths();
                let es = shared.exec.stats();
                shared
                    .metrics
                    .frame(
                        memo_len,
                        memo_bytes,
                        depths,
                        shared.shed_state(),
                        es.panics.load(Ordering::Relaxed),
                        es.worker_restarts.load(Ordering::Relaxed),
                        crate::mdb::reload_count() as u64,
                    )
                    .render()
            }
            Ok(WireRequest::ReloadModels) => match &shared.models_dir {
                None => {
                    ServeMetrics::bump(&shared.metrics.errors);
                    error_frame("bad_request", "server was started without --models-dir")
                }
                Some(dir) => match crate::mdb::scan_models_dir(std::path::Path::new(dir)) {
                    Ok(names) => ok_frame(
                        Format::Text,
                        false,
                        &format!("reloaded {} model(s) from {dir}", names.len()),
                    ),
                    Err(e) => {
                        ServeMetrics::bump(&shared.metrics.errors);
                        error_frame("internal_error", &format!("model reload failed: {e:#}"))
                    }
                },
            },
            Ok(WireRequest::Shutdown) => {
                let _ = write_frame(&mut stream, &bye_frame());
                shared.initiate_shutdown();
                return;
            }
            Ok(WireRequest::Sleep { ms }) => {
                let (rtx, rrx) = mpsc::sync_channel(1);
                let job = exec::Job::new(move |_engine: &mut Engine| {
                    thread::sleep(Duration::from_millis(ms));
                    let _ = rtx.try_send(ok_frame(Format::Text, false, "slept"));
                });
                match shared.exec.try_submit(Some(0), job) {
                    exec::Submit::Queued => rrx
                        .recv_timeout(shared.reply_timeout + Duration::from_millis(ms))
                        .unwrap_or_else(|_| {
                            error_frame("solver_timeout", "sleep reply timed out")
                        }),
                    exec::Submit::Full(depth) => overloaded_frame(0, depth, false),
                    exec::Submit::Closed => {
                        error_frame("service_unavailable", "server is draining")
                    }
                }
            }
            Ok(WireRequest::Panic) => {
                let (rtx, rrx) = mpsc::sync_channel(1);
                let s = shared.clone();
                let job = exec::Job::new(|_engine: &mut Engine| {
                    panic!("test-op: injected worker panic");
                })
                .on_panic(move |category| {
                    ServeMetrics::bump(&s.metrics.errors);
                    let _ = rtx.try_send(error_frame("internal_error", category));
                });
                match shared.exec.try_submit(Some(0), job) {
                    exec::Submit::Queued => {
                        rrx.recv_timeout(shared.reply_timeout).unwrap_or_else(|_| {
                            error_frame("solver_timeout", "panic reply timed out")
                        })
                    }
                    exec::Submit::Full(depth) => overloaded_frame(0, depth, false),
                    exec::Submit::Closed => {
                        error_frame("service_unavailable", "server is draining")
                    }
                }
            }
            Ok(WireRequest::Analyze { req, deadline_ms }) => {
                let resp = analyze_op(&shared, &mut bucket, &inflight, req, deadline_ms);
                ServeMetrics::bump(&shared.metrics.served);
                resp
            }
        };
        if !write_frame(&mut stream, &response) {
            return;
        }
    }
}

/// The analyze admission ladder: rate limit → in-flight cap → shed
/// check (memo hits still answer) → queue submission. Each rung
/// answers its own structured frame; only the last rung costs a queue
/// slot.
fn analyze_op(
    shared: &Arc<Shared>,
    bucket: &mut TokenBucket,
    inflight: &Arc<AtomicU64>,
    req: AnalysisRequest,
    deadline_ms: Option<u64>,
) -> String {
    if let Err(retry_ms) = bucket.try_acquire(Instant::now()) {
        ServeMetrics::bump(&shared.metrics.rate_limited);
        return rate_limited_frame("rps", retry_ms);
    }
    if shared.max_inflight > 0 && inflight.load(Ordering::Relaxed) >= shared.max_inflight {
        ServeMetrics::bump(&shared.metrics.rate_limited);
        return rate_limited_frame("inflight", RETRY_INFLIGHT_MS);
    }
    let idx = shard_index(&req.arch, shared.exec.workers());
    let key = req.fingerprint();
    if shared.shed_state() {
        // Degradation ladder: a saturated server still answers what it
        // already knows (memo hits bypass the queue entirely) and
        // rejects only work that needs a worker.
        if let Some(frame) = try_memo_frame(shared, key, &req.name, req.format) {
            return frame;
        }
        ServeMetrics::bump(&shared.metrics.shed);
        ServeMetrics::bump(&shared.metrics.overloaded);
        let depth = shared.exec.queue_depths()[idx];
        return overloaded_frame(idx, depth, true);
    }
    let deadline = deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
    // Reply channels are fresh per request so a timed-out connection's
    // late reply dies in try_send instead of leaking forward.
    let (rtx, rrx) = mpsc::sync_channel(1);
    inflight.fetch_add(1, Ordering::Relaxed);
    let s = shared.clone();
    let run_reply = rtx.clone();
    let run_inflight = inflight.clone();
    let job = exec::Job::new(move |engine: &mut Engine| {
        let frame = if deadline.is_some_and(|d| Instant::now() >= d) {
            ServeMetrics::bump(&s.metrics.deadline_expired);
            ServeMetrics::bump(&s.metrics.errors);
            error_frame("deadline_exceeded", "request deadline expired before dispatch")
        } else {
            let fault = s.chaos.as_ref().and_then(FaultPlan::next_dispatch);
            if let Some(Fault::StallQueue { ms }) = fault {
                thread::sleep(Duration::from_millis(ms));
            }
            if matches!(fault, Some(Fault::Panic)) {
                panic!("chaos: injected worker panic");
            }
            let frame = analyze_job(&s, engine, req, key);
            if let Some(Fault::DelayReply { ms }) = fault {
                thread::sleep(Duration::from_millis(ms));
            }
            frame
        };
        // A timed-out connection dropped its receiver; the failed send
        // is the intended outcome then.
        let _ = run_reply.try_send(frame);
        run_inflight.fetch_sub(1, Ordering::Relaxed);
    });
    let s = shared.clone();
    let panic_inflight = inflight.clone();
    let job = job.on_panic(move |category| {
        // The executor already counted the panic and rebuilt the
        // engine; this callback only owns the wire answer.
        ServeMetrics::bump(&s.metrics.errors);
        let _ = rtx.try_send(error_frame("internal_error", category));
        panic_inflight.fetch_sub(1, Ordering::Relaxed);
    });
    match shared.exec.try_submit(Some(idx), job) {
        exec::Submit::Queued => match rrx.recv_timeout(shared.reply_timeout) {
            Ok(frame) => frame,
            Err(_) => {
                // A worker still owns the job (and will decrement the
                // in-flight gauge when it finishes); only the reply is
                // abandoned.
                ServeMetrics::bump(&shared.metrics.errors);
                error_frame(
                    "solver_timeout",
                    &format!("no reply within {:?}", shared.reply_timeout),
                )
            }
        },
        exec::Submit::Full(depth) => {
            inflight.fetch_sub(1, Ordering::Relaxed);
            ServeMetrics::bump(&shared.metrics.overloaded);
            overloaded_frame(idx, depth, false)
        }
        exec::Submit::Closed => {
            inflight.fetch_sub(1, Ordering::Relaxed);
            ServeMetrics::bump(&shared.metrics.errors);
            error_frame("service_unavailable", "server is draining")
        }
    }
}

/// Outcome of one frame-read attempt.
enum ReadOutcome {
    Frame(String),
    /// The line under construction exceeded `max_frame` bytes without
    /// a newline; the caller answers a structured error and discards
    /// the rest of the line.
    Oversized,
    /// Connection closed, IO error, or drain.
    Closed,
}

/// Read one newline-terminated frame, polling the shutdown flag
/// between read attempts and bounding the line buffer.
fn read_frame(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    shutdown: &AtomicBool,
    max_frame: usize,
) -> ReadOutcome {
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = buf.drain(..=pos).collect();
            let mut line = String::from_utf8_lossy(&line[..line.len() - 1]).into_owned();
            if line.ends_with('\r') {
                line.pop();
            }
            return ReadOutcome::Frame(line);
        }
        if buf.len() > max_frame {
            return ReadOutcome::Oversized;
        }
        if shutdown.load(Ordering::Relaxed) {
            return ReadOutcome::Closed;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return ReadOutcome::Closed,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                continue
            }
            Err(_) => return ReadOutcome::Closed,
        }
    }
}

/// Skip input until the end of the current (oversized) line, keeping
/// memory bounded by clearing the buffer between reads. Bytes after
/// the newline are preserved for the next frame. Returns false when
/// the connection should close.
fn discard_through_newline(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    shutdown: &AtomicBool,
) -> bool {
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            buf.drain(..=pos);
            return true;
        }
        buf.clear();
        if shutdown.load(Ordering::Relaxed) {
            return false;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return false,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                continue
            }
            Err(_) => return false,
        }
    }
}

fn write_frame(stream: &mut TcpStream, frame: &str) -> bool {
    stream.write_all(frame.as_bytes()).and_then(|()| stream.write_all(b"\n")).is_ok()
}

/// Render an answer from the memo, if present: bump the hit counter,
/// clone the cached report, patch the presentation-only fields from
/// this request, render. Used both on the worker path and directly on
/// the connection path in shed mode (hits must not need a queue slot).
fn try_memo_frame(shared: &Shared, key: u64, name: &str, format: Format) -> Option<String> {
    let hit = shared.lock_memo().get(key)?;
    ServeMetrics::bump(&shared.metrics.memo_hits);
    // The fingerprint excludes presentation fields, so patch them from
    // this request before rendering. The clone shares the cached
    // report's Arc'd prediction decomposition.
    let mut patched = (*hit).clone();
    patched.name = name.to_string();
    patched.format = format;
    Some(ok_frame(format, true, &patched.render()))
}

fn analyze_job(shared: &Shared, engine: &Engine, req: AnalysisRequest, key: u64) -> String {
    if let Some(frame) = try_memo_frame(shared, key, &req.name, req.format) {
        return frame;
    }
    ServeMetrics::bump(&shared.metrics.memo_misses);
    ServeMetrics::bump(&shared.metrics.analyses);
    match engine.analyze(&req) {
        Ok(report) => {
            let format = report.format;
            let arc = Arc::new(report);
            // Fill the shared decomposition once, before the report
            // becomes visible to other requests.
            arc.prediction_shared();
            let rendered = arc.render();
            // The rendered length is the byte-cost proxy: the rendered
            // report dominates what a cached entry keeps alive.
            shared.lock_memo().insert(key, arc, rendered.len());
            ok_frame(format, false, &rendered)
        }
        Err(e) => {
            // Failures are not memoized: a registered-later model or a
            // transient solver problem should not pin an error.
            ServeMetrics::bump(&shared.metrics.errors);
            error_frame(e.kind_name(), &e.to_string())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_routing_is_stable_and_case_insensitive() {
        for arches in [["skl", "SKL"], ["zen", "Zen"], ["rv64", "RV64"]] {
            assert_eq!(shard_index(arches[0], 4), shard_index(arches[1], 4));
        }
        // Aliases canonicalize before hashing: every spelling of one
        // model family shares a home shard (registry satellite).
        for arches in [["skl", "Skylake"], ["zen", "znver1"], ["tx2", "ThunderX2"]] {
            assert_eq!(
                shard_index(arches[0], 4),
                shard_index(arches[1], 4),
                "{arches:?} should share a shard"
            );
        }
        // Different families spread (not all on one shard for the
        // built-ins we ship).
        let idx: Vec<usize> =
            ["skl", "zen", "hsw", "tx2", "rv64"].iter().map(|a| shard_index(a, 4)).collect();
        assert!(idx.iter().any(|&i| i != idx[0]), "built-ins all collided: {idx:?}");
        // Single shard degenerates safely.
        assert_eq!(shard_index("skl", 1), 0);
        assert_eq!(shard_index("skl", 0), 0);
    }

    #[test]
    fn config_defaults_are_documented_values() {
        let c = ServeConfig::default();
        assert_eq!(c.shards, 2);
        assert_eq!(c.memo_cap, 256);
        assert_eq!(c.memo_max_bytes, 0, "byte bound is opt-in");
        assert_eq!(c.queue_depth, 64);
        assert_eq!(c.max_rps, 0.0, "rate limiting is opt-in");
        assert_eq!(c.burst, 8);
        assert_eq!(c.max_inflight, 0, "in-flight cap is opt-in");
        assert_eq!(c.max_frame_bytes, 1 << 20);
        assert_eq!(c.shed_high, 0, "0 = auto (full gauge capacity)");
        assert_eq!(c.shed_low, 0, "0 = auto (quarter capacity)");
        assert!(!c.test_ops);
        assert!(c.chaos_seed.is_none());
        assert!(c.models_dir.is_none(), "dynamic model loading is opt-in");
    }
}
