//! Wire request decoding: one newline-delimited JSON frame → one
//! [`WireRequest`].
//!
//! The request grammar (responses are built in `report::emit` — the
//! frames share [`crate::report::emit::SCHEMA_VERSION`] with the report
//! emitters):
//!
//! ```json
//! {"op":"analyze","arch":"skl","source":"...","name":"triad",
//!  "passes":["throughput","critpath"],"frontend_bound":false,
//!  "unroll":4,"format":"json","deadline_ms":250}
//! {"op":"stats"}
//! {"op":"reload_models"}
//! {"op":"shutdown"}
//! {"op":"sleep","ms":250}        // test-ops builds only
//! {"op":"panic"}                 // test-ops builds only
//! ```
//!
//! `analyze` requires `arch` and `source`; everything else defaults
//! (`passes` → analytic, `format` → json, `unroll` → 1, `name` →
//! "wire", `deadline_ms` → none). `deadline_ms` is a serving concern,
//! not an analysis input — it rides next to the request rather than on
//! it, so the memo fingerprint is untouched by it. Malformed frames
//! produce a structured error with a machine-readable kind, never a
//! disconnect — the connection survives and the client can retry.

use crate::api::{AnalysisRequest, Format, Passes};
use crate::serve::json::{self, JsonValue};

/// One decoded request frame.
#[derive(Debug)]
pub enum WireRequest {
    Analyze {
        req: AnalysisRequest,
        /// Queue-time budget: if the request has not reached a worker
        /// within this many milliseconds it is answered with a
        /// `deadline_exceeded` error instead of being analyzed.
        deadline_ms: Option<u64>,
    },
    Stats,
    /// Re-scan the server's `--models-dir` into the process-wide
    /// dynamic model registry (no-op without a configured directory).
    /// Imported/updated `.mdb` files become visible to every shard —
    /// the registry is process-global — without a restart.
    ReloadModels,
    Shutdown,
    /// Test-ops only: occupy a shard worker for `ms` milliseconds so
    /// tests can saturate a queue deterministically.
    Sleep { ms: u64 },
    /// Test-ops only: panic inside a shard worker so tests can pin the
    /// supervision path (internal_error frame, engine rebuild).
    Panic,
}

/// Why a frame could not be decoded. `kind` is the machine-readable
/// error kind for the error frame (`bad_request` for grammar problems,
/// `unsupported_format` for a bad `format` value).
#[derive(Debug)]
pub struct FrameError {
    pub kind: &'static str,
    pub message: String,
}

impl FrameError {
    fn bad(message: impl Into<String>) -> FrameError {
        FrameError { kind: "bad_request", message: message.into() }
    }
}

/// Decode one frame. `test_ops` gates the ops that exist only so the
/// integration tests can shape server load (`sleep`) and fault it
/// (`panic`).
pub fn parse_request(line: &str, test_ops: bool) -> Result<WireRequest, FrameError> {
    let v = json::parse(line).map_err(|e| FrameError::bad(e.to_string()))?;
    if !matches!(v, JsonValue::Obj(_)) {
        return Err(FrameError::bad("frame must be a JSON object"));
    }
    let op = v
        .get("op")
        .and_then(JsonValue::as_str)
        .ok_or_else(|| FrameError::bad("missing string field `op`"))?;
    match op {
        "analyze" => {
            let deadline_ms = match v.get("deadline_ms") {
                None => None,
                Some(d) => Some(d.as_u64().ok_or_else(|| {
                    FrameError::bad("`deadline_ms` must be a non-negative integer")
                })?),
            };
            let req = analyze_request(&v)?;
            Ok(WireRequest::Analyze { req, deadline_ms })
        }
        "stats" => Ok(WireRequest::Stats),
        "reload_models" => Ok(WireRequest::ReloadModels),
        "shutdown" => Ok(WireRequest::Shutdown),
        "sleep" if test_ops => {
            let ms = v
                .get("ms")
                .and_then(JsonValue::as_u64)
                .ok_or_else(|| FrameError::bad("`sleep` needs integer field `ms`"))?;
            Ok(WireRequest::Sleep { ms })
        }
        "panic" if test_ops => Ok(WireRequest::Panic),
        other => Err(FrameError::bad(format!("unknown op `{other}`"))),
    }
}

fn analyze_request(v: &JsonValue) -> Result<AnalysisRequest, FrameError> {
    let arch = v
        .get("arch")
        .and_then(JsonValue::as_str)
        .ok_or_else(|| FrameError::bad("`analyze` needs string field `arch`"))?;
    let source = v
        .get("source")
        .and_then(JsonValue::as_str)
        .ok_or_else(|| FrameError::bad("`analyze` needs string field `source`"))?;
    let name = v.get("name").and_then(JsonValue::as_str).unwrap_or("wire");
    let mut req = AnalysisRequest::new(name).arch(arch).source(source);

    if let Some(passes) = v.get("passes") {
        let names = passes
            .as_array()
            .ok_or_else(|| FrameError::bad("`passes` must be an array of pass names"))?;
        let mut set = Passes::NONE;
        for n in names {
            let n = n
                .as_str()
                .ok_or_else(|| FrameError::bad("`passes` entries must be strings"))?;
            set |= Passes::from_name(n)
                .ok_or_else(|| FrameError::bad(format!("unknown pass `{n}`")))?;
        }
        req = req.passes(set);
    }
    if let Some(fb) = v.get("frontend_bound") {
        let fb = fb
            .as_bool()
            .ok_or_else(|| FrameError::bad("`frontend_bound` must be a boolean"))?;
        req = req.frontend_bound(fb);
    }
    if let Some(u) = v.get("unroll") {
        let u = u
            .as_u64()
            .ok_or_else(|| FrameError::bad("`unroll` must be a non-negative integer"))?;
        req = req.unroll(u as usize);
    }
    match v.get("format") {
        None => req = req.format(Format::Json),
        Some(f) => {
            let f = f.as_str().ok_or_else(|| FrameError::bad("`format` must be a string"))?;
            let format = Format::parse(f).map_err(|e| FrameError {
                kind: "unsupported_format",
                message: e.to_string(),
            })?;
            req = req.format(format);
        }
    }
    Ok(req)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analyze_defaults_and_overrides() {
        let r = parse_request(
            "{\"op\":\"analyze\",\"arch\":\"skl\",\"source\":\".L1:\\njne .L1\\n\"}",
            false,
        )
        .unwrap();
        let WireRequest::Analyze { req, deadline_ms } = r else { panic!("expected analyze") };
        assert_eq!(req.arch, "skl");
        assert_eq!(req.name, "wire");
        assert_eq!(req.passes, Passes::ANALYTIC);
        assert_eq!(req.format, Format::Json, "wire default is json, not text");
        assert_eq!(req.unroll, 1);
        assert_eq!(deadline_ms, None);

        let r = parse_request(
            "{\"op\":\"analyze\",\"arch\":\"rv64\",\"source\":\"x\",\"name\":\"triad\",\
             \"passes\":[\"throughput\",\"critpath\"],\"frontend_bound\":true,\
             \"unroll\":4,\"format\":\"csv\",\"deadline_ms\":250}",
            false,
        )
        .unwrap();
        let WireRequest::Analyze { req, deadline_ms } = r else { panic!("expected analyze") };
        assert_eq!(req.name, "triad");
        assert_eq!(req.passes, Passes::THROUGHPUT | Passes::CRITPATH);
        assert!(req.frontend_bound);
        assert_eq!(req.unroll, 4);
        assert_eq!(req.format, Format::Csv);
        assert_eq!(deadline_ms, Some(250));
    }

    #[test]
    fn deadline_does_not_perturb_the_fingerprint() {
        let plain = parse_request(
            "{\"op\":\"analyze\",\"arch\":\"skl\",\"source\":\"x\"}",
            false,
        )
        .unwrap();
        let bounded = parse_request(
            "{\"op\":\"analyze\",\"arch\":\"skl\",\"source\":\"x\",\"deadline_ms\":10}",
            false,
        )
        .unwrap();
        let (WireRequest::Analyze { req: a, .. }, WireRequest::Analyze { req: b, .. }) =
            (plain, bounded)
        else {
            panic!("expected analyze frames")
        };
        assert_eq!(a.fingerprint(), b.fingerprint(), "deadline is a serving concern only");
    }

    #[test]
    fn control_ops_parse() {
        assert!(matches!(parse_request("{\"op\":\"stats\"}", false), Ok(WireRequest::Stats)));
        // reload_models is a real control op, not test-ops-gated.
        assert!(matches!(
            parse_request("{\"op\":\"reload_models\"}", false),
            Ok(WireRequest::ReloadModels)
        ));
        assert!(matches!(
            parse_request("{\"op\":\"shutdown\"}", false),
            Ok(WireRequest::Shutdown)
        ));
        assert!(matches!(
            parse_request("{\"op\":\"sleep\",\"ms\":50}", true),
            Ok(WireRequest::Sleep { ms: 50 })
        ));
        assert!(matches!(parse_request("{\"op\":\"panic\"}", true), Ok(WireRequest::Panic)));
        // sleep and panic are gated behind test_ops.
        let e = parse_request("{\"op\":\"sleep\",\"ms\":50}", false).unwrap_err();
        assert_eq!(e.kind, "bad_request");
        let e = parse_request("{\"op\":\"panic\"}", false).unwrap_err();
        assert_eq!(e.kind, "bad_request");
    }

    #[test]
    fn malformed_frames_are_structured_errors() {
        for (frame, kind) in [
            ("not json", "bad_request"),
            ("[1,2]", "bad_request"),
            ("{\"op\":\"warp\"}", "bad_request"),
            ("{\"op\":\"analyze\",\"source\":\"x\"}", "bad_request"),
            ("{\"op\":\"analyze\",\"arch\":\"skl\"}", "bad_request"),
            (
                "{\"op\":\"analyze\",\"arch\":\"skl\",\"source\":\"x\",\"deadline_ms\":-1}",
                "bad_request",
            ),
            (
                "{\"op\":\"analyze\",\"arch\":\"skl\",\"source\":\"x\",\"passes\":[\"warp\"]}",
                "bad_request",
            ),
            (
                "{\"op\":\"analyze\",\"arch\":\"skl\",\"source\":\"x\",\"format\":\"yaml\"}",
                "unsupported_format",
            ),
        ] {
            let e = parse_request(frame, false).unwrap_err();
            assert_eq!(e.kind, kind, "frame: {frame}");
        }
    }
}
