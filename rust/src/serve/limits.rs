//! Per-connection fairness: the token bucket behind `--max-rps` /
//! `--burst`.
//!
//! Each connection thread owns one [`TokenBucket`]; a rejected acquire
//! becomes a `rate_limited` wire frame carrying the computed
//! `retry_after_ms` hint. The bucket takes the current `Instant` as an
//! explicit parameter so refill arithmetic is unit-testable without
//! sleeping. The companion in-flight cap (`--max-inflight`) is a plain
//! shared gauge owned by `serve::mod` — the jobs themselves carry the
//! decrement side — so no abstraction lives here for it.

use std::time::Instant;

/// A standard token bucket: `rate_per_s` tokens accrue per second up to
/// a ceiling of `burst`; each admitted request spends one token. A rate
/// of zero (or below) disables limiting entirely — every acquire
/// succeeds.
#[derive(Debug)]
pub struct TokenBucket {
    rate_per_s: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// `burst` is clamped to at least 1: a bucket that can never hold a
    /// whole token would reject everything forever.
    pub fn new(rate_per_s: f64, burst: u32) -> TokenBucket {
        let burst = f64::from(burst.max(1));
        TokenBucket { rate_per_s: rate_per_s.max(0.0), burst, tokens: burst, last: Instant::now() }
    }

    pub fn enabled(&self) -> bool {
        self.rate_per_s > 0.0
    }

    /// Spend one token, refilling for the time elapsed since the last
    /// call. On rejection returns the milliseconds until one whole
    /// token will have accrued (the `retry_after_ms` wire hint),
    /// rounded up so an honest client that waits exactly that long
    /// succeeds.
    pub fn try_acquire(&mut self, now: Instant) -> Result<(), u64> {
        if !self.enabled() {
            return Ok(());
        }
        let elapsed = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + elapsed * self.rate_per_s).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else {
            let deficit = 1.0 - self.tokens;
            Err(((deficit / self.rate_per_s) * 1000.0).ceil() as u64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn burst_then_starve_then_refill() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(2.0, 3);
        // The full burst is admitted back to back...
        for i in 0..3 {
            assert!(b.try_acquire(t0).is_ok(), "burst token {i}");
        }
        // ...then the bucket is dry: at 2 tokens/s one whole token is
        // 500ms away.
        let retry = b.try_acquire(t0).unwrap_err();
        assert_eq!(retry, 500);
        // 600ms later one token has accrued; the next request passes
        // and the one after is again told to wait.
        let t1 = t0 + Duration::from_millis(600);
        assert!(b.try_acquire(t1).is_ok());
        assert!(b.try_acquire(t1).is_err());
    }

    #[test]
    fn refill_is_capped_at_burst() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(100.0, 2);
        assert!(b.try_acquire(t0).is_ok());
        assert!(b.try_acquire(t0).is_ok());
        // An hour of idling still only banks `burst` tokens.
        let t1 = t0 + Duration::from_secs(3600);
        assert!(b.try_acquire(t1).is_ok());
        assert!(b.try_acquire(t1).is_ok());
        assert!(b.try_acquire(t1).is_err());
    }

    #[test]
    fn zero_rate_disables() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(0.0, 1);
        assert!(!b.enabled());
        for _ in 0..100 {
            assert!(b.try_acquire(t0).is_ok());
        }
    }

    #[test]
    fn zero_burst_is_clamped_to_one() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(1000.0, 0);
        assert!(b.try_acquire(t0).is_ok(), "a 0-burst bucket must still hold one token");
    }

    #[test]
    fn retry_hint_rounds_up() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(3.0, 1);
        assert!(b.try_acquire(t0).is_ok());
        // 1/3 s = 333.33ms; the hint must not round down to a time at
        // which the token has not yet accrued.
        assert_eq!(b.try_acquire(t0).unwrap_err(), 334);
    }
}
