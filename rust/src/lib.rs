//! # osaca-rs
//!
//! Reproduction of *Automated Instruction Stream Throughput Prediction for
//! Intel and AMD Microarchitectures* (OSACA, PMBS 2018) as a three-layer
//! rust + JAX + Pallas system.
//!
//! Layers:
//! * **L3 (this crate)** — assembly parsing, machine-model database,
//!   out-of-order core *simulator* (the measurement substrate standing in
//!   for real Skylake/Zen silicon), ibench-style benchmark generation,
//!   semi-automatic model construction, the OSACA throughput analyzer, an
//!   IACA-like balanced baseline, a batching analysis coordinator, and a
//!   persistent sharded analysis service ([`serve`]).
//! * **L2/L1 (python/, build-time only)** — the batched port-pressure
//!   solver (uniform + iteratively balanced) as a JAX model wrapping a
//!   Pallas kernel, AOT-lowered to `artifacts/port_solver.hlo.txt` and
//!   executed from rust via PJRT (`runtime`, behind the `pjrt` feature).
//!
//! **Entry point:** [`api::Engine`] is the public front door — request
//! builder, composable passes, batch submission, structured errors. The
//! per-module free functions remain as compatibility shims.
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod analyzer;
pub mod api;
pub mod asm;
pub mod baseline;
pub mod benchlib;
pub mod builder;
pub mod coordinator;
pub mod corpus;
pub mod exec;
pub mod ibench;
pub mod isa;
pub mod mdb;
pub mod proplite;
pub mod report;
pub mod serve;
pub mod runtime;
pub mod sim;
pub mod workloads;
pub mod zoo;
