//! Dynamic model registry: user `.mdb` models loaded beside the
//! built-ins, with one canonical place for arch-name aliasing.
//!
//! Three properties drive the design (ISSUE-10, DESIGN.md §13):
//!
//! * **Lazy parse-on-first-use.** Registering a model stores its raw
//!   `.mdb` text (plus a cheap scan of the `arch` directive for
//!   aliasing); the text is parsed the first time something resolves
//!   the name, and the parsed model is cached as an `Arc` forever
//!   after (eviction-free — models are small and a serving process
//!   must never re-parse on the hot path). A dozen imported
//!   uops.info models cost a directory scan at startup, not a dozen
//!   parses.
//! * **Process-wide.** The registry is global, like the built-in
//!   `OnceLock` caches: every `api::Engine` — including the fresh
//!   engines `serve` builds after a worker panic — sees registered
//!   models without per-shard plumbing. `serve --models-dir` +
//!   the `reload_models` wire op re-scan into live shards for free.
//! * **Canonical aliasing.** [`canonical_arch`] is the single
//!   case-insensitive alias table (built-ins, curated zoo aliases,
//!   and the `arch` short name of every registered model), so the
//!   serve shard hint, the engine lookup and the CLI all agree on
//!   what `SKYLAKE` or `CascadeLake` means — a hot imported arch
//!   shards identically to a built-in one.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use anyhow::{Context, Result};

use super::MachineModel;

/// Case-insensitive aliases for the built-in models (canonical CLI
/// name last). Previously scattered across `by_name_shared`, the
/// serve shard hint and the CLI; this table is now the only copy.
const BUILTIN_ALIASES: &[(&str, &str)] = &[
    ("skl", "skl"),
    ("skylake", "skl"),
    ("zen", "zen"),
    ("znver1", "zen"),
    ("hsw", "hsw"),
    ("haswell", "hsw"),
    ("tx2", "tx2"),
    ("thunderx2", "tx2"),
    ("rv64", "rv64"),
    ("riscv", "rv64"),
    ("rv64gc", "rv64"),
];

/// Curated aliases for zoo-imported models (see `zoo::overlay`): these
/// resolve only while a model with the canonical name is actually
/// registered, so an unimported `cascadelake` still reads as unknown.
const CURATED_ALIASES: &[(&str, &str)] = &[
    ("cascadelake", "clx"),
    ("icelake", "icl"),
    ("znver2", "zen2"),
];

enum Slot {
    /// Registered but never resolved: raw `.mdb` text.
    Unparsed(String),
    /// Parsed on first use and cached for the process lifetime.
    Parsed(Arc<MachineModel>),
}

#[derive(Default)]
struct Registry {
    /// Canonical (lowercased) name -> model slot.
    models: HashMap<String, Slot>,
    /// Lowercased alias -> canonical name, learned from each model's
    /// `arch` directive at registration time.
    aliases: HashMap<String, String>,
}

static REGISTRY: OnceLock<RwLock<Registry>> = OnceLock::new();
/// Dynamic-model parses performed so far — at most one per registered
/// model per process (the lazy-load analogue of `builtin_parse_count`,
/// asserted by `benches/hotpath.rs`).
static REGISTRY_PARSES: AtomicUsize = AtomicUsize::new(0);
/// Completed `scan_models_dir` passes (the serve `reload_models`
/// counter surfaces this through `stats`).
static RELOADS: AtomicUsize = AtomicUsize::new(0);

fn registry() -> &'static RwLock<Registry> {
    REGISTRY.get_or_init(Default::default)
}

/// How many registered (non-built-in) model texts have been parsed.
pub fn registry_parse_count() -> usize {
    REGISTRY_PARSES.load(Ordering::Relaxed)
}

/// How many registry re-scans (`scan_models_dir`) have completed.
pub fn reload_count() -> usize {
    RELOADS.load(Ordering::Relaxed)
}

/// Resolve any spelling of an architecture name to its canonical
/// lowercase form: built-in aliases first, then registered models and
/// their learned aliases, then the curated zoo aliases (which only
/// apply while their target is registered). `None` means unknown.
pub fn canonical_arch(name: &str) -> Option<String> {
    let lower = name.to_ascii_lowercase();
    if let Some((_, canon)) = BUILTIN_ALIASES.iter().find(|(a, _)| *a == lower) {
        return Some((*canon).to_string());
    }
    let reg = registry().read().unwrap_or_else(|e| e.into_inner());
    if reg.models.contains_key(&lower) {
        return Some(lower);
    }
    if let Some(canon) = reg.aliases.get(&lower) {
        return Some(canon.clone());
    }
    if let Some((_, canon)) = CURATED_ALIASES.iter().find(|(a, _)| *a == lower) {
        if reg.models.contains_key(*canon) {
            return Some((*canon).to_string());
        }
    }
    None
}

/// Names of every registered dynamic model (canonical, sorted).
pub fn registry_names() -> Vec<String> {
    let reg = registry().read().unwrap_or_else(|e| e.into_inner());
    let mut names: Vec<String> = reg.models.keys().cloned().collect();
    names.sort();
    names
}

/// Cheap scan for the `arch <short> "..."` directive — used at
/// registration to learn an alias without paying a full parse.
fn arch_short_name(text: &str) -> Option<String> {
    for raw in text.lines() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if let Some(rest) = line.strip_prefix("arch ") {
            let short = rest.split_whitespace().next()?;
            return Some(short.to_ascii_lowercase());
        }
    }
    None
}

/// Register (or replace) a dynamic model under `name`. The text is
/// *not* parsed here — first lookup pays the one parse. The model's
/// own `arch` short name becomes an alias when it differs from the
/// registered name (and does not shadow a built-in).
pub fn register_model_text(name: &str, text: &str) {
    let key = name.to_ascii_lowercase();
    let alias = arch_short_name(text);
    let mut reg = registry().write().unwrap_or_else(|e| e.into_inner());
    if let Some(short) = alias {
        let shadows_builtin = BUILTIN_ALIASES.iter().any(|(a, _)| *a == short);
        if short != key && !shadows_builtin {
            reg.aliases.insert(short, key.clone());
        }
    }
    reg.models.insert(key, Slot::Unparsed(text.to_string()));
}

/// Resolve a registered model by canonical name, parsing on first use.
/// A model whose text fails to parse is dropped from the registry and
/// reads as unknown (the eager `zoo` import path validates up front;
/// this lazy path serves directory scans, which must tolerate one bad
/// file without poisoning the rest).
pub fn lookup(canonical: &str) -> Option<Arc<MachineModel>> {
    {
        let reg = registry().read().unwrap_or_else(|e| e.into_inner());
        match reg.models.get(canonical) {
            Some(Slot::Parsed(m)) => return Some(Arc::clone(m)),
            Some(Slot::Unparsed(_)) => {}
            None => return None,
        }
    }
    let mut reg = registry().write().unwrap_or_else(|e| e.into_inner());
    // Re-check under the write lock: another thread may have parsed
    // (or replaced) the slot in between.
    match reg.models.get(canonical) {
        Some(Slot::Parsed(m)) => return Some(Arc::clone(m)),
        Some(Slot::Unparsed(_)) => {}
        None => return None,
    }
    let text = match reg.models.get(canonical) {
        Some(Slot::Unparsed(t)) => t.clone(),
        _ => unreachable!("checked above"),
    };
    REGISTRY_PARSES.fetch_add(1, Ordering::Relaxed);
    match MachineModel::parse(&text) {
        Ok(m) => {
            let shared = Arc::new(m);
            reg.models.insert(canonical.to_string(), Slot::Parsed(Arc::clone(&shared)));
            Some(shared)
        }
        Err(_) => {
            reg.models.remove(canonical);
            None
        }
    }
}

/// Scan a directory for `*.mdb` files and register each under its file
/// stem (lowercased), lazily. Files are taken in sorted order so
/// repeated scans are deterministic. Returns the registered names;
/// bumps the reload counter once per completed scan.
pub fn scan_models_dir(dir: &Path) -> Result<Vec<String>> {
    let mut paths: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("models dir `{}`", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x.eq_ignore_ascii_case("mdb")).unwrap_or(false))
        .collect();
    paths.sort();
    let mut names = Vec::with_capacity(paths.len());
    for p in paths {
        let stem = match p.file_stem().and_then(|s| s.to_str()) {
            Some(s) => s.to_ascii_lowercase(),
            None => continue,
        };
        let text =
            std::fs::read_to_string(&p).with_context(|| format!("read `{}`", p.display()))?;
        register_model_text(&stem, &text);
        names.push(stem);
    }
    RELOADS.fetch_add(1, Ordering::Relaxed);
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global and the lib test binary runs
    // threads in parallel, so every test here uses names no other
    // test (or built-in) touches.

    const MINI: &str = "arch regtesta \"Registry Test A\"\nports P0 LD\nloadports LD\n\
                        storedataports P0\nstoreaguports LD\n\
                        entry vaddpd-xmm_xmm_xmm lat=4 tp=0.5 uops=c@1:P0\n";

    #[test]
    fn register_is_lazy_and_lookup_parses_once() {
        let before = registry_parse_count();
        register_model_text("regtest-lazy", &MINI.replace("regtesta", "regtestlazy"));
        assert_eq!(registry_parse_count(), before, "registration must not parse");
        let a = lookup("regtest-lazy").expect("registered model resolves");
        let after = registry_parse_count();
        assert!(after > before);
        let b = lookup("regtest-lazy").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup is the cached Arc");
        assert_eq!(registry_parse_count(), after, "no re-parse on cached lookup");
    }

    #[test]
    fn arch_directive_becomes_an_alias() {
        register_model_text("regtest-aliased", MINI);
        // `arch regtesta` differs from the registered key -> alias.
        assert_eq!(canonical_arch("REGTESTA").as_deref(), Some("regtest-aliased"));
        assert_eq!(canonical_arch("regtest-aliased").as_deref(), Some("regtest-aliased"));
        let m = super::super::by_name_shared("RegTestA").expect("alias resolves to the model");
        assert_eq!(m.name, "regtesta");
    }

    #[test]
    fn builtin_aliases_are_canonicalized_here() {
        assert_eq!(canonical_arch("SKYLAKE").as_deref(), Some("skl"));
        assert_eq!(canonical_arch("znver1").as_deref(), Some("zen"));
        assert_eq!(canonical_arch("Haswell").as_deref(), Some("hsw"));
        assert_eq!(canonical_arch("THUNDERX2").as_deref(), Some("tx2"));
        assert_eq!(canonical_arch("rv64gc").as_deref(), Some("rv64"));
        assert_eq!(canonical_arch("m1max"), None);
    }

    #[test]
    fn curated_aliases_require_a_registered_target() {
        // `icelake` only resolves once an `icl` model is registered
        // (and this test is the only one to register it).
        assert_eq!(canonical_arch("regtest-nonexistent"), None);
        register_model_text("icl", &MINI.replace("regtesta", "icl"));
        assert_eq!(canonical_arch("IceLake").as_deref(), Some("icl"));
    }

    #[test]
    fn malformed_registered_text_reads_as_unknown() {
        register_model_text("regtest-bad", "arch regtestbad \"X\"\nbogus directive\n");
        assert!(lookup("regtest-bad").is_none());
        // And it is dropped, not retried forever.
        assert_eq!(canonical_arch("regtest-bad"), None);
    }

    #[test]
    fn scan_registers_mdb_files_by_stem() {
        let dir = std::env::temp_dir().join(format!("osaca-regtest-scan-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("RegTest-Scan.mdb"), MINI.replace("regtesta", "regtestscan"))
            .unwrap();
        std::fs::write(dir.join("notes.txt"), "not a model").unwrap();
        let reloads = reload_count();
        let names = scan_models_dir(&dir).unwrap();
        assert_eq!(names, vec!["regtest-scan".to_string()]);
        assert_eq!(reload_count(), reloads + 1);
        let m = super::super::by_name_shared("regtest-scan").expect("scanned model resolves");
        assert_eq!(m.name, "regtestscan");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
