//! Port sets as bitmasks. Machine models have at most 16 ports (SKL uses
//! 9 incl. the divider pseudo-port, Zen 11).

use std::fmt;

/// A set of ports a µ-op may be scheduled to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct PortMask(pub u16);

impl PortMask {
    pub const EMPTY: PortMask = PortMask(0);

    pub fn single(port: usize) -> Self {
        debug_assert!(port < 16);
        PortMask(1 << port)
    }

    pub fn from_ports(ports: &[usize]) -> Self {
        let mut m = 0u16;
        for &p in ports {
            debug_assert!(p < 16);
            m |= 1 << p;
        }
        PortMask(m)
    }

    pub fn contains(self, port: usize) -> bool {
        self.0 & (1 << port) != 0
    }

    pub fn count(self) -> u32 {
        self.0.count_ones()
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    pub fn union(self, other: PortMask) -> PortMask {
        PortMask(self.0 | other.0)
    }

    pub fn intersects(self, other: PortMask) -> bool {
        self.0 & other.0 != 0
    }

    /// Iterate over the port indices in the set, ascending.
    pub fn iter(self) -> impl Iterator<Item = usize> {
        (0..16).filter(move |&p| self.contains(p))
    }
}

impl fmt::Display for PortMask {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ports: Vec<String> = self.iter().map(|p| p.to_string()).collect();
        write!(f, "{{{}}}", ports.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_ops() {
        let m = PortMask::from_ports(&[0, 1, 5, 6]);
        assert_eq!(m.count(), 4);
        assert!(m.contains(5));
        assert!(!m.contains(2));
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![0, 1, 5, 6]);
    }

    #[test]
    fn union_and_intersect() {
        let a = PortMask::from_ports(&[2, 3]);
        let b = PortMask::from_ports(&[3, 7]);
        assert!(a.intersects(b));
        assert_eq!(a.union(b).count(), 3);
        assert!(!a.intersects(PortMask::single(4)));
    }

    #[test]
    fn display() {
        assert_eq!(PortMask::from_ports(&[2, 3]).to_string(), "{2,3}");
        assert_eq!(PortMask::EMPTY.to_string(), "{}");
    }
}
