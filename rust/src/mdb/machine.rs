//! The machine model: ports, parameters, entries, and form resolution.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::isa::{Instruction, InstructionForm, Isa};

use super::entry::{FormEntry, Provenance, ResolvedUops, Uop, UopKind};
use super::index::FormIndex;
use super::port::PortMask;

/// Microarchitectural parameters consumed by the simulator substrate.
/// Documented values for SKL/Zen; see data/*.mdb.
#[derive(Debug, Clone)]
pub struct CoreParams {
    /// Reorder-buffer entries (in-flight µ-ops).
    pub rob_size: usize,
    /// Scheduler/reservation-station entries.
    pub scheduler_size: usize,
    /// µ-ops renamed/allocated per cycle (pipeline width).
    pub rename_width: usize,
    /// µ-ops retired per cycle.
    pub retire_width: usize,
    /// L1 load-to-use latency (all loads hit L1 — paper assumption 1).
    pub load_latency: u32,
    /// Store-to-load forwarding latency: the penalty a load pays when its
    /// address matches an in-flight/recent store. This is what blows up
    /// the -O1 π kernel (paper §III-B).
    pub store_forward_latency: u32,
    /// Simulator-only scale on divider occupancy: models the not-fully-
    /// pipelined real divider that the analytic model's fixed occupancy
    /// underestimates (paper observes Zen ~20% slower than predicted).
    pub sim_divider_scale: f32,
    /// Load/store-queue entries (loads + store-address µ-ops in flight).
    /// Only consulted by the opt-in cache-aware simulation mode
    /// (`sim::mem`); the default infinite-L1 mode never gates on it.
    pub lsq_size: usize,
    /// Line-fill buffers: outstanding cache-line transfers a core can
    /// overlap (memory-level parallelism divisor of the analytic
    /// cycles-per-line model in `sim::mem`).
    pub lfb: u32,
}

impl Default for CoreParams {
    fn default() -> Self {
        CoreParams {
            rob_size: 224,
            scheduler_size: 97,
            rename_width: 4,
            retire_width: 4,
            load_latency: 4,
            store_forward_latency: 5,
            sim_divider_scale: 1.0,
            lsq_size: 72,
            lfb: 8,
        }
    }
}

/// One level of the parametric memory hierarchy (`cache` stanza in a
/// `.mdb` file), innermost (L1) first. `latency_cy` is the full
/// load-to-use latency when the working set resides in this level —
/// NOT the incremental hop cost; the ECM decomposition in `sim::mem`
/// derives the per-line transfer cost from latency *deltas*.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheLevel {
    /// Level name (`l1`, `l2`, `l3`); the CLI spec grammar keys
    /// overrides on it.
    pub name: String,
    /// Capacity in bytes.
    pub size_bytes: u64,
    /// Cache-line size in bytes.
    pub line_bytes: u32,
    /// Load-to-use latency (cycles) for a working set resident here.
    pub latency_cy: u32,
    /// Associativity (ways) — carried for completeness/serialization;
    /// the capacity model is fully-associative.
    pub assoc: u32,
}

/// A full machine model (one per microarchitecture).
#[derive(Debug)]
pub struct MachineModel {
    /// Short name used on the CLI (`skl`, `zen`, `tx2`, `rv64`).
    pub name: String,
    /// Human-readable name ("Intel Skylake").
    pub arch_name: String,
    /// Instruction-set architecture the model describes (`isa` directive
    /// in the `.mdb` file; defaults to x86). Kernels resolve against a
    /// model only when their ISA matches, and the synthesis fallbacks
    /// (x86 suffix normalization, 256-bit splitting, mem-form synthesis)
    /// are keyed on it so they can never fire on foreign-ISA forms.
    pub isa: Isa,
    /// Port display names, index = port id used in masks.
    pub ports: Vec<String>,
    /// Clock frequency used to convert cycles <-> time (paper: 1.8 GHz).
    pub frequency_ghz: f64,
    /// Zen executes 256-bit AVX as two 128-bit µ-op pairs (paper §III-A).
    pub avx256_split: bool,
    /// Zen AGU sharing: one load µ-op can hide behind each store's AGU
    /// slot in the analyzer's pressure accounting (paper Table IV).
    pub hide_load_behind_store: bool,
    /// Simulator: eliminate zeroing idioms at rename (real cores do; the
    /// analyzer deliberately does not — §III-B discrepancy).
    pub sim_zero_idiom_elim: bool,
    /// Simulator: cmp/test + jcc macro-fusion.
    pub sim_macro_fusion: bool,
    /// Simulator: reg-reg move elimination at rename.
    pub sim_move_elim: bool,
    /// Simulator: store-data µ-ops go to the store queue, not an
    /// execution port (Zen LS pipes — see data/zen.mdb header). The
    /// analyzer still charges them per the paper's Table IV convention.
    pub sim_store_data_free: bool,
    /// Ports that execute load µ-ops (used for mem-form synthesis).
    pub load_ports: PortMask,
    /// Ports for store-data µ-ops.
    pub store_data_ports: PortMask,
    /// Ports for store-AGU µ-ops with *indexed* addressing.
    pub store_agu_ports: PortMask,
    /// Ports for store-AGU µ-ops with *simple* addressing (SKL port 7).
    pub store_agu_simple_ports: PortMask,
    pub params: CoreParams,
    /// Parametric cache hierarchy (`cache` stanzas), innermost first.
    /// Empty for models without one; the cache-aware mode then requires
    /// a full `--mem-model` spec.
    pub caches: Vec<CacheLevel>,
    /// Main-memory load-to-use latency in cycles (`cache mem lat=N`);
    /// 0 when the model declares no hierarchy.
    pub mem_latency_cy: u32,
    pub entries: HashMap<InstructionForm, FormEntry>,
    /// Per-machine form-resolution cache (see `mdb::index`). Replaced
    /// wholesale by [`MachineModel::insert`]; fresh on every clone.
    pub(crate) index: Arc<FormIndex>,
}

impl Clone for MachineModel {
    /// Clones start with a **fresh** resolution cache: a clone may be
    /// mutated (builder workflows strip and re-learn entries), and a
    /// shared cache would serve stale resolutions afterwards.
    fn clone(&self) -> Self {
        MachineModel {
            name: self.name.clone(),
            arch_name: self.arch_name.clone(),
            isa: self.isa,
            ports: self.ports.clone(),
            frequency_ghz: self.frequency_ghz,
            avx256_split: self.avx256_split,
            hide_load_behind_store: self.hide_load_behind_store,
            sim_zero_idiom_elim: self.sim_zero_idiom_elim,
            sim_macro_fusion: self.sim_macro_fusion,
            sim_move_elim: self.sim_move_elim,
            sim_store_data_free: self.sim_store_data_free,
            load_ports: self.load_ports,
            store_data_ports: self.store_data_ports,
            store_agu_ports: self.store_agu_ports,
            store_agu_simple_ports: self.store_agu_simple_ports,
            params: self.params.clone(),
            caches: self.caches.clone(),
            mem_latency_cy: self.mem_latency_cy,
            entries: self.entries.clone(),
            index: Arc::new(FormIndex::default()),
        }
    }
}

impl MachineModel {
    pub fn port_index(&self, name: &str) -> Option<usize> {
        self.ports.iter().position(|p| p.eq_ignore_ascii_case(name))
    }

    pub fn n_ports(&self) -> usize {
        self.ports.len()
    }

    /// Divider pseudo-ports (named `*DV*`), excluded from issue-width
    /// accounting in the simulator.
    pub fn divider_ports(&self) -> PortMask {
        let mut m = PortMask::EMPTY;
        for (i, p) in self.ports.iter().enumerate() {
            if p.contains("DV") {
                m = m.union(PortMask::single(i));
            }
        }
        m
    }

    pub fn insert(&mut self, entry: FormEntry) {
        self.entries.insert(entry.form.clone(), entry);
        // The entry set changed: drop the resolution cache. It rebuilds
        // lazily on the next resolve (or via `prime_resolution_index`).
        self.index = Arc::new(FormIndex::default());
    }

    /// Build the direct tier of the resolution cache now instead of on
    /// the first resolve. Called at `.mdb` parse time so built-in models
    /// come up with every database form pre-resolved and interned.
    pub fn prime_resolution_index(&self) {
        let _ = self.direct_index();
    }

    /// Fresh (non-cached) syntheses this model instance has performed.
    /// Flat across repeated analyses of the same kernel — asserted by
    /// `tests/perf_caches.rs` and the hotpath bench.
    pub fn resolution_miss_count(&self) -> usize {
        self.index.miss_count()
    }

    fn direct_index(&self) -> &HashMap<InstructionForm, Arc<ResolvedUops>> {
        self.index.direct_or_init(|| {
            self.entries
                .iter()
                .map(|(f, e)| {
                    let r = ResolvedUops { entry: e.clone(), provenance: Provenance::Direct };
                    (f.clone(), Arc::new(r))
                })
                .collect()
        })
    }

    /// Resolve the µ-ops for a concrete instruction, applying the
    /// synthesis fallbacks in order:
    /// 1. direct hit (pre-resolved, interned — no clone);
    /// 2. size-suffix normalization for scalar-int mnemonics
    ///    (`addl $1,%eax` -> `add-imm_r32` via `add-imm_r`);
    /// 3. 256-bit from 128-bit by µ-op doubling (when `avx256_split`);
    /// 4. memory form from register form + load/store µ-ops.
    ///
    /// Branches resolve to a zero-µ-op pseudo-entry when fused.
    ///
    /// Synthesized resolutions (2-4) are memoized per
    /// `(form, simple-address)` — the only instruction context beyond
    /// the form that affects synthesis — so repeated resolution of the
    /// same kernel is a lock-light cache hit.
    pub fn resolve(&self, ins: &Instruction) -> Result<Arc<ResolvedUops>> {
        // ISA guard: a foreign-ISA instruction must never hit the direct
        // tier by coincidental form spelling, nor trigger this model's
        // synthesis rules (cross-ISA cache pollution would follow).
        if ins.isa != self.isa {
            return Err(anyhow!(
                "ISA mismatch: {} instruction `{ins}` (line {}) cannot resolve against the {} model `{}`",
                ins.isa,
                ins.line,
                self.isa,
                self.name
            ));
        }
        let form = ins.form();
        if let Some(r) = self.direct_index().get(&form) {
            return Ok(Arc::clone(r));
        }
        let simple_addr = ins.mem_operand().map(|m| m.is_simple()).unwrap_or(false);
        if let Some(r) = self.index.synth_get(&form, simple_addr) {
            return Ok(r);
        }
        let fresh = self.resolve_fresh(ins, &form)?;
        Ok(self.index.synth_insert(form, simple_addr, fresh))
    }

    /// The uncached synthesis fallbacks (steps 2-4 of [`resolve`]).
    ///
    /// Every fallback is x86-specific (AT&T size suffixes, AVX 256-bit
    /// halving, one-mem-operand synthesis), so models for other ISAs go
    /// straight to the database-miss error: an AArch64 or RISC-V form
    /// either hits the direct tier or fails loudly.
    fn resolve_fresh(&self, ins: &Instruction, form: &InstructionForm) -> Result<ResolvedUops> {
        if self.isa == Isa::X86 {
            // 2. scalar-int suffix normalization.
            if let Some(e) = self.suffix_normalized(form) {
                return Ok(ResolvedUops { entry: e, provenance: Provenance::SynthesizedSuffix });
            }
            // 3. ymm from xmm when the architecture splits 256-bit ops.
            if self.avx256_split && form.sig.0.contains("ymm") {
                let xmm_form = InstructionForm {
                    mnemonic: form.mnemonic.clone(),
                    sig: crate::isa::OperandSig(form.sig.0.replace("ymm", "xmm")),
                };
                if let Ok(base) = self.resolve_form_only(&xmm_form) {
                    let mut uops = base.uops.clone();
                    uops.extend(base.uops.iter().cloned());
                    let entry = FormEntry {
                        form: form.clone(),
                        latency: base.latency, // halves execute independently
                        throughput: base.throughput * 2.0,
                        uops,
                    };
                    return Ok(ResolvedUops { entry, provenance: Provenance::SynthesizedSplit });
                }
            }
            // 4. memory-form synthesis from the register form.
            if form.sig.0.contains("mem") {
                if let Some(resolved) = self.synthesize_mem(ins, form)? {
                    return Ok(resolved);
                }
            }
        }
        Err(anyhow!(
            "no database entry for instruction form `{form}` (line {}: `{ins}`) on {}",
            ins.line,
            self.name
        ))
    }

    /// Resolve an abstract form with suffix + split fallbacks but without
    /// an instruction context (used by split synthesis internally).
    fn resolve_form_only(&self, form: &InstructionForm) -> Result<FormEntry> {
        if let Some(e) = self.entries.get(form) {
            return Ok(e.clone());
        }
        self.suffix_normalized(form)
            .ok_or_else(|| anyhow!("no entry for `{form}`"))
    }

    fn suffix_normalized(&self, form: &InstructionForm) -> Option<FormEntry> {
        const SUFFIXES: [char; 4] = ['b', 'w', 'l', 'q'];
        let m = &form.mnemonic;
        if m.len() < 3 || m.starts_with('v') {
            return None;
        }
        // Generalize GP width in the signature: r32/r64/r16/r8 -> r.
        let gsig = form
            .sig
            .0
            .replace("r64", "r")
            .replace("r32", "r")
            .replace("r16", "r")
            .replace("r8", "r");
        // Try the mnemonic as-is first (covers unsuffixed spellings like
        // `add $1, %esi`), then with the AT&T size suffix stripped
        // (`addl` -> `add`).
        let key = InstructionForm::new(m, &gsig);
        if let Some(e) = self.entries.get(&key) {
            return Some(FormEntry { form: form.clone(), ..e.clone() });
        }
        let last = m.chars().last()?;
        if !SUFFIXES.contains(&last) {
            return None;
        }
        let stem = &m[..m.len() - 1];
        let key = InstructionForm::new(stem, &gsig);
        self.entries.get(&key).map(|e| FormEntry { form: form.clone(), ..e.clone() })
    }

    fn synthesize_mem(
        &self,
        ins: &Instruction,
        form: &InstructionForm,
    ) -> Result<Option<ResolvedUops>> {
        // Replace `mem` with the width class of the widest register
        // operand (reg form), then append load / store µ-ops.
        let reg_sig = match ins.vector_width() {
            256 => form.sig.0.replace("mem", "ymm"),
            128 => form.sig.0.replace("mem", "xmm"),
            _ => {
                // Scalar int: mem -> matching GP class of dest.
                let cls = ins
                    .operands
                    .iter()
                    .filter_map(|o| o.reg())
                    .map(|r| r.class.sig())
                    .next()
                    .unwrap_or("r64");
                form.sig.0.replace("mem", cls)
            }
        };
        let reg_form = InstructionForm::new(&form.mnemonic, &reg_sig);
        let base = match self.resolve_form_only(&reg_form) {
            Ok(e) => e,
            Err(_) if self.avx256_split && ins.vector_width() == 256 => {
                // Splitting architectures may only carry the 128-bit
                // register form; the doubling below restores the width.
                let xmm_form =
                    InstructionForm::new(&form.mnemonic, &reg_sig.replace("ymm", "xmm"));
                match self.resolve_form_only(&xmm_form) {
                    Ok(e) => e,
                    Err(_) => return Ok(None),
                }
            }
            Err(_) => return Ok(None),
        };
        let mut uops = base.uops.clone();
        // Latency stays the register-chain latency (paper §II-C: the
        // latency benchmark chains through registers; the load path is
        // modeled by the load µ-op itself in the simulator).
        let latency = base.latency;
        let mut provenance = Provenance::SynthesizedMem;
        if ins.is_store() {
            let agu = if ins.mem_operand().map(|m| m.is_simple()).unwrap_or(false)
                && !self.store_agu_simple_ports.is_empty()
            {
                self.store_agu_simple_ports
            } else {
                self.store_agu_ports
            };
            uops.push(Uop { kind: UopKind::StoreData, ports: self.store_data_ports, occupancy: 1.0 });
            uops.push(Uop { kind: UopKind::StoreAgu, ports: agu, occupancy: 1.0 });
        } else {
            uops.push(Uop { kind: UopKind::Load, ports: self.load_ports, occupancy: 1.0 });
        }
        // A synthesized split of a mem form doubles afterwards via
        // resolve(); here we only handle the direct case.
        if self.avx256_split && ins.vector_width() == 256 {
            let doubled: Vec<Uop> = uops.iter().chain(uops.iter()).cloned().collect();
            uops = doubled;
            provenance = Provenance::SynthesizedSplit;
        }
        let entry = FormEntry { form: form.clone(), latency, throughput: 0.0, uops };
        Ok(Some(ResolvedUops { entry, provenance }))
    }

    /// All forms currently in the database, sorted (for reports/dumps).
    pub fn forms(&self) -> Vec<&InstructionForm> {
        let mut v: Vec<_> = self.entries.keys().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::super::{skylake, zen};
    use super::*;
    use crate::asm::parser::parse_instruction;
    use crate::mdb::entry::Provenance;

    fn ins(s: &str) -> Instruction {
        parse_instruction(s, 1).unwrap()
    }

    #[test]
    fn direct_resolution() {
        let skl = skylake();
        let r = skl.resolve(&ins("vaddpd %xmm1, %xmm2, %xmm3")).unwrap();
        assert_eq!(r.provenance, Provenance::Direct);
        assert!((r.entry.implied_rtp() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn suffix_normalization() {
        let skl = skylake();
        let r = skl.resolve(&ins("addl $1, %ecx")).unwrap();
        assert_eq!(r.provenance, Provenance::SynthesizedSuffix);
        assert_eq!(r.entry.uops.len(), 1);
        assert_eq!(r.entry.uops[0].ports.count(), 4); // P0156
    }

    #[test]
    fn zen_splits_ymm() {
        let z = zen();
        let r = z.resolve(&ins("vaddpd %ymm1, %ymm2, %ymm3")).unwrap();
        assert_eq!(r.provenance, Provenance::SynthesizedSplit);
        // xmm form has 1 µ-op -> ymm has 2.
        assert_eq!(r.entry.uops.len(), 2);
    }

    #[test]
    fn skl_does_not_split_ymm() {
        let skl = skylake();
        let r = skl.resolve(&ins("vaddpd %ymm1, %ymm2, %ymm3")).unwrap();
        assert_eq!(r.provenance, Provenance::Direct);
        assert_eq!(r.entry.uops.len(), 1);
    }

    #[test]
    fn mem_synthesis_adds_load() {
        let skl = skylake();
        // vsubpd mem form is not in the DB; synthesized from reg form.
        let r = skl.resolve(&ins("vsubpd (%rax), %xmm1, %xmm2")).unwrap();
        assert_eq!(r.provenance, Provenance::SynthesizedMem);
        assert!(r.entry.uops.iter().any(|u| u.kind == UopKind::Load));
        let reg = skl.resolve(&ins("vsubpd %xmm0, %xmm1, %xmm2")).unwrap();
        assert_eq!(r.entry.uops.len(), reg.entry.uops.len() + 1);
        assert_eq!(r.entry.latency, reg.entry.latency);
    }

    #[test]
    fn unknown_form_errors() {
        let skl = skylake();
        assert!(skl.resolve(&ins("frobnicate %xmm0, %xmm1")).is_err());
    }

    #[test]
    fn divider_ports_detected() {
        assert_eq!(skylake().divider_ports().count(), 1);
        assert_eq!(zen().divider_ports().count(), 1);
    }

    #[test]
    fn synthesized_resolutions_are_interned() {
        let skl = skylake();
        // vsubpd mem form is synthesized; two instructions with the same
        // form share one interned resolution and cost one miss total.
        let a = skl.resolve(&ins("vsubpd (%rax), %xmm1, %xmm2")).unwrap();
        let misses = skl.resolution_miss_count();
        assert!(misses >= 1);
        let b = skl.resolve(&ins("vsubpd 8(%rbx), %xmm5, %xmm6")).unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
        assert_eq!(skl.resolution_miss_count(), misses);
        // Direct hits are interned at index build time, never misses.
        let c = skl.resolve(&ins("vaddpd %xmm1, %xmm2, %xmm3")).unwrap();
        let d = skl.resolve(&ins("vaddpd %xmm4, %xmm5, %xmm6")).unwrap();
        assert!(std::sync::Arc::ptr_eq(&c, &d));
        assert_eq!(skl.resolution_miss_count(), misses);
    }

    #[test]
    fn simple_and_indexed_stores_cache_separately() {
        use super::super::haswell;
        // On Haswell the store AGU port set depends on the addressing
        // mode, so the two contexts must not share a cache slot.
        let hsw = haswell();
        let simple = hsw.resolve(&ins("vmovapd %ymm0, 32(%rdi)")).unwrap();
        let indexed = hsw.resolve(&ins("vmovapd %ymm0, (%rdi,%rax,8)")).unwrap();
        let agu_of = |r: &ResolvedUops| {
            r.entry.uops.iter().find(|u| u.kind == UopKind::StoreAgu).unwrap().ports
        };
        assert_ne!(agu_of(&simple), agu_of(&indexed));
        // And the cached re-resolve returns the same interned entries.
        let simple2 = hsw.resolve(&ins("vmovapd %ymm1, 64(%rsi)")).unwrap();
        assert!(std::sync::Arc::ptr_eq(&simple, &simple2));
    }

    #[test]
    fn insert_invalidates_resolution_cache() {
        let mut m = skylake();
        assert!(m.resolve(&ins("frobnicate %xmm0, %xmm1")).is_err());
        let entry = FormEntry {
            form: InstructionForm::new("frobnicate", "xmm_xmm"),
            latency: 2.0,
            throughput: 1.0,
            uops: vec![Uop {
                kind: UopKind::Compute,
                ports: PortMask::single(0),
                occupancy: 1.0,
            }],
        };
        m.insert(entry);
        let r = m.resolve(&ins("frobnicate %xmm0, %xmm1")).unwrap();
        assert_eq!(r.provenance, Provenance::Direct);
    }
}
