//! Machine-model database (paper §II).
//!
//! A machine model is: a set of ports (including divider pseudo-ports
//! like Skylake's `0DV`), per-instruction-form entries (latency,
//! reciprocal throughput, µ-op decomposition with admissible-port sets),
//! plus architecture parameters used by the simulator substrate (ROB and
//! scheduler sizes, load latency, store-forward latency, ...).
//!
//! Models ship as `.mdb` text files embedded in the binary
//! (`data/skl.mdb`, `data/zen.mdb`) and can be written/extended by the
//! model builder (paper §II-C workflow).

pub mod entry;
pub mod format;
pub mod machine;
pub mod port;

pub use entry::{FormEntry, Provenance, ResolvedUops, Uop, UopKind};
pub use machine::MachineModel;
pub use port::PortMask;

/// Built-in Intel Skylake model (Fig. 2), compiled from the paper's
/// tables and Agner Fog-style documentation values.
pub fn skylake() -> MachineModel {
    MachineModel::parse(include_str!("data/skl.mdb")).expect("embedded skl.mdb is valid")
}

/// Built-in AMD Zen model (Fig. 3).
pub fn zen() -> MachineModel {
    MachineModel::parse(include_str!("data/zen.mdb")).expect("embedded zen.mdb is valid")
}

/// Built-in Intel Haswell model — implements the paper's §IV-B
/// future-work item: addressing-mode-aware store AGUs (port 7).
pub fn haswell() -> MachineModel {
    MachineModel::parse(include_str!("data/hsw.mdb")).expect("embedded hsw.mdb is valid")
}

/// Look up a built-in model by CLI name (`skl`, `zen`, `hsw`).
pub fn by_name(name: &str) -> Option<MachineModel> {
    match name.to_ascii_lowercase().as_str() {
        "skl" | "skylake" => Some(skylake()),
        "zen" | "znver1" => Some(zen()),
        "hsw" | "haswell" => Some(haswell()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_models_parse() {
        let skl = skylake();
        assert_eq!(skl.name, "skl");
        assert_eq!(skl.ports.len(), 9); // P0..P7 + 0DV
        let zen = zen();
        assert_eq!(zen.name, "zen");
        assert_eq!(zen.ports.len(), 11); // FP0..3, ALU0..3, AGU0..1, DV
        assert!(zen.avx256_split);
        assert!(!skl.avx256_split);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("skl").is_some());
        assert!(by_name("SKYLAKE").is_some());
        assert!(by_name("zen").is_some());
        assert!(by_name("hsw").is_some());
        assert!(by_name("cascadelake").is_none());
    }

    #[test]
    fn haswell_stores_are_addressing_mode_aware() {
        use crate::asm::parser::parse_instruction;
        let hsw = haswell();
        // Simple address: AGU may use the dedicated port 7.
        let simple = parse_instruction("vmovapd %ymm0, 32(%rdi)", 1).unwrap();
        let r = hsw.resolve(&simple).unwrap();
        let agu = r.entry.uops.iter().find(|u| u.kind == UopKind::StoreAgu).unwrap();
        assert!(agu.ports.contains(hsw.port_index("P7").unwrap()));
        assert_eq!(agu.ports.count(), 3); // P2|P3|P7
        // Indexed address: port 7 cannot generate it.
        let indexed = parse_instruction("vmovapd %ymm0, (%rdi,%rax,8)", 1).unwrap();
        let r = hsw.resolve(&indexed).unwrap();
        let agu = r.entry.uops.iter().find(|u| u.kind == UopKind::StoreAgu).unwrap();
        assert!(!agu.ports.contains(hsw.port_index("P7").unwrap()));
        assert_eq!(agu.ports.count(), 2); // P2|P3
    }

    #[test]
    fn haswell_add_is_port1_bound() {
        use crate::isa::InstructionForm;
        let hsw = haswell();
        let e = &hsw.entries[&InstructionForm::new("vaddpd", "xmm_xmm_xmm")];
        assert!((e.implied_rtp() - 1.0).abs() < 1e-6);
    }
}
