//! Machine-model database (paper §II).
//!
//! A machine model is: a set of ports (including divider pseudo-ports
//! like Skylake's `0DV`), per-instruction-form entries (latency,
//! reciprocal throughput, µ-op decomposition with admissible-port sets),
//! plus architecture parameters used by the simulator substrate (ROB and
//! scheduler sizes, load latency, store-forward latency, ...).
//!
//! Models ship as `.mdb` text files embedded in the binary
//! (`data/skl.mdb`, `data/zen.mdb`, `data/hsw.mdb`, and the AArch64
//! `data/tx2.mdb`) and can be written/extended by the model builder
//! (paper §II-C workflow). A model's `isa` directive selects the
//! assembly syntax and gates the synthesis fallbacks (see
//! `MachineModel::isa`).
//!
//! Built-in models are parsed **once** per process and shared as
//! `Arc<MachineModel>` (the registry behind `osaca::api::Engine`); the
//! by-value accessors below are compatibility shims that clone the
//! cached model instead of re-parsing the embedded text.

pub mod entry;
pub mod format;
pub(crate) mod index;
pub mod machine;
pub mod port;
pub mod registry;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

pub use entry::{FormEntry, Provenance, ResolvedUops, Uop, UopKind};
pub use machine::MachineModel;
pub use port::PortMask;
pub use registry::{
    canonical_arch, register_model_text, registry_names, registry_parse_count, reload_count,
    scan_models_dir,
};

/// Number of times an embedded `.mdb` text has actually been parsed.
/// At most one per built-in model per process — asserted by tests and
/// the hotpath bench so a regression back to parse-per-call is caught.
static BUILTIN_PARSES: AtomicUsize = AtomicUsize::new(0);

/// How many embedded-model parses have happened so far (diagnostics).
pub fn builtin_parse_count() -> usize {
    BUILTIN_PARSES.load(Ordering::Relaxed)
}

/// Process-wide count of *fresh* form resolutions — synthesis work that
/// was not served from a `FormIndex` cache. In the spirit of
/// [`builtin_parse_count`]: flat across repeated analyses of the same
/// kernels, so tests and `benches/hotpath.rs` can assert the warm path
/// performs zero new resolutions. (Per-model instance counts are on
/// [`MachineModel::resolution_miss_count`].)
pub fn resolution_miss_count() -> usize {
    RESOLUTION_MISSES.load(Ordering::Relaxed)
}

static RESOLUTION_MISSES: AtomicUsize = AtomicUsize::new(0);

pub(crate) fn note_resolution_miss() {
    RESOLUTION_MISSES.fetch_add(1, Ordering::Relaxed);
}

fn parse_builtin(text: &str, which: &str) -> Arc<MachineModel> {
    BUILTIN_PARSES.fetch_add(1, Ordering::Relaxed);
    match MachineModel::parse(text) {
        Ok(m) => Arc::new(m),
        Err(e) => panic!("embedded {which}.mdb is valid: {e:#}"),
    }
}

fn skl_shared() -> &'static Arc<MachineModel> {
    static M: OnceLock<Arc<MachineModel>> = OnceLock::new();
    M.get_or_init(|| parse_builtin(include_str!("data/skl.mdb"), "skl"))
}

fn zen_shared() -> &'static Arc<MachineModel> {
    static M: OnceLock<Arc<MachineModel>> = OnceLock::new();
    M.get_or_init(|| parse_builtin(include_str!("data/zen.mdb"), "zen"))
}

fn hsw_shared() -> &'static Arc<MachineModel> {
    static M: OnceLock<Arc<MachineModel>> = OnceLock::new();
    M.get_or_init(|| parse_builtin(include_str!("data/hsw.mdb"), "hsw"))
}

fn tx2_shared() -> &'static Arc<MachineModel> {
    static M: OnceLock<Arc<MachineModel>> = OnceLock::new();
    M.get_or_init(|| parse_builtin(include_str!("data/tx2.mdb"), "tx2"))
}

fn rv64_shared() -> &'static Arc<MachineModel> {
    static M: OnceLock<Arc<MachineModel>> = OnceLock::new();
    M.get_or_init(|| parse_builtin(include_str!("data/rv64.mdb"), "rv64"))
}

/// Canonical CLI names of the built-in models.
pub fn builtin_names() -> &'static [&'static str] {
    &["hsw", "rv64", "skl", "tx2", "zen"]
}

/// Shared handle to a model by CLI name: the five built-ins (`skl`,
/// `zen`, `hsw`, `tx2`, `rv64` plus long aliases) and every
/// dynamically registered model (`registry`), all through the one
/// canonical alias table. This is the lookup the `api::Engine`
/// registry uses: no parsing (after first use), no copying.
pub fn by_name_shared(name: &str) -> Option<Arc<MachineModel>> {
    match registry::canonical_arch(name)?.as_str() {
        "skl" => Some(skl_shared().clone()),
        "zen" => Some(zen_shared().clone()),
        "hsw" => Some(hsw_shared().clone()),
        "tx2" => Some(tx2_shared().clone()),
        "rv64" => Some(rv64_shared().clone()),
        dynamic => registry::lookup(dynamic),
    }
}

/// Built-in Intel Skylake model (Fig. 2), compiled from the paper's
/// tables and Agner Fog-style documentation values.
///
/// Compatibility shim: clones the cached model. Prefer
/// [`by_name_shared`] (or `api::Engine::machine`) for an `Arc` handle.
pub fn skylake() -> MachineModel {
    skl_shared().as_ref().clone()
}

/// Built-in AMD Zen model (Fig. 3). Compatibility shim; see [`skylake`].
pub fn zen() -> MachineModel {
    zen_shared().as_ref().clone()
}

/// Built-in Intel Haswell model — implements the paper's §IV-B
/// future-work item: addressing-mode-aware store AGUs (port 7).
/// Compatibility shim; see [`skylake`].
pub fn haswell() -> MachineModel {
    hsw_shared().as_ref().clone()
}

/// Built-in Marvell/Cavium ThunderX2 (AArch64) model — the outlook
/// item of the paper ("how the method may be generalized to new
/// architectures"), following the 2019 OSACA follow-up's ARM support.
/// Compatibility shim; see [`skylake`].
pub fn thunderx2() -> MachineModel {
    tx2_shared().as_ref().clone()
}

/// Built-in generic RV64GC model — the third backend of the DESIGN.md
/// §7 recipe, with the riscv-sim-derived dual-issue pipe structure
/// (see `data/rv64.mdb`). Compatibility shim; see [`skylake`].
pub fn rv64() -> MachineModel {
    rv64_shared().as_ref().clone()
}

/// Look up a built-in model by CLI name (`skl`, `zen`, `hsw`).
///
/// Compatibility shim returning an owned clone; prefer
/// [`by_name_shared`].
pub fn by_name(name: &str) -> Option<MachineModel> {
    by_name_shared(name).map(|m| m.as_ref().clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_models_parse() {
        let skl = skylake();
        assert_eq!(skl.name, "skl");
        assert_eq!(skl.ports.len(), 9); // P0..P7 + 0DV
        let zen = zen();
        assert_eq!(zen.name, "zen");
        assert_eq!(zen.ports.len(), 11); // FP0..3, ALU0..3, AGU0..1, DV
        assert!(zen.avx256_split);
        assert!(!skl.avx256_split);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("skl").is_some());
        assert!(by_name("SKYLAKE").is_some());
        assert!(by_name("zen").is_some());
        assert!(by_name("hsw").is_some());
        assert!(by_name("tx2").is_some());
        assert!(by_name("thunderx2").is_some());
        assert!(by_name("rv64").is_some());
        assert!(by_name("riscv").is_some());
        assert!(by_name("RV64GC").is_some());
        assert!(by_name("cascadelake").is_none());
    }

    #[test]
    fn rv64_model_is_riscv() {
        use crate::isa::Isa;
        let m = rv64();
        assert_eq!(m.name, "rv64");
        assert_eq!(m.isa, Isa::RiscV);
        assert_eq!(m.ports.len(), 7); // I0 I1 LS B F SD DV
        assert_eq!(m.divider_ports().count(), 1);
        assert!(!m.avx256_split);
        // No flags register -> nothing to macro-fuse; no rename-stage
        // eliminations are modeled for this core.
        assert!(!m.sim_macro_fusion);
        assert_eq!(m.params.rename_width, 2);
        assert_eq!(m.params.retire_width, 2);
        // Every branch form resolves to a real µ-op on the B pipe.
        use crate::isa::InstructionForm;
        let bne = &m.entries[&InstructionForm::new("bne", "x_x_lbl")];
        assert_eq!(bne.uops.len(), 1);
        assert!((bne.implied_rtp() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn tx2_model_is_aarch64() {
        use crate::isa::Isa;
        let m = thunderx2();
        assert_eq!(m.name, "tx2");
        assert_eq!(m.isa, Isa::AArch64);
        assert_eq!(m.ports.len(), 8); // I0 I1 F0 F1 LS0 LS1 SD DV
        assert_eq!(m.divider_ports().count(), 1);
        assert!(!m.avx256_split);
        assert!(m.sim_macro_fusion);
    }

    #[test]
    fn builtin_models_are_cached_not_reparsed() {
        // Warm all three caches, then hammer every accessor: the parse
        // counter must not move.
        let a = by_name_shared("skl").unwrap();
        let _ = by_name_shared("zen").unwrap();
        let _ = by_name_shared("hsw").unwrap();
        let parses = builtin_parse_count();
        assert!(parses >= 3);
        for _ in 0..100 {
            let b = by_name_shared("skylake").unwrap();
            assert!(Arc::ptr_eq(&a, &b));
            let _ = skylake();
            let _ = zen();
            let _ = haswell();
            let _ = by_name("zen");
        }
        assert_eq!(builtin_parse_count(), parses);
    }

    #[test]
    fn haswell_stores_are_addressing_mode_aware() {
        use crate::asm::parser::parse_instruction;
        let hsw = haswell();
        // Simple address: AGU may use the dedicated port 7.
        let simple = parse_instruction("vmovapd %ymm0, 32(%rdi)", 1).unwrap();
        let r = hsw.resolve(&simple).unwrap();
        let agu = r.entry.uops.iter().find(|u| u.kind == UopKind::StoreAgu).unwrap();
        assert!(agu.ports.contains(hsw.port_index("P7").unwrap()));
        assert_eq!(agu.ports.count(), 3); // P2|P3|P7
        // Indexed address: port 7 cannot generate it.
        let indexed = parse_instruction("vmovapd %ymm0, (%rdi,%rax,8)", 1).unwrap();
        let r = hsw.resolve(&indexed).unwrap();
        let agu = r.entry.uops.iter().find(|u| u.kind == UopKind::StoreAgu).unwrap();
        assert!(!agu.ports.contains(hsw.port_index("P7").unwrap()));
        assert_eq!(agu.ports.count(), 2); // P2|P3
    }

    #[test]
    fn haswell_add_is_port1_bound() {
        use crate::isa::InstructionForm;
        let hsw = haswell();
        let e = &hsw.entries[&InstructionForm::new("vaddpd", "xmm_xmm_xmm")];
        assert!((e.implied_rtp() - 1.0).abs() < 1e-6);
    }
}
