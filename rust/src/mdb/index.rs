//! Per-machine form-resolution cache — the `FormIndex`.
//!
//! `MachineModel::resolve` is the hottest query in the system: every
//! analyzer pass, every simulator decode and every baseline encode
//! resolves each kernel instruction against the model, and at serving
//! scale the same forms are resolved millions of times (uops.info treats
//! its form database the same way — a precompiled artifact queried, not
//! recomputed). The index has two tiers:
//!
//! * **direct** — every database form, pre-resolved and interned behind
//!   `Arc<ResolvedUops>` when the model is built (or lazily on the first
//!   resolve after a mutation). A direct hit is one hash lookup and one
//!   atomic refcount bump; no µ-op vectors are cloned.
//! * **synth** — memoized synthesis results (suffix normalization,
//!   mem-form synthesis, 256-bit splitting). The instruction form fully
//!   determines the synthesized entry except for one bit of context:
//!   whether a store's address is *simple* (dedicated simple-store AGU
//!   ports, e.g. Haswell port 7), so the tier is keyed by
//!   `(form, simple_addr)` as two form-keyed maps.
//!
//! Fresh (non-cached) syntheses bump both the per-model and the
//! process-wide miss counters (`MachineModel::resolution_miss_count`,
//! `mdb::resolution_miss_count`) so tests and benches can assert that
//! repeated analyses of a kernel perform zero new resolutions.
//!
//! The index lives behind `Arc` inside `MachineModel`; cloning a model
//! starts a **fresh** index (clones may be mutated — builder workflows
//! strip and re-learn entries), and `MachineModel::insert` replaces the
//! index wholesale. Mutating `MachineModel::entries` directly after
//! resolution has begun on the same instance is not supported.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use crate::isa::InstructionForm;

use super::entry::ResolvedUops;

#[derive(Debug, Default)]
pub(crate) struct FormIndex {
    /// Interned direct resolutions, one per database form.
    direct: OnceLock<HashMap<InstructionForm, Arc<ResolvedUops>>>,
    /// Memoized synthesized resolutions; `[0]` = regular context,
    /// `[1]` = simple-address store context.
    synth: [RwLock<HashMap<InstructionForm, Arc<ResolvedUops>>>; 2],
    /// Fresh syntheses performed through this index.
    misses: AtomicUsize,
}

impl FormIndex {
    /// The direct tier, built on first use from the model's entries.
    pub(crate) fn direct_or_init<F>(
        &self,
        init: F,
    ) -> &HashMap<InstructionForm, Arc<ResolvedUops>>
    where
        F: FnOnce() -> HashMap<InstructionForm, Arc<ResolvedUops>>,
    {
        self.direct.get_or_init(init)
    }

    pub(crate) fn synth_get(
        &self,
        form: &InstructionForm,
        simple_addr: bool,
    ) -> Option<Arc<ResolvedUops>> {
        self.synth[simple_addr as usize]
            .read()
            .expect("form index poisoned")
            .get(form)
            .cloned()
    }

    /// Intern a freshly synthesized resolution. Under a concurrent race
    /// the first insertion wins (both threads synthesized identical
    /// values — synthesis is a pure function of the key).
    pub(crate) fn synth_insert(
        &self,
        form: InstructionForm,
        simple_addr: bool,
        resolved: ResolvedUops,
    ) -> Arc<ResolvedUops> {
        self.misses.fetch_add(1, Ordering::Relaxed);
        super::note_resolution_miss();
        let arc = Arc::new(resolved);
        self.synth[simple_addr as usize]
            .write()
            .expect("form index poisoned")
            .entry(form)
            .or_insert(arc)
            .clone()
    }

    /// Fresh syntheses performed through this index instance.
    pub(crate) fn miss_count(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }
}
