//! `.mdb` text format: parse and serialize machine models.
//!
//! Line-oriented; `#` starts a comment. Grammar (one stanza per file):
//!
//! ```text
//! arch skl "Intel Skylake"
//! freq 1.8
//! ports P0 P1 P2 P3 P4 P5 P6 P7 0DV
//! loadports P2 P3
//! storedataports P4
//! storeaguports P2 P3
//! storeagusimpleports P2 P3 P7
//! flags  hide_load_behind_store avx256_split
//! simflags zero_idiom_elim macro_fusion move_elim
//! param rob 224
//! ...
//! entry vaddpd-xmm_xmm_xmm lat=4 tp=0.5 uops=c@1:P0|P1
//! entry vdivsd-xmm_xmm_xmm lat=13 tp=4 uops=c@1:P0,dv@4:0DV
//! ```

use anyhow::{anyhow, bail, Context, Result};

use crate::isa::{InstructionForm, Isa};

use super::entry::{FormEntry, Uop, UopKind};
use super::machine::{CacheLevel, CoreParams, MachineModel};
use super::port::PortMask;

impl MachineModel {
    /// Parse a machine model from `.mdb` text.
    pub fn parse(src: &str) -> Result<MachineModel> {
        let mut name = None;
        let mut arch_name = String::new();
        let mut isa = Isa::X86;
        let mut ports: Vec<String> = Vec::new();
        let mut frequency_ghz = 1.8f64;
        let mut flags: Vec<String> = Vec::new();
        let mut simflags: Vec<String> = Vec::new();
        let mut params = CoreParams::default();
        let mut load_ports = PortMask::EMPTY;
        let mut store_data_ports = PortMask::EMPTY;
        let mut store_agu_ports = PortMask::EMPTY;
        let mut store_agu_simple_ports = PortMask::EMPTY;
        let mut caches: Vec<CacheLevel> = Vec::new();
        let mut mem_latency_cy = 0u32;
        let mut entry_lines: Vec<(usize, String)> = Vec::new();

        for (lineno, raw) in src.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, rest) = line.split_once(char::is_whitespace).unwrap_or((line, ""));
            let rest = rest.trim();
            match key {
                "arch" => {
                    let (short, pretty) = rest.split_once(char::is_whitespace).unwrap_or((rest, ""));
                    name = Some(short.to_string());
                    arch_name = pretty.trim_matches('"').to_string();
                }
                "isa" => {
                    isa = Isa::parse(rest)
                        .ok_or_else(|| anyhow!("line {}: unknown isa `{rest}`", lineno + 1))?;
                }
                "freq" => frequency_ghz = rest.parse().context("bad freq")?,
                "ports" => ports = rest.split_whitespace().map(str::to_string).collect(),
                "loadports" | "storedataports" | "storeaguports" | "storeagusimpleports" => {
                    let mask = parse_port_list(&ports, rest)
                        .with_context(|| format!("line {}: {key}", lineno + 1))?;
                    match key {
                        "loadports" => load_ports = mask,
                        "storedataports" => store_data_ports = mask,
                        "storeaguports" => store_agu_ports = mask,
                        _ => store_agu_simple_ports = mask,
                    }
                }
                "flags" => flags.extend(rest.split_whitespace().map(str::to_string)),
                "simflags" => simflags.extend(rest.split_whitespace().map(str::to_string)),
                "param" => {
                    let (p, v) = rest
                        .split_once(char::is_whitespace)
                        .ok_or_else(|| anyhow!("line {}: param needs value", lineno + 1))?;
                    let v = v.trim();
                    match p {
                        "rob" => params.rob_size = v.parse()?,
                        "sched" => params.scheduler_size = v.parse()?,
                        "rename_width" => params.rename_width = v.parse()?,
                        "retire_width" => params.retire_width = v.parse()?,
                        "load_latency" => params.load_latency = v.parse()?,
                        "store_forward_latency" => params.store_forward_latency = v.parse()?,
                        "sim_divider_scale" => params.sim_divider_scale = v.parse()?,
                        "lsq" => params.lsq_size = v.parse()?,
                        "lfb" => params.lfb = v.parse()?,
                        other => bail!("line {}: unknown param `{other}`", lineno + 1),
                    }
                }
                "cache" => {
                    parse_cache_line(rest, &mut caches, &mut mem_latency_cy)
                        .with_context(|| format!("line {}: cache", lineno + 1))?;
                }
                "entry" => entry_lines.push((lineno + 1, rest.to_string())),
                other => bail!("line {}: unknown directive `{other}`", lineno + 1),
            }
        }

        let name = name.ok_or_else(|| anyhow!("missing `arch` line"))?;
        if ports.is_empty() {
            bail!("missing `ports` line");
        }
        if ports.len() > 16 {
            bail!("at most 16 ports supported, got {}", ports.len());
        }
        let mut model = MachineModel {
            name,
            arch_name,
            isa,
            ports,
            frequency_ghz,
            avx256_split: flags.iter().any(|f| f == "avx256_split"),
            hide_load_behind_store: flags.iter().any(|f| f == "hide_load_behind_store"),
            sim_zero_idiom_elim: simflags.iter().any(|f| f == "zero_idiom_elim"),
            sim_macro_fusion: simflags.iter().any(|f| f == "macro_fusion"),
            sim_move_elim: simflags.iter().any(|f| f == "move_elim"),
            sim_store_data_free: simflags.iter().any(|f| f == "store_data_free"),
            load_ports,
            store_data_ports,
            store_agu_ports,
            store_agu_simple_ports,
            params,
            caches,
            mem_latency_cy,
            entries: Default::default(),
            index: Default::default(),
        };
        for (lineno, line) in entry_lines {
            let entry = parse_entry(&model, &line).with_context(|| format!("entry line {lineno}"))?;
            model.insert(entry);
        }
        // Pre-resolve and intern every database form now, so the model
        // comes up with a warm direct tier (see `mdb::index`).
        model.prime_resolution_index();
        Ok(model)
    }

    /// Serialize back to `.mdb` text (builder output).
    pub fn serialize(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("arch {} \"{}\"\n", self.name, self.arch_name));
        if self.isa != Isa::X86 {
            out.push_str(&format!("isa {}\n", self.isa.name()));
        }
        out.push_str(&format!("freq {}\n", self.frequency_ghz));
        out.push_str(&format!("ports {}\n", self.ports.join(" ")));
        let plist = |m: PortMask| {
            m.iter().map(|i| self.ports[i].clone()).collect::<Vec<_>>().join(" ")
        };
        out.push_str(&format!("loadports {}\n", plist(self.load_ports)));
        out.push_str(&format!("storedataports {}\n", plist(self.store_data_ports)));
        out.push_str(&format!("storeaguports {}\n", plist(self.store_agu_ports)));
        if !self.store_agu_simple_ports.is_empty() {
            out.push_str(&format!("storeagusimpleports {}\n", plist(self.store_agu_simple_ports)));
        }
        let mut flags = Vec::new();
        if self.avx256_split {
            flags.push("avx256_split");
        }
        if self.hide_load_behind_store {
            flags.push("hide_load_behind_store");
        }
        if !flags.is_empty() {
            out.push_str(&format!("flags {}\n", flags.join(" ")));
        }
        let mut simflags = Vec::new();
        if self.sim_zero_idiom_elim {
            simflags.push("zero_idiom_elim");
        }
        if self.sim_macro_fusion {
            simflags.push("macro_fusion");
        }
        if self.sim_move_elim {
            simflags.push("move_elim");
        }
        if self.sim_store_data_free {
            simflags.push("store_data_free");
        }
        if !simflags.is_empty() {
            out.push_str(&format!("simflags {}\n", simflags.join(" ")));
        }
        let p = &self.params;
        out.push_str(&format!("param rob {}\n", p.rob_size));
        out.push_str(&format!("param sched {}\n", p.scheduler_size));
        out.push_str(&format!("param rename_width {}\n", p.rename_width));
        out.push_str(&format!("param retire_width {}\n", p.retire_width));
        out.push_str(&format!("param load_latency {}\n", p.load_latency));
        out.push_str(&format!("param store_forward_latency {}\n", p.store_forward_latency));
        if (p.sim_divider_scale - 1.0).abs() > 1e-6 {
            out.push_str(&format!("param sim_divider_scale {}\n", p.sim_divider_scale));
        }
        if !self.caches.is_empty() || self.mem_latency_cy != 0 {
            out.push_str(&format!("param lsq {}\n", p.lsq_size));
            out.push_str(&format!("param lfb {}\n", p.lfb));
        }
        for c in &self.caches {
            out.push_str(&format!(
                "cache {} size={} line={} lat={} assoc={}\n",
                c.name,
                fmt_size(c.size_bytes),
                c.line_bytes,
                c.latency_cy,
                c.assoc
            ));
        }
        if self.mem_latency_cy != 0 {
            out.push_str(&format!("cache mem lat={}\n", self.mem_latency_cy));
        }
        let mut forms: Vec<_> = self.entries.values().collect();
        forms.sort_by(|a, b| a.form.cmp(&b.form));
        for e in forms {
            let uops = e
                .uops
                .iter()
                .map(|u| {
                    format!(
                        "{}@{}:{}",
                        u.kind.code(),
                        trim_float(u.occupancy),
                        u.ports.iter().map(|i| self.ports[i].clone()).collect::<Vec<_>>().join("|")
                    )
                })
                .collect::<Vec<_>>()
                .join(",");
            if uops.is_empty() {
                // Port-free entries (branches).
                out.push_str(&format!(
                    "entry {} lat={} tp={}\n",
                    e.form,
                    trim_float(e.latency),
                    trim_float(e.throughput)
                ));
            } else {
                out.push_str(&format!(
                    "entry {} lat={} tp={} uops={}\n",
                    e.form,
                    trim_float(e.latency),
                    trim_float(e.throughput),
                    uops
                ));
            }
        }
        out
    }
}

fn trim_float(v: f32) -> String {
    if (v - v.round()).abs() < 1e-6 {
        format!("{}", v.round() as i64)
    } else {
        format!("{v}")
    }
}

/// Parse a size with an optional binary suffix: `64`, `32K`, `1M`, `8G`.
pub fn parse_size(s: &str) -> Result<u64> {
    let s = s.trim();
    let (digits, shift) = match s.as_bytes().last() {
        Some(b'K' | b'k') => (&s[..s.len() - 1], 10),
        Some(b'M' | b'm') => (&s[..s.len() - 1], 20),
        Some(b'G' | b'g') => (&s[..s.len() - 1], 30),
        _ => (s, 0),
    };
    let n: u64 = digits.trim().parse().with_context(|| format!("bad size `{s}`"))?;
    n.checked_shl(shift).ok_or_else(|| anyhow!("size `{s}` overflows"))
}

/// Render a byte count with the largest exact binary suffix.
pub fn fmt_size(bytes: u64) -> String {
    for (shift, suffix) in [(30u32, "G"), (20, "M"), (10, "K")] {
        if bytes != 0 && bytes % (1u64 << shift) == 0 {
            return format!("{}{}", bytes >> shift, suffix);
        }
    }
    bytes.to_string()
}

/// One `cache` stanza line: `cache l2 size=1M line=64 lat=12 assoc=16`
/// for a level, `cache mem lat=80` for main memory (no capacity).
fn parse_cache_line(rest: &str, caches: &mut Vec<CacheLevel>, mem_latency: &mut u32) -> Result<()> {
    let mut parts = rest.split_whitespace();
    let name = parts.next().ok_or_else(|| anyhow!("cache needs a level name"))?.to_string();
    let mut size = 0u64;
    let mut line = 64u32;
    let mut lat = 0u32;
    let mut assoc = 8u32;
    for kv in parts {
        let (k, v) = kv.split_once('=').ok_or_else(|| anyhow!("bad field `{kv}`"))?;
        match k {
            "size" => size = parse_size(v)?,
            "line" => line = v.parse().context("line")?,
            "lat" => lat = v.parse().context("lat")?,
            "assoc" => assoc = v.parse().context("assoc")?,
            other => bail!("unknown cache field `{other}`"),
        }
    }
    if lat == 0 {
        bail!("cache `{name}` needs lat=N");
    }
    if name.eq_ignore_ascii_case("mem") {
        *mem_latency = lat;
        return Ok(());
    }
    if size == 0 {
        bail!("cache `{name}` needs size=N (only `mem` is unbounded)");
    }
    if line == 0 {
        bail!("cache `{name}` needs a nonzero line size");
    }
    caches.push(CacheLevel { name, size_bytes: size, line_bytes: line, latency_cy: lat, assoc });
    Ok(())
}

fn parse_port_list(ports: &[String], s: &str) -> Result<PortMask> {
    let mut mask = PortMask::EMPTY;
    for name in s.split(['|', ' ']).filter(|p| !p.is_empty()) {
        let idx = ports
            .iter()
            .position(|p| p.eq_ignore_ascii_case(name))
            .ok_or_else(|| anyhow!("unknown port `{name}`"))?;
        mask = mask.union(PortMask::single(idx));
    }
    Ok(mask)
}

fn parse_entry(model: &MachineModel, line: &str) -> Result<FormEntry> {
    let mut parts = line.split_whitespace();
    let form = InstructionForm::parse(parts.next().ok_or_else(|| anyhow!("empty entry"))?);
    let mut latency = 0f32;
    let mut throughput = 0f32;
    let mut uops = Vec::new();
    for kv in parts {
        let (k, v) = kv.split_once('=').ok_or_else(|| anyhow!("bad field `{kv}`"))?;
        match k {
            "lat" => latency = v.parse().context("lat")?,
            "tp" => throughput = v.parse().context("tp")?,
            "uops" => {
                for u in v.split(',') {
                    let (kind_occ, port_s) =
                        u.split_once(':').ok_or_else(|| anyhow!("bad uop `{u}`"))?;
                    let (kind_s, occ_s) =
                        kind_occ.split_once('@').ok_or_else(|| anyhow!("bad uop `{u}`"))?;
                    let kind = UopKind::parse(kind_s).ok_or_else(|| anyhow!("bad kind `{kind_s}`"))?;
                    let occupancy: f32 = occ_s.parse().context("occupancy")?;
                    let ports = parse_port_list(&model.ports, port_s)?;
                    if ports.is_empty() {
                        bail!("uop `{u}` has empty port set");
                    }
                    uops.push(Uop { kind, ports, occupancy });
                }
            }
            other => bail!("unknown entry field `{other}`"),
        }
    }
    if uops.is_empty() && !model.isa.is_branch_mnemonic(&form.mnemonic) {
        bail!("entry `{form}` has no uops (only branches may)");
    }
    Ok(FormEntry { form, latency, throughput, uops })
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"
arch test "Test Arch"
freq 2.0
ports P0 P1 LD 0DV
loadports LD
storedataports P1
storeaguports LD
param rob 100
param load_latency 3
entry vaddpd-xmm_xmm_xmm lat=4 tp=0.5 uops=c@1:P0|P1
entry vdivsd-xmm_xmm_xmm lat=13 tp=4 uops=c@1:P0,dv@4:0DV
"#;

    #[test]
    fn parse_minimal() {
        let m = MachineModel::parse(MINI).unwrap();
        assert_eq!(m.name, "test");
        assert_eq!(m.arch_name, "Test Arch");
        assert_eq!(m.frequency_ghz, 2.0);
        assert_eq!(m.ports, vec!["P0", "P1", "LD", "0DV"]);
        assert_eq!(m.params.rob_size, 100);
        assert_eq!(m.params.load_latency, 3);
        assert_eq!(m.entries.len(), 2);
        let div = m.entries.get(&InstructionForm::new("vdivsd", "xmm_xmm_xmm")).unwrap();
        assert_eq!(div.uops.len(), 2);
        assert_eq!(div.uops[1].occupancy, 4.0);
    }

    #[test]
    fn roundtrip() {
        let m = MachineModel::parse(MINI).unwrap();
        let text = m.serialize();
        let m2 = MachineModel::parse(&text).unwrap();
        assert_eq!(m.entries.len(), m2.entries.len());
        assert_eq!(m.ports, m2.ports);
        assert_eq!(m.params.load_latency, m2.params.load_latency);
        for (form, e) in &m.entries {
            let e2 = &m2.entries[form];
            assert_eq!(e.uops, e2.uops, "{form}");
            assert_eq!(e.latency, e2.latency);
        }
    }

    #[test]
    fn unknown_port_errors() {
        let bad = MINI.replace("uops=c@1:P0|P1", "uops=c@1:P9");
        assert!(MachineModel::parse(&bad).is_err());
    }

    #[test]
    fn unknown_directive_errors() {
        assert!(MachineModel::parse("arch a \"A\"\nports P0\nbogus 1\n").is_err());
    }

    #[test]
    fn cache_stanza_roundtrip() {
        let src = format!(
            "{MINI}param lsq 48\nparam lfb 8\n\
             cache l1 size=32K line=64 lat=3 assoc=8\n\
             cache l2 size=1M line=64 lat=12 assoc=16\n\
             cache mem lat=80\n"
        );
        let m = MachineModel::parse(&src).unwrap();
        assert_eq!(m.params.lsq_size, 48);
        assert_eq!(m.params.lfb, 8);
        assert_eq!(m.caches.len(), 2);
        assert_eq!(m.caches[0].name, "l1");
        assert_eq!(m.caches[0].size_bytes, 32 * 1024);
        assert_eq!(m.caches[1].size_bytes, 1 << 20);
        assert_eq!(m.caches[1].latency_cy, 12);
        assert_eq!(m.mem_latency_cy, 80);
        let m2 = MachineModel::parse(&m.serialize()).unwrap();
        assert_eq!(m.caches, m2.caches);
        assert_eq!(m.mem_latency_cy, m2.mem_latency_cy);
        assert_eq!(m.params.lsq_size, m2.params.lsq_size);
        // Size suffixes render back in their largest exact form.
        assert!(m.serialize().contains("cache l1 size=32K"));
        assert!(m.serialize().contains("cache l2 size=1M"));
    }

    #[test]
    fn cache_stanza_rejects_malformed_lines() {
        let base = "arch a \"A\"\nports P0 LD\nloadports LD\n\
                    entry vaddpd-xmm_xmm_xmm lat=2 tp=1 uops=c@1:P0\n";
        // A bounded level without a size, a level without a latency, and
        // an unknown field must all fail with line context.
        for bad in [
            "cache l1 lat=4\n",
            "cache l1 size=32K\n",
            "cache l1 size=32K lat=4 ways=8\n",
            "cache mem size=1G lat=0\n",
        ] {
            assert!(MachineModel::parse(&format!("{base}{bad}")).is_err(), "{bad}");
        }
    }

    #[test]
    fn size_suffixes_parse_and_render() {
        assert_eq!(parse_size("64").unwrap(), 64);
        assert_eq!(parse_size("32K").unwrap(), 32 * 1024);
        assert_eq!(parse_size("1m").unwrap(), 1 << 20);
        assert_eq!(parse_size("8G").unwrap(), 8u64 << 30);
        assert!(parse_size("lots").is_err());
        assert_eq!(fmt_size(32 * 1024), "32K");
        assert_eq!(fmt_size(1 << 20), "1M");
        assert_eq!(fmt_size(96), "96");
    }

    #[test]
    fn builtin_serialize_roundtrip() {
        for m in [
            super::super::skylake(),
            super::super::zen(),
            super::super::thunderx2(),
            super::super::rv64(),
        ] {
            let m2 = MachineModel::parse(&m.serialize()).unwrap();
            assert_eq!(m.entries.len(), m2.entries.len(), "{}", m.name);
            assert_eq!(m.isa, m2.isa, "{}", m.name);
        }
    }

    #[test]
    fn isa_directive_parses_and_defaults() {
        let m = MachineModel::parse(MINI).unwrap();
        assert_eq!(m.isa, Isa::X86);
        let a64 = "arch t \"T\"\nisa aarch64\nports I0 LS\nloadports LS\n\
                   entry fadd-d_d_d lat=6 tp=0.5 uops=c@1:I0\n";
        let m = MachineModel::parse(a64).unwrap();
        assert_eq!(m.isa, Isa::AArch64);
        assert!(m.serialize().contains("isa aarch64"));
        let rv = "arch t \"T\"\nisa riscv\nports I0 LS\nloadports LS\n\
                  entry fadd.d-f_f_f lat=5 tp=1 uops=c@1:I0\n";
        let m = MachineModel::parse(rv).unwrap();
        assert_eq!(m.isa, Isa::RiscV);
        assert!(m.serialize().contains("isa riscv"));
        let bad = "arch t \"T\"\nisa sparc\nports I0\n";
        assert!(MachineModel::parse(bad).is_err());
    }
}
