//! Database entries: instruction forms and their µ-op decomposition.

use crate::isa::InstructionForm;

use super::port::PortMask;

/// µ-op role. Drives dependency wiring in the simulator and the
/// hideable-load / divider special cases in the analyzer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UopKind {
    /// Regular execution µ-op; produces the register result.
    Compute,
    /// Load µ-op: address generation + L1 access; feeds the compute µ-op.
    Load,
    /// Store-data µ-op.
    StoreData,
    /// Store address-generation µ-op.
    StoreAgu,
    /// Divider-pipe occupancy µ-op (SKL `0DV`, Zen `DV`): blocks the
    /// divider for `occupancy` cycles while the issuing port frees after
    /// one (paper §I-B).
    Divider,
}

impl UopKind {
    pub fn code(self) -> &'static str {
        match self {
            UopKind::Compute => "c",
            UopKind::Load => "ld",
            UopKind::StoreData => "st",
            UopKind::StoreAgu => "agu",
            UopKind::Divider => "dv",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "c" => UopKind::Compute,
            "ld" => UopKind::Load,
            "st" => UopKind::StoreData,
            "agu" => UopKind::StoreAgu,
            "dv" => UopKind::Divider,
            _ => return None,
        })
    }
}

/// One µ-op of an instruction form.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Uop {
    pub kind: UopKind,
    /// Ports that can execute this µ-op.
    pub ports: PortMask,
    /// Cycles the chosen port is occupied (1.0 for pipelined µ-ops,
    /// >1 for divider pipes).
    pub occupancy: f32,
}

/// A database entry for one instruction form.
#[derive(Debug, Clone, PartialEq)]
pub struct FormEntry {
    pub form: InstructionForm,
    /// Register-chain latency in cycles (paper §II-A latency benchmark).
    pub latency: f32,
    /// Documented reciprocal throughput in cy/instr — the benchmark
    /// value; the analyzer recomputes pressure from the µ-ops, this field
    /// is the cross-check the builder validates against.
    pub throughput: f32,
    pub uops: Vec<Uop>,
}

impl FormEntry {
    /// Reciprocal throughput implied by the µ-op decomposition alone
    /// (single-instruction-kind loop): the most-pressured port when the
    /// form runs back-to-back.
    pub fn implied_rtp(&self) -> f32 {
        let mut pressure = [0f32; 16];
        for u in &self.uops {
            let share = u.occupancy / u.ports.count().max(1) as f32;
            for p in u.ports.iter() {
                pressure[p] += share;
            }
        }
        pressure.iter().cloned().fold(0.0, f32::max)
    }

    /// Total µ-op count (fused-domain approximation).
    pub fn n_uops(&self) -> usize {
        self.uops.len()
    }
}

/// How a lookup was satisfied; surfaces in reports so users can tell
/// measured entries from synthesized ones.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provenance {
    /// Exact database hit.
    Direct,
    /// Memory-operand form synthesized from the register form + load/store
    /// µ-ops (paper: unknown forms would trigger benchmark generation; we
    /// synthesize *and* flag, and ibench can then confirm).
    SynthesizedMem,
    /// 256-bit form synthesized from the 128-bit form by µ-op doubling
    /// (Zen AVX splitting, paper §III-A).
    SynthesizedSplit,
    /// Size-suffixed scalar mnemonic normalized (addl -> add).
    SynthesizedSuffix,
}

impl Provenance {
    /// Stable machine-readable name (used by the JSON/CSV emitters and
    /// the serve wire format).
    pub fn name(self) -> &'static str {
        match self {
            Provenance::Direct => "direct",
            Provenance::SynthesizedMem => "synth_mem",
            Provenance::SynthesizedSplit => "synth_split",
            Provenance::SynthesizedSuffix => "synth_suffix",
        }
    }
}

/// Resolved µ-ops for a concrete instruction, with provenance.
#[derive(Debug, Clone)]
pub struct ResolvedUops {
    pub entry: FormEntry,
    pub provenance: Provenance,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uop(kind: UopKind, ports: &[usize], occ: f32) -> Uop {
        Uop { kind, ports: PortMask::from_ports(ports), occupancy: occ }
    }

    #[test]
    fn implied_rtp_two_ports() {
        let e = FormEntry {
            form: InstructionForm::new("vaddpd", "xmm_xmm_xmm"),
            latency: 4.0,
            throughput: 0.5,
            uops: vec![uop(UopKind::Compute, &[0, 1], 1.0)],
        };
        assert!((e.implied_rtp() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn implied_rtp_divider_dominates() {
        let e = FormEntry {
            form: InstructionForm::new("vdivsd", "xmm_xmm_xmm"),
            latency: 13.0,
            throughput: 4.0,
            uops: vec![uop(UopKind::Compute, &[0], 1.0), uop(UopKind::Divider, &[8], 4.0)],
        };
        assert!((e.implied_rtp() - 4.0).abs() < 1e-6);
    }

    #[test]
    fn uop_kind_roundtrip() {
        for k in [UopKind::Compute, UopKind::Load, UopKind::StoreData, UopKind::StoreAgu, UopKind::Divider] {
            assert_eq!(UopKind::parse(k.code()), Some(k));
        }
        assert_eq!(UopKind::parse("x"), None);
    }
}
