//! `osaca::exec` — the one work-stealing executor behind every
//! parallel path in the crate (DESIGN.md §11).
//!
//! Before this layer existed the crate had three independent execution
//! mechanisms — `api::Engine::analyze_batch`'s ad-hoc scoped pool, the
//! coordinator's dedicated solver thread, and `serve`'s N shard workers
//! on bounded `sync_channel`s — each with its own queueing, supervision
//! and stats story. This module unifies them:
//!
//! * **Queues.** Each worker owns a bounded FIFO deque; submissions
//!   carry an optional *home* hint (`Some(worker)`) that pins a job to
//!   a worker's deque for locality (serve uses the arch-hash shard
//!   index so FormIndex/memo locality survives), or go to a bounded
//!   global *injector* (`None`) that any worker drains. A worker takes
//!   from its own deque first, then the injector, then **steals** from
//!   other workers' deque fronts — an idle worker never sits out a
//!   hot-queue burst, and steal order (oldest job first) preserves
//!   rough submission fairness.
//! * **Backpressure.** [`Executor::try_submit`] answers a structured
//!   [`Submit::Full`] (carrying the home gauge) instead of blocking —
//!   the contract serve's `overloaded` frames are built on. The
//!   blocking [`Executor::submit`] waits for a slot (the coordinator's
//!   semantics) and hands the job back on a closed executor so the
//!   caller can notify its own waiters.
//! * **Supervision.** Every job runs under `catch_unwind`. A panic is
//!   redacted to a stable category ([`panic_category`], or the
//!   executor-wide `panic_label` override), the worker's context is
//!   rebuilt from the factory *before* the job's `on_panic` callback
//!   answers anyone — by the time a caller sees the categorized error,
//!   the worker is already fresh. `panics` and `worker_restarts`
//!   count every event.
//! * **Stats.** One [`ExecStats`] surface (queued / in-flight / steals
//!   / panics / worker restarts) plus per-worker [`WorkerStats`]
//!   (executed jobs, home gauge). `serve`'s wire `stats` frame and the
//!   coordinator's `ServiceStats` re-export these counters instead of
//!   reimplementing them.
//! * **Drain.** [`Executor::close`] stops admissions; workers finish
//!   everything already queued (own deque, injector, and stealable
//!   remainders) before exiting, so a close-then-join loses zero jobs.
//!
//! Worker contexts are built *inside* the worker thread by the factory
//! (`Fn(worker_index) -> C`), never moved across threads — the PJRT
//! solver client is not `Send`, and serve's per-shard `Engine`s follow
//! the same rule.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Executor tunables.
#[derive(Debug, Clone)]
pub struct ExecConfig {
    /// Worker (and deque) count, clamped ≥ 1.
    pub workers: usize,
    /// Per-worker deque bound, clamped ≥ 1; a full home deque answers
    /// [`Submit::Full`].
    pub queue_depth: usize,
    /// Injector bound for affinity-free submissions (0 = auto:
    /// `workers × queue_depth`).
    pub injector_depth: usize,
    /// Worker thread name prefix (worker `i` is named `{name}{i}`).
    pub name: String,
    /// Redact *every* caught panic to this category instead of
    /// payload-prefix classification (the coordinator pins
    /// `"solver_panic"` this way).
    pub panic_label: Option<&'static str>,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig {
            workers: 1,
            queue_depth: 64,
            injector_depth: 0,
            name: "osaca-exec".to_string(),
            panic_label: None,
        }
    }
}

/// Executor-wide counters. Plain relaxed atomics: monotonic event
/// counts and gauges with no cross-counter invariant, same discipline
/// as `serve::metrics`.
#[derive(Debug, Default)]
pub struct ExecStats {
    /// Jobs accepted but not yet picked up by a worker (deques +
    /// injector).
    pub queued: AtomicU64,
    /// Jobs currently running on some worker.
    pub in_flight: AtomicU64,
    /// Jobs a worker took from another worker's deque.
    pub steals: AtomicU64,
    /// Job panics caught by worker supervision.
    pub panics: AtomicU64,
    /// Worker contexts rebuilt after a caught panic (== panics today;
    /// kept separate so a pooled-restart strategy stays observable).
    pub worker_restarts: AtomicU64,
}

/// Per-worker counters.
#[derive(Debug, Default)]
pub struct WorkerStats {
    /// Jobs this worker ran to completion (including panicked jobs —
    /// the job was consumed either way).
    pub executed: AtomicU64,
    /// Gauge of jobs *homed* to this worker that are queued or still
    /// running (wherever they actually run): incremented at submit,
    /// decremented when the job finishes. This is the per-shard
    /// `queue_depths` gauge serve exposes on the wire.
    pub home: AtomicU64,
}

/// A unit of work plus its supervision callback.
///
/// `run` executes on a worker with exclusive access to that worker's
/// context. If it panics, the executor rebuilds the context and calls
/// `on_panic` with the redacted category — `on_panic` must own its own
/// reply senders (anything `run` owned went down with the unwind).
pub struct Job<C> {
    run: Box<dyn FnOnce(&mut C) + Send + 'static>,
    on_panic: Box<dyn FnOnce(&'static str) + Send + 'static>,
}

impl<C> Job<C> {
    pub fn new(run: impl FnOnce(&mut C) + Send + 'static) -> Job<C> {
        Job { run: Box::new(run), on_panic: Box::new(|_category| {}) }
    }

    /// Attach the panic callback (replaces the default no-op).
    pub fn on_panic(mut self, f: impl FnOnce(&'static str) + Send + 'static) -> Job<C> {
        self.on_panic = Box::new(f);
        self
    }
}

/// Outcome of a non-blocking submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Submit {
    Queued,
    /// The home deque (or injector) is full; carries the home gauge
    /// (queued + in-flight) so backpressure frames can report depth.
    Full(u64),
    /// The executor is closed (draining); nothing was accepted.
    Closed,
}

struct QueuedJob<C> {
    job: Job<C>,
    home: Option<usize>,
}

struct State<C> {
    deques: Vec<VecDeque<QueuedJob<C>>>,
    injector: VecDeque<QueuedJob<C>>,
    closed: bool,
}

struct Core<C> {
    state: Mutex<State<C>>,
    /// Workers wait here for work (or close).
    work_cv: Condvar,
    /// Blocking submitters wait here for queue space.
    space_cv: Condvar,
    stats: ExecStats,
    workers: Vec<WorkerStats>,
    queue_depth: usize,
    injector_depth: usize,
    panic_label: Option<&'static str>,
}

/// The work-stealing executor. Shareable by reference (all methods take
/// `&self`); dropping it closes and joins the workers.
pub struct Executor<C> {
    core: Arc<Core<C>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl<C: 'static> Executor<C> {
    /// Start `cfg.workers` workers, each owning a context built by
    /// `factory(worker_index)` on its own thread (contexts are never
    /// moved across threads, so `C` needs no `Send`).
    pub fn new(
        cfg: ExecConfig,
        factory: impl Fn(usize) -> C + Send + Sync + 'static,
    ) -> Executor<C> {
        let n = cfg.workers.max(1);
        let queue_depth = cfg.queue_depth.max(1);
        let injector_depth =
            if cfg.injector_depth > 0 { cfg.injector_depth } else { n * queue_depth };
        let core = Arc::new(Core {
            state: Mutex::new(State {
                deques: (0..n).map(|_| VecDeque::new()).collect(),
                injector: VecDeque::new(),
                closed: false,
            }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            stats: ExecStats::default(),
            workers: (0..n).map(|_| WorkerStats::default()).collect(),
            queue_depth,
            injector_depth,
            panic_label: cfg.panic_label,
        });
        let factory: Arc<dyn Fn(usize) -> C + Send + Sync> = Arc::new(factory);
        let handles = (0..n)
            .map(|i| {
                let core = core.clone();
                let factory = factory.clone();
                std::thread::Builder::new()
                    .name(format!("{}{}", cfg.name, i))
                    .spawn(move || worker_loop(&core, factory.as_ref(), i))
                    .expect("spawn exec worker")
            })
            .collect();
        Executor { core, handles: Mutex::new(handles) }
    }

    pub fn workers(&self) -> usize {
        self.core.workers.len()
    }

    pub fn stats(&self) -> &ExecStats {
        &self.core.stats
    }

    pub fn worker_stats(&self) -> &[WorkerStats] {
        &self.core.workers
    }

    /// Per-worker home gauges (queued + in-flight jobs homed to each
    /// worker) — the wire `queue_depths` array.
    pub fn queue_depths(&self) -> Vec<u64> {
        self.core.workers.iter().map(|w| w.home.load(Ordering::Relaxed)).collect()
    }

    /// Non-blocking submission. `home` pins the job to a worker's deque
    /// (for locality; idle workers may still steal it); `None` uses the
    /// shared injector.
    pub fn try_submit(&self, home: Option<usize>, job: Job<C>) -> Submit {
        let core = &self.core;
        let mut st = core.state.lock().expect("exec state");
        if st.closed {
            return Submit::Closed;
        }
        match home {
            Some(h) => {
                let h = h % st.deques.len();
                if st.deques[h].len() >= core.queue_depth {
                    return Submit::Full(core.workers[h].home.load(Ordering::Relaxed));
                }
                st.deques[h].push_back(QueuedJob { job, home: Some(h) });
                core.workers[h].home.fetch_add(1, Ordering::Relaxed);
            }
            None => {
                if st.injector.len() >= core.injector_depth {
                    return Submit::Full(st.injector.len() as u64);
                }
                st.injector.push_back(QueuedJob { job, home: None });
            }
        }
        core.stats.queued.fetch_add(1, Ordering::Relaxed);
        drop(st);
        core.work_cv.notify_one();
        Submit::Queued
    }

    /// Blocking submission: waits for queue space instead of answering
    /// `Full`. On a closed executor the job is handed back so the
    /// caller can notify whoever holds its reply channels.
    pub fn submit(&self, home: Option<usize>, job: Job<C>) -> Result<(), Job<C>> {
        let core = &self.core;
        let mut st = core.state.lock().expect("exec state");
        loop {
            if st.closed {
                drop(st);
                return Err(job);
            }
            let has_space = match home {
                Some(h) => st.deques[h % st.deques.len()].len() < core.queue_depth,
                None => st.injector.len() < core.injector_depth,
            };
            if has_space {
                break;
            }
            st = core.space_cv.wait(st).expect("exec space wait");
        }
        match home {
            Some(h) => {
                let h = h % st.deques.len();
                st.deques[h].push_back(QueuedJob { job, home: Some(h) });
                core.workers[h].home.fetch_add(1, Ordering::Relaxed);
            }
            None => st.injector.push_back(QueuedJob { job, home: None }),
        }
        core.stats.queued.fetch_add(1, Ordering::Relaxed);
        drop(st);
        core.work_cv.notify_one();
        Ok(())
    }

    /// Stop admissions. Workers finish everything already queued before
    /// exiting — the drain contract. Idempotent.
    pub fn close(&self) {
        let mut st = self.core.state.lock().expect("exec state");
        st.closed = true;
        drop(st);
        self.core.work_cv.notify_all();
        self.core.space_cv.notify_all();
    }

    /// Join every worker thread (call [`Executor::close`] first or this
    /// blocks forever). Idempotent.
    pub fn join(&self) {
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.handles.lock().expect("exec handles"));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl<C> Drop for Executor<C> {
    fn drop(&mut self) {
        // Safe teardown without the `C: 'static` bound of the inherent
        // methods: same close + join, inlined.
        {
            let mut st = self.core.state.lock().expect("exec state");
            st.closed = true;
        }
        self.core.work_cv.notify_all();
        self.core.space_cv.notify_all();
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.handles.lock().expect("exec handles"));
        for h in handles {
            let _ = h.join();
        }
    }
}

/// What a worker found when it went looking for work.
enum Found<C> {
    Job(QueuedJob<C>, /* stolen */ bool),
    Exit,
}

fn next_job<C>(core: &Core<C>, index: usize) -> Found<C> {
    let mut st = core.state.lock().expect("exec state");
    loop {
        if let Some(q) = st.deques[index].pop_front() {
            return Found::Job(q, false);
        }
        if let Some(q) = st.injector.pop_front() {
            return Found::Job(q, false);
        }
        // Steal scan: round-robin from the next worker up, oldest job
        // first (deque *front*, same end the owner takes from — strict
        // FIFO per home queue even under steals).
        let n = st.deques.len();
        for off in 1..n {
            let j = (index + off) % n;
            if let Some(q) = st.deques[j].pop_front() {
                return Found::Job(q, true);
            }
        }
        if st.closed {
            return Found::Exit;
        }
        // The timeout is a belt against lost-wakeup bugs, not a
        // correctness requirement: every submit notifies under the
        // same mutex.
        let (guard, _timed_out) = core
            .work_cv
            .wait_timeout(st, Duration::from_millis(50))
            .expect("exec work wait");
        st = guard;
    }
}

fn worker_loop<C>(core: &Core<C>, factory: &(dyn Fn(usize) -> C + Send + Sync), index: usize) {
    let mut ctx = factory(index);
    loop {
        let (queued, stolen) = match next_job(core, index) {
            Found::Job(q, stolen) => (q, stolen),
            Found::Exit => return,
        };
        core.stats.queued.fetch_sub(1, Ordering::Relaxed);
        core.stats.in_flight.fetch_add(1, Ordering::Relaxed);
        if stolen {
            core.stats.steals.fetch_add(1, Ordering::Relaxed);
        }
        // A queue slot just freed up; wake blocking submitters.
        core.space_cv.notify_all();
        let QueuedJob { job, home } = queued;
        let Job { run, on_panic } = job;
        match panic::catch_unwind(AssertUnwindSafe(|| run(&mut ctx))) {
            Ok(()) => {}
            Err(payload) => {
                core.stats.panics.fetch_add(1, Ordering::Relaxed);
                // Rebuild *before* answering: by the time a caller sees
                // the categorized error, the worker context is already
                // fresh — a restarted worker must not inherit state the
                // panic may have corrupted.
                ctx = factory(index);
                core.stats.worker_restarts.fetch_add(1, Ordering::Relaxed);
                let category =
                    core.panic_label.unwrap_or_else(|| panic_category(payload.as_ref()));
                // A panicking on_panic must not kill the worker too.
                let _ = panic::catch_unwind(AssertUnwindSafe(move || on_panic(category)));
            }
        }
        core.workers[index].executed.fetch_add(1, Ordering::Relaxed);
        core.stats.in_flight.fetch_sub(1, Ordering::Relaxed);
        if let Some(h) = home {
            core.workers[h].home.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// Redact a panic payload to a stable category. The injected classes
/// keep distinct names so tests can tell supervision paths apart; any
/// genuine panic is just "worker_panic". Payload text is never a wire
/// surface — it can carry internal state.
pub fn panic_category(payload: &(dyn Any + Send)) -> &'static str {
    let msg = payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str));
    match msg {
        Some(m) if m.starts_with("chaos:") => "injected_chaos_panic",
        Some(m) if m.starts_with("test-op:") => "injected_test_panic",
        _ => "worker_panic",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn pool(workers: usize, queue_depth: usize) -> Executor<()> {
        Executor::new(
            ExecConfig {
                workers,
                queue_depth,
                name: "exec-test".to_string(),
                ..Default::default()
            },
            |_| (),
        )
    }

    #[test]
    fn panic_categories_are_redacted() {
        let boxed: Box<dyn Any + Send> = Box::new("chaos: injected worker panic");
        assert_eq!(panic_category(boxed.as_ref()), "injected_chaos_panic");
        let boxed: Box<dyn Any + Send> = Box::new("test-op: injected worker panic".to_string());
        assert_eq!(panic_category(boxed.as_ref()), "injected_test_panic");
        let boxed: Box<dyn Any + Send> =
            Box::new("index out of bounds: secret internal detail".to_string());
        assert_eq!(panic_category(boxed.as_ref()), "worker_panic");
        let boxed: Box<dyn Any + Send> = Box::new(42u32);
        assert_eq!(panic_category(boxed.as_ref()), "worker_panic");
    }

    #[test]
    fn jobs_run_and_drain_on_close() {
        let ex = pool(3, 64);
        let (tx, rx) = mpsc::channel();
        for i in 0..48u64 {
            let tx = tx.clone();
            let sub = ex.try_submit(Some((i % 3) as usize), Job::new(move |_: &mut ()| {
                tx.send(i).unwrap();
            }));
            assert_eq!(sub, Submit::Queued);
        }
        ex.close();
        ex.join();
        drop(tx);
        let got: Vec<u64> = rx.iter().collect();
        assert_eq!(got.len(), 48, "close+join must lose zero jobs");
        assert_eq!(ex.try_submit(Some(0), Job::new(|_: &mut ()| {})), Submit::Closed);
        assert_eq!(ex.stats().queued.load(Ordering::Relaxed), 0);
        assert_eq!(ex.stats().in_flight.load(Ordering::Relaxed), 0);
        let executed: u64 =
            ex.worker_stats().iter().map(|w| w.executed.load(Ordering::Relaxed)).sum();
        assert_eq!(executed, 48);
    }

    #[test]
    fn full_home_deque_answers_structured_full() {
        let ex = pool(1, 1);
        let (hold_tx, hold_rx) = mpsc::channel::<()>();
        ex.try_submit(
            Some(0),
            Job::new(move |_: &mut ()| {
                hold_rx.recv().unwrap();
            }),
        );
        // Wait until the blocker is in flight (deque empty again).
        while ex.stats().in_flight.load(Ordering::Relaxed) == 0 {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(ex.try_submit(Some(0), Job::new(|_: &mut ()| {})), Submit::Queued);
        // Home gauge = 1 in flight + 1 queued.
        assert_eq!(ex.try_submit(Some(0), Job::new(|_: &mut ()| {})), Submit::Full(2));
        hold_tx.send(()).unwrap();
        ex.close();
        ex.join();
        assert_eq!(ex.queue_depths(), vec![0]);
    }

    #[test]
    fn factory_rebuilds_context_after_panic() {
        // Context = a generation counter: a panic must hand the next
        // job a *fresh* context, not the poisoned one.
        let built = Arc::new(AtomicU64::new(0));
        let b = built.clone();
        let ex = Executor::new(
            ExecConfig { workers: 1, name: "exec-gen".to_string(), ..Default::default() },
            move |_| b.fetch_add(1, Ordering::Relaxed),
        );
        let (tx, rx) = mpsc::channel();
        ex.try_submit(Some(0), Job::new(|_: &mut u64| panic!("boom")));
        let txc = tx.clone();
        ex.try_submit(Some(0), Job::new(move |gen: &mut u64| {
            txc.send(*gen).unwrap();
        }));
        let gen = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(gen, 1, "second job must see the rebuilt (generation-1) context");
        assert_eq!(built.load(Ordering::Relaxed), 2);
        assert_eq!(ex.stats().panics.load(Ordering::Relaxed), 1);
        assert_eq!(ex.stats().worker_restarts.load(Ordering::Relaxed), 1);
        ex.close();
        ex.join();
    }

    #[test]
    fn panic_label_overrides_payload_classification() {
        let ex = Executor::new(
            ExecConfig {
                workers: 1,
                name: "exec-label".to_string(),
                panic_label: Some("solver_panic"),
                ..Default::default()
            },
            |_| (),
        );
        let (tx, rx) = mpsc::channel();
        let job = Job::new(|_: &mut ()| panic!("chaos: would normally classify differently"))
            .on_panic(move |category| tx.send(category).unwrap());
        ex.try_submit(Some(0), job);
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap(), "solver_panic");
        ex.close();
        ex.join();
    }
}
