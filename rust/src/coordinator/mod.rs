//! Analysis coordinator: the service layer.
//!
//! Batches concurrent analysis requests into the fixed-size slots of
//! the AOT artifact (B = 8), the way a serving framework batches model
//! requests: requests are queued to a dedicated solver thread, flushed
//! either when a batch fills or when the oldest request exceeds the
//! batching window, and executed in one PJRT call. The OSACA analysis
//! and critical-path analysis run inline (they are pure rust and
//! cheap); only the balanced-baseline solve goes through the batcher.
//!
//! tokio is not available in this offline build, so the implementation
//! uses std::thread + mpsc; the public API is synchronous with
//! oneshot-style replies.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::analyzer::{analyze, critical_path, Analysis, CritPathReport};
use crate::asm::{extract_kernel, Kernel};
use crate::baseline::{encode, BaselinePrediction};
use crate::mdb::{self, MachineModel};
use crate::runtime::{solve_cpu, EncodedKernel, PortSolver, SolveOut, BATCH};

/// A full analysis response.
#[derive(Debug, Clone)]
pub struct AnalysisResponse {
    pub osaca: Analysis,
    pub baseline: BaselinePrediction,
    pub critpath: CritPathReport,
}

/// Service statistics (exposed for the perf pass and `serve` CLI).
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_kernels: AtomicU64,
    pub solve_micros: AtomicU64,
}

impl ServiceStats {
    pub fn avg_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_kernels.load(Ordering::Relaxed) as f64 / b as f64
    }
}

enum SolverBackend {
    /// AOT artifact through PJRT.
    Artifact(PortSolver),
    /// Pure-rust fallback (identical math; used when artifacts are not
    /// built, and in unit tests).
    Cpu,
}

struct Job {
    enc: EncodedKernel,
    reply: SyncSender<SolveOut>,
}

/// The coordinator service. Cloneable handles submit requests; one
/// solver thread owns the PJRT executable.
pub struct Coordinator {
    tx: Option<SyncSender<Job>>,
    worker: Option<JoinHandle<()>>,
    pub stats: Arc<ServiceStats>,
    /// Batching window: how long the solver thread waits for more
    /// requests before flushing a partial batch.
    pub window: Duration,
}

impl Coordinator {
    /// Create a coordinator; the backend is constructed *inside* the
    /// solver thread (the PJRT client is not `Send`).
    fn new<F>(make_backend: F, window: Duration) -> Self
    where
        F: FnOnce() -> SolverBackend + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<Job>(1024);
        let stats = Arc::new(ServiceStats::default());
        let wstats = stats.clone();
        let worker = std::thread::Builder::new()
            .name("osaca-solver".into())
            .spawn(move || solver_loop(rx, make_backend(), wstats, window))
            .expect("spawn solver thread");
        Coordinator { tx: Some(tx), worker: Some(worker), stats, window }
    }

    /// Coordinator backed by the AOT artifact at the default location
    /// (PJRT); errors surface on first use via the CPU fallback.
    pub fn with_artifact() -> Self {
        Self::new(
            || match PortSolver::load_default() {
                Ok(s) => SolverBackend::Artifact(s),
                Err(e) => {
                    eprintln!("artifact unavailable ({e}); using cpu solver");
                    SolverBackend::Cpu
                }
            },
            Duration::from_micros(200),
        )
    }

    /// Coordinator backed by the pure-rust solver.
    pub fn cpu_only() -> Self {
        Self::new(|| SolverBackend::Cpu, Duration::from_micros(200))
    }

    /// Artifact if present, CPU solver otherwise.
    pub fn auto() -> Self {
        Self::with_artifact()
    }

    /// Analyze assembly source for `arch`: OSACA throughput analysis +
    /// critical path inline, balanced baseline through the batcher.
    pub fn analyze_source(&self, name: &str, src: &str, arch: &str) -> Result<AnalysisResponse> {
        let machine =
            mdb::by_name(arch).ok_or_else(|| anyhow!("unknown architecture `{arch}`"))?;
        let kernel = extract_kernel(name, src)?;
        self.analyze_kernel(&kernel, &machine)
    }

    /// Analyze an already-extracted kernel.
    pub fn analyze_kernel(
        &self,
        kernel: &Kernel,
        machine: &MachineModel,
    ) -> Result<AnalysisResponse> {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let osaca = analyze(kernel, machine)?;
        let critpath = critical_path(kernel, machine)?;
        let enc = encode(kernel, machine)?;
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.tx
            .as_ref()
            .expect("coordinator running")
            .send(Job { enc, reply: rtx })
            .map_err(|_| anyhow!("solver thread gone"))?;
        let out = rrx
            .recv_timeout(Duration::from_secs(30))
            .map_err(|e| anyhow!("solver reply timeout: {e}"))?;
        let baseline = BaselinePrediction {
            cy_per_asm_iter: out.tp_balanced,
            uniform_cy: out.tp_uniform,
            port_pressure: out.press_balanced,
        };
        Ok(AnalysisResponse { osaca, baseline, critpath })
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn solver_loop(
    rx: Receiver<Job>,
    backend: SolverBackend,
    stats: Arc<ServiceStats>,
    window: Duration,
) {
    loop {
        // Block for the first job of a batch.
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return, // all senders dropped
        };
        let mut jobs = vec![first];
        let deadline = Instant::now() + window;
        while jobs.len() < BATCH {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        let encs: Vec<EncodedKernel> = jobs.iter().map(|j| j.enc.clone()).collect();
        let t0 = Instant::now();
        let outs = match &backend {
            SolverBackend::Artifact(s) => match s.solve(&encs) {
                Ok(o) => o,
                Err(e) => {
                    eprintln!("artifact solve failed ({e}); falling back to cpu");
                    solve_cpu(&encs, 32)
                }
            },
            SolverBackend::Cpu => solve_cpu(&encs, 32),
        };
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.batched_kernels.fetch_add(jobs.len() as u64, Ordering::Relaxed);
        stats
            .solve_micros
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        for (job, out) in jobs.into_iter().zip(outs.into_iter()) {
            let _ = job.reply.send(out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn cpu_coordinator_analyzes_triad() {
        let c = Coordinator::cpu_only();
        let w = workloads::find("triad", "skl", "-O3").unwrap();
        let r = c.analyze_source(&w.name(), w.source, "skl").unwrap();
        assert!((r.osaca.cy_per_asm_iter - 2.0).abs() < 0.01);
        assert!(r.baseline.cy_per_asm_iter <= r.osaca.cy_per_asm_iter + 0.25);
        assert_eq!(c.stats.requests.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn unknown_arch_is_error() {
        let c = Coordinator::cpu_only();
        assert!(c.analyze_source("x", ".L1:\naddl $1, %eax\njne .L1\n", "m1max").is_err());
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let c = Arc::new(Coordinator::cpu_only());
        let mut handles = Vec::new();
        for _ in 0..16 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let w = workloads::find("pi", "skl", "-O2").unwrap();
                c.analyze_source(&w.name(), w.source, "skl").unwrap().osaca.cy_per_asm_iter
            }));
        }
        for h in handles {
            let cy = h.join().unwrap();
            assert!((cy - 4.25).abs() < 0.01, "{cy}");
        }
        assert_eq!(c.stats.requests.load(Ordering::Relaxed), 16);
        // Batching must have coalesced at least some requests.
        assert!(c.stats.batches.load(Ordering::Relaxed) <= 16);
        assert!(c.stats.avg_batch_size() >= 1.0);
    }
}
