//! Analysis coordinator: the service layer.
//!
//! Batches concurrent analysis requests into the fixed-size slots of
//! the AOT artifact (B = 8), the way a serving framework batches model
//! requests. Two submission paths share one solver worker on the
//! crate-wide [`crate::exec`] executor:
//!
//! * **single** ([`Coordinator::solve_one`]): the request joins a
//!   batching window; the first submitter in an empty window becomes
//!   the *leader*, waits [`CoordinatorConfig::window`] for company,
//!   then submits one executor job that solves the whole window and
//!   answers every waiter — the latency-oriented interactive path;
//! * **batch** ([`Coordinator::solve_batch`]): a whole vector of
//!   encoded kernels is mapped directly onto consecutive B=8 artifact
//!   slots with no window wait and one reply channel for the entire
//!   submission — the throughput-oriented path behind
//!   `api::Engine::analyze_batch`.
//!
//! Supervision (panic → [`SubmitError::Panicked`] with the redacted
//! `solver_panic` category → backend rebuilt from the factory) lives in
//! the executor; this module only wires reply channels and stats. The
//! backend is constructed *inside* the worker thread because the PJRT
//! client is not `Send`.
//!
//! Reply channels are pooled and reused across requests; the reply
//! timeout and batching window are configurable through
//! [`CoordinatorConfig`] (surfaced on `api::Engine::builder`).
//!
//! tokio is not available in this offline build, so the implementation
//! uses std::thread + mpsc; the public API is synchronous.

use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::analyzer::{analyze, critical_path, Analysis, CritPathReport};
use crate::asm::{extract_kernel_isa, Kernel};
use crate::baseline::{encode, BaselinePrediction};
use crate::exec::{self, ExecStats, Executor};
use crate::mdb::{self, MachineModel};
use crate::runtime::{solve_cpu, EncodedKernel, PortSolver, SolveOut, BATCH};

/// A full analysis response (legacy shim shape; the `api` layer returns
/// the richer `AnalysisReport`).
#[derive(Debug, Clone)]
pub struct AnalysisResponse {
    pub osaca: Analysis,
    pub baseline: BaselinePrediction,
    pub critpath: CritPathReport,
}

/// Service statistics (exposed for the perf pass, `serve` CLI, and the
/// api layer's batch-splitting tests).
///
/// `queued` and `solver_restarts` are legacy mirrors kept for pinned
/// consumers; the executor-level truth is [`Coordinator::exec_stats`].
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_kernels: AtomicU64,
    pub solve_micros: AtomicU64,
    /// Submissions accepted but not yet picked up by the solver worker
    /// (a gauge, not a counter). Surfaced as
    /// [`Coordinator::queue_depth`] for serving introspection.
    pub queued: AtomicU64,
    /// Solver backends rebuilt after a caught panic: the solver worker
    /// never dies with a request — it answers
    /// [`SubmitError::Panicked`], the executor rebuilds its backend,
    /// and it keeps serving the queue. Mirrors
    /// `exec_stats().worker_restarts`.
    pub solver_restarts: AtomicU64,
}

impl ServiceStats {
    pub fn avg_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_kernels.load(Ordering::Relaxed) as f64 / b as f64
    }
}

/// Which solver implementation the worker thread constructs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// PJRT artifact if loadable, CPU reference otherwise.
    Auto,
    /// Pure-rust reference solver.
    Cpu,
}

/// Tunables for the coordinator service.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorConfig {
    pub backend: Backend,
    /// Batching window: how long a single-path leader waits for more
    /// requests before flushing a partial batch.
    pub window: Duration,
    /// How long a submitter waits for its reply before giving up.
    pub reply_timeout: Duration,
    /// Depth of the submission queue (the solver worker's deque).
    pub queue_depth: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            backend: Backend::Auto,
            window: Duration::from_micros(200),
            reply_timeout: Duration::from_secs(30),
            queue_depth: 1024,
        }
    }
}

/// Submission failure, structured so the api layer can map it onto
/// `OsacaError` without string matching.
#[derive(Debug)]
pub enum SubmitError {
    /// The solver did not reply within the configured timeout.
    Timeout { waited: Duration },
    /// The solver thread is gone (coordinator shut down).
    Closed,
    /// The backend panicked on this request. The executor caught it,
    /// rebuilt the backend, and kept serving; `category` is a redacted
    /// stable label (panic payloads are never forwarded).
    Panicked { category: String },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Timeout { waited } => {
                write!(f, "solver reply timeout after {waited:?}")
            }
            SubmitError::Closed => write!(f, "solver thread gone"),
            SubmitError::Panicked { category } => {
                write!(f, "solver worker panicked ({category}); backend restarted")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

enum SolverBackend {
    /// AOT artifact through PJRT.
    Artifact(PortSolver),
    /// Pure-rust fallback (identical math; used when artifacts are not
    /// built, and in unit tests).
    Cpu,
}

fn make_backend(backend: Backend) -> SolverBackend {
    match backend {
        Backend::Cpu => SolverBackend::Cpu,
        Backend::Auto => match PortSolver::load_default() {
            Ok(s) => SolverBackend::Artifact(s),
            Err(e) => {
                eprintln!("artifact unavailable ({e}); using cpu solver");
                SolverBackend::Cpu
            }
        },
    }
}

/// Why a reply carries no output. Distinguishing `Closed` from
/// `Panicked` matters on the single path: a window *leader* that finds
/// the executor draining must tell its window-mates the service is
/// gone, not that their kernels crashed the solver.
#[derive(Debug, Clone)]
enum SolveFailure {
    Panicked(String),
    Closed,
}

/// Reply payloads carry the failure so a submitter learns *why* there
/// is no output instead of waiting out its timeout against a reply
/// that will never come.
type SingleReply = Result<SolveOut, SolveFailure>;
type BatchReply = Result<Vec<SolveOut>, SolveFailure>;

/// A single-path request parked in the batching window, waiting for
/// the window leader to submit it.
struct PendingOne {
    enc: EncodedKernel,
    reply: SyncSender<SingleReply>,
}

type SinglePool = Mutex<Vec<(SyncSender<SingleReply>, Receiver<SingleReply>)>>;
type BatchPool = Mutex<Vec<(SyncSender<BatchReply>, Receiver<BatchReply>)>>;

/// How many idle reply channels each pool retains.
const POOL_CAP: usize = 64;

/// The coordinator service. Shareable (`Arc<Coordinator>`) handles
/// submit requests; one executor worker owns the PJRT executable.
pub struct Coordinator {
    exec: Executor<SolverBackend>,
    /// Single-path batching window (see [`Coordinator::solve_one`]).
    pending: Mutex<Vec<PendingOne>>,
    pub stats: Arc<ServiceStats>,
    /// Batching window (see [`CoordinatorConfig::window`]).
    pub window: Duration,
    /// Reply timeout (see [`CoordinatorConfig::reply_timeout`]).
    pub reply_timeout: Duration,
    single_pool: SinglePool,
    batch_pool: BatchPool,
}

impl Coordinator {
    /// Create a coordinator with explicit tunables; the backend is
    /// constructed *inside* the solver worker (the PJRT client is not
    /// `Send`), and rebuilt there after any caught panic.
    pub fn with_config(cfg: CoordinatorConfig) -> Self {
        let backend = cfg.backend;
        let exec = Executor::new(
            exec::ExecConfig {
                workers: 1,
                queue_depth: cfg.queue_depth.max(1),
                name: "osaca-solver".to_string(),
                panic_label: Some(SOLVER_PANIC_CATEGORY),
                ..Default::default()
            },
            move |_worker| make_backend(backend),
        );
        Coordinator {
            exec,
            pending: Mutex::new(Vec::new()),
            stats: Arc::new(ServiceStats::default()),
            window: cfg.window,
            reply_timeout: cfg.reply_timeout,
            single_pool: Mutex::new(Vec::new()),
            batch_pool: Mutex::new(Vec::new()),
        }
    }

    /// Coordinator backed by the AOT artifact at the default location
    /// (PJRT); errors surface at startup via the CPU fallback.
    pub fn with_artifact() -> Self {
        Self::with_config(CoordinatorConfig::default())
    }

    /// Coordinator backed by the pure-rust solver.
    pub fn cpu_only() -> Self {
        Self::with_config(CoordinatorConfig { backend: Backend::Cpu, ..Default::default() })
    }

    /// Artifact if present, CPU solver otherwise.
    pub fn auto() -> Self {
        Self::with_artifact()
    }

    /// Solve one encoded kernel through the windowed batching path.
    ///
    /// The first request into an empty window is the *leader*: it
    /// sleeps out the window, takes every request that joined
    /// meanwhile, and submits one executor job that maps them onto
    /// consecutive B=8 slots and answers each waiter on its own pooled
    /// channel. Followers just wait on their reply.
    pub fn solve_one(&self, enc: EncodedKernel) -> Result<SolveOut, SubmitError> {
        let (rtx, rrx) = self
            .single_pool
            .lock()
            .expect("single pool lock")
            .pop()
            .unwrap_or_else(|| mpsc::sync_channel(1));
        self.stats.queued.fetch_add(1, Ordering::Relaxed);
        let is_leader = {
            let mut pending = self.pending.lock().expect("pending lock");
            pending.push(PendingOne { enc, reply: rtx.clone() });
            pending.len() == 1
        };
        if is_leader {
            std::thread::sleep(self.window);
            let jobs: Vec<PendingOne> =
                std::mem::take(&mut *self.pending.lock().expect("pending lock"));
            self.stats.queued.fetch_sub(jobs.len() as u64, Ordering::Relaxed);
            // Senders the leader can still reach after the job closure
            // has consumed its own copies (for the failed-submit path).
            let notify: Vec<SyncSender<SingleReply>> =
                jobs.iter().map(|j| j.reply.clone()).collect();
            let on_panic_replies = notify.clone();
            let encs: Vec<EncodedKernel> = jobs.iter().map(|j| j.enc.clone()).collect();
            let senders: Vec<SyncSender<SingleReply>> =
                jobs.into_iter().map(|j| j.reply).collect();
            // How many waiters were already answered when a panic
            // unwound the job: `on_panic` must not push a stale error
            // into a channel whose waiter already took its output (the
            // channel would return to the pool poisoned).
            let done = Arc::new(AtomicUsize::new(0));
            let done_run = done.clone();
            let stats = self.stats.clone();
            let stats_panic = self.stats.clone();
            let job = exec::Job::new(move |backend: &mut SolverBackend| {
                let mut idx = 0;
                for chunk in encs.chunks(BATCH) {
                    let t0 = Instant::now();
                    let outs = run_backend(backend, chunk);
                    stats.batches.fetch_add(1, Ordering::Relaxed);
                    stats.batched_kernels.fetch_add(chunk.len() as u64, Ordering::Relaxed);
                    stats
                        .solve_micros
                        .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
                    for out in outs {
                        let _ = senders[idx].try_send(Ok(out));
                        idx += 1;
                        done_run.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
            .on_panic(move |category| {
                stats_panic.solver_restarts.fetch_add(1, Ordering::Relaxed);
                // One poisoned kernel fails its unanswered window-mates
                // too (outputs cannot be attributed), but every waiter
                // gets an answer instead of a timeout.
                let answered = done.load(Ordering::Relaxed);
                for s in on_panic_replies.iter().skip(answered) {
                    let _ = s.try_send(Err(SolveFailure::Panicked(category.to_string())));
                }
            });
            if self.exec.submit(Some(0), job).is_err() {
                for s in &notify {
                    let _ = s.try_send(Err(SolveFailure::Closed));
                }
            }
        }
        match rrx.recv_timeout(self.reply_timeout) {
            Ok(result) => {
                // Channel is drained: safe to reuse (a failure reply
                // drains it just like a success).
                let mut pool = self.single_pool.lock().expect("single pool lock");
                if pool.len() < POOL_CAP {
                    pool.push((rtx, rrx));
                }
                drop(pool);
                result.map_err(|f| match f {
                    SolveFailure::Panicked(category) => SubmitError::Panicked { category },
                    SolveFailure::Closed => SubmitError::Closed,
                })
            }
            Err(RecvTimeoutError::Timeout) => {
                // The reply may still arrive later; the channel is
                // stale and must not go back to the pool.
                Err(SubmitError::Timeout { waited: self.reply_timeout })
            }
            Err(RecvTimeoutError::Disconnected) => Err(SubmitError::Closed),
        }
    }

    /// Solve a whole submission in one executor job: the worker maps
    /// the kernels directly onto consecutive B=8 artifact slots (no
    /// batching-window wait, `ceil(n/8)` solver executions, one pooled
    /// reply channel). Returns outputs in submission order.
    pub fn solve_batch(&self, encs: Vec<EncodedKernel>) -> Result<Vec<SolveOut>, SubmitError> {
        if encs.is_empty() {
            return Ok(Vec::new());
        }
        let chunks = encs.len().div_ceil(BATCH) as u32;
        let (rtx, rrx) = self
            .batch_pool
            .lock()
            .expect("batch pool lock")
            .pop()
            .unwrap_or_else(|| mpsc::sync_channel(1));
        self.stats.queued.fetch_add(1, Ordering::Relaxed);
        let stats = self.stats.clone();
        let stats_panic = self.stats.clone();
        let reply = rtx.clone();
        let reply_panic = rtx.clone();
        let job = exec::Job::new(move |backend: &mut SolverBackend| {
            stats.queued.fetch_sub(1, Ordering::Relaxed);
            let mut outs = Vec::with_capacity(encs.len());
            for chunk in encs.chunks(BATCH) {
                let t0 = Instant::now();
                let res = run_backend(backend, chunk);
                stats.batches.fetch_add(1, Ordering::Relaxed);
                stats.batched_kernels.fetch_add(chunk.len() as u64, Ordering::Relaxed);
                stats.solve_micros.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
                outs.extend(res);
            }
            let _ = reply.try_send(Ok(outs));
        })
        .on_panic(move |category| {
            stats_panic.solver_restarts.fetch_add(1, Ordering::Relaxed);
            // A panic in any chunk fails the whole submission (outputs
            // must align with inputs) but the reply still arrives — the
            // submitter never deadlocks against a dead worker.
            let _ = reply_panic.try_send(Err(SolveFailure::Panicked(category.to_string())));
        });
        if self.exec.submit(Some(0), job).is_err() {
            self.stats.queued.fetch_sub(1, Ordering::Relaxed);
            return Err(SubmitError::Closed);
        }
        let timeout = self.reply_timeout.saturating_mul(chunks);
        match rrx.recv_timeout(timeout) {
            Ok(result) => {
                let mut pool = self.batch_pool.lock().expect("batch pool lock");
                if pool.len() < POOL_CAP {
                    pool.push((rtx, rrx));
                }
                drop(pool);
                result.map_err(|f| match f {
                    SolveFailure::Panicked(category) => SubmitError::Panicked { category },
                    SolveFailure::Closed => SubmitError::Closed,
                })
            }
            Err(RecvTimeoutError::Timeout) => Err(SubmitError::Timeout { waited: timeout }),
            Err(RecvTimeoutError::Disconnected) => Err(SubmitError::Closed),
        }
    }

    /// Analyze assembly source for `arch`: OSACA throughput analysis +
    /// critical path inline, balanced baseline through the batcher.
    ///
    /// Legacy shim — prefer `api::Engine::analyze`, which returns
    /// structured errors and composable passes.
    pub fn analyze_source(&self, name: &str, src: &str, arch: &str) -> Result<AnalysisResponse> {
        let machine =
            mdb::by_name_shared(arch).ok_or_else(|| anyhow!("unknown architecture `{arch}`"))?;
        let kernel = extract_kernel_isa(name, src, machine.isa)?;
        self.analyze_kernel(&kernel, &machine)
    }

    /// Analyze an already-extracted kernel.
    ///
    /// Legacy shim — prefer `api::Engine::analyze`.
    pub fn analyze_kernel(
        &self,
        kernel: &Kernel,
        machine: &MachineModel,
    ) -> Result<AnalysisResponse> {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let osaca = analyze(kernel, machine)?;
        let critpath = critical_path(kernel, machine)?;
        let enc = encode(kernel, machine)?;
        let out = self.solve_one(enc).map_err(|e| anyhow!("{e}"))?;
        let baseline = crate::baseline::to_prediction(&out);
        Ok(AnalysisResponse { osaca, baseline, critpath })
    }

    /// Messages currently waiting in the submission queue (see
    /// [`ServiceStats::queued`]).
    pub fn queue_depth(&self) -> u64 {
        self.stats.queued.load(Ordering::Relaxed)
    }

    /// Executor-level counters for the solver worker (queued /
    /// in-flight / panics / worker restarts). `ServiceStats` mirrors
    /// the legacy subset; this is the unified surface.
    pub fn exec_stats(&self) -> &ExecStats {
        self.exec.stats()
    }

    /// Graceful shutdown: close the submission queue (subsequent
    /// submissions return [`SubmitError::Closed`] instead of
    /// panicking) and join the solver worker, which finishes every
    /// job already queued before exiting. Idempotent; `Drop` calls
    /// it, so an explicit call is only needed to sequence the drain
    /// before other teardown.
    pub fn drain(&mut self) {
        self.exec.close();
        self.exec.join();
    }
}

fn run_backend(backend: &SolverBackend, encs: &[EncodedKernel]) -> Vec<SolveOut> {
    match backend {
        SolverBackend::Artifact(s) => match s.solve(encs) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("artifact solve failed ({e}); falling back to cpu");
                solve_cpu(encs, 32)
            }
        },
        SolverBackend::Cpu => solve_cpu(encs, 32),
    }
}

/// The redacted category every caught backend panic collapses to
/// (installed as the executor's `panic_label`). Panic payloads can
/// carry internal state (slice indices, model internals); they are
/// logged nowhere and never cross a channel.
const SOLVER_PANIC_CATEGORY: &str = "solver_panic";

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn cpu_coordinator_analyzes_triad() {
        let c = Coordinator::cpu_only();
        let w = workloads::find("triad", "skl", "-O3").unwrap();
        let r = c.analyze_source(&w.name(), w.source, "skl").unwrap();
        assert!((r.osaca.cy_per_asm_iter - 2.0).abs() < 0.01);
        assert!(r.baseline.cy_per_asm_iter <= r.osaca.cy_per_asm_iter + 0.25);
        assert_eq!(c.stats.requests.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn unknown_arch_is_error() {
        let c = Coordinator::cpu_only();
        assert!(c.analyze_source("x", ".L1:\naddl $1, %eax\njne .L1\n", "m1max").is_err());
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let c = Arc::new(Coordinator::cpu_only());
        let mut handles = Vec::new();
        for _ in 0..16 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let w = workloads::find("pi", "skl", "-O2").unwrap();
                c.analyze_source(&w.name(), w.source, "skl").unwrap().osaca.cy_per_asm_iter
            }));
        }
        for h in handles {
            let cy = h.join().unwrap();
            assert!((cy - 4.25).abs() < 0.01, "{cy}");
        }
        assert_eq!(c.stats.requests.load(Ordering::Relaxed), 16);
        // Batching must have coalesced at least some requests.
        assert!(c.stats.batches.load(Ordering::Relaxed) <= 16);
        assert!(c.stats.avg_batch_size() >= 1.0);
    }

    #[test]
    fn batch_submission_maps_onto_solver_slots() {
        let c = Coordinator::cpu_only();
        let w = workloads::find("triad", "skl", "-O3").unwrap();
        let machine = mdb::skylake();
        let enc = encode(&w.kernel(), &machine).unwrap();
        let outs = c.solve_batch(vec![enc; 20]).unwrap();
        assert_eq!(outs.len(), 20);
        // 20 kernels -> ceil(20/8) = 3 solver executions.
        assert_eq!(c.stats.batches.load(Ordering::Relaxed), 3);
        assert_eq!(c.stats.batched_kernels.load(Ordering::Relaxed), 20);
        let first = outs[0].tp_balanced;
        assert!(outs.iter().all(|o| (o.tp_balanced - first).abs() < 1e-6));
    }

    #[test]
    fn reply_channels_are_pooled() {
        let c = Coordinator::cpu_only();
        let w = workloads::find("triad", "skl", "-O3").unwrap();
        let machine = mdb::skylake();
        let enc = encode(&w.kernel(), &machine).unwrap();
        for _ in 0..4 {
            c.solve_one(enc.clone()).unwrap();
        }
        assert_eq!(c.single_pool.lock().unwrap().len(), 1);
        for _ in 0..3 {
            c.solve_batch(vec![enc.clone(); 2]).unwrap();
        }
        assert_eq!(c.batch_pool.lock().unwrap().len(), 1);
    }

    #[test]
    fn drained_coordinator_returns_closed_not_panic() {
        let mut c = Coordinator::cpu_only();
        let w = workloads::find("triad", "skl", "-O3").unwrap();
        let machine = mdb::skylake();
        let enc = encode(&w.kernel(), &machine).unwrap();
        assert!(c.solve_one(enc.clone()).is_ok());
        assert_eq!(c.queue_depth(), 0, "gauge returns to zero after dequeue");
        c.drain();
        c.drain(); // idempotent
        assert!(matches!(c.solve_one(enc.clone()), Err(SubmitError::Closed)));
        assert!(matches!(c.solve_batch(vec![enc]), Err(SubmitError::Closed)));
        assert_eq!(c.queue_depth(), 0);
    }

    #[test]
    fn solver_panic_is_contained_and_reported() {
        let c = Coordinator::cpu_only();
        let w = workloads::find("triad", "skl", "-O3").unwrap();
        let machine = mdb::skylake();
        let good = encode(&w.kernel(), &machine).unwrap();
        // An empty encoding drives solve_cpu out of bounds — a
        // deterministic stand-in for any backend bug.
        let poison = EncodedKernel { mask: Vec::new(), cost: Vec::new() };
        let err = c.solve_one(poison.clone()).unwrap_err();
        assert!(
            matches!(&err, SubmitError::Panicked { category } if category == "solver_panic"),
            "{err}"
        );
        assert!(err.to_string().contains("restarted"));
        assert_eq!(c.stats.solver_restarts.load(Ordering::Relaxed), 1);
        // The executor surface agrees with the legacy mirror.
        assert_eq!(c.exec_stats().panics.load(Ordering::Relaxed), 1);
        assert_eq!(c.exec_stats().worker_restarts.load(Ordering::Relaxed), 1);
        // The rebuilt backend keeps serving — both paths.
        assert!(c.solve_one(good.clone()).is_ok());
        let err = c.solve_batch(vec![good.clone(), poison]).unwrap_err();
        assert!(matches!(err, SubmitError::Panicked { .. }));
        assert_eq!(c.stats.solver_restarts.load(Ordering::Relaxed), 2);
        assert_eq!(c.solve_batch(vec![good; 3]).unwrap().len(), 3);
    }

    #[test]
    fn reply_timeout_is_configurable() {
        let c = Coordinator::with_config(CoordinatorConfig {
            backend: Backend::Cpu,
            reply_timeout: Duration::from_millis(250),
            ..Default::default()
        });
        assert_eq!(c.reply_timeout, Duration::from_millis(250));
        // Normal requests still complete well within it.
        let w = workloads::find("pi", "skl", "-O3").unwrap();
        let machine = mdb::skylake();
        let enc = encode(&w.kernel(), &machine).unwrap();
        assert!(c.solve_one(enc).is_ok());
    }
}
