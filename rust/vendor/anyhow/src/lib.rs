//! Minimal offline-compatible subset of the `anyhow` error-handling API.
//!
//! The build environment has no crates.io registry, so this vendored
//! shim provides exactly the surface the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] and [`bail!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`. The semantics
//! mirror the real crate closely enough that swapping the path
//! dependency for `anyhow = "1"` is a no-op for this codebase:
//!
//! * `Error` captures a chain of messages (innermost cause last);
//! * `Display` shows the outermost message, `{:#}` the full chain
//!   joined with `: `;
//! * `Error` deliberately does NOT implement `std::error::Error`, which
//!   is what makes the blanket `From<E: std::error::Error>` impl legal —
//!   the same trick the real crate uses.

use std::fmt;

/// A dynamically typed error with a context chain.
pub struct Error {
    /// `chain[0]` is the outermost message (latest context), the last
    /// element is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause message (innermost of the chain).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, matching anyhow's alternate format.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` alias defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and `None`s), the anyhow way.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err()).context("reading file").unwrap_err();
        assert_eq!(format!("{e}"), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: missing");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("nothing here").unwrap_err();
        assert_eq!(format!("{e}"), "nothing here");
    }

    #[test]
    fn macros_and_nested_context() {
        fn inner() -> Result<()> {
            bail!("inner {}", 42);
        }
        let e = inner().with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            let _: i32 = "zzz".parse()?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
