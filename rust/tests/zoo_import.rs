//! ISSUE-10: the model zoo end to end. The vendored uops.info-format
//! fixture compiles into `.mdb` models that register with the dynamic
//! registry, resolve under their curated aliases, and reproduce pinned
//! throughput predictions on the paper's validation kernels; malformed
//! inputs yield structured `bad_model_import` errors, never a panic.

use osaca::api::{Engine, OsacaError, Passes};
use osaca::mdb::{self, MachineModel};
use osaca::workloads;
use osaca::zoo;

const XML: &str = include_str!("fixtures/uops_trimmed.xml");

/// Analyze one embedded workload against `arch` and return the winning
/// model bound (cycles per assembly iteration, bound kind).
fn predict(engine: &Engine, arch: &str, family: &str, target: &str, flag: &str) -> (f32, String) {
    let w = workloads::find(family, target, flag)
        .unwrap_or_else(|| panic!("no workload {family}-{target}-{flag}"));
    let report = engine
        .analyze(
            &Engine::request(&w.name())
                .arch(arch)
                .source(w.source)
                .passes(Passes::THROUGHPUT)
                .unroll(w.unroll),
        )
        .unwrap_or_else(|e| panic!("{} on {arch}: {e}", w.name()));
    let p = report.prediction();
    let winner = p.winner().expect("throughput pass produces a model bound");
    (winner.cy_per_asm_iter, winner.kind.name().to_string())
}

#[test]
fn imported_models_register_and_reproduce_pinned_predictions() {
    // The fixture carries measurements for exactly the curated set.
    assert_eq!(zoo::arches_in(XML).unwrap(), vec!["CLX", "ICL", "ZEN2"]);
    for arch in zoo::curated_arches() {
        let name = zoo::import_and_register(XML, arch).expect(arch);
        assert_eq!(name, arch, "canonical short name is the curated key");
    }
    let engine = Engine::cpu_only();

    // Cascade Lake mirrors the built-in skl port model, so the paper's
    // Table-IV triad bound (2 cy: 6 load/store µ-ops over P2|P3) and
    // the π divider bound (16 cy: 2 × vdivpd-ymm at 8 divider cycles)
    // carry over exactly.
    let (cy, bound) = predict(&engine, "clx", "triad", "skl", "-O3");
    assert_eq!((cy, bound.as_str()), (2.0, "port_pressure"));
    let (cy, bound) = predict(&engine, "clx", "pi", "any", "-O3");
    assert_eq!((cy, bound.as_str()), (16.0, "divider"));

    // Ice Lake moves stores onto dedicated pipes (p49 data, p78 AGU),
    // leaving only the three 0.5-cy loads on P2|P3: 1.5 cy.
    let (cy, bound) = predict(&engine, "icl", "triad", "skl", "-O3");
    assert_eq!((cy, bound.as_str()), (1.5, "port_pressure"));

    // Zen 2 funnels every memory µ-op through three AGU pipes: 2 loads
    // + 1 folded load + a 2-µ-op store = 5 AGU µ-ops / 3 ports.
    let (cy, bound) = predict(&engine, "zen2", "triad", "zen", "-O3");
    assert!((cy - 5.0 / 3.0).abs() < 1e-3, "zen2 triad: {cy} ({bound})");
}

#[test]
fn curated_aliases_resolve_once_the_model_is_registered() {
    zoo::import_and_register(XML, "clx").expect("import clx");
    assert_eq!(mdb::canonical_arch("CascadeLake").as_deref(), Some("clx"));
    let engine = Engine::cpu_only();
    let m = engine.machine("CASCADELAKE").expect("alias resolves through the registry");
    assert_eq!(m.name, "clx");
    assert_eq!(m.arch_name, "Intel Cascade Lake");
}

#[test]
fn imported_text_round_trips_byte_identically() {
    for arch in zoo::curated_arches() {
        let imp = zoo::import_model(XML, arch).expect(arch);
        assert!(imp.entries > 0, "{arch}: no entries compiled");
        let reparsed = MachineModel::parse(&imp.text)
            .unwrap_or_else(|e| panic!("{arch}: emitted text failed to parse: {e:#}"));
        assert_eq!(
            reparsed.serialize(),
            imp.text,
            "{arch}: serialize∘parse must be the identity on emitted text"
        );
    }
}

#[test]
fn malformed_imports_are_structured_errors_never_panics() {
    // Truncated mid-tag: a structured error, localized to an XML line.
    let cut = &XML[..XML.len() / 2];
    match zoo::import_model(cut, "clx") {
        Err(OsacaError::BadModelImport { line, .. }) => {
            assert!(line.is_some(), "truncation should carry a line number");
        }
        Err(other) => panic!("wrong error kind: {other}"),
        Ok(_) => panic!("truncated XML imported cleanly"),
    }

    // An uncurated architecture lists what the overlay does know.
    let err = zoo::import_model(XML, "skx").unwrap_err();
    assert_eq!(err.kind_name(), "bad_model_import");
    let msg = err.to_string();
    assert!(msg.contains("clx") && msg.contains("zen2"), "{msg}");

    // Parseable XML with no measurements for the arch is an import
    // error too, not an empty model.
    let empty = "<root><instruction asm=\"NOP\" string=\"NOP\">\
                 <architecture name=\"CLX\"/></instruction></root>";
    let err = zoo::import_model(empty, "clx").unwrap_err();
    assert_eq!(err.kind_name(), "bad_model_import");

    // Assorted broken inputs: always Err, never a panic.
    for bad in ["<a", "<a attr=></a>", "<root><instruction></root>", "plain text"] {
        let err = zoo::import_model(bad, "clx").unwrap_err();
        assert_eq!(err.kind_name(), "bad_model_import", "input: {bad}");
    }
}
