//! ISSUE-5: the machine-readable report emitters.
//!
//! * Golden-file snapshots of the JSON and CSV emitters for one x86 and
//!   one RISC-V fixture (the rv64 one with the width-aware frontend
//!   bound on, so the full bound decomposition is pinned byte-for-byte),
//!   plus a memory-model-active snapshot (strided triad, `ws=4M`).
//! * A schema lock: the version-4 JSON key set is pinned, so changing
//!   the emitted shape without bumping `SCHEMA_VERSION` (and this test)
//!   fails CI.
//! * A hand-rolled JSON validity check over every workload fixture ×
//!   matching built-in model — the in-test half of ci.sh's
//!   `--format json | python3 -m json.tool` sweep.

use osaca::api::{AnalysisReport, BoundKind, Engine, Format, OsacaError, Passes, SCHEMA_VERSION};
use osaca::sim::SimConfig;
use osaca::workloads;

fn skl_triad_report(engine: &Engine) -> AnalysisReport {
    let w = workloads::find("triad", "skl", "-O3").unwrap();
    engine
        .analyze(
            &Engine::request(&w.name())
                .arch("skl")
                .source(w.source)
                .passes(Passes::THROUGHPUT)
                .unroll(w.unroll),
        )
        .unwrap()
}

fn rv64_triad_report(engine: &Engine) -> AnalysisReport {
    let w = workloads::find("triad", "rv64", "-O2").unwrap();
    engine
        .analyze(
            &Engine::request(&w.name())
                .arch("rv64")
                .source(w.source)
                .passes(Passes::THROUGHPUT | Passes::CRITPATH)
                .frontend_bound(true)
                .unroll(w.unroll),
        )
        .unwrap()
}

#[test]
fn json_golden_skl_triad() {
    let engine = Engine::cpu_only();
    let got = skl_triad_report(&engine).to_json();
    let want = include_str!("golden/skl_triad.json");
    assert_eq!(got.trim_end(), want.trim_end());
}

#[test]
fn json_golden_rv64_triad() {
    let engine = Engine::cpu_only();
    let got = rv64_triad_report(&engine).to_json();
    let want = include_str!("golden/rv64_triad.json");
    assert_eq!(got.trim_end(), want.trim_end());
}

#[test]
fn csv_golden_skl_triad() {
    let engine = Engine::cpu_only();
    let got = skl_triad_report(&engine).to_csv();
    let want = include_str!("golden/skl_triad.csv");
    assert_eq!(got.trim_end(), want.trim_end());
}

#[test]
fn csv_golden_rv64_triad() {
    let engine = Engine::cpu_only();
    let got = rv64_triad_report(&engine).to_csv();
    let want = include_str!("golden/rv64_triad.csv");
    assert_eq!(got.trim_end(), want.trim_end());
}

/// The memory-model-active shape, pinned byte-for-byte: the strided
/// triad with an L3-resident working set is memory-bound at the
/// hand-derived 40.0 cy / asm iteration, and the `memory` section
/// carries the ECM decomposition.
fn strided_mem_report(engine: &Engine) -> AnalysisReport {
    let w = workloads::find("triad-strided", "any", "-O3").unwrap();
    engine
        .analyze(
            &Engine::request(&w.name())
                .arch("skl")
                .source(w.source)
                .passes(Passes::THROUGHPUT)
                .mem_model("ws=4M")
                .unroll(w.unroll),
        )
        .unwrap()
}

#[test]
fn json_golden_strided_triad_mem() {
    let engine = Engine::cpu_only();
    let got = strided_mem_report(&engine).to_json();
    let want = include_str!("golden/skl_triad_mem.json");
    assert_eq!(got.trim_end(), want.trim_end());
}

#[test]
fn csv_golden_strided_triad_mem() {
    let engine = Engine::cpu_only();
    let got = strided_mem_report(&engine).to_csv();
    let want = include_str!("golden/skl_triad_mem.csv");
    assert_eq!(got.trim_end(), want.trim_end());
}

/// The version-5 key set: identical to v4 for the report emitters —
/// the v5 bump covers the serve wire surface (the `stats` frame's
/// `model_reloads` counter and the `reload_models` op), which
/// `serve_session.rs` pins; the report JSON shape itself carried over
/// unchanged. Changing the JSON shape requires bumping
/// `SCHEMA_VERSION` *and* pinning the new set here — one without the
/// other fails.
#[test]
fn schema_version_pins_json_shape() {
    const V5_KEYS: &[&str] = &[
        "arch",
        "baseline",
        "bottleneck_port",
        "bound",
        "bounds",
        "bytes_per_iter",
        "carried_per_iteration",
        "critpath",
        "cy_per_asm_iter",
        "cy_per_line",
        "cy_per_source_iter",
        "cycles_per_iteration",
        "ecm",
        "forwarded_loads",
        "frontend",
        "hidden",
        "instr",
        "intra_iteration",
        "isa",
        "issue_stall_cycles",
        "iterations",
        "kind",
        "level",
        "level_latency",
        "lines",
        "lines_per_iter",
        "lsq_size",
        "lsq_stall_cycles",
        "memory",
        "model_bound",
        "name",
        "occupancy",
        "prediction",
        "provenance",
        "rename_width",
        "resource",
        "schema_version",
        "simulation",
        "slots",
        "source",
        "streams",
        "text",
        "throughput",
        "totals",
        "uniform_cy",
        "unroll",
        "working_set",
    ];
    // This test pins version 5. A schema bump invalidates it by
    // construction: update SCHEMA_VERSION, this constant and the pinned
    // key list together.
    assert_eq!(SCHEMA_VERSION, 5, "schema bumped: re-pin the key set for the new version");
    // A report with every section present (all passes + frontend bound
    // + the opt-in memory model) must emit exactly the pinned keys.
    let engine = Engine::cpu_only();
    let w = workloads::find("triad", "skl", "-O3").unwrap();
    let report = engine
        .analyze(
            &Engine::request(&w.name())
                .arch("skl")
                .source(w.source)
                .passes(Passes::ALL)
                .frontend_bound(true)
                .mem_model("ws=4M")
                .sim_config(SimConfig { iterations: 120, warmup: 30 })
                .unroll(w.unroll),
        )
        .unwrap();
    assert!(report.baseline.is_some() && report.simulation.is_some());
    assert!(report.memory.is_some());
    let mut keys = json_keys(&report.to_json());
    keys.sort();
    keys.dedup();
    assert_eq!(keys, V5_KEYS, "JSON shape changed without a SCHEMA_VERSION bump");
}

/// Every fixture × matching built-in model emits valid JSON and
/// rectangular CSV (the library-side half of ci.sh's isa-smoke JSON
/// leg, which additionally round-trips through `python3 -m json.tool`).
#[test]
fn emitters_are_well_formed_for_every_fixture_and_model() {
    let engine = Engine::cpu_only();
    let mut checked = 0;
    for w in workloads::all_isa() {
        for arch in ["skl", "zen", "hsw", "tx2", "rv64"] {
            let model = engine.machine(arch).unwrap();
            if model.isa != w.isa {
                continue;
            }
            let report = match engine.analyze(
                &Engine::request(&w.name())
                    .arch(arch)
                    .source(w.source)
                    .passes(Passes::THROUGHPUT | Passes::CRITPATH)
                    .frontend_bound(true)
                    .unroll(w.unroll),
            ) {
                Ok(r) => r,
                // Cross-model x86 cases that genuinely cannot resolve
                // are not emitter bugs; the ci.sh sweep pins which
                // combinations must analyze.
                Err(OsacaError::UnresolvedForm { .. }) => continue,
                Err(e) => panic!("{}/{arch}: {e}", w.name()),
            };
            let json = report.to_json();
            validate_json(&json).unwrap_or_else(|e| panic!("{}/{arch}: {e}\n{json}", w.name()));
            let csv = report.to_csv();
            let mut lines = csv.lines();
            let header_cols = lines.next().unwrap().split(',').count();
            assert_eq!(header_cols, 9, "{}/{arch}: header arity", w.name());
            for l in lines {
                assert_eq!(
                    split_csv(l).len(),
                    header_cols,
                    "{}/{arch}: ragged CSV row `{l}`",
                    w.name()
                );
            }
            checked += 1;
        }
    }
    assert!(checked >= 16, "only {checked} fixture×model combinations checked");
}

#[test]
fn unknown_format_is_a_structured_error() {
    match Format::parse("yaml") {
        Err(OsacaError::UnsupportedFormat { requested, supported }) => {
            assert_eq!(requested, "yaml");
            assert!(supported.contains(&"json".to_string()));
        }
        other => panic!("expected UnsupportedFormat, got {other:?}"),
    }
}

/// `render()` follows the request's emitter selection.
#[test]
fn render_honors_requested_format() {
    let engine = Engine::cpu_only();
    let w = workloads::find("triad", "skl", "-O3").unwrap();
    let base = Engine::request(&w.name()).arch("skl").source(w.source).passes(Passes::THROUGHPUT);
    let text = engine.analyze(&base.clone()).unwrap();
    assert_eq!(text.format, Format::Text);
    assert!(text.render().starts_with("=== "));
    let json = engine.analyze(&base.clone().format(Format::Json)).unwrap();
    assert!(json.render().starts_with("{\"schema_version\":"));
    let csv = engine.analyze(&base.format(Format::Csv)).unwrap();
    assert!(csv.render().starts_with("schema_version,"));
}

/// Baseline and simulation enter the CSV as `observation` records, not
/// `bound`s — they never steer the prediction row.
#[test]
fn observations_are_labelled_in_csv() {
    let engine = Engine::cpu_only();
    let w = workloads::find("triad", "skl", "-O3").unwrap();
    let report = engine
        .analyze(
            &Engine::request(&w.name())
                .arch("skl")
                .source(w.source)
                .passes(Passes::ALL)
                .sim_config(SimConfig { iterations: 120, warmup: 30 })
                .unroll(w.unroll),
        )
        .unwrap();
    let csv = report.to_csv();
    assert!(csv.contains(",observation,baseline,"), "{csv}");
    assert!(csv.contains(",observation,simulated,"), "{csv}");
    assert!(csv.contains(",prediction,port_pressure,"), "{csv}");
    let p = report.prediction();
    assert!(!p.bound(BoundKind::Simulated).unwrap().kind.is_model_bound());
}

// ---------------------------------------------------------------------
// Minimal JSON machinery for the tests above (serde is not vendored).

/// Collect every object key (`"k":`) in the document.
fn json_keys(s: &str) -> Vec<String> {
    let bytes: Vec<char> = s.chars().collect();
    let mut keys = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == '"' {
            let start = i + 1;
            let mut j = start;
            while j < bytes.len() && bytes[j] != '"' {
                if bytes[j] == '\\' {
                    j += 1;
                }
                j += 1;
            }
            let content: String = bytes[start..j].iter().collect();
            let mut k = j + 1;
            while k < bytes.len() && bytes[k].is_whitespace() {
                k += 1;
            }
            if k < bytes.len() && bytes[k] == ':' {
                keys.push(content);
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    keys
}

/// Recursive-descent JSON validity check (objects, arrays, strings,
/// numbers, booleans, null). Returns the parse error position.
fn validate_json(s: &str) -> Result<(), String> {
    let b: Vec<char> = s.chars().collect();
    let mut pos = 0;
    skip_ws(&b, &mut pos);
    value(&b, &mut pos)?;
    skip_ws(&b, &mut pos);
    if pos != b.len() {
        return Err(format!("trailing garbage at {pos}"));
    }
    Ok(())
}

fn skip_ws(b: &[char], pos: &mut usize) {
    while *pos < b.len() && b[*pos].is_whitespace() {
        *pos += 1;
    }
}

fn value(b: &[char], pos: &mut usize) -> Result<(), String> {
    match b.get(*pos) {
        Some('{') => {
            *pos += 1;
            skip_ws(b, pos);
            if b.get(*pos) == Some(&'}') {
                *pos += 1;
                return Ok(());
            }
            loop {
                skip_ws(b, pos);
                string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, ':')?;
                skip_ws(b, pos);
                value(b, pos)?;
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(',') => *pos += 1,
                    Some('}') => {
                        *pos += 1;
                        return Ok(());
                    }
                    other => return Err(format!("expected , or }} at {pos}, got {other:?}")),
                }
            }
        }
        Some('[') => {
            *pos += 1;
            skip_ws(b, pos);
            if b.get(*pos) == Some(&']') {
                *pos += 1;
                return Ok(());
            }
            loop {
                skip_ws(b, pos);
                value(b, pos)?;
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(',') => *pos += 1,
                    Some(']') => {
                        *pos += 1;
                        return Ok(());
                    }
                    other => return Err(format!("expected , or ] at {pos}, got {other:?}")),
                }
            }
        }
        Some('"') => string(b, pos),
        Some('t') => literal(b, pos, "true"),
        Some('f') => literal(b, pos, "false"),
        Some('n') => literal(b, pos, "null"),
        Some(c) if *c == '-' || c.is_ascii_digit() => number(b, pos),
        other => Err(format!("unexpected {other:?} at {pos}")),
    }
}

fn string(b: &[char], pos: &mut usize) -> Result<(), String> {
    expect(b, pos, '"')?;
    while let Some(&c) = b.get(*pos) {
        *pos += 1;
        match c {
            '"' => return Ok(()),
            '\\' => {
                match b.get(*pos) {
                    Some('u') => {
                        for k in 1..=4 {
                            if !b.get(*pos + k).map(|c| c.is_ascii_hexdigit()).unwrap_or(false) {
                                return Err(format!("bad \\u escape at {pos}"));
                            }
                        }
                        *pos += 5;
                    }
                    Some('"' | '\\' | '/' | 'b' | 'f' | 'n' | 'r' | 't') => *pos += 1,
                    other => return Err(format!("bad escape {other:?} at {pos}")),
                }
            }
            c if (c as u32) < 0x20 => return Err(format!("raw control char at {pos}")),
            _ => {}
        }
    }
    Err("unterminated string".to_string())
}

fn number(b: &[char], pos: &mut usize) -> Result<(), String> {
    let start = *pos;
    if b.get(*pos) == Some(&'-') {
        *pos += 1;
    }
    let digits = |b: &[char], pos: &mut usize| {
        let s = *pos;
        while b.get(*pos).map(|c| c.is_ascii_digit()).unwrap_or(false) {
            *pos += 1;
        }
        *pos > s
    };
    if !digits(b, pos) {
        return Err(format!("bad number at {start}"));
    }
    if b.get(*pos) == Some(&'.') {
        *pos += 1;
        if !digits(b, pos) {
            return Err(format!("bad fraction at {start}"));
        }
    }
    if matches!(b.get(*pos), Some('e' | 'E')) {
        *pos += 1;
        if matches!(b.get(*pos), Some('+' | '-')) {
            *pos += 1;
        }
        if !digits(b, pos) {
            return Err(format!("bad exponent at {start}"));
        }
    }
    Ok(())
}

fn literal(b: &[char], pos: &mut usize, lit: &str) -> Result<(), String> {
    for c in lit.chars() {
        if b.get(*pos) != Some(&c) {
            return Err(format!("bad literal at {pos}, wanted `{lit}`"));
        }
        *pos += 1;
    }
    Ok(())
}

fn expect(b: &[char], pos: &mut usize, c: char) -> Result<(), String> {
    if b.get(*pos) == Some(&c) {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected `{c}` at {pos}, got {:?}", b.get(*pos)))
    }
}

/// Split one CSV line honoring RFC-4180 quoting.
fn split_csv(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut quoted = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if quoted => {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    quoted = false;
                }
            }
            '"' => quoted = true,
            ',' if !quoted => fields.push(std::mem::take(&mut cur)),
            c => cur.push(c),
        }
    }
    fields.push(cur);
    fields
}
