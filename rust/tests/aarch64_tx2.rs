//! End-to-end pinned numbers for the AArch64 (ThunderX2) backend: the
//! multi-ISA frontend parses the ARM fixtures, the `tx2` machine model
//! resolves them, and analyzer/critpath/simulator agree on the
//! designed bottlenecks. Also pins zero cross-ISA resolution-cache
//! pollution when x86 and AArch64 kernels alternate.

use osaca::analyzer::{analyze, critical_path};
use osaca::api::{Engine, OsacaError, Passes};
use osaca::isa::Isa;
use osaca::mdb::{by_name, thunderx2};
use osaca::sim::{simulate, SimConfig};
use osaca::workloads;

fn cfg() -> SimConfig {
    SimConfig { iterations: 600, warmup: 150 }
}

fn approx(a: f32, b: f32) -> bool {
    (a - b).abs() < 0.011
}

/// Triad, 128-bit ASIMD: 2 loads + 1 store AGU on the two LS pipes
/// -> 1.5 cy per assembly iteration (0.75 cy per source iteration).
#[test]
fn triad_tx2_analyzer_pinned() {
    let w = workloads::find("triad", "tx2", "-O2").unwrap();
    let m = thunderx2();
    let a = analyze(&w.kernel(), &m).unwrap();
    assert!(approx(a.cy_per_asm_iter, 1.5), "{}", a.cy_per_asm_iter);
    assert!(approx(a.cy_per_source_it(2), 0.75));
    for port in ["LS0", "LS1"] {
        let p = m.port_index(port).unwrap();
        assert!(approx(a.totals[p], 1.5), "{port}: {}", a.totals[p]);
    }
    let sd = m.port_index("SD").unwrap();
    assert!(approx(a.totals[sd], 1.0), "SD: {}", a.totals[sd]);
    for port in ["F0", "F1"] {
        let p = m.port_index(port).unwrap();
        assert!(approx(a.totals[p], 0.5), "{port}: {}", a.totals[p]);
    }
    for port in ["I0", "I1"] {
        let p = m.port_index(port).unwrap();
        assert!(approx(a.totals[p], 1.0), "{port}: {}", a.totals[p]);
    }
    // The branch row is blank.
    assert!(a.lines.last().unwrap().occupancy.iter().all(|&x| x == 0.0));
}

/// Triad latency structure: no loop-carried FP chain (v0 is re-loaded
/// every iteration), so the carried bound is the 1-cycle counter chain;
/// intra-iteration chain is load(4) + fmla(6) + store-data(1).
#[test]
fn triad_tx2_critpath_pinned() {
    let w = workloads::find("triad", "tx2", "-O2").unwrap();
    let r = critical_path(&w.kernel(), &thunderx2()).unwrap();
    assert!((r.carried_per_iteration - 1.0).abs() < 1e-3, "{r:?}");
    assert!((r.intra_iteration - 11.0).abs() < 1e-3, "{r:?}");
}

/// Simulated triad: LS pipes and the 4-wide frontend both bound the
/// loop at 1.5 cy/asm-iter; no store-to-load forwarding (three
/// distinct streams).
#[test]
fn triad_tx2_simulated() {
    let w = workloads::find("triad", "tx2", "-O2").unwrap();
    let m = simulate(&w.kernel(), &thunderx2(), cfg()).unwrap();
    assert!(
        (1.4..1.7).contains(&m.cycles_per_iteration),
        "{}",
        m.cycles_per_iteration
    );
    assert_eq!(m.counters.forwarded_loads, 0);
    let cy_it = m.cy_per_source_it(2);
    assert!((0.7..0.85).contains(&cy_it), "{cy_it}");
}

/// π at -O1: the non-pipelined divide (DV busy 16 cy) dominates both
/// the 3-per-pipe FP pressure and the 6-cycle sum recurrence.
#[test]
fn pi_tx2_analyzer_divider_bound() {
    let w = workloads::find("pi", "tx2", "-O1").unwrap();
    let m = thunderx2();
    let a = analyze(&w.kernel(), &m).unwrap();
    assert!(approx(a.cy_per_asm_iter, 16.0), "{}", a.cy_per_asm_iter);
    assert_eq!(m.ports[a.bottleneck_port], "DV");
}

/// π latency structure: the sum recurrence (fadd, 6 cy) is the carried
/// bound; the in-iteration chain threads five 6-cycle FP ops and the
/// 23-cycle divide.
#[test]
fn pi_tx2_critpath_pinned() {
    let w = workloads::find("pi", "tx2", "-O1").unwrap();
    let r = critical_path(&w.kernel(), &thunderx2()).unwrap();
    assert!((r.carried_per_iteration - 6.0).abs() < 1e-3, "{r:?}");
    assert!((r.intra_iteration - 59.0).abs() < 1e-3, "{r:?}");
}

/// Simulated π: divider-serialized at ~16 cy/iter, like the x86 π
/// kernels are at their own divider periods (Table V's shape).
#[test]
fn pi_tx2_simulated() {
    let w = workloads::find("pi", "tx2", "-O1").unwrap();
    let m = simulate(&w.kernel(), &thunderx2(), cfg()).unwrap();
    assert!(
        (15.5..16.6).contains(&m.cycles_per_iteration),
        "{}",
        m.cycles_per_iteration
    );
    assert_eq!(m.counters.forwarded_loads, 0);
}

/// The whole Engine pipeline works on an AArch64 request: `.arch("tx2")`
/// selects the AArch64 syntax automatically, and throughput + critpath
/// + simulate all run from one decode.
#[test]
fn engine_end_to_end_tx2() {
    let engine = Engine::cpu_only();
    let w = workloads::find("triad", "tx2", "-O2").unwrap();
    let req = Engine::request(&w.name())
        .arch("tx2")
        .source(w.source)
        .passes(Passes::THROUGHPUT | Passes::CRITPATH | Passes::SIMULATE)
        .unroll(w.unroll)
        .sim_config(cfg());
    let report = engine.analyze(&req).unwrap();
    let t = report.throughput.as_ref().unwrap();
    assert!(approx(t.cy_per_asm_iter, 1.5), "{}", t.cy_per_asm_iter);
    assert!(report.critpath.is_some());
    let sim = report.simulation.as_ref().unwrap();
    assert!((1.4..1.7).contains(&sim.cycles_per_iteration), "{}", sim.cycles_per_iteration);
    assert!(approx(report.predicted_cy_per_asm_iter().unwrap(), 1.5));
    assert!(approx(report.predicted_cy_per_source_it().unwrap(), 0.75));
    let json = report.to_json();
    assert!(json.contains("\"arch\":\"tx2\""));
    assert!(json.contains("\"throughput\""));
    assert!(json.contains("\"simulation\""));
}

/// The engine lists tx2 among the available architectures and rejects
/// ISA-mismatched requests with a structured error.
#[test]
fn isa_mismatch_is_structured() {
    let engine = Engine::cpu_only();
    assert!(engine.available_arches().contains(&"tx2".to_string()));
    // An x86 kernel explicitly handed to the tx2 model.
    let xk = workloads::find("triad", "skl", "-O3").unwrap().kernel();
    let req = Engine::request("mismatch").arch("tx2").kernel(xk);
    match engine.analyze(&req) {
        Err(OsacaError::IsaMismatch { kernel_isa, model_isa, arch }) => {
            assert_eq!(kernel_isa, "x86");
            assert_eq!(model_isa, "aarch64");
            assert_eq!(arch, "tx2");
        }
        other => panic!("expected IsaMismatch, got {other:?}"),
    }
    // Forcing the x86 syntax on an AArch64 model is the same mismatch.
    let w = workloads::find("triad", "skl", "-O3").unwrap();
    let req = Engine::request("mismatch2").arch("tx2").isa(Isa::X86).source(w.source);
    assert!(matches!(engine.analyze(&req), Err(OsacaError::IsaMismatch { .. })));
}

/// Compare-and-branch forms are not macro-fused, so they pre-validate:
/// an unmodeled one is a structured UnresolvedForm, not a stringly
/// pass-time failure; a modeled one analyzes fine.
#[test]
fn compare_branch_validation_is_structured() {
    let engine = Engine::cpu_only();
    // cbnz on an FP register has no tx2 entry (and no hardware
    // meaning) — prepare() must catch it.
    let req = Engine::request("cb")
        .arch("tx2")
        .source("\n.L1:\nadd x4, x4, #1\ncbnz d0, .L1\n")
        .passes(Passes::THROUGHPUT | Passes::SIMULATE);
    match engine.analyze(&req) {
        Err(OsacaError::UnresolvedForm { form, arch, .. }) => {
            assert!(form.contains("cbnz"), "{form}");
            assert_eq!(arch, "tx2");
        }
        other => panic!("expected UnresolvedForm, got {other:?}"),
    }
    // The modeled cbnz form runs end to end, and the analyzer charges
    // it on the I pipes exactly like the simulator executes it:
    // add + sub + cbnz = 3 integer µ-ops on 2 pipes = 1.5 cy/iter.
    let req = Engine::request("cb2")
        .arch("tx2")
        .source("\n.L1:\nldr q0, [x7, x4]\nadd x4, x4, #16\nsub x5, x5, #2\ncbnz x5, .L1\n")
        .passes(Passes::THROUGHPUT | Passes::SIMULATE)
        .sim_config(cfg());
    let report = engine.analyze(&req).unwrap();
    let t = report.throughput.as_ref().unwrap();
    assert!(approx(t.cy_per_asm_iter, 1.5), "{}", t.cy_per_asm_iter);
    let sim = report.simulation.as_ref().unwrap();
    assert!((1.4..1.7).contains(&sim.cycles_per_iteration), "{}", sim.cycles_per_iteration);
}

/// Cross-ISA cache hygiene: alternating x86-on-skl and AArch64-on-tx2
/// analyses perform zero fresh form resolutions once warm, and a
/// foreign-ISA instruction can never resolve against the other model
/// (the x86 suffix/split/mem synthesis tiers are gated off for ARM).
#[test]
fn form_index_has_no_cross_isa_pollution() {
    let skl = by_name("skl").unwrap();
    let tx2 = by_name("tx2").unwrap();
    let xk = workloads::find("triad", "skl", "-O3").unwrap().kernel();
    let ak = workloads::find("triad", "tx2", "-O2").unwrap().kernel();
    let sim_cfg = SimConfig { iterations: 60, warmup: 15 };
    // Warm both models.
    analyze(&xk, &skl).unwrap();
    simulate(&xk, &skl, sim_cfg).unwrap();
    analyze(&ak, &tx2).unwrap();
    simulate(&ak, &tx2, sim_cfg).unwrap();
    let skl_misses = skl.resolution_miss_count();
    let tx2_misses = tx2.resolution_miss_count();
    // The AArch64 fixture resolves entirely from direct entries: no
    // synthesis may ever run for it.
    assert_eq!(tx2_misses, 0, "ARM forms must be direct hits");
    for _ in 0..3 {
        analyze(&xk, &skl).unwrap();
        analyze(&ak, &tx2).unwrap();
        simulate(&xk, &skl, sim_cfg).unwrap();
        simulate(&ak, &tx2, sim_cfg).unwrap();
    }
    assert_eq!(skl.resolution_miss_count(), skl_misses, "x86 misses moved");
    assert_eq!(tx2.resolution_miss_count(), tx2_misses, "ARM misses moved");
    // Foreign-ISA instructions are rejected outright — x86 suffix/split
    // rules can never fire on ARM forms and vice versa.
    assert!(tx2.resolve(&xk.instructions[0]).is_err());
    assert!(skl.resolve(&ak.instructions[0]).is_err());
    assert_eq!(skl.resolution_miss_count(), skl_misses);
    assert_eq!(tx2.resolution_miss_count(), tx2_misses);
}
